"""Multi-device semantics (MoE fabric sharding, compressed pod protocol,
dry-run smoke) — run in subprocesses so the main session keeps 1 device."""

import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

sys.path.insert(0, os.path.join(REPO, "src"))
from repro import compat  # noqa: E402

needs_partial_manual = pytest.mark.skipif(
    not compat.has_partial_manual_shard_map(),
    reason="partial-manual shard_map (pod protocol) unsupported on jax<=0.4.x")


def _run(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", code], env=env, cwd=REPO,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_moe_fabric_sharded_equals_single_device():
    """The switch-fabric MoE must be invariant to the mesh layout."""
    _run("""
import jax, jax.numpy as jnp, numpy as np
from repro.launch.mesh import compat_make_mesh
from repro.models.config import ModelConfig, ShardingPlan
from repro.models.moe import init_moe, apply_moe, MoEOptions
cfg = ModelConfig(name="t", family="moe", n_layers=1, d_model=128, n_heads=4,
                  n_kv_heads=2, d_ff=256, vocab=512, moe_experts=8, moe_topk=2,
                  capacity_factor=8.0)   # no drops -> layouts must agree exactly
plan = ShardingPlan()
params, _ = init_moe(jax.random.PRNGKey(0), cfg, plan)
x = jax.random.normal(jax.random.PRNGKey(1), (8, 32, 128), jnp.float32).astype(jnp.bfloat16)
outs = []
for shape in [(1, 1), (2, 4), (4, 2), (8, 1)]:
    mesh = compat_make_mesh(shape, ("data", "model"))
    y, aux = apply_moe(params, cfg, plan, mesh, x)
    outs.append(np.asarray(y.astype(jnp.float32)))
for o in outs[1:]:
    np.testing.assert_allclose(outs[0], o, atol=3e-2)
print("fabric mesh-invariant OK")
""")


@needs_partial_manual
def test_compressed_pod_protocol_close_to_exact_mean():
    _run("""
import jax, jax.numpy as jnp, numpy as np
from repro.launch.mesh import compat_make_mesh
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.comm.protocols import compressed_mean
mesh = compat_make_mesh((2, 2, 2), ("pod", "data", "model"))
g = jax.random.normal(jax.random.PRNGKey(0), (2, 4, 256), jnp.float32)

def f(g):
    # the pod-sharded input already differs per pod member (axis_index over a
    # manual axis is unsupported under 0.4.x partial-manual shard_map)
    local = g * (1.0 + jnp.abs(g).mean())          # pod-varying gradients
    exact = jax.lax.pmean(local, "pod")
    comp = compressed_mean({"g": local}, "pod")["g"]
    return exact, comp
from repro import compat
exact, comp = jax.jit(compat.shard_map(f, mesh, axis_names={"pod"},
                                       in_specs=P("pod"), out_specs=(P(), P()),
                                       check=False))(g)
err = float(jnp.abs(exact - comp).max())
scale = float(jnp.abs(exact).max())
assert err < 0.02 * scale, (err, scale)
print("compressed pod mean OK", err)
""")


@needs_partial_manual
def test_train_step_with_compressed_pod_grads_runs():
    _run("""
import jax, jax.numpy as jnp, numpy as np
from repro.launch.mesh import compat_make_mesh
from repro.configs import get_smoke
from repro.models.config import MULTI_POD_PLAN
from repro.models import transformer as T
from repro.train import adamw, make_train_step, TrainSpec
from repro.data import DataConfig, SyntheticLM
mesh = compat_make_mesh((2, 2, 2), ("pod", "data", "model"))
cfg = get_smoke("llama3.2-1b")
plan = MULTI_POD_PLAN
params, _ = T.init_params(jax.random.PRNGKey(0), cfg, plan)
opt = adamw(lr=1e-3)
for compress in (False, True):
    ts = jax.jit(make_train_step(cfg, plan, mesh, opt,
                                 TrainSpec(compress_pod_grads=compress)))
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=4))
    batch = {k: jnp.asarray(v) for k, v in data.batch(0).items()}
    p, o, m = ts(params, opt.init(params), batch, jnp.asarray(0))
    assert np.isfinite(float(m["loss"]))
print("compressed-grad train step OK")
""")


def test_dryrun_single_cell_smoke():
    """The actual dry-run entry point on the 512-device production mesh."""
    out = _run("""
import sys
sys.argv = ["dryrun", "--arch", "llama3.2-1b", "--shape", "decode_32k",
            "--mesh", "single", "--out", "/tmp/test_dryrun"]
import shutil; shutil.rmtree("/tmp/test_dryrun", ignore_errors=True)
from repro.launch.dryrun import main
try:
    main()
except SystemExit as e:
    assert e.code == 0, "dry-run cell failed"
""", devices=512)
