"""Serving-path contracts: the shared slot array and the DSE serve engine.

Two layers share one slot discipline (``repro.serve.slots.SlotArray``): the
token server (``repro.serve.engine``) and the continuously-batched DSE
service (``repro.api.service``).  This file locks the slot semantics —
FIFO admission into the lowest free slot, loud duplicate-rid rejection,
exactly-once completion-ordered harvest — and the service's headline
contract: a served report is bit-identical to ``run_scenario`` on the same
(scenario, seed), modulo the volatile ``*_time_s`` keys, no matter how
requests interleave, chunk, pad, dedup, or shard across a device mesh.
"""

import json
import os
import subprocess
import sys

import pytest

from repro.api import registry, run_scenario
from repro.api.service import (Client, DSEServeEngine, request_key,
                               strip_times)
from repro.serve.slots import SlotArray

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _tiny(name, **over):
    return registry[name].override(back_annotation=False, top_k=2,
                                   trace_params={"duration_s": 8e-5}, **over)


# --------------------------------------------------------------------------
# SlotArray semantics
# --------------------------------------------------------------------------

def test_slot_array_fifo_admission_and_reuse():
    sa = SlotArray(2)
    for rid in ("a", "b", "c", "d"):
        sa.submit(rid, rid.upper())
    assert sa.queued == 4 and len(sa) == 0     # admission is lazy
    assert [(s, r) for s, r, _ in sa.admit()] == [(0, "a"), (1, "b")]
    assert len(sa) == 2 and sa.queued == 2
    assert sa.admit() == []                    # batch full: nothing admits
    sa.finish(0)                               # slot 0 frees ...
    assert [(s, r) for s, r, _ in sa.admit()] == [(0, "c")]  # ... and is reused
    sa.finish(1)
    sa.finish(0)
    assert [(s, r) for s, r, _ in sa.admit()] == [(0, "d")]
    sa.finish(0)
    assert sa.drained
    # harvest is completion-ordered and exactly-once
    assert sa.harvest() == ["A", "B", "C", "D"]
    assert sa.harvest() == []


def test_slot_array_duplicate_rid_rejected_loudly():
    sa = SlotArray(1)
    sa.submit(7, "x")
    with pytest.raises(ValueError, match="already in flight"):
        sa.submit(7, "y")                      # still queued
    sa.admit()
    with pytest.raises(ValueError, match="already in flight"):
        sa.submit(7, "y")                      # now active
    sa.finish(0)
    with pytest.raises(ValueError, match="already in flight"):
        sa.submit(7, "y")                      # finished, awaiting harvest
    assert sa.harvest() == ["x"]
    sa.submit(7, "z")                          # harvested: rid is free again
    assert [(s, r) for s, r, _ in sa.admit()] == [(0, 7)]


def test_slot_array_finish_guards():
    sa = SlotArray(2)
    sa.submit("a", 1)
    sa.admit()
    with pytest.raises(ValueError):
        sa.finish(1)                           # free slot
    sa.finish(0)
    with pytest.raises(ValueError):
        sa.finish(0)                           # double finish


def test_slot_array_validates_width():
    with pytest.raises(ValueError):
        SlotArray(0)


# --------------------------------------------------------------------------
# DSE serve engine: determinism, caching, interleaving
# --------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny_hft():
    return _tiny("hft")


@pytest.fixture(scope="module")
def hft_golden(tiny_hft):
    """The standalone single-scenario report every served variant must hit."""
    return strip_times(run_scenario(tiny_hft).to_dict())


def test_serve_matches_run_scenario_and_caches(tiny_hft, hft_golden):
    cli = Client(slots=2, batch_width=16, verify_width=4)
    first = cli.result(cli.submit(tiny_hft))
    assert strip_times(first) == hft_golden
    # repeat request: answered from the report cache, byte-identical
    rep = cli.submit(tiny_hft)
    assert cli.result(rep) == first
    assert rep.cached
    st = cli.engine.stats()
    assert st["report_hits"] == 1 and st["report_misses"] == 1
    assert st["stage2_rows"] > 0 and st["stage2_chunks"] > 0


def test_serve_inflight_twins_compute_once(tiny_hft):
    """Identical concurrent requests cost ONE computation: the twin waits on
    the in-flight original and is served from the cache when it lands."""
    eng = DSEServeEngine(slots=4, batch_width=16, verify_width=4)
    a = eng.submit(tiny_hft)
    b = eng.submit(tiny_hft)
    done = eng.run_until_drained()
    assert {r.rid for r in done} == {a.rid, b.rid}
    assert a.report == b.report
    assert b.cached and not a.cached
    st = eng.stats()
    assert st["report_misses"] == 1 and st["report_hits"] == 1


def test_serve_seed_is_part_of_request_identity(tiny_hft):
    assert request_key(tiny_hft) != request_key(
        tiny_hft.override(trace_params={"seed": 3}))
    cli = Client(slots=2, batch_width=16, verify_width=4)
    base = cli.result(cli.submit(tiny_hft))
    other = cli.result(cli.submit(tiny_hft, seed=3))
    st = cli.engine.stats()
    assert st["report_misses"] == 2 and st["report_hits"] == 0
    # different trace seed -> different workload -> (at least) distinct keys
    assert base["scenario"] != other["scenario"]


def test_serve_interleaved_scenarios_stay_deterministic(tiny_hft, hft_golden):
    """hft and datacenter requests sharing the engine — different problems,
    different chunk groups — each still reproduce their standalone report."""
    tiny_dc = _tiny("datacenter")
    dc_golden = strip_times(run_scenario(tiny_dc).to_dict())
    eng = DSEServeEngine(slots=4, batch_width=16, verify_width=4)
    reqs = [eng.submit(s) for s in (tiny_hft, tiny_dc, tiny_hft, tiny_dc)]
    done = eng.run_until_drained()
    assert len(done) == 4 and all(r.report is not None for r in done)
    for req, want in zip(reqs, (hft_golden, dc_golden) * 2):
        assert strip_times(req.report) == want
    st = eng.stats()
    assert st["report_misses"] == 2 and st["report_hits"] == 2
    assert st["problem_entries"] == 2


def test_serve_nsga2_search_matches_run_scenario():
    """Search-mode requests: stage 2 is the generational ask/tell loop fed
    chunk-at-a-time by the engine — the front must not move."""
    from repro.api.scenario import SearchSpec
    spec = _tiny("hft", search=SearchSpec(population=8, generations=3, seed=0))
    want = strip_times(run_scenario(spec).to_dict())
    cli = Client(slots=2, batch_width=16, verify_width=4)
    got = cli.result(cli.submit(spec))
    assert strip_times(got) == want


def test_serve_use_kernel_on_matches_run_scenario():
    spec = _tiny("hft", use_kernel="on")
    want = strip_times(run_scenario(spec).to_dict())
    cli = Client(slots=2, batch_width=16, verify_width=4)
    got = cli.result(cli.submit(spec))
    assert strip_times(got) == want


def test_serve_two_device_mesh_bit_identical(tiny_hft, hft_golden):
    """Serving with every chunk sharded over 2 (simulated host) devices must
    reproduce the single-device standalone report exactly."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", """
import json
from repro.api import registry
from repro.api.service import Client, strip_times
tiny = registry["hft"].override(back_annotation=False, top_k=2,
                                trace_params={"duration_s": 8e-5})
cli = Client(slots=2, batch_width=16, verify_width=4, mesh=2)
print(json.dumps(strip_times(cli.result(cli.submit(tiny)))))
"""], env=env, cwd=REPO, capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-4000:]
    got = json.loads(out.stdout.strip().splitlines()[-1])
    assert got == hft_golden


def test_serve_bad_request_errors_without_killing_service(tiny_hft,
                                                          monkeypatch):
    import repro.api.service as service
    eng = DSEServeEngine(slots=2, batch_width=16, verify_width=4)
    with pytest.raises(KeyError):
        eng.submit("no-such-scenario")         # unknown names fail loudly
    # a spec that cannot build must fail its own request only: poison the
    # problem builder for one submit, then serve a healthy request after
    real = service.build_problem
    monkeypatch.setattr(service, "build_problem",
                        lambda *a, **k: (_ for _ in ()).throw(
                            RuntimeError("boom")))
    bad = eng.submit(tiny_hft, seed=99)
    while not bad.done:
        eng.step()
    assert bad.error is not None and "boom" in bad.error
    monkeypatch.setattr(service, "build_problem", real)
    ok = eng.submit(tiny_hft)
    done = eng.run_until_drained()
    assert ok in done and ok.report is not None
    assert eng.counters["errors"] == 1


def test_serve_cli_smoke(tmp_path):
    """``spac serve`` end to end: repeats hit the cache, JSON lands on disk."""
    from repro.api.cli import main
    out = tmp_path / "served.json"
    rc = main(["serve", "hft", "--repeat", "2", "--slots", "2",
               "--batch-width", "16", "--verify-width", "4",
               "--out", str(out)])
    assert rc == 0
    payload = json.loads(out.read_text())
    assert len(payload["requests"]) == 2
    assert payload["stats"]["report_misses"] == 1
    assert payload["stats"]["report_hits"] == 1
    a, b = payload["requests"]
    assert strip_times(a["report"]) == strip_times(b["report"])
