"""Model zoo: 10-arch smoke, MoE fabric invariants, decode consistency."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_arch_names, get_config, get_smoke, shapes_for
from repro.data import make_batch_for_shape
from repro.models import SINGLE_POD_PLAN, MoEOptions
from repro.models import transformer as T
from repro.models.moe import apply_moe, init_moe

PLAN = SINGLE_POD_PLAN
B, S = 2, 64


def _batch(cfg, rng):
    batch = {"labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)}
    if cfg.frontend == "tokens":
        batch["tokens"] = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    else:
        batch["embeddings"] = jnp.asarray(rng.normal(0, 1, (B, S, cfg.d_model)),
                                          jnp.bfloat16)
    return batch


@pytest.mark.parametrize("name", all_arch_names())
def test_smoke_forward_grad_decode(name, mesh11):
    """REQUIRED smoke: reduced config, one forward/train step, shapes + no NaN."""
    cfg = get_smoke(name)
    params, specs = T.init_params(jax.random.PRNGKey(0), cfg, PLAN)
    batch = _batch(cfg, np.random.default_rng(0))
    logits, aux = T.forward(params, cfg, PLAN, mesh11, batch)
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    loss, metrics = T.loss_fn(params, cfg, PLAN, mesh11, batch)
    assert jnp.isfinite(loss) and 3.0 < float(loss) < 12.0
    g = jax.grad(lambda p: T.loss_fn(p, cfg, PLAN, mesh11, batch)[0])(params)
    leaves = jax.tree.leaves(g)
    assert all(bool(jnp.isfinite(l.astype(jnp.float32)).all()) for l in leaves)
    assert any(float(jnp.abs(l.astype(jnp.float32)).max()) > 0 for l in leaves)
    state, _ = T.init_decode_state(cfg, PLAN, B, 128)
    tok = batch.get("tokens")
    inp = tok[:, :1] if tok is not None else batch["embeddings"][:, :1]
    state, logits1 = T.decode_step(params, cfg, PLAN, mesh11, state, inp)
    assert logits1.shape == (B, 1, cfg.vocab)
    assert bool(jnp.isfinite(logits1.astype(jnp.float32)).all())
    assert int(state["pos"]) == 1


@pytest.mark.parametrize("name", ["llama3.2-1b", "mamba2-780m", "hymba-1.5b"])
def test_prefill_matches_forward_last_token(name, mesh11):
    """prefill() last-token logits == forward() logits at the last position."""
    cfg = dataclasses.replace(get_smoke(name), remat="none")
    params, _ = T.init_params(jax.random.PRNGKey(0), cfg, PLAN)
    batch = _batch(cfg, np.random.default_rng(1))
    logits_all, _ = T.forward(params, cfg, PLAN, mesh11, batch,
                              window=cfg.sliding_window)
    logits_last, state = T.prefill(params, cfg, PLAN, mesh11, batch)
    np.testing.assert_allclose(
        np.asarray(logits_last.astype(jnp.float32)),
        np.asarray(logits_all[:, -1].astype(jnp.float32)), atol=2e-2, rtol=2e-2)


def test_decode_matches_forward_teacher_forcing(mesh11):
    """Running decode_step over a short prompt reproduces forward() logits."""
    cfg = dataclasses.replace(get_smoke("llama3.2-1b"), remat="none")
    params, _ = T.init_params(jax.random.PRNGKey(0), cfg, PLAN)
    rng = np.random.default_rng(2)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, 8)), jnp.int32)
    logits_fwd, _ = T.forward(params, cfg, PLAN, mesh11, {"tokens": toks})
    state, _ = T.init_decode_state(cfg, PLAN, B, 16)
    outs = []
    for t in range(8):
        state, lg = T.decode_step(params, cfg, PLAN, mesh11, state, toks[:, t:t + 1])
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec.astype(jnp.float32)),
                               np.asarray(logits_fwd.astype(jnp.float32)),
                               atol=3e-2, rtol=3e-2)


# -------------------------------------------------------------- MoE fabric

def _moe_cfg(**kw):
    base = dict(name="m", family="moe", n_layers=1, d_model=128, n_heads=4,
                n_kv_heads=2, d_ff=256, vocab=512, moe_experts=8, moe_topk=2)
    base.update(kw)
    from repro.models.config import ModelConfig
    return ModelConfig(**base)


def test_moe_capacity_factor_controls_drops(mesh11):
    cfg = _moe_cfg()
    params, _ = init_moe(jax.random.PRNGKey(0), cfg, PLAN)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 64, 128), jnp.bfloat16)
    drops = []
    for cf in (0.5, 1.0, 2.0):
        _, aux = apply_moe(params, cfg, PLAN, mesh11, x, MoEOptions(capacity_factor=cf))
        drops.append(float(aux["drop_frac"]))
    assert drops[0] >= drops[1] >= drops[2]
    assert drops[2] <= 0.05


def test_moe_int8_payload_close_to_bf16(mesh11):
    cfg = _moe_cfg()
    params, _ = init_moe(jax.random.PRNGKey(0), cfg, PLAN)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 64, 128), jnp.bfloat16)
    y1, _ = apply_moe(params, cfg, PLAN, mesh11, x, MoEOptions())
    y2, _ = apply_moe(params, cfg, PLAN, mesh11, x, MoEOptions(payload="int8"))
    rel = float(jnp.abs(y1.astype(jnp.float32) - y2.astype(jnp.float32)).max())
    scale = float(jnp.abs(y1.astype(jnp.float32)).max())
    assert rel < 0.1 * max(scale, 1e-3)


def test_moe_chunked_schedule_is_equivalent(mesh11):
    cfg = _moe_cfg()
    params, _ = init_moe(jax.random.PRNGKey(0), cfg, PLAN)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 64, 128), jnp.bfloat16)
    y1, _ = apply_moe(params, cfg, PLAN, mesh11, x, MoEOptions(a2a_chunks=1))
    y2, _ = apply_moe(params, cfg, PLAN, mesh11, x, MoEOptions(a2a_chunks=4))
    np.testing.assert_allclose(np.asarray(y1.astype(jnp.float32)),
                               np.asarray(y2.astype(jnp.float32)), atol=1e-2)


def test_moe_hash_router_is_deterministic_and_balanced(mesh11):
    cfg = _moe_cfg(router="hash")
    params, _ = init_moe(jax.random.PRNGKey(0), cfg, PLAN)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 64, 128), jnp.bfloat16)
    _, a1 = apply_moe(params, cfg, PLAN, mesh11, x, MoEOptions(router="hash"))
    _, a2 = apply_moe(params, cfg, PLAN, mesh11, x, MoEOptions(router="hash"))
    np.testing.assert_array_equal(np.asarray(a1["expert_load"]),
                                  np.asarray(a2["expert_load"]))
    load = np.asarray(a1["expert_load"], float)
    assert load.sum() == 4 * 64 * cfg.moe_topk     # every token routed k times
    assert load.max() < load.sum() * 0.6           # no total collapse


def test_moe_grads_flow_through_fabric(mesh11):
    cfg = _moe_cfg()
    params, _ = init_moe(jax.random.PRNGKey(0), cfg, PLAN)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 128), jnp.bfloat16)

    def loss(p):
        y, _ = apply_moe(p, cfg, PLAN, mesh11, x)
        return jnp.mean(y.astype(jnp.float32) ** 2)

    g = jax.grad(loss)(params)
    assert float(jnp.linalg.norm(g["w1"].astype(jnp.float32))) > 0
    assert float(jnp.linalg.norm(g["router"])) > 0


# ------------------------------------------------------------ M-RoPE / window

def test_mrope_distinct_positions_change_logits(mesh11):
    cfg = get_smoke("qwen2-vl-72b")
    params, _ = T.init_params(jax.random.PRNGKey(0), cfg, PLAN)
    rng = np.random.default_rng(3)
    emb = jnp.asarray(rng.normal(0, 1, (B, S, cfg.d_model)), jnp.bfloat16)
    base = np.broadcast_to(np.arange(S, dtype=np.int32)[None], (B, S))
    p_text = jnp.asarray(np.stack([base, base, base], 1))
    grid = np.stack([base, base // 8, base % 8], 1).astype(np.int32)
    p_img = jnp.asarray(grid)
    l1, _ = T.forward(params, cfg, PLAN, mesh11, {"embeddings": emb, "positions3": p_text})
    l2, _ = T.forward(params, cfg, PLAN, mesh11, {"embeddings": emb, "positions3": p_img})
    assert float(jnp.abs(l1.astype(jnp.float32) - l2.astype(jnp.float32)).max()) > 1e-3


def test_sliding_window_limits_attention(mesh11):
    from repro.models.attention import plain_attention
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 2, 32, 16))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 2, 32, 16))
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 2, 32, 16))
    full = plain_attention(q, k, v, causal=True)
    win = plain_attention(q, k, v, causal=True, window=4)
    assert float(jnp.abs(full - win).max()) > 1e-4  # genuinely different
    # early tokens (pos < window) identical
    np.testing.assert_allclose(np.asarray(full[:, :, :4]), np.asarray(win[:, :, :4]),
                               atol=1e-5)
