"""The fidelity-ladder registry, the verify_engine knob, and the escalation
policy ("auto" verifies the front with batched netsim, the champion with the
cycle-accurate datapath)."""

import math

import numpy as np
import pytest

from repro.core import (ArchRequest, ResourceBudget, SLA, SchedulerKind,
                        SwitchArch, ForwardTableKind, VOQKind, bind,
                        compressed_protocol, run_dse)
from repro.core.dse import VerifyResult
from repro.sim import ENGINES, get_engine, ladder, run_netsim
from repro.sim.switch_problem import SwitchDSEProblem
from repro.traces import hft, uniform

BOUND = bind(compressed_protocol(addr_bits=4, length_bits=6), flit_bits=256)


def _arch(**kw):
    base = dict(n_ports=4, bus_bits=256, fwd=ForwardTableKind.FULL_LOOKUP,
                voq=VOQKind.NXN, sched=SchedulerKind.RR, voq_depth=64,
                addr_bits=4)
    base.update(kw)
    return SwitchArch(**base)


# ------------------------------------------------------------------ registry

def test_ladder_is_complete_and_ordered():
    names = {"analytic", "surrogate", "batched_surrogate", "netsim",
             "batched_netsim", "cycle"}
    assert names <= set(ENGINES)
    rungs = [e.rung for e in ladder()]
    assert rungs == sorted(rungs)
    assert ladder()[0].name == "analytic" and ladder()[-1].name == "cycle"
    # the batched rungs advertise native batch forms, the serial ones do not
    assert get_engine("batched_netsim").batched
    assert get_engine("batched_surrogate").batched
    assert not get_engine("netsim").batched
    assert not get_engine("cycle").batched


def test_unknown_engine_raises():
    with pytest.raises(KeyError, match="unknown engine"):
        get_engine("hdl_simulation")


@pytest.mark.parametrize("name", ["analytic", "surrogate", "batched_surrogate",
                                  "netsim", "batched_netsim"])
def test_engine_contract(name):
    """Every rung takes (arch, bound, trace) and returns a VerifyResult with
    finite metrics on a live trace; batch results index-align."""
    tr = uniform(seed=0, n_ports=4, duration_s=30e-6, load=0.3, payload=256)
    eng = get_engine(name)
    archs = [_arch(), _arch(bus_bits=512)]
    v = eng.evaluate(archs[0], BOUND, tr)
    assert isinstance(v, VerifyResult)
    assert math.isfinite(v.p99_latency_ns)
    assert v.throughput_gbps > 0
    assert v.meta["engine"] in (name, "surrogate", "netsim", "batched_netsim")
    vs = eng.evaluate_batch(archs, BOUND, tr)
    assert len(vs) == 2
    assert all(isinstance(x, VerifyResult) for x in vs)


def test_surrogate_rungs_report_infinite_buffers():
    tr = uniform(seed=0, n_ports=4, duration_s=30e-6, load=0.3, payload=256)
    for name in ("surrogate", "batched_surrogate"):
        assert get_engine(name).evaluate(_arch(), BOUND, tr).drop_rate == 0.0


def test_batched_rungs_match_their_serial_rung():
    tr = uniform(seed=0, n_ports=4, duration_s=30e-6, load=0.6, payload=256)
    a = _arch(voq_depth=4)
    vn = get_engine("netsim").evaluate(a, BOUND, tr)
    vb = get_engine("batched_netsim").evaluate(a, BOUND, tr)
    assert vb.drop_rate == vn.drop_rate
    np.testing.assert_array_equal(vb.meta["latency_ns"], vn.meta["latency_ns"])


def test_cycle_engine_smoke():
    tr = uniform(seed=2, n_ports=4, duration_s=30e-6, load=0.4, payload=256)
    v = get_engine("cycle").evaluate(_arch(sched=SchedulerKind.ISLIP), BOUND, tr)
    assert v.meta["engine"] == "cycle"
    assert math.isfinite(v.p99_latency_ns) and v.throughput_gbps > 0


# ------------------------------------------------------- verify_engine knob

def _small_problem(verify_engine):
    tr = uniform(seed=2, n_ports=4, duration_s=30e-6, load=0.4, payload=256)
    return SwitchDSEProblem(ArchRequest(n_ports=4, addr_bits=4), BOUND, tr,
                            back_annotation=False, verify_engine=verify_engine)


def _switch_span_ns(v, trace):
    """Netsim latency minus the host-side constants (mean NIC serialisation +
    both propagation hops) — closer to the span the cycle-accurate datapath
    measures.  Netsim additionally holds ports for the egress-link occupancy
    (wire/link·η) where the cycle sim serialises flits at f_clk, so cross-
    fidelity latency comparisons stay order-of-magnitude, not tight."""
    wire = np.asarray(trace.payload_bytes) + BOUND.header_bytes
    offset = wire.mean() * 8 / (trace.link_gbps * 1e9) + 2 * 50e-9
    return v.mean_latency_ns - offset * 1e9


def test_unknown_verify_engine_rejected():
    with pytest.raises(ValueError, match="verify_engine"):
        _small_problem("rtl")


def test_auto_escalates_only_the_champion():
    """"auto" ranks with batched netsim (identical front to "netsim") and
    attaches a cycle-accurate verdict to the champion only, landing within a
    model-fidelity tolerance of the netsim metrics."""
    sla = SLA(p99_latency_ns=math.inf, drop_rate=1e-3)
    budget = ResourceBudget({"luts": 870_000, "ffs": 1_740_000, "brams": 1_344,
                             "bram": 1_344})
    res_auto = run_dse(_small_problem("auto"), sla, budget, top_k=2)
    res_net = run_dse(_small_problem("netsim"), sla, budget, top_k=2)
    assert res_auto.best.short() == res_net.best.short()
    assert sorted(a.short() for a, _ in res_auto.pareto) == \
           sorted(a.short() for a, _ in res_net.pareto)
    # only the champion carries the escalated cycle-sim verdict
    esc = res_auto.best_verify.meta["escalated"]
    assert esc.meta["engine"] == "cycle"
    others = [v for a, v, _, _ in res_auto.evaluated if a is not res_auto.best]
    assert all("escalated" not in v.meta for v in others)
    assert "escalated" not in res_net.best_verify.meta
    # the cycle-accurate champion metrics corroborate netsim (Fig.6-style
    # cross-fidelity tolerance on the switch-internal span, not bit equality:
    # netsim additionally counts host NIC serialisation + propagation)
    span = _switch_span_ns(res_auto.best_verify, _small_problem("auto").trace)
    assert 0.1 < esc.mean_latency_ns / span < 10.0
    assert esc.drop_rate <= sla.drop_rate * 1.5
    assert 0.5 < esc.throughput_gbps / res_auto.best_verify.throughput_gbps < 2.0


def test_cycle_verify_engine_runs_rung4_for_all_survivors():
    sla = SLA(p99_latency_ns=math.inf, drop_rate=5e-2)
    budget = ResourceBudget({"luts": 870_000, "ffs": 1_740_000, "brams": 1_344,
                             "bram": 1_344})
    res = run_dse(_small_problem("cycle"), sla, budget, top_k=1)
    assert res.best is not None
    assert all(v.meta["engine"] == "cycle" for _, v, _, _ in res.evaluated)
    # cross-fidelity sanity vs the netsim verdict on the same candidate
    tr = _small_problem("cycle").trace
    vn = run_netsim(res.best, BOUND, tr, back_annotation=False)
    assert 0.1 < res.best_verify.mean_latency_ns / _switch_span_ns(vn, tr) < 10.0
    assert 0.5 < res.best_verify.throughput_gbps / vn.throughput_gbps < 2.0


# ------------------------------------------------------------ API surface

def test_fidelity_verify_engine_round_trips():
    from repro.api import Scenario, registry
    from repro.api.scenario import Fidelity
    import dataclasses
    assert Fidelity().verify_engine == "netsim"
    with pytest.raises(ValueError, match="verify_engine"):
        Fidelity(verify_engine="spice")
    s = registry["hft"]
    s2 = dataclasses.replace(s, fidelity=Fidelity(back_annotation=False,
                                                  verify_engine="auto"))
    rt = Scenario.from_json(s2.to_json())
    assert rt == s2 and rt.fidelity.verify_engine == "auto"
    assert s.override(verify_engine="cycle").fidelity.verify_engine == "cycle"
    # overriding other knobs must not clobber the engine choice
    assert s2.override(top_k=3).fidelity.verify_engine == "auto"


def test_cli_verify_engine_flag():
    from repro.api.cli import build_parser
    args = build_parser().parse_args(
        ["run", "hft", "--verify-engine", "auto"])
    assert args.verify_engine == "auto"
    with pytest.raises(SystemExit):
        build_parser().parse_args(["run", "hft", "--verify-engine", "rtl"])


def test_run_scenario_reports_stage4_throughput():
    from repro.api import registry, run_scenario
    s = registry["underwater"].override(back_annotation=False, top_k=2,
                                        trace_params={"duration_s": 4e-4})
    rep = run_scenario(s)
    assert rep.stage4_candidates >= 1
    assert rep.stage4_time_s > 0
    d = rep.to_dict()
    assert d["stage4_candidates"] == rep.stage4_candidates
    assert d["stage2_candidates"] == rep.stage2_candidates


def test_campaign_batches_stage4_and_reports_throughput():
    from repro.api import registry, run_campaign
    base = registry["underwater"].override(back_annotation=False, top_k=2,
                                           trace_params={"duration_s": 4e-4})
    import dataclasses
    twin = dataclasses.replace(base, name="underwater_twin")
    camp = run_campaign([base, twin], name="stage4-batch")
    # two scenarios share (trace, bound, engine): one batched verify call
    assert camp.stage4_batches == 1
    assert camp.stage4_candidates == sum(r.stage4_candidates
                                         for r in camp.reports)
    assert camp.stage4_cands_per_sec > 0
    assert "stage4_cands_per_sec" in camp.to_dict()
    # campaign results identical to solo runs (batch rows are independent)
    from repro.api import run_scenario
    solo = run_scenario(base)
    assert [a.short() for a, _ in camp.reports[0].pareto] == \
           [a.short() for a, _ in solo.pareto]
