"""Generational NSGA-II search engine: determinism matrix, checkpoint/resume,
search-quality acceptance, CLI surface.

The determinism contract under test (ISSUE 4):

  * same ``SearchSpec.seed`` ⇒ bit-identical front and ``DSEResult``,
  * fresh-vs-resumed-from-checkpoint runs are bit-identical,
  * ``verify_engine="netsim"`` and ``"auto"`` produce the identical Pareto
    front (escalation only annotates the champion's meta),
  * a checkpoint round-trip restores the RNG state exactly.

Acceptance bar: on the enlarged (>=1024-point) hft space, NSGA-II reaches
>=95% of the exhaustive front's hypervolume while evaluating <=25% of the
space.
"""

import dataclasses
import json
import math

import numpy as np
import pytest

from repro.core import (ArchRequest, ResourceBudget, SLA, bind,
                        compressed_protocol, pareto_front, run_dse)
from repro.core.pareto import hypervolume_2d
from repro.core.search import (NSGA2Search, SearchDriver, SearchSpec,
                               constrained_non_dominated_sort,
                               crowding_distance, evaluate_space,
                               load_search_state, run_search,
                               save_search_state)
from repro.sim.resources import ALVEO_U45N
from repro.sim.switch_problem import SwitchDSEProblem
from repro.traces import hft

BOUND = bind(compressed_protocol(addr_bits=4, length_bits=6), flit_bits=256)
SLA_HFT = SLA(p99_latency_ns=5000, drop_rate=1e-3)
BUDGET = ResourceBudget(dict(ALVEO_U45N))


def _problem(duration_s=8e-5, **kw):
    return SwitchDSEProblem(ArchRequest(n_ports=8, addr_bits=4), BOUND,
                            hft(seed=0, duration_s=duration_s),
                            back_annotation=False, **kw)


def _shorts(valid):
    return [c.short() for c, _ in valid]


# --------------------------------------------------------------------------
# design space
# --------------------------------------------------------------------------

def test_switch_space_is_parameterized_and_large():
    space = _problem().space()
    assert space.size() >= 1024                 # the enlarged joint space
    assert set(space.signature()) == {
        "bus_bits", "fwd", "voq", "sched", "islip_iters", "hash_banks",
        "hash_depth"}
    # explicit request policies collapse to single-choice dimensions
    from repro.core.archspec import SchedulerKind
    prob = SwitchDSEProblem(
        ArchRequest(n_ports=8, addr_bits=4, bus_bits=256,
                    sched=SchedulerKind.RR),
        BOUND, hft(seed=0, duration_s=8e-5), back_annotation=False)
    sig = prob.space().signature()
    assert sig["bus_bits"] == 1 and sig["sched"] == 1


def test_comm_space_and_decode():
    """The comm problem's space/decode are pure: no fabric build needed."""
    from repro.comm.dse_comm import CommDSEProblem, CommSpec
    space = CommDSEProblem.space(None)
    assert space.size() == 24
    assert set(space.signature()) == {"payload", "a2a_chunks", "microbatches"}
    c = CommDSEProblem.decode(None, space.assignment((1, 2, 0)))
    assert c == CommSpec(capacity_factor=2.0, payload="int8", a2a_chunks=4,
                         microbatches=1)


def test_decode_canonicalises_inert_genes():
    prob = _problem()
    space = prob.space()
    names = [d.name for d in space.dims]
    base = {d.name: d.choices[0] for d in space.dims}
    from repro.core.archspec import ForwardTableKind, SchedulerKind
    base["sched"] = SchedulerKind.RR
    base["fwd"] = ForwardTableKind.FULL_LOOKUP
    a = prob.decode({**base, "islip_iters": 1, "hash_banks": 2,
                     "hash_depth": 128})
    b = prob.decode({**base, "islip_iters": 4, "hash_banks": 8,
                     "hash_depth": 512})
    assert a == b                               # inert genes -> one phenotype
    assert names == list(space.signature())


# --------------------------------------------------------------------------
# NSGA-II primitives (example-based twins of the hypothesis properties)
# --------------------------------------------------------------------------

def test_constrained_sort_feasible_dominates_infeasible():
    objs = np.array([[1.0, 1.0], [0.0, 0.0], [2.0, 2.0]])
    viol = np.array([0.0, 5.0, 0.0])
    ranks = constrained_non_dominated_sort(objs, viol)
    assert ranks[0] == 0                        # feasible front
    assert ranks[2] == 1                        # dominated feasible
    assert ranks[1] == 2                        # infeasible ranks last
    # two infeasible points order by violation alone
    ranks2 = constrained_non_dominated_sort(
        np.array([[0.0, 0.0], [9.0, 9.0]]), np.array([2.0, 1.0]))
    assert ranks2[1] < ranks2[0]


def test_crowding_distance_boundaries_infinite():
    objs = np.array([[0.0, 3.0], [1.0, 2.0], [2.0, 1.0], [3.0, 0.0]])
    d = crowding_distance(objs)
    assert math.isinf(d[0]) and math.isinf(d[3])
    assert np.all(np.isfinite(d[1:3]))


def test_plateau_clock_waits_for_first_feasible_point():
    """An all-infeasible run must exhaust its generations, not stop after
    ``patience`` 0->0 "plateaus" while still hunting feasibility."""
    from repro.core.search import DesignSpace, Dim
    space = DesignSpace((Dim("x", tuple(range(8))), Dim("y", tuple(range(8)))))
    eng = NSGA2Search(space, SearchSpec(population=8, generations=6, seed=0,
                                        patience=2))
    while not eng.done:
        asked = eng.ask()
        eng.tell({g: ((float(g[0]), float(g[1])), 1.0 + g[0]) for g in asked})
    assert eng.generation == 6                  # ran the full budget
    assert eng.archive() == [] and eng.hv_history == [0.0] * 6


def test_engine_same_seed_bit_identical_and_hv_monotone():
    """Engine-level twin of the hypothesis NSGA-II invariants, on a cheap
    synthetic objective (no problem, no surrogate)."""
    from repro.core.search import DesignSpace, Dim
    space = DesignSpace(tuple(
        Dim(f"x{i}", tuple(range(8))) for i in range(4)))

    def objective(g):
        f1 = float(sum(g))
        f2 = float(sum((7 - x) ** 2 for x in g))
        return (f1, f2)

    def drive(seed):
        eng = NSGA2Search(space, SearchSpec(population=16, generations=8,
                                            seed=seed, patience=100))
        while not eng.done:
            asked = eng.ask()
            eng.tell({g: (objective(g), 0.0) for g in asked})
        return eng

    a, b = drive(11), drive(11)
    assert a.front() == b.front()               # bit-identical
    assert a.hv_history == b.hv_history
    hist = a.hv_history
    assert all(h2 >= h1 - 1e-12 for h1, h2 in zip(hist, hist[1:]))
    c = drive(12)
    assert c.hv_history[-1] > 0.0               # different seed still works


# --------------------------------------------------------------------------
# determinism matrix
# --------------------------------------------------------------------------

def test_same_seed_identical_dse_result():
    spec = SearchSpec(population=16, generations=5, seed=3)
    res1 = run_dse(_problem(), SLA_HFT, BUDGET, search=spec, top_k=4)
    res2 = run_dse(_problem(), SLA_HFT, BUDGET, search=spec, top_k=4)
    assert res1.best == res2.best
    assert [a.short() for a, _ in res1.pareto] == [a.short() for a, _ in res2.pareto]
    assert [(v.p99_latency_ns, v.drop_rate) for _, v, _, _ in res1.evaluated] \
        == [(v.p99_latency_ns, v.drop_rate) for _, v, _, _ in res2.evaluated]
    assert [(lg.stage, lg.considered, lg.survived, tuple(lg.notes))
            for lg in res1.logs] \
        == [(lg.stage, lg.considered, lg.survived, tuple(lg.notes))
            for lg in res2.logs]


def test_identical_across_verify_engines():
    """netsim vs auto: escalation annotates meta, never the ranking."""
    spec = SearchSpec(population=16, generations=4, seed=2)
    res_n = run_dse(_problem(verify_engine="netsim"), SLA_HFT, BUDGET,
                    search=spec, top_k=3)
    res_a = run_dse(_problem(verify_engine="auto"), SLA_HFT, BUDGET,
                    search=spec, top_k=3)
    assert res_n.best == res_a.best
    assert [a.short() for a, _ in res_n.pareto] \
        == [a.short() for a, _ in res_a.pareto]
    assert res_a.best_verify.meta.get("escalated") is not None
    assert res_n.best_verify.meta.get("escalated") is None


def test_resume_matches_uninterrupted(tmp_path):
    spec = SearchSpec(population=16, generations=6, seed=4, patience=100)
    prob = _problem()
    full = run_search(prob, spec, SLA_HFT)
    ck = str(tmp_path / "ck")
    part = run_search(_problem(), spec, SLA_HFT, checkpoint_dir=ck,
                      max_generations_this_run=2)
    assert part.generations == 2                # genuinely interrupted
    resumed = run_search(_problem(), spec, SLA_HFT, checkpoint_dir=ck,
                         resume=True)
    assert resumed.resumed
    assert resumed.generations == full.generations
    assert _shorts(resumed.valid) == _shorts(full.valid)
    assert resumed.hv_history == full.hv_history
    # and the full DSE result is identical either way
    res_full = run_dse(_problem(), SLA_HFT, BUDGET, search=spec, top_k=3)
    res_res = run_dse(_problem(), SLA_HFT, BUDGET, search=spec, top_k=3,
                      checkpoint_dir=ck, resume=True)
    assert res_full.best == res_res.best
    assert [a.short() for a, _ in res_full.pareto] \
        == [a.short() for a, _ in res_res.pareto]


def test_checkpoint_roundtrip_restores_rng_state_exactly(tmp_path):
    spec = SearchSpec(population=12, generations=5, seed=8, patience=100)
    prob = _problem()
    driver = SearchDriver(prob, spec, SLA_HFT)
    for _ in range(2):
        driver.tell_candidates(prob.surrogate_batch(driver.ask_candidates()))
    ck = str(tmp_path / "ck")
    save_search_state(ck, driver.engine)
    eng = load_search_state(ck, prob.space(), spec)
    assert eng.rng.bit_generator.state == driver.engine.rng.bit_generator.state
    # the next draws are bit-identical too
    assert eng.rng.integers(1 << 30, size=8).tolist() \
        == driver.engine.rng.integers(1 << 30, size=8).tolist()
    # full engine state round-trips
    assert eng.parents == driver.engine.parents
    assert eng.pending == driver.engine.pending
    assert eng.cache == driver.engine.cache
    assert eng.hv_history == driver.engine.hv_history
    assert eng.ref == driver.engine.ref


def test_resume_warns_when_checkpoint_dir_is_empty(tmp_path):
    """A mistyped --checkpoint-dir must not silently restart from gen 0."""
    spec = SearchSpec(population=8, generations=1, seed=0)
    with pytest.warns(RuntimeWarning, match="no search checkpoint"):
        out = run_search(_problem(), spec, SLA_HFT,
                         checkpoint_dir=str(tmp_path / "nope"), resume=True)
    assert not out.resumed


def test_resume_validates_spec_and_space(tmp_path):
    spec = SearchSpec(population=12, generations=4, seed=1)
    prob = _problem()
    ck = str(tmp_path / "ck")
    run_search(prob, spec, SLA_HFT, checkpoint_dir=ck,
               max_generations_this_run=1)
    with pytest.raises(ValueError, match="SearchSpec differs"):
        load_search_state(ck, prob.space(),
                          dataclasses.replace(spec, seed=2))
    other = SwitchDSEProblem(
        ArchRequest(n_ports=8, addr_bits=4, bus_bits=256), BOUND,
        hft(seed=0, duration_s=8e-5), back_annotation=False)
    with pytest.raises(ValueError, match="design space differs"):
        load_search_state(ck, other.space(), spec)


# --------------------------------------------------------------------------
# acceptance: search quality vs exhaustive on the enlarged hft space
# --------------------------------------------------------------------------

def test_nsga2_hits_exhaustive_hypervolume_within_budget():
    """>=95% of the exhaustive front's hypervolume with <=25% of the space
    evaluated (the ISSUE 4 acceptance bar, also reported by
    ``benchmarks/search_quality.py`` into BENCH_dse.json)."""
    prob = _problem(duration_s=4e-4)            # the full Table-II hft trace
    space = prob.space()
    assert space.size() >= 1024
    ex = evaluate_space(prob, SLA_HFT)
    ref = tuple(float(x) for x in ex.objectives.max(axis=0) * 1.1 + 1e-9)
    hv_ex = hypervolume_2d(ex.front_objectives(), ref)
    assert hv_ex > 0

    budget = space.size() // 4
    spec = SearchSpec(population=48, generations=10, seed=0,
                      max_evaluations=budget)
    out = run_search(prob, spec, SLA_HFT)
    assert out.evaluations <= budget
    assert out.surrogate_rows <= budget
    objs = np.asarray([prob.surrogate_objectives(c, sr)
                       for c, sr in out.valid], float)
    keep = pareto_front(list(range(len(objs))), key=lambda i: tuple(objs[i]))
    hv_s = hypervolume_2d(objs[keep], ref)
    assert hv_s >= 0.95 * hv_ex, (
        f"NSGA-II reached {hv_s / hv_ex:.3f} of exhaustive hypervolume "
        f"({out.surrogate_rows}/{space.size()} evaluations)")


# --------------------------------------------------------------------------
# API + CLI surface
# --------------------------------------------------------------------------

def test_scenario_search_roundtrip_bit_for_bit():
    from repro.api import Scenario, registry
    s = registry["hft"].override(
        search=SearchSpec(population=20, generations=6, seed=5,
                          max_evaluations=200, checkpoint_dir="ckpt/hft"))
    assert Scenario.from_json(s.to_json()) == s
    d = json.loads(s.to_json())
    assert d["search"]["algorithm"] == "nsga2"
    assert d["search"]["max_evaluations"] == 200
    # dropping the search key round-trips to exhaustive mode
    assert Scenario.from_dict(registry["hft"].to_dict()).search is None


def test_campaign_locksteps_search_scenarios_with_solo_parity():
    from repro.api import registry, run_campaign, run_scenario
    spec = SearchSpec(population=12, generations=3, seed=5)
    base = registry["hft"].override(back_annotation=False, top_k=2,
                                    trace_params={"duration_s": 8e-5},
                                    search=spec)
    relaxed = base.override(name="hft_relaxed", sla_p99_latency_ns=1e6,
                            search=SearchSpec(population=12, generations=3,
                                              seed=6))
    campaign = run_campaign([base, relaxed], name="lockstep")
    assert campaign.shared_trace_scenarios == 1
    # generational lockstep: one batched call per generation, both engines
    assert campaign.stage2_batches <= spec.generations
    for s in (base, relaxed):
        solo = run_scenario(s)
        batched = campaign[s.name]
        assert batched.best == solo.best
        assert [a.short() for a, _ in batched.pareto] \
            == [a.short() for a, _ in solo.pareto]


def test_cli_search_run_and_resume(tmp_path, capsys):
    from repro.api.cli import main
    ck = str(tmp_path / "ck")
    args = ["run", "hft", "--duration-s", "8e-05", "--no-back-annotation",
            "--top-k", "2", "--search", "nsga2", "--generations", "3",
            "--population", "8", "--search-seed", "1",
            "--checkpoint-dir", ck,
            "--out", str(tmp_path / "report.json")]
    assert main(args) == 0
    first = capsys.readouterr().out
    assert "search-nsga2" in first
    report = json.loads((tmp_path / "report.json").read_text())
    assert report["scenario"]["search"]["seed"] == 1
    assert any(st["stage"] == "search-nsga2" for st in report["stages"])
    # resuming from the finished checkpoint reproduces the identical result
    assert main(args + ["--resume"]) == 0
    second = capsys.readouterr().out
    assert first.splitlines()[1:] == second.splitlines()[1:]


def test_cli_search_flags_require_search():
    from repro.api.cli import main
    with pytest.raises(SystemExit, match="--search"):
        main(["run", "hft", "--generations", "3"])


def test_error_messages_name_problem_and_shapes():
    from repro.core.dse import stage2_screen, stage4_verify

    class Broken(SwitchDSEProblem):
        def surrogate_batch(self, archs):
            return super().surrogate_batch(archs)[:-1]

        def verify_batch(self, archs):
            return super().verify_batch(archs)[:-1]

    prob = Broken(ArchRequest(n_ports=8, addr_bits=4), BOUND,
                  hft(seed=0, duration_s=8e-5), back_annotation=False)
    cands = prob.candidates()[:4]
    with pytest.raises(ValueError, match=r"Broken\.surrogate_batch.*\[3\].*\[4\]"):
        stage2_screen(prob, cands, SLA_HFT)
    sized = [(c, prob.resources(c)) for c in cands]
    with pytest.raises(ValueError, match=r"Broken\.verify_batch.*\[3\].*\[4\]"):
        stage4_verify(prob, sized, SLA_HFT)
