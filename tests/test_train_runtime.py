"""Training substrate + fault tolerance: convergence, µbatch equivalence,
checkpoint/restart, straggler watch, elastic remesh, serving engine."""

import os
import tempfile

import jax
import jax.numpy as jnp

from repro.launch.mesh import compat_make_mesh
import numpy as np
import pytest

from repro.checkpoint import latest_step, restore, save
from repro.configs import get_smoke
from repro.data import DataConfig, SyntheticLM
from repro.models import SINGLE_POD_PLAN
from repro.models import transformer as T
from repro.runtime import (FaultInjector, StragglerWatch, Supervisor, remesh,
                           scaled_microbatches, shardings_for)
from repro.serve import Request, ServeEngine
from repro.train import TrainSpec, adafactor, adamw, lr_schedule, make_train_step

PLAN = SINGLE_POD_PLAN


@pytest.fixture(scope="module")
def setup(request):
    mesh = compat_make_mesh((1, 1), ("data", "model"))
    cfg = get_smoke("llama3.2-1b")
    params, specs = T.init_params(jax.random.PRNGKey(0), cfg, PLAN)
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=4))
    return mesh, cfg, params, specs, data


def test_loss_decreases_adamw(setup):
    mesh, cfg, params, _, data = setup
    opt = adamw(lr=1e-3)
    ts = jax.jit(make_train_step(cfg, PLAN, mesh, opt,
                                 TrainSpec(lr=1e-3, warmup_steps=5, total_steps=30)))
    o = opt.init(params)
    p = params
    losses = []
    for step in range(30):
        batch = {k: jnp.asarray(v) for k, v in data.batch(step).items()}
        p, o, m = ts(p, o, batch, jnp.asarray(step))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.3


def test_adafactor_also_trains(setup):
    mesh, cfg, params, _, data = setup
    opt = adafactor(lr=3e-3)
    ts = jax.jit(make_train_step(cfg, PLAN, mesh, opt,
                                 TrainSpec(lr=3e-3, warmup_steps=5, total_steps=20)))
    o = opt.init(params)
    p = params
    losses = []
    for step in range(20):
        batch = {k: jnp.asarray(v) for k, v in data.batch(step).items()}
        p, o, m = ts(p, o, batch, jnp.asarray(step))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]


def test_microbatch_grad_equivalence(setup):
    """mb=1 vs mb=2 must produce (nearly) the same update."""
    mesh, cfg, params, _, data = setup
    batch = {k: jnp.asarray(v) for k, v in data.batch(0).items()}
    def run_with(mb):
        opt = adamw(lr=1e-3)
        ts = jax.jit(make_train_step(cfg, PLAN, mesh, opt, TrainSpec(microbatches=mb)))
        p, o, m = ts(params, opt.init(params), batch, jnp.asarray(0))
        return p

    outs = [run_with(mb) for mb in (1, 2)]
    d = jax.tree.map(lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                                - b.astype(jnp.float32)).max()),
                     outs[0], outs[1])
    assert max(jax.tree.leaves(d)) < 5e-2


def test_lr_schedule_shapes():
    spec = TrainSpec(lr=1.0, warmup_steps=10, total_steps=100, schedule="wsd")
    assert float(lr_schedule(spec, jnp.asarray(0))) == 0.0
    assert float(lr_schedule(spec, jnp.asarray(10))) == 1.0
    assert float(lr_schedule(spec, jnp.asarray(50))) == 1.0
    assert float(lr_schedule(spec, jnp.asarray(100))) == pytest.approx(0.1, abs=1e-6)


def test_data_pipeline_deterministic_resume():
    d1 = SyntheticLM(DataConfig(vocab=100, seq_len=16, global_batch=2, seed=7))
    d2 = SyntheticLM(DataConfig(vocab=100, seq_len=16, global_batch=2, seed=7))
    np.testing.assert_array_equal(d1.batch(13)["tokens"], d2.batch(13)["tokens"])
    assert not np.array_equal(d1.batch(13)["tokens"], d1.batch(14)["tokens"])


# ------------------------------------------------------------- checkpointing

def test_checkpoint_roundtrip_bf16():
    tree = {"a": jnp.arange(6, dtype=jnp.bfloat16).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.float32), "step": jnp.asarray(3)}}
    with tempfile.TemporaryDirectory() as d:
        save(d, 5, tree)
        assert latest_step(d) == 5
        got, manifest = restore(d, template=tree)
        assert manifest["step"] == 5
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
            assert a.dtype == b.dtype
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_supervisor_restart_resumes_training(setup):
    mesh, cfg, params, _, data = setup
    opt = adamw(lr=1e-3)
    ts = jax.jit(make_train_step(cfg, PLAN, mesh, opt, TrainSpec()))

    def step_fn(state, step):
        p, o = state
        batch = {k: jnp.asarray(v) for k, v in data.batch(step).items()}
        p, o, m = ts(p, o, batch, jnp.asarray(step))
        return (p, o), m

    with tempfile.TemporaryDirectory() as d:
        inj = FaultInjector(schedule={7: "crash", 15: "crash"})
        sup = Supervisor(d, ckpt_every=5, injector=inj)
        res = sup.run((params, opt.init(params)), step_fn, total_steps=20)
        assert res.final_step == 20
        assert res.restarts == 2
        steps = [h["step"] for h in res.metrics_history]
        assert steps.count(5) >= 2        # step 5 replayed after the crash at 7


def test_straggler_watch_fires():
    w = StragglerWatch(deadline_multiple=2.0)
    fired = []
    for step, dt in enumerate([1.0, 1.0, 1.0, 5.0, 1.0]):
        w.observe(step, dt, on_straggler=lambda s, d, e: fired.append(s))
    assert fired == [3]
    assert len(w.events) == 1


def test_elastic_remesh_roundtrip(setup):
    mesh, cfg, params, specs, _ = setup
    new_mesh = compat_make_mesh((1, 1), ("data", "model"))
    moved = remesh(params, specs, new_mesh)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(moved)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert scaled_microbatches(2, old_dp=16, new_dp=8) == 4


# ------------------------------------------------------------------- serving

def test_serve_engine_continuous_batching(setup):
    mesh, cfg, params, _, _ = setup
    eng = ServeEngine(cfg, PLAN, mesh, params, slots=2, s_max=64)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, 5).astype(np.int32),
                    max_new=4) for i in range(5)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_drained(max_ticks=200)
    assert all(r.done for r in reqs)
    assert all(len(r.out) == 4 for r in reqs)
    assert all(0 <= t < cfg.vocab for r in reqs for t in r.out)


def test_serve_greedy_matches_decode_loop(setup):
    mesh, cfg, params, _, _ = setup
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab, 6).astype(np.int32)
    eng = ServeEngine(cfg, PLAN, mesh, params, slots=1, s_max=32)
    r = Request(rid=0, prompt=prompt, max_new=3)
    eng.submit(r)
    eng.run_until_drained(max_ticks=64)
    # manual greedy decode
    state, _ = T.init_decode_state(cfg, PLAN, 1, 32)
    toks = list(prompt)
    outs = []
    for t in range(len(prompt) + 3 - 1):
        inp = jnp.asarray([[toks[t] if t < len(toks) else outs[-1]]], jnp.int32)
        state, lg = T.decode_step(params, cfg, PLAN, mesh, state, inp)
        if t >= len(prompt) - 1:
            nxt = int(jnp.argmax(lg[0, 0]))
            outs.append(nxt)
            if t >= len(toks) - 1:
                toks.append(nxt)
    assert r.out == outs[:3]


def test_serve_run_until_drained_returns_finished(setup):
    """Regression: ``run_until_drained`` must hand back every completed
    request exactly once, in completion order — it used to return [] always
    (finished requests were dropped on slot free)."""
    mesh, cfg, params, _, _ = setup
    eng = ServeEngine(cfg, PLAN, mesh, params, slots=2, s_max=64)
    rng = np.random.default_rng(2)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, 4).astype(np.int32),
                    max_new=2 + i) for i in range(4)]
    for r in reqs:
        eng.submit(r)
    finished = eng.run_until_drained(max_ticks=200)
    assert [r.rid for r in finished] == [0, 1, 2, 3]   # shorter gens land first
    assert all(r.done for r in finished)
    assert eng.run_until_drained(max_ticks=1) == []    # exactly-once harvest
    with pytest.raises(ValueError, match="already in flight"):
        eng.submit(reqs[0])
        eng.submit(reqs[0])                            # duplicate rid is loud


def test_serve_tick_accounting_samples_prefill_final_logits(setup):
    """The engine docstring's contract: prefill and decode share the tick,
    the prefill-final logits are sampled (not discarded), so a request takes
    exactly ``len(prompt) + max_new - 1`` ticks for ``max_new`` tokens."""
    mesh, cfg, params, _, _ = setup
    eng = ServeEngine(cfg, PLAN, mesh, params, slots=1, s_max=32)
    prompt = np.asarray([3, 1, 4, 1, 5], np.int32)
    r = Request(rid=0, prompt=prompt, max_new=4)
    eng.submit(r)
    ticks = 0
    while not r.done:
        eng.step()
        ticks += 1
        assert ticks < 64
    assert ticks == len(prompt) + r.max_new - 1
    assert len(r.out) == r.max_new
    # first output token appears on tick len(prompt): the tick that feeds
    # the last prompt token also samples from its logits
    eng2 = ServeEngine(cfg, PLAN, mesh, params, slots=1, s_max=32)
    r2 = Request(rid=0, prompt=prompt, max_new=4)
    eng2.submit(r2)
    for _ in range(len(prompt) - 1):
        eng2.step()
    assert r2.out == []                 # still prefilling
    eng2.step()
    assert len(r2.out) == 1             # prefill-final tick sampled
