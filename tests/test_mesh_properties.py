"""Property tests for the mesh-sharding contract (host-side, no devices).

Three invariants back the multi-device determinism matrix in
``tests/test_mesh_dse.py``:

  * ``shard_pad``/``shard_unpad`` round-trip for any (B, shard count) and
    pad rows are throwaway replicas of row 0,
  * Pareto-front ranking is permutation-invariant — the algebraic reason a
    sharded batch (any partition + merge order of the candidate axis)
    yields the same front as the serial scan,
  * ``remesh_search_state(state, N -> M -> N)`` is the identity: NSGA-II
    checkpoint state carries nothing shaped by the mesh.

Properties run under hypothesis when installed (``hypothesis_compat``
makes them skip cleanly otherwise); example twins alongside always run.
"""

import numpy as np
import pytest

from hypothesis_compat import given, settings, st

from repro.core.search import (Dim, DesignSpace, NSGA2Search, SearchSpec,
                               constrained_non_dominated_sort,
                               remesh_search_state)
from repro.launch.mesh import MeshSpec, padded_size, shard_pad, shard_unpad


# --------------------------------------------------------------------------
# shard-pad / unpad round-trips
# --------------------------------------------------------------------------

def _check_roundtrip(b, k, m=3, seed=0):
    rng = np.random.default_rng(seed)
    a = rng.normal(size=(b, m))
    p = shard_pad(a, k)
    assert p.shape[0] == padded_size(b, k)
    assert p.shape[0] % k == 0
    np.testing.assert_array_equal(shard_unpad(p, b), a)
    if p.shape[0] > b:      # every pad row replicates row 0
        np.testing.assert_array_equal(p[b:], np.broadcast_to(a[0], (p.shape[0] - b, m)))


@settings(max_examples=60, deadline=None)
@given(b=st.integers(min_value=1, max_value=64),
       k=st.integers(min_value=1, max_value=16),
       seed=st.integers(min_value=0, max_value=2**32 - 1))
def test_pad_unpad_roundtrip_property(b, k, seed):
    _check_roundtrip(b, k, seed=seed)


def test_pad_unpad_roundtrip_examples():
    for b, k in ((1, 8), (7, 8), (21, 2), (16, 8), (8, 8), (1, 1)):
        _check_roundtrip(b, k)
    # divisible batches are returned untouched (no copy, no-op)
    a = np.arange(12.0).reshape(6, 2)
    assert shard_pad(a, 3) is a
    # 1-D candidate arrays (wire_bits, pipe, depth) pad on axis 0 too
    v = np.arange(5.0)
    np.testing.assert_array_equal(shard_unpad(shard_pad(v, 4), 5), v)
    # candidate axis other than 0 (stage-4 svc arrives [m, B])
    np.testing.assert_array_equal(
        shard_unpad(shard_pad(a.T, 4, axis=1), 6, axis=1), a.T)


def test_padded_size_rejects_zero_shards():
    with pytest.raises(ValueError, match="must be >= 1"):
        padded_size(8, 0)


# --------------------------------------------------------------------------
# Pareto ranking is permutation-invariant (sharded == serial fronts)
# --------------------------------------------------------------------------

def _check_permutation_invariance(objs, viol, perm):
    ranks = constrained_non_dominated_sort(objs, viol)
    ranks_p = constrained_non_dominated_sort(objs[perm], viol[perm])
    np.testing.assert_array_equal(ranks_p, ranks[perm])
    # front *membership* (what the DSE reads off rank 0) is order-free
    assert sorted(map(tuple, objs[ranks == 0])) == \
           sorted(map(tuple, objs[perm][ranks_p == 0]))


@settings(max_examples=60, deadline=None)
@given(n=st.integers(min_value=1, max_value=40),
       seed=st.integers(min_value=0, max_value=2**32 - 1))
def test_pareto_rank_permutation_invariant_property(n, seed):
    rng = np.random.default_rng(seed)
    # coarse grid => plenty of ties/duplicates, the hard case for sorters
    objs = rng.integers(0, 5, size=(n, 2)).astype(float)
    viol = np.where(rng.random(n) < 0.3, rng.random(n), 0.0)
    _check_permutation_invariance(objs, viol, rng.permutation(n))


def test_pareto_rank_permutation_invariant_example():
    objs = np.array([[1.0, 4.0], [2.0, 2.0], [4.0, 1.0],
                     [2.0, 2.0], [3.0, 3.0], [5.0, 5.0]])
    viol = np.array([0.0, 0.0, 0.0, 0.0, 0.5, 0.0])
    _check_permutation_invariance(objs, viol, np.array([5, 3, 0, 2, 4, 1]))


# --------------------------------------------------------------------------
# remesh(state, N -> M -> N) is the identity on checkpoint state
# --------------------------------------------------------------------------

def _searched_state(generations=3):
    """Real engine state: a tiny pure-NumPy search driven to ``generations``."""
    space = DesignSpace((Dim("a", (1, 2, 3, 4)), Dim("b", (8, 16, 32))))
    eng = NSGA2Search(space, SearchSpec(population=8, generations=generations,
                                        seed=11))
    while not eng.done:
        asked = eng.ask()
        eng.tell({g: ((float(sum(g)), float(g[0] * g[1])), 0.0)
                  for g in asked})
    return eng


def _assert_state_equal(a, b, *, compare_mesh=True):
    tree_a, extra_a = a
    tree_b, extra_b = b
    assert sorted(tree_a) == sorted(tree_b)
    for key in tree_a:
        np.testing.assert_array_equal(tree_a[key], tree_b[key])
    if not compare_mesh:
        extra_a = {k: v for k, v in extra_a.items() if k != "mesh"}
        extra_b = {k: v for k, v in extra_b.items() if k != "mesh"}
    assert extra_a == extra_b


@settings(max_examples=25, deadline=None)
@given(n=st.integers(min_value=1, max_value=64),
       m=st.integers(min_value=1, max_value=64))
def test_remesh_roundtrip_identity_property(n, m):
    tree, extra = _searched_state()
    start = remesh_search_state(tree, extra, MeshSpec(devices=n))
    via_m = remesh_search_state(*start, MeshSpec(devices=m))
    back = remesh_search_state(*via_m, MeshSpec(devices=n))
    _assert_state_equal(back, start)                    # N -> M -> N identity
    _assert_state_equal(via_m, start, compare_mesh=False)  # arrays never move


def test_remesh_roundtrip_identity_example():
    eng = _searched_state()
    tree, extra = eng.state()
    start = remesh_search_state(tree, extra, MeshSpec(devices=8))
    assert start[1]["mesh"] == {"devices": 8, "scenario_axis": 1}
    via2 = remesh_search_state(*start, MeshSpec(devices=2))
    assert via2[1]["mesh"] == {"devices": 2, "scenario_axis": 1}
    back = remesh_search_state(*via2, MeshSpec(devices=8))
    _assert_state_equal(back, start)
    # the remeshed state restores to an engine whose next RNG draws (and
    # archive) match the original bit-for-bit
    restored = NSGA2Search.from_state(eng.space, eng.spec, *back)
    assert restored.archive() == eng.archive()
    assert restored.hv_history == eng.hv_history
    np.testing.assert_array_equal(restored.rng.random(16), eng.rng.random(16))
    # dropping the stamp entirely (mesh=None) also restores cleanly
    bare = remesh_search_state(tree, extra, None)
    assert "mesh" not in bare[1]
    _assert_state_equal(bare, (tree, extra), compare_mesh=False)
