"""Protocol DSL: bit-exact layout, straddle detection, semantic binding."""

import numpy as np
import pytest
from hypothesis_compat import given, settings, st  # optional dep, skips cleanly

from repro.core import (ETHERNET_HEADER_BYTES, Field, Protocol, SemanticBinding,
                        bind, compressed_protocol, ethernet_ipv4_udp)
from repro.switch.parser import WORD_BITS, make_field_extractor, pack_header_words


def test_ethernet_is_42_bytes():
    assert ethernet_ipv4_udp().header_bytes == ETHERNET_HEADER_BYTES


def test_compressed_default_is_2_bytes():
    assert compressed_protocol().header_bytes == 2


def test_offsets_are_packed_back_to_back():
    p = compressed_protocol(addr_bits=4, qos_bits=2, length_bits=6)
    assert p.offset_of("dst") == 0
    assert p.offset_of("src") == 4
    assert p.offset_of("qos") == 8
    assert p.offset_of("len") == 10
    assert p.header_bits == 16


def test_straddle_detection():
    p = ethernet_ipv4_udp()
    plan = p.compile(256)
    assert "ip_dst" in plan.straddling_fields  # bits 240..271 cross flit 0/1
    plan64 = p.compile(64)
    assert set(plan64.straddling_fields) == {"eth_src", "ip_dst"}


def test_binding_requires_routing_key():
    p = Protocol("anon", [Field("a", 8), Field("b", 8)])
    with pytest.raises(ValueError, match="routing_key"):
        bind(p)
    bp = bind(p, SemanticBinding(routing_key="b"))
    assert bp.routing_field.name == "b"


@st.composite
def _protocols(draw):
    n = draw(st.integers(2, 8))
    fields = [Field(f"f{i}", draw(st.integers(1, 32))) for i in range(n)]
    return Protocol("rand", fields)


@given(_protocols(), st.data())
@settings(max_examples=30, deadline=None)
def test_pack_unpack_roundtrip(proto, data):
    values = {f.name: data.draw(st.integers(0, (1 << f.bits) - 1)) for f in proto.fields}
    wire = proto.pack(values)
    assert len(wire) == proto.header_bytes
    assert proto.unpack(wire) == values


@given(_protocols(), st.data())
@settings(max_examples=30, deadline=None)
def test_pack_unpack_match_reference_oracle(proto, data):
    """The vectorized numpy-bit-ops path must be byte-for-byte the per-bit
    reference oracle, both directions."""
    values = {f.name: data.draw(st.integers(0, (1 << f.bits) - 1)) for f in proto.fields}
    wire = proto.pack(values)
    assert wire == proto.pack_reference(values)
    assert proto.unpack(wire) == proto.unpack_reference(wire) == values


@given(_protocols(), st.sampled_from([8, 16, 32, 64, 128, 256, 512]))
@settings(max_examples=40, deadline=None)
def test_parser_plan_slices_cover_every_bit_once(proto, flit_bits):
    """Compiled ``ParserPlan`` slices must tile the header exactly: every
    header bit in exactly one slice, per-field pieces reassembling the full
    width, straddlers flagged iff a field crosses a flit boundary."""
    plan = proto.compile(flit_bits)
    covered = np.zeros(proto.header_bits, dtype=int)
    for s in plan.slices:
        assert 0 <= s.lo <= s.hi < flit_bits
        width = s.hi - s.lo + 1
        # stream bit of the slice's MSB: word base + position inside the flit
        start = s.word * flit_bits + (flit_bits - 1 - s.hi)
        covered[start:start + width] += 1
    assert (covered == 1).all()
    for f in proto.fields:
        pieces = plan.slices_for(f.name)
        assert sum(s.hi - s.lo + 1 for s in pieces) == f.bits
        # dst_shift stitches the pieces MSB-first without gaps or overlaps
        shifts = sorted((s.dst_shift, s.hi - s.lo + 1) for s in pieces)
        assert shifts[0][0] == 0
        acc = 0
        for shift, width in shifts:
            assert shift == acc
            acc += width
        assert (f.name in plan.straddling_fields) == (len(pieces) > 1 or (
            proto.offset_of(f.name) // flit_bits
            != (proto.offset_of(f.name) + f.bits - 1) // flit_bits))


# example-based twins: the same invariants stay exercised without hypothesis
def test_pack_matches_reference_oracle_examples():
    for proto, values in [
        (compressed_protocol(addr_bits=4, qos_bits=2, length_bits=6, seq_bits=8),
         {"dst": 5, "src": 9, "qos": 3, "len": 42, "seq": 200}),
        (ethernet_ipv4_udp(),
         {f.name: (1 << f.bits) - 1 for f in ethernet_ipv4_udp().fields}),
        (Protocol("edge", [Field("a", 64), Field("b", 1), Field("c", 48)]),
         {"a": (1 << 64) - 1, "b": 1, "c": 0x123456789ABC}),
    ]:
        wire = proto.pack(values)
        assert wire == proto.pack_reference(values)
        assert proto.unpack(wire) == proto.unpack_reference(wire) == values


def test_unpack_rejects_truncated_headers():
    p = compressed_protocol()            # 2-byte header
    with pytest.raises(ValueError, match="needs 2 bytes, got 1"):
        p.unpack(b"\xff")
    assert p.unpack(p.pack({"dst": 3})) == {"dst": 3, "src": 0, "qos": 0, "len": 0}


def test_parser_plan_covers_every_bit_once_examples():
    for proto, flit in [(ethernet_ipv4_udp(), 256), (ethernet_ipv4_udp(), 64),
                        (compressed_protocol(addr_bits=4, length_bits=6), 8)]:
        plan = proto.compile(flit)
        covered = np.zeros(proto.header_bits, dtype=int)
        for s in plan.slices:
            start = s.word * flit + (flit - 1 - s.hi)
            covered[start:start + (s.hi - s.lo + 1)] += 1
        assert (covered == 1).all()


@given(_protocols(), st.data())
@settings(max_examples=20, deadline=None)
def test_vectorised_pack_matches_scalar(proto, data):
    n = 5
    vals = {f.name: np.array([data.draw(st.integers(0, (1 << f.bits) - 1))
                              for _ in range(n)], dtype=np.uint64)
            for f in proto.fields}
    words = pack_header_words(proto, vals)
    for i in range(n):
        wire = proto.pack({k: int(v[i]) for k, v in vals.items()})
        padded = wire + b"\0" * (words.shape[1] * 4 - len(wire))
        expect = np.frombuffer(padded, dtype=">u4").astype(np.uint32)
        np.testing.assert_array_equal(words[i], expect)


@given(_protocols(), st.data())
@settings(max_examples=20, deadline=None)
def test_extractor_recovers_fields(proto, data):
    import jax.numpy as jnp
    n = 4
    vals = {f.name: np.array([data.draw(st.integers(0, (1 << f.bits) - 1))
                              for _ in range(n)], dtype=np.uint64)
            for f in proto.fields}
    words = jnp.asarray(pack_header_words(proto, vals))
    names = [f.name for f in proto.fields]
    out = make_field_extractor(proto, names)(words)
    for i, f in enumerate(proto.fields):
        np.testing.assert_array_equal(
            np.asarray(out[i]), (vals[f.name] & 0xFFFFFFFF).astype(np.uint32))
