"""Batched finite-buffer verifier vs the serial heapq oracle (stage-4 fan-out).

Acceptance contract: on the same sized candidates + trace the two paths must
agree *exactly* on drop counts (at several sized depths, both VOQ kinds, hft
and datacenter workloads), within rtol 1e-3 on p50/p99 latency, and on
throughput — and ``run_dse`` must produce the same Pareto front and best
candidate through either stage-4 path.
"""

import math

import numpy as np
import pytest

from repro.core import (ArchRequest, ForwardTableKind, ResourceBudget, SLA,
                        SchedulerKind, SwitchArch, VOQKind, bind,
                        compressed_protocol, enumerate_candidates, run_dse)
from repro.core.dse import DSEProblem
from repro.sim import run_netsim, run_netsim_batched
from repro.sim.netsim import NetSimConfig
from repro.sim.resources import ALVEO_U45N
from repro.sim.switch_problem import SwitchDSEProblem
from repro.traces import datacenter, hft
from repro.traces.base import Trace

BOUND = bind(compressed_protocol(addr_bits=4, length_bits=6), flit_bits=256)


def _traces():
    return {
        "hft": hft(seed=0),
        "datacenter": datacenter(seed=0, n_ports=8, duration_s=400e-6, load=0.8),
    }


def _sized_candidates():
    """Every (bus, fwd, voq, sched) family at several sized depths — small
    depths force drops so the exact-drop assertion has teeth."""
    base = enumerate_candidates(ArchRequest(n_ports=8, addr_bits=4))
    assert {a.voq for a in base} == {VOQKind.NXN, VOQKind.SHARED}
    return [a.with_depth(d) for a in base[:12] for d in (2, 8, 64)]


@pytest.mark.parametrize("workload", ["hft", "datacenter"])
def test_batched_matches_heapq_oracle(workload):
    tr = _traces()[workload]
    cands = _sized_candidates()
    vb = run_netsim_batched(cands, BOUND, tr, back_annotation=False)
    vs = [run_netsim(a, BOUND, tr, back_annotation=False) for a in cands]
    assert any(v.drop_rate > 0 for v in vs)     # the depths actually bind
    for a, b, s in zip(cands, vb, vs):
        msg = a.short()
        # drop counts exact (drop_rate is drops/m, m shared)
        assert b.drop_rate == s.drop_rate, msg
        assert b.meta["delivered"] == s.meta["delivered"], msg
        # the delivered latency array is bit-identical, packet order included
        np.testing.assert_array_equal(b.meta["latency_ns"],
                                      s.meta["latency_ns"], err_msg=msg)
        for q, sq in ((b.p99_latency_ns, s.p99_latency_ns),
                      (b.mean_latency_ns, s.mean_latency_ns)):
            assert q == pytest.approx(sq, rel=1e-3), msg
        assert b.throughput_gbps == pytest.approx(s.throughput_gbps, rel=1e-6), msg


def test_shared_cap_fallback_is_exact():
    """An incast pattern that fills many VOQs part-way crosses the shared
    N·depth cap before any per-queue depth binds — those candidates must take
    the flagged serial fallback and still match the oracle exactly."""
    n = 8
    rng = np.random.default_rng(0)
    per_src = 120
    times = np.concatenate([np.arange(per_src) * 2.2e-7 + s * 1e-9
                            for s in range(n)])
    srcs = np.concatenate([np.full(per_src, s) for s in range(n)])
    dsts = np.concatenate([rng.integers(0, 4, per_src) for _ in range(n)])
    tr = Trace("incast4", times, srcs, dsts, np.full(n * per_src, 200), n,
               link_gbps=10.0)
    cands = [SwitchArch(n_ports=8, bus_bits=bw, fwd=ForwardTableKind.FULL_LOOKUP,
                        voq=voq, sched=SchedulerKind.RR, voq_depth=d, addr_bits=4)
             for bw in (128, 512)
             for voq in (VOQKind.SHARED, VOQKind.NXN) for d in (8, 16)]
    vb = run_netsim_batched(cands, BOUND, tr, back_annotation=False)
    vs = [run_netsim(a, BOUND, tr, back_annotation=False) for a in cands]
    fallbacks = [v.meta.get("shared_cap_fallback", False) for v in vb]
    assert any(fallbacks)                        # the cap genuinely binds
    assert not any(f for f, a in zip(fallbacks, cands)
                   if a.voq is VOQKind.NXN)      # ...and only for SHARED
    for b, s in zip(vb, vs):
        assert b.drop_rate == s.drop_rate
        np.testing.assert_array_equal(b.meta["latency_ns"],
                                      s.meta["latency_ns"])


def test_degenerate_depth_matches_serial():
    """depth<=0 means "always full" in the serial model (every packet drops);
    the batched path must route such candidates through the flagged serial
    fallback rather than silently diverge."""
    tr = hft(seed=0).head(64)
    cands = [_sized_candidates()[0].with_depth(0),
             _sized_candidates()[1].with_depth(8)]
    vb = run_netsim_batched(cands, BOUND, tr, back_annotation=False)
    vs = [run_netsim(a, BOUND, tr, back_annotation=False) for a in cands]
    assert vb[0].meta["fallback"] == "degenerate_depth"
    assert "fallback" not in vb[1].meta
    for b, s in zip(vb, vs):
        assert b.drop_rate == s.drop_rate
        np.testing.assert_array_equal(b.meta["latency_ns"], s.meta["latency_ns"])
    assert vs[0].drop_rate == 1.0


def test_empty_trace():
    empty = Trace("empty", np.zeros(0), np.zeros(0, np.int32),
                  np.zeros(0, np.int32), np.zeros(0, np.int64), 8)
    cands = _sized_candidates()[:4]
    vb = run_netsim_batched(cands, BOUND, empty, back_annotation=False)
    vs = run_netsim(cands[0], BOUND, empty, back_annotation=False)
    assert len(vb) == 4
    for v in vb:
        assert v.drop_rate == vs.drop_rate == 0.0
        assert v.throughput_gbps == vs.throughput_gbps == 0.0
        assert math.isinf(v.p99_latency_ns) and math.isinf(vs.p99_latency_ns)


def test_empty_batch():
    assert run_netsim_batched([], BOUND, hft(seed=0)) == []


def test_single_candidate():
    tr = hft(seed=1)
    a = _sized_candidates()[0]
    [vb] = run_netsim_batched([a], BOUND, tr, back_annotation=False)
    vs = run_netsim(a, BOUND, tr, back_annotation=False)
    assert vb.drop_rate == vs.drop_rate
    np.testing.assert_array_equal(vb.meta["latency_ns"], vs.meta["latency_ns"])


def test_mixed_port_batches_are_partitioned():
    tr = hft(seed=0)
    mixed = ([a.with_depth(4) for a in
              enumerate_candidates(ArchRequest(n_ports=8, addr_bits=4))[:3]]
             + [a.with_depth(4) for a in
                enumerate_candidates(ArchRequest(n_ports=4, addr_bits=4))[:3]])
    vb = run_netsim_batched(mixed, BOUND, tr, back_annotation=False)
    for a, b in zip(mixed, vb):
        s = run_netsim(a, BOUND, tr, back_annotation=False)
        assert b.drop_rate == s.drop_rate
        np.testing.assert_array_equal(b.meta["latency_ns"], s.meta["latency_ns"])


def test_retransmit_stays_serial():
    proto = compressed_protocol(addr_bits=4, seq_bits=8)
    bound_seq = bind(proto, flit_bits=256)
    with pytest.raises(NotImplementedError, match="retransmission"):
        run_netsim_batched(_sized_candidates()[:2], bound_seq, hft(seed=0),
                           cfg=NetSimConfig(retransmit=True),
                           back_annotation=False)


def test_misaligned_hw_list_raises():
    with pytest.raises(ValueError, match="index-aligned"):
        run_netsim_batched(_sized_candidates()[:4], BOUND, hft(seed=0),
                           hw=[None, None])


def test_mutable_default_config_fixed():
    """``run_netsim(..., cfg=None)`` must build a fresh NetSimConfig per call
    rather than sharing one mutable default instance across all calls."""
    import inspect
    for fn in (run_netsim, run_netsim_batched):
        assert inspect.signature(fn).parameters["cfg"].default is None, fn


def test_verify_batch_misalignment_raises():
    class Broken(SwitchDSEProblem):
        def verify_batch(self, archs):
            return super().verify_batch(archs)[:-1]   # drops one result

    prob = Broken(ArchRequest(n_ports=8, addr_bits=4), BOUND, hft(seed=0),
                  back_annotation=False)
    with pytest.raises(ValueError, match="index-aligned"):
        run_dse(prob, SLA(p99_latency_ns=5000, drop_rate=1e-3),
                ResourceBudget(dict(ALVEO_U45N)))


class _SerialVerifyProblem(SwitchDSEProblem):
    """The same problem forced through the serial stage-4 fallback."""
    verify_batch = DSEProblem.verify_batch


def test_run_dse_identical_batched_vs_serial_verify():
    tr = hft(seed=0)
    req = ArchRequest(n_ports=8, addr_bits=4)
    sla = SLA(p99_latency_ns=5000, drop_rate=1e-3)
    budget = ResourceBudget(dict(ALVEO_U45N))
    res_b = run_dse(SwitchDSEProblem(req, BOUND, tr, back_annotation=False),
                    sla, budget)
    res_s = run_dse(_SerialVerifyProblem(req, BOUND, tr, back_annotation=False),
                    sla, budget)
    assert sorted(a.short() for a, _ in res_b.pareto) == \
           sorted(a.short() for a, _ in res_s.pareto)
    assert res_b.best.short() == res_s.best.short()
    assert res_b.best_verify.p99_latency_ns == res_s.best_verify.p99_latency_ns
    assert res_b.best_verify.drop_rate == res_s.best_verify.drop_rate
    assert [lg.survived for lg in res_b.logs] == \
           [lg.survived for lg in res_s.logs]
