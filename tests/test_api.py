"""Scenario/Campaign API: serialization, registry, runner parity, CLI."""

import dataclasses
import json
import math

import numpy as np
import pytest

from repro.api import (ProtocolSpec, Scenario, ScenarioRegistry, TraceSpec,
                       registry, run_campaign, run_scenario)
from repro.api.cli import load_campaign_config, main, resolve_entry
from repro.api.runner import build_bound
from repro.api.scenario import CommModelSpec, Fidelity
from repro.core import (AUTO, ArchRequest, ResourceBudget, SLA, SchedulerKind,
                        VOQKind, Field, bind, compressed_protocol)
from repro.sim import optimize_switch
from repro.traces import Trace, WORKLOADS, hft


def _roundtrip(s: Scenario) -> Scenario:
    return Scenario.from_dict(json.loads(json.dumps(s.to_dict())))


# ------------------------------------------------------------- serialization

def test_registry_scenarios_roundtrip_bit_for_bit():
    for s in registry:
        assert _roundtrip(s) == s


def test_roundtrip_inline_protocol_auto_policies_and_inf():
    proto = compressed_protocol(name="wire", addr_bits=5, qos_bits=3,
                                length_bits=7, seq_bits=4)
    s = Scenario(
        name="inline_test",
        protocol=ProtocolSpec.inline(proto),
        flit_bits=128,
        binding={"opcode": "qos"},
        trace=TraceSpec(generator="uniform",
                        params={"seed": 3, "duration_s": 1e-4, "load": 0.5}),
        arch=ArchRequest(n_ports=8, addr_bits=5, bus_bits=AUTO, voq=AUTO,
                         sched=SchedulerKind.RR, voq_depth=32),
        sla=SLA(p99_latency_ns=math.inf, drop_rate=5e-3),
        budget=ResourceBudget({"luts": 1e6, "brams": math.inf}),
        fidelity=Fidelity(back_annotation=False, top_k=3),
        notes="inline + AUTO + inf round-trip",
    )
    s2 = _roundtrip(s)
    assert s2 == s
    # AUTO must come back as the singleton, not a lookalike
    assert s2.arch.bus_bits is AUTO and s2.arch.voq is AUTO
    assert s2.arch.sched is SchedulerKind.RR
    assert math.isinf(s2.sla.p99_latency_ns)
    assert s2.protocol.fields == tuple(proto.fields)
    # the rebuilt protocol is layout-identical
    assert s2.protocol.build().compile(128) == proto.compile(128)


def test_roundtrip_comm_scenario_and_file_trace(tmp_path):
    s = registry["moe_dispatch"]
    assert _roundtrip(s) == s
    # file-sourced trace spec
    p = tmp_path / "t.npz"
    hft(seed=1, duration_s=5e-5).save(p)
    s = dataclasses.replace(registry["hft"], trace=TraceSpec(path=str(p)))
    s2 = _roundtrip(s)
    assert s2 == s
    tr = s2.trace.build()
    assert tr.name == "hft" and len(tr) == len(hft(seed=1, duration_s=5e-5))


def test_scenario_json_file_and_validation(tmp_path):
    s = registry["underwater"]
    path = tmp_path / "s.json"
    s.save(path)
    assert Scenario.load(path) == s
    with pytest.raises(ValueError):
        Scenario(name="bad", domain="switch", arch=None)
    with pytest.raises(ValueError):
        Scenario(name="bad", domain="nope",
                 arch=ArchRequest(n_ports=8, addr_bits=4))
    with pytest.raises(ValueError):
        TraceSpec(generator="hft", path="x.npz")
    with pytest.raises(ValueError):
        ProtocolSpec(builder="definitely_not_a_builder")


def test_override_surface():
    s = registry["hft"].override(sla_p99_latency_ns=123.0,
                                 trace_params={"duration_s": 1e-4},
                                 back_annotation=False, top_k=2)
    assert s.sla.p99_latency_ns == 123.0
    assert s.sla.drop_rate == registry["hft"].sla.drop_rate
    assert s.trace.params["duration_s"] == 1e-4
    assert s.trace.params["seed"] == 0          # merged, not replaced
    assert s.fidelity == Fidelity(back_annotation=False, top_k=2)
    # the original is untouched (frozen specs)
    assert registry["hft"].fidelity.back_annotation is True


# ------------------------------------------------------------------ registry

def test_registry_covers_all_trace_workloads():
    switch_gens = {s.trace.generator for s in registry if s.domain == "switch"}
    assert set(WORKLOADS) <= switch_gens
    # and each workload scenario is named after its generator
    for name in WORKLOADS:
        assert registry[name].trace.generator == name


def test_registry_has_comm_scenarios():
    comm = [s for s in registry if s.domain == "comm"]
    assert {s.name for s in comm} >= {"moe_dispatch", "grad_bucket"}
    for s in comm:
        assert isinstance(s.comm, CommModelSpec)


def test_registry_duplicate_and_lookup():
    r = ScenarioRegistry()
    s = registry["hft"]
    r.register(s)
    with pytest.raises(ValueError):
        r.register(s)
    r.register(s.override(name="hft"), replace=True)
    assert "hft" in r and r["hft"].name == "hft"
    with pytest.raises(KeyError):
        r["nope"]


# ------------------------------------------------------------- traces on disk

def test_trace_save_load_roundtrip(tmp_path):
    tr = hft(seed=2, duration_s=1e-4)
    p = tmp_path / "trace.npz"
    tr.save(p)
    tr2 = Trace.load(p)
    assert tr2.name == tr.name
    assert tr2.n_ports == tr.n_ports and tr2.link_gbps == tr.link_gbps
    np.testing.assert_array_equal(tr2.time_s, tr.time_s)
    np.testing.assert_array_equal(tr2.src, tr.src)
    np.testing.assert_array_equal(tr2.dst, tr.dst)
    np.testing.assert_array_equal(tr2.payload_bytes, tr.payload_bytes)
    assert tr2.src.dtype == tr.src.dtype
    assert tr2.payload_bytes.dtype == tr.payload_bytes.dtype


# -------------------------------------------------------------------- runner

def test_run_scenario_matches_legacy_optimize_switch():
    """Acceptance: identical Pareto front + best arch vs the legacy path."""
    scenario = registry["hft"].override(back_annotation=False)
    report = run_scenario(scenario)

    bound = build_bound(scenario)
    res, _ = optimize_switch(scenario.arch, bound, scenario.trace.build(),
                             sla=scenario.sla, back_annotation=False)
    assert report.best == res.best
    assert report.best_verify.p99_latency_ns == res.best_verify.p99_latency_ns
    assert report.best_verify.drop_rate == res.best_verify.drop_rate
    assert ([a.short() for a, _ in report.pareto]
            == [a.short() for a, _ in res.pareto])
    assert [(lg.stage, lg.considered, lg.survived) for lg in report.result.logs] \
        == [(lg.stage, lg.considered, lg.survived) for lg in res.logs]
    # the structured report serializes
    d = json.loads(json.dumps(report.to_dict()))
    assert d["best"] == res.best.short()
    assert d["stages"][0]["stage"] == "stage1-static"
    assert report.resources["brams"] > 0


def _tiny(name, **trace_params):
    return registry[name].override(back_annotation=False, top_k=2,
                                   trace_params=trace_params)


def test_run_campaign_parity_and_aggregate_throughput():
    """Campaign over 3 registry scenarios: per-scenario results identical to
    run_scenario, aggregate batched stage-2 throughput reported."""
    scns = [_tiny("hft", duration_s=8e-5),
            _tiny("underwater", duration_s=4e-4),
            _tiny("industry", duration_s=4e-4)]
    campaign = run_campaign(scns, name="smoke")
    assert len(campaign.reports) == 3
    assert campaign.stage2_candidates >= sum(
        r.result.logs[0].survived for r in campaign.reports)
    assert campaign.stage2_batches == 3          # three distinct traces
    assert campaign.stage2_cands_per_sec > 0
    for s in scns:
        solo = run_scenario(s)
        batched = campaign[s.name]
        assert batched.best == solo.best
        assert ([a.short() for a, _ in batched.pareto]
                == [a.short() for a, _ in solo.pareto])
    # report is JSON-serializable
    json.dumps(campaign.to_dict())


def test_run_campaign_shares_traces_and_batches_across_scenarios():
    """Two scenarios over the same trace+protocol share one batched call."""
    base = _tiny("hft", duration_s=8e-5)
    relaxed = base.override(name="hft_relaxed", sla_p99_latency_ns=1e6)
    campaign = run_campaign([base, relaxed], name="shared")
    assert campaign.shared_trace_scenarios == 1
    assert campaign.stage2_batches == 1          # one call, both scenarios
    assert campaign["hft"].best is not None
    # same spec -> same best either way; the relaxed SLA can only widen
    solo = run_scenario(base)
    assert campaign["hft"].best == solo.best


# ----------------------------------------------------------------------- CLI

def test_cli_list_and_show(capsys):
    assert main(["list"]) == 0
    out = capsys.readouterr().out
    for name in registry.names():
        assert name in out
    assert main(["show", "hft"]) == 0
    spec = json.loads(capsys.readouterr().out)
    assert Scenario.from_dict(spec) == registry["hft"]


def test_cli_run_with_overrides_and_report(tmp_path, capsys):
    out_file = tmp_path / "report.json"
    cfg_file = tmp_path / "scenario.json"
    rc = main(["run", "hft", "--duration-s", "8e-05", "--no-back-annotation",
               "--top-k", "2", "--out", str(out_file),
               "--save-config", str(cfg_file)])
    assert rc == 0
    capsys.readouterr()
    report = json.loads(out_file.read_text())
    assert report["best"] is not None
    assert report["scenario"]["fidelity"]["back_annotation"] is False
    # the saved config re-runs identically through the file path
    rc = main(["run", str(cfg_file), "--top-k", "2"])
    assert rc == 0


def test_cli_sweep_config_file(tmp_path, capsys):
    cfg = {
        "name": "smoke",
        "scenarios": [
            {"base": "hft",
             "trace": {"params": {"duration_s": 8e-5}},
             "fidelity": {"back_annotation": False, "top_k": 2}},
            {"base": "underwater",
             "trace": {"params": {"duration_s": 4e-4}},
             "fidelity": {"back_annotation": False, "top_k": 2}},
        ],
    }
    path = tmp_path / "campaign.json"
    path.write_text(json.dumps(cfg))
    out_file = tmp_path / "campaign_report.json"
    assert main(["sweep", "--config", str(path), "--out", str(out_file)]) == 0
    rep = json.loads(out_file.read_text())
    assert rep["name"] == "smoke"
    assert len(rep["scenarios"]) == 2
    assert rep["stage2_cands_per_sec"] > 0


def test_campaign_config_resolution():
    cfg = load_campaign_config(["hft", {"base": "underwater",
                                        "sla": {"p99_latency_ns": 1234.0}}])
    assert cfg["name"] == "campaign"
    assert cfg["scenarios"][0] == registry["hft"]
    over = cfg["scenarios"][1]
    assert over.sla.p99_latency_ns == 1234.0
    # deep-merge keeps untouched leaves
    assert over.sla.drop_rate == registry["underwater"].sla.drop_rate
    assert over.trace == registry["underwater"].trace
    full = resolve_entry(registry["hft"].to_dict())
    assert full == registry["hft"]


def test_campaign_override_can_switch_trace_source(tmp_path):
    """A base+override entry may swap a generator trace for a saved file."""
    p = tmp_path / "cap.npz"
    hft(seed=4, duration_s=5e-5).save(p)
    s = resolve_entry({"base": "hft", "trace": {"path": str(p)}})
    assert s.trace == TraceSpec(path=str(p))
    assert len(s.trace.build()) == len(hft(seed=4, duration_s=5e-5))
    # and a generator swap drops the base generator's params wholesale
    s = resolve_entry({"base": "hft", "trace": {"generator": "uniform"}})
    assert s.trace == TraceSpec(generator="uniform")
    # param-only overrides still deep-merge into the base trace
    s = resolve_entry({"base": "hft", "trace": {"params": {"duration_s": 1e-4}}})
    assert s.trace.generator == "hft" and s.trace.params["seed"] == 0
    assert s.trace.params["duration_s"] == 1e-4
