"""Import guard for the optional ``hypothesis`` dependency.

The property-based tests want hypothesis, but the tier-1 suite must collect
and run without it (the dependency is declared in ``pyproject.toml``'s
``test`` extra, not baked into every environment).  A bare module-level
``pytest.importorskip("hypothesis")`` would skip whole modules — including
their plain example-based tests — so instead test modules import
``given``/``settings``/``st`` from here:

  * hypothesis installed  -> the real decorators; property tests run.
  * hypothesis missing    -> stand-ins that mark only the ``@given`` tests
                             as skipped (via ``pytest.importorskip`` inside
                             the replacement body); everything else runs.
"""

import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    class _StrategyStub:
        """Accepts any strategy construction; only real use is inside @given
        bodies, which never execute without hypothesis."""

        def composite(self, fn):
            return lambda *a, **k: None

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _StrategyStub()

    def given(*_args, **_kwargs):
        def deco(fn):
            # zero-arg replacement: hypothesis-bound parameters must not look
            # like pytest fixtures, and the body must skip, not run
            def skipper():
                pytest.importorskip("hypothesis")
            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper
        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn
