"""Batched JAX surrogate engine vs the serial reference (stage-2 fan-out).

Acceptance contract: on the same candidates + trace the two paths must agree
*exactly* on occupancy-derived drop counts and within rtol 1e-3 on latency
quantiles, across the hft and datacenter workloads and both VOQ kinds, for a
>= 32 candidate batch — and ``run_dse`` must produce the same Pareto front
through either path.
"""

import numpy as np
import pytest

from repro.core import (ArchRequest, ResourceBudget, SLA, SchedulerKind,
                        SwitchArch, ForwardTableKind, VOQKind, bind,
                        compressed_protocol, enumerate_candidates, run_dse)
from repro.core.dse import DSEProblem
from repro.sim import run_surrogate, run_surrogate_batched
from repro.sim.resources import ALVEO_U45N
from repro.sim.switch_problem import SwitchDSEProblem
from repro.traces import datacenter, hft

BOUND = bind(compressed_protocol(addr_bits=4, length_bits=6), flit_bits=256)


def _traces():
    return {
        "hft": hft(seed=0),
        "datacenter": datacenter(seed=0, n_ports=8, duration_s=200e-6),
    }


def _candidates():
    cands = enumerate_candidates(ArchRequest(n_ports=8, addr_bits=4))
    assert len(cands) >= 32
    assert {a.voq for a in cands} == {VOQKind.NXN, VOQKind.SHARED}
    return cands


@pytest.mark.parametrize("workload", ["hft", "datacenter"])
def test_batched_matches_serial_surrogate(workload):
    tr = _traces()[workload]
    cands = _candidates()
    batch = run_surrogate_batched(cands, BOUND, tr, back_annotation=False)
    serial = [run_surrogate(a, BOUND, tr, back_annotation=False) for a in cands]
    for arch, rb, rs in zip(cands, batch.results(), serial):
        # occupancy samples (and hence drop counts at ANY depth) exact
        np.testing.assert_array_equal(rb.q_occupancy, rs.q_occupancy,
                                      err_msg=arch.short())
        for depth in (4, 16, 64):
            assert int((rb.q_occupancy > depth).sum()) == \
                   int((rs.q_occupancy > depth).sum())
        # latency quantiles within tolerance
        for q in (50.0, 99.0):
            assert rb.p(q) == pytest.approx(rs.p(q), rel=1e-3)
        assert rb.throughput_gbps == pytest.approx(rs.throughput_gbps, rel=1e-6)
        assert rb.meta["line_rate_feasible"] == rs.meta["line_rate_feasible"]
        if arch.voq is VOQKind.SHARED:
            np.testing.assert_array_equal(rb.meta["shared_occupancy"],
                                          rs.meta["shared_occupancy"])


def test_float64_path_is_bitwise_exact():
    """The absolute-time f64 scan reproduces the serial recurrence verbatim."""
    tr = hft(seed=1)
    cands = _candidates()[:8]
    batch = run_surrogate_batched(cands, BOUND, tr, back_annotation=False)
    for a, rb in zip(cands, batch.results()):
        rs = run_surrogate(a, BOUND, tr, back_annotation=False)
        np.testing.assert_array_equal(rb.latency_ns, rs.latency_ns)


def test_batched_summary_arrays():
    tr = hft(seed=0)
    cands = _candidates()
    batch = run_surrogate_batched(cands, BOUND, tr, back_annotation=False,
                                  quantiles=(50.0, 90.0, 99.0))
    b, m = len(cands), len(tr)
    assert batch.latency_ns.shape == (b, m)
    assert batch.quantiles.shape == (b, 3)
    assert batch.throughput_gbps.shape == (b,)
    assert batch.peak_occupancy.shape == (b,)
    hist = batch.occupancy_hist()
    assert hist.shape[0] == b
    # every sample lands in a bin; clamp mirrors the engine's occ >= 0 floor
    assert (hist.sum(axis=1) == m).all()
    # quantiles agree with the per-candidate latency arrays
    np.testing.assert_allclose(
        batch.quantiles[:, 2],
        np.percentile(batch.latency_ns, 99.0, axis=1), rtol=1e-12)


def test_mixed_port_batches_are_partitioned():
    tr = hft(seed=0)
    mixed = (enumerate_candidates(ArchRequest(n_ports=8, addr_bits=4))[:3]
             + enumerate_candidates(ArchRequest(n_ports=4, addr_bits=4))[:3])
    batch = run_surrogate_batched(mixed, BOUND, tr, back_annotation=False)
    for a, rb in zip(mixed, batch.results()):
        rs = run_surrogate(a, BOUND, tr, back_annotation=False)
        np.testing.assert_array_equal(rb.q_occupancy, rs.q_occupancy)


def test_empty_batch():
    assert run_surrogate_batched([], BOUND, hft(seed=0)).results() == []


def test_empty_trace():
    from repro.traces.base import Trace
    empty = Trace("empty", np.zeros(0), np.zeros(0, np.int32),
                  np.zeros(0, np.int32), np.zeros(0, np.int64), 8)
    batch = run_surrogate_batched(_candidates()[:4], BOUND, empty,
                                  back_annotation=False)
    assert batch.latency_ns.shape == (4, 0)
    for r in batch.results():
        assert r.q_occupancy.size == 0


def test_use_pallas_coerces_precision():
    """The Pallas kernel is float32 by design; requesting it must not pretend
    the bit-exact float64 contract still holds."""
    tr = hft(seed=0).head(64)
    batch = run_surrogate_batched(_candidates()[:2], BOUND, tr,
                                  back_annotation=False, use_pallas=True)
    assert batch.meta["precision"] == "float32"


def test_misaligned_hw_list_raises():
    with pytest.raises(ValueError, match="index-aligned"):
        run_surrogate_batched(_candidates()[:4], BOUND, hft(seed=0),
                              hw=[None, None])


def test_xbar_absolute_requires_f64():
    import jax.numpy as jnp
    from repro.kernels.xbar import xbar_contend
    m, b, n = 8, 2, 4
    z32 = jnp.zeros((m,), jnp.float32)
    svc = jnp.ones((b, m), jnp.float32)
    idx = jnp.zeros((m,), jnp.int32)
    with pytest.raises(ValueError, match="float64"):
        xbar_contend(z32, z32, idx, idx, svc, n_ports=n, absolute=True)


def test_surrogate_batch_misalignment_raises():
    class Broken(SwitchDSEProblem):
        def surrogate_batch(self, archs):
            return super().surrogate_batch(archs)[:-1]   # drops one result

    tr = hft(seed=0)
    prob = Broken(ArchRequest(n_ports=8, addr_bits=4), BOUND, tr,
                  back_annotation=False)
    with pytest.raises(ValueError, match="index-aligned"):
        run_dse(prob, SLA(p99_latency_ns=5000, drop_rate=1e-3),
                ResourceBudget(dict(ALVEO_U45N)))


def test_pallas_xbar_matches_slack_oracle():
    import jax.numpy as jnp
    from repro.kernels.xbar import xbar_contend
    from repro.kernels.xbar.ref import xbar_contend_slack_ref

    m, b, n = 160, 10, 8
    rng = np.random.default_rng(3)
    t = np.sort(rng.uniform(0, 1e-5, m))
    dt = np.diff(t, prepend=t[:1]).astype(np.float32)
    src = rng.integers(0, n, m).astype(np.int32)
    dst = rng.integers(0, n, m).astype(np.int32)
    svc = np.abs(rng.normal(1e-8, 2e-9, (b, m))).astype(np.float32)
    ref = xbar_contend_slack_ref(jnp.asarray(dt), jnp.asarray(src),
                                 jnp.asarray(dst), jnp.asarray(svc), n_ports=n)
    pal = xbar_contend(jnp.asarray(t, jnp.float32), jnp.asarray(dt),
                       jnp.asarray(src), jnp.asarray(dst), jnp.asarray(svc),
                       n_ports=n, use_pallas=True)
    np.testing.assert_array_equal(np.asarray(pal), np.asarray(ref))


def test_float32_precision_mode_within_tolerance():
    tr = hft(seed=0)
    cands = _candidates()[:8]
    b32 = run_surrogate_batched(cands, BOUND, tr, back_annotation=False,
                                precision="float32")
    for a, rb in zip(cands, b32.results()):
        rs = run_surrogate(a, BOUND, tr, back_annotation=False)
        for q in (50.0, 99.0):
            assert rb.p(q) == pytest.approx(rs.p(q), rel=1e-3)


class _SerialSwitchProblem(SwitchDSEProblem):
    """The same problem forced through the serial stage-2 fallback."""
    surrogate_batch = DSEProblem.surrogate_batch


def test_run_dse_pareto_front_identical_batched_vs_serial():
    tr = hft(seed=0)
    req = ArchRequest(n_ports=8, addr_bits=4)
    sla = SLA(p99_latency_ns=5000, drop_rate=1e-3)
    budget = ResourceBudget(dict(ALVEO_U45N))
    res_b = run_dse(SwitchDSEProblem(req, BOUND, tr, back_annotation=False),
                    sla, budget)
    res_s = run_dse(_SerialSwitchProblem(req, BOUND, tr, back_annotation=False),
                    sla, budget)
    assert sorted(a.short() for a, _ in res_b.pareto) == \
           sorted(a.short() for a, _ in res_s.pareto)
    assert res_b.best.short() == res_s.best.short()
    assert [lg.survived for lg in res_b.logs] == \
           [lg.survived for lg in res_s.logs]


def _comm_step_time_scalar(prob, c):
    """Independent scalar reference for the analytic fabric model (the
    pre-vectorisation formulas, kept here so the parity test does not become
    a tautology now that ``surrogate`` delegates to ``surrogate_batch``)."""
    slots = prob.tokens_per_device * prob.cfg.moe_topk * c.capacity_factor
    slot = prob.cfg.d_model * (1 if c.payload == "int8" else 2)
    a2a = 2.0 * slots * slot * ((prob.tp_size - 1) / prob.tp_size)
    t_compute = 3 * 2 * slots * prob.cfg.d_model * prob.cfg.d_ff \
        / prob.hw["peak_flops_bf16"]
    t_wire = a2a / prob.hw["ici_link_gbps"]
    n_chunks = max(c.a2a_chunks, 1)
    t_issue = 5e-6 * n_chunks
    if n_chunks > 1:
        per = max(t_compute, t_wire) / n_chunks
        return per * (n_chunks + 1) + t_issue, a2a
    return t_compute + t_wire + t_issue, a2a


def test_comm_surrogate_batch_matches_scalar_reference():
    """The vectorised analytic fabric model matches an independent scalar
    re-derivation of the formulas, per candidate."""
    jax = pytest.importorskip("jax")
    from repro.comm.dse_comm import CommDSEProblem
    from repro.models.config import ModelConfig, ShardingPlan
    from repro.models.moe import init_moe
    from repro.launch.mesh import compat_make_mesh

    cfg = ModelConfig(name="t", family="moe", n_layers=1, d_model=128,
                      n_heads=4, n_kv_heads=2, d_ff=256, vocab=256,
                      moe_experts=8, moe_topk=2)
    plan = ShardingPlan()
    params, _ = init_moe(jax.random.PRNGKey(0), cfg, plan)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 128))
    mesh = compat_make_mesh((1, 1), ("data", "model"))
    prob = CommDSEProblem(params, cfg, plan, mesh, x, model_tp=8)
    cands = prob.candidates()
    assert len(cands) >= 8
    expected_occ = prob.loads.reshape(-1) / max(prob.loads.mean(), 1e-9)
    results = prob.surrogate_batch(cands)
    assert len(results) == len(cands)
    for c, sb in zip(cands, results):
        t_ref, a2a_ref = _comm_step_time_scalar(prob, c)
        np.testing.assert_array_equal(sb.q_occupancy, expected_occ)
        np.testing.assert_allclose(sb.latency_ns, np.full(16, t_ref * 1e9),
                                   rtol=1e-12)
        assert sb.throughput_gbps == pytest.approx(
            a2a_ref * 8 / max(t_ref, 1e-12) / 1e9, rel=1e-12)
        # the serial hook is the same body at batch size 1
        ss = prob.surrogate(c)
        np.testing.assert_array_equal(sb.q_occupancy, ss.q_occupancy)
        assert ss.q_occupancy is not sb.q_occupancy   # no cross-result aliasing
