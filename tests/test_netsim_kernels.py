"""Segmented netsim kernels vs the oracle engines (PR: kernels/netsim).

Contract under test: with ``use_kernel`` enabled the batched engines produce
**bit-identical** results to the oracle paths — drop counts exact, latency
arrays ``assert_array_equal`` (the documented f64 tolerance is 0), occupancy
counts integer-equal — across workloads, VOQ kinds and sized depths; the
Pallas tile matches the float32 slack oracle bitwise in interpret mode; the
trace-keyed timeline memo sorts each trace exactly once across a whole
NSGA-II run; and the ``use_kernel`` knob round-trips through ``Fidelity``
JSON and composes with the device mesh bit-identically.
"""

import json
import math

import numpy as np
import pytest

from repro.core import (ArchRequest, ForwardTableKind, SLA, SchedulerKind,
                        SwitchArch, VOQKind, bind, compressed_protocol,
                        enumerate_candidates)
from repro.kernels import netsim as kn
from repro.sim import run_netsim, run_netsim_batched, run_surrogate_batched
from repro.sim import timeline as tlmod
from repro.sim.switch_problem import SwitchDSEProblem
from repro.traces import datacenter, hft
from repro.traces.base import Trace

BOUND = bind(compressed_protocol(addr_bits=4, length_bits=6), flit_bits=256)


def _traces():
    return {
        "hft": hft(seed=0),
        "datacenter": datacenter(seed=0, n_ports=8, duration_s=400e-6, load=0.8),
    }


def _sized_candidates():
    base = enumerate_candidates(ArchRequest(n_ports=8, addr_bits=4))
    assert {a.voq for a in base} == {VOQKind.NXN, VOQKind.SHARED}
    return [a.with_depth(d) for a in base[:12] for d in (2, 8, 64)]


def _assert_results_identical(kernel_results, oracle_results):
    for b, s in zip(kernel_results, oracle_results):
        assert b.drop_rate == s.drop_rate
        assert b.p99_latency_ns == s.p99_latency_ns
        assert b.mean_latency_ns == s.mean_latency_ns
        assert b.throughput_gbps == s.throughput_gbps
        assert b.meta["delivered"] == s.meta["delivered"]
        np.testing.assert_array_equal(b.meta["latency_ns"],
                                      s.meta["latency_ns"])


# --------------------------------------------------------------------------
# stage-4 parity matrix: workloads x VOQ kinds x sized depths
# --------------------------------------------------------------------------

@pytest.mark.parametrize("workload", ["hft", "datacenter"])
def test_stage4_kernel_parity_matrix(workload):
    """Kernel vs oracle across both VOQ kinds at depths 2/8/64 — drops
    bit-exact (the small depths genuinely bind), latency bit-identical."""
    tr = _traces()[workload]
    cands = _sized_candidates()
    vk = run_netsim_batched(cands, BOUND, tr, back_annotation=False,
                            use_kernel=True)
    vo = run_netsim_batched(cands, BOUND, tr, back_annotation=False,
                            use_kernel=False)
    assert any(v.drop_rate > 0 for v in vo)      # the depths actually bind
    _assert_results_identical(vk, vo)


def test_stage4_kernel_matches_serial_oracle():
    """Straight to the heapq oracle (not just the ring scan) on a dropping
    workload — the fixed point's drop decisions are the serial decisions."""
    tr = hft(seed=0)
    cands = [a.with_depth(2) for a in
             enumerate_candidates(ArchRequest(n_ports=8, addr_bits=4))[:6]]
    vk = run_netsim_batched(cands, BOUND, tr, back_annotation=False,
                            use_kernel=True)
    vs = [run_netsim(a, BOUND, tr, back_annotation=False) for a in cands]
    assert any(v.drop_rate > 0 for v in vs)
    _assert_results_identical(vk, vs)


def test_shared_cap_fallback_on_kernel_path():
    """Candidates whose shared N·depth cap binds must take the flagged
    serial fallback on the kernel path too, and still match the oracle."""
    n = 8
    rng = np.random.default_rng(0)
    per_src = 120
    times = np.concatenate([np.arange(per_src) * 2.2e-7 + s * 1e-9
                            for s in range(n)])
    srcs = np.concatenate([np.full(per_src, s) for s in range(n)])
    dsts = np.concatenate([rng.integers(0, 4, per_src) for _ in range(n)])
    tr = Trace("incast4", times, srcs, dsts, np.full(n * per_src, 200), n,
               link_gbps=10.0)
    cands = [SwitchArch(n_ports=8, bus_bits=bw,
                        fwd=ForwardTableKind.FULL_LOOKUP, voq=voq,
                        sched=SchedulerKind.RR, voq_depth=d, addr_bits=4)
             for bw in (128, 512)
             for voq in (VOQKind.SHARED, VOQKind.NXN) for d in (8, 16)]
    vk = run_netsim_batched(cands, BOUND, tr, back_annotation=False,
                            use_kernel=True)
    vo = run_netsim_batched(cands, BOUND, tr, back_annotation=False)
    assert any(v.meta.get("shared_cap_fallback") for v in vk)
    for a, b, s in zip(cands, vk, vo):
        assert (b.meta.get("fallback") == "shared_cap") == \
               (s.meta.get("fallback") == "shared_cap"), a.short()
    _assert_results_identical(vk, vo)


# --------------------------------------------------------------------------
# edges: degenerate depth, empty trace, single candidate, single chain
# --------------------------------------------------------------------------

def test_degenerate_depth_kernel():
    tr = hft(seed=0).head(64)
    cands = [_sized_candidates()[0].with_depth(0),
             _sized_candidates()[1].with_depth(8)]
    vk = run_netsim_batched(cands, BOUND, tr, back_annotation=False,
                            use_kernel=True)
    assert vk[0].meta["fallback"] == "degenerate_depth"
    assert vk[0].drop_rate == 1.0
    assert "fallback" not in vk[1].meta
    vo = run_netsim_batched(cands, BOUND, tr, back_annotation=False)
    _assert_results_identical(vk, vo)


def test_empty_trace_kernel():
    empty = Trace("empty", np.zeros(0), np.zeros(0, np.int32),
                  np.zeros(0, np.int32), np.zeros(0, np.int64), 8)
    vk = run_netsim_batched(_sized_candidates()[:3], BOUND, empty,
                            back_annotation=False, use_kernel=True)
    assert len(vk) == 3
    for v in vk:
        assert v.drop_rate == 0.0 and math.isinf(v.p99_latency_ns)
    sk = run_surrogate_batched(_sized_candidates()[:3], BOUND, empty,
                               back_annotation=False, use_kernel=True)
    assert sk.q_occupancy.shape == (3, 0)


def test_single_candidate_kernel():
    tr = hft(seed=1)
    a = _sized_candidates()[0]
    [vk] = run_netsim_batched([a], BOUND, tr, back_annotation=False,
                              use_kernel=True)
    vs = run_netsim(a, BOUND, tr, back_annotation=False)
    assert vk.drop_rate == vs.drop_rate
    np.testing.assert_array_equal(vk.meta["latency_ns"], vs.meta["latency_ns"])


def test_single_chain_trace():
    """All events on one (src, dst) pair: the segmented pass degenerates to
    one chain and must still reproduce the serial model exactly."""
    m = 96
    tr = Trace("onechain", np.arange(m) * 3e-7,
               np.zeros(m, np.int32), np.ones(m, np.int32),
               np.full(m, 300, np.int64), 8, link_gbps=10.0)
    cands = [a.with_depth(d) for a in
             enumerate_candidates(ArchRequest(n_ports=8, addr_bits=4))[:4]
             for d in (2, 8)]
    vk = run_netsim_batched(cands, BOUND, tr, back_annotation=False,
                            use_kernel=True)
    vs = [run_netsim(a, BOUND, tr, back_annotation=False) for a in cands]
    _assert_results_identical(vk, vs)


def test_duplicate_rows_fan_out_with_fresh_meta():
    """NSGA-II batches repeat genomes; deduped rows must come back as
    distinct results whose meta dicts are independently mutable."""
    tr = hft(seed=0).head(256)
    a = _sized_candidates()[0]
    vk = run_netsim_batched([a, a, a], BOUND, tr, back_annotation=False,
                            use_kernel=True)
    assert vk[0].p99_latency_ns == vk[1].p99_latency_ns == vk[2].p99_latency_ns
    vk[0].meta["marker"] = "x"
    assert "marker" not in vk[1].meta


# --------------------------------------------------------------------------
# stage-2: segmented occupancy + lean replay oracles
# --------------------------------------------------------------------------

@pytest.mark.parametrize("workload", ["hft", "datacenter"])
def test_stage2_kernel_occupancy_bitwise(workload):
    tr = _traces()[workload]
    cands = enumerate_candidates(ArchRequest(n_ports=8, addr_bits=4))[:10]
    sk = run_surrogate_batched(cands, BOUND, tr, back_annotation=False,
                               use_kernel=True)
    so = run_surrogate_batched(cands, BOUND, tr, back_annotation=False,
                               use_kernel=False)
    np.testing.assert_array_equal(sk.q_occupancy, so.q_occupancy)
    np.testing.assert_array_equal(sk.latency_ns, so.latency_ns)
    np.testing.assert_array_equal(sk.dep_end_s, so.dep_end_s)
    for rk, ro in zip(sk.results(), so.results()):
        a, b = rk.meta["shared_occupancy"], ro.meta["shared_occupancy"]
        assert (a is None) == (b is None)
        if a is not None:
            np.testing.assert_array_equal(a, b)


def test_pallas_tile_matches_slack_oracle_bitwise():
    """The candidate-tiled Pallas kernel is bit-for-bit the float32 slack
    reference in interpret mode (same formulation, same dtype)."""
    import jax.numpy as jnp

    rng = np.random.default_rng(3)
    n_ports, b_n, m = 8, 5, 160
    now = np.sort(rng.uniform(0, 1e-4, m))
    src = rng.integers(0, n_ports, m).astype(np.int32)
    dst = rng.integers(0, n_ports, m).astype(np.int32)
    svc = rng.uniform(1e-8, 4e-7, (b_n, m)).astype(np.float32)
    pipe = rng.uniform(0, 5e-8, b_n).astype(np.float32)
    admit = rng.random((b_n, m)) > 0.2
    dnow = np.diff(now, prepend=0.0).astype(np.float32)
    ref = np.asarray(kn.netsim_replay_slack_ref(
        jnp.asarray(dnow), jnp.asarray(src), jnp.asarray(dst),
        jnp.asarray(svc), jnp.asarray(pipe), jnp.asarray(admit),
        n_ports=n_ports))
    tile = np.asarray(kn.lean_replay(now, src, dst, svc, pipe, admit,
                                     n_ports=n_ports, use_pallas=True,
                                     interpret=True))
    np.testing.assert_array_equal(tile, ref)


def test_abs_oracle_is_gated_replay():
    """The f64 absolute oracle under all-ones flags equals the slack form
    reconstructed to absolute times within f32-off tolerance, and its gated
    updates actually gate: a dropped event must leave port state alone."""
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    rng = np.random.default_rng(5)
    n_ports, m = 4, 64
    now = np.sort(rng.uniform(0, 1e-5, m))
    src = rng.integers(0, n_ports, m).astype(np.int32)
    dst = rng.integers(0, n_ports, m).astype(np.int32)
    svc = rng.uniform(1e-8, 2e-7, (1, m))
    pipe = np.array([2e-8])
    all_on = np.ones((1, m), bool)
    gated = all_on.copy()
    gated[0, 10] = False
    with enable_x64():
        e_on = np.asarray(kn.netsim_replay_abs_ref(
            jnp.asarray(now), jnp.asarray(src), jnp.asarray(dst),
            jnp.asarray(svc), jnp.asarray(pipe), jnp.asarray(all_on),
            n_ports=n_ports))
        e_gate = np.asarray(kn.netsim_replay_abs_ref(
            jnp.asarray(now), jnp.asarray(src), jnp.asarray(dst),
            jnp.asarray(svc), jnp.asarray(pipe), jnp.asarray(gated),
            n_ports=n_ports))
    # the dropped event still gets an end time, but successors on its ports
    # must not wait for it
    later_same_port = [k for k in range(11, m)
                       if src[k] == src[10] or dst[k] == dst[10]]
    assert later_same_port
    assert np.all(e_gate[0, later_same_port] <= e_on[0, later_same_port])


def test_segmented_admission_matches_bruteforce():
    """The compacted segmented pass equals the obvious per-chain loop."""
    rng = np.random.default_rng(11)
    b_n, m, n_chains, depth = 7, 200, 9, 3
    qid = rng.integers(0, n_chains, m)
    chain = kn.build_chain_index(qid)
    now = np.sort(rng.uniform(0, 1.0, m))
    end = now[None, :] + rng.uniform(0.0, 0.4, (b_n, m))
    admit = rng.random((b_n, m)) > 0.3
    depths = rng.integers(1, depth + 2, b_n)
    got = kn.segmented_admission(end, admit, now, depths, chain)
    want = np.empty_like(got)
    for b in range(b_n):
        for k in range(m):
            mine = [j for j in range(k) if qid[j] == qid[k] and admit[b, j]]
            na = len(mine)
            full = (na >= depths[b]
                    and end[b, mine[na - depths[b]]] > now[k])
            want[b, k] = not full
    np.testing.assert_array_equal(got, want)


# --------------------------------------------------------------------------
# timeline memo: each trace sorted exactly once across a whole search
# --------------------------------------------------------------------------

def test_timeline_sorted_once_across_nsga2_run():
    from repro.api.scenario import SearchSpec
    from repro.core.search import run_search

    tlmod.clear()
    tr = hft(seed=0).head(1024)
    problem = SwitchDSEProblem(
        ArchRequest(n_ports=8, addr_bits=4), BOUND, tr,
        back_annotation=False, use_kernel="on")
    outcome = run_search(problem, SearchSpec(population=12, generations=10,
                                             seed=3),
                         SLA(drop_rate=1e-3), delta=0.2)
    # stage 4 runs post-search in the runner; emulate its repeated
    # verify_batch calls over the survivors (plus a re-verification pass,
    # as campaign scenarios do)
    front = [a for a, _ in outcome.valid][:6]
    assert front
    problem.verify_batch(front)
    problem.verify_batch(front)
    s = tlmod.stats()
    assert s["stage2_builds"] >= 1 and s["stage4_builds"] >= 1
    # the satellite contract: every (trace, structure) timeline was built —
    # i.e. argsort/lexsort/serialisation ran — exactly once for the whole run
    assert all(v == 1 for v in s["builds_by_key"].values()), s["builds_by_key"]
    assert s["stage2_hits"] + s["stage4_hits"] > 0


def test_timeline_memo_hits_across_trace_rebuilds():
    tlmod.clear()
    a, b = hft(seed=0).head(128), hft(seed=0).head(128)
    t1 = tlmod.stage2_timeline(a, 8)
    t2 = tlmod.stage2_timeline(b, 8)        # same content, fresh instance
    assert t1 is t2
    assert tlmod.stats()["stage2_builds"] == 1
    assert tlmod.stats()["stage2_hits"] == 1


# --------------------------------------------------------------------------
# knob plumbing: resolve, Fidelity JSON, engine registry
# --------------------------------------------------------------------------

def test_resolve_use_kernel(monkeypatch):
    assert kn.resolve_use_kernel(True) is True
    assert kn.resolve_use_kernel(False) is False
    assert kn.resolve_use_kernel("on") is True
    assert kn.resolve_use_kernel("off") is False
    assert kn.resolve_use_kernel("auto") is True
    monkeypatch.setenv("SPAC_NETSIM_KERNEL", "off")
    assert kn.resolve_use_kernel("auto") is False
    assert kn.resolve_use_kernel("on") is True     # explicit on still wins
    with pytest.raises(ValueError):
        kn.resolve_use_kernel("sometimes")


def test_use_kernel_fidelity_json_roundtrip():
    from repro.api.scenario import Fidelity, Scenario
    from repro.api import registry

    fid = Fidelity(use_kernel="on")
    assert Fidelity.from_dict(json.loads(json.dumps(fid.to_dict()))) == fid
    # bools normalise to the canonical strings
    assert Fidelity(use_kernel=True).use_kernel == "on"
    assert Fidelity(use_kernel=False).use_kernel == "off"
    with pytest.raises(ValueError):
        Fidelity(use_kernel="sometimes")
    # whole-scenario JSON round-trip preserves the knob
    scn = registry["hft"].override(use_kernel="off")
    assert scn.fidelity.use_kernel == "off"
    back = Scenario.from_json(scn.to_json())
    assert back.fidelity.use_kernel == "off"
    assert back == scn


def test_kernel_rungs_registered():
    from repro.sim.engines import get_engine

    for name, rung in (("batched_surrogate[kernel]", 2),
                       ("batched_netsim[kernel]", 3)):
        spec = get_engine(name)
        assert spec.rung == rung and spec.batched
    tr = hft(seed=0).head(256)
    cands = _sized_candidates()[:3]
    vk = get_engine("batched_netsim[kernel]").evaluate_batch(
        cands, BOUND, tr, back_annotation=False)
    vo = get_engine("batched_netsim").evaluate_batch(
        cands, BOUND, tr, back_annotation=False)
    _assert_results_identical(vk, vo)


def test_problem_rejects_unknown_use_kernel():
    tr = hft(seed=0).head(64)
    with pytest.raises(ValueError, match="use_kernel"):
        SwitchDSEProblem(ArchRequest(n_ports=8, addr_bits=4), BOUND, tr,
                         use_kernel="banana")


# --------------------------------------------------------------------------
# mesh x kernel composition (forced host devices, subprocess)
# --------------------------------------------------------------------------

def test_mesh_kernel_composition_bit_identical():
    from tests.test_mesh_dse import _require_forced_devices, _run

    _require_forced_devices()
    _run("""
import numpy as np
from repro.core import ArchRequest, bind, compressed_protocol, enumerate_candidates
from repro.launch.mesh import MeshSpec
from repro.sim import run_netsim_batched, run_surrogate_batched
from repro.traces import hft

BOUND = bind(compressed_protocol(addr_bits=4, length_bits=6), flit_bits=256)
tr = hft(seed=0)
cands = [a.with_depth(d) for a in
         enumerate_candidates(ArchRequest(n_ports=8, addr_bits=4))[:7]
         for d in (2, 64)]                   # depth 2 drops -> subset iteration
base = run_netsim_batched(cands, BOUND, tr, back_annotation=False,
                          use_kernel=True)
assert any(v.drop_rate > 0 for v in base)
for d in (2, 8):
    got = run_netsim_batched(cands, BOUND, tr, back_annotation=False,
                             use_kernel=True, mesh=MeshSpec(devices=d))
    for vb, vr in zip(base, got):
        assert vb.p99_latency_ns == vr.p99_latency_ns
        assert vb.drop_rate == vr.drop_rate
        assert vb.throughput_gbps == vr.throughput_gbps
        np.testing.assert_array_equal(vb.meta["latency_ns"],
                                      vr.meta["latency_ns"])
    s = run_surrogate_batched(cands, BOUND, tr, back_annotation=False,
                              use_kernel=True, mesh=MeshSpec(devices=d))
    s0 = run_surrogate_batched(cands, BOUND, tr, back_annotation=False,
                               use_kernel=True)
    np.testing.assert_array_equal(s0.q_occupancy, s.q_occupancy)
    np.testing.assert_array_equal(s0.latency_ns, s.latency_ns)
    print("devices", d, "kernel bit-identical OK")
""")
