"""Per-kernel interpret-mode validation: shape/dtype sweeps vs ref oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st  # optional dep, skips cleanly

from repro.core import ethernet_ipv4_udp, compressed_protocol, Field, Protocol


# ----------------------------------------------------------------- quant_pack

@pytest.mark.parametrize("shape", [(8, 128), (256, 384), (64, 1024)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quantize_matches_ref(shape, dtype):
    from repro.kernels.quant_pack import kernel, ref
    x = jax.random.normal(jax.random.PRNGKey(0), shape, dtype)
    q1, s1 = kernel.quantize(x)
    q2, s2 = ref.quantize_ref(x)
    np.testing.assert_array_equal(np.asarray(q1), np.asarray(q2))
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-6)


def test_quantize_roundtrip_error_bounded():
    from repro.kernels.quant_pack import kernel
    x = jax.random.normal(jax.random.PRNGKey(1), (128, 256), jnp.float32)
    q, s = kernel.quantize(x)
    xr = kernel.dequantize(q, s)
    group_max = np.abs(np.asarray(x)).reshape(128, 2, 128).max(-1)
    bound = np.repeat(group_max / 127.0, 128, axis=-1).reshape(128, 256) * 0.5 + 1e-6
    assert (np.abs(np.asarray(xr) - np.asarray(x)) <= bound).all()


def test_compress_arbitrary_shapes():
    from repro.kernels.quant_pack.ops import compress, decompress, compression_ratio
    x = jax.random.normal(jax.random.PRNGKey(2), (3, 5, 37), jnp.float32)
    q, s, meta = compress(x)
    xr = decompress(q, s, meta)
    assert xr.shape == x.shape
    assert compression_ratio(x) > 3.0
    assert float(jnp.abs(xr - x).max()) < 0.05


# --------------------------------------------------------------------- parser

@pytest.mark.parametrize("proto_fn,fields", [
    (ethernet_ipv4_udp, ["eth_dst", "ip_tos", "ip_dst", "udp_dst"]),
    (lambda: compressed_protocol(addr_bits=4, length_bits=6), ["dst", "src", "len"]),
])
@pytest.mark.parametrize("n", [1, 7, 300])
def test_parser_kernel_matches_ref(proto_fn, fields, n):
    from repro.kernels.parser.ops import parse_headers
    from repro.kernels.parser.ref import parse_ref
    from repro.switch.parser import pack_header_words
    proto = proto_fn()
    rng = np.random.default_rng(0)
    vals = {f.name: rng.integers(0, min(1 << f.bits, 1 << 31), n, dtype=np.uint64)
            for f in proto.fields}
    words = jnp.asarray(pack_header_words(proto, vals))
    out_k = parse_headers(proto, fields, words, use_pallas=True)
    out_r = parse_ref(proto, fields, words)
    np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))


@st.composite
def _proto_and_vals(draw):
    nf = draw(st.integers(2, 6))
    fields = [Field(f"f{i}", draw(st.integers(1, 31))) for i in range(nf)]
    proto = Protocol("rand", fields)
    vals = {f.name: np.array([draw(st.integers(0, (1 << f.bits) - 1))
                              for _ in range(3)], dtype=np.uint64)
            for f in fields}
    return proto, vals


@given(_proto_and_vals())
@settings(max_examples=15, deadline=None)
def test_parser_kernel_random_protocols(pv):
    from repro.kernels.parser.ops import parse_headers
    from repro.switch.parser import pack_header_words
    proto, vals = pv
    words = jnp.asarray(pack_header_words(proto, vals))
    names = [f.name for f in proto.fields]
    out = parse_headers(proto, names, words, use_pallas=True)
    for i, f in enumerate(proto.fields):
        np.testing.assert_array_equal(np.asarray(out[:, i]),
                                      vals[f.name].astype(np.uint32))


# ------------------------------------------------------------ flash attention

@pytest.mark.parametrize("s,d,hq,hkv", [(128, 64, 4, 4), (256, 64, 8, 2), (256, 128, 4, 1)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_matches_ref(s, d, hq, hkv, causal):
    from repro.kernels.flash_attention.ops import attention_reference, flash_attention
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(k1, (2, hq, s, d), jnp.float32)
    k = jax.random.normal(k2, (2, hkv, s, d), jnp.float32)
    v = jax.random.normal(k3, (2, hkv, s, d), jnp.float32)
    o1 = flash_attention(q, k, v, causal=causal, block_q=64, block_k=128)
    o2 = attention_reference(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=3e-5, rtol=3e-5)


def test_flash_attention_bf16():
    from repro.kernels.flash_attention.ops import attention_reference, flash_attention
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(4), 3)
    q = jax.random.normal(k1, (1, 4, 128, 64), jnp.bfloat16)
    k = jax.random.normal(k2, (1, 4, 128, 64), jnp.bfloat16)
    v = jax.random.normal(k3, (1, 4, 128, 64), jnp.bfloat16)
    o1 = flash_attention(q, k, v, block_q=64, block_k=64)
    o2 = attention_reference(q, k, v)
    assert float(jnp.abs(o1.astype(jnp.float32) - o2.astype(jnp.float32)).max()) < 0.05


def test_xla_blockwise_matches_pallas():
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.models.attention import blockwise_attention
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(5), 3)
    q = jax.random.normal(k1, (2, 4, 256, 64), jnp.float32)
    k = jax.random.normal(k2, (2, 2, 256, 64), jnp.float32)
    v = jax.random.normal(k3, (2, 2, 256, 64), jnp.float32)
    o1 = blockwise_attention(q, k, v, causal=True, block_q=64, block_k=64)
    o2 = flash_attention(q, k, v, causal=True, block_q=64, block_k=64)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=3e-5)


# ------------------------------------------------------------------------ ssd

@pytest.mark.parametrize("s,p,n,chunk", [(128, 32, 16, 32), (256, 64, 32, 64),
                                         (256, 64, 128, 128)])
def test_ssd_kernel_and_chunked_match_ref(s, p, n, chunk):
    from repro.kernels.ssd.kernel import ssd_scan
    from repro.kernels.ssd.ops import ssd_chunked, ssd_reference
    kk = jax.random.split(jax.random.PRNGKey(3), 5)
    bh = 2
    x = jax.random.normal(kk[0], (bh, s, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(kk[1], (bh, s))) * 0.1
    a = -jnp.exp(jax.random.normal(kk[2], (bh,)) * 0.3)
    b = jax.random.normal(kk[3], (bh, s, n), jnp.float32)
    c = jax.random.normal(kk[4], (bh, s, n), jnp.float32)
    ref = ssd_reference(x, dt, a, b, c)
    np.testing.assert_allclose(np.asarray(ssd_chunked(x, dt, a, b, c, chunk=chunk)),
                               np.asarray(ref), atol=2e-3, rtol=2e-3)
    np.testing.assert_allclose(np.asarray(ssd_scan(x, dt, a, b, c, chunk=chunk)),
                               np.asarray(ref), atol=2e-3, rtol=2e-3)


def test_ssd_decode_step_matches_prefill_state():
    """Chunked prefill final state == running the sequential decode steps."""
    from repro.kernels.ssd.ops import ssd_chunked, ssd_decode_step
    kk = jax.random.split(jax.random.PRNGKey(7), 5)
    bh, s, p, n = 2, 64, 16, 8
    x = jax.random.normal(kk[0], (bh, s, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(kk[1], (bh, s))) * 0.1
    a = -jnp.exp(jax.random.normal(kk[2], (bh,)) * 0.3)
    b = jax.random.normal(kk[3], (bh, s, n), jnp.float32)
    c = jax.random.normal(kk[4], (bh, s, n), jnp.float32)
    _, final = ssd_chunked(x, dt, a, b, c, chunk=16, return_state=True)
    state = jnp.zeros((bh, p, n))
    for t in range(s):
        state, _ = ssd_decode_step(state, x[:, t], dt[:, t], a, b[:, t], c[:, t])
    np.testing.assert_allclose(np.asarray(final), np.asarray(state), atol=1e-3)


# ---------------------------------------------------------------- iSLIP

@pytest.mark.parametrize("n,iters", [(4, 1), (8, 2), (16, 3)])
def test_islip_kernel_matches_lax_scheduler(n, iters):
    from repro.kernels.islip.ops import islip_schedule
    rng = np.random.default_rng(1)
    B = 16
    req = jnp.asarray(rng.integers(0, 2, (B, n, n)), jnp.int32)
    g = jnp.asarray(rng.integers(0, n, (B, n)), jnp.int32)
    a = jnp.asarray(rng.integers(0, n, (B, n)), jnp.int32)
    m1, g1, a1 = islip_schedule(req, g, a, iters=iters, use_pallas=True)
    m2, g2, a2 = islip_schedule(req, g, a, iters=iters, use_pallas=False)
    np.testing.assert_array_equal(np.asarray(m1), np.asarray(m2))
    np.testing.assert_array_equal(np.asarray(g1), np.asarray(g2))
    np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))


@given(st.integers(0, 2**16 - 1), st.integers(1, 3))
@settings(max_examples=25, deadline=None)
def test_islip_kernel_match_validity_property(bits, iters):
    from repro.kernels.islip.ops import islip_schedule
    n = 4
    req = jnp.asarray([(bits >> i) & 1 for i in range(n * n)], jnp.int32).reshape(1, n, n)
    g = jnp.zeros((1, n), jnp.int32)
    a = jnp.zeros((1, n), jnp.int32)
    m, _, _ = islip_schedule(req, g, a, iters=iters, use_pallas=True)
    m = np.asarray(m[0])
    assert (m.sum(0) <= 1).all() and (m.sum(1) <= 1).all()
    assert not (m & ~np.asarray(req[0]).astype(bool)).any()
    if np.asarray(req[0]).any():
        assert m.any()
