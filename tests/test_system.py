"""End-to-end system behaviour: the two-stage SPAC workflow (paper §III) and
the Table-II adaptation loop on real workload traces."""

import numpy as np
import pytest

from repro.core import (ArchRequest, ForwardTableKind, SLA, SchedulerKind,
                        VOQKind, analyze, bind, compressed_protocol,
                        ethernet_ipv4_udp)
from repro.sim import optimize_switch, run_netsim, synthesize
from repro.core.archspec import SwitchArch
from repro.traces import WORKLOADS, underwater


def _spac_ethernet_baseline(n_ports: int) -> SwitchArch:
    """The paper's fixed general-purpose design point (§V-A Baselines)."""
    return SwitchArch(n_ports=n_ports, bus_bits=512,
                      fwd=ForwardTableKind.MULTIBANK_HASH, voq=VOQKind.NXN,
                      sched=SchedulerKind.ISLIP, voq_depth=160, addr_bits=12)


def test_two_stage_workflow_underwater():
    """§V-C underwater row: compressed protocol + minimalist architecture cuts
    LUT/BRAM vs the SPAC-Ethernet baseline while keeping delivery."""
    tr = underwater(seed=0)
    proto = compressed_protocol(addr_bits=4, length_bits=6)   # 2B header
    bound = bind(proto, flit_bits=256)
    res, prob = optimize_switch(
        ArchRequest(n_ports=8, addr_bits=4), bound, tr,
        sla=SLA(p99_latency_ns=1e5, drop_rate=1e-3), back_annotation=False)
    assert res.best is not None
    base = _spac_ethernet_baseline(8)
    eth = bind(ethernet_ipv4_udp(), flit_bits=512)
    r_opt = synthesize(res.best, bound)
    r_base = synthesize(base, eth)
    assert r_opt.luts < 0.6 * r_base.luts        # ≥40% LUT saving (paper: ~55%)
    assert r_opt.brams < 0.6 * r_base.brams      # ≥40% BRAM saving (paper: ~53%)
    assert res.best_verify.drop_rate <= 1.5e-3


@pytest.mark.parametrize("workload", ["hft", "industry", "underwater"])
def test_adaptation_beats_fixed_baseline_on_latency(workload):
    """Table II: DSE-custom design has lower mean latency than SPAC-Ethernet."""
    tr = WORKLOADS[workload](seed=0)
    n = tr.n_ports
    bound = bind(compressed_protocol(addr_bits=max(4, (n - 1).bit_length()),
                                     length_bits=8), flit_bits=256)
    res, _ = optimize_switch(ArchRequest(n_ports=n, addr_bits=bound.addr_bits),
                             bound, tr, sla=SLA(p99_latency_ns=1e6, drop_rate=1e-2),
                             back_annotation=False)
    assert res.best is not None
    base = _spac_ethernet_baseline(n)
    eth = bind(ethernet_ipv4_udp(), flit_bits=512)
    v_base = run_netsim(base, eth, tr, back_annotation=False)
    assert res.best_verify.mean_latency_ns < v_base.mean_latency_ns


def test_trace_features_drive_architecture_choice():
    """Protocol sensitivity (Fig. 1 right): compressed headers raise goodput
    on tiny payloads; feature extraction reflects each workload's character."""
    f = {name: analyze(gen(seed=0)) for name, gen in WORKLOADS.items()}
    assert f["underwater"].s_mean < 4
    assert f["rl_allreduce"].incast_ratio >= 0.4       # aggregator hotspot
    assert f["hft"].i_burst > f["industry"].i_burst    # bursts vs polling
    # goodput ratio: 2B header vs 42B on 2-byte payloads
    wire_compressed = 2 + 2
    wire_eth = 2 + 42
    assert wire_eth / wire_compressed > 10
