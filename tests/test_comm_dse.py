"""Algorithm 1 on the TPU fabric (repro.comm.dse_comm): sizing + Pareto."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import CommSpec, autotune_moe, route_trace
from repro.models.config import ModelConfig, ShardingPlan
from repro.models.moe import MoEOptions, apply_moe, init_moe

PLAN = ShardingPlan()


@pytest.fixture(scope="module")
def fabric(mesh11):
    cfg = ModelConfig(name="t", family="moe", n_layers=1, d_model=256, n_heads=4,
                      n_kv_heads=2, d_ff=512, vocab=512, moe_experts=16, moe_topk=2)
    params, _ = init_moe(jax.random.PRNGKey(0), cfg, PLAN)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 128, 256), jnp.bfloat16)
    return cfg, params, x


def test_route_trace_shape_and_conservation(fabric, mesh11):
    cfg, params, x = fabric
    loads = route_trace(params, cfg, x, tp_size=1)
    assert loads.shape[1] == cfg.moe_experts
    assert loads.sum() == x.shape[0] * x.shape[1] * cfg.moe_topk


def test_autotune_returns_verified_low_drop_spec(fabric, mesh11):
    cfg, params, x = fabric
    res, prob = autotune_moe(params, cfg, PLAN, mesh11, x, model_tp=16)
    assert res.best is not None
    assert res.best_verify.drop_rate <= 3e-2          # ε + sizing slack
    # sized capacity factor covers the measured load quantile
    assert res.best.capacity_factor >= 1.0


def test_autotune_respects_memory_budget(fabric, mesh11):
    cfg, params, x = fabric
    res, prob = autotune_moe(params, cfg, PLAN, mesh11, x, model_tp=16,
                             hbm_budget_bytes=5e5)    # absurdly tight
    for c, v, r, ok in res.evaluated:
        assert r["bytes_per_device"] <= 5e5


def test_commspec_roundtrip_to_moe_options(fabric, mesh11):
    cfg, params, x = fabric
    c = CommSpec(capacity_factor=2.0, payload="int8", a2a_chunks=2)
    y, aux = apply_moe(params, cfg, PLAN, mesh11, x, c.moe_options())
    assert bool(jnp.isfinite(y.astype(jnp.float32)).all())
