"""Protocol/architecture co-design: the protocol layout as a search dimension.

Covers the PR-5 acceptance bars:
  * ProtocolSpace mechanics (decode/enumerate/layout_key/feasible),
  * ranged ProtocolSpec + Scenario co_design JSON round-trips bit-for-bit,
  * scenario-build validation of under-sized address fields,
  * the genome splice (proto:* dims, memoized binds, phenotype dedupe),
  * checkpoint/resume with protocol genes, bit-identical,
  * stage-2 stays one batched call per generation on the shared trace
    (no per-genome trace rebuilds),
  * on hft, co-design strictly dominates the best fixed-Ethernet design on
    (mean latency, LUTs).
"""

import dataclasses
import json

import numpy as np
import pytest

from repro.api import ProtocolSpec, Scenario, SearchSpec, registry, run_scenario
from repro.api.runner import build_problem
from repro.core import (ArchRequest, FieldSpec, ProtocolSpace, ResourceBudget,
                        SLA, compressed_protocol, compressed_protocol_space,
                        ethernet_ipv4_udp, layout_key, run_dse)
from repro.core.search import run_search
from repro.sim import ALVEO_U45N, CoDesignCandidate, SwitchDSEProblem, synthesize
from repro.sim.switch_problem import PROTO_DIM_PREFIX
from repro.traces.workloads import hft

FAST_TRACE = {"duration_s": 8e-5}


def _space():
    return compressed_protocol_space(
        "spac_t", addr_bits=(4, 8), qos_bits=(0, 2), length_bits=(0, 6, 12),
        seq_bits=(0, 8))


# --------------------------------------------------------------------------
# ProtocolSpace mechanics
# --------------------------------------------------------------------------

def test_space_decode_matches_builder_layout():
    sp = _space()
    pt = sp.decode({"dst": 4, "src": 4, "qos": 2, "len": 6, "seq": 0})
    built = compressed_protocol(addr_bits=4, qos_bits=2, length_bits=6)
    assert layout_key(pt) == layout_key(built)
    assert pt.header_bits == built.header_bits == 16
    assert pt.name == "spac_t/dst4-src4-qos2-len6"


def test_space_enumerate_and_size():
    sp = _space()
    protos = list(sp.enumerate())
    assert len(protos) == sp.size() == 2 * 2 * 2 * 3 * 2
    assert len({layout_key(p) for p in protos}) == sp.size()


def test_layout_key_canonicalises_dropped_fields():
    sp = _space()
    k = sp.layout_key({"dst": 4, "src": 4, "qos": 0, "len": 0, "seq": 0})
    assert k == (("dst", 4, "routing_key", 0), ("src", 4, "src_key", 0))
    assert k == layout_key(sp.decode((4, 4, 0, 0, 0)))


def test_space_rejects_widths_outside_choices():
    with pytest.raises(ValueError, match="not among the choices"):
        _space().decode({"dst": 5, "src": 4, "qos": 0, "len": 0, "seq": 0})


def test_fieldspec_validation():
    with pytest.raises(ValueError, match="no width choices"):
        FieldSpec("x", ())
    with pytest.raises(ValueError, match="always dropped"):
        FieldSpec("x", (0,))
    with pytest.raises(ValueError, match="duplicate width"):
        FieldSpec("x", (4, 4))


def test_feasibility_rules_name_the_numbers():
    sp = _space()
    ok = {"dst": 4, "src": 4, "qos": 0, "len": 12, "seq": 0}
    assert sp.feasible(ok, n_ports=8) is None
    r = sp.feasible(ok, n_ports=32)
    assert "4 bits" in r and "n_ports=32" in r and ">= 5 bits" in r
    r = sp.feasible({**ok, "len": 0}, variable_payload=True)
    assert "length" in r
    r = sp.feasible({**ok, "len": 6}, max_payload_bytes=100)
    assert "63" in r and "100" in r
    r = sp.feasible(ok, needs_seq=True)
    assert "seq" in r
    assert sp.feasible({**ok, "seq": 8}, needs_seq=True) is None


# --------------------------------------------------------------------------
# ranged ProtocolSpec + Scenario round-trips
# --------------------------------------------------------------------------

def test_ranged_protocol_spec_roundtrip_bit_for_bit():
    spec = ProtocolSpec(params={"addr_bits": [4, 8, 16], "length_bits": 12,
                                "name": "wire"})
    assert spec.is_space
    d = spec.to_dict()
    back = ProtocolSpec.from_dict(json.loads(json.dumps(d)))
    assert back == spec
    assert back.to_dict() == d
    with pytest.raises(ValueError, match="protocol .space."):
        spec.build()
    sp = spec.space()
    assert dict(sp.dims())["dst"] == (4, 8, 16)
    assert dict(sp.dims())["len"] == (12,)


def test_inline_fieldspec_roundtrip():
    spec = ProtocolSpec(
        builder="inline", name="custom",
        fields=(FieldSpec("key", (8, 16), "routing_key"),
                ProtocolSpec.inline(compressed_protocol()).fields[1]))
    d = json.loads(json.dumps(spec.to_dict()))
    back = ProtocolSpec.from_dict(d)
    assert back == spec and back.is_space
    assert dict(back.space().dims())["key"] == (8, 16)


def test_widen_keeps_pinned_value_reachable():
    spec = ProtocolSpec(params={"addr_bits": 5, "length_bits": 12, "name": "w"})
    wide = spec.widen()
    assert wide.is_space
    assert 5 in wide.params["addr_bits"]           # original layout reachable
    assert 12 in wide.params["length_bits"]
    assert wide.widen() == wide                    # idempotent
    with pytest.raises(ValueError, match="fixed layout"):
        ProtocolSpec(builder="ethernet_ipv4_udp").space()


def test_codesign_scenario_roundtrip_bit_for_bit():
    s = registry["hft"].override(
        back_annotation=False, co_design=True,
        search=SearchSpec(population=16, generations=3, seed=7))
    assert s.co_design and s.protocol.is_space
    d = s.to_dict()
    back = Scenario.from_dict(json.loads(json.dumps(d)))
    assert back == s
    assert back.to_dict() == d


def test_codesign_scenario_validation():
    with pytest.raises(ValueError, match="switch domain"):
        registry["moe_dispatch"].override(co_design=True)
    with pytest.raises(ValueError, match="ranged protocol params"):
        dataclasses.replace(registry["hft"], co_design=True)
    # co-design without a search spec fails at build time with guidance
    s = registry["hft"].override(co_design=True)
    with pytest.raises(ValueError, match="SearchSpec"):
        build_problem(s)


def test_co_design_cannot_be_silently_narrowed():
    s = registry["hft"].override(
        co_design=True, search=SearchSpec(population=8, generations=2, seed=0))
    # widening is lossy: explicitly turning co-design back off must fail
    # with guidance, not leave a ranged spec that build() rejects later
    with pytest.raises(ValueError, match="pin each width"):
        s.override(co_design=False)
    # and the CLI surfaces override problems as clean SystemExit, no traceback
    from repro.api.cli import build_parser, _apply_overrides
    args = build_parser().parse_args(
        ["run", "underwater", "--search", "nsga2", "--co-design"])
    eth = dataclasses.replace(registry["hft"],
                              protocol=ProtocolSpec(builder="ethernet_ipv4_udp"))
    with pytest.raises(SystemExit, match="cannot widen builder"):
        _apply_overrides(eth, args)
    # a scenario FILE carrying co_design without a search spec (no flags)
    # also exits cleanly instead of a build_problem traceback
    args = build_parser().parse_args(["run", "whatever.json"])
    from_file = registry["hft"].override(
        co_design=True, search=SearchSpec(population=8, generations=2))
    from_file = dataclasses.replace(from_file, search=None)
    with pytest.raises(SystemExit, match="no search"):
        _apply_overrides(from_file, args)


def test_undersized_address_fields_fail_at_build():
    s = registry["hft"].override(back_annotation=False)
    s = dataclasses.replace(
        s, protocol=ProtocolSpec(params={"addr_bits": 2, "name": "tiny"}))
    with pytest.raises(ValueError, match=r"2 bits .addresses 4 ports.*n_ports=8"):
        build_problem(s)


# --------------------------------------------------------------------------
# the genome splice
# --------------------------------------------------------------------------

def _problem(n_ports=8, addr_bits=(4, 8), trace=None, **kw):
    sp = compressed_protocol_space("spac_t", addr_bits=addr_bits,
                                   qos_bits=(0, 2), length_bits=(0, 12),
                                   seq_bits=0)
    tr = trace if trace is not None else hft(seed=0, **FAST_TRACE)
    return SwitchDSEProblem(
        ArchRequest(n_ports=n_ports, addr_bits=4), None, tr,
        back_annotation=False, protocol_space=sp, flit_bits=256, **kw), sp


def test_space_splices_protocol_genes():
    prob, sp = _problem()
    space = prob.space()
    proto_dims = {d.name: d.choices for d in space.dims
                  if d.name.startswith(PROTO_DIM_PREFIX)}
    assert proto_dims == {PROTO_DIM_PREFIX + n: c for n, c in sp.dims()}
    arch_only = SwitchDSEProblem(
        ArchRequest(n_ports=8, addr_bits=4), prob.bound, prob.trace,
        back_annotation=False).space()
    assert space.size() == arch_only.size() * sp.size()


def test_decode_memoizes_binds_and_dedupes_phenotypes():
    prob, _ = _problem()
    space = prob.space()
    a = space.assignment(next(space.genomes()))
    c1 = prob.decode(a)
    c2 = prob.decode(dict(a))
    assert isinstance(c1, CoDesignCandidate)
    assert c1 == c2 and hash(c1) == hash(c2)
    assert c1.bound is c2.bound                    # one bind per layout
    # a different layout is a different phenotype
    other = dict(a)
    other[PROTO_DIM_PREFIX + "dst"] = 8
    assert prob.decode(other) != c1
    # addr_bits follows the decoded routing field (CAM key pricing)
    assert prob.decode(other).arch.addr_bits == 8


def test_infeasible_layouts_are_statically_pruned():
    # 32 ports: 4-bit addresses cannot address them
    from repro.traces.workloads import uniform
    tr = uniform(seed=0, n_ports=32, **FAST_TRACE)
    prob, _ = _problem(n_ports=32, trace=tr)
    space = prob.space()
    a = space.assignment(next(space.genomes()))
    a[PROTO_DIM_PREFIX + "dst"] = 4
    c = prob.decode(a)
    assert c.bound is None and "n_ports=32" in c.infeasible
    t_proc, t_arrival = prob.static_timing(c)
    assert not np.isfinite(t_proc)
    a[PROTO_DIM_PREFIX + "dst"] = 8
    a[PROTO_DIM_PREFIX + "src"] = 8
    c = prob.decode(a)
    assert c.bound is not None
    assert np.isfinite(prob.static_timing(c)[0])


def test_binding_override_on_dropped_field_is_infeasible_not_a_crash():
    # an explicit SemanticBinding naming an optional field only fails when a
    # layout drops that field — decode must absorb it as static infeasibility
    from repro.core import SemanticBinding
    prob, _ = _problem(binding=SemanticBinding(qos="qos"))
    space = prob.space()
    a = space.assignment(next(space.genomes()))
    a[PROTO_DIM_PREFIX + "qos"] = 0
    c = prob.decode(a)
    assert c.bound is None and "qos" in c.infeasible
    assert not np.isfinite(prob.static_timing(c)[0])
    # the bind failure is memoized like successful binds
    assert prob.decode(dict(a)).infeasible == c.infeasible
    # layouts that keep the field bind fine
    a[PROTO_DIM_PREFIX + "qos"] = 2
    assert prob.decode(a).bound is not None
    # and a full search over the overridden binding completes
    outcome = _run(prob, SearchSpec(population=10, generations=2, seed=0))
    assert outcome.valid


def test_require_seq_prunes_seqless_layouts():
    sp = compressed_protocol_space("spac_t", addr_bits=(4,), qos_bits=0,
                                   length_bits=(0, 12), seq_bits=(0, 8))
    tr = hft(seed=0, **FAST_TRACE)
    prob = SwitchDSEProblem(ArchRequest(n_ports=8, addr_bits=4), None, tr,
                            back_annotation=False, protocol_space=sp,
                            flit_bits=256, require_seq=True)
    space = prob.space()
    a = space.assignment(next(space.genomes()))
    a[PROTO_DIM_PREFIX + "seq"] = 0
    c = prob.decode(a)
    assert c.bound is None and "seq" in c.infeasible
    a[PROTO_DIM_PREFIX + "seq"] = 8
    assert prob.decode(a).bound is not None


def test_candidates_refuses_codesign_enumeration():
    prob, _ = _problem()
    with pytest.raises(ValueError, match="generational-search"):
        prob.candidates()


# --------------------------------------------------------------------------
# search integration: checkpoint/resume, one batched call per generation
# --------------------------------------------------------------------------

def _run(prob, spec, **kw):
    return run_search(prob, spec, SLA(p99_latency_ns=5e3, drop_rate=1e-3), **kw)


def test_checkpoint_resume_with_protocol_genes_bit_identical(tmp_path):
    spec = SearchSpec(population=10, generations=4, seed=3)
    trace = hft(seed=0, **FAST_TRACE)
    straight = _run(_problem(trace=trace)[0], spec)

    ck = str(tmp_path / "ck")
    interrupted = _run(_problem(trace=trace)[0], spec, checkpoint_dir=ck,
                       max_generations_this_run=2)
    assert interrupted.generations == 2
    resumed = _run(_problem(trace=trace)[0], spec, checkpoint_dir=ck,
                   resume=True)
    assert resumed.resumed
    assert resumed.generations == straight.generations
    assert resumed.hv_history == straight.hv_history
    assert [c for c, _ in resumed.valid] == [c for c, _ in straight.valid]
    # the checkpointed space signature carries the protocol genes
    from repro.core.search import load_search_state
    prob, sp = _problem(trace=trace)
    eng = load_search_state(ck, prob.space(), spec)
    proto_sig = {k: v for k, v in eng.space.signature().items()
                 if k.startswith(PROTO_DIM_PREFIX)}
    assert proto_sig == {PROTO_DIM_PREFIX + n: len(c) for n, c in sp.dims()}


def test_one_batched_call_per_generation_no_trace_rebuilds(monkeypatch):
    import repro.sim.switch_problem as swp
    calls = []
    real = swp.run_surrogate_batched

    def spy(archs, bound, trace, **kw):
        calls.append((len(archs), trace))
        return real(archs, bound, trace, **kw)

    monkeypatch.setattr(swp, "run_surrogate_batched", spy)
    prob, _ = _problem()
    spec = SearchSpec(population=12, generations=3, seed=0)
    outcome = _run(prob, spec)
    # one batched surrogate call per generation (+ none extra for finalize:
    # the archive is already in the phenotype cache)
    assert len(calls) == outcome.generations
    assert all(tr is prob.trace for _, tr in calls)   # the ONE shared trace
    assert sum(n for n, _ in calls) == outcome.surrogate_rows


# --------------------------------------------------------------------------
# the acceptance bar: co-design dominates the fixed-Ethernet design on hft
# --------------------------------------------------------------------------

def test_codesign_dominates_fixed_ethernet_on_hft():
    fixed = registry["hft"].override(
        back_annotation=False, top_k=4, trace_params=FAST_TRACE)
    fixed = dataclasses.replace(
        fixed, protocol=ProtocolSpec(builder="ethernet_ipv4_udp"), flit_bits=512)
    fixed_rep = run_scenario(fixed)
    assert fixed_rep.best is not None

    codesign = registry["hft"].override(
        back_annotation=False, top_k=4, trace_params=FAST_TRACE,
        co_design=True, search=SearchSpec(population=16, generations=5, seed=7))
    cd_rep = run_scenario(codesign)
    assert cd_rep.best is not None
    assert isinstance(cd_rep.best, CoDesignCandidate)

    # Table II's headline: the co-designed header is a fraction of 42 B
    assert cd_rep.best_bound.header_bytes < ethernet_ipv4_udp().header_bytes

    lat_cd = cd_rep.best_verify.mean_latency_ns
    lat_eth = fixed_rep.best_verify.mean_latency_ns
    lut_cd = cd_rep.resources["luts"]
    lut_eth = fixed_rep.resources["luts"]
    # strict Pareto domination on (mean latency, LUTs)
    assert lat_cd <= lat_eth and lut_cd <= lut_eth
    assert lat_cd < lat_eth or lut_cd < lut_eth

    # the report carries the winning layout, serialized
    d = cd_rep.to_dict()
    assert d["best_protocol"]["name"].startswith("spac_hft/")
    assert d["best_protocol"]["header_bytes"] == cd_rep.best_bound.header_bytes
    assert any(f["semantic"] == "routing_key" for f in d["best_protocol"]["fields"])


def test_campaign_mixes_codesign_and_fixed_scenarios():
    from repro.api import run_campaign
    cd = registry["hft"].override(
        back_annotation=False, top_k=2, trace_params=FAST_TRACE,
        co_design=True, name="hft_cd",
        search=SearchSpec(population=10, generations=3, seed=0))
    plain = registry["hft"].override(back_annotation=False, top_k=2,
                                     trace_params=FAST_TRACE)
    rep = run_campaign([cd, plain], name="mix")
    assert rep["hft_cd"].best is not None and rep["hft"].best is not None
    assert isinstance(rep["hft_cd"].best, CoDesignCandidate)
    assert rep["hft_cd"].to_dict()["best_protocol"]["name"].startswith("spac_hft/")
    assert rep["hft"].to_dict()["best_protocol"]["name"] == "spac_hft"
    assert rep.shared_trace_scenarios == 1      # one built trace serves both


def test_codesign_run_dse_end_to_end_objectives_use_own_layout():
    prob, _ = _problem()
    res = run_dse(prob, SLA(p99_latency_ns=5e3, drop_rate=1e-3),
                  ResourceBudget(dict(ALVEO_U45N)),
                  search=SearchSpec(population=10, generations=3, seed=1),
                  top_k=3)
    assert res.best is not None
    for c, v in res.pareto:
        assert isinstance(c, CoDesignCandidate) and c.bound is not None
        rep = synthesize(c.arch, c.bound)
        assert prob.objectives(c, v) == (v.mean_latency_ns, rep.brams)
