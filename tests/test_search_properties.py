"""Property-based invariants for the Pareto utilities and the NSGA-II engine
(hypothesis when available; skips cleanly otherwise — example-based twins of
the same assertions live in ``tests/test_search.py`` so the logic is always
exercised).

  * ``pareto_front``: no returned member is dominated by any input point;
    the result is invariant under input permutation; union-stability
    (front(front(A) ∪ front(B)) == front(A ∪ B) as sets).
  * ``hypervolume_2d``: non-negative; monotone non-decreasing under point
    insertion; dominated points contribute nothing (hv(S) == hv(front(S))).
  * NSGA-II: archive hypervolume is non-decreasing generation over
    generation, and the same seed yields a bit-identical front.
"""

import numpy as np

from hypothesis_compat import given, settings, st

from repro.core.pareto import hypervolume_2d, is_dominated, pareto_front
from repro.core.search import DesignSpace, Dim, NSGA2Search, SearchSpec

_coord = st.floats(min_value=0.0, max_value=100.0,
                   allow_nan=False, allow_infinity=False)
_points = st.lists(st.tuples(_coord, _coord), min_size=1, max_size=24)


def _front_set(pts):
    return set(pareto_front(list(pts), key=lambda p: p))


# --------------------------------------------------------------------------
# pareto_front
# --------------------------------------------------------------------------

@settings(deadline=None)
@given(_points)
def test_pareto_front_members_not_dominated(pts):
    front = pareto_front(pts, key=lambda p: p)
    assert front, "front of a non-empty set is non-empty"
    for f in front:
        assert not any(is_dominated(f, q) for q in pts)


@settings(deadline=None)
@given(_points, st.integers(min_value=0, max_value=2 ** 16))
def test_pareto_front_permutation_invariant(pts, seed):
    shuffled = list(pts)
    np.random.default_rng(seed).shuffle(shuffled)
    assert _front_set(pts) == _front_set(shuffled)


@settings(deadline=None)
@given(_points, _points)
def test_pareto_front_union_stability(a, b):
    partial = list(_front_set(a)) + list(_front_set(b))
    assert _front_set(partial) == _front_set(list(a) + list(b))


# --------------------------------------------------------------------------
# hypervolume_2d
# --------------------------------------------------------------------------

@settings(deadline=None)
@given(_points)
def test_hypervolume_nonnegative_and_front_equivalent(pts):
    ref = (101.0, 101.0)                       # strictly beyond every point
    hv = hypervolume_2d(pts, ref)
    assert hv >= 0.0
    front = pareto_front(pts, key=lambda q: q)
    assert abs(hypervolume_2d(front, ref) - hv) <= 1e-9 * max(hv, 1.0)


@settings(deadline=None)
@given(_points, st.tuples(_coord, _coord))
def test_hypervolume_monotone_under_insertion(pts, p):
    ref = (101.0, 101.0)
    before = hypervolume_2d(pts, ref)
    after = hypervolume_2d(list(pts) + [p], ref)
    assert after >= before - 1e-9 * max(before, 1.0)


# --------------------------------------------------------------------------
# NSGA-II engine invariants
# --------------------------------------------------------------------------

def _drive(seed: int) -> NSGA2Search:
    space = DesignSpace(tuple(Dim(f"x{i}", tuple(range(6)))
                              for i in range(4)))
    eng = NSGA2Search(space, SearchSpec(population=12, generations=6,
                                        seed=seed, patience=100))
    while not eng.done:
        asked = eng.ask()
        eng.tell({g: ((float(sum(g)),
                       float(sum((5 - x) ** 2 for x in g))), 0.0)
                  for g in asked})
    return eng


@settings(deadline=None, max_examples=10)
@given(st.integers(min_value=0, max_value=2 ** 16))
def test_nsga2_archive_hypervolume_non_decreasing(seed):
    eng = _drive(seed)
    hist = eng.hv_history
    assert len(hist) == eng.generation
    assert all(h2 >= h1 - 1e-12 for h1, h2 in zip(hist, hist[1:]))


@settings(deadline=None, max_examples=10)
@given(st.integers(min_value=0, max_value=2 ** 16))
def test_nsga2_same_seed_bit_identical_front(seed):
    a, b = _drive(seed), _drive(seed)
    assert a.front() == b.front()
    assert a.hv_history == b.hv_history
    assert a.parents == b.parents
    assert a.n_asked == b.n_asked


@settings(deadline=None, max_examples=10)
@given(st.integers(min_value=0, max_value=2 ** 16))
def test_nsga2_respects_evaluation_budget(seed):
    space = DesignSpace(tuple(Dim(f"x{i}", tuple(range(6)))
                              for i in range(4)))
    eng = NSGA2Search(space, SearchSpec(population=12, generations=50,
                                        seed=seed, patience=100,
                                        max_evaluations=30))
    while not eng.done:
        asked = eng.ask()
        eng.tell({g: ((float(sum(g)), float(-min(g))), 0.0) for g in asked})
    assert eng.n_asked <= 30
    assert len(eng.cache) <= 30
