"""Multi-hop fabric layer: topologies, hop-composed evaluation, per-tier DSE.

Contracts under test (see ``docs/architecture.md`` "Fabric topologies"):

* every topology routes every (src, dst) pair with structurally valid,
  deterministic hops — ECMP resolves by the explicit flow hash, never by
  ``hash()`` or an RNG — and ``TopologySpec`` round-trips through JSON;
* a 1-node ring fabric is the *identity*: ``evaluate_fabric_batched``
  reproduces the direct ``run_netsim_batched`` call bit-for-bit (the
  hop-composition latency identity telescopes at one hop);
* multi-hop composition equals a manual per-hop replay through the serial
  heapq oracle: arrival forwarding, sentinel drop masking and latency
  telescoping are all independently recomputed here;
* ``VOQKind.SHARED`` is fabric-infeasible at every layer (flattening would
  pool the shared cap across a tier's nodes);
* the per-tier genome splice round-trips through ``space()``/``decode()``;
* acceptance: per-tier co-design strictly dominates the homogeneous
  fixed-Ethernet fabric on (end-to-end p99, summed fabric LUTs).
"""

import dataclasses
import itertools
import json

import numpy as np
import pytest

from repro.core import (ArchRequest, ForwardTableKind, ResourceBudget, SLA,
                        VOQKind, bind, compressed_protocol,
                        enumerate_candidates, run_dse)
from repro.core.dsl import ethernet_ipv4_udp
from repro.fabric import (FabricCandidate, FabricDSEProblem, FatTree,
                          LeafSpine, Ring, TIER_DIM_PREFIX, TopologySpec,
                          build_topology, evaluate_fabric_batched,
                          fabric_routes, flatten_tier_arch, flow_hash)
from repro.sim import run_netsim, run_netsim_batched
from repro.sim.backannotate import annotate
from repro.sim.resources import ALVEO_U45N
from repro.traces import datacenter, uniform
from repro.traces.base import Trace

BOUND = bind(compressed_protocol(addr_bits=4, length_bits=12), flit_bits=256)

TOPOLOGIES = {
    "fattree4": FatTree(4),
    "leafspine": LeafSpine(leaves=2, spines=3, hosts_per_leaf=2),
    "ring": Ring(n_nodes=4, hosts_per_node=2),
    "ring1": Ring(n_nodes=1, hosts_per_node=8),
}


# --------------------------------------------------------------------------
# topology structure
# --------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(TOPOLOGIES))
def test_topology_routes_every_pair(name):
    topo = TOPOLOGIES[name]
    for src in range(topo.n_hosts):
        for dst in range(topo.n_hosts):
            hops = topo.route(src, dst)
            assert hops and len(hops) <= topo.max_hops
            topo.validate_route(hops)
            # the same pair must re-route identically (goldens diff routes)
            assert topo.route(src, dst) == hops
    with pytest.raises(ValueError):
        topo.route(0, topo.n_hosts)


def test_topology_nodes_and_links_are_deterministic():
    topo = TOPOLOGIES["fattree4"]
    assert topo.nodes() == [(0, e) for e in range(4)] + [(1, c) for c in range(2)]
    links = topo.links()
    assert links == topo.links()
    # 8 host attachments + 4*2 edge-core links
    assert len(links) == 8 + 8


def test_fattree_ecmp_spreads_and_is_hash_keyed():
    topo = TOPOLOGIES["fattree4"]
    cores = set()
    for src, dst in itertools.product(range(topo.n_hosts), repeat=2):
        hops = topo.route(src, dst)
        if len(hops) == 3:
            assert hops[1].tier == 1
            assert hops[1].node == flow_hash(src, dst) % 2
            cores.add(hops[1].node)
        else:
            # intra-edge stays a single 1-hop traversal
            assert len(hops) == 1 and src // 2 == dst // 2
    assert cores == {0, 1}          # ECMP actually uses both cores


def test_ring_shortest_path_with_hash_tiebreak():
    topo = TOPOLOGIES["ring"]       # 4 nodes, 2 hosts each
    # adjacent nodes: 2 switch traversals, never the long way round
    assert len(topo.route(0, 2)) == 2
    # antipodal nodes tie at 2 steps either way: the hash picks, 3 hops
    hops = topo.route(0, 4)
    assert len(hops) == 3
    assert topo.route(0, 4) == hops


def test_topology_spec_roundtrip_and_validation():
    spec = TopologySpec.make("leafspine", leaves=2, spines=3, hosts_per_leaf=2)
    again = TopologySpec.from_dict(json.loads(json.dumps(spec.to_dict())))
    assert again == spec
    assert again.build().key() == spec.build().key()
    with pytest.raises(ValueError):
        TopologySpec.make("mesh3d")          # unknown kind
    with pytest.raises(ValueError):
        TopologySpec.make("fattree", k=3)    # bad params fail at spec time
    with pytest.raises(ValueError):
        build_topology("nosuch")


def test_scenario_topology_field_roundtrip():
    from repro.api import registry
    from repro.api.scenario import Scenario
    s = registry["fattree_dc"]
    assert Scenario.from_json(s.to_json()) == s
    with pytest.raises(ValueError):
        dataclasses.replace(registry["moe_dispatch"],
                            topology=TopologySpec.make("fattree", k=4))


# --------------------------------------------------------------------------
# hop-composed evaluation
# --------------------------------------------------------------------------

def _nxn_candidates(n_ports, depths=(1, 64)):
    base = [a for a in enumerate_candidates(
        ArchRequest(n_ports=n_ports, addr_bits=4,
                    fwd=ForwardTableKind.MULTIBANK_HASH))
            if a.voq is VOQKind.NXN]
    return [a.with_depth(d) for a in base[:3] for d in depths]


def test_single_hop_ring_is_bit_identical_to_direct_engine():
    """Ring(1 node) has every route = one hop through the only switch, so
    the fabric evaluator must reproduce ``run_netsim_batched`` exactly —
    latencies to the last ulp, drops to the packet."""
    topo = TOPOLOGIES["ring1"]
    tr = uniform(seed=0, n_ports=8)
    cands = _nxn_candidates(8)
    direct = run_netsim_batched(cands, BOUND, tr, back_annotation=False)
    fabric = evaluate_fabric_batched(
        topo, [(a,) for a in cands], [(BOUND,) for _ in cands], tr,
        back_annotation=False)
    assert any(v.drop_rate > 0 for v in direct)      # the depths bind
    for a, d, f in zip(cands, direct, fabric):
        msg = a.short()
        assert f.drop_rate == d.drop_rate, msg
        assert f.meta["delivered"] == d.meta["delivered"], msg
        np.testing.assert_array_equal(f.meta["latency_full_ns"],
                                      d.meta["latency_full_ns"], err_msg=msg)
        assert f.p99_latency_ns == d.p99_latency_ns, msg
        assert f.mean_latency_ns == d.mean_latency_ns, msg
        assert f.meta["fabric"]["per_tier_drops"] == [
            int(d.drop_rate * d.meta["offered"])], msg


def test_multihop_matches_manual_per_hop_oracle():
    """Independent replay of the composition contract with the *serial*
    heapq oracle: per hop, sort the masked arrivals, run ``run_netsim`` on
    the flattened tier, forward ``t + latency*1e-9``, mask drops, and sum
    latencies in ns.  The batched fabric evaluator must agree exactly."""
    topo = TOPOLOGIES["fattree4"]
    tr = uniform(seed=1, n_ports=8, duration_s=100e-6)
    # depth 1 at the edge forces real drops, so the sentinel masking and
    # per-tier drop attribution are exercised, not just the happy path
    arch = _nxn_candidates(4, depths=(1,))[0]
    tiers = (arch, arch.with_depth(16))
    hw = tuple(annotate(a, BOUND, source="model") for a in tiers)

    got = evaluate_fabric_batched(topo, [tiers], [(BOUND, BOUND)], tr,
                                  back_annotation=False)[0]

    routes = fabric_routes(topo, tr)
    t0 = np.asarray(tr.time_s, float)
    payload = np.asarray(tr.payload_bytes)
    arr, e2e = t0.copy(), np.zeros(t0.size)
    alive = np.ones(t0.size, bool)
    for s in range(routes.max_hops):
        for t, tier in enumerate(topo.tiers):
            sel = np.nonzero(routes.tier_of[s] == t)[0]
            if not sel.size:
                continue
            times = arr[sel].copy()
            if not alive[sel].all():
                live = times[alive[sel]]
                times[~alive[sel]] = (live.max() if live.size else 0.0) + 1.0
            # the oracle re-sorts per hop on purpose: it must independently
            # reproduce the evaluator's per-hop sorting, not share it
            perm = np.argsort(times, kind="stable")  # spaclint: disable=SPAC208
            sub = Trace(name=f"manual@{s}{t}", time_s=times[perm],
                        src=routes.flat_in[s, sel][perm].astype(np.int32),
                        dst=routes.flat_out[s, sel][perm].astype(np.int32),
                        payload_bytes=payload[sel][perm],
                        n_ports=tier.n_nodes * tier.degree,
                        link_gbps=tr.link_gbps)
            v = run_netsim(flatten_tier_arch(tiers[t], tier.n_nodes), BOUND,
                           sub, hw=hw[t], back_annotation=False)
            lat = np.empty(sel.size)
            lat[perm] = v.meta["latency_full_ns"]
            ok = alive[sel] & ~np.isnan(lat)
            arr[sel] = np.where(ok, arr[sel] + lat * 1e-9, arr[sel])
            e2e[sel] = np.where(ok, e2e[sel] + lat, e2e[sel])
            alive[sel] = ok

    assert got.drop_rate > 0                 # the masking path has teeth
    assert got.meta["delivered"] == int(alive.sum())
    np.testing.assert_array_equal(got.meta["latency_full_ns"],
                                  np.where(alive, e2e, np.nan))
    # multi-hop routes actually exercised composition
    assert got.meta["fabric"]["mean_hops"] > 1.0
    assert got.meta["fabric"]["max_hops"] == 3


def test_shared_voq_is_fabric_infeasible_everywhere():
    from repro.sim.switch_problem import SwitchDSEProblem
    shared = [a for a in enumerate_candidates(
        ArchRequest(n_ports=4, addr_bits=4)) if a.voq is VOQKind.SHARED][0]
    with pytest.raises(ValueError, match="SHARED"):
        flatten_tier_arch(shared, 4)
    problem = _fabric_problem(BOUND)
    for p in problem.tier_problems:
        assert all(SwitchDSEProblem._arch(c).voq is not VOQKind.SHARED
                   for c in p.candidates())
        for d in p.space().dims:
            if d.name == "voq":
                assert VOQKind.SHARED not in d.choices


# --------------------------------------------------------------------------
# the fabric DSE problem
# --------------------------------------------------------------------------

def _fabric_problem(bound, topo=None, **kwargs):
    return FabricDSEProblem(
        topo or TOPOLOGIES["fattree4"],
        ArchRequest(n_ports=4, addr_bits=4,
                    fwd=ForwardTableKind.MULTIBANK_HASH, voq=VOQKind.NXN),
        bound, datacenter(seed=0, n_ports=8),
        back_annotation=False, **kwargs)


def test_fabric_space_is_the_per_tier_splice():
    problem = _fabric_problem(BOUND)
    space = problem.space()
    per_tier = problem.tier_problems[0].space()
    assert space.size() == per_tier.size() ** 2
    names = [d.name for d in space.dims]
    assert all(n.startswith(TIER_DIM_PREFIX(0)) or
               n.startswith(TIER_DIM_PREFIX(1)) for n in names)
    # decode strips the prefix per tier and may specialise tiers separately
    assignment = {}
    for d in space.dims:
        assignment[d.name] = (d.choices[0] if d.name.startswith("t0:")
                              else d.choices[-1])
    cand = problem.decode(assignment)
    assert isinstance(cand, FabricCandidate) and len(cand.tiers) == 2
    archs = problem._tier_archs(cand)
    assert [a.n_ports for a in archs] == [4, 4]
    assert archs[0].bus_bits != archs[1].bus_bits
    assert len(problem.diversity_key(cand)) == 2


def test_fabric_resources_sum_over_nodes():
    problem = _fabric_problem(BOUND)
    cand = problem.candidates()[0]
    from repro.sim.resources import synthesize
    per_node = [synthesize(a, b) for a, b in
                zip(problem._tier_archs(cand), problem._tier_bounds(cand))]
    tot = problem.resources(cand)
    # 4 edge nodes + 2 core nodes
    assert tot["luts"] == pytest.approx(
        per_node[0].luts * 4 + per_node[1].luts * 2)
    assert tot["bram"] == tot["brams"]


class _HomogeneousFabric(FabricDSEProblem):
    """Baseline problem: both tiers forced to one identical design."""

    def candidates(self):
        return [FabricCandidate(tiers=(a, a))
                for a in self.tier_problems[0].candidates()]


def test_codesign_strictly_dominates_homogeneous_ethernet():
    """Acceptance: the per-tier compressed-protocol search produces a point
    that beats *every* Pareto point of the homogeneous fixed-Ethernet
    fabric on both end-to-end p99 and summed fabric LUTs."""
    sla = SLA(p99_latency_ns=1e5, drop_rate=1e-2)
    budget = ResourceBudget({k: v * 6 for k, v in ALVEO_U45N.items()})
    req = ArchRequest(n_ports=4, addr_bits=4,
                      fwd=ForwardTableKind.MULTIBANK_HASH, voq=VOQKind.NXN)
    tr = datacenter(seed=0, n_ports=8)

    pc = FabricDSEProblem(TOPOLOGIES["fattree4"], req, BOUND, tr,
                          back_annotation=False)
    front_c = [pc.objectives(a, v)
               for a, v in run_dse(pc, sla, budget, delta=2.5).pareto]

    pe = _HomogeneousFabric(TOPOLOGIES["fattree4"], req,
                            bind(ethernet_ipv4_udp(), flit_bits=256), tr,
                            back_annotation=False)
    front_e = [pe.objectives(a, v)
               for a, v in run_dse(pe, sla, budget, delta=2.5).pareto]
    assert front_c and front_e
    assert any(all(c[0] < e[0] and c[1] < e[1] for e in front_e)
               for c in front_c), (front_c, front_e)


def test_fabric_report_carries_multi_hop_metrics():
    """The golden snapshot's fabric block is the multi-hop story a single
    switch cannot express: end-to-end p50 alongside p99, hop statistics and
    per-tier drop attribution."""
    from repro.api import registry, run_scenario
    report = run_scenario(registry["fattree_dc"].override(
        back_annotation=False))
    assert report.best is not None
    fab = report.to_dict()["best_verify"]["fabric"]
    assert fab["max_hops"] == 3 and 1.0 < fab["mean_hops"] <= 3.0
    assert fab["p50_latency_ns"] <= report.result.best_verify.p99_latency_ns
    assert len(fab["per_tier_drops"]) == 2
