"""Static-analysis layer: spec diagnostics, lint rules, retrace guard.

Three contracts under test (see ``docs/architecture.md`` "Static analysis"):

* ``spac check`` flags each seeded bad-fixture spec with its documented
  ``SPAC1xx`` code and comes back clean on every registry scenario;
* ``spaclint`` rules fire on minimal positive fixtures (including a
  reproduction of the PR 3 shared-mutable-default bug), honour suppression
  comments, and find nothing in the repo itself;
* the retrace guard proves the stage-2/stage-4 engines compile exactly once
  per (shape, mesh) — at one device in-process and at two forced devices in
  a subprocess.
"""

import dataclasses
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

sys.path.insert(0, os.path.join(REPO, "src"))

from repro.analysis import lint as lint_mod
from repro.analysis.check import check_scenario
from repro.analysis.diagnostics import (Diagnostic, exit_code,
                                        to_json_payload, worst_severity)
from repro.analysis.retrace import RetraceError, retrace_guard
from repro.api.cli import main as cli_main
from repro.api.registry import registry
from repro.core.dse import SLA, ResourceBudget


# --------------------------------------------------------------------------
# spac check: the four seeded bad fixtures + registry cleanliness
# --------------------------------------------------------------------------

def _with_protocol_params(scenario, **params):
    proto = dataclasses.replace(scenario.protocol,
                                params={**scenario.protocol.params, **params})
    return dataclasses.replace(scenario, protocol=proto)


def _codes(diags):
    return {d.code for d in diags}


def test_check_underaddressed_routing_field():
    """hft has 8 ports; a 2-bit dst/src cannot address them -> SPAC101."""
    bad = _with_protocol_params(registry["hft"], addr_bits=2)
    diags = check_scenario(bad)
    hits = [d for d in diags if d.code == "SPAC101"]
    assert {d.location for d in hits} == {"protocol.dst", "protocol.src"}
    assert all(d.severity == "error" and d.hint for d in hits)
    assert exit_code(diags) == 1


def test_check_unsatisfiable_sla():
    """p99 of 1 ns sits below every pipeline's analytic floor -> SPAC102."""
    bad = dataclasses.replace(registry["hft"], sla=SLA(p99_latency_ns=1.0))
    diags = check_scenario(bad)
    assert "SPAC102" in _codes(diags)
    (hit,) = [d for d in diags if d.code == "SPAC102"]
    assert hit.severity == "error"
    assert hit.location == "sla.p99_latency_ns"
    assert "lower bound" in hit.message


def test_check_sla_throughput_facet():
    bad = dataclasses.replace(registry["hft"],
                              sla=SLA(min_throughput_gbps=1e6))
    assert "SPAC102" in _codes(check_scenario(bad))


def test_check_overbudget_resource_request():
    """10 LUTs is under the cheapest depth-1 candidate -> SPAC103 error;
    a key the resource model never produces -> SPAC103 warning."""
    bad = dataclasses.replace(registry["hft"],
                              budget=ResourceBudget({"luts": 10.0,
                                                     "gates": 1.0}))
    diags = [d for d in check_scenario(bad) if d.code == "SPAC103"]
    assert {d.severity for d in diags} == {"error", "warning"}
    assert any(d.location.endswith(".luts") and d.severity == "error"
               for d in diags)
    assert any(d.location.endswith(".gates") and d.severity == "warning"
               for d in diags)


def test_check_dead_codesign_gene():
    """datacenter has 32 ports; an addr menu of (2, 4) bits leaves no live
    width -> SPAC104 dead gene, and the whole layout space dies -> SPAC105."""
    base = registry["datacenter"]
    wide = dataclasses.replace(base, protocol=base.protocol.widen())
    dead = _with_protocol_params(wide, addr_bits=(2, 4))
    diags = check_scenario(dead)
    d104 = [d for d in diags if d.code == "SPAC104"]
    assert any(d.location == "protocol.dst" and d.severity == "error"
               for d in d104)
    d105 = [d for d in diags if d.code == "SPAC105"]
    assert d105 and d105[0].severity == "error"
    assert "0 of" in d105[0].message


def test_check_codesign_space_is_info_only():
    """A healthy widened space reports size/fraction as info, exit 0."""
    base = registry["datacenter"]
    wide = dataclasses.replace(base, protocol=base.protocol.widen())
    diags = check_scenario(wide)
    assert _codes(diags) == {"SPAC105"}
    assert worst_severity(diags) == "info"
    assert exit_code(diags) == 0


def test_check_fabric_topology_addressability():
    """SPAC106: swapping fattree_dc's k=4 topology for k=8 makes 32 hosts —
    the 4-bit routing/src fields, the 4-port tier template, and the 8-id
    trace all stop matching the fabric, and each mismatch is named."""
    from repro.api.scenario import TopologySpec
    bad = dataclasses.replace(
        registry["fattree_dc"], name="bad_fabric",
        topology=TopologySpec.make("fattree", k=8))
    diags = [d for d in check_scenario(bad) if d.code == "SPAC106"]
    assert all(d.severity == "error" and d.hint for d in diags)
    locs = {d.location for d in diags}
    # routing + src addressability vs the *host* count, not n_ports
    assert {"protocol.dst", "protocol.src"} <= locs
    assert any("32 hosts" in d.message for d in diags)
    # tier degree vs the arch template (both tiers of a k=8 tree have deg 8)
    assert sum(1 for d in diags if d.location == "arch.n_ports") == 2
    # trace endpoint ids vs the host count
    assert "trace.n_ports" in locs


def test_check_fabric_codesign_space_addressability():
    """The space path of SPAC106: a widened protocol whose every routing
    width is narrower than the host count is a dead fabric gene."""
    from repro.api.scenario import TopologySpec
    base = registry["fattree_dc"]
    wide = dataclasses.replace(base, protocol=base.protocol.widen())
    # 3-bit max routing addresses 8 hosts of k=4; k=8's 32 hosts need 5 bits
    dead = _with_protocol_params(
        dataclasses.replace(wide, name="bad_fabric_space",
                            topology=TopologySpec.make("fattree", k=8)),
        addr_bits=(2, 4))
    hits = [d for d in check_scenario(dead) if d.code == "SPAC106"
            and d.location == "protocol.dst"]
    assert hits and "no width choice" in hits[0].message


def test_check_registry_all_clean():
    """Acceptance: every registered workload (switch and comm) exits 0."""
    for name in registry.names():
        diags = check_scenario(registry[name])
        assert exit_code(diags) == 0, (name, [d.format() for d in diags])


def test_diagnostic_record_shape():
    d = Diagnostic("SPAC101", "error", "msg", "protocol.dst", hint="widen")
    assert d.to_dict() == {"code": "SPAC101", "severity": "error",
                           "message": "msg", "location": "protocol.dst",
                           "hint": "widen"}
    assert "hint: widen" in d.format()
    with pytest.raises(ValueError):
        Diagnostic("SPAC101", "fatal", "msg", "loc")
    payload = to_json_payload([d])
    assert payload["exit_code"] == 1 and payload["worst_severity"] == "error"


# --------------------------------------------------------------------------
# spac check CLI: exit codes 0 / 1 / 2, no tracebacks
# --------------------------------------------------------------------------

def test_check_cli_clean_and_json(capsys):
    assert cli_main(["check", "hft", "grad_bucket"]) == 0
    assert "clean" in capsys.readouterr().out
    assert cli_main(["check", "hft", "--format", "json"]) == 0
    assert '"exit_code": 0' in capsys.readouterr().out


def test_check_cli_findings_from_fixture_file(tmp_path, capsys):
    bad = _with_protocol_params(registry["hft"], addr_bits=2)
    path = tmp_path / "bad_hft.json"
    path.write_text(bad.to_json())
    assert cli_main(["check", str(path)]) == 1
    out = capsys.readouterr().out
    assert "SPAC101" in out and str(path) in out


def test_check_cli_usage_errors(tmp_path, capsys):
    assert cli_main(["check", "no_such_scenario"]) == 2
    assert "unknown scenario" in capsys.readouterr().err
    bad = tmp_path / "malformed.json"
    bad.write_text("{ not json")
    assert cli_main(["check", str(bad)]) == 2
    assert "cannot load" in capsys.readouterr().err
    with pytest.raises(SystemExit) as e:
        cli_main(["check", "hft", "--format", "yaml"])
    assert e.value.code == 2


# --------------------------------------------------------------------------
# spaclint rules: positive + suppressed fixtures
# --------------------------------------------------------------------------

def _lint(src, **kw):
    return lint_mod.lint_source(textwrap.dedent(src), filename="fix.py", **kw)


def test_lint_parse_error_is_spac200():
    (d,) = _lint("def f(:\n")
    assert d.code == "SPAC200" and d.severity == "error"


def test_lint_mutable_default_pr3_reproduction():
    """The exact PR 3 bug class: a constructed config as a default."""
    diags = _lint("""
        def run_netsim(arch, cfg: NetSimConfig = NetSimConfig()):
            return cfg
    """)
    assert [d.code for d in diags] == ["SPAC201"]
    assert "NetSimConfig" in diags[0].message and "None" in diags[0].hint


def test_lint_mutable_default_literals_and_exemptions():
    assert [d.code for d in _lint("def f(xs=[]): pass")] == ["SPAC201"]
    assert [d.code for d in _lint("def f(m={}): pass")] == ["SPAC201"]
    assert [d.code for d in _lint("def f(*, s=set()): pass")] == ["SPAC201"]
    assert _lint("def f(t=(1, 2), fs=frozenset({1}), x=None): pass") == []


def test_lint_global_np_random():
    diags = _lint("""
        import numpy as np
        x = np.random.rand(3)
    """)
    assert [d.code for d in diags] == ["SPAC202"]
    assert _lint("import numpy as np\nrng = np.random.default_rng(0)\n") == []


def test_lint_wallclock_report_key():
    diags = _lint("""
        import time
        def bench():
            t0 = time.time()
            work()
            return {"wall_s": time.time() - t0,
                    "wall_time_s": time.time() - t0}
    """)
    assert [d.code for d in diags] == ["SPAC203"]
    assert "'wall_s'" in diags[0].message
    # division launders: a rate derived from a timestamp is not a timestamp
    assert _lint("""
        import time
        def bench(n):
            t0 = time.time()
            return {"rows_per_sec": n / (time.time() - t0)}
    """) == []


def test_lint_wallclock_subscript_assignment():
    diags = _lint("""
        import time
        def bench(rec):
            t0 = time.perf_counter()
            rec["elapsed"] = time.perf_counter() - t0
            rec["elapsed_time_s"] = time.perf_counter() - t0
    """)
    assert [d.code for d in diags] == ["SPAC203"]


def test_lint_set_iteration():
    assert [d.code for d in _lint("for d in {1, 2, 3}:\n    use(d)\n")] \
        == ["SPAC204"]
    assert [d.code for d in _lint("xs = list({f(d) for d in ds})\n")] \
        == ["SPAC204"]
    assert _lint("for d in sorted({1, 2, 3}):\n    use(d)\n") == []


def test_lint_jit_closing_over_mutable_global():
    diags = _lint("""
        import jax
        STATE = {"k": 1}
        @jax.jit
        def f(x):
            return x + STATE["k"]
    """)
    assert [d.code for d in diags] == ["SPAC205"]
    assert _lint("""
        import jax
        STATE = (1, 2)
        @jax.jit
        def f(x):
            return x + STATE[0]
    """) == []


def test_lint_unscoped_x64():
    assert [d.code for d in _lint("enable_x64()\n")] == ["SPAC206"]
    assert [d.code for d in
            _lint("config.update('jax_enable_x64', True)\n")] == ["SPAC206"]
    assert _lint("with enable_x64():\n    run()\n") == []


def test_lint_jit_in_loop():
    diags = _lint("""
        import jax
        for i in range(3):
            f = jax.jit(lambda x: x + i)
    """)
    assert [d.code for d in diags] == ["SPAC207"]
    # the builder idiom (one jit per static config) is the sanctioned fix
    assert _lint("""
        import jax
        def build(i):
            return jax.jit(lambda x: x + i)
        for i in range(3):
            f = build(i)
    """) == []


def test_lint_sort_in_loop():
    diags = _lint("""
        import numpy as np
        for b in range(8):
            ends = np.sort(dep[b])
    """)
    assert [d.code for d in diags] == ["SPAC208"]
    assert "loop body" in diags[0].message
    # the For iterable is evaluated once — a sort there is not per-iteration
    assert _lint("""
        import numpy as np
        for k in np.argsort(times, kind="stable"):
            use(k)
    """) == []
    # batch-axis sort outside the loop is the sanctioned fix
    assert _lint("""
        import numpy as np
        ends = np.sort(dep, axis=1)
        for b in range(8):
            use(ends[b])
    """) == []
    # a while condition re-evaluates every iteration, so it does count
    assert [d.code for d in _lint("""
        import numpy as np
        while np.lexsort((a, b))[0] != 0:
            step()
    """)] == ["SPAC208"]


def test_lint_suppression_comment():
    line = "def f(xs=[]):  # spaclint: disable=SPAC201\n    pass\n"
    assert _lint(line) == []
    bare = "def f(xs=[]):  # spaclint: disable\n    pass\n"
    assert _lint(bare) == []
    wrong = "def f(xs=[]):  # spaclint: disable=SPAC204\n    pass\n"
    assert [d.code for d in _lint(wrong)] == ["SPAC201"]


def test_lint_select_filter():
    src = "def f(xs=[]):\n    pass\nfor d in {1, 2}:\n    use(d)\n"
    assert [d.code for d in _lint(src, select={"SPAC204"})] == ["SPAC204"]


def test_lint_cli_exit_codes(tmp_path, capsys):
    assert lint_mod.main(["--list-rules"]) == 0
    assert "SPAC201" in capsys.readouterr().out
    assert lint_mod.main(["--select", "SPAC999", str(tmp_path)]) == 2
    assert lint_mod.main([str(tmp_path / "nope")]) == 2
    dirty = tmp_path / "dirty.py"
    dirty.write_text("def f(xs=[]):\n    pass\n")
    capsys.readouterr()
    assert lint_mod.main([str(dirty)]) == 1
    assert "SPAC201" in capsys.readouterr().out
    assert cli_main(["lint", str(dirty), "--select", "SPAC204"]) == 0


def test_repo_lints_clean():
    """Satellite (a): every violation the rules found was fixed, not
    suppressed — the whole repo must come back empty."""
    paths = [os.path.join(REPO, d) for d in ("src", "tests", "benchmarks")]
    diags = lint_mod.lint_paths(paths)
    assert diags == [], "\n".join(d.format() for d in diags)


# --------------------------------------------------------------------------
# retrace guard: one compile per (shape, mesh)
# --------------------------------------------------------------------------

def test_retrace_guard_single_device():
    from repro.core import (ArchRequest, bind, compressed_protocol,
                            enumerate_candidates)
    from repro.sim import run_netsim_batched, run_surrogate_batched
    from repro.traces import hft

    bound = bind(compressed_protocol(addr_bits=4, length_bits=6),
                 flit_bits=256)
    cands = enumerate_candidates(ArchRequest(n_ports=8, addr_bits=4))[:3]
    # a duration no other test uses -> a fresh event-count shape, so the
    # expectations hold regardless of what ran earlier in the suite
    tr = hft(seed=0, duration_s=6.7e-5)

    with retrace_guard(expect=1) as g:
        run_surrogate_batched(cands, bound, tr, back_annotation=False)
    assert g.deltas() == {"surrogate.engine": 1}
    with retrace_guard(expect=0):
        run_surrogate_batched(cands, bound, tr, back_annotation=False)
    with retrace_guard(expect=1):        # new batch size -> exactly one more
        run_surrogate_batched(cands[:2], bound, tr, back_annotation=False)

    with retrace_guard(expect=1) as g:
        run_netsim_batched(cands, bound, tr, back_annotation=False)
    assert g.deltas() == {"netsim.engine": 1}
    with retrace_guard(expect=0):
        run_netsim_batched(cands, bound, tr, back_annotation=False)


def test_retrace_guard_raises_on_mismatch():
    with pytest.raises(RetraceError, match="expected exactly 3"):
        with retrace_guard(expect=3):
            pass


def _run_forced(code, devices=2):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", code], env=env, cwd=REPO,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def _forced_devices_work():
    try:
        out = _run_forced("import jax; print(jax.device_count())")
    except AssertionError:
        return False
    return out.strip().endswith("2")


def test_retrace_guard_sharded_two_devices():
    """Acceptance: the lru-cached sharded builders compile exactly once per
    (shape, mesh) at 2 devices, and repeat calls add zero."""
    if not _forced_devices_work():
        pytest.skip("cannot force 2 simulated host devices on this backend")
    out = _run_forced(textwrap.dedent("""
        from repro.core import (ArchRequest, bind, compressed_protocol,
                                enumerate_candidates)
        from repro.sim import run_netsim_batched, run_surrogate_batched
        from repro.launch.mesh import MeshSpec
        from repro.traces import hft
        from repro.analysis.retrace import retrace_guard

        bound = bind(compressed_protocol(addr_bits=4, length_bits=6),
                     flit_bits=256)
        cands = enumerate_candidates(ArchRequest(n_ports=8, addr_bits=4))[:4]
        tr = hft(seed=0, duration_s=6.7e-5)
        mesh = MeshSpec(devices=2)

        with retrace_guard(expect=1) as g:
            run_surrogate_batched(cands, bound, tr, back_annotation=False,
                                  mesh=mesh)
        (name,) = g.deltas()
        assert name.startswith("surrogate.sharded["), name
        with retrace_guard(expect=0):
            run_surrogate_batched(cands, bound, tr, back_annotation=False,
                                  mesh=mesh)

        with retrace_guard(expect=1) as g:
            run_netsim_batched(cands, bound, tr, back_annotation=False,
                               mesh=mesh)
        (name,) = g.deltas()
        assert name.startswith("netsim.sharded["), name
        with retrace_guard(expect=0):
            run_netsim_batched(cands, bound, tr, back_annotation=False,
                               mesh=mesh)
        print("ok")
    """))
    assert out.strip().endswith("ok")
