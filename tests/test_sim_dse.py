"""Simulation stack + Algorithm-1 DSE: fidelity, sizing, Pareto consistency."""

import math

import numpy as np
import pytest
from hypothesis_compat import given, settings, st  # optional dep, skips cleanly

from repro.core import (ArchRequest, ResourceBudget, SLA, SchedulerKind,
                        SwitchArch, ForwardTableKind, VOQKind, analyze, bind,
                        compressed_protocol, depth_for_drop_rate,
                        ethernet_ipv4_udp, pareto_front, is_dominated)
from repro.sim import (ALVEO_U45N, annotate, estimate_quick, optimize_switch,
                       run_netsim, run_surrogate, synthesize)
from repro.traces import hft, underwater, uniform


def _arch(**kw):
    base = dict(n_ports=8, bus_bits=256, fwd=ForwardTableKind.FULL_LOOKUP,
                voq=VOQKind.NXN, sched=SchedulerKind.RR, voq_depth=64, addr_bits=4)
    base.update(kw)
    return SwitchArch(**base)


# ------------------------------------------------------------------ features

def test_features_burstiness_orders_traces():
    f_hft = analyze(hft(seed=0))
    f_uni = analyze(uniform(seed=0))
    assert f_hft.i_burst > f_uni.i_burst
    assert f_hft.s_min == 24


# ------------------------------------------------------------------ resources

def test_resource_model_matches_table1_within_tolerance():
    from repro.sim.resources import TABLE1_SPAC_ROWS
    eth = bind(ethernet_ipv4_udp(), flit_bits=512)
    cmp16 = bind(compressed_protocol(), flit_bits=256)
    for (arch, hdr), lut, ff, bram, fmax, lat in TABLE1_SPAC_ROWS:
        r = synthesize(arch, eth if hdr > 100 else cmp16)
        assert abs(r.luts / 1e3 / lut - 1) < 0.25
        assert abs(r.brams / bram - 1) < 0.25
        assert abs(r.fmax_mhz / fmax - 1) < 0.10
        assert abs(r.latency_ns / lat - 1) < 0.20


def test_quick_estimate_close_to_synthesize():
    bound = bind(compressed_protocol(), flit_bits=256)
    a = _arch(sched=SchedulerKind.ISLIP)
    q, s = estimate_quick(a, bound), synthesize(a, bound)
    assert abs(q.luts / s.luts - 1) < 0.25
    assert abs(q.fmax_mhz / s.fmax_mhz - 1) < 0.15


# ------------------------------------------------------------------ surrogate

def test_surrogate_latency_increases_with_load():
    bound = bind(compressed_protocol(addr_bits=4), flit_bits=256)
    a = _arch()
    lo = run_surrogate(a, bound, uniform(seed=1, load=0.1))
    hi = run_surrogate(a, bound, uniform(seed=1, load=0.9))
    assert hi.p(99) > lo.p(99)
    assert hi.q_occupancy.max() >= lo.q_occupancy.max()


def test_surrogate_vs_cycle_sim_fidelity():
    """Fig.6-style: back-annotated surrogate p50 within 2x of the cycle sim."""
    from repro.switch import simulate
    bound = bind(compressed_protocol(addr_bits=4), flit_bits=256)
    a = _arch(n_ports=4, sched=SchedulerKind.ISLIP)
    tr = uniform(seed=2, n_ports=4, duration_s=60e-6, load=0.4, payload=256)
    hw = annotate(a, bound, source="cycle_sim")
    sur = run_surrogate(a, bound, tr, hw=hw)
    cyc = simulate(a, bound, tr, fclk_hz=hw.fclk_hz)
    assert 0.5 < sur.p(50) / cyc.p(50) < 2.0


def test_netsim_retransmission_recovers_drops():
    from repro.core import Field
    from repro.sim import NetSimConfig
    proto = compressed_protocol(addr_bits=4, seq_bits=8)
    bound = bind(proto, flit_bits=256)
    tr = uniform(seed=0, n_ports=4, duration_s=30e-6, load=0.95, payload=512)
    a = _arch(n_ports=4, voq_depth=2)
    base = run_netsim(a, bound, tr, back_annotation=False)
    retx = run_netsim(a, bound, tr, back_annotation=False,
                      cfg=NetSimConfig(retransmit=True, rto_s=5e-6))
    assert retx.drop_rate <= base.drop_rate


# ------------------------------------------------------------------ DSE

@given(st.lists(st.integers(0, 200), min_size=10, max_size=300),
       st.floats(1e-4, 0.2))
@settings(max_examples=40, deadline=None)
def test_depth_for_drop_rate_property(occ, eps):
    d = depth_for_drop_rate(np.asarray(occ, float), eps)
    frac_over = float(np.mean(np.asarray(occ) > d))
    assert frac_over <= eps + 1e-9
    assert d >= 1


def test_pareto_front_non_dominated():
    pts = [(1, 5), (2, 2), (5, 1), (3, 3), (6, 6)]
    front = pareto_front(pts, key=lambda p: p)
    assert (3, 3) not in front and (6, 6) not in front
    for a in front:
        assert not any(is_dominated(a, b) for b in front if b != a)


def test_dse_hft_selects_low_latency_architecture():
    """Table-II HFT row: FullLookup + RR at a narrow bus."""
    tr = hft(seed=0)
    bound = bind(compressed_protocol(addr_bits=4, length_bits=6), flit_bits=256)
    res, prob = optimize_switch(
        ArchRequest(n_ports=8, addr_bits=4), bound, tr,
        sla=SLA(p99_latency_ns=5000, drop_rate=1e-3), back_annotation=False)
    assert res.best is not None
    assert res.best.fwd is ForwardTableKind.FULL_LOOKUP
    assert res.best.sched is SchedulerKind.RR
    assert res.best_verify.drop_rate <= 1.5e-3


def test_dse_stage1_prunes_infeasible_timing():
    """Tiny packets on a fast link must eliminate slow/wide configs."""
    tr = hft(seed=0, link_gbps=100.0)     # 24B @ 100G: arrival every ~5ns
    bound = bind(compressed_protocol(addr_bits=4, length_bits=6), flit_bits=256)
    res, prob = optimize_switch(ArchRequest(n_ports=8, addr_bits=4), bound, tr,
                                back_annotation=False)
    assert res.logs[0].survived < res.logs[0].considered


def test_dse_result_on_own_pareto_front():
    tr = underwater(seed=0)
    bound = bind(compressed_protocol(addr_bits=4, length_bits=6), flit_bits=256)
    res, prob = optimize_switch(ArchRequest(n_ports=8, addr_bits=4), bound, tr,
                                sla=SLA(p99_latency_ns=1e5, drop_rate=1e-3),
                                back_annotation=False)
    assert res.best is not None
    objs = [prob.objectives(a, v) for a, v, _, ok in res.evaluated if ok]
    best_obj = prob.objectives(res.best, res.best_verify)
    assert not any(is_dominated(best_obj, o) for o in objs if o != best_obj)
