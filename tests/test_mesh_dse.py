"""Multi-device determinism matrix for the sharded DSE hot path.

The sharding contract (see ``docs/architecture.md`` "Mesh sharding &
elastic resume") is not "close enough" — it is *bit-identical*: the stage-2
and stage-4 scans are rowwise over the candidate axis, so any shard_map
partition of the batch must reproduce the serial recurrence exactly, and
NSGA-II state never touches the mesh, so a checkpoint written on N devices
must resume on M with the same fronts, hv history and RNG stream.  These
tests force 8 simulated host devices in subprocesses (the main session keeps
its single real device) and assert equality with ``assert_array_equal``,
never ``allclose``.
"""

import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

sys.path.insert(0, os.path.join(REPO, "src"))


def _run(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", code], env=env, cwd=REPO,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def _forced_devices_available() -> bool:
    """Skip-clean guard: some backends ignore the host-device-count flag."""
    try:
        out = _run("import jax; print(jax.device_count())")
    except AssertionError:
        return False
    return out.strip().endswith("8")


_HAVE_8 = None


def _require_forced_devices():
    global _HAVE_8
    if _HAVE_8 is None:
        _HAVE_8 = _forced_devices_available()
    if not _HAVE_8:
        pytest.skip("cannot force 8 simulated host devices on this backend")


# --------------------------------------------------------------------------
# (a) + (d): engine-level bit-identity, incl. non-divisible batch sizes
# --------------------------------------------------------------------------

def test_stage2_stage4_bit_identical_across_device_counts():
    """1-vs-2-vs-8-device (and 2x2-mesh) batch results are bitwise equal:
    latency arrays under the scoped f64 scan, exact drop counts, occupancy,
    departure times — at B=21 (not divisible by 2 or 8, so padding is
    exercised on every mesh)."""
    _require_forced_devices()
    _run("""
import numpy as np
from repro.core import ArchRequest, bind, compressed_protocol, enumerate_candidates
from repro.launch.mesh import MeshSpec
from repro.sim import run_surrogate_batched
from repro.sim.batched_netsim import run_netsim_batched
from repro.traces import hft

BOUND = bind(compressed_protocol(addr_bits=4, length_bits=6), flit_bits=256)
tr = hft(seed=0)
cands = enumerate_candidates(ArchRequest(n_ports=8, addr_bits=4))[:21]
meshes = [None, MeshSpec(devices=2), MeshSpec(devices=8),
          MeshSpec(devices=2, scenario_axis=2), MeshSpec(devices=4, scenario_axis=2)]

base2 = run_surrogate_batched(cands, BOUND, tr, back_annotation=False)
base4 = run_netsim_batched(cands, BOUND, tr, back_annotation=False)
for mesh in meshes[1:]:
    r2 = run_surrogate_batched(cands, BOUND, tr, back_annotation=False, mesh=mesh)
    np.testing.assert_array_equal(base2.latency_ns, r2.latency_ns)
    np.testing.assert_array_equal(base2.q_occupancy, r2.q_occupancy)   # drops exact
    np.testing.assert_array_equal(base2.dep_end_s, r2.dep_end_s)
    np.testing.assert_array_equal(base2.throughput_gbps, r2.throughput_gbps)
    np.testing.assert_array_equal(base2.line_rate_feasible, r2.line_rate_feasible)
    r4 = run_netsim_batched(cands, BOUND, tr, back_annotation=False, mesh=mesh)
    for vb, vr in zip(base4, r4):
        assert vb.p99_latency_ns == vr.p99_latency_ns
        assert vb.drop_rate == vr.drop_rate
        assert vb.throughput_gbps == vr.throughput_gbps
        np.testing.assert_array_equal(vb.meta["latency_ns"], vr.meta["latency_ns"])
    print("mesh", mesh, "OK")

# padding edges: B=1 and B=axis-1 on the widest mesh
for B in (1, 7):
    m8 = MeshSpec(devices=8)
    r2 = run_surrogate_batched(cands[:B], BOUND, tr, back_annotation=False, mesh=m8)
    np.testing.assert_array_equal(base2.latency_ns[:B], r2.latency_ns)
    np.testing.assert_array_equal(base2.q_occupancy[:B], r2.q_occupancy)
    r4 = run_netsim_batched(cands[:B], BOUND, tr, back_annotation=False, mesh=m8)
    assert len(r4) == B
    for vb, vr in zip(base4[:B], r4):
        assert vb.drop_rate == vr.drop_rate
        np.testing.assert_array_equal(vb.meta["latency_ns"], vr.meta["latency_ns"])
    print("padding B =", B, "OK")
""")


# --------------------------------------------------------------------------
# (b): NSGA-II same-seed fronts identical across device counts
# --------------------------------------------------------------------------

def test_nsga2_front_identical_across_device_counts():
    """The full scenario report — Pareto front membership, hv history notes,
    stage logs, every latency number — is identical whether the batched
    stages ran serial, on 2 or on 8 devices."""
    _require_forced_devices()
    _run("""
import json
from repro.api import registry, run_scenario
from repro.api.scenario import MeshSpec, SearchSpec
from tests.test_golden import diff_reports

scn = registry["hft"].override(
    back_annotation=False, search=SearchSpec(population=16, generations=3, seed=7))
base = json.loads(json.dumps(run_scenario(scn).to_dict()))
for d in (2, 8):
    got = json.loads(json.dumps(
        run_scenario(scn, mesh=MeshSpec(devices=d)).to_dict()))
    errs = diff_reports(got, base)
    assert not errs, (d, errs[:10])
    print("devices", d, "report identical OK")
""")


# --------------------------------------------------------------------------
# (c): remesh-proof checkpoints — N devices -> M devices, bit-identical
# --------------------------------------------------------------------------

def test_checkpoint_remesh_resume_bit_identical():
    """A search checkpointed mid-run on N devices and resumed on M != N
    matches the uninterrupted serial run bit-for-bit: final front, hv
    history, and the engine's *next* RNG draws."""
    _require_forced_devices()
    _run("""
import shutil
import numpy as np
from repro.api import registry
from repro.api.runner import build_problem
from repro.api.scenario import MeshSpec, SearchSpec
from repro.core.search import load_search_state, run_search

scn = registry["hft"].override(
    back_annotation=False, search=SearchSpec(population=16, generations=4, seed=7))

def search(mesh, ckpt=None, resume=False, cut=None):
    problem, sla, _ = build_problem(scn, mesh=mesh)
    return run_search(problem, scn.search, sla, delta=scn.fidelity.delta,
                      checkpoint_dir=ckpt, resume=resume,
                      max_generations_this_run=cut)

def front(outcome):
    return sorted(c.short() for c, _ in outcome.valid)

ref_ckpt = "/tmp/mesh_dse_ref"
shutil.rmtree(ref_ckpt, ignore_errors=True)
ref = search(None, ckpt=ref_ckpt)           # uninterrupted serial, checkpointed

for n, m in ((8, 2), (2, 8)):
    ckpt = f"/tmp/mesh_dse_{n}to{m}"
    shutil.rmtree(ckpt, ignore_errors=True)
    search(MeshSpec(devices=n), ckpt=ckpt, cut=2)          # killed mid-run on N
    out = search(MeshSpec(devices=m), ckpt=ckpt, resume=True)  # resumed on M
    assert front(out) == front(ref), (n, m)
    # hv history and next RNG draws from the final checkpointed state
    prob, _, _ = build_problem(scn)
    eng_a = load_search_state(ckpt, prob.space(), scn.search)
    eng_b = load_search_state(ref_ckpt, prob.space(), scn.search)
    assert eng_a.hv_history == eng_b.hv_history, (n, m)
    np.testing.assert_array_equal(eng_a.rng.random(16), eng_b.rng.random(16))
    print(f"{n}->{m} resume bit-identical OK")
""")


# --------------------------------------------------------------------------
# satellite fix: loud failures instead of silently-wrong shardings
# --------------------------------------------------------------------------

def test_mesh_validation_names_both_numbers():
    import jax

    from repro.launch.mesh import MeshSpec, compat_make_mesh

    with pytest.raises(ValueError, match=r"extent 0"):
        compat_make_mesh((0, 1), ("scenario", "cand"))
    avail = jax.device_count()
    with pytest.raises(ValueError) as ei:
        compat_make_mesh((avail + 1, 1), ("scenario", "cand"))
    assert str(avail + 1) in str(ei.value) and str(avail) in str(ei.value)
    with pytest.raises(ValueError, match=r"size 0"):
        MeshSpec(devices=0)
    with pytest.raises(ValueError, match=r"size 0"):
        MeshSpec(scenario_axis=0)
    with pytest.raises(ValueError) as ei:
        MeshSpec(devices=avail + 3).build()
    assert str(avail + 3) in str(ei.value) and str(avail) in str(ei.value)


def test_remesh_rejects_oversized_target(monkeypatch, mesh11):
    import jax

    from repro.runtime.elastic import remesh

    monkeypatch.setattr(jax, "device_count", lambda: 0)
    with pytest.raises(ValueError) as ei:
        remesh({"x": 1.0}, {"x": jax.sharding.PartitionSpec()}, mesh11)
    msg = str(ei.value)
    assert "needs 1" in msg and "only 0" in msg
