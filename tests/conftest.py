import os
import sys

# tests run on the single real CPU device (the dry-run sets its own flags in a
# separate process); keep compilation deterministic and quiet.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

from repro.launch.mesh import compat_make_mesh  # noqa: E402

import pytest  # noqa: E402


def pytest_addoption(parser):
    parser.addoption(
        "--update-golden", action="store_true", default=False,
        help="regenerate tests/golden/*.json from the current pipeline "
             "instead of diffing against it (see tests/test_golden.py)")


@pytest.fixture(scope="session")
def mesh11():
    return compat_make_mesh((1, 1), ("data", "model"))
