"""Cycle-level switch: matching validity, table learning, VOQ conservation,
end-to-end delivery."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st  # optional dep, skips cleanly

from repro.core import (SchedulerKind, SwitchArch, ForwardTableKind, VOQKind,
                        bind, compressed_protocol)
from repro.switch import (init_sched, init_table, learn, lookup, schedule, simulate)
from repro.switch.forward_table import BROADCAST
from repro.traces import uniform
from repro.traces.base import Trace


def _arch(sched=SchedulerKind.ISLIP, fwd=ForwardTableKind.FULL_LOOKUP,
          voq=VOQKind.NXN, n=4, depth=32):
    return SwitchArch(n_ports=n, bus_bits=256, fwd=fwd, voq=voq, sched=sched,
                      voq_depth=depth, addr_bits=4)


@given(st.integers(0, 2**16 - 1), st.sampled_from(list(SchedulerKind)),
       st.integers(0, 3))
@settings(max_examples=60, deadline=None)
def test_matching_is_valid(occ_bits, sched, ptr_seed):
    """Property: the schedule is a matching (≤1 per input, ≤1 per output) and
    only matches requesting pairs."""
    n = 4
    arch = _arch(sched=sched, n=n)
    occ = jnp.asarray([(occ_bits >> i) & 1 for i in range(n * n)],
                      jnp.int32).reshape(n, n) * (1 + ptr_seed)
    st_ = init_sched(arch)
    st_ = st_._replace(grant_ptr=jnp.full((n,), ptr_seed, jnp.int32),
                       accept_ptr=jnp.full((n,), (ptr_seed + 1) % n, jnp.int32))
    busy = jnp.zeros((n,), bool)
    match, _ = schedule(arch, st_, occ, busy, busy)
    m = np.asarray(match)
    req = np.asarray(occ) > 0
    assert (m.sum(0) <= 1).all() and (m.sum(1) <= 1).all()
    assert not (m & ~req).any()
    # work-conserving-ish: if any request exists, at least one match is made
    if req.any():
        assert m.any()


def test_full_lookup_learn_then_hit():
    arch = _arch()
    state = init_table(arch)
    src = jnp.asarray([3, 1, 0, 2], jnp.uint32)
    ports = jnp.arange(4, dtype=jnp.int32)
    valid = jnp.ones(4, bool)
    state = learn(arch, state, src, ports, valid)
    out = lookup(arch, state, jnp.asarray([3, 1, 9], jnp.uint32),
                 jnp.ones(3, bool))
    assert out[0] == 0 and out[1] == 1          # learned
    assert out[2] == BROADCAST                  # miss -> flood


def test_multibank_hash_learn_then_hit():
    arch = _arch(fwd=ForwardTableKind.MULTIBANK_HASH)
    state = init_table(arch)
    keys = jnp.asarray([11, 57, 123, 9000], jnp.uint32)
    state = learn(arch, state, keys, jnp.arange(4, dtype=jnp.int32), jnp.ones(4, bool))
    out = lookup(arch, state, keys, jnp.ones(4, bool))
    np.testing.assert_array_equal(np.asarray(out), [0, 1, 2, 3])


@pytest.mark.parametrize("voq", [VOQKind.NXN, VOQKind.SHARED])
@pytest.mark.parametrize("sched", list(SchedulerKind))
def test_light_load_delivers_everything(voq, sched):
    tr = uniform(seed=3, n_ports=4, duration_s=60e-6, load=0.2, payload=64)
    arch = _arch(sched=sched, voq=voq, depth=64)
    bound = bind(compressed_protocol(addr_bits=4), flit_bits=256)
    res = simulate(arch, bound, tr, fclk_hz=200e6)
    assert res.drops == 0
    # unicast after learning + broadcast copies before: delivered >= offered
    assert res.delivered_copies >= res.offered * 0.95
    assert np.isfinite(res.latency_ns).all()
    assert res.p(50) < 2000  # ns


def test_overload_drops_with_tiny_buffers():
    # 3-into-1 incast: fan-in exceeds the output drain rate; depth-4 queues drop
    n = 4
    t = np.repeat(np.arange(300) * 20e-9, n - 1)
    src = np.tile(np.arange(1, n), 300)
    dst = np.zeros((n - 1) * 300, np.int64)
    t = np.concatenate([[0.0], t + 40e-9])
    src = np.concatenate([[0], src])
    dst = np.concatenate([[1], dst])
    tr = Trace("incast", t, src, dst, np.full(1 + (n - 1) * 300, 64), n)
    arch = _arch(depth=4)
    bound = bind(compressed_protocol(addr_bits=4), flit_bits=256)
    res = simulate(arch, bound, tr, fclk_hz=200e6)
    assert res.drops > 0


def test_incast_queues_grow():
    n = 4
    t = np.repeat(np.arange(200) * 10e-9, n - 1)
    src = np.tile(np.arange(1, n), 200)
    dst = np.zeros((n - 1) * 200, np.int64)
    # host 0 announces itself first (else unknown-unicast floods all ports)
    t = np.concatenate([[0.0], t + 20e-9])
    src = np.concatenate([[0], src])
    dst = np.concatenate([[1], dst])
    tr = Trace("incast", t, src, dst, np.full(1 + (n - 1) * 200, 64), n)
    arch = _arch(depth=256)
    bound = bind(compressed_protocol(addr_bits=4), flit_bits=256)
    res = simulate(arch, bound, tr, fclk_hz=200e6)
    assert res.occ_max.max() > 10                   # queue to port 0 backs up
    assert res.occ_max[:, 0].sum() >= res.occ_max[:, 1:].sum()


@given(st.integers(0, 10), st.integers(1, 40), st.sampled_from(list(VOQKind)))
@settings(max_examples=20, deadline=None)
def test_voq_conservation_property(seed, n_pkts, voq):
    """Enqueued copies == delivered + dropped + still-queued (no loss/dup)."""
    rng = np.random.default_rng(seed)
    n = 4
    t = np.sort(rng.uniform(0, 4e-6, n_pkts))
    src = rng.integers(0, n, n_pkts)
    dst = (src + 1 + rng.integers(0, n - 1, n_pkts)) % n
    # everyone announces first so all traffic is unicast
    t = np.concatenate([np.arange(n) * 10e-9, t + 1e-6])
    src = np.concatenate([np.arange(n), src])
    dst = np.concatenate([(np.arange(n) + 1) % n, dst])
    tr = Trace("cons", t, src, dst, np.full(t.size, 32), n)
    arch = _arch(voq=voq, depth=8)
    bound = bind(compressed_protocol(addr_bits=4), flit_bits=256)
    res = simulate(arch, bound, tr, fclk_hz=200e6)
    assert res.delivered_copies + res.drops >= res.offered  # broadcast >= 1 copy
    assert res.drops <= res.offered * (n - 1)
    assert (res.occ_max >= 0).all()
