"""Golden-report regression harness: seeded ``ScenarioReport.to_dict()``
snapshots under ``tests/golden/`` are re-run and diffed on every suite run.

The pipeline is seeded end to end (trace generators, the NSGA-II engine, the
comm router), so reports are reproducible — any structural drift (Pareto
front membership, stage survivor counts, drop counts, resource totals,
search metadata) fails here with a path-by-path diff.  Comparison policy:

  * timing fields (``*_time_s``) are volatile and skipped,
  * numbers under latency / throughput / hypervolume keys compare with
    ``rtol=1e-6`` (float-op ordering may differ across BLAS/libm builds),
  * everything else — drops, resources, candidate shorts, stage logs —
    compares exactly.

Regenerate after an *intentional* behaviour change with:

    PYTHONPATH=src python -m pytest tests/test_golden.py --update-golden
"""

import json
import math
import os
import subprocess
import sys

import pytest

from repro.api import registry, run_scenario
from repro.api.scenario import CommModelSpec, Fidelity, Scenario, SearchSpec
from repro.core.dse import ResourceBudget, SLA

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "golden")

#: substrings of the *nearest dict key* that switch a float to rtol compare
RTOL_KEYS = ("latency", "throughput")
RTOL = 1e-6
#: report keys that are timing noise, skipped entirely
VOLATILE = ("wall_time_s", "stage2_time_s", "stage4_time_s")


def _comm_small() -> Scenario:
    """A deliberately small MoE dispatch fabric: the comm-domain pipeline
    (router trace -> analytic surrogate -> capacity sizing -> real fabric
    verify) at a size the tier-1 suite can afford."""
    return Scenario(
        name="comm_small",
        domain="comm",
        comm=CommModelSpec(d_model=128, d_ff=256, n_heads=4, n_kv_heads=2,
                           vocab=256, moe_experts=8, moe_topk=2, batch=2,
                           seq=64, model_tp=4),
        sla=SLA(p99_latency_ns=math.inf, drop_rate=2e-2),
        budget=ResourceBudget({"bytes_per_device": 4e9}),
        fidelity=Fidelity(back_annotation=False, top_k=2),
        notes="small MoE dispatch fabric for the golden-report harness")


#: name -> scenario builder; every entry is fully seeded
SCENARIOS = {
    "hft": lambda: registry["hft"].override(back_annotation=False),
    "datacenter": lambda: registry["datacenter"].override(back_annotation=False),
    "comm_small": _comm_small,
    "hft_nsga2": lambda: registry["hft"].override(
        back_annotation=False,
        search=SearchSpec(population=16, generations=4, seed=7)),
    # protocol co-design: the winning layout (name, per-field widths) is part
    # of the snapshot, so protocol genes are locked down bit-for-bit
    "hft_codesign": lambda: registry["hft"].override(
        back_annotation=False, co_design=True,
        search=SearchSpec(population=16, generations=4, seed=7)),
    # multi-hop fabric: the snapshot carries end-to-end p50/p99 and per-tier
    # drop counts the single-switch path cannot express
    "fattree_dc": lambda: registry["fattree_dc"].override(
        back_annotation=False),
}


# --------------------------------------------------------------------------
# structural diff
# --------------------------------------------------------------------------

def _is_rtol_key(key: str) -> bool:
    return any(tag in key for tag in RTOL_KEYS)


def _num_close(a, b) -> bool:
    if math.isinf(a) or math.isinf(b):
        return a == b
    return abs(a - b) <= RTOL * max(abs(a), abs(b), 1e-300)


def diff_reports(got, want, *, path="report", key="", errors=None):
    """Path-by-path diff of two report dicts; returns a list of mismatches."""
    errors = [] if errors is None else errors
    if isinstance(want, dict):
        if not isinstance(got, dict):
            errors.append(f"{path}: expected dict, got {type(got).__name__}")
            return errors
        missing = sorted(set(want) - set(got))
        extra = sorted(set(got) - set(want))
        if missing:
            errors.append(f"{path}: missing keys {missing}")
        if extra:
            errors.append(f"{path}: unexpected keys {extra}")
        for k in sorted(set(want) & set(got)):
            if k in VOLATILE:
                continue
            diff_reports(got[k], want[k], path=f"{path}.{k}", key=k,
                         errors=errors)
    elif isinstance(want, list):
        if not isinstance(got, list) or len(got) != len(want):
            errors.append(f"{path}: length {len(got) if isinstance(got, list) else type(got).__name__} != {len(want)}")
            return errors
        for i, (g, w) in enumerate(zip(got, want)):
            diff_reports(g, w, path=f"{path}[{i}]", key=key, errors=errors)
    elif isinstance(want, str) and want.startswith("hypervolume="):
        # search stage note: embedded float compares with rtol
        if not (isinstance(got, str) and got.startswith("hypervolume=")):
            errors.append(f"{path}: {got!r} != {want!r}")
        elif not _num_close(float(got.split("=", 1)[1]),
                            float(want.split("=", 1)[1])):
            errors.append(f"{path}: {got!r} !~ {want!r}")
    elif isinstance(want, float) and not isinstance(want, bool) and _is_rtol_key(key):
        if not (isinstance(got, (int, float)) and _num_close(float(got), want)):
            errors.append(f"{path}: {got!r} !~ {want!r} (rtol={RTOL})")
    else:
        # drops, resources, counts, candidate shorts: exact
        if got != want:
            errors.append(f"{path}: {got!r} != {want!r}")
    return errors


# --------------------------------------------------------------------------
# the harness
# --------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(SCENARIOS))
def test_golden_report(name, request):
    update = request.config.getoption("--update-golden")
    path = os.path.join(GOLDEN_DIR, f"{name}.json")
    report = run_scenario(SCENARIOS[name]())
    # round-trip through JSON so the diff sees exactly what's on disk
    got = json.loads(json.dumps(report.to_dict()))
    if update:
        os.makedirs(GOLDEN_DIR, exist_ok=True)
        with open(path, "w") as f:
            json.dump(got, f, indent=2, sort_keys=True)
            f.write("\n")
        pytest.skip(f"regenerated {path}")
    if not os.path.exists(path):
        pytest.fail(f"no golden report at {path}; generate with "
                    "`pytest tests/test_golden.py --update-golden`")
    with open(path) as f:
        want = json.load(f)
    errors = diff_reports(got, want)
    assert not errors, (
        f"{name}: report drifted from {path} "
        f"({len(errors)} mismatch(es)):\n" + "\n".join(errors))


# --------------------------------------------------------------------------
# mesh invariance: the same snapshot must hold on a sharded device mesh
# --------------------------------------------------------------------------

def test_golden_hft_nsga2_mesh_invariant():
    """Re-run ``hft_nsga2`` under 2 simulated host devices and diff against
    the *single-device* golden snapshot.  The goldens are mesh-invariant by
    contract — sharding the batched stages may never shift a front, a drop
    count, or a latency quantile — so no regeneration is allowed here: a
    mismatch is a sharding bug, not snapshot drift."""
    path = os.path.join(GOLDEN_DIR, "hft_nsga2.json")
    if not os.path.exists(path):
        pytest.fail(f"no golden report at {path}; generate with "
                    "`pytest tests/test_golden.py --update-golden` "
                    "(on a single device)")
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    env["PYTHONPATH"] = os.path.join(repo, "src")
    out = subprocess.run([sys.executable, "-c", """
import json
from repro.api import registry, run_scenario
from repro.api.scenario import MeshSpec, SearchSpec
scenario = registry["hft"].override(
    back_annotation=False,
    search=SearchSpec(population=16, generations=4, seed=7))
report = run_scenario(scenario, mesh=MeshSpec(devices=2))
print(json.dumps(report.to_dict()))
"""], env=env, cwd=repo, capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-4000:]
    got = json.loads(out.stdout.strip().splitlines()[-1])
    with open(path) as f:
        want = json.load(f)
    errors = diff_reports(got, want)
    assert not errors, (
        "hft_nsga2 under a 2-device mesh drifted from the single-device "
        f"golden ({len(errors)} mismatch(es)):\n" + "\n".join(errors))


@pytest.mark.parametrize("devices", [2, 8])
def test_golden_fattree_mesh_invariant(devices):
    """Hop-composed fabric evaluation must be bit-identical whether the
    batched stages run on 1, 2, or 8 forced host devices: per-hop departures
    feed the next hop, so any sharding drift would compound.  Diff against
    the single-device golden — no regeneration allowed."""
    path = os.path.join(GOLDEN_DIR, "fattree_dc.json")
    if not os.path.exists(path):
        pytest.fail(f"no golden report at {path}; generate with "
                    "`pytest tests/test_golden.py --update-golden` "
                    "(on a single device)")
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(repo, "src")
    out = subprocess.run([sys.executable, "-c", f"""
import json
from repro.api import registry, run_scenario
from repro.api.scenario import MeshSpec
scenario = registry["fattree_dc"].override(back_annotation=False)
report = run_scenario(scenario, mesh=MeshSpec(devices={devices}))
print(json.dumps(report.to_dict()))
"""], env=env, cwd=repo, capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-4000:]
    got = json.loads(out.stdout.strip().splitlines()[-1])
    with open(path) as f:
        want = json.load(f)
    errors = diff_reports(got, want)
    assert not errors, (
        f"fattree_dc under a {devices}-device mesh drifted from the "
        f"single-device golden ({len(errors)} mismatch(es)):\n"
        + "\n".join(errors))


# --------------------------------------------------------------------------
# the harness's own teeth
# --------------------------------------------------------------------------

def test_diff_catches_exact_drift():
    want = {"best_verify": {"drop_rate": 0.0, "p99_latency_ns": 100.0},
            "resources": {"brams": 16.0}}
    got = json.loads(json.dumps(want))
    assert diff_reports(got, want) == []
    got["best_verify"]["drop_rate"] = 1e-9          # drops compare exactly
    assert any("drop_rate" in e for e in diff_reports(got, want))
    got = json.loads(json.dumps(want))
    got["resources"]["brams"] = 17.0                # resources too
    assert any("brams" in e for e in diff_reports(got, want))


def test_diff_latency_rtol_and_structure():
    want = {"best_verify": {"p99_latency_ns": 100.0}, "pareto": [1, 2]}
    got = {"best_verify": {"p99_latency_ns": 100.0 * (1 + 1e-9)},
           "pareto": [1, 2]}
    assert diff_reports(got, want) == []            # inside rtol
    got["best_verify"]["p99_latency_ns"] = 101.0    # outside rtol
    assert any("p99_latency_ns" in e for e in diff_reports(got, want))
    assert any("length" in e
               for e in diff_reports({"best_verify": {}, "pareto": [1]}, want))
