"""Capture ingestion: readers, declarative stages, stressors, the CLI.

Contracts under test:

* CSV and synthetic-pcap captures round-trip to ``Trace.npz`` bit-exactly
  (times through the single integer-ns conversion, ids, payloads, metadata);
* stage composition is order-deterministic — application order is the tuple
  order, and a pipeline is pure data (``to_dict`` round-trips it);
* generative stressors are seed-reproducible: one ``(seed, stage index)``
  stream per stage, so the same pipeline replays bit-identically;
* ``spac ingest`` writes the .npz on good input and exits 2 on malformed
  input (unreadable file, bad rows, unknown stage, bad stage syntax);
* ``traces.merge`` validates mismatched ``link_gbps`` and overlapping port
  ids, naming both offending values.
"""

import numpy as np
import pytest

from repro.api.cli import main as cli_main
from repro.traces import Trace, merge
from repro.traces.ingest import (IngestError, Pipeline, Stage, ingest,
                                 read_csv, read_pcap, write_pcap)

CSV_HEADER = "time_s,src,dst,payload_bytes"


def _write(path, text):
    path.write_text(text)
    return str(path)


def _assert_traces_bit_equal(a: Trace, b: Trace):
    np.testing.assert_array_equal(a.time_s, b.time_s)
    np.testing.assert_array_equal(a.src, b.src)
    np.testing.assert_array_equal(a.dst, b.dst)
    np.testing.assert_array_equal(a.payload_bytes, b.payload_bytes)
    assert (a.name, a.n_ports, a.link_gbps) == (b.name, b.n_ports, b.link_gbps)


# --------------------------------------------------------------------------
# readers round-trip to .npz bit-exactly
# --------------------------------------------------------------------------

def test_csv_roundtrips_to_npz_bit_exactly(tmp_path):
    # header in shuffled order with an extra ignored column
    p = _write(tmp_path / "cap.csv",
               "dst,payload_bytes,flow_label,time_s,src\n"
               "1,64,a,0.0,0\n"
               "2,128,b,1e-6,1\n"
               "0,1500,c,2.5e-6,3\n")
    tr = read_csv(p, n_ports=4, link_gbps=25.0)
    assert tr.n_ports == 4 and tr.link_gbps == 25.0
    np.testing.assert_array_equal(tr.src, [0, 1, 3])
    out = tmp_path / "cap.npz"
    tr.save(out)
    _assert_traces_bit_equal(Trace.load(out), tr)


def test_csv_headerless_positional_matches_header(tmp_path):
    rows = "0.0,0,1,64\n1e-6,1,2,128\n"
    with_h = read_csv(_write(tmp_path / "a.csv", CSV_HEADER + "\n" + rows),
                      name="cap")
    without = read_csv(_write(tmp_path / "b.csv", rows), name="cap")
    _assert_traces_bit_equal(with_h, without)


def test_pcap_roundtrips_to_npz_bit_exactly(tmp_path):
    # ids above 255 exercise the 16-bit host-id convention; integer-ns
    # timestamps must survive the float conversion to the bit
    t_ns = [0, 1_000, 999_999_999, 1_000_000_001, 7_123_456_789]
    src = [0, 300, 2, 65535, 4]
    dst = [1, 2, 300, 4, 0]
    pay = [64, 1500, 9000, 46, 128]
    p = tmp_path / "cap.pcap"
    write_pcap(p, t_ns, src, dst, pay)
    tr = read_pcap(p)
    np.testing.assert_array_equal(tr.src, src)
    np.testing.assert_array_equal(tr.dst, dst)
    np.testing.assert_array_equal(tr.payload_bytes, pay)
    np.testing.assert_array_equal(
        tr.time_s, np.array([t * 1e-9 for t in t_ns]))
    out = tmp_path / "cap.npz"
    tr.save(out)
    _assert_traces_bit_equal(Trace.load(out), tr)
    # ingest() dispatches on the pcap magic even without the suffix
    _assert_traces_bit_equal(ingest(p, name="cap"), read_pcap(p, name="cap"))


def test_pcap_rejects_malformed_input(tmp_path):
    p = tmp_path / "x.pcap"
    p.write_bytes(b"\x0a\x0d\x0d\x0a" + b"\x00" * 20)      # pcapng magic
    with pytest.raises(IngestError, match="pcapng"):
        read_pcap(p)
    import struct
    p.write_bytes(struct.pack("<IHHiIII", 0xA1B23C4D, 2, 4, 0, 0, 65535, 101))
    with pytest.raises(IngestError, match="linktype"):     # not Ethernet
        read_pcap(p)
    write_pcap(p, [0], [0], [1], [64])
    p.write_bytes(p.read_bytes()[:-4])                     # truncated record
    with pytest.raises(IngestError, match="truncated"):
        read_pcap(p)


def test_reader_validates_port_range(tmp_path):
    p = _write(tmp_path / "cap.csv", CSV_HEADER + "\n0.0,0,9,64\n")
    with pytest.raises(IngestError, match="port id 9"):
        read_csv(p, n_ports=4)
    assert read_csv(p).n_ports == 10                       # inferred


# --------------------------------------------------------------------------
# stages and pipelines
# --------------------------------------------------------------------------

def _base_trace(m=200, n_ports=8, seed=3):
    rng = np.random.default_rng(seed)
    return Trace("base", np.sort(rng.uniform(0, 1e-4, m)),
                 rng.integers(n_ports, size=m).astype(np.int32),
                 rng.integers(n_ports, size=m).astype(np.int32),
                 rng.integers(64, 1500, size=m).astype(np.int64),
                 n_ports=n_ports)


def test_stage_composition_is_order_deterministic():
    tr = _base_trace()
    a = Pipeline(seed=0).then("rescale_time", factor=2.0) \
                        .then("clip", duration_s=1e-4).apply(tr)
    b = Pipeline(seed=0).then("clip", duration_s=1e-4) \
                        .then("rescale_time", factor=2.0).apply(tr)
    # rescale-then-clip keeps half the span; clip-then-rescale keeps it all
    assert len(a) < len(b)
    # and replaying either composition is bit-identical
    _assert_traces_bit_equal(
        a, Pipeline(seed=0).then("rescale_time", factor=2.0)
                           .then("clip", duration_s=1e-4).apply(tr))


def test_pipeline_is_serializable_data():
    pipe = (Pipeline(seed=9).then("filter", min_payload=100)
            .then("incast", dst=2, n_senders=3, n_packets=32)
            .then("diurnal", periods=3.0, depth=0.4))
    again = Pipeline.from_dict(pipe.to_dict())
    assert again == pipe
    _assert_traces_bit_equal(pipe.apply(_base_trace()),
                             again.apply(_base_trace()))
    with pytest.raises(IngestError, match="unknown stage"):
        Stage("nosuch")
    with pytest.raises(IngestError, match="filter"):
        Pipeline().then("filter", bogus_param=1).apply(_base_trace())


@pytest.mark.parametrize("kind,params,stochastic", [
    ("incast", {"dst": 0, "n_senders": 5, "n_packets": 64}, True),
    ("zipf_drift", {"alpha": 1.1, "frac": 0.6, "n_phases": 3}, True),
    # diurnal is a deterministic time warp — same output for every seed
    ("diurnal", {"periods": 2.0, "depth": 0.7}, False),
])
def test_stressors_are_seed_reproducible(kind, params, stochastic):
    tr = _base_trace()
    one = Pipeline(seed=42).then(kind, **params).apply(tr)
    two = Pipeline(seed=42).then(kind, **params).apply(tr)
    _assert_traces_bit_equal(one, two)
    other = Pipeline(seed=43).then(kind, **params).apply(tr)
    diverged = (len(other) != len(one)
                or not np.array_equal(other.time_s, one.time_s)
                or not np.array_equal(other.dst, one.dst))
    assert diverged == stochastic


def test_stage_parameter_validation():
    tr = _base_trace()
    with pytest.raises(IngestError, match="factor"):
        Pipeline().then("rescale_time", factor=0.0).apply(tr)
    with pytest.raises(IngestError, match="depth"):
        Pipeline().then("diurnal", depth=1.5).apply(tr)
    with pytest.raises(IngestError, match="remap_ports"):
        Pipeline().then("remap_ports").apply(tr)
    with pytest.raises(IngestError, match="no mapping"):
        Pipeline().then("remap_ports", mapping={0: 0}).apply(tr)


def test_remap_and_filter_shape_the_port_space():
    tr = _base_trace(n_ports=8)
    out = Pipeline().then("filter", ports=[0, 1, 2, 3]) \
                    .then("remap_ports", n_ports=2).apply(tr)
    assert out.n_ports == 2
    assert set(np.unique(out.src)) <= {0, 1}


# --------------------------------------------------------------------------
# the spac ingest CLI
# --------------------------------------------------------------------------

def test_cli_ingest_writes_npz(tmp_path, capsys):
    cap = _write(tmp_path / "cap.csv",
                 CSV_HEADER + "\n0.0,0,1,64\n1e-6,1,0,128\n")
    out = tmp_path / "cap.npz"
    rc = cli_main(["ingest", cap, "-o", str(out), "--seed", "7",
                   "--stage", "incast:dst=0,n_senders=1,n_packets=8",
                   "--stage", "clip:max_packets=6"])
    assert rc == 0 and out.exists()
    tr = Trace.load(out)
    assert len(tr) == 6 and tr.name == "cap"
    assert "wrote" in capsys.readouterr().out
    # default output path is the capture stem + .npz
    assert cli_main(["ingest", cap]) == 0
    assert (tmp_path / "cap.npz").exists()


@pytest.mark.parametrize("argv", [
    ["ingest", "{tmp}/missing.csv"],                       # unreadable file
    ["ingest", "{tmp}/bad.csv"],                           # bad row
    ["ingest", "{tmp}/cap.csv", "--stage", "nosuch:x=1"],  # unknown stage
    ["ingest", "{tmp}/cap.csv", "--stage", "clip:junk"],   # bad k=v syntax
    ["ingest", "{tmp}/cap.csv", "--stage",
     "filter:min_payload=9999"],                           # empty result
])
def test_cli_ingest_malformed_input_exits_2(tmp_path, argv, capsys):
    _write(tmp_path / "cap.csv", CSV_HEADER + "\n0.0,0,1,64\n")
    _write(tmp_path / "bad.csv", CSV_HEADER + "\n0.0,0,oops,64\n")
    rc = cli_main([a.format(tmp=tmp_path) for a in argv])
    assert rc == 2
    assert "spac ingest:" in capsys.readouterr().err


# --------------------------------------------------------------------------
# merge validation (traces/base.py)
# --------------------------------------------------------------------------

def _mini(name, port, gbps=100.0):
    return Trace(name, np.array([0.0]), np.array([port], np.int32),
                 np.array([port], np.int32), np.array([64], np.int64),
                 n_ports=port + 1, link_gbps=gbps)


def test_merge_rejects_link_gbps_mismatch():
    with pytest.raises(ValueError) as e:
        merge("m", [_mini("a", 0, gbps=100.0), _mini("b", 1, gbps=25.0)],
              n_ports=2, link_gbps=100.0)
    assert "100" in str(e.value) and "25" in str(e.value) and "'b'" in str(e.value)


def test_merge_rejects_overlapping_ports():
    with pytest.raises(ValueError) as e:
        merge("m", [_mini("a", 1), _mini("b", 1)], n_ports=4)
    assert "port id 1" in str(e.value)
    assert "'a'" in str(e.value) and "'b'" in str(e.value)


def test_merge_rejects_out_of_range_ports():
    with pytest.raises(ValueError, match="n_ports=1"):
        merge("m", [_mini("a", 3)], n_ports=1)
    # disjoint, in-range sub-traces still merge
    out = merge("m", [_mini("a", 0), _mini("b", 1)], n_ports=2)
    assert len(out) == 2 and out.n_ports == 2
