"""Quickstart: the two-stage SPAC workflow as one declarative Scenario.

Stage 1 — define a custom protocol in the DSL and semantically bind it.
Stage 2 — wrap protocol + trace + SLA in a ``Scenario`` (every architecture
policy on AUTO) and run it; the DSE returns the Pareto-optimal switch,
verified in the hardware-aware simulator.  The same spec serializes to JSON,
so the experiment is reproducible from a config file (or the CLI:
``spac run hft --sla-p99-ns 5000``).

    pip install -e .   # once
    python examples/quickstart.py
"""

from repro.api import ProtocolSpec, Scenario, TraceSpec, run_scenario
from repro.api.scenario import Fidelity
from repro.core import ArchRequest, SLA, analyze, ethernet_ipv4_udp
from repro.api.runner import build_bound
from repro.sim import synthesize


def main():
    # ---- the whole experiment, declaratively (a JSON-serializable spec)
    scenario = Scenario(
        name="hft_quickstart",
        protocol=ProtocolSpec(
            builder="compressed_protocol",
            params={"name": "hft_wire", "addr_bits": 4, "qos_bits": 2,
                    "length_bits": 6}),                   # 2-byte header
        flit_bits=256,
        trace=TraceSpec(generator="hft", params={"seed": 0}),
        arch=ArchRequest(n_ports=8, addr_bits=4),         # every policy AUTO
        sla=SLA(p99_latency_ns=5_000, drop_rate=1e-3),
        fidelity=Fidelity(back_annotation=True),
    )
    print("scenario spec (reproducible config):")
    print(scenario.to_json())

    # ---- protocol definition + semantic binding (single source of truth)
    bound = build_bound(scenario)
    print()
    print(bound.describe())
    print(f"vs Ethernet/IP/UDP: {ethernet_ipv4_udp().header_bytes} B of header\n")

    # ---- trace-aware DSE (Algorithm 1, batched stage-2 fan-out)
    print("trace:", analyze(scenario.trace.build()).describe())
    report = run_scenario(scenario, verbose=True)
    print()
    print(report.summary())

    best = report.best
    rep = synthesize(best, bound)
    print(f"\nselected micro-architecture : {best.short()}")
    print(f"resources                   : {rep.luts/1e3:.1f}k LUT, "
          f"{rep.brams:.0f} BRAM @ {rep.fmax_mhz:.0f} MHz")
    print(f"verified                    : p99 {report.best_verify.p99_latency_ns:.0f} ns, "
          f"drops {report.best_verify.drop_rate:.2e}")


if __name__ == "__main__":
    main()
