"""Quickstart: the two-stage SPAC workflow in ~40 lines.

Stage 1 — define a custom protocol in the DSL and semantically bind it.
Stage 2 — hand the DSE a traffic trace with every policy on AUTO; it returns
the Pareto-optimal switch, verified in the hardware-aware simulator.

    PYTHONPATH=src python examples/quickstart.py
"""

import sys

sys.path.insert(0, "src")

from repro.core import (ArchRequest, SLA, analyze, bind, compressed_protocol,
                        ethernet_ipv4_udp)
from repro.sim import optimize_switch, run_netsim, synthesize
from repro.traces import hft


def main():
    # ---- protocol definition + semantic binding (single source of truth)
    proto = compressed_protocol(name="hft_wire", addr_bits=4, qos_bits=2,
                                length_bits=6)                   # 2-byte header
    bound = bind(proto, flit_bits=256)
    print(bound.describe())
    print(f"vs Ethernet/IP/UDP: {ethernet_ipv4_udp().header_bytes} B of header\n")

    # ---- trace-aware DSE (every architecture policy on AUTO)
    trace = hft(seed=0)
    print("trace:", analyze(trace).describe())
    result, problem = optimize_switch(
        ArchRequest(n_ports=8, addr_bits=4), bound, trace,
        sla=SLA(p99_latency_ns=5_000, drop_rate=1e-3), verbose=True)
    print()
    print(result.summary())

    best = result.best
    rep = synthesize(best, bound)
    print(f"\nselected micro-architecture : {best.short()}")
    print(f"resources                   : {rep.luts/1e3:.1f}k LUT, "
          f"{rep.brams:.0f} BRAM @ {rep.fmax_mhz:.0f} MHz")
    print(f"verified                    : p99 {result.best_verify.p99_latency_ns:.0f} ns, "
          f"drops {result.best_verify.drop_rate:.2e}")


if __name__ == "__main__":
    main()
