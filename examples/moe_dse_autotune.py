"""Beyond-paper demo: SPAC's Algorithm 1 auto-tuning the MoE dispatch fabric.

The layer's token→expert traffic is extracted as a routing trace (packets →
output ports), the DSE sizes the capacity factor from the expert-load
histogram at a target token-drop rate (the paper's VOQ-depth sizing), picks
the payload protocol (bf16 vs int8 wire format) and the all-to-all schedule,
then verifies on the real fabric.

    PYTHONPATH=src python examples/moe_dse_autotune.py
"""

import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.launch.mesh import compat_make_mesh
import numpy as np

from repro.comm import autotune_moe
from repro.models import SINGLE_POD_PLAN, ModelConfig, MoEOptions
from repro.models.moe import apply_moe, init_moe


def main():
    mesh = compat_make_mesh((1, 1), ("data", "model"))
    cfg = ModelConfig(name="moe-demo", family="moe", n_layers=1, d_model=512,
                      n_heads=8, n_kv_heads=4, d_ff=1024, vocab=1000,
                      moe_experts=32, moe_topk=4)
    plan = SINGLE_POD_PLAN
    params, _ = init_moe(jax.random.PRNGKey(0), cfg, plan)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 256, 512), jnp.bfloat16)

    # fixed general-purpose baseline (the "SPAC Ethernet" of the fabric)
    _, aux = apply_moe(params, cfg, plan, mesh, x, MoEOptions(capacity_factor=1.25))
    load = np.asarray(aux["expert_load"], float)
    print(f"baseline  : cf=1.25/bf16/a2a×1  drop={float(aux['drop_frac']):.4f} "
          f"load_cv={load.std()/load.mean():.2f}")

    # analytics modelled at 16-way expert parallelism (the production mesh)
    result, problem = autotune_moe(params, cfg, plan, mesh, x, model_tp=16,
                                   verbose=True)
    print()
    print(result.summary())
    best = result.best
    print(f"\nselected CommSpec : {best.short()}")
    print(f"verified drop     : {result.best_verify.drop_rate:.4f} "
          f"(target ε=2e-2, statistical sizing from the routing trace)")
    print(f"dispatch buffers  : {problem._buffer_bytes(best)/1e6:.2f} MB/device "
          f"(wire {problem._a2a_bytes(best)/1e6:.2f} MB/step)")
    print("\nPareto front:")
    for c, v in result.pareto:
        print(f"  {c.short():32s} step≈{v.p99_latency_ns/1e3:.1f}µs drop={v.drop_rate:.4f}")


if __name__ == "__main__":
    main()
