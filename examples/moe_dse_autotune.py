"""Beyond-paper demo: SPAC's Algorithm 1 auto-tuning the MoE dispatch fabric.

The layer's token→expert traffic is extracted as a routing trace (packets →
output ports), the DSE sizes the capacity factor from the expert-load
histogram at a target token-drop rate (the paper's VOQ-depth sizing), picks
the payload protocol (bf16 vs int8 wire format) and the all-to-all schedule,
then verifies on the real fabric.

The whole experiment is the registry's ``moe_dispatch`` scenario — one
serializable spec (``spac show moe_dispatch``); ``autotune_moe`` remains the
legacy one-call wrapper over the same machinery.

    pip install -e .   # once
    python examples/moe_dse_autotune.py
"""

import numpy as np

from repro.api import registry, run_scenario
from repro.models import MoEOptions
from repro.models.moe import apply_moe


def main():
    scenario = registry["moe_dispatch"]
    print("scenario spec:", scenario.to_json(), sep="\n")

    report = run_scenario(scenario, verbose=True)
    problem = report.problem                    # the live CommDSEProblem

    # fixed general-purpose baseline (the "SPAC Ethernet" of the fabric)
    _, aux = apply_moe(problem.params, problem.cfg, problem.plan, problem.mesh,
                       problem.sample_x, MoEOptions(capacity_factor=1.25))
    load = np.asarray(aux["expert_load"], float)
    print(f"\nbaseline  : cf=1.25/bf16/a2a×1  drop={float(aux['drop_frac']):.4f} "
          f"load_cv={load.std()/load.mean():.2f}")

    result = report.result
    print()
    print(result.summary())
    best = result.best
    print(f"\nselected CommSpec : {best.short()}")
    print(f"verified drop     : {result.best_verify.drop_rate:.4f} "
          f"(target ε={scenario.sla.drop_rate:g}, statistical sizing from "
          "the routing trace)")
    print(f"dispatch buffers  : {problem._buffer_bytes(best)/1e6:.2f} MB/device "
          f"(wire {problem._a2a_bytes(best)/1e6:.2f} MB/step)")
    print("\nPareto front:")
    for c, v in result.pareto:
        print(f"  {c.short():32s} step≈{v.p99_latency_ns/1e3:.1f}µs drop={v.drop_rate:.4f}")


if __name__ == "__main__":
    main()
