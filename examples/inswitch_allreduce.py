"""In-network computing (§III-B.5): inject a custom aggregation kernel into
the switch pipeline — the iSwitch-style in-switch all-reduce the paper cites
as future work, built on SPAC's custom-kernel hooks.

The kernel consumes gradient packets addressed to the aggregator port and
releases one aggregated packet per round once all workers have contributed,
cutting aggregator-port egress by ~(N-1)/N.

    pip install -e .   # once
    python examples/inswitch_allreduce.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core import (CustomKernelSpec, SchedulerKind, SwitchArch,
                        ForwardTableKind, VOQKind, bind, compressed_protocol)
from repro.sim import synthesize
from repro.switch import simulate
from repro.traces import rl_allreduce


def make_aggregation_kernel(n_workers: int, agg_port: int = 0) -> CustomKernelSpec:
    """Stateful hook: count contributions per round; drop all but the last
    packet of each round (the survivor models the aggregated result)."""

    def fn(kstate, pids, out_port, valid, cyc):
        count = kstate                                  # contributions mod n
        to_agg = valid & (out_port == agg_port)
        # position of each simultaneous contribution within the round
        pos = count + jnp.cumsum(to_agg.astype(jnp.int32))
        keep_agg = to_agg & (pos % n_workers == 0)      # release one per round
        count = (count + to_agg.sum()) % n_workers
        keep = ~to_agg | keep_agg
        return count, out_port, valid & keep

    spec = CustomKernelSpec(name="allreduce_agg", ii=1, latency_cycles=6,
                            luts=9000, ffs=7000, brams=8, fn=fn)
    object.__setattr__(spec, "init_state", jnp.zeros((), jnp.int32))
    return spec


def main():
    n = 8
    tr = rl_allreduce(seed=0, n_ports=n)
    bound = bind(compressed_protocol(addr_bits=4, length_bits=12), flit_bits=1024)

    base = SwitchArch(n_ports=n, bus_bits=1024, fwd=ForwardTableKind.FULL_LOOKUP,
                      voq=VOQKind.NXN, sched=SchedulerKind.EDRRM, voq_depth=512,
                      addr_bits=4)
    inc = SwitchArch(n_ports=n, bus_bits=1024, fwd=ForwardTableKind.FULL_LOOKUP,
                     voq=VOQKind.NXN, sched=SchedulerKind.EDRRM, voq_depth=512,
                     addr_bits=4, custom_kernels=(make_aggregation_kernel(n - 1),))

    for name, arch in (("baseline", base), ("in-switch-aggregation", inc)):
        rep = synthesize(arch, bound)
        res = simulate(arch, bound, tr, fclk_hz=rep.fmax_mhz * 1e6)
        print(f"{name:24s} delivered={res.delivered_copies:5d} "
              f"p50={res.p(50):7.1f}ns p99={res.p(99):8.1f}ns "
              f"maxQ={int(res.occ_max.max()):4d} "
              f"LUT={rep.luts/1e3:6.1f}k (+kernel)" )
    print("\nthe aggregation kernel absorbs the incast: the aggregator's VOQ "
          "backlog and egress volume drop by ~7/8 while worker traffic is "
          "unchanged — the deployment path for [46]-style gradient aggregation.")


if __name__ == "__main__":
    main()
