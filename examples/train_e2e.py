"""End-to-end training driver: a ~100M-parameter llama-family model trained
for a few hundred steps on synthetic data with the full production substrate —
microbatched train step, WSD schedule, async checkpointing, fault-tolerant
supervisor (with an injected crash to prove restart), and exact data resume.

    pip install -e .   # once
    python examples/train_e2e.py --steps 200      # full run
    python examples/train_e2e.py --steps 20       # quick look
"""

import argparse
import tempfile
import time

import jax
import jax.numpy as jnp

from repro.launch.mesh import compat_make_mesh

from repro.data import DataConfig, SyntheticLM
from repro.models import SINGLE_POD_PLAN, ModelConfig
from repro.models import transformer as T
from repro.runtime import FaultInjector, Supervisor
from repro.train import TrainSpec, adamw, make_train_step


def model_100m() -> ModelConfig:
    return ModelConfig(name="llama-100m", family="dense", n_layers=12,
                       d_model=768, n_heads=12, n_kv_heads=4, head_dim=64,
                       d_ff=2048, vocab=32000, rope_theta=1e4, remat="none")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--inject-crash", type=int, default=None,
                    help="step at which to kill the 'node' (default steps//2)")
    args = ap.parse_args()

    mesh = compat_make_mesh((1, 1), ("data", "model"))
    cfg = model_100m()
    plan = SINGLE_POD_PLAN
    print(f"model: {cfg.name} — {cfg.param_count()/1e6:.0f}M params")

    params, _ = T.init_params(jax.random.PRNGKey(0), cfg, plan)
    opt = adamw(lr=6e-4)
    spec = TrainSpec(microbatches=2, lr=6e-4, warmup_steps=max(args.steps // 20, 2),
                     total_steps=args.steps, schedule="wsd")
    train_step = jax.jit(make_train_step(cfg, plan, mesh, opt, spec))
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                                  global_batch=args.batch, seed=0))

    def step_fn(state, step):
        p, o = state
        batch = {k: jnp.asarray(v) for k, v in data.batch(step).items()}
        p, o, m = train_step(p, o, batch, jnp.asarray(step))
        return (p, o), m

    crash_at = args.inject_crash if args.inject_crash is not None else args.steps // 2
    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="spac_e2e_")
    sup = Supervisor(ckpt_dir, ckpt_every=max(args.steps // 8, 5),
                     injector=FaultInjector(schedule={crash_at: "crash"}))

    t0 = time.time()
    res = sup.run((params, opt.init(params)), step_fn, total_steps=args.steps)
    dt = time.time() - t0
    losses = [h["loss"] for h in res.metrics_history]
    n_tok = args.batch * args.seq
    print(f"\n{res.final_step} steps in {dt:.0f}s "
          f"({n_tok * len(losses) / dt:.0f} tok/s incl. {res.restarts} restart(s))")
    k = max(len(losses) // 10, 1)
    print(f"loss: {sum(losses[:k])/k:.3f} -> {sum(losses[-k:])/k:.3f}")
    print(f"checkpoints in {ckpt_dir}")
    assert sum(losses[-k:]) / k < sum(losses[:k]) / k, "training must make progress"


if __name__ == "__main__":
    main()
