"""Serving driver: continuous batching over the jitted decode step.

    pip install -e .   # once
    python examples/serve_batched.py --arch llama3.2-1b
"""

import argparse
import time

import jax

from repro.launch.mesh import compat_make_mesh
import numpy as np

from repro.configs import get_smoke
from repro.models import SINGLE_POD_PLAN
from repro.models import transformer as T
from repro.serve import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()

    mesh = compat_make_mesh((1, 1), ("data", "model"))
    cfg = get_smoke(args.arch)
    plan = SINGLE_POD_PLAN
    params, _ = T.init_params(jax.random.PRNGKey(0), cfg, plan)
    eng = ServeEngine(cfg, plan, mesh, params, slots=args.slots, s_max=128)

    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, rng.integers(3, 9))
                    .astype(np.int32), max_new=args.max_new,
                    temperature=0.0 if i % 2 else 0.8)
            for i in range(args.requests)]
    for r in reqs:
        eng.submit(r)

    t0 = time.time()
    finished = eng.run_until_drained(max_ticks=10_000)
    dt = time.time() - t0
    toks = sum(len(r.out) for r in reqs)
    print(f"{len(finished)}/{len(reqs)} requests served, {toks} tokens "
          f"({dt:.1f}s, {toks/dt:.1f} tok/s on CPU, slots={args.slots})")
    for r in reqs[:4]:
        print(f"  req{r.rid}: prompt{list(r.prompt[:4])}… -> {r.out}")
    assert len(finished) == len(reqs) and all(r.done for r in finished)


if __name__ == "__main__":
    main()
