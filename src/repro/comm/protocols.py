"""Comm-layer message protocols — SPAC's protocol customisation on the ICI.

The paper strips general-purpose header overhead per workload; here the
cross-pod gradient synchronisation protocol is customisable the same way:

  * ``bf16``  — baseline: plain all-reduce (GSPMD default behaviour)
  * ``int8``  — compressed protocol: per-128-group int8 payload + f32 scales,
                exchanged with an all-gather and averaged after dequantise
                (~3.5× fewer cross-pod bytes than a bf16 all-reduce)

``wrap_grad_fn_with_pod_protocol`` runs the whole grad computation inside a
shard_map that is *manual over the pod axis only* (data/model stay under
GSPMD), so the cross-pod exchange is exactly the collective we emit — the
dry-run HLO shows the byte reduction directly.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.kernels.quant_pack import ref as qref

__all__ = ["compressed_mean", "wrap_grad_fn_with_pod_protocol"]


def _leaf_compressed_mean(g: jnp.ndarray, axis: str) -> jnp.ndarray:
    """int8 all-gather + dequantised mean over a manual mesh axis."""
    shape, size = g.shape, g.size
    pad = (-size) % qref.GROUP
    flat = jnp.pad(g.reshape(-1).astype(jnp.float32), (0, pad)).reshape(-1, qref.GROUP)
    q, s = qref.quantize_ref(flat)
    qg = jax.lax.all_gather(q, axis)                    # [npod, R, G] int8 on the wire
    sg = jax.lax.all_gather(s, axis)                    # [npod, R, 1] f32 scales
    deq = qg.astype(jnp.float32) * sg                   # [npod, R, G]
    mean = deq.mean(0).reshape(-1)[: size].reshape(shape)
    return mean.astype(g.dtype)


def compressed_mean(grads, axis: str):
    return jax.tree.map(lambda g: _leaf_compressed_mean(g, axis), grads)


def wrap_grad_fn_with_pod_protocol(grad_fn: Callable, mesh, *, payload: str = "int8"):
    """grad_fn(params, batch) -> ((loss, metrics), grads), pod-synchronised
    with the chosen payload protocol."""
    if not compat.has_partial_manual_shard_map():
        # fail fast: on 0.4.x the partial-manual all_gather below aborts the
        # whole process with a native XLA CHECK, which is uncatchable
        raise NotImplementedError(
            "the pod gradient protocol needs partial-manual shard_map "
            "(jax >= 0.5); this JAX's SPMD partitioner CHECK-fails on "
            "manual-subgroup collectives")

    def wrapped(params, batch):
        def inner(p, b):
            (loss, metrics), g = grad_fn(p, b)          # pod-local gradients
            if payload == "int8":
                g = compressed_mean(g, "pod")
            else:
                g = jax.tree.map(lambda x: jax.lax.pmean(x, "pod"), g)
            loss = jax.lax.pmean(loss, "pod")
            metrics = jax.tree.map(lambda m: jax.lax.pmean(m, "pod"), metrics)
            return (loss, metrics), g

        return compat.shard_map(
            inner, mesh, axis_names={"pod"},
            in_specs=(P(), P("pod")),
            out_specs=((P(), P()), P()),
            check=False,
        )(params, batch)

    return wrapped
