"""SPAC DSE over the TPU comm/dispatch layer (DESIGN.md §2.2).

Algorithm 1, verbatim machinery (``repro.core.dse``), re-targeted: the
"trace" is the model's own routing trace (token → expert = packet → port),
the "templates" are ``CommSpec``s (capacity factor / payload dtype / a2a
schedule / microbatches), stage-2's infinite-buffer surrogate is an analytic
roofline model fed by expert-load histograms, stage-3 sizes the capacity
factor exactly like the paper sizes VOQ depths (load-quantile @ token-drop
rate ε, aligned to MXU tiles), and stage-4 verifies by running the real
fabric.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dse import (DSEProblem, ResourceBudget, SLA, SurrogateResult,
                            VerifyResult, run_dse)
from repro.core.search import DesignSpace, Dim
from repro.launch.roofline import TPU_V5E
from repro.models.config import ModelConfig, ShardingPlan
from repro.models.moe import MoEOptions, apply_moe

__all__ = ["CommSpec", "CommDSEProblem", "route_trace", "autotune_moe"]


@dataclasses.dataclass(frozen=True)
class CommSpec:
    """One comm-layer candidate (the fabric's SwitchArch analogue)."""

    capacity_factor: float = 1.25
    payload: str = "bf16"          # bf16 | int8
    a2a_chunks: int = 1
    microbatches: int = 1

    def moe_options(self, router: str = "learned_topk") -> MoEOptions:
        return MoEOptions(capacity_factor=self.capacity_factor,
                          payload=self.payload, a2a_chunks=self.a2a_chunks,
                          router=router)

    def short(self) -> str:
        return (f"cf={self.capacity_factor:.2f}/{self.payload}/"
                f"a2a×{self.a2a_chunks}/µb={self.microbatches}")


def route_trace(params, cfg: ModelConfig, x: jnp.ndarray, tp_size: int,
                n_rounds: int = 8) -> np.ndarray:
    """Per-dispatch-round expert-load matrix [rounds, E] — the traffic trace.

    Runs only the router (cheap) over shards of the token stream, mirroring
    how each device slice routes independently in the fabric.
    """
    e, k = cfg.moe_experts, cfg.moe_topk
    flat = x.reshape(-1, x.shape[-1])
    t_m = max(flat.shape[0] // n_rounds, 1)
    loads = []
    for r in range(n_rounds):
        xs = flat[r * t_m:(r + 1) * t_m]
        if xs.shape[0] == 0:
            break
        logits = jnp.einsum("td,de->te", xs.astype(jnp.float32), params["router"])
        _, experts = jax.lax.top_k(logits, k)
        counts = np.bincount(np.asarray(experts).reshape(-1), minlength=e)
        loads.append(counts)
    return np.asarray(loads)                      # [rounds, E]


class CommDSEProblem(DSEProblem):
    def __init__(
        self,
        params,
        cfg: ModelConfig,
        plan: ShardingPlan,
        mesh,
        sample_x: jnp.ndarray,                    # [B, S, d] routing sample
        *,
        tokens_per_device: Optional[int] = None,
        model_tp: Optional[int] = None,     # tensor extent for the analytic
        hw: Dict = TPU_V5E,                 # model (default: the actual mesh)
    ):
        self.params, self.cfg, self.plan, self.mesh = params, cfg, plan, mesh
        self.sample_x = sample_x
        self.tp_size = model_tp or mesh.shape[plan.tp_axis]
        self.hw = hw
        self.loads = route_trace(params, cfg, sample_x, self.tp_size)
        self.tokens_per_round = int(self.loads.sum(1).mean()) // cfg.moe_topk
        self.tokens_per_device = tokens_per_device or self.tokens_per_round

    # ------------------------------------------------------------- helpers
    def _buffer_bytes(self, c: CommSpec) -> float:
        """Dispatch-buffer footprint per device (the BRAM analogue)."""
        t_m = self.tokens_per_device / c.microbatches
        cap = t_m * self.cfg.moe_topk / self.cfg.moe_experts * c.capacity_factor
        slot = self.cfg.d_model * (1 if c.payload == "int8" else 2)
        return 2.0 * self.cfg.moe_experts * max(cap, 1) * slot   # send+recv

    def _a2a_bytes_batch(self, cs: List[CommSpec]) -> np.ndarray:
        """Wire bytes per step per device (both directions, all µbatches) for
        a whole candidate batch — the single home of the formula; the scalar
        helper delegates here so the two can never drift."""
        cf = np.array([c.capacity_factor for c in cs], np.float64)
        slot = self.cfg.d_model * np.array(
            [1 if c.payload == "int8" else 2 for c in cs], np.float64)
        slots = self.tokens_per_device * self.cfg.moe_topk * cf
        frac_remote = (self.tp_size - 1) / self.tp_size
        return 2.0 * slots * slot * frac_remote

    def _a2a_bytes(self, c: CommSpec) -> float:
        return float(self._a2a_bytes_batch([c])[0])

    def _step_time_batch(self, cs: List[CommSpec]) -> np.ndarray:
        """Analytic fabric time: max(compute, wire) per chunk + issue cost."""
        cf = np.array([c.capacity_factor for c in cs], np.float64)
        chunks = np.maximum(np.array([c.a2a_chunks for c in cs]), 1)
        slots = self.tokens_per_device * self.cfg.moe_topk * cf
        flops = 3 * 2 * slots * self.cfg.d_model * self.cfg.d_ff
        t_compute = flops / self.hw["peak_flops_bf16"]
        t_wire = self._a2a_bytes_batch(cs) / self.hw["ici_link_gbps"]
        t_issue = 5e-6 * chunks                   # per-collective issue cost
        per = np.maximum(t_compute, t_wire) / chunks
        return np.where(chunks > 1,               # pipelined: overlap comm/compute
                        per * (chunks + 1) + t_issue,
                        t_compute + t_wire + t_issue)

    def _step_time(self, c: CommSpec) -> float:
        return float(self._step_time_batch([c])[0])

    # ------------------------------------------------------------- Alg. 1
    def candidates(self) -> List[CommSpec]:
        out = []
        for payload in ("bf16", "int8"):
            for chunks in (1, 2, 4):
                for mb in (1, 2):
                    out.append(CommSpec(capacity_factor=2.0, payload=payload,
                                        a2a_chunks=chunks, microbatches=mb))
        return out

    # ------------------------------------------------------ search support
    def space(self) -> DesignSpace:
        """Parameterized fabric space for the generational search engine —
        per-dimension ranges (wider than the ``candidates()`` grid: 8-way
        a2a chunking and 4 microbatches join the sweep).  The capacity
        factor stays out of the genome: stage 3 *sizes* it from the routing
        trace exactly like VOQ depths."""
        return DesignSpace((
            Dim("payload", ("bf16", "int8")),
            Dim("a2a_chunks", (1, 2, 4, 8)),
            Dim("microbatches", (1, 2, 4)),
        ))

    def decode(self, assignment) -> CommSpec:
        return CommSpec(capacity_factor=2.0,
                        payload=assignment["payload"],
                        a2a_chunks=assignment["a2a_chunks"],
                        microbatches=assignment["microbatches"])

    def static_timing(self, c: CommSpec) -> Tuple[float, float]:
        """Stage-1 prune: dispatch buffers must clear the HBM headroom within
        the per-step arrival budget (line-rate feasibility analogue)."""
        t_proc = self._buffer_bytes(c) / self.hw["hbm_gbps"]
        t_arrival = self.tokens_per_device * self.cfg.d_model * 2 / self.hw["hbm_gbps"]
        return t_proc, 8.0 * t_arrival            # δ folded into the budget

    def surrogate(self, c: CommSpec) -> SurrogateResult:
        """Stage 2: infinite buffers — per-expert occupancy from the routing
        trace; latency distribution from the analytic fabric model.  One body
        with the batch path so the two can never drift."""
        return self.surrogate_batch([c])[0]

    def surrogate_batch(self, cands: List[CommSpec]) -> List[SurrogateResult]:
        """Stage-2 fan-out: the fabric model is closed-form, so the whole
        candidate batch reduces to one pass over the vectorised formulas."""
        if not cands:
            return []
        t = self._step_time_batch(cands)
        a2a = self._a2a_bytes_batch(cands)
        occupancy = self.loads.reshape(-1) / max(self.loads.mean(), 1e-9)
        return [
            SurrogateResult(
                q_occupancy=occupancy.copy(),   # no aliasing across candidates
                latency_ns=np.full(16, tb * 1e9),
                throughput_gbps=float(ab * 8 / max(tb, 1e-12) / 1e9),
                meta={"step_s": float(tb), "batched": True})
            for tb, ab in zip(t, a2a)
        ]

    def size_buffers(self, c: CommSpec, occupancy: np.ndarray, eps: float) -> CommSpec:
        """Stage 3: capacity factor = (1-ε) quantile of normalised expert load,
        aligned up to MXU-tile token multiples."""
        cf = float(np.quantile(occupancy, 1.0 - eps))
        t_m = max(self.tokens_per_device / c.microbatches, 1)
        slot_quantum = 8 * self.cfg.moe_experts / (t_m * self.cfg.moe_topk)
        cf = math.ceil(cf / max(slot_quantum, 1e-9)) * slot_quantum
        return dataclasses.replace(c, capacity_factor=max(round(cf, 3), 0.05))

    def resources(self, c: CommSpec) -> Dict[str, float]:
        b = self._buffer_bytes(c)
        return {"bytes_per_device": b, "bram": b}

    def verify(self, c: CommSpec) -> VerifyResult:
        """Stage 4: run the real fabric; measure the actual token-drop rate.
        One body with the batch path so the two can never drift."""
        return self.verify_batch([c])[0]

    def verify_batch(self, cands: List[CommSpec]) -> List[VerifyResult]:
        """Stage-4 fan-out: the analytic fabric metrics (step time, wire
        bytes) vectorise over the whole batch in one pass; only the genuinely
        dynamic part — dispatching through the real fabric to measure the
        actual token-drop rate — stays per candidate."""
        if not cands:
            return []
        t = self._step_time_batch(cands)
        a2a = self._a2a_bytes_batch(cands)
        out: List[VerifyResult] = []
        for c, tb, ab in zip(cands, t, a2a):
            _, aux = apply_moe(self.params, self.cfg, self.plan, self.mesh,
                               self.sample_x, c.moe_options(self.cfg.router))
            out.append(VerifyResult(
                p99_latency_ns=float(tb) * 1e9, mean_latency_ns=float(tb) * 1e9,
                drop_rate=float(aux["drop_frac"]),
                throughput_gbps=float(ab) * 8 / max(float(tb), 1e-12) / 1e9,
                meta={"expert_load": np.asarray(aux["expert_load"])}))
        return out

    def objectives(self, c: CommSpec, v: VerifyResult) -> Tuple[float, float]:
        return (v.p99_latency_ns, self._buffer_bytes(c))


def autotune_moe(params, cfg, plan, mesh, sample_x, *,
                 sla: Optional[SLA] = None, hbm_budget_bytes: float = 4e9,
                 model_tp: Optional[int] = None, verbose: bool = False):
    """One-call fabric auto-tune: routing trace in, Pareto CommSpec out."""
    problem = CommDSEProblem(params, cfg, plan, mesh, sample_x, model_tp=model_tp)
    sla = sla or SLA(p99_latency_ns=math.inf, drop_rate=2e-2)
    budget = ResourceBudget({"bytes_per_device": hbm_budget_bytes})
    result = run_dse(problem, sla, budget, top_k=8, verbose=verbose)
    return result, problem
