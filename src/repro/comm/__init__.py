"""Comm layer: message protocols (compressed gradients/dispatch) + the
SPAC DSE over comm configurations (dse_comm, built on repro.core.dse)."""
from .dse_comm import CommDSEProblem, CommSpec, autotune_moe, route_trace
from .protocols import compressed_mean, wrap_grad_fn_with_pod_protocol
__all__ = ["CommDSEProblem", "CommSpec", "autotune_moe", "compressed_mean",
           "route_trace", "wrap_grad_fn_with_pod_protocol"]
