"""The five evaluation workloads (§V-A), as parameterised generators.

Each generator matches the published packet statistics:

* **HFT** — low latency, high burstiness (market-data bursts), 24 B payloads
  (Table II), feed fan-out concentrated on a few subscriber ports.
* **RL All-Reduce** — iSwitch-style synchronous rounds: workers → aggregator
  incast with ~1463 B gradient chunks, then broadcast back; regular + hotspot.
* **DataCenter** — Alibaba-trace-style microservice RPC: heavy-tailed mice
  flows (~965 B mean), Zipf destination popularity over 32 nodes.
* **Industry** — SCADA polling from the medical-waste-incinerator capture:
  master/outstation request/response, ~58.7 B payloads, low rate, regular.
* **Underwater** — 8 DESERT robots, periodic 2 B beacons.
"""

from __future__ import annotations

import numpy as np

from .base import Trace, burst_times, poisson_times

__all__ = ["hft", "rl_allreduce", "datacenter", "industry", "underwater", "uniform", "WORKLOADS"]


def hft(
    seed: int = 0,
    n_ports: int = 8,
    duration_s: float = 400e-6,
    link_gbps: float = 10.0,
    load: float = 0.35,
) -> Trace:
    rng = np.random.default_rng(seed)
    # feed handlers on ports 0-1 publish to subscriber ports; bursts on ticks
    payload = 24
    pkt_s = (payload + 42) * 8 / (link_gbps * 1e9)
    per_src_rate = load * link_gbps * 1e9 / ((payload + 42) * 8) / n_ports
    times, srcs, dsts = [], [], []
    for s in range(n_ports):
        bursty = s < 2  # feed sources burst hard
        t = burst_times(
            rng,
            burst_rate_ps=per_src_rate / (8.0 if bursty else 2.0),
            duration_s=duration_s,
            burst_len_mean=8.0 if bursty else 2.0,
            intra_gap_s=pkt_s * 1.05,
        )
        times.append(t)
        srcs.append(np.full(t.size, s))
        # subscribers cluster: Zipf-ish preference for ports 2-4
        pref = np.array([0.05, 0.05, 0.3, 0.25, 0.15, 0.08, 0.07, 0.05][:n_ports])
        pref[s] = 0.0
        pref = pref / pref.sum()
        dsts.append(rng.choice(n_ports, size=t.size, p=pref))
    n = sum(t.size for t in times)
    return Trace("hft", np.concatenate(times), np.concatenate(srcs),
                 np.concatenate(dsts), np.full(n, payload), n_ports, link_gbps)


def rl_allreduce(
    seed: int = 0,
    n_ports: int = 8,
    rounds: int = 12,
    chunks_per_round: int = 24,
    payload: int = 1463,
    link_gbps: float = 10.0,
) -> Trace:
    """iSwitch [46]: workers stream gradient chunks to the aggregator (port 0),
    which broadcasts the reduced tensor back — incast then fan-out, per round."""
    rng = np.random.default_rng(seed)
    pkt_s = (payload + 42) * 8 / (link_gbps * 1e9)
    n_w = n_ports - 1
    # a round = synchronised incast (workers at line rate, in parallel) then the
    # aggregator's fan-out, which its single link must serialise
    bcast_s = chunks_per_round * n_w * pkt_s * 1.02
    round_gap = chunks_per_round * pkt_s * 1.3 + bcast_s * 1.15
    times, srcs, dsts = [], [], []
    for r in range(rounds):
        base = r * round_gap
        for w in range(1, n_ports):  # incast: all workers → port 0, synchronised
            jit = rng.uniform(0, pkt_s * 0.5)
            t = base + jit + np.arange(chunks_per_round) * pkt_s * 1.02
            times.append(t)
            srcs.append(np.full(t.size, w))
            dsts.append(np.zeros(t.size, dtype=np.int64))
        # fan-out of the reduced tensor: one serialised stream from port 0
        bb = base + chunks_per_round * pkt_s * 1.2
        seq = np.arange(chunks_per_round * n_w)
        t = bb + seq * pkt_s * 1.02
        times.append(t)
        srcs.append(np.zeros(t.size, dtype=np.int64))
        dsts.append(1 + (seq % n_w))
    n = sum(t.size for t in times)
    return Trace("rl_allreduce", np.concatenate(times), np.concatenate(srcs),
                 np.concatenate(dsts), np.full(n, payload), n_ports, link_gbps)


def datacenter(
    seed: int = 0,
    n_ports: int = 32,
    duration_s: float = 800e-6,
    link_gbps: float = 25.0,
    load: float = 0.2,
    mean_payload: float = 965.5,
) -> Trace:
    rng = np.random.default_rng(seed)
    mean_wire = mean_payload + 42
    rate = load * link_gbps * 1e9 / (mean_wire * 8) / n_ports
    times, srcs, dsts, sizes = [], [], [], []
    # Zipf destination popularity (microservice hotspots)
    ranks = np.arange(1, n_ports + 1, dtype=np.float64)
    zipf = (1.0 / ranks**1.1)
    for s in range(n_ports):
        t = poisson_times(rng, rate, duration_s)
        times.append(t)
        srcs.append(np.full(t.size, s))
        p = zipf.copy()
        p[s] = 0.0
        p /= p.sum()
        dsts.append(rng.choice(n_ports, size=t.size, p=p))
        # heavy-tailed mice: lognormal with the published mean
        raw = rng.lognormal(mean=np.log(200), sigma=1.3, size=t.size)
        sizes.append(np.clip(raw * (mean_payload / max(raw.mean(), 1.0)), 4, 9000).astype(np.int64))
    n = sum(t.size for t in times)
    return Trace("datacenter", np.concatenate(times), np.concatenate(srcs),
                 np.concatenate(dsts), np.concatenate(sizes), n_ports, link_gbps)


def industry(
    seed: int = 0,
    n_ports: int = 10,
    duration_s: float = 2e-3,
    link_gbps: float = 10.0,
    poll_period_s: float = 40e-6,
) -> Trace:
    """SCADA master (port 0) polls outstations round-robin; responses return."""
    rng = np.random.default_rng(seed)
    times, srcs, dsts, sizes = [], [], [], []
    t = 0.0
    station = 1
    while t < duration_s:
        # request master->station (small), response station->master (58.7B mean)
        times += [t, t + 6e-6 + rng.uniform(0, 2e-6)]
        srcs += [0, station]
        dsts += [station, 0]
        sizes += [16, max(2, int(rng.normal(58.7, 10)))]
        station = station % (n_ports - 1) + 1
        t += poll_period_s / (n_ports - 1)
    n = len(times)
    return Trace("industry", np.array(times), np.array(srcs), np.array(dsts),
                 np.array(sizes, dtype=np.int64), n_ports, link_gbps)


def underwater(
    seed: int = 0,
    n_ports: int = 8,
    duration_s: float = 4e-3,
    link_gbps: float = 1.0,
    beacon_period_s: float = 50e-6,
) -> Trace:
    """8 DESERT robots exchange 2 B beacons on a regular schedule."""
    rng = np.random.default_rng(seed)
    times, srcs, dsts = [], [], []
    for s in range(n_ports):
        t = np.arange(s * beacon_period_s / n_ports, duration_s, beacon_period_s)
        t = t + rng.uniform(0, beacon_period_s * 0.02, size=t.size)
        times.append(t)
        srcs.append(np.full(t.size, s))
        dsts.append(rng.permutation(np.resize(np.delete(np.arange(n_ports), s), t.size)))
    n = sum(t.size for t in times)
    return Trace("underwater", np.concatenate(times), np.concatenate(srcs),
                 np.concatenate(dsts), np.full(n, 2), n_ports, link_gbps)


def uniform(
    seed: int = 0,
    n_ports: int = 8,
    duration_s: float = 400e-6,
    link_gbps: float = 10.0,
    load: float = 0.6,
    payload: int = 512,
) -> Trace:
    """Uniform Bernoulli traffic — the Fig. 1/Fig. 8 sensitivity baseline."""
    rng = np.random.default_rng(seed)
    rate = load * link_gbps * 1e9 / ((payload + 42) * 8) / n_ports
    times, srcs, dsts = [], [], []
    for s in range(n_ports):
        t = poisson_times(rng, rate, duration_s)
        times.append(t)
        srcs.append(np.full(t.size, s))
        d = rng.integers(0, n_ports - 1, size=t.size)
        dsts.append(np.where(d >= s, d + 1, d))
    n = sum(t.size for t in times)
    return Trace("uniform", np.concatenate(times), np.concatenate(srcs),
                 np.concatenate(dsts), np.full(n, payload), n_ports, link_gbps)


WORKLOADS = {
    "hft": hft,
    "rl_allreduce": rl_allreduce,
    "datacenter": datacenter,
    "industry": industry,
    "underwater": underwater,
    "uniform": uniform,
}
