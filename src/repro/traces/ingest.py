"""Capture ingestion: pcap/CSV → ``Trace`` through declarative stages.

Real captures enter the DSE here.  A reader parses the container format
(classic libpcap via stdlib ``struct``, or CSV) into the raw packet arrays,
then a ``Pipeline`` of declarative, composable stages massages them into a
simulation-ready ``Trace`` — filter, remap ports, rescale time, clip — plus
*generative stressors* (incast storm, Zipf drift, diurnal load) that
synthesise adversarial traffic on top of the capture.  Pipelines are data:
``to_dict``/``from_dict`` round-trip them, stage application order is the
tuple order (order-deterministic by construction), and every stochastic
stage draws from a generator seeded by ``(pipeline seed, stage index)`` so
one seed reproduces the whole pipeline regardless of which stages surround
a stressor.

    tr = ingest("capture.csv",
                pipeline=Pipeline(seed=7)
                    .then("filter", min_payload=64)
                    .then("remap_ports", n_ports=8)
                    .then("incast", dst=0, n_senders=6)
                    .then("rescale_time", factor=0.5))
    tr.save("capture.npz")     # → TraceSpec(path="capture.npz") in a Scenario
"""

from __future__ import annotations

import csv
import dataclasses
import math
import struct
from pathlib import Path
from typing import Any, Callable, Dict, Iterable, Optional, Tuple

import numpy as np

from .base import Trace

__all__ = ["IngestError", "Pipeline", "Stage", "STAGES", "ingest",
           "read_csv", "read_pcap", "write_pcap"]


class IngestError(ValueError):
    """Malformed capture or pipeline — ``spac ingest`` maps this to exit 2."""


# --------------------------------------------------------------------------
# readers
# --------------------------------------------------------------------------

_CSV_COLUMNS = ("time_s", "src", "dst", "payload_bytes")


def read_csv(path, *, name: Optional[str] = None, n_ports: Optional[int] = None,
             link_gbps: float = 100.0) -> Trace:
    """CSV → ``Trace``.  Accepts a header row naming (any superset of)
    ``time_s, src, dst, payload_bytes`` in any order, or headerless rows in
    exactly that positional order."""
    path = Path(path)
    try:
        with open(path, newline="") as fh:
            rows = [r for r in csv.reader(fh) if r and any(f.strip() for f in r)]
    except OSError as e:
        raise IngestError(f"cannot read {path}: {e}") from e
    if not rows:
        raise IngestError(f"{path}: empty capture")

    def _numeric(field: str) -> bool:
        try:
            float(field)
            return True
        except ValueError:
            return False

    first = [f.strip() for f in rows[0]]
    if all(_numeric(f) for f in first):
        cols = {c: i for i, c in enumerate(_CSV_COLUMNS)}
        body = rows
    else:
        cols = {c.strip(): i for i, c in enumerate(first)}
        missing = [c for c in _CSV_COLUMNS if c not in cols]
        if missing:
            raise IngestError(
                f"{path}: header is missing column(s) {missing}; "
                f"need {list(_CSV_COLUMNS)} (extra columns are ignored)")
        body = rows[1:]
    if not body:
        raise IngestError(f"{path}: no packet rows")

    time_s, src, dst, payload = [], [], [], []
    for ln, row in enumerate(body, start=1):
        try:
            time_s.append(float(row[cols["time_s"]]))
            src.append(int(row[cols["src"]]))
            dst.append(int(row[cols["dst"]]))
            payload.append(int(row[cols["payload_bytes"]]))
        except (ValueError, IndexError) as e:
            raise IngestError(f"{path}: bad row {ln}: {row!r} ({e})") from e
    return _make_trace(path.stem if name is None else name,
                       np.asarray(time_s), np.asarray(src), np.asarray(dst),
                       np.asarray(payload), n_ports, link_gbps)


#: classic-pcap magic → (byte order, fraction-of-second unit in ns)
_PCAP_MAGICS = {
    0xA1B2C3D4: ("<", 1000), 0xD4C3B2A1: (">", 1000),     # microsecond
    0xA1B23C4D: ("<", 1), 0x4D3CB2A1: (">", 1),           # nanosecond
}
_LINKTYPE_ETHERNET = 1
_ETHERTYPE_IPV4 = 0x0800


def read_pcap(path, *, name: Optional[str] = None, n_ports: Optional[int] = None,
              link_gbps: float = 100.0) -> Trace:
    """Classic libpcap → ``Trace`` (stdlib ``struct``; no capture library).

    Ethernet + IPv4 frames only; a packet's host id is the low 16 bits of
    its IPv4 address (deterministic — no first-seen renumbering), so ingest
    of the same capture always yields the same ids; use the ``remap_ports``
    stage to fold a real address plan onto the simulated port space.  The
    payload is the frame's original (untruncated) wire length; timestamps
    convert through one integer-nanosecond value so they are reproducible to
    the bit."""
    path = Path(path)
    try:
        data = path.read_bytes()
    except OSError as e:
        raise IngestError(f"cannot read {path}: {e}") from e
    if len(data) < 24:
        raise IngestError(f"{path}: truncated pcap global header")
    magic = struct.unpack("<I", data[:4])[0]
    if magic not in _PCAP_MAGICS:
        magic = struct.unpack(">I", data[:4])[0]
    if magic not in _PCAP_MAGICS:
        raise IngestError(f"{path}: not a classic pcap (magic {magic:#x}); "
                          f"pcapng is not supported — convert with tshark")
    order, frac_ns = _PCAP_MAGICS[magic]
    linktype = struct.unpack(order + "I", data[20:24])[0]
    if linktype != _LINKTYPE_ETHERNET:
        raise IngestError(f"{path}: linktype {linktype} unsupported "
                          f"(need Ethernet = {_LINKTYPE_ETHERNET})")

    time_s, src, dst, payload = [], [], [], []
    off, skipped = 24, 0
    while off < len(data):
        if off + 16 > len(data):
            raise IngestError(f"{path}: truncated record header at {off}")
        sec, frac, incl, orig = struct.unpack(order + "IIII", data[off:off + 16])
        off += 16
        if off + incl > len(data):
            raise IngestError(f"{path}: truncated packet record at {off}")
        frame = data[off:off + incl]
        off += incl
        # Ethernet(14) + IPv4 header up to the addresses (34 bytes)
        if len(frame) < 34 or struct.unpack(">H", frame[12:14])[0] != _ETHERTYPE_IPV4:
            skipped += 1
            continue
        time_s.append((sec * 10**9 + frac * frac_ns) * 1e-9)
        src.append(struct.unpack(">H", frame[28:30])[0])
        dst.append(struct.unpack(">H", frame[32:34])[0])
        payload.append(orig)
    if not time_s:
        raise IngestError(f"{path}: no Ethernet/IPv4 packets "
                          f"({skipped} frames skipped)")
    return _make_trace(path.stem if name is None else name,
                       np.asarray(time_s), np.asarray(src), np.asarray(dst),
                       np.asarray(payload), n_ports, link_gbps)


def write_pcap(path, time_ns: Iterable[int], src: Iterable[int],
               dst: Iterable[int], payload_bytes: Iterable[int]) -> None:
    """Synthesise a minimal nanosecond classic pcap (Ethernet + IPv4 + UDP).

    The inverse convention of :func:`read_pcap`: host id h becomes IPv4
    ``10.0.(h>>8).(h&255)`` and ``payload_bytes`` becomes the record's
    original length (the stored frame is header-only, a legal snaplen
    truncation) — so write → read round-trips ids, times and sizes exactly.
    Test/fixture helper; real captures come from real taps."""
    out = bytearray()
    out += struct.pack("<IHHiIII", 0xA1B23C4D, 2, 4, 0, 0, 65535,
                       _LINKTYPE_ETHERNET)
    for t, s, d, p in zip(time_ns, src, dst, payload_bytes):
        t, s, d, p = int(t), int(s), int(d), int(p)
        ip = struct.pack(">BBHHHBBH", 0x45, 0, 28, 0, 0, 64, 17, 0)
        ip += bytes((10, 0, (s >> 8) & 0xFF, s & 0xFF))
        ip += bytes((10, 0, (d >> 8) & 0xFF, d & 0xFF))
        udp = struct.pack(">HHHH", 4000, 4000, 8, 0)
        frame = b"\x02" * 6 + b"\x04" * 6 + struct.pack(">H", _ETHERTYPE_IPV4) + ip + udp
        out += struct.pack("<IIII", t // 10**9, t % 10**9, len(frame), p)
        out += frame
    Path(path).write_bytes(bytes(out))


def _make_trace(name, time_s, src, dst, payload, n_ports, link_gbps) -> Trace:
    if np.any(payload < 0):
        raise IngestError(f"{name}: negative payload_bytes")
    if np.any(src < 0) or np.any(dst < 0):
        raise IngestError(f"{name}: negative port ids")
    inferred = int(max(src.max(), dst.max())) + 1 if src.size else 1
    if n_ports is None:
        n_ports = inferred
    elif inferred > n_ports:
        raise IngestError(
            f"{name}: port id {inferred - 1} out of range for "
            f"n_ports={n_ports} (add a remap_ports stage or raise n_ports)")
    return Trace(name=name, time_s=time_s.astype(np.float64),
                 src=src.astype(np.int32), dst=dst.astype(np.int32),
                 payload_bytes=payload.astype(np.int64),
                 n_ports=int(n_ports), link_gbps=float(link_gbps))


# --------------------------------------------------------------------------
# stages
# --------------------------------------------------------------------------

def _stage_filter(tr: Trace, rng: np.random.Generator, *,
                  min_payload: Optional[int] = None,
                  max_payload: Optional[int] = None,
                  t_start: Optional[float] = None,
                  t_stop: Optional[float] = None,
                  ports: Optional[Iterable[int]] = None) -> Trace:
    """Keep packets matching every given predicate."""
    keep = np.ones(len(tr), bool)
    if min_payload is not None:
        keep &= tr.payload_bytes >= min_payload
    if max_payload is not None:
        keep &= tr.payload_bytes <= max_payload
    if t_start is not None:
        keep &= tr.time_s >= t_start
    if t_stop is not None:
        keep &= tr.time_s < t_stop
    if ports is not None:
        allowed = np.asarray(sorted(int(p) for p in ports), np.int64)
        keep &= np.isin(tr.src, allowed) & np.isin(tr.dst, allowed)
    return Trace(tr.name, tr.time_s[keep], tr.src[keep], tr.dst[keep],
                 tr.payload_bytes[keep], tr.n_ports, tr.link_gbps)


def _stage_remap_ports(tr: Trace, rng: np.random.Generator, *,
                       n_ports: Optional[int] = None,
                       mapping: Optional[Dict[Any, Any]] = None) -> Trace:
    """Fold endpoint ids onto the simulated port space: an explicit old→new
    ``mapping`` (unmapped ids raise), or modulo ``n_ports``."""
    if mapping is not None:
        lut: Dict[int, int] = {int(k): int(v) for k, v in mapping.items()}
        ids = np.union1d(np.unique(tr.src), np.unique(tr.dst))
        unmapped = [int(i) for i in ids if int(i) not in lut]
        if unmapped:
            raise IngestError(f"remap_ports: no mapping for ids {unmapped}")
        remap = np.vectorize(lut.__getitem__, otypes=[np.int64])
        src, dst = remap(tr.src), remap(tr.dst)
        np_new = n_ports if n_ports is not None else int(max(lut.values())) + 1
    else:
        if n_ports is None:
            raise IngestError("remap_ports needs n_ports or mapping")
        np_new = int(n_ports)
        src, dst = tr.src % np_new, tr.dst % np_new
    return Trace(tr.name, tr.time_s, src, dst, tr.payload_bytes,
                 np_new, tr.link_gbps)


def _stage_rescale_time(tr: Trace, rng: np.random.Generator, *,
                        factor: float = 1.0, origin: bool = False) -> Trace:
    """Compress (<1) or dilate (>1) the timeline; ``origin`` re-bases the
    first arrival to t=0."""
    if factor <= 0:
        raise IngestError(f"rescale_time factor must be > 0, got {factor}")
    t = tr.time_s * float(factor)
    if origin and t.size:
        t = t - t.min()
    return Trace(tr.name, t, tr.src, tr.dst, tr.payload_bytes,
                 tr.n_ports, tr.link_gbps)


def _stage_clip(tr: Trace, rng: np.random.Generator, *,
                max_packets: Optional[int] = None,
                duration_s: Optional[float] = None) -> Trace:
    """Bound the trace: at most ``duration_s`` after the first arrival
    and/or the first ``max_packets`` packets."""
    out = tr
    if duration_s is not None and len(out):
        keep = out.time_s < out.time_s.min() + duration_s
        out = Trace(out.name, out.time_s[keep], out.src[keep], out.dst[keep],
                    out.payload_bytes[keep], out.n_ports, out.link_gbps)
    if max_packets is not None:
        out = out.head(int(max_packets))
    return out


def _stage_incast(tr: Trace, rng: np.random.Generator, *, dst: int = 0,
                  n_senders: int = 4, n_packets: int = 64,
                  payload_bytes: int = 1500, t_frac: float = 0.5,
                  window_s: Optional[float] = None) -> Trace:
    """Incast storm: ``n_senders`` distinct sources hammer one destination
    inside a short window — the classic fan-in buffer killer."""
    if len(tr) == 0:
        return tr
    others = np.asarray([p for p in range(tr.n_ports) if p != int(dst)],
                        np.int64)
    if others.size == 0:
        raise IngestError("incast: no source ports besides dst")
    senders = rng.choice(others, size=min(int(n_senders), others.size),
                         replace=False)
    window = float(window_s) if window_s is not None else max(
        tr.duration_s * 0.02, 1e-9)
    start = tr.time_s.min() + float(t_frac) * tr.duration_s
    times = start + rng.uniform(0.0, window, int(n_packets))
    src = senders[rng.integers(senders.size, size=int(n_packets))]
    return Trace(
        tr.name,
        np.concatenate([tr.time_s, times]),
        np.concatenate([tr.src, src.astype(np.int32)]),
        np.concatenate([tr.dst, np.full(int(n_packets), int(dst), np.int32)]),
        np.concatenate([tr.payload_bytes,
                        np.full(int(n_packets), int(payload_bytes), np.int64)]),
        tr.n_ports, tr.link_gbps)


def _stage_zipf_drift(tr: Trace, rng: np.random.Generator, *,
                      alpha: float = 1.2, frac: float = 0.5,
                      n_phases: int = 4) -> Trace:
    """Zipf popularity drift: a ``frac`` subset of packets is redirected to
    Zipf-popular destinations, and the popularity *ranking permutes* between
    ``n_phases`` time phases — hot destinations move mid-trace, defeating
    any single static hot-port assumption."""
    m = len(tr)
    if m == 0 or tr.n_ports < 2:
        return tr
    ranks = np.arange(1, tr.n_ports + 1, dtype=np.float64)
    probs = ranks ** -float(alpha)
    probs /= probs.sum()
    touched = rng.random(m) < float(frac)
    phase = np.zeros(m, np.int64)
    if tr.duration_s > 0:
        phase = np.minimum(
            ((tr.time_s - tr.time_s.min()) / tr.duration_s
             * int(n_phases)).astype(np.int64),
            int(n_phases) - 1)
    perms = np.stack([rng.permutation(tr.n_ports)
                      for _ in range(int(n_phases))])
    popular = rng.choice(tr.n_ports, size=m, p=probs)
    dst = tr.dst.copy()
    dst[touched] = perms[phase[touched], popular[touched]].astype(np.int32)
    return Trace(tr.name, tr.time_s, tr.src, dst, tr.payload_bytes,
                 tr.n_ports, tr.link_gbps)


def _stage_diurnal(tr: Trace, rng: np.random.Generator, *,
                   periods: float = 2.0, depth: float = 0.5) -> Trace:
    """Diurnal load: an order-preserving time warp that bunches arrivals at
    sinusoidal load peaks (``periods`` cycles over the trace, modulation
    depth in [0, 1)) — same packets, bursty-on-schedule arrival process."""
    if not 0 <= depth < 1:
        raise IngestError(f"diurnal depth must be in [0, 1), got {depth}")
    m = len(tr)
    if m == 0 or tr.duration_s == 0:
        return tr
    t0, dur = tr.time_s.min(), tr.duration_s
    u = (tr.time_s - t0) / dur                       # normalised [0, 1]
    w = 2.0 * math.pi * float(periods)
    # inverse-intensity warp of rate(u) = 1 + depth*sin(w u): cumulative
    # Λ(u) = u + depth/w (1 − cos(w u)), rescaled back onto the span
    lam = u + float(depth) / w * (1.0 - np.cos(w * u))
    lam_end = 1.0 + float(depth) / w * (1.0 - math.cos(w))
    t = t0 + lam / lam_end * dur
    return Trace(tr.name, t, tr.src, tr.dst, tr.payload_bytes,
                 tr.n_ports, tr.link_gbps)


#: stage registry: kind -> fn(trace, rng, **params).  filter/remap/rescale/
#: clip shape the capture; incast/zipf_drift/diurnal are generative stressors.
STAGES: Dict[str, Callable[..., Trace]] = {
    "filter": _stage_filter,
    "remap_ports": _stage_remap_ports,
    "rescale_time": _stage_rescale_time,
    "clip": _stage_clip,
    "incast": _stage_incast,
    "zipf_drift": _stage_zipf_drift,
    "diurnal": _stage_diurnal,
}


# --------------------------------------------------------------------------
# pipeline
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Stage:
    """One declarative transform: a registry kind plus its parameters."""

    kind: str
    params: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        if self.kind not in STAGES:
            raise IngestError(f"unknown stage {self.kind!r}; "
                              f"known: {sorted(STAGES)}")

    def apply(self, tr: Trace, rng: np.random.Generator) -> Trace:
        try:
            return STAGES[self.kind](tr, rng, **self.params)
        except TypeError as e:
            raise IngestError(f"stage {self.kind!r}: {e}") from e

    def to_dict(self) -> Dict[str, Any]:
        return {"kind": self.kind, "params": dict(self.params)}

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "Stage":
        return cls(kind=d["kind"], params=dict(d.get("params", {})))


@dataclasses.dataclass(frozen=True)
class Pipeline:
    """An ordered, serializable stage composition.

    Application order is tuple order — composition is order-deterministic by
    construction.  Stage i draws randomness from
    ``np.random.default_rng([seed, i])``: independent of every other stage's
    consumption, so inserting a deterministic stage never shifts a
    stressor's stream, and one ``seed`` reproduces the pipeline exactly."""

    stages: Tuple[Stage, ...] = ()
    seed: int = 0

    def then(self, kind: str, **params) -> "Pipeline":
        return dataclasses.replace(
            self, stages=self.stages + (Stage(kind, params),))

    def apply(self, tr: Trace) -> Trace:
        for i, stage in enumerate(self.stages):
            tr = stage.apply(tr, np.random.default_rng([int(self.seed), i]))
        return tr

    def to_dict(self) -> Dict[str, Any]:
        return {"seed": int(self.seed),
                "stages": [s.to_dict() for s in self.stages]}

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "Pipeline":
        return cls(stages=tuple(Stage.from_dict(s)
                                for s in d.get("stages", [])),
                   seed=int(d.get("seed", 0)))


# --------------------------------------------------------------------------
# entry point
# --------------------------------------------------------------------------

def ingest(path, *, pipeline: Optional[Pipeline] = None,
           name: Optional[str] = None, n_ports: Optional[int] = None,
           link_gbps: float = 100.0) -> Trace:
    """Capture file → simulation-ready ``Trace``.

    Dispatches on content (pcap magic) falling back to suffix, applies the
    pipeline, and validates the result is non-empty and addressable."""
    p = Path(path)
    try:
        head = p.open("rb").read(4)
    except OSError as e:
        raise IngestError(f"cannot read {p}: {e}") from e
    is_pcap = (len(head) == 4
               and (struct.unpack("<I", head)[0] in _PCAP_MAGICS
                    or struct.unpack(">I", head)[0] in _PCAP_MAGICS))
    if is_pcap or p.suffix.lower() in (".pcap", ".cap"):
        tr = read_pcap(p, name=name, n_ports=n_ports, link_gbps=link_gbps)
    elif p.suffix.lower() in (".csv", ".txt", ""):
        tr = read_csv(p, name=name, n_ports=n_ports, link_gbps=link_gbps)
    else:
        raise IngestError(f"{p}: unrecognised capture format "
                          f"(need .pcap/.cap or .csv)")
    if pipeline is not None:
        tr = pipeline.apply(tr)
    if len(tr) == 0:
        raise IngestError(f"{p}: pipeline produced an empty trace")
    return tr
