"""Trace substrate: the packet-trace datatype shared by simulators and DSE."""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

__all__ = ["Trace", "merge", "poisson_times", "burst_times"]


@dataclasses.dataclass
class Trace:
    """A packet trace: parallel arrays sorted by time."""

    name: str
    time_s: np.ndarray        # float64 [n]
    src: np.ndarray           # int32 [n] source port/host
    dst: np.ndarray           # int32 [n] destination port/host
    payload_bytes: np.ndarray # int64 [n]
    n_ports: int
    link_gbps: float = 100.0

    def __post_init__(self):
        order = np.argsort(self.time_s, kind="stable")
        self.time_s = np.asarray(self.time_s, np.float64)[order]
        self.src = np.asarray(self.src, np.int32)[order]
        self.dst = np.asarray(self.dst, np.int32)[order]
        self.payload_bytes = np.asarray(self.payload_bytes, np.int64)[order]

    def __len__(self) -> int:
        return int(self.time_s.size)

    @property
    def duration_s(self) -> float:
        return float(self.time_s.max() - self.time_s.min()) if len(self) > 1 else 0.0

    def offered_gbps(self, header_bytes: int = 0) -> float:
        bits = float((self.payload_bytes + header_bytes).sum() * 8)
        return bits / max(self.duration_s, 1e-12) / 1e9

    def head(self, n: int) -> "Trace":
        return Trace(self.name, self.time_s[:n], self.src[:n], self.dst[:n],
                     self.payload_bytes[:n], self.n_ports, self.link_gbps)

    # ------------------------------------------------------------ persistence
    def save(self, path) -> None:
        """Write the trace as a ``.npz`` archive (lossless, pickle-free).

        Saved traces are what ``repro.api.TraceSpec(path=...)`` references, so
        a captured trace is a first-class scenario input alongside the named
        generators."""
        np.savez_compressed(
            path,
            time_s=self.time_s, src=self.src, dst=self.dst,
            payload_bytes=self.payload_bytes,
            meta_name=np.asarray(self.name),
            meta_n_ports=np.asarray(self.n_ports, np.int64),
            meta_link_gbps=np.asarray(self.link_gbps, np.float64),
        )

    @classmethod
    def load(cls, path) -> "Trace":
        """Inverse of :meth:`save`; round-trips every field exactly."""
        with np.load(path, allow_pickle=False) as z:
            return cls(
                name=str(z["meta_name"][()]),
                time_s=z["time_s"], src=z["src"], dst=z["dst"],
                payload_bytes=z["payload_bytes"],
                n_ports=int(z["meta_n_ports"][()]),
                link_gbps=float(z["meta_link_gbps"][()]),
            )


def merge(name: str, traces, n_ports: int, link_gbps: float = 100.0) -> Trace:
    """Combine per-port-domain sub-traces into one trace.

    The inputs must be one consistent capture: every sub-trace on the same
    link rate (service times and hop composition are priced against a single
    ``link_gbps``) and on *disjoint* port ids (two sub-traces claiming the
    same endpoint would silently interleave two hosts' traffic into one) —
    both are validated here because multi-hop fabric composition depends on
    them.
    """
    traces = list(traces)
    if not traces:
        raise ValueError("merge() needs at least one trace")
    for t in traces:
        if t.link_gbps != link_gbps:
            raise ValueError(
                f"merge link_gbps mismatch: merged trace declares "
                f"{link_gbps} Gbps but input {t.name!r} carries "
                f"{t.link_gbps} Gbps — resample one side first")
    owner: dict = {}
    for t in traces:
        ports = np.union1d(np.unique(t.src), np.unique(t.dst))
        for p in ports:
            p = int(p)
            if p in owner and owner[p] != t.name:
                raise ValueError(
                    f"merge port overlap: port id {p} appears in both "
                    f"{owner[p]!r} and {t.name!r} — merged sub-traces must "
                    f"cover disjoint port ids")
            owner[p] = t.name
        if ports.size and int(ports.max()) >= n_ports:
            raise ValueError(
                f"merge port out of range: input {t.name!r} uses port id "
                f"{int(ports.max())} but the merged trace declares "
                f"n_ports={n_ports}")
    return Trace(
        name=name,
        time_s=np.concatenate([t.time_s for t in traces]),
        src=np.concatenate([t.src for t in traces]),
        dst=np.concatenate([t.dst for t in traces]),
        payload_bytes=np.concatenate([t.payload_bytes for t in traces]),
        n_ports=n_ports,
        link_gbps=link_gbps,
    )


def poisson_times(rng: np.random.Generator, rate_pps: float, duration_s: float) -> np.ndarray:
    n = rng.poisson(rate_pps * duration_s)
    return np.sort(rng.uniform(0.0, duration_s, size=n))


def burst_times(
    rng: np.random.Generator,
    burst_rate_ps: float,
    duration_s: float,
    burst_len_mean: float,
    intra_gap_s: float,
) -> np.ndarray:
    """Poisson bursts of geometric length with fixed intra-burst spacing."""
    starts = poisson_times(rng, burst_rate_ps, duration_s)
    out = []
    for s in starts:
        blen = 1 + rng.geometric(1.0 / max(burst_len_mean, 1.0))
        out.append(s + np.arange(blen) * intra_gap_s)
    if not out:
        return np.zeros(0)
    return np.sort(np.concatenate(out))
