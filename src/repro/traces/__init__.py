"""Workload traces (paper SS V-A) + the Trace datatype + capture ingestion."""
from .base import Trace, merge
from .ingest import (IngestError, Pipeline, STAGES, Stage, ingest, read_csv,
                     read_pcap, write_pcap)
from .workloads import WORKLOADS, datacenter, hft, industry, rl_allreduce, underwater, uniform

__all__ = ["IngestError", "Pipeline", "STAGES", "Stage", "Trace", "WORKLOADS",
           "datacenter", "hft", "industry", "ingest", "merge", "read_csv",
           "read_pcap", "rl_allreduce", "underwater", "uniform", "write_pcap"]
