"""Workload traces (paper SS V-A) + the Trace datatype."""
from .base import Trace, merge
from .workloads import WORKLOADS, datacenter, hft, industry, rl_allreduce, underwater, uniform

__all__ = ["Trace", "WORKLOADS", "datacenter", "hft", "industry", "merge",
           "rl_allreduce", "underwater", "uniform"]
