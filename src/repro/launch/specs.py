"""ShapeDtypeStruct stand-ins + shardings for every dry-run cell.

``input_specs(cfg, shape, plan, mesh)`` returns abstract (no-allocation)
descriptions of every input of the lowered step: the training batch for
``train_*``, the request batch for ``prefill``, and (token, KV-cache/SSM
state) for ``decode``.  Modality frontends are stubs per the assignment:
``[vlm]``/``[audio]`` cells get precomputed patch/frame embeddings.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.shapes import ShapeSpec
from repro.models import transformer as T
from repro.models.config import ModelConfig, ShardingPlan

__all__ = ["input_specs", "batch_specs", "abstract_params", "sharding_tree", "div_axes"]


def _sanitize_spec(shape, spec: P, mesh) -> P:
    """Drop axis entries whose mesh extent does not divide the dim size."""
    entries = tuple(spec) + (None,) * (len(shape) - len(tuple(spec)))
    out = []
    for dim, ax in zip(shape, entries):
        if ax is None:
            out.append(None)
            continue
        axes = (ax,) if isinstance(ax, str) else tuple(ax)
        total = 1
        for a in axes:
            total *= mesh.shape[a]
        out.append(ax if (dim % total == 0 and dim >= total) else None)
    return P(*out)


def sharding_tree(mesh, specs, structs=None):
    """Specs -> NamedShardings; with `structs`, indivisible dims fall back to
    replication (e.g. vocab 50280 on a 16-way tensor axis)."""
    if structs is None:
        return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                            is_leaf=lambda x: isinstance(x, P))
    return jax.tree.map(
        lambda st, s: NamedSharding(mesh, _sanitize_spec(st.shape, s, mesh)),
        structs, specs, is_leaf=lambda x: isinstance(x, (P, jax.ShapeDtypeStruct)))


def div_axes(size: int, axes: Tuple[str, ...], mesh) -> Any:
    """Use the dp axes for a dim only if the size divides; else replicate."""
    total = 1
    for a in axes:
        total *= mesh.shape[a]
    if size % total == 0 and size >= total:
        return axes if len(axes) > 1 else axes[0]
    return None


def batch_specs(cfg: ModelConfig, shape: ShapeSpec, plan: ShardingPlan, mesh):
    """(ShapeDtypeStruct tree, PartitionSpec tree) for one data batch."""
    b, s = shape.global_batch, shape.seq_len
    dp = div_axes(b, tuple(plan.dp_axes), mesh)
    structs: Dict[str, Any] = {}
    specs: Dict[str, Any] = {}
    if shape.kind == "train":
        structs["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        specs["labels"] = P(dp, None)
    if cfg.frontend == "tokens":
        structs["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        specs["tokens"] = P(dp, None)
    else:
        structs["embeddings"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.bfloat16)
        specs["embeddings"] = P(dp, None, None)
    if cfg.mrope:
        structs["positions3"] = jax.ShapeDtypeStruct((b, 3, s), jnp.int32)
        specs["positions3"] = P(dp, None, None)
    return structs, specs


def abstract_params(cfg: ModelConfig, plan: ShardingPlan):
    """(param ShapeDtypeStructs, PartitionSpec tree) without allocation."""
    captured = {}

    def f(k):
        params, specs = T.init_params(k, cfg, plan)
        captured["specs"] = specs      # concrete P objects, captured at trace time
        return params

    shapes = jax.eval_shape(f, jax.random.PRNGKey(0))
    return shapes, captured["specs"]


def decode_state_specs(cfg: ModelConfig, plan: ShardingPlan, mesh, shape: ShapeSpec):
    """Abstract decode state + shardings (divisibility-aware)."""
    state_struct, specs = T.decode_state_structs(cfg, plan, shape.global_batch,
                                                 shape.seq_len)
    # fix up divisibility: any dim the default spec shards must divide
    fixed = {}
    for k, spec in specs.items():
        arr = state_struct[k]
        new = []
        for dim, ax in enumerate(tuple(spec) + (None,) * (arr.ndim - len(tuple(spec)))):
            if ax is None:
                new.append(None)
                continue
            axes = (ax,) if isinstance(ax, str) else tuple(ax)
            total = 1
            for a in axes:
                total *= mesh.shape[a]
            new.append(ax if arr.shape[dim] % total == 0 and arr.shape[dim] >= total else None)
        fixed[k] = P(*new)
    return state_struct, fixed


def input_specs(cfg: ModelConfig, shape: ShapeSpec, plan: ShardingPlan, mesh,
                *, opt=None) -> Dict[str, Any]:
    """Everything the dry-run needs to lower one cell."""
    params_s, params_spec = abstract_params(cfg, plan)
    out: Dict[str, Any] = {
        "params": params_s,
        "params_spec": params_spec,
    }
    if shape.kind == "train":
        batch_s, batch_spec = batch_specs(cfg, shape, plan, mesh)
        out.update(batch=batch_s, batch_spec=batch_spec)
        if opt is not None:
            opt_s = jax.eval_shape(opt.init, params_s)
            out["opt_state"] = opt_s
            out["opt_spec"] = opt.state_specs(params_spec)
    elif shape.kind == "prefill":
        batch_s, batch_spec = batch_specs(cfg, shape, plan, mesh)
        out.update(batch=batch_s, batch_spec=batch_spec)
    else:  # decode / long_decode
        b = shape.global_batch
        dp = div_axes(b, tuple(plan.dp_axes), mesh)
        if cfg.frontend == "tokens":
            out["tok"] = jax.ShapeDtypeStruct((b, 1), jnp.int32)
            out["tok_spec"] = P(dp, None)
        else:
            out["tok"] = jax.ShapeDtypeStruct((b, 1, cfg.d_model), jnp.bfloat16)
            out["tok_spec"] = P(dp, None, None)
        state_s, state_spec = decode_state_specs(cfg, plan, mesh, shape)
        out["state"] = state_s
        out["state_spec"] = state_spec
    return out
