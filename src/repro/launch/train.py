"""Production training driver.

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --smoke \\
        --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/run1

On a real TPU fleet the same entry point runs under the production mesh
(--mesh single|multi); on CPU use --smoke (reduced config, 1×1 mesh).
"""

import argparse
import sys
import tempfile
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_smoke
from repro.data import DataConfig, SyntheticLM
from repro.models import transformer as T
from repro.models.moe import MoEOptions
from repro.runtime import Supervisor
from repro.train import TrainSpec, adafactor, adamw, make_train_step
from .mesh import make_production_mesh, make_smoke_mesh, plan_for_mesh


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config on a 1x1 mesh (CPU)")
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--optimizer", choices=["adamw", "adafactor"], default="adamw")
    ap.add_argument("--moe-payload", choices=["bf16", "int8"], default="bf16")
    ap.add_argument("--compress-pod-grads", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    args = ap.parse_args(argv)

    if args.smoke:
        cfg = get_smoke(args.arch)
        mesh = make_smoke_mesh()
    else:
        cfg = get_config(args.arch)
        mesh = make_production_mesh(multi_pod=args.mesh == "multi")
    plan = plan_for_mesh(mesh)
    print(f"arch={cfg.name} params={cfg.param_count()/1e6:.0f}M mesh={dict(mesh.shape)}")

    params, _ = T.init_params(jax.random.PRNGKey(0), cfg, plan)
    opt = adamw(lr=args.lr) if args.optimizer == "adamw" else adafactor(lr=args.lr)
    spec = TrainSpec(microbatches=args.microbatches, lr=args.lr,
                     warmup_steps=max(args.steps // 20, 2), total_steps=args.steps,
                     moe_opts=MoEOptions(payload=args.moe_payload,
                                         capacity_factor=cfg.capacity_factor),
                     compress_pod_grads=args.compress_pod_grads)
    step = jax.jit(make_train_step(cfg, plan, mesh, opt, spec))
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                                  global_batch=args.batch,
                                  frontend=cfg.frontend, d_model=cfg.d_model,
                                  mrope=cfg.mrope))

    def step_fn(state, i):
        p, o = state
        batch = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
        p, o, m = step(p, o, batch, jnp.asarray(i))
        return (p, o), m

    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="repro_train_")
    sup = Supervisor(ckpt_dir, ckpt_every=args.ckpt_every)
    t0 = time.time()
    res = sup.run((params, opt.init(params)), step_fn, total_steps=args.steps)
    losses = [h["loss"] for h in res.metrics_history]
    print(f"{res.final_step} steps in {time.time()-t0:.0f}s; "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}; ckpts in {ckpt_dir}")


if __name__ == "__main__":
    main()
