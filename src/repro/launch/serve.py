"""Production serving driver: continuous batching over the decode step.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --smoke \\
        --requests 8 --slots 4
"""

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, get_smoke
from repro.models import transformer as T
from repro.serve import Request, ServeEngine
from .mesh import make_production_mesh, make_smoke_mesh, plan_for_mesh


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--s-max", type=int, default=256)
    args = ap.parse_args(argv)

    if args.smoke:
        cfg = get_smoke(args.arch)
        mesh = make_smoke_mesh()
    else:
        cfg = get_config(args.arch)
        mesh = make_production_mesh(multi_pod=args.mesh == "multi")
    plan = plan_for_mesh(mesh)
    params, _ = T.init_params(jax.random.PRNGKey(0), cfg, plan)
    eng = ServeEngine(cfg, plan, mesh, params, slots=args.slots, s_max=args.s_max)

    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, rng.integers(4, 12))
                    .astype(np.int32), max_new=args.max_new)
            for i in range(args.requests)]
    for r in reqs:
        eng.submit(r)
    t0 = time.time()
    ticks = 0
    while (eng._queue or eng._active) and ticks < 100_000:
        eng.step()
        ticks += 1
    dt = time.time() - t0
    toks = sum(len(r.out) for r in reqs)
    print(f"served {sum(r.done for r in reqs)}/{len(reqs)} requests, "
          f"{toks} tokens, {ticks} ticks, {dt:.1f}s")


if __name__ == "__main__":
    main()
