"""Production meshes.  A function, not a constant — importing this module
never touches jax device state.

Also home of :class:`MeshSpec`, the serializable description of how the DSE
hot path (stage-2 batched surrogate, stage-4 batched netsim) shards its
candidate axis across devices, plus the pad/unpad helpers that make any
batch size divisible by the mesh extent.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import numpy as np

__all__ = ["compat_make_mesh", "make_production_mesh", "plan_for_mesh",
           "N_DEVICES", "MeshSpec", "padded_size", "shard_pad", "shard_unpad"]

N_DEVICES = {"single": 256, "multi": 512}


def _validate_mesh_shape(shape, axes):
    """Raise (naming the numbers) instead of building a wrong-shaped mesh."""
    for extent, name in zip(shape, axes):
        if extent < 1:
            raise ValueError(
                f"mesh axis {name!r} has extent {extent}; every axis needs "
                f"extent >= 1 (shape={tuple(shape)})")
    needed = math.prod(shape)
    available = jax.device_count()
    if needed > available:
        raise ValueError(
            f"mesh shape {tuple(shape)} needs {needed} devices but only "
            f"{available} are available (set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={needed} "
            f"to simulate more on CPU)")


def compat_make_mesh(shape, axes):
    """``jax.make_mesh`` with Auto axis types across JAX versions.

    ``axis_types=`` / ``jax.sharding.AxisType`` only exist on newer releases;
    older ones (0.4.x) behave as Auto everywhere, which is what we want.
    Raises ``ValueError`` (with both numbers named) for zero-extent axes or
    shapes larger than the available device count instead of letting jax
    build a sharding that silently misassigns data."""
    _validate_mesh_shape(shape, axes)
    try:
        return jax.make_mesh(shape, axes,
                             axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    except (AttributeError, TypeError):
        return jax.make_mesh(shape, axes)


# --------------------------------------------------------------------------
# MeshSpec: serializable sharding request for the DSE hot path
# --------------------------------------------------------------------------

#: (scenario_axis, devices) -> jax Mesh; meshes are hashable jit keys, so a
#: stable identity per process keeps the sharded-engine jit caches warm.
_MESH_CACHE: dict = {}


@dataclasses.dataclass(frozen=True)
class MeshSpec:
    """How to shard the DSE candidate axis across the device mesh.

    ``devices`` is the candidate-axis extent (``--devices N`` on the CLI);
    ``scenario_axis`` is a second, data-parallel axis campaigns use to spread
    scenario groups.  The candidate batch is sharded over *both* axes (a
    campaign's concatenated per-scenario blocks land on different device
    groups), so the total shard count is ``devices * scenario_axis``.

    The spec is plain data — safe to serialize into scenario dicts and
    checkpoint manifests — and deliberately *not* part of ``SearchSpec``:
    search state is mesh-agnostic, which is what lets a checkpoint written
    on N devices resume bit-identically on M (see ``runtime/elastic.py``).
    """

    devices: int = 1
    scenario_axis: int = 1

    def __post_init__(self):
        if self.devices < 1:
            raise ValueError(
                f"MeshSpec candidate axis has size {self.devices}; "
                f"need >= 1 device")
        if self.scenario_axis < 1:
            raise ValueError(
                f"MeshSpec scenario axis has size {self.scenario_axis}; "
                f"need >= 1")

    @property
    def shard_axis(self) -> int:
        """Total candidate-axis shard count (both mesh axes combined)."""
        return self.devices * self.scenario_axis

    def is_single(self) -> bool:
        """True when this spec is the serial single-device path."""
        return self.shard_axis == 1

    def build(self):
        """The (cached) jax Mesh: shape (scenario_axis, devices)."""
        key = (self.scenario_axis, self.devices)
        mesh = _MESH_CACHE.get(key)
        if mesh is None:
            mesh = compat_make_mesh(key, ("scenario", "cand"))
            _MESH_CACHE[key] = mesh
        return mesh

    def to_dict(self) -> dict:
        return {"devices": self.devices, "scenario_axis": self.scenario_axis}

    @classmethod
    def from_dict(cls, d: dict) -> "MeshSpec":
        return cls(devices=int(d.get("devices", 1)),
                   scenario_axis=int(d.get("scenario_axis", 1)))

    @classmethod
    def coerce(cls, value) -> Optional["MeshSpec"]:
        """None | int | dict | MeshSpec -> Optional[MeshSpec]."""
        if value is None or isinstance(value, cls):
            return value
        if isinstance(value, int):
            return cls(devices=value)
        if isinstance(value, dict):
            return cls.from_dict(value)
        raise TypeError(f"cannot build a MeshSpec from {value!r}")


def padded_size(n: int, k: int) -> int:
    """Smallest multiple of ``k`` that is >= ``n`` (>= ``k`` when n == 0)."""
    if k < 1:
        raise ValueError(f"shard count {k} must be >= 1")
    return k * max(1, -(-n // k))


def shard_pad(a: np.ndarray, k: int, axis: int = 0) -> np.ndarray:
    """Pad ``a`` along ``axis`` to a multiple of ``k`` by replicating row 0.

    Pad rows are throwaway duplicates of an existing candidate: every engine
    scan is rowwise-independent, so they cannot perturb real rows, and the
    host strips them with :func:`shard_unpad` before pricing — a padded run
    is bit-identical to the unpadded one."""
    n = a.shape[axis]
    pad = padded_size(n, k) - n
    if pad == 0:
        return a
    fill = np.repeat(np.take(a, [0], axis=axis), pad, axis=axis)
    return np.concatenate([np.asarray(a), fill], axis=axis)


def shard_unpad(a, n: int, axis: int = 0):
    """Strip pad rows: the first ``n`` entries of ``a`` along ``axis``."""
    index = (slice(None),) * axis + (slice(0, n),)
    return a[index]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_make_mesh(shape, axes)


def make_smoke_mesh(data: int = 1, model: int = 1):
    return compat_make_mesh((data, model), ("data", "model"))


def plan_for_mesh(mesh):
    from repro.models.config import MULTI_POD_PLAN, SINGLE_POD_PLAN
    return MULTI_POD_PLAN if "pod" in mesh.axis_names else SINGLE_POD_PLAN
