"""Production meshes.  A function, not a constant — importing this module
never touches jax device state."""

from __future__ import annotations

import jax

__all__ = ["compat_make_mesh", "make_production_mesh", "plan_for_mesh", "N_DEVICES"]

N_DEVICES = {"single": 256, "multi": 512}


def compat_make_mesh(shape, axes):
    """``jax.make_mesh`` with Auto axis types across JAX versions.

    ``axis_types=`` / ``jax.sharding.AxisType`` only exist on newer releases;
    older ones (0.4.x) behave as Auto everywhere, which is what we want."""
    try:
        return jax.make_mesh(shape, axes,
                             axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    except (AttributeError, TypeError):
        return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_make_mesh(shape, axes)


def make_smoke_mesh(data: int = 1, model: int = 1):
    return compat_make_mesh((data, model), ("data", "model"))


def plan_for_mesh(mesh):
    from repro.models.config import MULTI_POD_PLAN, SINGLE_POD_PLAN
    return MULTI_POD_PLAN if "pod" in mesh.axis_names else SINGLE_POD_PLAN
