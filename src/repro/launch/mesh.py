"""Production meshes.  A function, not a constant — importing this module
never touches jax device state."""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "plan_for_mesh", "N_DEVICES"]

N_DEVICES = {"single": 256, "multi": 512}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_smoke_mesh(data: int = 1, model: int = 1):
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


def plan_for_mesh(mesh):
    from repro.models.config import MULTI_POD_PLAN, SINGLE_POD_PLAN
    return MULTI_POD_PLAN if "pod" in mesh.axis_names else SINGLE_POD_PLAN
