import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × shape × mesh)
cell and extract memory / cost / collective statistics.

The two lines above MUST stay first: jax locks the device count at first
initialisation, and the production meshes need 512 host placeholder devices.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both --out experiments/dryrun
"""

import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, all_arch_names, get_config, shapes_for
from repro.models.moe import MoEOptions
from repro.train.optimizer import adafactor, adamw
from repro.train.train_step import TrainSpec, make_train_step
from repro.models import transformer as T
from .mesh import make_production_mesh, plan_for_mesh
from .roofline import (collective_bytes, derive_terms, model_flops,
                       structural_memory_bytes)
from .specs import input_specs, sharding_tree


def choose_optimizer(cfg, name: Optional[str] = None):
    """fp32 Adam fits every arch except the 1T MoE → factored states there."""
    if name is None:
        name = "adafactor" if cfg.param_count() > 3e11 else "adamw"
    if name == "adafactor":
        return adafactor(lr=1e-3), "adafactor"
    if name == "adamw_bf16":
        import jax.numpy as _jnp
        return adamw(lr=3e-4, state_dtype=_jnp.bfloat16), "adamw_bf16"
    return adamw(lr=3e-4), "adamw"


def choose_microbatches(cfg, shape, mesh) -> int:
    if shape.kind != "train":
        return 1
    dp = 1
    for a in mesh.axis_names:
        if a != "model":
            dp *= mesh.shape[a]
    tokens_per_device = shape.global_batch * shape.seq_len // dp
    mb = max(1, tokens_per_device // 16384)
    while shape.global_batch % (mb * dp) and mb > 1:   # µb batch must shard
        mb -= 1
    return mb


def _lower_one(cfg, shape, plan, mesh, opt, moe_opts, microbatches,
               train_spec_overrides=None):
    """Build + lower + compile the jitted step for one concrete config."""
    spec = input_specs(cfg, shape, plan, mesh, opt=opt)
    if shape.kind == "train":
        tspec = TrainSpec(microbatches=microbatches, moe_opts=moe_opts,
                          **(train_spec_overrides or {}))
        step_fn = make_train_step(cfg, plan, mesh, opt, tspec)
        p_sh = sharding_tree(mesh, spec["params_spec"], spec["params"])
        o_sh = sharding_tree(mesh, spec["opt_spec"], spec["opt_state"])
        in_sh = (p_sh, o_sh, sharding_tree(mesh, spec["batch_spec"]), None)
        out_sh = (p_sh, o_sh, None)
        jitted = jax.jit(step_fn, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=(0, 1))
        return jitted.lower(spec["params"], spec["opt_state"], spec["batch"],
                            jax.ShapeDtypeStruct((), jnp.int32))
    if shape.kind == "prefill":
        def fn(params, batch):
            return T.prefill(params, cfg, plan, mesh, batch, moe_opts=moe_opts)
        in_sh = (sharding_tree(mesh, spec["params_spec"], spec["params"]),
                 sharding_tree(mesh, spec["batch_spec"]))
        jitted = jax.jit(fn, in_shardings=in_sh)
        return jitted.lower(spec["params"], spec["batch"])

    def fn(params, state, tok):
        return T.decode_step(params, cfg, plan, mesh, state, tok,
                             moe_opts=moe_opts)
    in_sh = (sharding_tree(mesh, spec["params_spec"], spec["params"]),
             sharding_tree(mesh, spec["state_spec"]),
             sharding_tree(mesh, spec["tok_spec"]))
    out_sh = (sharding_tree(mesh, spec["state_spec"]), None)
    jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                     donate_argnums=(1,))
    return jitted.lower(spec["params"], spec["state"], spec["tok"])


def _extrapolated_cost(cfg, shape, plan, mesh, opt, moe_opts,
                       train_spec_overrides=None):
    """cost_analysis counts scan bodies once; lower L=1 and L=2 variants and
    extrapolate linearly.  The variants unroll every loop (python-loop layers,
    fully-unrolled blockwise attention, microbatch=1) so the body is counted
    exactly L times; token counts match the real step, so per-layer FLOPs
    equal the real per-layer totals."""
    pts = []
    for lyr in (1, 2):
        cfg_l = dataclasses.replace(cfg, n_layers=lyr, scan_layers=False,
                                    attn_unroll=True)
        lowered = _lower_one(cfg_l, shape, plan, mesh, opt, moe_opts, 1,
                             train_spec_overrides)
        compiled = lowered.compile()
        cost = compiled.cost_analysis() or {}
        if isinstance(cost, (list, tuple)):      # jax<=0.4.x: one dict per program
            cost = cost[0] if cost else {}
        coll = collective_bytes(compiled.as_text())
        pts.append((float(cost.get("flops", 0.0)),
                    float(cost.get("bytes accessed", 0.0)), coll))
    l_full = cfg.n_layers
    f = pts[0][0] + (pts[1][0] - pts[0][0]) * (l_full - 1)
    b = pts[0][1] + (pts[1][1] - pts[0][1]) * (l_full - 1)
    coll = {}
    for kind in pts[0][2]:
        c1, c2 = pts[0][2][kind], pts[1][2][kind]
        coll[kind] = {
            "count": int(c1["count"] + (c2["count"] - c1["count"]) * (l_full - 1)),
            "bytes": float(c1["bytes"] + (c2["bytes"] - c1["bytes"]) * (l_full - 1)),
        }
    return {"flops": f, "bytes accessed": b}, coll


def lower_cell(
    arch: str,
    shape_name: str,
    *,
    multi_pod: bool = False,
    moe_opts: Optional[MoEOptions] = None,
    train_spec_overrides: Optional[Dict] = None,
    plan_overrides: Optional[Dict] = None,
    optimizer: Optional[str] = None,
    cfg_overrides: Optional[Dict] = None,
    verbose: bool = True,
    extrapolate: bool = True,
) -> Dict[str, Any]:
    """Lower + compile one cell; return the §Dry-run/§Roofline record."""
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    plan = plan_for_mesh(mesh)
    if plan_overrides:
        plan = dataclasses.replace(plan, **plan_overrides)
    n_chips = 1
    for a in mesh.axis_names:
        n_chips *= mesh.shape[a]
    opt, opt_name = choose_optimizer(cfg, optimizer)
    moe_opts = moe_opts or MoEOptions.from_config(cfg)
    mb = 1
    extra = {}
    if shape.kind == "train":
        mb = (train_spec_overrides or {}).get("microbatches") or \
            choose_microbatches(cfg, shape, mesh)
        if train_spec_overrides:
            train_spec_overrides = {k: v for k, v in train_spec_overrides.items()
                                    if k != "microbatches"}
        extra = {"optimizer": opt_name, "microbatches": mb}
    t0 = time.time()
    lowered = _lower_one(cfg, shape, plan, mesh, opt, moe_opts, mb,
                         train_spec_overrides)
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    if extrapolate:
        cost, coll = _extrapolated_cost(cfg, shape, plan, mesh, opt, moe_opts,
                                        train_spec_overrides)
    else:
        cost = compiled.cost_analysis() or {}
        coll = collective_bytes(compiled.as_text())
    mem_struct = structural_memory_bytes(cfg, shape, dict(mesh.shape), opt_name)
    terms = derive_terms(cost, coll, model_flops_global=model_flops(cfg, shape),
                         n_chips=n_chips, memory_bytes=mem_struct)

    mem_rec = {
        "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
        "output_bytes": getattr(mem, "output_size_in_bytes", None),
        "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
        "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
        "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
    }
    live = (mem_rec["argument_bytes"] or 0) + (mem_rec["temp_bytes"] or 0) \
        + (mem_rec["output_bytes"] or 0) - (mem_rec["alias_bytes"] or 0)
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": n_chips,
        "kind": shape.kind,
        "lower_time_s": round(t_lower, 1),
        "compile_time_s": round(t_compile, 1),
        "memory": mem_rec,
        "bytes_per_device_live": live,
        "fits_16gb": bool(live <= 16e9),
        "cost": {k: cost.get(k) for k in ("flops", "bytes accessed") if k in cost},
        "memory_bytes_structural": mem_struct,
        "memory_bytes_unfused_upper": cost.get("bytes accessed"),
        "collectives": coll,
        "roofline": terms.as_dict(),
        **extra,
    }
    if verbose:
        print(f"[{arch} × {shape_name} × {rec['mesh']}] "
              f"lower {t_lower:.0f}s compile {t_compile:.0f}s | "
              f"live {live/1e9:.2f} GB/dev (fits16GB={rec['fits_16gb']}) | "
              f"compute {terms.compute_s*1e3:.2f}ms mem {terms.memory_s*1e3:.2f}ms "
              f"coll {terms.collective_s*1e3:.2f}ms -> {terms.dominant}-bound | "
              f"useful-flops {terms.useful_flops_ratio:.2f} "
              f"roofline {terms.roofline_fraction:.2%}")
        print("  memory_analysis:", {k: v for k, v in mem_rec.items() if v})
        print("  cost_analysis:", rec["cost"])
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    cells = []
    archs = all_arch_names() if (args.all or args.arch is None) else [args.arch]
    for a in archs:
        cfg = get_config(a)
        shapes = [s.name for s in shapes_for(cfg)] if args.shape is None else [args.shape]
        for sh in shapes:
            meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
            for mp in meshes:
                cells.append((a, sh, mp))

    failures = 0
    for a, sh, mp in cells:
        tag = f"{a}_{sh}_{'multi' if mp else 'single'}".replace(".", "_")
        out_path = os.path.join(args.out, tag + ".json")
        if os.path.exists(out_path):
            print(f"skip {tag} (exists)")
            continue
        try:
            rec = lower_cell(a, sh, multi_pod=mp)
            with open(out_path, "w") as f:
                json.dump(rec, f, indent=1)
        except Exception as e:  # noqa: BLE001 — a failing cell is a bug to record
            failures += 1
            print(f"FAIL {tag}: {e}")
            traceback.print_exc()
            with open(out_path + ".fail", "w") as f:
                f.write(traceback.format_exc())
    print(f"done: {len(cells)} cells, {failures} failures")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
