"""Roofline terms from a compiled dry-run artifact.

    compute term    = HLO_FLOPs_per_device / peak_FLOP/s        (197 TF bf16)
    memory term     = HLO_bytes_per_device / HBM_bw             (819 GB/s)
    collective term = collective_bytes_per_device / link_bw     (~50 GB/s/link)

``cost_analysis()`` supplies FLOPs/bytes of the per-device SPMD module;
collective bytes are not in cost_analysis, so we parse the post-optimisation
HLO and sum the result-shape bytes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute (shapes in the SPMD module
are already per-device).  The collective term assumes all traffic serialises
through one 50 GB/s ICI link — a conservative bound; per-axis overlap is a
§Perf lever, not baked into the baseline.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

TPU_V5E = {
    "peak_flops_bf16": 197e12,   # per chip
    "hbm_gbps": 819e9,           # bytes/s
    "ici_link_gbps": 50e9,       # bytes/s per link
}

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(text: str) -> int:
    """Sum bytes of every dtype[shape] token in a result-type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Per-collective-kind {count, bytes} from (lowered or compiled) HLO text."""
    stats = {k: {"count": 0, "bytes": 0} for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        if "=" not in line:
            continue
        lhs, _, rhs = line.partition("=")
        m = re.match(r"\s*\(?[\w.\-]*\)?\s*(.*)", rhs)
        body = rhs.strip()
        for kind in _COLLECTIVES:
            # match the opcode, not tuple-element accessors like get-tuple-element
            if re.search(rf"\b{kind}(-start|-done)?\(", body):
                if kind + "-done(" in body:
                    continue  # bytes counted at -start
                # result type string = text before the opcode
                restype = body.split(kind)[0]
                stats[kind]["count"] += 1
                stats[kind]["bytes"] += _shape_bytes(restype)
                break
    return stats


@dataclasses.dataclass
class RooflineTerms:
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops_per_device: float = 0.0

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        if self.flops_per_device <= 0:
            return 0.0
        return self.model_flops_per_device / self.flops_per_device

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the compute roofline achieved if the step ran at its
        bound: useful model FLOPs / (peak × bound-time)."""
        if self.bound_s <= 0:
            return 0.0
        return self.model_flops_per_device / (TPU_V5E["peak_flops_bf16"] * self.bound_s)

    def as_dict(self):
        return {**dataclasses.asdict(self),
                "bound_s": self.bound_s,
                "useful_flops_ratio": self.useful_flops_ratio,
                "roofline_fraction": self.roofline_fraction}


def structural_memory_bytes(cfg, shape, mesh_shape: Dict[str, int],
                            opt_name: str = "adamw") -> float:
    """Analytic per-device HBM traffic estimate for one step.

    The CPU backend's ``bytes accessed`` counts unfused operand+result bytes
    (~10-20× real fused HBM traffic), so the memory roofline term uses this
    structural model instead; the unfused number is still recorded as an
    upper bound.  Conventions:
      * weights: fwd read + remat re-read + bwd read (bf16) and, for train,
        fp32 grad write+read plus optimizer state read+write,
      * activations: residual-stream in/out per layer ×2 passes + internal
        working tensors of attention/MLP/MoE at their sharded widths,
      * vocab head: logits write + CE read + bwd read at the sharded vocab,
      * decode: all local weights once + full KV-cache/SSM-state read.
    """
    n_chips = 1
    for v in mesh_shape.values():
        n_chips *= v
    tp = mesh_shape.get("model", 1)
    dp = n_chips // tp
    n_params_local = cfg.param_count() / n_chips
    A = 2  # bf16 activation bytes
    d = cfg.d_model
    vocab_shard = cfg.vocab / tp

    if shape.kind in ("train", "prefill"):
        tokens_local = shape.seq_len * shape.global_batch / dp
    else:
        tokens_local = max(shape.global_batch / dp, 1)

    # ---- weights
    if shape.kind == "train":
        per_param = 2 + 2 + 2 + 4 + 4          # fwd, remat, bwd reads + grad w/r
        per_param += {"adamw": 20, "adafactor": 8}.get(opt_name, 20)
    else:
        per_param = 2                           # single fwd read
    weight_bytes = n_params_local * per_param

    # ---- per-layer activations
    passes = 3 if shape.kind == "train" else 1  # fwd, remat-fwd, bwd
    resid = 4 * tokens_local * d * A            # read+write per pass boundary
    internal = 0.0
    if cfg.has_attention:
        heads_w = cfg.n_heads * cfg.hd / tp
        internal += 6 * tokens_local * heads_w * A       # q,o + scores blocks
        internal += 4 * tokens_local * (cfg.n_kv_heads * cfg.hd) * A
        if shape.kind == "prefill" and shape.seq_len >= 8192:
            # blockwise attention re-reads local KV once per q block
            nq = shape.seq_len / 2048
            internal += nq * tokens_local * (cfg.n_kv_heads * cfg.hd) * A * 0.25
    if cfg.has_ssm:
        internal += 8 * tokens_local * (cfg.ssm_inner / tp) * A
        internal += 2 * tokens_local * cfg.ssm_state * A
    if cfg.is_moe:
        ff_w = cfg.d_ff  # expert ff (local expert count × ff / experts ≈ ff per token-slot)
        internal += 2 * cfg.moe_topk * cfg.capacity_factor * tokens_local * d * A * 4
        internal += 2 * cfg.moe_topk * tokens_local * ff_w * A
    elif cfg.d_ff:
        internal += 6 * tokens_local * (cfg.d_ff / tp) * A
    act_bytes = cfg.n_layers * passes * (resid + internal) / 2  # /2: fusion of elementwise pairs

    # ---- vocab head
    head_passes = 10 if shape.kind == "train" else 2
    head_bytes = tokens_local * vocab_shard * head_passes

    # ---- decode state traffic
    state_bytes = 0.0
    if shape.kind in ("decode", "long_decode"):
        b_local = max(shape.global_batch / dp, 1)
        if cfg.has_attention:
            cache_len = min(cfg.sliding_window or shape.seq_len, shape.seq_len) / tp
            state_bytes += cfg.n_layers * b_local * cfg.n_kv_heads * cfg.hd * cache_len * A * 2
        if cfg.has_ssm:
            state_bytes += (cfg.n_layers * b_local * cfg.ssm_heads * cfg.ssm_headdim
                            * cfg.ssm_state * 4 * 2)
    return float(weight_bytes + act_bytes + head_bytes + state_bytes)


def derive_terms(cost: Dict, coll_stats: Dict, *, model_flops_global: float,
                 n_chips: int, memory_bytes: Optional[float] = None,
                 hw: Dict = TPU_V5E) -> RooflineTerms:
    flops = float(cost.get("flops", 0.0))
    byts = float(memory_bytes if memory_bytes is not None
                 else cost.get("bytes accessed", 0.0))
    cbytes = float(sum(v["bytes"] for v in coll_stats.values()))
    compute_s = flops / hw["peak_flops_bf16"]
    memory_s = byts / hw["hbm_gbps"]
    coll_s = cbytes / hw["ici_link_gbps"]
    dom = max((("compute", compute_s), ("memory", memory_s), ("collective", coll_s)),
              key=lambda kv: kv[1])[0]
    return RooflineTerms(
        flops_per_device=flops, bytes_per_device=byts,
        collective_bytes_per_device=cbytes,
        compute_s=compute_s, memory_s=memory_s, collective_s=coll_s,
        dominant=dom, model_flops_per_device=model_flops_global / n_chips)


def model_flops(cfg, shape) -> float:
    """Useful-work convention: 6·N·D train (3 passes), 2·N·D fwd-only; MoE
    uses N_active.  D = tokens processed by the step."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.seq_len * shape.global_batch
    if shape.kind == "prefill":
        return 2.0 * n * shape.seq_len * shape.global_batch
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch
