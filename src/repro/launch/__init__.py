"""Launch layer: production meshes, dry-run, train/serve drivers.

NOTE: do NOT import .dryrun from here — it sets XLA device-count flags at
import time and must only be imported as the top-level entry point.
"""
from .mesh import make_production_mesh, make_smoke_mesh, plan_for_mesh
__all__ = ["make_production_mesh", "make_smoke_mesh", "plan_for_mesh"]
