"""Checkpointing: mesh-agnostic save/restore with async snapshots.

Layout:  <dir>/step_<N>/
           manifest.json     — tree structure, shapes/dtypes, step, metadata
           <idx>.npy         — one file per leaf (gathered to host)

Arrays are saved unsharded (host-gathered), which makes checkpoints
*mesh-agnostic*: restore onto any device count / sharding plan (the elastic
re-mesh path in ``repro.runtime.elastic``).  ``AsyncCheckpointer`` snapshots
to host memory synchronously (cheap) and writes to disk on a background
thread, so the training loop never blocks on IO — the standard large-run
pattern.  At multi-thousand-node scale the same manifest format would point
at per-shard files; that variant is sketched in DESIGN.md.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

_NATIVE = set("?bhilqBHILQefdgFDG")  # numpy kind chars that np.save round-trips


def _to_disk(arr: np.ndarray) -> np.ndarray:
    if arr.dtype.char in _NATIVE or arr.dtype.kind in "iufb":
        return arr
    return np.ascontiguousarray(arr).view(np.uint8)   # e.g. bfloat16


def _from_disk(arr: np.ndarray, dtype_str: str) -> np.ndarray:
    dt = jnp.dtype(dtype_str)
    if arr.dtype == dt:
        return arr
    return arr.view(dt)

__all__ = ["save", "restore", "latest_step", "AsyncCheckpointer"]


def _flatten(tree) -> Tuple[list, Any]:
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(ckpt_dir: str, step: int, tree, *, extra: Optional[Dict] = None) -> str:
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = path + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    leaves, treedef = _flatten(tree)
    host = [np.asarray(jax.device_get(l)) for l in leaves]
    for i, arr in enumerate(host):
        np.save(os.path.join(tmp, f"{i}.npy"), _to_disk(arr))
    manifest = {
        "step": step,
        "n_leaves": len(leaves),
        "shapes": [list(a.shape) for a in host],
        "dtypes": [str(a.dtype) for a in host],
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(path):
        shutil.rmtree(path)
    os.rename(tmp, path)                      # atomic publish
    return path


def _step_of(entry: str) -> Optional[int]:
    """``step_<N>`` -> N; None for tmp dirs and stray non-step entries
    (editor droppings, ``step_backup`` copies, ...) instead of ValueError."""
    if not entry.startswith("step_") or entry.endswith(".tmp"):
        return None
    try:
        return int(entry.split("_", 1)[1])
    except ValueError:
        return None


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [s for s in map(_step_of, os.listdir(ckpt_dir)) if s is not None]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: Optional[int] = None, *, template=None,
            shardings=None) -> Tuple[Any, Dict]:
    """Restore a tree; optionally resharded onto ``shardings`` (same treedef)."""
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    leaves = [_from_disk(np.load(os.path.join(path, f"{i}.npy")), dt)
              for i, dt in zip(range(manifest["n_leaves"]), manifest["dtypes"])]
    if template is None:
        raise ValueError("restore() requires a template tree for structure")
    _, treedef = jax.tree.flatten(template)
    if shardings is not None:
        shard_leaves = jax.tree.leaves(shardings)
        leaves = [jax.device_put(l, s) for l, s in zip(leaves, shard_leaves)]
    tree = jax.tree.unflatten(treedef, leaves)
    return tree, manifest


class AsyncCheckpointer:
    """Snapshot-to-host now, write-to-disk in the background."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._pool = ThreadPoolExecutor(max_workers=1)
        self._pending = None
        self._lock = threading.Lock()

    def save_async(self, step: int, tree, *, extra: Optional[Dict] = None):
        host = jax.tree.map(lambda l: np.asarray(jax.device_get(l)), tree)
        self.wait()
        self._pending = self._pool.submit(self._write, step, host, extra)

    def _write(self, step, host_tree, extra):
        save(self.ckpt_dir, step, host_tree, extra=extra)
        self._gc()

    def _gc(self):
        steps = sorted(s for s in map(_step_of, os.listdir(self.ckpt_dir))
                       if s is not None)
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.ckpt_dir, f"step_{s:08d}"),
                          ignore_errors=True)

    def wait(self):
        with self._lock:
            if self._pending is not None:
                self._pending.result()
                self._pending = None
