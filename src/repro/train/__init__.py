"""Training substrate: optimizers, microbatched train step, LR schedules."""
from .optimizer import Optimizer, adafactor, adamw, clip_by_global_norm
from .train_step import TrainSpec, lr_schedule, make_train_step
__all__ = ["Optimizer", "TrainSpec", "adafactor", "adamw",
           "clip_by_global_norm", "lr_schedule", "make_train_step"]
