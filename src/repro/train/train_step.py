"""Training step factory: microbatched grad accumulation, clipping, LR
schedule, optional compressed cross-pod gradient protocol.

``make_train_step`` returns a pure function
    (params, opt_state, batch, step) -> (params, opt_state, metrics)
ready for ``jax.jit`` with the shardings produced by the launcher.  The
global batch is split into ``microbatches`` chunks accumulated with a
``lax.scan`` — the live-activation knob that keeps MoE dispatch buffers and
32k-context activations inside HBM (a DSE-tunable, see EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.models.config import ModelConfig, ShardingPlan
from repro.models.moe import MoEOptions
from .optimizer import Optimizer, clip_by_global_norm

__all__ = ["TrainSpec", "make_train_step", "lr_schedule"]


@dataclasses.dataclass(frozen=True)
class TrainSpec:
    microbatches: int = 1
    max_grad_norm: float = 1.0
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "wsd"            # wsd (minicpm) | cosine | const
    moe_opts: Optional[MoEOptions] = None
    compress_pod_grads: bool = False  # int8 cross-pod gradient protocol
    shard_grads: bool = False         # constrain grads to param shardings
                                      # (reduce-scatter instead of all-reduce)


def lr_schedule(spec: TrainSpec, step: jnp.ndarray) -> jnp.ndarray:
    s = step.astype(jnp.float32)
    warm = jnp.minimum(s / max(spec.warmup_steps, 1), 1.0)
    if spec.schedule == "cosine":
        frac = jnp.clip(s / spec.total_steps, 0.0, 1.0)
        base = 0.5 * (1 + jnp.cos(jnp.pi * frac))
    elif spec.schedule == "wsd":                      # warmup-stable-decay
        decay_start = 0.9 * spec.total_steps
        frac = jnp.clip((s - decay_start) / (0.1 * spec.total_steps), 0.0, 1.0)
        base = 1.0 - frac * (1.0 - 0.1)
    else:
        base = jnp.ones(())
    return spec.lr * warm * base


def make_train_step(
    cfg: ModelConfig,
    plan: ShardingPlan,
    mesh,
    opt: Optimizer,
    spec: Optional[TrainSpec] = None,
    param_shardings=None,
) -> Callable:
    if spec is None:
        spec = TrainSpec()

    def loss_for(params, mb):
        return T.loss_fn(params, cfg, plan, mesh, mb, moe_opts=spec.moe_opts)

    grad_fn = jax.value_and_grad(loss_for, has_aux=True)
    if spec.compress_pod_grads and "pod" in mesh.axis_names:
        from repro.comm.protocols import wrap_grad_fn_with_pod_protocol
        grad_fn = wrap_grad_fn_with_pod_protocol(grad_fn, mesh, payload="int8")

    def train_step(params, opt_state, batch, step):
        nmb = spec.microbatches
        if nmb > 1:
            def split(x):
                b = x.shape[0]
                return x.reshape(nmb, b // nmb, *x.shape[1:])
            mbs = jax.tree.map(split, batch)

            def acc_fn(carry, mb):
                g_acc, l_acc = carry
                (loss, metrics), g = grad_fn(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32) / nmb, g_acc, g)
                return (g_acc, l_acc + loss / nmb), metrics

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), _ = jax.lax.scan(acc_fn, (g0, jnp.zeros(())), mbs)
            metrics = {"loss": loss}
        else:
            (loss, metrics), grads = grad_fn(params, batch)

        if spec.shard_grads and param_shardings is not None:
            # tell GSPMD gradients are consumed sharded: the cross-device
            # reduction lowers to reduce-scatter instead of all-reduce+slice
            grads = jax.lax.with_sharding_constraint(grads, param_shardings)
        grads, gnorm = clip_by_global_norm(grads, spec.max_grad_norm)
        lr = lr_schedule(spec, step)
        params, opt_state = opt.update(grads, opt_state, params, lr)
        metrics = dict(metrics)
        metrics.update({"grad_norm": gnorm, "lr": lr})
        return params, opt_state, metrics

    return train_step
