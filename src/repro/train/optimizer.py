"""Optimizers: AdamW (fp32 or bf16 state) and Adafactor (factored 2nd moment).

State lives in the same sharding as the parameters (specs mirrored), so
FSDP shards optimizer memory too (ZeRO).  Adafactor exists because fp32 Adam
for kimi-k2 (1T params) cannot fit 512×16 GB; factored states cut optimizer
memory from 8 B/param to ~2 B/param.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["Optimizer", "adamw", "adafactor", "clip_by_global_norm"]


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]                      # params -> opt_state
    update: Callable[[Any, Any, Any, jnp.ndarray], Tuple[Any, Any]]
    state_specs: Callable[[Any], Any]               # param specs -> state specs


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), gn


def adamw(
    lr: float = 3e-4,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    state_dtype=jnp.float32,
) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, state_dtype)
        return {"mu": jax.tree.map(zeros, params),
                "nu": jax.tree.map(zeros, params),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, step_lr=None):
        count = state["count"] + 1
        a = (step_lr if step_lr is not None else lr)
        a = a * jnp.sqrt(1 - b2 ** count.astype(jnp.float32)) / (1 - b1 ** count.astype(jnp.float32))

        def upd(g, mu, nu, p):
            g = g.astype(jnp.float32)
            mu_n = b1 * mu.astype(jnp.float32) + (1 - b1) * g
            nu_n = b2 * nu.astype(jnp.float32) + (1 - b2) * g * g
            step = a * mu_n / (jnp.sqrt(nu_n) + eps)
            if weight_decay and p.ndim >= 2:
                step = step + (step_lr if step_lr is not None else lr) * weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - step).astype(p.dtype), mu_n.astype(state_dtype), nu_n.astype(state_dtype)

        out = jax.tree.map(upd, grads, state["mu"], state["nu"], params)
        new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
        mu = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
        nu = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"mu": mu, "nu": nu, "count": count}

    def state_specs(param_specs):
        from jax.sharding import PartitionSpec as P
        return {"mu": param_specs, "nu": param_specs, "count": P()}

    return Optimizer(init, update, state_specs)


def adafactor(
    lr: float = 1e-3,
    decay: float = 0.8,
    eps: float = 1e-30,
    weight_decay: float = 0.0,
) -> Optimizer:
    """Factored second moment for >=2-D params (memory: 2·(r+c) vs r·c)."""

    def _factored(p):
        return p.ndim >= 2

    def init(params):
        def per(p):
            if _factored(p):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"v": jax.tree.map(per, params), "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, step_lr=None):
        count = state["count"] + 1
        beta = 1.0 - count.astype(jnp.float32) ** -decay
        a = step_lr if step_lr is not None else lr

        def upd(g, v, p):
            g = g.astype(jnp.float32)
            if _factored(p):
                g2 = g * g + eps
                vr = beta * v["vr"] + (1 - beta) * g2.mean(-1)
                vc = beta * v["vc"] + (1 - beta) * g2.mean(-2)
                denom = (vr[..., None] * vc[..., None, :]) / jnp.maximum(
                    vr.mean(-1)[..., None, None], eps)
                step = g / jnp.sqrt(jnp.maximum(denom, eps))
                nv = {"vr": vr, "vc": vc}
            else:
                vv = beta * v["v"] + (1 - beta) * (g * g + eps)
                step = g / jnp.sqrt(jnp.maximum(vv, eps))
                nv = {"v": vv}
            # update clipping (RMS<=1) as in the paper's Adafactor
            rms = jnp.sqrt(jnp.mean(step ** 2))
            step = step / jnp.maximum(1.0, rms)
            newp = p.astype(jnp.float32) - a * step
            if weight_decay and p.ndim >= 2:
                newp = newp - a * weight_decay * p.astype(jnp.float32)
            return newp.astype(p.dtype), nv

        flat = jax.tree.map(upd, grads, state["v"], params,
                            is_leaf=lambda x: isinstance(x, dict) and ("vr" in x or "v" in x))
        new_params = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda x: isinstance(x, tuple))
        v = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"v": v, "count": count}

    def state_specs(param_specs):
        from jax.sharding import PartitionSpec as P

        def per(spec):
            # vr drops the last dim's spec entry, vc the second-to-last
            s = tuple(spec)
            if len(s) >= 2:
                return {"vr": P(*s[:-1]), "vc": P(*(s[:-2] + s[-1:]))}
            return {"v": P(*s)}

        return {"v": jax.tree.map(per, param_specs,
                                  is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec)),
                "count": P()}

    return Optimizer(init, update, state_specs)
