"""The fidelity ladder: every simulator rung behind one contract (§IV-A).

The paper's enabler is a *multi-fidelity simulation stack* the DSE climbs —
cheap models screen thousands of candidates, faithful simulation verifies the
survivors.  This registry makes the ladder explicit: every rung implements

    evaluate(arch, bound, trace, *, hw=None, ...)        -> VerifyResult
    evaluate_batch(archs, bound, trace, *, hw=None, ...) -> [VerifyResult]

so engines are swappable pipeline stages (a batched variant is either native
— one jitted call for the whole batch — or the serial loop fallback).

    rung  engine             model                                   cost
    ----  -----------------  --------------------------------------  --------
      0   analytic           closed-form resource/timing model       ~µs
      1   surrogate          event-driven transaction model          ~ms
      2   batched_surrogate  one jitted contention scan, B at once   ~ms/batch
      2   batched_surrogate[kernel]  + segmented occupancy kernel    ~ms/batch
      3   netsim             finite buffers, drops, retransmission   ~100ms
      3   batched_netsim     the same model, one jitted scan         ~ms/cand
      3   batched_netsim[kernel]  segmented fixed-point kernel       ~µs/cand
      4   cycle              cycle-accurate JAX switch datapath      ~s

Who uses which rung: DSE stage 1 prices candidates with the rung-0 resource
model, stage 2 screens through rung 2 (`DSEProblem.surrogate_batch`), stage 4
verifies through rung 3 (`DSEProblem.verify_batch`), and the
``verify_engine="auto"`` policy escalates only the champion to rung 4 —
so the expensive cycle-accurate datapath runs O(1) times per DSE, not O(B).

``VerifyResult.meta["engine"]`` names the rung that produced a result;
rung-1/2 results report ``drop_rate=0.0`` honestly (infinite buffers by
construction — sizing happens *after* them).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.core.archspec import SwitchArch
from repro.core.binding import BoundProtocol
from repro.core.dse import SurrogateResult, VerifyResult

from .backannotate import HardwareParams, annotate
from .batched_netsim import run_netsim_batched
from .batched_surrogate import run_surrogate_batched
from .netsim import run_netsim
from .surrogate import run_surrogate

__all__ = ["EngineSpec", "ENGINES", "get_engine", "ladder", "register_engine"]


@dataclasses.dataclass(frozen=True)
class EngineSpec:
    """One rung of the fidelity ladder.

    ``evaluate`` and ``evaluate_batch`` share the keyword surface
    ``(hw=None, back_annotation=False, i_burst=1.0)``; ``batched`` records
    whether ``evaluate_batch`` is native (one call for the whole batch) or
    the serial loop fallback."""

    name: str
    rung: int
    evaluate: Callable[..., VerifyResult]
    evaluate_batch: Callable[..., List[VerifyResult]]
    batched: bool
    doc: str = ""


ENGINES: Dict[str, EngineSpec] = {}


def register_engine(
    name: str,
    rung: int,
    evaluate: Callable[..., VerifyResult],
    evaluate_batch: Optional[Callable[..., List[VerifyResult]]] = None,
    doc: str = "",
) -> EngineSpec:
    """Add a rung; without ``evaluate_batch`` the serial loop stands in."""
    batched = evaluate_batch is not None
    if evaluate_batch is None:
        def evaluate_batch(archs, bound, trace, **kw):
            return [evaluate(a, bound, trace, **kw) for a in archs]
    spec = EngineSpec(name=name, rung=rung, evaluate=evaluate,
                      evaluate_batch=evaluate_batch, batched=batched, doc=doc)
    ENGINES[name] = spec
    return spec


def get_engine(name: str) -> EngineSpec:
    try:
        return ENGINES[name]
    except KeyError:
        raise KeyError(f"unknown engine {name!r}; known: {sorted(ENGINES)}") from None


def ladder() -> List[EngineSpec]:
    """The registered rungs, cheapest first."""
    return sorted(ENGINES.values(), key=lambda e: (e.rung, e.name))


def _annotate(arch, bound, hw, back_annotation, i_burst) -> HardwareParams:
    if hw is not None:
        return hw
    return annotate(arch, bound,
                    source="cycle_sim" if back_annotation else "model",
                    i_burst=i_burst)


# --------------------------------------------------------------------------
# rung 0 — analytic: no trace replay at all
# --------------------------------------------------------------------------

def _analytic_evaluate(
    arch: SwitchArch, bound: BoundProtocol, trace, *,
    hw: Optional[HardwareParams] = None, back_annotation: bool = False,
    i_burst: float = 1.0,
) -> VerifyResult:
    """Unloaded pipeline latency + mean-packet serialisation; throughput is
    the offered load capped by the datapath's sustainable rate.  Queueing and
    drops are invisible at this rung — it prices candidates, it does not
    verify them."""
    hw = _annotate(arch, bound, hw, back_annotation, i_burst)
    payload = np.asarray(trace.payload_bytes, np.float64)
    mean_wire = float(payload.mean()) + bound.header_bytes if payload.size \
        else float(bound.header_bytes)
    flits = max(1.0, math.ceil(mean_wire / (arch.bus_bits / 8)))
    svc_s = (flits + hw.ingress_stall_cycles) / (hw.fclk_hz * hw.eta)
    lat_ns = ((hw.pipeline_cycles + hw.arb_cycles) / hw.fclk_hz + svc_s) * 1e9
    cap_gbps = arch.bus_bits * hw.fclk_hz * hw.eta / arch.ii / 1e9
    offered = trace.offered_gbps(bound.header_bytes) if len(trace) else 0.0
    return VerifyResult(
        p99_latency_ns=lat_ns, mean_latency_ns=lat_ns, drop_rate=0.0,
        throughput_gbps=min(offered, cap_gbps),
        meta={"hw": hw, "engine": "analytic", "capacity_gbps": cap_gbps})


def _analytic_batch(archs, bound, trace, *, hw=None, back_annotation=False,
                    i_burst=1.0) -> List[VerifyResult]:
    archs = list(archs)
    hw = list(hw) if hw is not None else [None] * len(archs)
    return [_analytic_evaluate(a, bound, trace, hw=h,
                               back_annotation=back_annotation, i_burst=i_burst)
            for a, h in zip(archs, hw)]


# --------------------------------------------------------------------------
# rungs 1+2 — the infinite-buffer transaction model
# --------------------------------------------------------------------------

def _surrogate_to_verify(sr: SurrogateResult) -> VerifyResult:
    lat = sr.latency_ns
    return VerifyResult(
        p99_latency_ns=sr.p(99),
        mean_latency_ns=float(lat.mean()) if lat.size else math.inf,
        drop_rate=0.0,                      # infinite buffers by construction
        throughput_gbps=sr.throughput_gbps,
        meta={**sr.meta, "engine": "surrogate",
              "q_occupancy": sr.q_occupancy})


def _surrogate_evaluate(arch, bound, trace, *, hw=None, back_annotation=False,
                        i_burst=1.0) -> VerifyResult:
    return _surrogate_to_verify(run_surrogate(
        arch, bound, trace, hw=hw, back_annotation=back_annotation,
        i_burst=i_burst))


def _batched_surrogate_batch(archs, bound, trace, *, hw=None,
                             back_annotation=False, i_burst=1.0, mesh=None,
                             use_kernel=False):
    res = run_surrogate_batched(list(archs), bound, trace, hw=hw,
                                back_annotation=back_annotation,
                                i_burst=i_burst, mesh=mesh,
                                use_kernel=use_kernel)
    return [_surrogate_to_verify(sr) for sr in res.results()]


def _batched_surrogate_evaluate(arch, bound, trace, *, hw=None,
                                back_annotation=False, i_burst=1.0):
    return _batched_surrogate_batch(
        [arch], bound, trace, hw=[hw] if hw is not None else None,
        back_annotation=back_annotation, i_burst=i_burst)[0]


def _batched_surrogate_kernel_batch(archs, bound, trace, *, hw=None,
                                    back_annotation=False, i_burst=1.0,
                                    mesh=None):
    return _batched_surrogate_batch(
        archs, bound, trace, hw=hw, back_annotation=back_annotation,
        i_burst=i_burst, mesh=mesh, use_kernel=True)


def _batched_surrogate_kernel_evaluate(arch, bound, trace, *, hw=None,
                                       back_annotation=False, i_burst=1.0):
    return _batched_surrogate_kernel_batch(
        [arch], bound, trace, hw=[hw] if hw is not None else None,
        back_annotation=back_annotation, i_burst=i_burst)[0]


# --------------------------------------------------------------------------
# rung 3 — the finite-buffer event-driven verifier
# --------------------------------------------------------------------------

def _netsim_evaluate(arch, bound, trace, *, hw=None, back_annotation=False,
                     i_burst=1.0, cfg=None) -> VerifyResult:
    return run_netsim(arch, bound, trace, hw=hw, cfg=cfg,
                      back_annotation=back_annotation, i_burst=i_burst)


def _batched_netsim_batch(archs, bound, trace, *, hw=None,
                          back_annotation=False, i_burst=1.0, cfg=None,
                          mesh=None, use_kernel=False):
    return run_netsim_batched(list(archs), bound, trace, hw=hw, cfg=cfg,
                              back_annotation=back_annotation,
                              i_burst=i_burst, mesh=mesh,
                              use_kernel=use_kernel)


def _batched_netsim_evaluate(arch, bound, trace, *, hw=None,
                             back_annotation=False, i_burst=1.0, cfg=None):
    return _batched_netsim_batch(
        [arch], bound, trace, hw=[hw] if hw is not None else None,
        back_annotation=back_annotation, i_burst=i_burst, cfg=cfg)[0]


def _batched_netsim_kernel_batch(archs, bound, trace, *, hw=None,
                                 back_annotation=False, i_burst=1.0, cfg=None,
                                 mesh=None):
    return _batched_netsim_batch(
        archs, bound, trace, hw=hw, back_annotation=back_annotation,
        i_burst=i_burst, cfg=cfg, mesh=mesh, use_kernel=True)


def _batched_netsim_kernel_evaluate(arch, bound, trace, *, hw=None,
                                    back_annotation=False, i_burst=1.0,
                                    cfg=None):
    return _batched_netsim_kernel_batch(
        [arch], bound, trace, hw=[hw] if hw is not None else None,
        back_annotation=back_annotation, i_burst=i_burst, cfg=cfg)[0]


# --------------------------------------------------------------------------
# rung 4 — the cycle-accurate JAX switch datapath
# --------------------------------------------------------------------------

def _cycle_evaluate(arch, bound, trace, *, hw=None, back_annotation=False,
                    i_burst=1.0, max_cycles=None) -> VerifyResult:
    from repro.switch.switch import simulate   # heavy import, keep lazy
    hw = _annotate(arch, bound, hw, back_annotation, i_burst)
    res = simulate(arch, bound, trace, fclk_hz=hw.fclk_hz,
                   max_cycles=max_cycles)
    lat = res.latency_ns
    return VerifyResult(
        p99_latency_ns=res.p(99),
        mean_latency_ns=float(lat.mean()) if lat.size else math.inf,
        drop_rate=res.drop_rate,
        throughput_gbps=res.throughput_gbps,
        meta={"hw": hw, "engine": "cycle", "cycle": res})


register_engine(
    "analytic", 0, _analytic_evaluate, _analytic_batch,
    doc="closed-form resource/timing model; prices candidates, no queueing")
register_engine(
    "surrogate", 1, _surrogate_evaluate,
    doc="serial event-driven transaction model, infinite buffers")
register_engine(
    "batched_surrogate", 2, _batched_surrogate_evaluate,
    _batched_surrogate_batch,
    doc="the transaction model as one jitted contention scan over the batch")
register_engine(
    "batched_surrogate[kernel]", 2, _batched_surrogate_kernel_evaluate,
    _batched_surrogate_kernel_batch,
    doc="rung 2 with the segmented occupancy kernel (bit-identical counts)")
register_engine(
    "netsim", 3, _netsim_evaluate,
    doc="finite-buffer event-driven verifier (drops, retransmission)")
register_engine(
    "batched_netsim", 3, _batched_netsim_evaluate, _batched_netsim_batch,
    doc="the finite-buffer verifier as one jitted scan, sized depths batched")
register_engine(
    "batched_netsim[kernel]", 3, _batched_netsim_kernel_evaluate,
    _batched_netsim_kernel_batch,
    doc="rung 3 via the segmented fixed-point kernel (lean replay + chain "
        "admission), bit-identical results, serial-oracle fallback on "
        "unconverged rows")
register_engine(
    "cycle", 4, _cycle_evaluate,
    doc="cycle-accurate JAX switch datapath (the repo's 'real hardware')")
