"""Batched stage-2 surrogate engine (§IV-A.2 at fan-out scale).

``run_surrogate`` evaluates one ``(arch, depths)`` candidate per call with a
Python-loop crossbar — fine for a handful of candidates, hopeless for the
thousands Algorithm 1's stage 2 wants to screen.  This module reformulates
the event-driven transaction model as a *sorted-arrival scan over a shared
trace* in which every per-candidate parameter (bus width, pipeline depth, η,
ingress stalls, f_clk) is a batch axis:

  * the greedy-crossbar recurrence runs as one ``jax.lax.scan`` with
    ``[B, n_ports]`` port-availability carries (``repro.kernels.xbar``, with
    an optional Pallas kernel alongside the iSLIP family),
  * per-candidate departure offsets and sustained throughput come out of the
    same jitted call; latency (one broadcast) and its quantiles reduce on
    the host (numpy's sort beats XLA's CPU sort on the [B, m] matrix by
    ~10x, measured, and shipping a second [B, m] matrix off-device would
    double the transfer),
  * exact per-VOQ occupancy counting (PASTA sampling) is integer math done
    once on the host from the batched departure times — bit-identical to the
    serial path, so stage-3 sizing and drop counts cannot drift.

Precision: with ``precision="float64"`` (default) the scan runs under a
scoped ``jax.experimental.enable_x64`` so departure times match the serial
float64 model exactly; ``precision="float32"`` keeps TPU-native dtypes (the
scan carries arrival-relative *slacks*, never absolute timestamps, so f32
still holds queueing-delay precision on arbitrarily long traces).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from repro.analysis.retrace import track
from repro.core.archspec import SwitchArch, VOQKind
from repro.core.binding import BoundProtocol
from repro.core.dse import SurrogateResult

from .backannotate import HardwareParams, annotate
from .timeline import stage2_timeline
from repro.kernels.netsim import resolve_use_kernel, segmented_occupancy
from repro.kernels.xbar import xbar_contend

__all__ = ["BatchedSurrogateResult", "run_surrogate_batched", "DEFAULT_QUANTILES"]

DEFAULT_QUANTILES = (50.0, 90.0, 99.0)


def _engine_impl(dt, src, dst, svc, t, wire_bits, *, n_ports, use_pallas,
                 interpret):
    """One call: contention scan + throughput.

    Latency (one broadcast over dep) and quantile reduction deliberately
    stay on the host: returning the [B, m] latency matrix would double the
    largest device-to-host transfer, and XLA's CPU sort is ~10x slower than
    numpy's (measured).  The scan is rowwise over the candidate axis (per-
    candidate carries, replicated timeline), so any partition of the batch —
    including a shard_map split across devices — is bitwise-identical to the
    monolithic call."""
    dep = xbar_contend(t, dt, src, dst, svc, n_ports=n_ports,
                       use_pallas=use_pallas, interpret=interpret)
    # dep is absolute on the f64 path, an arrival-relative offset on f32
    absolute = dep.dtype == jnp.float64 and not use_pallas
    dep_end = dep if absolute else t[None, :] + dep
    duration = jnp.maximum(jnp.max(dep_end, axis=1), 1e-12)
    thru = wire_bits / duration / 1e9                           # [B] Gbps
    return dep, thru


_engine = track("surrogate.engine",
                jax.jit(_engine_impl,
                        static_argnames=("n_ports", "use_pallas",
                                         "interpret")))


@functools.lru_cache(maxsize=None)
def _sharded_engine(mesh, n_ports, use_pallas, interpret):
    """The same scan, candidate axis sharded over every mesh axis.

    ``svc`` [B, m] and ``wire_bits`` [B] split along B; the timeline
    (``dt``/``src``/``dst``/``t``) is replicated.  No collectives: rows are
    independent, so each shard runs the serial recurrence on its slice and
    the result is bitwise-identical to the single-device call."""
    from jax.sharding import PartitionSpec as P

    from repro import compat

    cand = P(tuple(mesh.axis_names))
    rep = P()
    body = functools.partial(_engine_impl, n_ports=n_ports,
                             use_pallas=use_pallas, interpret=interpret)
    name = (f"surrogate.sharded[{'x'.join(map(str, mesh.devices.shape))} "
            f"{','.join(mesh.axis_names)} n_ports={n_ports}]")
    return track(name, jax.jit(compat.shard_map(
        body, mesh,
        in_specs=(rep, rep, rep, cand, rep, cand),
        out_specs=(cand, cand))))


def _exact_occupancy(t, qid, dep):
    """Per-VOQ occupancy at arrival instants for every candidate at once.

    Serial reference loops ``np.searchsorted`` per queue; here one
    searchsorted per candidate row covers all queues: keys ``qid*span + time``
    order departures queue-major (FIFO keeps them sorted inside a queue).
    All float64 — the counts are exact integers identical to the serial
    model's.  The key spends ~log2(n_ports²) mantissa bits on the queue id,
    leaving time resolution of span·n²·2⁻⁵² (≈ femtoseconds even at 1024
    ports — far below any physical service-time margin); the candidate axis
    deliberately stays a Python loop rather than a third key term so batch
    size cannot erode that budget.
    """
    b_n, m = dep.shape
    order = np.argsort(qid, kind="stable")
    g = qid[order]
    first = np.ones(m, bool)
    first[1:] = g[1:] != g[:-1]
    run_starts = np.nonzero(first)[0]
    run_ids = np.cumsum(first) - 1
    rank_grouped = np.arange(m) - run_starts[run_ids]
    rank = np.empty(m, np.int64)
    rank[order] = rank_grouped                 # arrivals-before-me in my queue
    qstart = np.empty(m, np.int64)
    qstart[order] = run_starts[run_ids]        # my queue's block start position

    span = max(float(dep.max(initial=0.0)), float(t.max(initial=0.0))) + 1.0
    key_arr = qid * span + t
    occ = np.empty((b_n, m), np.int64)
    for b in range(b_n):
        key_dep = g * span + dep[b, order]
        departed = np.searchsorted(key_dep, key_arr, side="right") - qstart
        occ[b] = rank - departed
    return occ                                 # [B, m] int64


@dataclasses.dataclass
class BatchedSurrogateResult:
    """Stage-2 fan-out output: [B, ...] arrays over the candidate batch."""

    archs: List[SwitchArch]
    hw: List[HardwareParams]
    latency_ns: np.ndarray         # [B, m] per-packet latency
    quantiles: np.ndarray          # [B, nq] latency quantiles (ns)
    quantile_qs: Sequence[float]   # the nq percentile points
    throughput_gbps: np.ndarray    # [B]
    q_occupancy: np.ndarray        # [B, m] exact per-VOQ occupancy samples
    dep_end_s: np.ndarray          # [B, m] absolute departure times
    t_s: np.ndarray                # [m] shared arrival times (t[0] == 0)
    line_rate_feasible: np.ndarray  # [B] bool
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def peak_occupancy(self) -> np.ndarray:
        """[B] — the BRAM lower bound the paper reads off stage 2."""
        return self.q_occupancy.max(axis=1, initial=0)

    def occupancy_hist(self) -> np.ndarray:
        """[B, peak+1] histogram of occupancy samples (shared bin edges)."""
        b, m = self.q_occupancy.shape
        occ = np.maximum(self.q_occupancy, 0)
        width = int(occ.max(initial=0)) + 1
        flat = (np.arange(b)[:, None] * width + occ).reshape(-1)
        return np.bincount(flat, minlength=b * width).reshape(b, width)

    def results(self) -> List[SurrogateResult]:
        """Materialise per-candidate ``SurrogateResult``s (serial-compatible)."""
        out = []
        shared_rows = [b for b, a in enumerate(self.archs)
                       if a.voq is VOQKind.SHARED]
        if shared_rows:
            # sort all shared rows at once instead of one np.sort per
            # candidate inside the loop below
            sorted_dep = np.sort(self.dep_end_s[shared_rows], axis=1)
            sorted_of = {b: sorted_dep[i] for i, b in enumerate(shared_rows)}
        for b, (arch, hw) in enumerate(zip(self.archs, self.hw)):
            if arch.voq is VOQKind.SHARED:
                m = self.t_s.size
                departed = np.searchsorted(sorted_of[b], self.t_s,
                                           side="right")
                shared_occ = np.arange(m) - departed
            else:
                shared_occ = None
            out.append(SurrogateResult(
                q_occupancy=self.q_occupancy[b].astype(np.float64),
                latency_ns=self.latency_ns[b],
                throughput_gbps=float(self.throughput_gbps[b]),
                meta={
                    "hw": hw,
                    "shared_occupancy": shared_occ,
                    "q_occ_max": int(self.q_occupancy[b].max(initial=0)),
                    "line_rate_feasible": bool(self.line_rate_feasible[b]),
                    "batched": True,
                },
            ))
        return out


def _run_group(archs, bounds, trace, hw_list, use_pallas, interpret, precision,
               quantiles, mesh_spec=None, use_kernel=False):
    """All candidates share n_ports; every other parameter — including the
    protocol's header wire-bytes under co-design — is a batch axis.  The
    shared arrival timeline is the trace's (candidate-independent), so mixed
    header widths still ride one jitted scan: the header only reshapes the
    per-candidate service times and delivered wire bits."""
    n = archs[0].n_ports
    tl2 = stage2_timeline(trace, n)
    t, src, dst, payload = tl2.t, tl2.src, tl2.dst, tl2.payload
    m = t.size

    b_n = len(archs)
    svc = np.empty((b_n, m), np.float64)
    pipe_s = np.empty(b_n, np.float64)
    feasible = np.empty(b_n, bool)
    wire_bits = np.empty(b_n, np.float64)
    # one wire-size array per distinct header width: classic shared-bound
    # batches pay for it once, co-design pays once per layout width
    wire_cache: Dict[int, Any] = {}
    for b, (arch, bound, hw) in enumerate(zip(archs, bounds, hw_list)):
        cached = wire_cache.get(bound.header_bytes)
        if cached is None:
            wb = payload + bound.header_bytes
            cached = (wb, float(wb.sum() * 8))
            wire_cache[bound.header_bytes] = cached
        wire_bytes, wire_bits[b] = cached
        flit_bytes = arch.bus_bits // 8
        size_flits = np.maximum(1, -(-wire_bytes // flit_bytes))
        svc[b] = (size_flits + hw.ingress_stall_cycles) / (hw.fclk_hz * hw.eta)
        pipe_s[b] = (hw.pipeline_cycles + hw.arb_cycles) / hw.fclk_hz
        feasible[b] = bool(m == 0 or svc[b].mean() * hw.fclk_hz
                           <= arch.ii * size_flits.mean() * 1.25)

    dtype = np.float64 if precision == "float64" else np.float32
    if m == 0:
        dep = np.zeros((b_n, 0))
        thru = np.zeros(b_n)
    else:
        dt = tl2.dt
        k = 1 if mesh_spec is None else mesh_spec.shard_axis
        if k > 1:
            # pad the candidate axis to the mesh extent (throwaway replicas
            # of row 0, stripped below) and shard it over every mesh axis
            from repro.launch.mesh import shard_pad
            args = (dt.astype(dtype), src.astype(np.int32),
                    dst.astype(np.int32),
                    shard_pad(svc.astype(dtype), k),
                    t.astype(dtype),
                    shard_pad(wire_bits.astype(dtype), k))
            engine = _sharded_engine(mesh_spec.build(), n, use_pallas,
                                     interpret)
        else:
            args = (dt.astype(dtype), src.astype(np.int32),
                    dst.astype(np.int32), svc.astype(dtype), t.astype(dtype),
                    wire_bits.astype(dtype))
            engine = functools.partial(_engine, n_ports=n,
                                       use_pallas=use_pallas,
                                       interpret=interpret)
        if precision == "float64":
            with enable_x64():
                dep, thru = engine(*args)
                dep, thru = np.asarray(dep), np.asarray(thru)
        else:
            dep, thru = engine(*args)
            dep, thru = np.asarray(dep, np.float64), np.asarray(thru, np.float64)
        dep, thru = dep[:b_n], thru[:b_n]       # strip pad rows (no-op serial)
    if precision == "float64":
        # the f64 scan returns absolute departure times so the occupancy
        # comparisons below see the serial path's exact values (no offset
        # round-trip); latency then subtracts t exactly as the serial model
        dep_end = np.asarray(dep, np.float64)
        lat = (dep_end - t[None, :] + pipe_s[:, None]) * 1e9
    else:
        dep_end = t[None, :] + np.asarray(dep, np.float64)
        lat = (dep + pipe_s[:, None]) * 1e9
    quant = (np.percentile(lat, quantiles, axis=1).T if m
             else np.zeros((b_n, len(quantiles))))
    if m == 0:
        occupancy = np.zeros((b_n, 0), np.int64)
    elif use_kernel:
        # one flat searchsorted over the whole [B, m] block (chain structure
        # from the trace memo) — integer counts bit-identical to the serial
        # per-row reference, asserted in tests/test_netsim_kernels.py
        occupancy = segmented_occupancy(np.asarray(t), dep_end, tl2.chain)
    else:
        occupancy = _exact_occupancy(t, tl2.qid, dep_end)
    return BatchedSurrogateResult(
        archs=list(archs), hw=list(hw_list),
        latency_ns=np.asarray(lat, np.float64),
        quantiles=np.asarray(quant, np.float64), quantile_qs=tuple(quantiles),
        throughput_gbps=np.asarray(thru, np.float64),
        q_occupancy=occupancy, dep_end_s=dep_end, t_s=t,
        line_rate_feasible=feasible,
        meta={"n_ports": n, "precision": precision, "use_pallas": use_pallas,
              "use_kernel": bool(use_kernel)},
    )


def run_surrogate_batched(
    archs: Sequence[SwitchArch],
    bound: Union[BoundProtocol, Sequence[BoundProtocol]],
    trace,
    *,
    hw: Optional[Sequence[HardwareParams]] = None,
    back_annotation: bool = False,
    i_burst: float = 1.0,
    use_pallas: bool = False,
    interpret: bool = True,
    precision: str = "float64",
    quantiles: Sequence[float] = DEFAULT_QUANTILES,
    mesh=None,
    use_kernel=False,
) -> BatchedSurrogateResult:
    """Evaluate a whole candidate batch against one shared trace.

    ``mesh`` is an optional ``repro.launch.mesh.MeshSpec`` (or anything its
    ``coerce`` accepts): when it names more than one shard the candidate
    axis is padded to the mesh extent and the scan runs under ``shard_map``
    across the device mesh — bit-identical to the serial path, which remains
    the byte-identical default (``mesh=None``).

    ``bound`` is one ``BoundProtocol`` shared by the batch, or — for the
    protocol/architecture co-design DSE — a per-candidate sequence (index-
    aligned with ``archs``): header wire-bytes then become a batch axis like
    bus width and η, and the batch still costs one jitted scan (the arrival
    timeline is the trace's, never rebuilt per candidate).

    Candidates may mix every architectural policy; only ``n_ports`` is a
    structural axis, so mixed-port batches are partitioned internally and the
    per-group results are stitched back in input order.

    ``use_pallas`` selects the Pallas crossbar kernel (float32);
    ``interpret=True`` (the default) validates it on CPU, ``interpret=False``
    compiles it for a real TPU backend.

    ``use_kernel`` (``"auto"``/``"on"``/``"off"`` or a bool) switches the
    exact occupancy count to the segmented flat-searchsorted kernel
    (``repro.kernels.netsim.segmented_occupancy``) — bit-identical integer
    counts, one pass over the whole batch instead of one per candidate.

    Memory: the result holds per-candidate sample arrays ([B, m] latencies,
    occupancy and departure times — stage 3 consumes the samples), so host
    memory scales as O(B·m); at ~1e5-packet traces budget ~2.5 MB/candidate
    and chunk very large sweeps into multiple calls.
    """
    use_kernel = resolve_use_kernel(use_kernel)
    if use_pallas and precision == "float64":
        # the Pallas kernel is float32 by design (slack formulation); honour
        # that in the dtype, the meta, and the skipped enable_x64 — a silent
        # downcast would betray the documented bit-exactness of the f64 path
        precision = "float32"
    from repro.launch.mesh import MeshSpec
    mesh = MeshSpec.coerce(mesh)
    if mesh is not None and mesh.is_single():
        mesh = None
    archs = list(archs)
    bounds = (list(bound) if isinstance(bound, (list, tuple))
              else [bound] * len(archs))
    if len(bounds) != len(archs):
        raise ValueError(f"bound has {len(bounds)} entries for {len(archs)} "
                         "archs; they must be index-aligned")
    if not archs:
        return BatchedSurrogateResult(
            archs=[], hw=[], latency_ns=np.zeros((0, 0)),
            quantiles=np.zeros((0, len(quantiles))), quantile_qs=tuple(quantiles),
            throughput_gbps=np.zeros(0), q_occupancy=np.zeros((0, 0), np.int64),
            dep_end_s=np.zeros((0, 0)), t_s=np.zeros(0),
            line_rate_feasible=np.zeros(0, bool))
    if hw is None:
        source = "cycle_sim" if back_annotation else "model"
        hw = [annotate(a, b, source=source, i_burst=i_burst)
              for a, b in zip(archs, bounds)]
    hw = list(hw)
    if len(hw) != len(archs):
        raise ValueError(f"hw has {len(hw)} entries for {len(archs)} archs; "
                         "they must be index-aligned")

    groups: Dict[int, List[int]] = {}
    for i, a in enumerate(archs):
        groups.setdefault(a.n_ports, []).append(i)
    if len(groups) == 1:
        return _run_group(archs, bounds, trace, hw, use_pallas, interpret,
                          precision, quantiles, mesh_spec=mesh,
                          use_kernel=use_kernel)

    parts = {n: _run_group([archs[i] for i in idx], [bounds[i] for i in idx],
                           trace, [hw[i] for i in idx], use_pallas, interpret,
                           precision, quantiles, mesh_spec=mesh,
                           use_kernel=use_kernel)
             for n, idx in groups.items()}
    # stitch [B, m] arrays back in input order (m is shared: one trace)
    first = next(iter(parts.values()))
    merged = BatchedSurrogateResult(
        archs=archs, hw=hw,
        latency_ns=np.empty((len(archs),) + first.latency_ns.shape[1:]),
        quantiles=np.empty((len(archs), len(quantiles))),
        quantile_qs=tuple(quantiles),
        throughput_gbps=np.empty(len(archs)),
        q_occupancy=np.empty((len(archs),) + first.q_occupancy.shape[1:], np.int64),
        dep_end_s=np.empty((len(archs),) + first.dep_end_s.shape[1:]),
        t_s=first.t_s, line_rate_feasible=np.empty(len(archs), bool),
        meta={"precision": precision, "use_pallas": use_pallas,
              "use_kernel": bool(use_kernel)})
    for n, idx in groups.items():
        part = parts[n]
        for row, i in enumerate(idx):
            merged.latency_ns[i] = part.latency_ns[row]
            merged.quantiles[i] = part.quantiles[row]
            merged.throughput_gbps[i] = part.throughput_gbps[row]
            merged.q_occupancy[i] = part.q_occupancy[row]
            merged.dep_end_s[i] = part.dep_end_s[row]
            merged.line_rate_feasible[i] = part.line_rate_feasible[row]
    return merged
