"""Multi-fidelity simulation stack (paper §IV-A): the engine ladder from the
analytic resource model up to the cycle-accurate datapath, plus the batched
stage-2/stage-4 fan-out engines and the switch DSE problem."""
from .backannotate import HardwareParams, analytic_eta, annotate
from .batched_netsim import run_netsim_batched
from .batched_surrogate import BatchedSurrogateResult, run_surrogate_batched
from .engines import ENGINES, EngineSpec, get_engine, ladder, register_engine
from .netsim import NetSimConfig, run_netsim
from .resources import ALVEO_U45N, ResourceReport, estimate_quick, synthesize
from .surrogate import run_surrogate
from .switch_problem import (CoDesignCandidate, SwitchDSEProblem,
                             align_depth_to_bram, optimize_switch)

__all__ = [
    "ALVEO_U45N", "BatchedSurrogateResult", "CoDesignCandidate", "ENGINES",
    "EngineSpec", "HardwareParams", "NetSimConfig", "ResourceReport",
    "SwitchDSEProblem",
    "align_depth_to_bram", "analytic_eta", "annotate", "estimate_quick",
    "get_engine", "ladder", "optimize_switch", "register_engine", "run_netsim",
    "run_netsim_batched", "run_surrogate", "run_surrogate_batched",
    "synthesize",
]
