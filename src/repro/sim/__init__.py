"""Multi-level simulation stack (paper SS IV-A): surrogate, netsim, resources."""
from .backannotate import HardwareParams, analytic_eta, annotate
from .batched_surrogate import BatchedSurrogateResult, run_surrogate_batched
from .netsim import NetSimConfig, run_netsim
from .resources import ALVEO_U45N, ResourceReport, estimate_quick, synthesize
from .surrogate import run_surrogate
from .switch_problem import SwitchDSEProblem, align_depth_to_bram, optimize_switch

__all__ = [
    "ALVEO_U45N", "BatchedSurrogateResult", "HardwareParams", "NetSimConfig",
    "ResourceReport", "SwitchDSEProblem", "align_depth_to_bram", "analytic_eta",
    "annotate", "estimate_quick", "optimize_switch", "run_netsim",
    "run_surrogate", "run_surrogate_batched", "synthesize",
]
