"""Trace-keyed event-timeline memos shared by the batched engines.

Both batched engines open with host-side ordering work that is a pure
function of the built trace — stage 2's stable ``np.argsort`` over arrival
times, stage 4's host-NIC serialisation loop (``switch_arrival_times``, m
Python iterations) plus the ``np.lexsort`` that reproduces the serial heap's
(time, packet) pop order — yet both used to redo it on **every call**: every
generation of a search, every candidate batch of a campaign scenario, every
fidelity rung.  The ordering never changes, only the per-candidate service
times do.

This module hoists that work into content-keyed memos.  A trace is keyed by
a SHA-1 over its event arrays (cached on the instance, so the hash itself is
also paid once); stage-4 timelines additionally key on the structural knobs
the serialisation depends on (``n_ports``, header wire-bytes, propagation
delay — ``link_gbps`` rides the trace key).  Memo entries carry everything
order-derived, including the per-(src,dst) :class:`~repro.kernels.netsim.ChainIndex`
the kernel engines' segmented passes consume, with arrays frozen read-only
(they are shared across calls and generations).

``stats()`` exposes build/hit counters so tests can assert the contract
directly: a 10-generation NSGA-II run builds each trace's timeline exactly
once (``tests/test_netsim_kernels.py``).
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, Tuple

import numpy as np

from repro.kernels.netsim import ChainIndex, build_chain_index

from .netsim import switch_arrival_times

__all__ = ["Stage2Timeline", "Stage4Timeline", "stage2_timeline",
           "stage4_timeline", "trace_key", "stats", "clear"]

#: bounded memo size — campaigns cycle a handful of traces; evicting the
#: oldest entry keeps long multi-trace sessions from accumulating [m] arrays
_MAX_ENTRIES = 32

_STAGE2: Dict[Tuple, "Stage2Timeline"] = {}
_STAGE4: Dict[Tuple, "Stage4Timeline"] = {}
_COUNTS = {"stage2_builds": 0, "stage2_hits": 0,
           "stage4_builds": 0, "stage4_hits": 0}
_BUILDS_BY_KEY: Dict[Tuple, int] = {}


def trace_key(trace) -> str:
    """Content hash of a trace's event arrays, cached on the instance.

    Keyed on content, not identity, so a trace reloaded from ``.npz`` (or
    rebuilt by a resumed campaign) still hits the memo."""
    cached = getattr(trace, "_spac_timeline_key", None)
    if cached is not None:
        return cached
    h = hashlib.sha1()
    for a in (trace.time_s, trace.src, trace.dst, trace.payload_bytes):
        arr = np.ascontiguousarray(a)
        h.update(str(arr.dtype).encode())
        h.update(arr.tobytes())
    h.update(f"|{int(trace.n_ports)}|{float(trace.link_gbps)!r}".encode())
    key = h.hexdigest()
    try:
        trace._spac_timeline_key = key
    except AttributeError:
        pass                      # slotted/frozen trace: hash again next call
    return key


def _freeze(*arrays):
    for a in arrays:
        a.setflags(write=False)


def _evict(cache: Dict) -> None:
    while len(cache) > _MAX_ENTRIES:
        cache.pop(next(iter(cache)))


@dataclasses.dataclass(frozen=True)
class Stage2Timeline:
    """Order-derived view of one trace for the stage-2 surrogate.

    ``order`` stably sorts raw arrival times; ``t`` is shifted to start at 0
    exactly as the engine's serial path did.  ``chain`` segments the ordered
    events by (src, dst) VOQ for the kernel occupancy pass."""

    order: np.ndarray     # [m] intp — stable argsort of trace.time_s
    t: np.ndarray         # [m] float64 — sorted, shifted arrival times
    dt: np.ndarray        # [m] float64 — inter-arrival gaps, dt[0] == 0
    src: np.ndarray       # [m] int64 — ordered source ports (mod n_ports)
    dst: np.ndarray       # [m] int64
    payload: np.ndarray   # [m] int64 — ordered payload bytes
    qid: np.ndarray       # [m] int64 — src * n_ports + dst
    chain: ChainIndex


@dataclasses.dataclass(frozen=True)
class Stage4Timeline:
    """Order-derived view of one (trace, structure) pair for the verifier.

    Holds the host-NIC serialisation (``switch_arrival_times`` — the m-step
    Python loop) and the heap-order ``lexsort``, both candidate-independent,
    plus the chain segmentation the fixed-point kernel path consumes."""

    t0: np.ndarray        # [m] float64 — raw generation times (trace order)
    order: np.ndarray     # [m] intp — lexsort by (switch arrival, packet id)
    now: np.ndarray       # [m] float64 — sorted switch-arrival times
    src_o: np.ndarray     # [m] int64 — ordered source ports (mod n_ports)
    dst_o: np.ndarray     # [m] int64
    wire: np.ndarray      # [m] int64 — payload + header bytes (trace order)
    wire_e: np.ndarray    # [m] int64 — wire bytes in event order
    t0_min: float
    chain: ChainIndex


def stage2_timeline(trace, n_ports: int) -> Stage2Timeline:
    key = ("s2", trace_key(trace), int(n_ports))
    hit = _STAGE2.get(key)
    if hit is not None:
        _COUNTS["stage2_hits"] += 1
        return hit
    _COUNTS["stage2_builds"] += 1
    _BUILDS_BY_KEY[key] = _BUILDS_BY_KEY.get(key, 0) + 1
    t = np.asarray(trace.time_s, np.float64)
    order = np.argsort(t, kind="stable")
    t0 = t.min() if t.size else 0.0
    t = t[order] - t0
    src = (np.asarray(trace.src, np.int64) % n_ports)[order]
    dst = (np.asarray(trace.dst, np.int64) % n_ports)[order]
    payload = np.asarray(trace.payload_bytes, np.int64)[order]
    dt = np.diff(t, prepend=t[:1]) if t.size else np.zeros(0)
    qid = src * n_ports + dst
    entry = Stage2Timeline(order=order, t=t, dt=dt, src=src, dst=dst,
                           payload=payload, qid=qid,
                           chain=build_chain_index(qid))
    _freeze(order, t, dt, src, dst, payload, qid)
    _STAGE2[key] = entry
    _evict(_STAGE2)
    return entry


def stage4_timeline(trace, n_ports: int, header_bytes: int,
                    prop_delay_s: float) -> Stage4Timeline:
    key = ("s4", trace_key(trace), int(n_ports), int(header_bytes),
           float(prop_delay_s))
    hit = _STAGE4.get(key)
    if hit is not None:
        _COUNTS["stage4_hits"] += 1
        return hit
    _COUNTS["stage4_builds"] += 1
    _BUILDS_BY_KEY[key] = _BUILDS_BY_KEY.get(key, 0) + 1
    t0 = np.asarray(trace.time_s, np.float64)
    src = np.asarray(trace.src, np.int64) % n_ports
    dst = np.asarray(trace.dst, np.int64) % n_ports
    payload = np.asarray(trace.payload_bytes, np.int64)
    wire = payload + int(header_bytes)
    link_bps = trace.link_gbps * 1e9
    m = t0.size
    arr = switch_arrival_times(t0, src, wire, link_bps, prop_delay_s, n_ports)
    order = np.lexsort((np.arange(m), arr))   # == the heap's (time, pkt) order
    now = arr[order]
    src_o, dst_o = src[order], dst[order]
    qid_o = src_o * n_ports + dst_o
    entry = Stage4Timeline(t0=t0, order=order, now=now, src_o=src_o,
                           dst_o=dst_o, wire=wire, wire_e=wire[order],
                           t0_min=float(t0.min()) if m else 0.0,
                           chain=build_chain_index(qid_o))
    # NB: t0 may alias trace.time_s (asarray no-copy), so it stays writable
    _freeze(order, now, src_o, dst_o, wire, entry.wire_e)
    _STAGE4[key] = entry
    _evict(_STAGE4)
    return entry


def stats() -> Dict[str, int]:
    """Build/hit counters plus per-key build counts (copies)."""
    out = dict(_COUNTS)
    out["builds_by_key"] = dict(_BUILDS_BY_KEY)
    return out


def counters() -> Dict[str, int]:
    """Flat build/hit counters only — ``stats()`` minus the per-key map.
    Service dashboards (``spac serve``) fold this into their own counter
    dict, where a nested ``builds_by_key`` blob would just be noise."""
    return dict(_COUNTS)


def clear() -> None:
    """Drop all memo entries and counters (test isolation)."""
    _STAGE2.clear()
    _STAGE4.clear()
    _BUILDS_BY_KEY.clear()
    for k in _COUNTS:
        _COUNTS[k] = 0
