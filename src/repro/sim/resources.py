"""Analytical FPGA resource & timing model, calibrated against Table I.

There is no Vitis HLS in this container, so the paper's post-synthesis numbers
are reproduced by a structural cost model: per-module LUT/FF/BRAM terms with
physically-motivated scaling (crossbar ∝ N²·width, VOQ BRAM ∝ queues·depth·
width, parser ∝ ports·header_bits, iSLIP critical path ∝ log N + width), whose
global scale factors are least-squares calibrated at import time against the
four SPAC rows of Table I.  The model plays the role Vitis reports play in the
paper's hardware back-annotation loop; `benchmarks/table1_resources.py`
prints model-vs-paper deltas so the calibration quality is visible.

Two fidelities (Fig. 6 reproduction):
  * ``estimate_quick``  — closed-form, uncalibrated residuals (DSE inner loop)
  * ``synthesize``      — calibrated model (the "post-synthesis report" role)
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional

import numpy as np

from repro.core.archspec import ForwardTableKind, SchedulerKind, SwitchArch, VOQKind
from repro.core.binding import BoundProtocol

__all__ = ["ResourceReport", "synthesize", "estimate_quick", "TABLE1_SPAC_ROWS", "ALVEO_U45N"]

BRAM_BITS = 36 * 1024  # RAMB36

#: Alveo U45N budget (xcu26): the C_Res default for the DSE
ALVEO_U45N = {"luts": 870_000, "ffs": 1_740_000, "brams": 1_344}


@dataclasses.dataclass(frozen=True)
class ResourceReport:
    luts: float
    ffs: float
    brams: float
    fmax_mhz: float
    pipeline_cycles: int
    latency_ns: float          # unloaded port-to-port (Table I definition)
    max_throughput_gbps: float # datawidth × fmax / II

    def as_dict(self) -> Dict[str, float]:
        return {"luts": self.luts, "ffs": self.ffs, "brams": self.brams}


# ---------------------------------------------------------------------------
# structural terms (uncalibrated)
# ---------------------------------------------------------------------------

def _lut_terms(arch: SwitchArch, header_bits: int, straddlers: int,
               key_bits: Optional[int] = None) -> float:
    """``key_bits`` is the forwarding lookup key width — the *routing field's*
    width when a decoded protocol is available (a 48 b Ethernet MAC costs a
    48 b hash/CAM key; a 4 b compressed address costs 4), falling back to the
    declared ``arch.addr_bits``.  FullLookup stays on ``arch.addr_bits``: its
    table is direct-indexed by the declared address space."""
    n, w = arch.n_ports, arch.bus_bits
    key_bits = arch.addr_bits if key_bits is None else key_bits
    parser = n * (80 + 0.2 * header_bits + 0.002 * header_bits * w + 60 * straddlers)
    if arch.fwd is ForwardTableKind.FULL_LOOKUP:
        fwd = 1.2 * (1 << arch.addr_bits) * n
    else:
        fwd = n * (150 + 60 * arch.hash_banks + 8 * key_bits)
    crossbar = 0.05 * w * n * n
    if arch.voq is VOQKind.NXN:
        voq = 30 * n * n
    else:
        voq = 45 * n * n + 25 * n * n  # pointer free-list + bitmap management
    sched = {
        SchedulerKind.RR: 3.0 * n * n,
        SchedulerKind.ISLIP: 10.0 * n * n * arch.islip_iters,
        SchedulerKind.EDRRM: 5.0 * n * n,
    }[arch.sched]
    meta = 0.5 * n * n * header_bits  # MetaData side-channel routing
    kern = sum(k.luts for k in arch.custom_kernels)
    return parser + fwd + crossbar + voq + sched + meta + kern


def _ff_terms(arch: SwitchArch, header_bits: int, straddlers: int = 0) -> float:
    n, w = arch.n_ports, arch.bus_bits
    stream_regs = 2.0 * n * w              # AXI-Stream pipeline registers
    meta_regs = 1.2 * n * header_bits
    # a field straddling a flit boundary needs its partial value held across
    # cycles — per-port state-retention registers (§III-B.1)
    retention = 32.0 * n * straddlers
    ctrl = 18.0 * n * n
    kern = sum(k.ffs for k in arch.custom_kernels)
    return stream_regs + meta_regs + retention + ctrl + kern


def _bram_terms(arch: SwitchArch, key_bits: Optional[int] = None) -> float:
    n, w, d = arch.n_ports, arch.bus_bits, arch.voq_depth
    if arch.voq is VOQKind.NXN:
        data_bits = n * n * d * w
        ptr_bits = 0
    else:
        data_bits = n * d * w               # central buffer: N×depth slots
        ptr_bits = n * n * d * (math.ceil(math.log2(max(n * d, 2))) + n)  # ptr + bitmap
    fwd_bits = 0.0
    if arch.fwd is ForwardTableKind.MULTIBANK_HASH:
        kb = arch.addr_bits if key_bits is None else key_bits
        fwd_bits = arch.hash_banks * arch.hash_depth * (kb + 8)
    io_fifos = 2 * n * w * 32               # ingress/egress skid buffers
    kern = sum(k.brams for k in arch.custom_kernels)
    return (data_bits + ptr_bits + fwd_bits + io_fifos) / BRAM_BITS + kern


def _critical_path_ns(arch: SwitchArch) -> float:
    n, w = arch.n_ports, arch.bus_bits
    log_n = math.log2(max(n, 2))
    paths = [
        2.2 + 0.0006 * w,                                   # parser bit-slicing
        2.0 if arch.fwd is ForwardTableKind.FULL_LOOKUP     # table access
        else 3.3 + 0.05 * math.log2(max(arch.hash_depth, 2)),
        (2.4 + 0.30 * log_n) if arch.voq is VOQKind.NXN     # queue control
        else (3.0 + 0.40 * log_n),
        {
            SchedulerKind.RR: 2.6 + 0.55 * log_n,
            SchedulerKind.ISLIP: 4.0 + 0.75 * log_n,        # find-first chain
            SchedulerKind.EDRRM: 3.1 + 0.65 * log_n,
        }[arch.sched],
    ]
    width_term = 0.0017 * w
    return max(paths) + width_term


def _pipeline_cycles(arch: SwitchArch, fmax_mhz: float) -> int:
    parser = 2
    fwd = 1 if arch.fwd is ForwardTableKind.FULL_LOOKUP else 2
    voq = 1 if arch.voq is VOQKind.NXN else 2
    sched = {SchedulerKind.RR: 1, SchedulerKind.EDRRM: 2,
             SchedulerKind.ISLIP: 1 + arch.islip_iters}[arch.sched]
    deparser = 2
    kern = sum(k.latency_cycles for k in arch.custom_kernels)
    base = parser + fwd + voq + sched + deparser + kern
    base += max(0, round(0.4 * (arch.n_ports - 8)))   # port-mux stages (Fig. 8 linearity)
    if fmax_mhz > 250:                                 # fine-grained pipelining regime
        base = int(base * 2)
    return base


# ---------------------------------------------------------------------------
# Table I calibration (SPAC rows)
# ---------------------------------------------------------------------------

def _spac_row(n_ports, bus, fwd, voq, sched, header_bits, depth):
    return SwitchArch(
        n_ports=n_ports, bus_bits=bus, fwd=fwd, voq=voq, sched=sched,
        voq_depth=depth, addr_bits=12 if fwd is ForwardTableKind.MULTIBANK_HASH else 4,
    ), header_bits


#: (config, paper LUT K, paper FF K, paper BRAM, paper fmax MHz, paper latency ns)
TABLE1_SPAC_ROWS = [
    # SPAC Ethernet 512b 8/16 ports (MBH, NxN, iSLIP), 336-bit Ethernet headers
    (_spac_row(8, 512, ForwardTableKind.MULTIBANK_HASH, VOQKind.NXN, SchedulerKind.ISLIP, 336, 320),
     80.1, 45.8, 304, 146, 68.3),
    (_spac_row(16, 512, ForwardTableKind.MULTIBANK_HASH, VOQKind.NXN, SchedulerKind.ISLIP, 336, 160),
     315.6, 135.1, 608, 137, 109.2),
    # SPAC Basic 256b (compressed 16-bit header, same architecture)
    (_spac_row(8, 256, ForwardTableKind.MULTIBANK_HASH, VOQKind.NXN, SchedulerKind.ISLIP, 16, 640),
     38.9, 30.5, 260, 165, 57.3),
    (_spac_row(16, 256, ForwardTableKind.MULTIBANK_HASH, VOQKind.NXN, SchedulerKind.ISLIP, 16, 320),
     96.1, 83.2, 498, 142, 85.5),
]


def _calibrate() -> Dict[str, float]:
    """Fit global multiplicative scales (geometric-mean of paper/model ratios)."""
    ratios = {"luts": [], "ffs": [], "brams": [], "path": []}
    for (arch, hdr), lut_k, ff_k, bram, fmax, _lat in TABLE1_SPAC_ROWS:
        ratios["luts"].append(lut_k * 1e3 / _lut_terms(arch, hdr, straddlers=2))
        ratios["ffs"].append(ff_k * 1e3 / _ff_terms(arch, hdr, straddlers=2))
        ratios["brams"].append(bram / max(_bram_terms(arch), 1e-9))
        ratios["path"].append((1e3 / fmax) / _critical_path_ns(arch))
    return {k: float(np.exp(np.mean(np.log(v)))) for k, v in ratios.items()}


_CALIB = _calibrate()


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------

def _plan_params(arch: SwitchArch, bound: Optional[BoundProtocol]):
    """(header_bits, straddlers, key_bits) priced from the decoded plan.

    The compiled ``ParserPlan`` carries exactly what the hardware pays for:
    total header bits, boundary-straddling fields (state retention), and the
    bound routing field's width (the hash/CAM key).  Without a bound the
    classic defaults apply (14 B Ethernet-ish header, keys = addr_bits)."""
    if bound is None:
        return 8 * 14, 0, None
    key_bits = (bound.routing_field.bits
                if "routing_key" in bound.semantics else None)
    return bound.protocol.header_bits, len(bound.plan.straddling_fields), key_bits


def synthesize(arch: SwitchArch, bound: Optional[BoundProtocol] = None) -> ResourceReport:
    """Calibrated model — the repo's stand-in for a Vitis post-synthesis report."""
    header_bits, straddlers, key_bits = _plan_params(arch, bound)
    luts = _CALIB["luts"] * _lut_terms(arch, header_bits, straddlers, key_bits)
    ffs = _CALIB["ffs"] * _ff_terms(arch, header_bits, straddlers)
    brams = _CALIB["brams"] * _bram_terms(arch, key_bits)
    path_ns = _CALIB["path"] * _critical_path_ns(arch)
    fmax = min(1e3 / path_ns, 350.0)                 # 350 MHz target clock cap
    cycles = _pipeline_cycles(arch, fmax)
    latency_ns = cycles / fmax * 1e3
    return ResourceReport(
        luts=luts, ffs=ffs, brams=brams, fmax_mhz=fmax,
        pipeline_cycles=cycles, latency_ns=latency_ns,
        max_throughput_gbps=arch.bus_bits * fmax * 1e6 / arch.ii / 1e9,
    )


def estimate_quick(arch: SwitchArch, bound: Optional[BoundProtocol] = None) -> ResourceReport:
    """Uncalibrated closed-form estimate (the DSE's cheap inner-loop fidelity).

    Differs from ``synthesize`` by rounded scale factors — the gap between the
    two fidelities is what Fig. 6's MAPE experiment measures.
    """
    header_bits, straddlers, key_bits = _plan_params(arch, bound)
    rounded = {k: float(f"{v:.1g}") for k, v in _CALIB.items()}
    luts = rounded["luts"] * _lut_terms(arch, header_bits, straddlers, key_bits)
    ffs = rounded["ffs"] * _ff_terms(arch, header_bits, straddlers)
    brams = rounded["brams"] * _bram_terms(arch, key_bits)
    path_ns = rounded["path"] * _critical_path_ns(arch)
    fmax = min(1e3 / path_ns, 350.0)
    cycles = _pipeline_cycles(arch, fmax)
    return ResourceReport(
        luts=luts, ffs=ffs, brams=brams, fmax_mhz=fmax,
        pipeline_cycles=cycles, latency_ns=cycles / fmax * 1e3,
        max_throughput_gbps=arch.bus_bits * fmax * 1e6 / arch.ii / 1e9,
    )
