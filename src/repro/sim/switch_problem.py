"""The FPGA-switch DSE problem — Algorithm 1 instantiated on the paper's domain.

Plugs the resource model, statistical surrogate and network simulator into the
generic Progressive-Constraint-Satisfaction engine (``repro.core.dse``).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.archspec import (AUTO, ArchRequest, BUS_WIDTHS,
                                 ForwardTableKind, SchedulerKind, SwitchArch,
                                 VOQKind, enumerate_candidates)
from repro.core.binding import BoundProtocol
from repro.core.dse import (
    DSEProblem,
    ResourceBudget,
    SLA,
    SurrogateResult,
    VERIFY_ENGINES,
    VerifyResult,
    depth_for_drop_rate,
    run_dse,
)
from repro.core.features import TraceFeatures, analyze
from repro.core.search import DesignSpace, Dim
from .backannotate import annotate
from .batched_netsim import run_netsim_batched
from .batched_surrogate import run_surrogate_batched
from .netsim import NetSimConfig, run_netsim
from .resources import ALVEO_U45N, BRAM_BITS, synthesize
from .surrogate import run_surrogate

__all__ = ["SwitchDSEProblem", "VERIFY_ENGINES", "optimize_switch",
           "ISLIP_ITER_RANGE", "HASH_BANK_RANGE", "HASH_DEPTH_RANGE"]

#: extended per-dimension ranges the parameterized ``space()`` sweeps beyond
#: the classic ``enumerate_candidates`` grid (which pins these to the
#: ``SwitchArch`` defaults) — the joint space for the paper's all-AUTO 8-port
#: request is 4*2*2*3*4*3*3 = 1728 points, squarely generational-search
#: territory
ISLIP_ITER_RANGE: Tuple[int, ...] = (1, 2, 3, 4)
HASH_BANK_RANGE: Tuple[int, ...] = (2, 4, 8)
HASH_DEPTH_RANGE: Tuple[int, ...] = (128, 256, 512)


def align_depth_to_bram(d_opt: int, bus_bits: int) -> int:
    """AlignToBRAM: round the depth up to a whole number of RAMB36 rows."""
    entries_per_bram = max(1, BRAM_BITS // bus_bits)
    return int(math.ceil(max(d_opt, 1) / entries_per_bram) * entries_per_bram)


class SwitchDSEProblem(DSEProblem):
    def __init__(
        self,
        request: ArchRequest,
        bound: BoundProtocol,
        trace,
        *,
        back_annotation: bool = True,
        headroom: float = 1.25,
        features: Optional[TraceFeatures] = None,
        verify_engine: str = "netsim",
    ):
        if verify_engine not in VERIFY_ENGINES:
            raise ValueError(f"unknown verify_engine {verify_engine!r}; "
                             f"known: {VERIFY_ENGINES}")
        self.request = request
        self.bound = bound
        self.trace = trace
        # campaigns hand every problem sharing a trace one precomputed analysis
        self.features: TraceFeatures = features if features is not None else analyze(trace)
        self.back_annotation = back_annotation
        self.headroom = headroom
        self.verify_engine = verify_engine

    # ------------------------------------------------------------- stage 1
    def candidates(self) -> List[SwitchArch]:
        return enumerate_candidates(self.request)

    # ------------------------------------------------------ search support
    def space(
        self,
        *,
        islip_iters: Tuple[int, ...] = ISLIP_ITER_RANGE,
        hash_banks: Tuple[int, ...] = HASH_BANK_RANGE,
        hash_depths: Tuple[int, ...] = HASH_DEPTH_RANGE,
    ) -> DesignSpace:
        """Parameterized design space for the generational search engine.

        Per-dimension ranges instead of the pre-built ``candidates()`` list:
        explicit (non-AUTO) request policies collapse to single-choice
        dimensions, and the micro-architecture knobs ``enumerate_candidates``
        pins (iSLIP iterations, hash banking/depth) become searchable.
        """
        req = self.request
        fwd_opts = [
            f for f in (list(ForwardTableKind) if req.fwd is AUTO else [req.fwd])
            if not (f is ForwardTableKind.FULL_LOOKUP and req.addr_bits > 16)
        ] or [ForwardTableKind.MULTIBANK_HASH]
        voq_opts = list(VOQKind) if self.request.voq is AUTO else [req.voq]
        sched_opts = list(SchedulerKind) if req.sched is AUTO else [req.sched]
        bus_opts = BUS_WIDTHS if req.bus_bits is AUTO else (req.bus_bits,)
        return DesignSpace((
            Dim("bus_bits", tuple(bus_opts)),
            Dim("fwd", tuple(fwd_opts)),
            Dim("voq", tuple(voq_opts)),
            Dim("sched", tuple(sched_opts)),
            Dim("islip_iters", tuple(islip_iters)),
            Dim("hash_banks", tuple(hash_banks)),
            Dim("hash_depth", tuple(hash_depths)),
        ))

    def decode(self, assignment) -> SwitchArch:
        """One space point -> concrete template.  Genes that are inert for
        the selected policies (iSLIP iterations under RR/EDRRM, hash banking
        under FullLookup) canonicalise to the ``SwitchArch`` defaults so
        distinct genomes encoding the same micro-architecture decode to one
        phenotype — the search driver dedupes on it."""
        req = self.request
        fwd, sched = assignment["fwd"], assignment["sched"]
        is_islip = sched is SchedulerKind.ISLIP
        is_hash = fwd is ForwardTableKind.MULTIBANK_HASH
        return SwitchArch(
            n_ports=req.n_ports,
            bus_bits=assignment["bus_bits"],
            fwd=fwd,
            voq=assignment["voq"],
            sched=sched,
            voq_depth=64 if req.voq_depth is AUTO else req.voq_depth,
            hash_banks=assignment["hash_banks"] if is_hash else 4,
            hash_depth=assignment["hash_depth"] if is_hash else 256,
            islip_iters=assignment["islip_iters"] if is_islip else 2,
            addr_bits=req.addr_bits,
            custom_kernels=req.custom_kernels,
        )

    def static_timing(self, a: SwitchArch) -> Tuple[float, float]:
        rep = synthesize(a, self.bound)
        # one flit of the smallest packet must clear the pipe before the next
        s_min_wire = self.features.s_min + self.bound.header_bytes
        flits = max(1, math.ceil(s_min_wire / (a.bus_bits / 8)))
        t_proc = a.ii * flits / (rep.fmax_mhz * 1e6)
        t_arrival = s_min_wire * 8 / (self.trace.link_gbps * 1e9)
        return t_proc, t_arrival

    # ------------------------------------------------------------- stage 2
    def surrogate(self, a: SwitchArch) -> SurrogateResult:
        return run_surrogate(a, self.bound, self.trace,
                             back_annotation=self.back_annotation,
                             i_burst=self.features.i_burst)

    def surrogate_batch(self, archs) -> List[SurrogateResult]:
        """Fan stage 2 out through the batched JAX engine: one jitted
        contention scan over the shared trace with all candidate parameters
        (bus width, η, pipeline, stalls) as batch axes."""
        if not archs:
            return []
        return run_surrogate_batched(
            list(archs), self.bound, self.trace,
            back_annotation=self.back_annotation,
            i_burst=self.features.i_burst).results()

    # ------------------------------------------------------------- stage 3
    def size_buffers(self, a: SwitchArch, q_occupancy: np.ndarray, eps: float) -> Optional[SwitchArch]:
        d_opt = depth_for_drop_rate(q_occupancy, eps)
        d = align_depth_to_bram(int(d_opt * self.headroom) + 1, a.bus_bits)
        return a.with_depth(d)

    def resources(self, a: SwitchArch) -> Dict[str, float]:
        rep = synthesize(a, self.bound)
        return {"luts": rep.luts, "ffs": rep.ffs, "brams": rep.brams, "bram": rep.brams}

    # ------------------------------------------------------------- stage 4
    def verify(self, a: SwitchArch) -> VerifyResult:
        if self.verify_engine == "cycle":
            from .engines import get_engine
            return get_engine("cycle").evaluate(
                a, self.bound, self.trace,
                back_annotation=self.back_annotation,
                i_burst=self.features.i_burst)
        return run_netsim(a, self.bound, self.trace,
                          back_annotation=self.back_annotation,
                          i_burst=self.features.i_burst)

    def verify_batch(self, archs) -> List[VerifyResult]:
        """Fan stage 4 out through the batched finite-buffer verifier: one
        jitted scan over the shared event timeline with every sized VOQ depth
        (and bus width, η, pipeline/arb cycles, stalls, f_clk) as a batch
        axis — drop counts and latencies exact vs the serial heapq path."""
        if not archs:
            return []
        if self.verify_engine == "cycle":
            return [self.verify(a) for a in archs]     # rung 4 has no batch form
        return run_netsim_batched(
            list(archs), self.bound, self.trace,
            back_annotation=self.back_annotation,
            i_burst=self.features.i_burst)

    def escalate(self, a: SwitchArch, v: VerifyResult) -> Optional[VerifyResult]:
        """``verify_engine="auto"``: the front was verified by batched netsim;
        climb the champion one rung to the cycle-accurate datapath.  The
        result lands in ``meta["escalated"]`` (ranking stays netsim-based, so
        "auto" and "netsim" produce the identical Pareto front)."""
        if self.verify_engine != "auto":
            return None
        from .engines import get_engine
        return get_engine("cycle").evaluate(
            a, self.bound, self.trace, hw=v.meta.get("hw"),
            back_annotation=self.back_annotation,
            i_burst=self.features.i_burst)

    def objectives(self, a: SwitchArch, v: VerifyResult) -> Tuple[float, float]:
        # Table II reports *average* latency; p99 is already an SLA constraint
        rep = synthesize(a, self.bound)
        return (v.mean_latency_ns, rep.brams)

    def diversity_key(self, a: SwitchArch):
        return (a.sched, a.voq)


def optimize_switch(
    request: ArchRequest,
    bound: BoundProtocol,
    trace,
    *,
    sla: Optional[SLA] = None,
    budget: Optional[ResourceBudget] = None,
    back_annotation: bool = True,
    delta: float = 0.2,
    top_k: int = 8,
    verify_engine: str = "netsim",
    verbose: bool = False,
):
    """One-call wrapper: trace in, Pareto-optimal switch out (Table II flow).

    Compatibility wrapper for the pre-Scenario API.  New code should build a
    ``repro.api.Scenario`` and call ``repro.api.run_scenario`` — a scenario is
    the same (request, protocol, trace, SLA, budget) bundle as a serializable
    config, and ``run_scenario`` runs exactly this path underneath.
    """
    problem = SwitchDSEProblem(request, bound, trace,
                               back_annotation=back_annotation,
                               verify_engine=verify_engine)
    sla = sla or SLA(p99_latency_ns=math.inf, drop_rate=1e-3)
    budget = budget or ResourceBudget(dict(ALVEO_U45N))
    result = run_dse(problem, sla, budget, delta=delta, top_k=top_k, verbose=verbose)
    return result, problem
