"""The FPGA-switch DSE problem — Algorithm 1 instantiated on the paper's domain.

Plugs the resource model, statistical surrogate and network simulator into the
generic Progressive-Constraint-Satisfaction engine (``repro.core.dse``).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.archspec import (AUTO, ArchRequest, BUS_WIDTHS,
                                 ForwardTableKind, SchedulerKind, SwitchArch,
                                 VOQKind, enumerate_candidates)
from repro.core.binding import BoundProtocol, SemanticBinding, bind
from repro.core.dse import (
    DSEProblem,
    ResourceBudget,
    SLA,
    SurrogateResult,
    USE_KERNEL_MODES,
    VERIFY_ENGINES,
    VerifyResult,
    depth_for_drop_rate,
    run_dse,
)
from repro.core.dsl import LayoutKey, ProtocolSpace
from repro.core.features import TraceFeatures, analyze
from repro.core.search import DesignSpace, Dim
from .backannotate import annotate
from .batched_netsim import run_netsim_batched
from .batched_surrogate import run_surrogate_batched
from .netsim import NetSimConfig, run_netsim
from .resources import ALVEO_U45N, BRAM_BITS, synthesize
from .surrogate import run_surrogate

__all__ = ["SwitchDSEProblem", "CoDesignCandidate", "VERIFY_ENGINES",
           "optimize_switch", "ISLIP_ITER_RANGE", "HASH_BANK_RANGE",
           "HASH_DEPTH_RANGE", "PROTO_DIM_PREFIX"]

#: genome dimension-name prefix separating protocol genes from architecture
#: genes in ``SwitchDSEProblem.space()`` (checkpoint signatures include it)
PROTO_DIM_PREFIX = "proto:"

#: extended per-dimension ranges the parameterized ``space()`` sweeps beyond
#: the classic ``enumerate_candidates`` grid (which pins these to the
#: ``SwitchArch`` defaults) — the joint space for the paper's all-AUTO 8-port
#: request is 4*2*2*3*4*3*3 = 1728 points, squarely generational-search
#: territory
ISLIP_ITER_RANGE: Tuple[int, ...] = (1, 2, 3, 4)
HASH_BANK_RANGE: Tuple[int, ...] = (2, 4, 8)
HASH_DEPTH_RANGE: Tuple[int, ...] = (128, 256, 512)


def align_depth_to_bram(d_opt: int, bus_bits: int) -> int:
    """AlignToBRAM: round the depth up to a whole number of RAMB36 rows."""
    entries_per_bram = max(1, BRAM_BITS // bus_bits)
    return int(math.ceil(max(d_opt, 1) / entries_per_bram) * entries_per_bram)


@dataclasses.dataclass(frozen=True, eq=False)
class CoDesignCandidate:
    """One joint (protocol layout, micro-architecture) phenotype.

    The co-design DSE's candidate: the decoded ``SwitchArch`` plus the
    decoded-and-bound protocol it was priced against.  Identity — for
    phenotype dedupe, surrogate caching and checkpoint equivalence — is
    ``(arch, layout)`` where ``layout`` is the canonical
    ``ProtocolSpace.layout_key`` tuple, so two genomes decoding to the same
    architecture *and* wire layout are one phenotype regardless of which
    memoized ``BoundProtocol`` instance they carry.  ``bound is None`` marks
    a statically infeasible layout (the stage-1 prune rejects it before any
    simulation)."""

    arch: SwitchArch
    bound: Optional[BoundProtocol]
    layout: LayoutKey
    infeasible: Optional[str] = None     # ProtocolSpace.feasible() reason

    def __hash__(self):
        return hash((self.arch, self.layout))

    def __eq__(self, other):
        return (isinstance(other, CoDesignCandidate)
                and self.arch == other.arch and self.layout == other.layout)

    @property
    def protocol(self):
        return self.bound.protocol if self.bound is not None else None

    def with_depth(self, depth: int) -> "CoDesignCandidate":
        return dataclasses.replace(self, arch=self.arch.with_depth(depth))

    def short(self) -> str:
        if self.bound is None:
            return f"{self.arch.short()} | <infeasible layout>"
        p = self.bound.protocol
        return f"{self.arch.short()} | {p.name} ({p.header_bytes}B hdr)"


class SwitchDSEProblem(DSEProblem):
    """The paper's FPGA-switch DSE problem.

    Classic mode: one fixed ``bound`` protocol, candidates are plain
    ``SwitchArch`` templates.  Co-design mode (``protocol_space`` given): the
    protocol layout joins the genome — candidates are ``CoDesignCandidate``
    phenotypes carrying their own decoded+bound protocol, ``space()`` splices
    the per-field width genes next to the architecture genes, and every
    stage prices/simulates against the candidate's own layout.  Decoded
    layouts are bound once and memoized on the canonical layout key, so N
    genomes sharing a layout compile one ``ParserPlan``.  ``require_seq=True``
    (for retransmitting deployments, cf. ``NetSimConfig.retransmit``) makes
    layouts without a ``seq_no`` field statically infeasible."""

    def __init__(
        self,
        request: ArchRequest,
        bound: Optional[BoundProtocol],
        trace,
        *,
        back_annotation: bool = True,
        headroom: float = 1.25,
        features: Optional[TraceFeatures] = None,
        verify_engine: str = "netsim",
        protocol_space: Optional[ProtocolSpace] = None,
        binding: Optional[SemanticBinding] = None,
        flit_bits: Optional[int] = None,
        require_seq: bool = False,
        mesh=None,
        use_kernel: str = "auto",
    ):
        if verify_engine not in VERIFY_ENGINES:
            raise ValueError(f"unknown verify_engine {verify_engine!r}; "
                             f"known: {VERIFY_ENGINES}")
        if not isinstance(use_kernel, bool) and use_kernel not in USE_KERNEL_MODES:
            raise ValueError(f"unknown use_kernel {use_kernel!r}; "
                             f"known: {USE_KERNEL_MODES} or a bool")
        self.request = request
        self.trace = trace
        # optional launch.mesh.MeshSpec: shards the stage-2/stage-4 batched
        # scans across the device mesh (bit-identical to the serial default)
        from repro.launch.mesh import MeshSpec
        self.mesh_spec = MeshSpec.coerce(mesh)
        self.protocol_space = protocol_space
        self.binding = binding if binding is not None else SemanticBinding()
        self.require_seq = require_seq
        self._bound_cache: Dict[LayoutKey, BoundProtocol] = {}
        self._bind_errors: Dict[LayoutKey, str] = {}
        if bound is None:
            if protocol_space is None:
                raise ValueError("SwitchDSEProblem needs a bound protocol or "
                                 "a protocol_space to decode one from")
            # reference point: the widest layout (bindable iff any layout is)
            self.flit_bits = flit_bits if flit_bits is not None else 256
            bound = self._bind_layout(protocol_space.max_widths())
        else:
            self.flit_bits = (flit_bits if flit_bits is not None
                              else bound.plan.flit_bits)
        self.bound = bound
        # campaigns hand every problem sharing a trace one precomputed analysis
        self.features: TraceFeatures = features if features is not None else analyze(trace)
        self.back_annotation = back_annotation
        self.headroom = headroom
        self.verify_engine = verify_engine
        # "auto"|"on"|"off"|bool — resolved per batch call so the
        # SPAC_NETSIM_KERNEL kill-switch works mid-session
        self.use_kernel = use_kernel
        payload = np.asarray(trace.payload_bytes)
        self._max_payload = int(payload.max()) if payload.size else 0
        self._variable_payload = bool(payload.size
                                      and int(payload.min()) != self._max_payload)

    # --------------------------------------------------- co-design plumbing
    def _bind_layout(self, widths) -> BoundProtocol:
        """Decode + bind one layout, memoized on the canonical layout key, so
        recompiling ``ParserPlan``s costs one ``bind`` per distinct layout no
        matter how many genomes the search sends through it."""
        key = self.protocol_space.layout_key(widths)
        bp = self._bound_cache.get(key)
        if bp is None:
            bp = bind(self.protocol_space.decode(widths), self.binding,
                      flit_bits=self.flit_bits)
            self._bound_cache[key] = bp
        return bp

    @property
    def co_design(self) -> bool:
        return self.protocol_space is not None

    @property
    def addressing_ports(self) -> int:
        """Endpoint count the routing field must address.  A standalone
        switch addresses its own ports; fabric tier sub-problems override
        this with the *fabric* host count — a tier switch's routing field
        must name any host in the network, not just its local ports."""
        return self.request.n_ports

    @staticmethod
    def _arch(c) -> SwitchArch:
        return c.arch if isinstance(c, CoDesignCandidate) else c

    def _bound_for(self, c) -> BoundProtocol:
        return c.bound if isinstance(c, CoDesignCandidate) else self.bound

    def _batch_bound(self, cands):
        """The ``bound`` argument for a batched engine call: the shared
        protocol in classic mode, a per-candidate list under co-design."""
        if not self.co_design:
            return self.bound
        return [self._bound_for(c) for c in cands]

    # ------------------------------------------------------------- stage 1
    def candidates(self) -> List[SwitchArch]:
        if self.co_design:
            raise ValueError(
                "co-design joint spaces are generational-search territory; "
                "run with a SearchSpec (space()/decode()) instead of "
                "exhaustive candidates()")
        return enumerate_candidates(self.request)

    # ------------------------------------------------------ search support
    def space(
        self,
        *,
        islip_iters: Tuple[int, ...] = ISLIP_ITER_RANGE,
        hash_banks: Tuple[int, ...] = HASH_BANK_RANGE,
        hash_depths: Tuple[int, ...] = HASH_DEPTH_RANGE,
    ) -> DesignSpace:
        """Parameterized design space for the generational search engine.

        Per-dimension ranges instead of the pre-built ``candidates()`` list:
        explicit (non-AUTO) request policies collapse to single-choice
        dimensions, and the micro-architecture knobs ``enumerate_candidates``
        pins (iSLIP iterations, hash banking/depth) become searchable.
        """
        req = self.request
        fwd_opts = [
            f for f in (list(ForwardTableKind) if req.fwd is AUTO else [req.fwd])
            if not (f is ForwardTableKind.FULL_LOOKUP and req.addr_bits > 16)
        ] or [ForwardTableKind.MULTIBANK_HASH]
        voq_opts = list(VOQKind) if self.request.voq is AUTO else [req.voq]
        sched_opts = list(SchedulerKind) if req.sched is AUTO else [req.sched]
        bus_opts = BUS_WIDTHS if req.bus_bits is AUTO else (req.bus_bits,)
        dims = [
            Dim("bus_bits", tuple(bus_opts)),
            Dim("fwd", tuple(fwd_opts)),
            Dim("voq", tuple(voq_opts)),
            Dim("sched", tuple(sched_opts)),
            Dim("islip_iters", tuple(islip_iters)),
            Dim("hash_banks", tuple(hash_banks)),
            Dim("hash_depth", tuple(hash_depths)),
        ]
        if self.protocol_space is not None:
            # the tentpole splice: per-field width genes ride the same genome
            dims.extend(Dim(PROTO_DIM_PREFIX + fname, choices)
                        for fname, choices in self.protocol_space.dims())
        return DesignSpace(tuple(dims))

    def _decode_arch(self, assignment, addr_bits: int) -> SwitchArch:
        req = self.request
        fwd, sched = assignment["fwd"], assignment["sched"]
        is_islip = sched is SchedulerKind.ISLIP
        is_hash = fwd is ForwardTableKind.MULTIBANK_HASH
        return SwitchArch(
            n_ports=req.n_ports,
            bus_bits=assignment["bus_bits"],
            fwd=fwd,
            voq=assignment["voq"],
            sched=sched,
            voq_depth=64 if req.voq_depth is AUTO else req.voq_depth,
            hash_banks=assignment["hash_banks"] if is_hash else 4,
            hash_depth=assignment["hash_depth"] if is_hash else 256,
            islip_iters=assignment["islip_iters"] if is_islip else 2,
            addr_bits=addr_bits,
            custom_kernels=req.custom_kernels,
        )

    def decode(self, assignment):
        """One space point -> concrete template.  Genes that are inert for
        the selected policies (iSLIP iterations under RR/EDRRM, hash banking
        under FullLookup) canonicalise to the ``SwitchArch`` defaults so
        distinct genomes encoding the same micro-architecture decode to one
        phenotype — the search driver dedupes on it.

        Under co-design, the ``proto:*`` genes decode to a protocol layout:
        statically infeasible layouts (``ProtocolSpace.feasible``) come back
        as ``CoDesignCandidate(bound=None)`` — ``static_timing`` prices them
        infeasible so stage 1 prunes without ever binding or simulating —
        and feasible ones bind through the layout-keyed memo, with the
        architecture's forwarding key width (``addr_bits``) taken from the
        decoded routing field, so CAM/hash pricing follows the layout."""
        if self.protocol_space is None:
            return self._decode_arch(assignment, self.request.addr_bits)
        widths = {fname: assignment[PROTO_DIM_PREFIX + fname]
                  for fname, _ in self.protocol_space.dims()}
        key = self.protocol_space.layout_key(widths)
        reason = self.protocol_space.feasible(
            widths,
            n_ports=self.addressing_ports,
            max_payload_bytes=self._max_payload,
            variable_payload=self._variable_payload,
            needs_seq=self.require_seq,
        )
        if reason is None:
            reason = self._bind_errors.get(key)
        if reason is None:
            try:
                bound = self._bind_layout(widths)
            except ValueError as e:
                # feasible() reasons about semantics, not explicit binding
                # overrides — an override naming a field this layout drops
                # (binding={'qos': 'qos'} with qos width 0) fails only at
                # bind time; treat it as one more static-infeasibility
                reason = str(e)
                self._bind_errors[key] = reason
        if reason is not None:
            arch = self._decode_arch(assignment, self.request.addr_bits)
            return CoDesignCandidate(arch=arch, bound=None, layout=key,
                                     infeasible=reason)
        arch = self._decode_arch(assignment, bound.routing_field.bits)
        return CoDesignCandidate(arch=arch, bound=bound, layout=key)

    def static_timing(self, c) -> Tuple[float, float]:
        if isinstance(c, CoDesignCandidate) and c.bound is None:
            return math.inf, 1.0           # infeasible layout: stage-1 prune
        a, bound = self._arch(c), self._bound_for(c)
        rep = synthesize(a, bound)
        # one flit of the smallest packet must clear the pipe before the next
        s_min_wire = self.features.s_min + bound.header_bytes
        flits = max(1, math.ceil(s_min_wire / (a.bus_bits / 8)))
        t_proc = a.ii * flits / (rep.fmax_mhz * 1e6)
        t_arrival = s_min_wire * 8 / (self.trace.link_gbps * 1e9)
        return t_proc, t_arrival

    # ------------------------------------------------------------- stage 2
    def surrogate(self, c) -> SurrogateResult:
        return run_surrogate(self._arch(c), self._bound_for(c), self.trace,
                             back_annotation=self.back_annotation,
                             i_burst=self.features.i_burst)

    def surrogate_batch(self, cands) -> List[SurrogateResult]:
        """Fan stage 2 out through the batched JAX engine: one jitted
        contention scan over the shared trace with all candidate parameters
        (bus width, η, pipeline, stalls — and, under co-design, per-candidate
        header wire-bytes) as batch axes."""
        cands = list(cands)
        if not cands:
            return []
        return run_surrogate_batched(
            [self._arch(c) for c in cands], self._batch_bound(cands),
            self.trace,
            back_annotation=self.back_annotation,
            i_burst=self.features.i_burst,
            mesh=self.mesh_spec, use_kernel=self.use_kernel).results()

    # ------------------------------------------------------------- stage 3
    def size_buffers(self, c, q_occupancy: np.ndarray, eps: float):
        d_opt = depth_for_drop_rate(q_occupancy, eps)
        d = align_depth_to_bram(int(d_opt * self.headroom) + 1,
                                self._arch(c).bus_bits)
        return c.with_depth(d)

    def resources(self, c) -> Dict[str, float]:
        rep = synthesize(self._arch(c), self._bound_for(c))
        return {"luts": rep.luts, "ffs": rep.ffs, "brams": rep.brams, "bram": rep.brams}

    # ------------------------------------------------------------- stage 4
    def verify(self, c) -> VerifyResult:
        if self.verify_engine == "cycle":
            from .engines import get_engine
            return get_engine("cycle").evaluate(
                self._arch(c), self._bound_for(c), self.trace,
                back_annotation=self.back_annotation,
                i_burst=self.features.i_burst)
        return run_netsim(self._arch(c), self._bound_for(c), self.trace,
                          back_annotation=self.back_annotation,
                          i_burst=self.features.i_burst)

    def verify_batch(self, cands) -> List[VerifyResult]:
        """Fan stage 4 out through the batched finite-buffer verifier: one
        jitted scan over the shared event timeline with every sized VOQ depth
        (and bus width, η, pipeline/arb cycles, stalls, f_clk) as a batch
        axis — drop counts and latencies exact vs the serial heapq path.
        Co-design batches mixing header widths partition internally by
        ``(n_ports, header_bytes)`` (the event timeline is width-dependent)."""
        cands = list(cands)
        if not cands:
            return []
        if self.verify_engine == "cycle":
            return [self.verify(c) for c in cands]     # rung 4 has no batch form
        return run_netsim_batched(
            [self._arch(c) for c in cands], self._batch_bound(cands),
            self.trace,
            back_annotation=self.back_annotation,
            i_burst=self.features.i_burst,
            mesh=self.mesh_spec, use_kernel=self.use_kernel)

    def escalate(self, c, v: VerifyResult) -> Optional[VerifyResult]:
        """``verify_engine="auto"``: the front was verified by batched netsim;
        climb the champion one rung to the cycle-accurate datapath.  The
        result lands in ``meta["escalated"]`` (ranking stays netsim-based, so
        "auto" and "netsim" produce the identical Pareto front)."""
        if self.verify_engine != "auto":
            return None
        from .engines import get_engine
        return get_engine("cycle").evaluate(
            self._arch(c), self._bound_for(c), self.trace, hw=v.meta.get("hw"),
            back_annotation=self.back_annotation,
            i_burst=self.features.i_burst)

    def objectives(self, c, v: VerifyResult) -> Tuple[float, float]:
        # Table II reports *average* latency; p99 is already an SLA constraint
        rep = synthesize(self._arch(c), self._bound_for(c))
        return (v.mean_latency_ns, rep.brams)

    def diversity_key(self, c):
        a = self._arch(c)
        return (a.sched, a.voq)


def optimize_switch(
    request: ArchRequest,
    bound: BoundProtocol,
    trace,
    *,
    sla: Optional[SLA] = None,
    budget: Optional[ResourceBudget] = None,
    back_annotation: bool = True,
    delta: float = 0.2,
    top_k: int = 8,
    verify_engine: str = "netsim",
    verbose: bool = False,
):
    """One-call wrapper: trace in, Pareto-optimal switch out (Table II flow).

    Compatibility wrapper for the pre-Scenario API.  New code should build a
    ``repro.api.Scenario`` and call ``repro.api.run_scenario`` — a scenario is
    the same (request, protocol, trace, SLA, budget) bundle as a serializable
    config, and ``run_scenario`` runs exactly this path underneath.
    """
    problem = SwitchDSEProblem(request, bound, trace,
                               back_annotation=back_annotation,
                               verify_engine=verify_engine)
    sla = sla or SLA(p99_latency_ns=math.inf, drop_rate=1e-3)
    budget = budget or ResourceBudget(dict(ALVEO_U45N))
    result = run_dse(problem, sla, budget, delta=delta, top_k=top_k, verbose=verbose)
    return result, problem
