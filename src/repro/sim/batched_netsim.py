"""Batched stage-4 verifier (§IV-A.1 at fan-out scale).

``run_netsim`` replays the event-driven, finite-buffer switch model one
candidate at a time through a Python heapq loop — the DSE's wall-clock
bottleneck once stage 2 went batched (PR 1) and campaigns began fanning many
scenarios at once (PR 2).  This module reformulates that verifier as one
jitted sorted-arrival ``jax.lax.scan`` over the *shared* event timeline in
which every per-candidate parameter — bus width, η, pipeline/arbitration
cycles, ingress stalls, f_clk and, crucially, the stage-3 **sized VOQ
depths** — is a batch axis.

The finite-VOQ trick: departures inside a VOQ are FIFO (each admitted
packet's end time is ≥ its predecessor's, because both share the input and
output port), so "queue (i,j) holds ``depth`` undeparted packets at time t"
is equivalent to "the packet admitted ``depth`` admissions ago has not
departed by t".  A ``[B, N², D]`` ring buffer of departure times indexed by
admission count therefore answers the fullness check in O(1) — the slot an
admission is about to overwrite *is* the depth-ago packet — and the scan
needs no per-queue heaps, no draining, and no data-dependent inner loops
(which dominate wall-clock on CPU XLA; measured ~15x over the O(1) form).

``VOQKind.SHARED`` adds a global cap (``N·depth`` packets in flight across
the whole buffer) whose count is *not* FIFO across queues, so it is settled
exactly in a second, host-side pass: the scan runs unconstrained by the cap,
then for each shared candidate the in-flight timeline ``G(t_k) =
admitted-before-k − #(ends ≤ t_k)`` is reconstructed vectorially (one sort +
searchsorted).  If the cap was never reached at an admitted event, the
unconstrained run *is* the constrained run (the cap could never have fired)
and the batched result is exact; the rare candidates whose cap does bind
fall back to the serial heapq oracle — exact by definition, and flagged in
``meta["shared_cap_fallback"]`` so throughput reports stay honest.

The scan runs in float64 under a scoped ``enable_x64`` and shares
``service_times`` / ``switch_arrival_times`` with the serial path, so
admission decisions, drop counts and departure times are bit-identical to
``run_netsim`` (``tests/test_batched_netsim.py`` asserts it per candidate).

Plain ``jax.lax`` rather than Pallas: the verifier is an irregular
gather/scatter state machine whose contract is float64 exactness against the
serial oracle — the opposite of the f32 tile-parallel shape Pallas rewards
(the stage-2 engine keeps a Pallas crossbar for that; see
``kernels/xbar/kernel.py``).

Retransmission (driver ARQ) inserts events dynamically and stays on the
serial path: ``run_netsim_batched`` raises ``NotImplementedError`` for
retransmitting configs so callers fall back honestly instead of silently
diverging.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from repro.analysis.retrace import track
from repro.core.archspec import SwitchArch, VOQKind
from repro.core.binding import BoundProtocol
from repro.core.dse import VerifyResult
from repro.kernels.netsim import netsim_fixed_point, resolve_use_kernel

from .backannotate import HardwareParams, annotate
from .netsim import NetSimConfig, run_netsim, service_times
from .timeline import stage4_timeline

__all__ = ["run_netsim_batched"]


def _verify_engine_impl(now, src, dst, svc, pipe, depth, mod, *, n_ports,
                        d_max):
    """One call: the finite-VOQ admission scan for a whole batch.

    Carries ``in_free``/``out_free`` [B, N] port availability, the [B, N², D]
    departure-time ring and the [B, N²] admission counters.  ``mod`` is the
    per-candidate ring modulus (``min(depth, m)`` — a queue can never hold
    more than the whole trace), so one static ``d_max`` serves mixed-depth
    batches.  Returns per-event departure times and admission flags; drop
    counts and latencies reduce on the host.  All carries are per-candidate
    (the timeline is replicated), so sharding the candidate axis with
    ``shard_map`` reproduces the monolithic scan bit-for-bit."""
    b_n = svc.shape[1]
    q_n = n_ports * n_ports
    brange = jnp.arange(b_n)

    def step(carry, xs):
        in_free, out_free, ring, tail = carry
        t_now, i, j, s = xs
        q = i * n_ports + j
        tq = tail[:, q]
        # the slot this admission would overwrite holds the departure time of
        # the packet `depth` admissions ago — FIFO order makes "that packet
        # has not left by now" ⟺ "the queue holds depth undeparted packets"
        oldest = ring[brange, q, tq % mod]
        full = (tq >= depth) & (oldest > t_now)
        admit = ~full
        start = jnp.maximum(jnp.maximum(t_now + pipe, in_free[:, i]),
                            out_free[:, j])
        end = start + s
        in_free = in_free.at[:, i].set(jnp.where(admit, end, in_free[:, i]))
        out_free = out_free.at[:, j].set(jnp.where(admit, end, out_free[:, j]))
        ring = ring.at[brange, q, tq % mod].set(jnp.where(admit, end, oldest))
        tail = tail.at[:, q].add(admit.astype(tail.dtype))
        return (in_free, out_free, ring, tail), (end, admit)

    ports0 = jnp.zeros((b_n, n_ports), svc.dtype)
    init = (ports0, ports0, jnp.zeros((b_n, q_n, d_max), svc.dtype),
            jnp.zeros((b_n, q_n), jnp.int32))
    _, (end, admit) = jax.lax.scan(step, init, (now, src, dst, svc))
    return end.T, admit.T                                  # [B, m] each


_verify_engine = track("netsim.engine",
                       jax.jit(_verify_engine_impl,
                               static_argnames=("n_ports", "d_max")))


@functools.lru_cache(maxsize=None)
def _sharded_verify_engine(mesh, n_ports, d_max):
    """The same admission scan, candidate axis sharded over the mesh.

    ``svc`` arrives [m, B] (scan xs layout) so its candidate axis is axis 1;
    ``pipe``/``depth``/``mod`` split along axis 0; the event timeline
    (``now``/``src``/``dst``) is replicated.  Rowwise-independent carries —
    no collectives — so each shard is bitwise the serial recurrence on its
    slice."""
    from jax.sharding import PartitionSpec as P

    from repro import compat

    names = tuple(mesh.axis_names)
    cand = P(names)
    rep = P()
    body = functools.partial(_verify_engine_impl, n_ports=n_ports,
                             d_max=d_max)
    name = (f"netsim.sharded[{'x'.join(map(str, mesh.devices.shape))} "
            f"{','.join(names)} n_ports={n_ports} d_max={d_max}]")
    return track(name, jax.jit(compat.shard_map(
        body, mesh,
        in_specs=(rep, rep, rep, P(None, names), cand, cand, cand),
        out_specs=(cand, cand))))


def _shared_cap_ok(admit_b: np.ndarray, sorted_ends_b: np.ndarray,
                   now: np.ndarray, cap: int) -> bool:
    """True iff the shared-buffer cap never binds in the unconstrained run.

    ``G(t_k) = admitted-before-k − #(admitted ends ≤ t_k)`` is the exact
    in-flight count the serial path's shared heap sees at event k (later
    admissions end strictly after t_k, so counting departures over *all*
    admitted ends is safe).  If G < cap at every per-queue-admitted event,
    the cap could never have dropped a packet and the unconstrained dynamics
    are the true dynamics.

    ``sorted_ends_b`` is the candidate's ascending departure times with
    dropped events mapped to +inf — sorted once for the whole batch by the
    caller (one ``np.sort(where(admit, end, inf), axis=1)``) instead of a
    fresh per-candidate ``np.sort`` inside the loop; the inf tail never
    lands left of a finite ``now``, so ``side="right"`` counts are
    unchanged."""
    g_before = np.cumsum(admit_b) - admit_b
    departed = np.searchsorted(sorted_ends_b, now, side="right")
    return not bool(np.any(admit_b & (g_before - departed >= cap)))


def _sorted_admitted_ends(end: np.ndarray, admit: np.ndarray,
                         rows: Sequence[int]) -> Dict[int, np.ndarray]:
    """Batched replacement for the per-candidate ``np.sort`` the shared-cap
    check used to do: one sort over the selected rows, dropped events pushed
    to +inf so every row shares one [len(rows), m] sort."""
    if not rows:
        return {}
    idx = np.asarray(rows)
    sorted_ends = np.sort(np.where(admit[idx], end[idx], np.inf), axis=1)
    return {int(b): sorted_ends[i] for i, b in enumerate(idx)}


def _empty_result(hw: HardwareParams) -> VerifyResult:
    return VerifyResult(
        p99_latency_ns=math.inf, mean_latency_ns=math.inf, drop_rate=0.0,
        throughput_gbps=0.0,
        meta={"latency_ns": np.zeros(0), "latency_full_ns": np.zeros(0),
              "delivered": 0, "offered": 0,
              "hw": hw, "engine": "batched_netsim"})


def _run_group(archs, bounds, trace, hw_list, cfg,
               mesh_spec=None) -> List[VerifyResult]:
    """All candidates share n_ports *and* header wire-bytes; every other
    parameter is a batch axis.  The header width is structural here — unlike
    stage 2, the event timeline (host-NIC serialisation) depends on wire
    size, so mixed-header co-design batches are partitioned by
    ``header_bytes`` upstream and each partition shares one timeline."""
    n = archs[0].n_ports
    tl4 = stage4_timeline(trace, n, bounds[0].header_bytes, cfg.prop_delay_s)
    t0 = tl4.t0
    m = t0.size
    wire = tl4.wire
    link_bps = trace.link_gbps * 1e9
    b_n = len(archs)
    if m == 0:
        return [_empty_result(hw) for hw in hw_list]

    svc = np.empty((b_n, m), np.float64)
    pipe = np.empty(b_n, np.float64)
    depth = np.empty(b_n, np.int64)
    for b, (arch, hw) in enumerate(zip(archs, hw_list)):
        svc[b], pipe[b] = service_times(arch, hw, wire, link_bps)
        depth[b] = arch.voq_depth

    order = tl4.order                          # == the heap's (time, pkt) order
    now = tl4.now
    # ring modulus: a queue never holds more than min(depth, m) packets; the
    # static ring size rounds up to a power of two so sweeps with nearby sized
    # depths reuse one compiled scan
    mod = np.minimum(np.maximum(depth, 1), m).astype(np.int32)
    # d_max comes from the *unpadded* depths (pad rows replicate row 0), so
    # the compiled ring size — and the scan it keys — is mesh-invariant
    d_max = 1 << int(int(mod.max()) - 1).bit_length()

    k = 1 if mesh_spec is None else mesh_spec.shard_axis
    if k > 1:
        from repro.launch.mesh import shard_pad
        svc_p = shard_pad(svc, k)
        with enable_x64():
            end, admit = _sharded_verify_engine(mesh_spec.build(), n, d_max)(
                jnp.asarray(now), jnp.asarray(tl4.src_o, jnp.int32),
                jnp.asarray(tl4.dst_o, jnp.int32),
                jnp.asarray(svc_p[:, order].T),
                jnp.asarray(shard_pad(pipe, k)),
                jnp.asarray(shard_pad(depth, k), jnp.int32),
                jnp.asarray(shard_pad(mod, k)))
    else:
        with enable_x64():
            end, admit = _verify_engine(
                jnp.asarray(now), jnp.asarray(tl4.src_o, jnp.int32),
                jnp.asarray(tl4.dst_o, jnp.int32), jnp.asarray(svc[:, order].T),
                jnp.asarray(pipe), jnp.asarray(depth, jnp.int32),
                jnp.asarray(mod), n_ports=n, d_max=d_max)
    end = np.asarray(end, np.float64)[:b_n]     # strip pad rows (no-op serial)
    admit = np.asarray(admit, bool)[:b_n]

    t0_min = tl4.t0_min
    wire_e = tl4.wire_e
    # one batched sort replaces the per-candidate np.sort the shared-cap
    # check used to run inside the loop below
    sorted_ends = _sorted_admitted_ends(
        end, admit,
        [b for b in range(b_n)
         if archs[b].voq is VOQKind.SHARED and int(depth[b]) >= 1])
    out: List[VerifyResult] = []
    for b, (arch, bound, hw) in enumerate(zip(archs, bounds, hw_list)):
        fallback = None
        if int(depth[b]) < 1:
            # degenerate depth<=0: serial semantics drop every packet; the
            # scan's ring check can't express an always-full queue
            fallback = "degenerate_depth"
        elif arch.voq is VOQKind.SHARED and not _shared_cap_ok(
                admit[b], sorted_ends[b], now, n * int(depth[b])):
            # the global cap binds for this candidate: the per-queue-only scan
            # diverges
            fallback = "shared_cap"
        if fallback is not None:
            # replay through the exact serial oracle, flagged for honesty
            v = run_netsim(arch, bound, trace, hw=hw, cfg=cfg)
            v.meta["shared_cap_fallback"] = fallback == "shared_cap"
            v.meta["fallback"] = fallback
            out.append(v)
            continue
        # reconstruct the serial path's packet-ordered latency array exactly
        latency = np.full(m, np.nan)
        latency[order] = np.where(
            admit[b], (end[b] + cfg.prop_delay_s - t0[order]) * 1e9, np.nan)
        done = ~np.isnan(latency)
        lat = latency[done]
        t_end = float(np.max(end[b], where=admit[b], initial=0.0))
        delivered_bits = float(int(wire_e[admit[b]].sum()) * 8)
        duration = max(t_end - t0_min, 1e-12)
        out.append(VerifyResult(
            p99_latency_ns=float(np.percentile(lat, 99)) if lat.size else math.inf,
            mean_latency_ns=float(lat.mean()) if lat.size else math.inf,
            drop_rate=int((~admit[b]).sum()) / max(m, 1),
            throughput_gbps=delivered_bits / duration / 1e9,
            meta={"latency_ns": lat, "latency_full_ns": latency,
                  "delivered": int(done.sum()),
                  "offered": int(m), "hw": hw, "engine": "batched_netsim"},
        ))
    return out


def _metrics_result(end_b, admit_b, order, t0, wire_e, t0_min, cfg, hw,
                    m) -> VerifyResult:
    """Reduce one candidate's (end, admit) to a VerifyResult — verbatim the
    default path's reduction, so kernel-path results are bit-identical."""
    latency = np.full(m, np.nan)
    latency[order] = np.where(
        admit_b, (end_b + cfg.prop_delay_s - t0[order]) * 1e9, np.nan)
    done = ~np.isnan(latency)
    lat = latency[done]
    t_end = float(np.max(end_b, where=admit_b, initial=0.0))
    delivered_bits = float(int(wire_e[admit_b].sum()) * 8)
    duration = max(t_end - t0_min, 1e-12)
    return VerifyResult(
        p99_latency_ns=float(np.percentile(lat, 99)) if lat.size else math.inf,
        mean_latency_ns=float(lat.mean()) if lat.size else math.inf,
        drop_rate=int((~admit_b).sum()) / max(m, 1),
        throughput_gbps=delivered_bits / duration / 1e9,
        meta={"latency_ns": lat, "latency_full_ns": latency,
              "delivered": int(done.sum()),
              "offered": int(m), "hw": hw, "engine": "batched_netsim"},
    )


def _run_group_kernel(archs, bounds, trace, hw_list, cfg,
                      mesh_spec=None) -> List[VerifyResult]:
    """The segmented-kernel twin of ``_run_group``.

    Replaces the [B, N², D] ring scan with the speculative fixed point
    (``kernels.netsim.netsim_fixed_point``): one lean port replay fused with
    a segmented all-admitted fullness check settles the whole batch in a
    single round when stage-3 sizing holds, and only dropping rows iterate.
    Identical dynamics rows — NSGA-II batches repeat genomes — collapse to
    one scan row and fan back out afterwards.  Departure times, admission
    flags and every reduced metric are bit-identical to the default path
    (same float64 arithmetic in the same order); rows the fixed point cannot
    settle exactly (degenerate depth, binding shared cap, no convergence)
    take the serial oracle, flagged in ``meta`` exactly like the default
    path's fallbacks."""
    n = archs[0].n_ports
    tl4 = stage4_timeline(trace, n, bounds[0].header_bytes, cfg.prop_delay_s)
    m = tl4.now.size
    b_n = len(archs)
    if m == 0:
        return [_empty_result(hw) for hw in hw_list]
    link_bps = trace.link_gbps * 1e9
    order, now, t0 = tl4.order, tl4.now, tl4.t0

    svc_e = np.empty((b_n, m), np.float64)      # event order (pre-permuted)
    pipe = np.empty(b_n, np.float64)
    depth = np.empty(b_n, np.int64)
    for b, (arch, hw) in enumerate(zip(archs, hw_list)):
        s, pipe[b] = service_times(arch, hw, tl4.wire, link_bps)
        svc_e[b] = s[order]
        depth[b] = arch.voq_depth

    out: List[Optional[VerifyResult]] = [None] * b_n
    fall: Dict[int, str] = {}
    # candidate dedup: rows with identical (service times, pipe, depth, VOQ
    # kind) have identical dynamics — one scan row serves them all
    slot_of: Dict[Tuple, int] = {}
    uniq_rows: List[int] = []
    rep = np.full(b_n, -1, np.int64)
    for b in range(b_n):
        if int(depth[b]) < 1:
            fall[b] = "degenerate_depth"
            continue
        key = (svc_e[b].tobytes(), float(pipe[b]), int(depth[b]),
               archs[b].voq is VOQKind.SHARED)
        slot = slot_of.setdefault(key, len(uniq_rows))
        if slot == len(uniq_rows):
            uniq_rows.append(b)
        rep[b] = slot

    uniq_res: List[Optional[VerifyResult]] = []
    if uniq_rows:
        ui = np.asarray(uniq_rows)
        with enable_x64():
            end, admit, conv, _rounds = netsim_fixed_point(
                now, tl4.src_o.astype(np.int32), tl4.dst_o.astype(np.int32),
                svc_e[ui], pipe[ui], depth[ui], n_ports=n, chain=tl4.chain,
                mesh_spec=mesh_spec)
        sorted_ends = _sorted_admitted_ends(
            end, admit,
            [i for i, b in enumerate(uniq_rows)
             if archs[b].voq is VOQKind.SHARED and bool(conv[i])])
        for i, b in enumerate(uniq_rows):
            if not bool(conv[i]):
                uniq_res.append(None)
                fall[b] = "kernel_unconverged"
                continue
            if archs[b].voq is VOQKind.SHARED and not _shared_cap_ok(
                    admit[i], sorted_ends[i], now, n * int(depth[b])):
                uniq_res.append(None)
                fall[b] = "shared_cap"
                continue
            uniq_res.append(_metrics_result(
                end[i], admit[i], order, t0, tl4.wire_e, tl4.t0_min, cfg,
                hw_list[b], m))

    for b in range(b_n):
        slot = int(rep[b])
        if slot >= 0 and uniq_res[slot] is not None:
            v = uniq_res[slot]
            # duplicates share the (read-only by convention) arrays but get
            # fresh meta dicts — callers annotate meta in place
            out[b] = dataclasses.replace(
                v, meta={**v.meta, "hw": hw_list[b]})
        else:
            # the fixed point defers to the serial oracle, flagged exactly
            # like the default path
            fb = fall.get(b) or fall.get(uniq_rows[slot], "kernel_unconverged")
            v = run_netsim(archs[b], bounds[b], trace, hw=hw_list[b], cfg=cfg)
            v.meta["shared_cap_fallback"] = fb == "shared_cap"
            v.meta["fallback"] = fb
            out[b] = v
    return out


def run_netsim_batched(
    archs: Sequence[SwitchArch],
    bound: Union[BoundProtocol, Sequence[BoundProtocol]],
    trace,
    *,
    hw: Optional[Sequence[HardwareParams]] = None,
    cfg: Optional[NetSimConfig] = None,
    back_annotation: bool = True,
    i_burst: float = 1.0,
    mesh=None,
    use_kernel=False,
) -> List[VerifyResult]:
    """Verify a whole sized-candidate batch against one shared trace.

    ``mesh`` is an optional ``repro.launch.mesh.MeshSpec``: more than one
    shard pads the candidate axis to the mesh extent and runs the admission
    scan under ``shard_map``, bit-identical to the serial default
    (``mesh=None``, byte-identical path).

    Results are index-aligned with ``archs`` and, candidate by candidate,
    bit-identical to ``run_netsim`` (same drop counts, same delivered set,
    same latency array).  ``bound`` is one ``BoundProtocol`` or a per-
    candidate sequence (the co-design DSE's mixed header widths); candidates
    may mix every architectural policy and any sized VOQ depth.  ``n_ports``
    and header wire-bytes are structural (the event timeline depends on
    both), so mixed batches are partitioned internally by
    ``(n_ports, header_bytes)`` and stitched back in input order.

    Memory: the scan carries a ``[B, N², min(max_depth, m)]`` float64 ring of
    departure times — ~34 MB for 64 candidates at 8 ports and depth 1024;
    chunk very large sweeps into multiple calls.

    ``use_kernel`` selects the segmented-kernel engine (``"auto"``/``"on"``/
    ``"off"`` or a bool; auto = on unless ``SPAC_NETSIM_KERNEL=off``): the
    speculative fixed point of ``repro.kernels.netsim`` replaces the ring
    scan, bit-identical per candidate, several times faster on sized sweeps
    (see ``benchmarks/netsim_kernel.py``).  The default stays the ring-scan
    path byte-for-byte.
    """
    if cfg is None:
        cfg = NetSimConfig()
    from repro.launch.mesh import MeshSpec
    mesh = MeshSpec.coerce(mesh)
    if mesh is not None and mesh.is_single():
        mesh = None
    archs = list(archs)
    bounds = (list(bound) if isinstance(bound, (list, tuple))
              else [bound] * len(archs))
    if len(bounds) != len(archs):
        raise ValueError(f"bound has {len(bounds)} entries for {len(archs)} "
                         "archs; they must be index-aligned")
    if cfg.retransmit and any(b.has("seq_no") for b in bounds):
        raise NotImplementedError(
            "driver-level retransmission inserts events dynamically; "
            "fall back to the serial run_netsim for retransmitting configs")
    if not archs:
        return []
    if hw is None:
        source = "cycle_sim" if back_annotation else "model"
        hw = [annotate(a, b, source=source, i_burst=i_burst)
              for a, b in zip(archs, bounds)]
    hw = list(hw)
    if len(hw) != len(archs):
        raise ValueError(f"hw has {len(hw)} entries for {len(archs)} archs; "
                         "they must be index-aligned")

    runner = (_run_group_kernel if resolve_use_kernel(use_kernel)
              else _run_group)
    groups: Dict[Tuple[int, int], List[int]] = {}
    for i, a in enumerate(archs):
        groups.setdefault((a.n_ports, bounds[i].header_bytes), []).append(i)
    if len(groups) == 1:
        return runner(archs, bounds, trace, hw, cfg, mesh_spec=mesh)
    out: List[Optional[VerifyResult]] = [None] * len(archs)
    for idx in groups.values():
        part = runner([archs[i] for i in idx], [bounds[i] for i in idx],
                      trace, [hw[i] for i in idx], cfg, mesh_spec=mesh)
        for i, v in zip(idx, part):
            out[i] = v
    return out
