"""Hardware back-annotation (§IV-A.1).

The paper injects performance metrics from physical FPGA runs into the
simulators.  Our "physical hardware" is the cycle-level JAX switch
(``repro.switch``): ``annotate(..., source="cycle_sim")`` runs a short
saturation trace through it and measures the achieved scheduler efficiency η
(matching quality) per (scheduler, ports, VOQ) family, caching the result.
``source="model"`` uses the analytic defaults instead (fast functional mode) —
the user-facing accuracy/speed toggle the paper describes.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

from repro.core.archspec import SchedulerKind, SwitchArch, ForwardTableKind
from repro.core.binding import BoundProtocol
from .resources import ResourceReport, synthesize

__all__ = ["HardwareParams", "annotate", "analytic_eta"]


@dataclasses.dataclass(frozen=True)
class HardwareParams:
    fclk_hz: float
    pipeline_cycles: int
    eta: float                 # scheduler/matching efficiency in (0, 1]
    arb_cycles: float          # mean extra arbitration wait per packet
    ingress_stall_cycles: float  # e.g. MultiBankHash conflict stalls
    report: ResourceReport


def analytic_eta(arch: SwitchArch, i_burst: float = 1.0) -> float:
    n = arch.n_ports
    if arch.sched is SchedulerKind.ISLIP:
        eta = min(0.96 + 0.02 * (arch.islip_iters - 2), 0.99)
        eta -= 0.05 * min(i_burst / 20.0, 1.0)   # per-cycle re-arbitration under bursts
    elif arch.sched is SchedulerKind.RR:
        eta = 0.80 + 0.6 / n
    else:  # EDRRM: exhaustive service amortises arbitration over bursts
        eta = 0.86 + min(0.012 * i_burst, 0.12)
    return float(min(max(eta, 0.5), 0.995))


def _arb_cycles(arch: SwitchArch, i_burst: float) -> float:
    n = arch.n_ports
    if arch.sched is SchedulerKind.RR:
        return 0.35 * n
    if arch.sched is SchedulerKind.ISLIP:
        return arch.islip_iters + 1.0
    return 2.0 + 0.25 * n / max(i_burst, 1.0)


_ETA_CACHE: Dict[Tuple, float] = {}


def _measured_eta(arch: SwitchArch, bound: BoundProtocol, fclk_hz: float) -> float:
    """Run a short saturation trace through the cycle-level switch; measure the
    achieved output utilisation = matching efficiency."""
    key = (arch.sched, arch.n_ports, arch.voq, arch.islip_iters)
    if key in _ETA_CACHE:
        return _ETA_CACHE[key]
    import numpy as np
    from repro.traces.base import Trace
    from repro.switch.switch import simulate

    rng = np.random.default_rng(0)
    n = arch.n_ports
    # saturated single-flit uniform traffic: every port offers a packet per cycle
    cycles = 1200
    payload = max(1, arch.bus_bits // 8 - bound.header_bytes)
    per_cycle = 1.0 / fclk_hz
    times, srcs, dsts = [], [], []
    for s in range(n):
        t = np.arange(cycles) * per_cycle
        times.append(t)
        srcs.append(np.full(cycles, s))
        d = rng.integers(0, n - 1, size=cycles)
        dsts.append(np.where(d >= s, d + 1, d))
    tr = Trace("calib", np.concatenate(times), np.concatenate(srcs),
               np.concatenate(dsts), np.full(n * cycles, payload), n)
    res = simulate(arch, bound, tr, fclk_hz=fclk_hz, max_cycles=cycles + 256)
    eta = res.delivered_copies / float(n * cycles)
    eta = float(min(max(eta, 0.4), 1.0))
    _ETA_CACHE[key] = eta
    return eta


def annotate(
    arch: SwitchArch,
    bound: Optional[BoundProtocol] = None,
    *,
    source: str = "model",
    i_burst: float = 1.0,
) -> HardwareParams:
    rep = synthesize(arch, bound)
    fclk = rep.fmax_mhz * 1e6
    if source == "cycle_sim" and bound is not None:
        eta = _measured_eta(arch, bound, fclk)
    else:
        eta = analytic_eta(arch, i_burst)
    stall = 0.3 if arch.fwd is ForwardTableKind.MULTIBANK_HASH else 0.0
    return HardwareParams(
        fclk_hz=fclk,
        pipeline_cycles=rep.pipeline_cycles,
        eta=eta,
        arb_cycles=_arb_cycles(arch, i_burst),
        ingress_stall_cycles=stall,
        report=rep,
    )
