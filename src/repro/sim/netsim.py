"""Event-driven network simulator — the ns-3 role in the SPAC stack (§IV-A.1).

Mirrors ns-3's four-layer node abstraction:

  application layer   → trace generators (``repro.traces``)
  host network stack  → the Custom Protocol Adapter: DSL-compiled driver
                        (header serialisation, optional seq_no retransmission)
  device layer        → host NIC serialisation at link rate
  channel layer       → propagation delay

The switch is a "SPAC Port Device" node modelling forwarding-table lookup,
finite VOQ buffering (drops!) and scheduling, parameterised by hardware
back-annotation (fclk, pipeline depth, η) so results reflect the generated
hardware.  This is the DSE's stage-4 verifier and the Table II harness.

``run_netsim`` is the serial reference (one heapq replay per candidate); the
batched fan-out lives in ``repro.sim.batched_netsim`` and shares the
``service_times`` / ``switch_arrival_times`` helpers below so the two paths
cannot drift numerically.
"""

from __future__ import annotations

import dataclasses
import heapq
import math
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.archspec import SwitchArch, VOQKind
from repro.core.binding import BoundProtocol
from repro.core.dse import VerifyResult
from .backannotate import HardwareParams, annotate

__all__ = ["NetSimConfig", "run_netsim", "service_times", "switch_arrival_times"]


@dataclasses.dataclass
class NetSimConfig:
    prop_delay_s: float = 50e-9        # channel propagation (10 m fibre)
    retransmit: bool = False           # driver-level ARQ if protocol has seq_no
    rto_s: float = 20e-6
    max_retries: int = 3


def service_times(
    arch: SwitchArch, hw: HardwareParams, wire: np.ndarray, link_bps: float,
) -> Tuple[np.ndarray, float]:
    """Per-packet output occupancy + pipeline latency for one candidate.

    The occupancy is the slower of the switch datapath and the egress link;
    matching efficiency η caps the sustainable egress rate (a scheduler that
    matches 76% of slots delivers at most 0.76×line rate).  One home for the
    formula: both the serial verifier and the batched engine call this, so
    their service times are bit-identical.
    """
    fclk = hw.fclk_hz
    flit_bytes = arch.bus_bits // 8
    size_flits = np.maximum(1, -(-wire // flit_bytes))
    svc_switch = size_flits / fclk + hw.ingress_stall_cycles / fclk
    svc_egress = wire * 8 / (link_bps * hw.eta)
    svc = np.maximum(svc_switch / hw.eta, svc_egress)
    pipe_s = (hw.pipeline_cycles + hw.arb_cycles) / fclk
    return svc, pipe_s


def switch_arrival_times(
    t0: np.ndarray, src: np.ndarray, wire: np.ndarray, link_bps: float,
    prop_delay_s: float, n_ports: int,
) -> np.ndarray:
    """Host stack + NIC model: serialise each packet onto its source's link in
    generation order, then propagate.  Candidate-independent — the batched
    verifier computes this once and shares the event timeline across the
    whole batch."""
    host_free = np.zeros(n_ports)
    arr = np.empty(t0.size, np.float64)
    for k in np.argsort(t0, kind="stable"):
        start = max(t0[k], host_free[src[k]])
        tx = wire[k] * 8 / link_bps
        host_free[src[k]] = start + tx
        arr[k] = start + tx + prop_delay_s
    return arr


def run_netsim(
    arch: SwitchArch,
    bound: BoundProtocol,
    trace,
    *,
    hw: Optional[HardwareParams] = None,
    cfg: Optional[NetSimConfig] = None,
    back_annotation: bool = True,
    i_burst: float = 1.0,
) -> VerifyResult:
    if cfg is None:
        cfg = NetSimConfig()     # per call: NetSimConfig is mutable
    if hw is None:
        hw = annotate(arch, bound, source="cycle_sim" if back_annotation else "model",
                      i_burst=i_burst)
    n = arch.n_ports
    link_bps = trace.link_gbps * 1e9
    can_retx = cfg.retransmit and bound.has("seq_no")

    t0 = np.asarray(trace.time_s, np.float64)
    src = np.asarray(trace.src, np.int64) % n
    dst = np.asarray(trace.dst, np.int64) % n
    payload = np.asarray(trace.payload_bytes, np.int64)
    m = t0.size
    wire = payload + bound.header_bytes
    svc, pipe_s = service_times(arch, hw, wire, link_bps)

    # host stack + NIC: serialise onto the link, then propagate
    arr = switch_arrival_times(t0, src, wire, link_bps, cfg.prop_delay_s, n)
    events: List[Tuple[float, int, int]] = [(arr[k], int(k), 0) for k in range(m)]
    heapq.heapify(events)         # pops in (switch_arrival_time, pkt) order

    in_free = np.zeros(n)
    out_free = np.zeros(n)
    q_dep: Dict[Tuple[int, int], List[float]] = {}     # per-VOQ departure heap
    shared_dep: List[float] = []                       # shared-buffer departures
    depth = arch.voq_depth
    shared_cap = n * depth

    latency = np.full(m, np.nan)
    drops = 0
    delivered_bits = 0.0
    t_end = 0.0

    while events:
        now, k, attempt = heapq.heappop(events)
        i, j = int(src[k]), int(dst[k])
        q = (i, j)
        dep = q_dep.setdefault(q, [])
        while dep and dep[0] <= now:
            heapq.heappop(dep)
        full = len(dep) >= depth
        if arch.voq is VOQKind.SHARED:
            while shared_dep and shared_dep[0] <= now:
                heapq.heappop(shared_dep)
            full = full or len(shared_dep) >= shared_cap
        if full:
            if can_retx and attempt < cfg.max_retries:
                heapq.heappush(events, (now + cfg.rto_s, k, attempt + 1))
            else:
                drops += 1
            continue
        start = max(now + pipe_s, in_free[i], out_free[j])
        end = start + svc[k]
        in_free[i] = end
        out_free[j] = end
        heapq.heappush(dep, end)
        if arch.voq is VOQKind.SHARED:
            heapq.heappush(shared_dep, end)
        latency[k] = (end + cfg.prop_delay_s - t0[k]) * 1e9
        delivered_bits += wire[k] * 8
        t_end = max(t_end, end)

    done = ~np.isnan(latency)
    lat = latency[done]
    duration = max(t_end - (float(t0.min()) if m else 0.0), 1e-12)
    return VerifyResult(
        p99_latency_ns=float(np.percentile(lat, 99)) if lat.size else math.inf,
        mean_latency_ns=float(lat.mean()) if lat.size else math.inf,
        drop_rate=drops / max(m, 1),
        throughput_gbps=delivered_bits / duration / 1e9,
        meta={"latency_ns": lat, "latency_full_ns": latency,
              "delivered": int(done.sum()), "offered": int(m),
              "hw": hw, "engine": "netsim"},
    )
