"""Statistical surrogate model (§IV-A.2).

Exploits the determinism of the FPGA datapath (fixed II, fixed pipeline
latency) to replace signal-level simulation with an event-driven *transaction*
model: packets flow through a greedy crossbar in arrival order, constrained by
input/output port availability and a back-annotated scheduler efficiency η.
Processes 10⁵-packet traces in milliseconds — the DSE's stage-2 engine.

Outputs (paper: "line-rate feasibility, BRAM lower bounds from peak VOQ
occupancy, and latency distributions"):
  * per-packet latency distribution (deterministic pipeline + queueing),
  * per-queue occupancy samples at arrival instants (PASTA) → stage-3 sizing,
  * sustained throughput.
"""

from __future__ import annotations

import numpy as np

from repro.core.archspec import SwitchArch, VOQKind
from repro.core.binding import BoundProtocol
from repro.core.dse import SurrogateResult
from .backannotate import HardwareParams, annotate

__all__ = ["run_surrogate"]


def run_surrogate(
    arch: SwitchArch,
    bound: BoundProtocol,
    trace,
    *,
    hw: HardwareParams = None,
    back_annotation: bool = False,
    i_burst: float = 1.0,
) -> SurrogateResult:
    if hw is None:
        hw = annotate(arch, bound, source="cycle_sim" if back_annotation else "model",
                      i_burst=i_burst)
    n = arch.n_ports
    fclk = hw.fclk_hz

    t = np.asarray(trace.time_s, np.float64)
    src = np.asarray(trace.src, np.int64) % n
    dst = np.asarray(trace.dst, np.int64) % n
    payload = np.asarray(trace.payload_bytes, np.int64)
    order = np.argsort(t, kind="stable")
    t, src, dst, payload = t[order] - t.min(), src[order], dst[order], payload[order]
    m = t.size

    flit_bytes = arch.bus_bits // 8
    size_flits = np.maximum(1, -(-(payload + bound.header_bytes) // flit_bytes))
    svc = (size_flits + hw.ingress_stall_cycles) / (fclk * hw.eta)   # seconds

    # greedy crossbar: arrival-order admission against input/output availability
    in_free = np.zeros(n)
    out_free = np.zeros(n)
    dep_end = np.zeros(m)
    for k in range(m):
        i, j = src[k], dst[k]
        start = max(t[k], in_free[i], out_free[j])
        end = start + svc[k]
        in_free[i] = end
        out_free[j] = end
        dep_end[k] = end

    pipe_s = (hw.pipeline_cycles + hw.arb_cycles) / fclk
    latency_ns = (dep_end - t + pipe_s) * 1e9

    # per-(src,dst) queue occupancy at arrival instants (PASTA sampling)
    qid = src * n + dst
    occupancy = np.zeros(m, dtype=np.int64)
    for q in np.unique(qid):
        sel = np.nonzero(qid == q)[0]
        arr_q = t[sel]
        dep_q = dep_end[sel]          # FIFO within a queue -> nondecreasing
        departed = np.searchsorted(dep_q, arr_q, side="right")
        occupancy[sel] = np.arange(sel.size) - departed

    if arch.voq is VOQKind.SHARED:
        # shared central buffer: occupancy of the data store (packets in flight)
        departed_glob = np.searchsorted(np.sort(dep_end), t, side="right")
        shared_occ = np.arange(m) - departed_glob
    else:
        shared_occ = None

    duration = max(dep_end.max() - t.min(), 1e-12)
    thru = float((payload + bound.header_bytes).sum() * 8 / duration / 1e9)
    return SurrogateResult(
        q_occupancy=occupancy.astype(np.float64),
        latency_ns=latency_ns,
        throughput_gbps=thru,
        meta={
            "hw": hw,
            "shared_occupancy": shared_occ,
            "q_occ_max": int(occupancy.max()) if m else 0,
            "line_rate_feasible": bool(svc.mean() * fclk <= arch.ii * size_flits.mean() * 1.25),
        },
    )
