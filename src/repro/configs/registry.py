"""Architecture registry: full configs (dry-run) + smoke variants (CPU tests)."""

from __future__ import annotations

import dataclasses
from typing import Dict

from repro.models.config import ModelConfig

__all__ = ["register", "get_config", "get_smoke", "ARCHS", "smoke_variant"]

ARCHS: Dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    ARCHS[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    _ensure_loaded()
    return ARCHS[name]


def smoke_variant(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config: 2 layers, narrow widths, tiny tables."""
    kw = dict(
        name=cfg.name + "-smoke",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=max(1, min(cfg.n_kv_heads, 2)) if cfg.n_kv_heads < cfg.n_heads else 4,
        head_dim=32,
        d_ff=256 if cfg.d_ff else 0,
        vocab=512,
        remat="none",
    )
    if cfg.is_moe:
        kw.update(moe_experts=8, moe_topk=2)
    if cfg.has_ssm:
        kw.update(ssm_state=16, ssm_headdim=32, ssm_expand=2)
    if cfg.sliding_window:
        kw.update(sliding_window=64)
    if cfg.mrope:
        kw.update(mrope_sections=(4, 6, 6))   # scaled to the reduced head_dim
    return dataclasses.replace(cfg, **kw)


def get_smoke(name: str) -> ModelConfig:
    return smoke_variant(get_config(name))


def _ensure_loaded():
    if not ARCHS:
        from . import (kimi_k2_1t_a32b, llama3_2_1b, mamba2_780m,  # noqa: F401
                       minicpm_2b, minitron_8b, mistral_nemo_12b, musicgen_large,
                       hymba_1_5b, qwen2_vl_72b, qwen3_moe_235b_a22b)


def all_arch_names():
    _ensure_loaded()
    return sorted(ARCHS.keys())
