"""hymba-1.5b [hybrid] — 32L d1600 25H(kv5) ff5504 v32001 ssm_state=16;
parallel attention + mamba heads.  [arXiv:2411.13676; hf]"""
from repro.models.config import ModelConfig
from .registry import register

CONFIG = register(ModelConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5, head_dim=64,
    d_ff=5504, vocab=32001, ssm_state=16, ssm_headdim=64, ssm_expand=2,
    sliding_window=1024, rope_theta=1e4,
))
