"""minicpm-2b [dense] — 40L d2304 36H(kv36 ≡ MHA) ff5760 v122753 (WSD
schedule, llama-like arch).  [arXiv:2404.06395; hf]"""
from repro.models.config import ModelConfig
from .registry import register

CONFIG = register(ModelConfig(
    name="minicpm-2b", family="dense",
    n_layers=40, d_model=2304, n_heads=36, n_kv_heads=36, head_dim=64,
    d_ff=5760, vocab=122753, rope_theta=1e4,
))
