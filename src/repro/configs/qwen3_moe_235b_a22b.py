"""qwen3-moe-235b-a22b [moe] — 94L d4096 64H(kv4) expert_ff1536 v151936,
128 experts top-8.  [hf:Qwen/Qwen3-30B-A3B family; hf]"""
from repro.models.config import ModelConfig
from .registry import register

CONFIG = register(ModelConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4, head_dim=128,
    d_ff=1536, vocab=151936, moe_experts=128, moe_topk=8,
    rope_theta=1e6,
))
