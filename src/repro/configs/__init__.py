"""Assigned architecture configs (10) + smoke variants + input shapes."""
from .registry import ARCHS, all_arch_names, get_config, get_smoke, smoke_variant
from .shapes import SHAPES, ShapeSpec, shapes_for

__all__ = ["ARCHS", "SHAPES", "ShapeSpec", "all_arch_names", "get_config",
           "get_smoke", "shapes_for", "smoke_variant"]
