"""qwen2-vl-72b [vlm] — 80L d8192 64H(kv8) ff29568 v152064, M-RoPE, dynamic
resolution.  Vision frontend is a STUB per assignment: input_specs() provides
precomputed patch embeddings + 3-component positions.  [arXiv:2409.12191; hf]"""
from repro.models.config import ModelConfig
from .registry import register

CONFIG = register(ModelConfig(
    name="qwen2-vl-72b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=29568, vocab=152064, mrope=True, mrope_sections=(16, 24, 24),
    frontend="embeddings", rope_theta=1e6,
))
