"""minitron-8b [dense] — 32L d4096 32H(kv8) ff16384 v256000 (pruned
nemotron).  [arXiv:2407.14679; hf]"""
from repro.models.config import ModelConfig
from .registry import register

CONFIG = register(ModelConfig(
    name="minitron-8b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=16384, vocab=256000, rope_theta=1e6,
))
