"""Assigned input-shape set (4 shapes × 10 archs = 40 dry-run cells)."""

from __future__ import annotations

import dataclasses
from typing import List

from repro.models.config import ModelConfig

__all__ = ["ShapeSpec", "SHAPES", "shapes_for"]


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode | long_decode

    @property
    def lowers(self) -> str:
        return "train_step" if self.kind == "train" else (
            "prefill" if self.kind == "prefill" else "serve_step")


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "long_decode"),
}


def shapes_for(cfg: ModelConfig) -> List[ShapeSpec]:
    """long_500k needs sub-quadratic sequence mixing: SSM/hybrid only (the 8
    pure-full-attention archs skip it — DESIGN.md §Arch-applicability)."""
    out = [SHAPES["train_4k"], SHAPES["prefill_32k"], SHAPES["decode_32k"]]
    if cfg.family in ("ssm", "hybrid"):
        out.append(SHAPES["long_500k"])
    return out
