"""mamba2-780m [ssm] — 48L d1536 attn-free v50280 ssm_state=128 (SSD).
[arXiv:2405.21060; unverified]"""
from repro.models.config import ModelConfig
from .registry import register

CONFIG = register(ModelConfig(
    name="mamba2-780m", family="ssm",
    n_layers=48, d_model=1536, n_heads=1, n_kv_heads=1, head_dim=64,
    d_ff=0, vocab=50280, ssm_state=128, ssm_headdim=64, ssm_expand=2,
))
