"""kimi-k2-1t-a32b [moe] — 61L d7168 64H(kv8) expert_ff2048 v163840,
384 experts top-8 (trillion-param MoE).  [arXiv:2501.kimi2; unverified]"""
from repro.models.config import ModelConfig
from .registry import register

CONFIG = register(ModelConfig(
    name="kimi-k2-1t-a32b", family="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=2048, vocab=163840, moe_experts=384, moe_topk=8,
    rope_theta=5e6,
))
