"""musicgen-large [audio] — 48L d2048 32H(kv32) ff8192 v2048, decoder-only
over EnCodec tokens.  EnCodec frontend is a STUB per assignment:
input_specs() provides precomputed frame embeddings.  [arXiv:2306.05284; hf]"""
from repro.models.config import ModelConfig
from .registry import register

CONFIG = register(ModelConfig(
    name="musicgen-large", family="audio",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
    d_ff=8192, vocab=2048, frontend="embeddings", rope_theta=1e4,
))
