"""JAX version-compatibility shims.

The codebase targets current JAX APIs; these wrappers keep it running on the
0.4.x line too.  Every use of a recent-API entry point routes through here
(meshes route through ``repro.launch.mesh.compat_make_mesh``).
"""

from __future__ import annotations

import jax

__all__ = ["has_partial_manual_shard_map", "shard_map"]


def has_partial_manual_shard_map() -> bool:
    """True when shard_map supports being manual over a subset of mesh axes.

    The 0.4.x line nominally has ``auto=`` but its SPMD partitioner CHECK-
    fails on manual-subgroup collectives (all_gather inside the compressed
    pod protocol), so the capability tracks the new top-level API."""
    return hasattr(jax, "shard_map")


def shard_map(f, mesh, *, in_specs, out_specs, axis_names=None, check=False):
    """``jax.shard_map`` across versions.

    New API: top-level ``jax.shard_map(..., check_vma=, axis_names=)``.
    Old API (jax<=0.4.x): ``jax.experimental.shard_map.shard_map`` with
    ``check_rep=`` and partial manualness via ``auto=`` (the complement of
    ``axis_names``).  Caveat on the old path: inside a partially-manual
    region, ``jax.lax.axis_index`` over a manual axis lowers to a
    ``PartitionId`` op that SPMD partitioning rejects — collectives
    (pmean/psum/all_gather) are fine; derive positions from sharded data
    instead of axis_index there.
    """
    if hasattr(jax, "shard_map"):
        kw = {"axis_names": axis_names} if axis_names is not None else {}
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check, **kw)
    from jax.experimental.shard_map import shard_map as _sm
    kw = {}
    if axis_names is not None and set(axis_names) != set(mesh.axis_names):
        kw["auto"] = frozenset(mesh.axis_names) - set(axis_names)
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check, **kw)
