"""Batched serving engine: continuous batching over a jitted decode step.

``ServeEngine`` keeps a fixed-width slot array (the serving batch); requests
occupy free slots, finished sequences free them — the standard continuous-
batching loop, scale-invariant because the jitted ``decode_step`` shape never
changes.  Sampling: greedy or temperature.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T
from repro.models.config import ModelConfig, ShardingPlan

__all__ = ["Request", "ServeEngine"]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # [P] int32 tokens (or [P, d] embeddings)
    max_new: int = 16
    temperature: float = 0.0
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, cfg: ModelConfig, plan: ShardingPlan, mesh, params,
                 *, slots: int = 4, s_max: int = 256, seed: int = 0):
        self.cfg, self.plan, self.mesh = cfg, plan, mesh
        self.params = params
        self.slots = slots
        self.s_max = s_max
        self.key = jax.random.PRNGKey(seed)
        self.state, _ = T.init_decode_state(cfg, plan, slots, s_max)
        self._active: Dict[int, Request] = {}
        self._slot_req: List[Optional[int]] = [None] * slots
        self._queue: List[Request] = []
        self._decode = jax.jit(
            lambda params, state, tok: T.decode_step(params, cfg, plan, mesh, state, tok))

    # ------------------------------------------------------------- frontend
    def submit(self, req: Request):
        self._queue.append(req)

    def _admit(self):
        for i in range(self.slots):
            if self._slot_req[i] is None and self._queue:
                req = self._queue.pop(0)
                self._slot_req[i] = req.rid
                self._active[req.rid] = req
                req._fed = 0            # prompt cursor

    # ----------------------------------------------------------------- step
    def step(self) -> int:
        """One engine tick = one decode_step over the slot batch."""
        self._admit()
        tok = np.zeros((self.slots, 1), np.int32)
        for i, rid in enumerate(self._slot_req):
            if rid is None:
                continue
            req = self._active[rid]
            if req._fed < len(req.prompt):
                tok[i, 0] = req.prompt[req._fed]
                req._fed += 1
            elif req.out:
                tok[i, 0] = req.out[-1]
        self.state, logits = self._decode(self.params, self.state, jnp.asarray(tok))
        logits = np.asarray(logits[:, 0].astype(jnp.float32))
        for i, rid in enumerate(self._slot_req):
            if rid is None:
                continue
            req = self._active[rid]
            if req._fed < len(req.prompt):
                continue                       # still prefilling this slot
            if req.temperature > 0:
                self.key, sub = jax.random.split(self.key)
                nxt = int(jax.random.categorical(sub, jnp.asarray(logits[i]) / req.temperature))
            else:
                nxt = int(logits[i].argmax())
            req.out.append(nxt)
            if len(req.out) >= req.max_new:
                req.done = True
                self._slot_req[i] = None
                del self._active[rid]
        return sum(r is not None for r in self._slot_req)

    def run_until_drained(self, max_ticks: int = 10_000) -> List[Request]:
        finished: List[Request] = []
        seen = set()
        for _ in range(max_ticks):
            if not self._queue and not self._active:
                break
            self.step()
        return finished
