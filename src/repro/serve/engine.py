"""Batched serving engine: continuous batching over a jitted decode step.

``ServeEngine`` keeps a fixed-width slot array (the serving batch); requests
occupy free slots, finished sequences free them — the standard continuous-
batching loop, scale-invariant because the jitted ``decode_step`` shape never
changes.  Sampling: greedy or temperature.  The slot bookkeeping itself
(admission queue, rid ownership, completion-ordered harvest) lives in
:class:`repro.serve.slots.SlotArray`, shared with the DSE serving engine.

Tick accounting (the contract ``tests/test_train_runtime.py`` locks): prefill
and decode share the tick.  On the tick a request's last prompt token is fed,
``_fed`` has already advanced past the prompt, so that decode's logits — the
prefill-final logits — are **sampled, not discarded**: the first generated
token lands on tick ``len(prompt)`` and a request completes in exactly
``len(prompt) + max_new - 1`` ticks with exactly ``max_new`` output tokens.
"""

from __future__ import annotations

import dataclasses
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T
from repro.models.config import ModelConfig, ShardingPlan

from .slots import SlotArray

__all__ = ["Request", "ServeEngine"]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # [P] int32 tokens (or [P, d] embeddings)
    max_new: int = 16
    temperature: float = 0.0
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    #: prompt cursor, owned by the engine.  Declared on the dataclass (it
    #: used to be injected at admission) so a queued-but-unadmitted request
    #: is a complete object — touching it can never raise AttributeError.
    _fed: int = 0


class ServeEngine:
    def __init__(self, cfg: ModelConfig, plan: ShardingPlan, mesh, params,
                 *, slots: int = 4, s_max: int = 256, seed: int = 0):
        self.cfg, self.plan, self.mesh = cfg, plan, mesh
        self.params = params
        self.slots = slots
        self.s_max = s_max
        self.key = jax.random.PRNGKey(seed)
        self.state, _ = T.init_decode_state(cfg, plan, slots, s_max)
        self._slots: SlotArray[Request] = SlotArray(slots)
        self._decode = jax.jit(
            lambda params, state, tok: T.decode_step(params, cfg, plan, mesh, state, tok))

    # ------------------------------------------------------------- frontend
    def submit(self, req: Request):
        if len(req.prompt) == 0:
            raise ValueError(f"request {req.rid}: empty prompt")
        self._slots.submit(req.rid, req)

    @property
    def drained(self) -> bool:
        return self._slots.drained

    # ----------------------------------------------------------------- step
    def step(self) -> int:
        """One engine tick = one decode_step over the slot batch."""
        for _, _, req in self._slots.admit():
            req._fed = 0            # reset the prompt cursor: slots are reused
        tok = np.zeros((self.slots, 1), np.int32)
        for i, _, req in self._slots.active_slots():
            if req._fed < len(req.prompt):
                tok[i, 0] = req.prompt[req._fed]
                req._fed += 1
            elif req.out:
                tok[i, 0] = req.out[-1]
        self.state, logits = self._decode(self.params, self.state, jnp.asarray(tok))
        logits = np.asarray(logits[:, 0].astype(jnp.float32))
        for i, _, req in list(self._slots.active_slots()):
            if req._fed < len(req.prompt):
                continue                       # still prefilling this slot
            if req.temperature > 0:
                self.key, sub = jax.random.split(self.key)
                nxt = int(jax.random.categorical(sub, jnp.asarray(logits[i]) / req.temperature))
            else:
                nxt = int(logits[i].argmax())
            req.out.append(nxt)
            if len(req.out) >= req.max_new:
                req.done = True
                self._slots.finish(i)
        return len(self._slots)

    def run_until_drained(self, max_ticks: int = 10_000) -> List[Request]:
        """Tick until queue and slots are empty; returns every completed
        request exactly once, in completion order."""
        for _ in range(max_ticks):
            if self._slots.drained:
                break
            self.step()
        return self._slots.harvest()
