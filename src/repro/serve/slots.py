"""Fixed-width slot-array scheduling — the continuous-batching core.

Extracted from the token :class:`~repro.serve.engine.ServeEngine` so the DSE
serving engine (``repro.api.service``) shares the identical admission /
free / harvest discipline instead of re-growing its own.  The invariant both
engines rely on: the slot array never changes width, so whatever rides the
slots (a ``[slots, 1]`` token batch, a fixed-width candidate block) keeps a
fixed leading dimension and the downstream jitted calls never re-trace as
requests come and go.

Bookkeeping contract (each rule fixes a real bug in the original engine):

* a rid is *owned* from ``submit`` until its item is harvested, and
  submitting an owned rid raises — two live requests sharing a rid used to
  silently corrupt the active map (the second overwrote the first, whose
  slot then fed stale state forever);
* finished items accumulate **in completion order** until ``harvest()``
  hands them back exactly once — ``run_until_drained`` used to return a
  never-appended empty list no matter how much work was done;
* admission fills the lowest free slot from a FIFO queue, so slot indices
  are reused with the admitting engine explicitly resetting per-slot state.
"""

from __future__ import annotations

from typing import (Any, Dict, Generic, Iterator, List, Optional, Set, Tuple,
                    TypeVar)

__all__ = ["SlotArray"]

T = TypeVar("T")


class SlotArray(Generic[T]):
    """Fixed-width slot array with a FIFO admission queue.

    ``submit(rid, item)`` queues; ``admit()`` moves queued items into free
    slots (lowest index first) and returns what was admitted this call so
    the owner can initialise per-slot state; ``finish(slot)`` frees a slot
    and records the item in completion order; ``harvest()`` pops the
    completed items exactly once and releases their rids.
    """

    def __init__(self, slots: int):
        if slots < 1:
            raise ValueError(f"SlotArray needs at least one slot, got {slots}")
        self.slots = slots
        self._slot_rid: List[Optional[Any]] = [None] * slots
        self._active: Dict[Any, T] = {}
        self._queue: List[Tuple[Any, T]] = []
        self._finished: List[Tuple[Any, T]] = []   # completion order
        self._owned: Set[Any] = set()

    # ------------------------------------------------------------ frontend
    def submit(self, rid: Any, item: T) -> None:
        """Queue ``item`` under ``rid``; raises on a rid that is still owned
        (queued, active, or finished-but-unharvested)."""
        if rid in self._owned:
            raise ValueError(
                f"request id {rid!r} is already in flight (queued, active, "
                "or awaiting harvest); rids must be unique per batch")
        self._owned.add(rid)
        self._queue.append((rid, item))

    def admit(self) -> List[Tuple[int, Any, T]]:
        """Fill free slots from the queue; returns [(slot, rid, item)] newly
        admitted so the owner can reset per-slot state."""
        admitted: List[Tuple[int, Any, T]] = []
        for i in range(self.slots):
            if self._slot_rid[i] is None and self._queue:
                rid, item = self._queue.pop(0)
                self._slot_rid[i] = rid
                self._active[rid] = item
                admitted.append((i, rid, item))
        return admitted

    # ------------------------------------------------------------- queries
    def __len__(self) -> int:
        """Occupied slot count."""
        return len(self._active)

    @property
    def queued(self) -> int:
        return len(self._queue)

    @property
    def drained(self) -> bool:
        return not self._queue and not self._active

    def rid_at(self, slot: int) -> Optional[Any]:
        return self._slot_rid[slot]

    def item_at(self, slot: int) -> Optional[T]:
        rid = self._slot_rid[slot]
        return None if rid is None else self._active[rid]

    def active_slots(self) -> Iterator[Tuple[int, Any, T]]:
        """(slot, rid, item) for every occupied slot, in slot order."""
        for i, rid in enumerate(self._slot_rid):
            if rid is not None:
                yield i, rid, self._active[rid]

    # ------------------------------------------------------------- retire
    def finish(self, slot: int) -> T:
        """Free ``slot``; its item joins the completion-ordered finished
        list (the rid stays owned until the item is harvested)."""
        rid = self._slot_rid[slot]
        if rid is None:
            raise ValueError(f"slot {slot} is already free")
        item = self._active.pop(rid)
        self._slot_rid[slot] = None
        self._finished.append((rid, item))
        return item

    def harvest(self) -> List[T]:
        """Pop the finished items (completion order), releasing their rids."""
        done, self._finished = self._finished, []
        for rid, _ in done:
            self._owned.discard(rid)
        return [item for _, item in done]
