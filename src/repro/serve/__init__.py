"""Batched serving engine over the jitted decode step."""
from .engine import Request, ServeEngine
__all__ = ["Request", "ServeEngine"]
