"""Continuous-batching serving: the shared slot-array core + token engine."""
from .engine import Request, ServeEngine
from .slots import SlotArray

__all__ = ["Request", "ServeEngine", "SlotArray"]
