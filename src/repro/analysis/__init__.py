"""Static analysis for SPAC: spec diagnostics, lint rules, retrace guard.

Three coordinated pieces:

* ``repro.analysis.check`` — ``spac check``: spec-level ``SPAC1xx``
  diagnostics (addressability, SLA satisfiability, budget vs the minimal
  resource plan, dead co-design genes, feasible-fraction estimates) with
  no trace build and no jit trace.
* ``repro.analysis.lint`` — ``spaclint`` / ``spac lint``: AST ``SPAC2xx``
  rules for the determinism and jit-hygiene contracts.
* ``repro.analysis.retrace`` — compile-count guard asserting the
  lru-cached sharded engines compile exactly once per (shape, mesh).

This ``__init__`` stays light on purpose: the sim engine modules import
``retrace`` at load time while ``check`` imports ``repro.api`` (which
imports the sim engines) — so ``check``/``lint`` resolve lazily to keep
the graph acyclic.
"""

from .diagnostics import (Diagnostic, SEVERITIES, EXIT_CLEAN, EXIT_FINDINGS,
                          EXIT_USAGE, worst_severity, exit_code, format_text,
                          to_json_payload)
from .retrace import (track, tracked_names, compile_counts, RetraceError,
                      RetraceGuard, retrace_guard)

__all__ = [
    "Diagnostic", "SEVERITIES", "EXIT_CLEAN", "EXIT_FINDINGS", "EXIT_USAGE",
    "worst_severity", "exit_code", "format_text", "to_json_payload",
    "track", "tracked_names", "compile_counts", "RetraceError",
    "RetraceGuard", "retrace_guard",
    "check_scenario", "lint_source", "lint_paths",
]


def __getattr__(name):
    if name == "check_scenario":
        from .check import check_scenario
        return check_scenario
    if name in ("lint_source", "lint_paths"):
        from . import lint
        return getattr(lint, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
