"""``spac check``: spec-level static diagnostics — stage 1 as a user-facing pass.

The DSE's first stage prunes statically infeasible points before any
simulation (PAPER §IV); this module promotes the same rules (plus the SLA
and budget sanity the stages only discover mid-run) to a standalone check
that needs **no trace and no jit trace**: everything here is closed-form —
protocol build + semantic binding, ``address_width_error``, the calibrated
resource model (``repro.sim.resources.synthesize``), and per-field layout
feasibility (``ProtocolSpace.feasible``).

Codes (the table in ``docs/architecture.md`` mirrors this):

  * ``SPAC100`` error — the spec does not build/bind at all (unknown
    builder params, unbindable protocol, broken trace generator name).
  * ``SPAC101`` error — an address field cannot address ``n_ports``
    (the rule ``_validate_addressing`` would raise mid-build, surfaced
    up front with a fix hint).
  * ``SPAC102`` error — the SLA is unsatisfiable against the analytic
    lower bound: p99 below the fastest pipeline + header wire time, or a
    throughput floor above what any bus/link can carry.
  * ``SPAC103`` error — the ``ResourceBudget`` is below the *minimal*
    resource plan (cheapest candidate at depth 1, per budget key);
    warning for budget keys the resource model never produces.
  * ``SPAC104`` error/warning — dead co-design gene (a searchable width
    whose every choice is statically infeasible) / inert search dimension
    (a gene the decode canonicalises away, wasting genome bits).
  * ``SPAC105`` info/error — co-design space size and statically feasible
    layout fraction; error when zero layouts survive.
  * ``SPAC106`` error — fabric topology addressability: the routing field
    must address the *fabric host count* (not one switch's ``n_ports``),
    every tier's port count must match the topology's degree, and the
    trace must emit fabric host ids.

Comm-domain scenarios get the spec-shape checks only (their fabric model
has no port-addressing or FPGA-resource analogue), so every registry
scenario — switch and comm — must come back clean.
"""

from __future__ import annotations

import inspect
import itertools
import math
from typing import Dict, List, Optional

from .diagnostics import Diagnostic

__all__ = ["check_scenario", "SPEC_CODES"]

#: code -> one-line description (docs + ``spac check --list-codes``)
SPEC_CODES = {
    "SPAC100": "scenario spec fails to build or bind statically",
    "SPAC101": "address field cannot address n_ports",
    "SPAC102": "SLA unsatisfiable against the analytic lower bound",
    "SPAC103": "resource budget below the minimal resource plan",
    "SPAC104": "dead co-design gene / inert search dimension",
    "SPAC105": "co-design space size and feasible-fraction estimate",
    "SPAC106": "fabric topology addressability / tier-degree mismatch",
}

#: full enumeration of the layout space is capped here; larger spaces get
#: size-only SPAC105 info plus the per-field (local) dead-gene analysis
_ENUM_CAP = 20_000


# --------------------------------------------------------------------------
# shared closed-form ingredients
# --------------------------------------------------------------------------

def _link_gbps(scenario) -> Optional[float]:
    """Link rate without building the trace: explicit param, else the
    generator signature's default.  ``None`` (file-backed or unknown
    generator) makes the wire-time term 0 — the bound stays conservative."""
    spec = scenario.trace
    if spec.generator is None:
        return None
    v = spec.params.get("link_gbps")
    if v is not None:
        return float(v)
    from repro.traces.workloads import WORKLOADS
    gen = WORKLOADS.get(spec.generator)
    if gen is None:
        return None
    p = inspect.signature(gen).parameters.get("link_gbps")
    if p is None or p.default is inspect.Parameter.empty:
        return None
    return float(p.default)


def _min_reports(scenario, bound):
    """Cheapest closed-form resource/timing report per candidate template:
    every ``enumerate_candidates`` point at depth 1 (resources are monotone
    in depth, so each per-key minimum is a valid lower bound)."""
    from repro.core.archspec import enumerate_candidates
    from repro.sim.resources import synthesize
    return [synthesize(a.with_depth(1), bound)
            for a in enumerate_candidates(scenario.arch)]


# --------------------------------------------------------------------------
# the individual checks (switch domain)
# --------------------------------------------------------------------------

def _check_addressing_point(scenario, bound) -> List[Diagnostic]:
    from repro.core.dsl import address_width_error
    out = []
    n = scenario.arch.n_ports
    need = max(1, (n - 1).bit_length())
    for sem in ("routing_key", "src_key"):
        if not bound.has(sem):
            continue
        f = bound.protocol.field(bound.semantics[sem])
        err = address_width_error(sem, f.name, f.bits, n)
        if err is not None:
            out.append(Diagnostic(
                "SPAC101", "error", err, f"protocol.{f.name}",
                hint=f"widen {f.name!r} to >= {need} bits (or reduce "
                     f"arch.n_ports); the run would fail at build time "
                     f"with this same rule"))
    return out


def _check_space_genes(scenario, space) -> List[Diagnostic]:
    """Per-field dead-choice analysis — local, so it works at any space size."""
    from repro.core.dsl import address_width_error
    out = []
    n = scenario.arch.n_ports
    for f in space.fields:
        if f.semantic not in ("routing_key", "src_key"):
            continue
        live, dead = [], []
        for b in f.bits:
            if b == 0:
                # a dropped src is legal; a dropped routing key is not
                (dead if f.semantic == "routing_key" else live).append(b)
            elif address_width_error(f.semantic, f.name, b, n) is None:
                live.append(b)
            else:
                dead.append(b)
        loc = f"protocol.{f.name}"
        if not live:
            out.append(Diagnostic(
                "SPAC104", "error",
                f"dead co-design gene: no width choice of {f.semantic} field "
                f"{f.name!r} ({f.bits}) can address n_ports={n}",
                loc,
                hint=f"add a choice >= {max(1, (n - 1).bit_length())} bits "
                     f"to the {f.name!r} menu — every genome decodes "
                     f"statically infeasible"))
        elif len(f.bits) > 1 and len(live) == 1:
            out.append(Diagnostic(
                "SPAC104", "warning",
                f"co-design gene {f.name!r} is effectively pinned: of "
                f"{f.bits} only width {live[0]} survives the static rules "
                f"(dead: {dead})", loc,
                hint="drop the dead choices — they cost genome space and "
                     "search evaluations without adding reachable layouts"))
    return out


def _check_inert_arch_dims(scenario) -> List[Diagnostic]:
    """Genome dimensions ``SwitchDSEProblem.decode`` canonicalises away for
    the pinned policies (only meaningful when a search genome exists)."""
    from repro.core.archspec import (AUTO, ForwardTableKind, SchedulerKind)
    out = []
    req = scenario.arch
    sched_opts = list(SchedulerKind) if req.sched is AUTO else [req.sched]
    fwd_opts = [
        f for f in (list(ForwardTableKind) if req.fwd is AUTO else [req.fwd])
        if not (f is ForwardTableKind.FULL_LOOKUP and req.addr_bits > 16)
    ] or [ForwardTableKind.MULTIBANK_HASH]
    if SchedulerKind.ISLIP not in sched_opts:
        out.append(Diagnostic(
            "SPAC104", "warning",
            f"islip_iters gene is inert: scheduler pinned to "
            f"{[s.value for s in sched_opts]} so decode() canonicalises "
            f"every iteration count to the default", "arch.sched",
            hint="pin sched to AUTO (or include islip) to make the gene "
                 "live, or accept the wasted genome dimension"))
    if ForwardTableKind.MULTIBANK_HASH not in fwd_opts:
        out.append(Diagnostic(
            "SPAC104", "warning",
            "hash_banks/hash_depth genes are inert: forwarding pinned to "
            "full_lookup so banking genes never reach the decoded "
            "architecture", "arch.fwd",
            hint="pin fwd to AUTO (or multibank_hash) to make the banking "
                 "genes live"))
    return out


def _check_space_fraction(scenario, space) -> List[Diagnostic]:
    total = space.size()
    loc = "protocol"
    if total > _ENUM_CAP:
        return [Diagnostic(
            "SPAC105", "info",
            f"co-design layout space has {total} points (> {_ENUM_CAP}, "
            f"feasible fraction not enumerated)", loc)]
    n = scenario.arch.n_ports
    feasible = sum(
        1 for combo in itertools.product(*(f.bits for f in space.fields))
        if space.feasible(combo, n_ports=n) is None)
    if feasible == 0:
        return [Diagnostic(
            "SPAC105", "error",
            f"0 of {total} protocol layouts are statically feasible — the "
            f"co-design search has nothing to evaluate", loc,
            hint="fix the dead genes reported by SPAC104; feasibility here "
                 "uses the addressing/structure rules only (payload-length "
                 "rules additionally need the built trace)")]
    return [Diagnostic(
        "SPAC105", "info",
        f"{feasible} of {total} protocol layouts statically feasible "
        f"({feasible / total:.0%}); the joint genome adds the architecture "
        f"dimensions on top", loc)]


def _check_topology(scenario, bound, space) -> List[Diagnostic]:
    """SPAC106 — multi-hop fabric shape rules.  Exactly one of ``bound``
    (point protocol) / ``space`` (co-design space) is non-None.

    A topology changes the addressing target: packets carry *fabric host*
    ids end-to-end, so the routing field must cover ``topology.n_hosts``
    even though each individual switch only exposes ``degree`` ports.
    ``_validate_addressing`` in the runner enforces the same rule at build
    time; this surfaces it statically with the topology named."""
    from repro.core.dsl import address_width_error
    out: List[Diagnostic] = []
    topo = scenario.topology.build()
    n = topo.n_hosts
    need = max(1, (n - 1).bit_length())

    if space is not None:
        for f in space.fields:
            if f.semantic != "routing_key":
                continue
            live = [b for b in f.bits if b and address_width_error(
                "routing_key", f.name, b, n) is None]
            if not live:
                out.append(Diagnostic(
                    "SPAC106", "error",
                    f"no width choice of routing field {f.name!r} ({f.bits}) "
                    f"can address the fabric's {n} hosts (topology "
                    f"{topo.kind!r}) — one switch's n_ports="
                    f"{scenario.arch.n_ports} is not the addressing target",
                    f"protocol.{f.name}",
                    hint=f"add a routing width >= {need} bits; multi-hop "
                         f"routes are keyed by destination *host* id"))
    elif bound is not None:
        for sem in ("routing_key", "src_key"):
            if not bound.has(sem):
                continue
            f = bound.protocol.field(bound.semantics[sem])
            if address_width_error(sem, f.name, f.bits, n) is not None:
                out.append(Diagnostic(
                    "SPAC106", "error",
                    f"{sem} field {f.name!r} ({f.bits} bits) cannot address "
                    f"the fabric's {n} hosts (topology {topo.kind!r}); with "
                    f"a topology, addressing is checked against the host "
                    f"count, not one switch's n_ports="
                    f"{scenario.arch.n_ports}",
                    f"protocol.{f.name}",
                    hint=f"widen {f.name!r} to >= {need} bits — endpoints "
                         f"are fabric host ids, so a single switch's port "
                         f"width is not enough"))

    for t, tier in enumerate(topo.tiers):
        if tier.degree != scenario.arch.n_ports:
            out.append(Diagnostic(
                "SPAC106", "error",
                f"tier {t} ({tier.name!r}) of topology {topo.kind!r} has "
                f"degree {tier.degree} but arch.n_ports="
                f"{scenario.arch.n_ports} — every tier's switches must "
                f"expose exactly the topology's per-tier port count",
                "arch.n_ports",
                hint=f"set arch.n_ports={tier.degree}; the fabric problem "
                     f"sizes each tier to its degree and a mismatched "
                     f"template means the addr/policy menu was shaped for "
                     f"the wrong radix"))

    tr = scenario.trace
    tr_ports = None
    if tr.generator is not None:
        tr_ports = tr.params.get("n_ports")
        if tr_ports is None:
            from repro.traces.workloads import WORKLOADS
            gen = WORKLOADS.get(tr.generator)
            if gen is not None:
                p = inspect.signature(gen).parameters.get("n_ports")
                if p is not None and p.default is not inspect.Parameter.empty:
                    tr_ports = p.default
    if tr_ports is not None and int(tr_ports) != n:
        out.append(Diagnostic(
            "SPAC106", "error",
            f"trace generator emits {int(tr_ports)} endpoint ids but "
            f"topology {topo.kind!r} has {n} hosts — multi-hop routing "
            f"needs src/dst in [0, {n})", "trace.n_ports",
            hint=f"set trace params n_ports={n} so packets target fabric "
                 f"hosts"))
    return out


def _check_sla(scenario, bound, min_header_bytes: int) -> List[Diagnostic]:
    out = []
    reports = _min_reports(scenario, bound)
    link = _link_gbps(scenario)
    min_pipe_ns = min(r.latency_ns for r in reports)
    wire_ns = (min_header_bytes * 8 / link) if link else 0.0
    lower = min_pipe_ns + wire_ns
    sla = scenario.sla
    if sla.p99_latency_ns < lower:
        detail = (f"fastest pipeline {min_pipe_ns:.1f} ns"
                  + (f" + {min_header_bytes} B header at {link:g} Gbps "
                     f"= {wire_ns:.1f} ns wire time" if link else ""))
        out.append(Diagnostic(
            "SPAC102", "error",
            f"sla.p99_latency_ns={sla.p99_latency_ns:g} is below the "
            f"analytic lower bound {lower:.1f} ns ({detail}) — no candidate "
            f"can ever satisfy it", "sla.p99_latency_ns",
            hint=f"raise the p99 SLA above ~{lower:.0f} ns or the whole "
                 f"Pareto front will be empty"))
    if sla.min_throughput_gbps > 0:
        max_tp = max(r.max_throughput_gbps for r in reports)
        cap = min(max_tp, link) if link else max_tp
        if sla.min_throughput_gbps > cap:
            which = ("the link rate" if link and link < max_tp
                     else "the widest bus at its fmax")
            out.append(Diagnostic(
                "SPAC102", "error",
                f"sla.min_throughput_gbps={sla.min_throughput_gbps:g} "
                f"exceeds {which} ({cap:.1f} Gbps) — unsatisfiable",
                "sla.min_throughput_gbps",
                hint="lower the throughput floor or raise the trace's "
                     "link_gbps"))
    return out


def _check_budget(scenario, bound) -> List[Diagnostic]:
    out = []
    budget = scenario.budget
    if budget is None:
        from repro.sim.resources import ALVEO_U45N
        limits: Dict[str, float] = dict(ALVEO_U45N)
        origin = "budget (default Alveo U45N)"
    else:
        limits = dict(budget.limits)
        origin = "budget"
    reports = _min_reports(scenario, bound)
    minimal = {
        "luts": min(r.luts for r in reports),
        "ffs": min(r.ffs for r in reports),
        "brams": min(r.brams for r in reports),
    }
    minimal["bram"] = minimal["brams"]       # resources() exposes both keys
    for key, limit in sorted(limits.items()):
        need = minimal.get(key)
        if need is None:
            out.append(Diagnostic(
                "SPAC103", "warning",
                f"budget key {key!r} is not produced by the switch resource "
                f"model (known: {sorted(minimal)}) — the limit can never "
                f"constrain anything", f"{origin}.{key}",
                hint="likely a typo; admits() treats absent usage as 0"))
        elif need > limit:
            out.append(Diagnostic(
                "SPAC103", "error",
                f"{origin[:6]}.{key}={limit:g} is below the minimal plan: "
                f"even the cheapest candidate at depth 1 needs "
                f"{need:,.0f} {key}", f"{origin}.{key}",
                hint=f"raise the {key} limit to at least {need:,.0f} or "
                     f"shrink the request (fewer ports, narrower bus menu)"))
    return out


# --------------------------------------------------------------------------
# entry point
# --------------------------------------------------------------------------

def check_scenario(scenario) -> List[Diagnostic]:
    """All static diagnostics for one ``Scenario`` — no trace is built, no
    jit is traced; see the module docstring for the code table."""
    diags: List[Diagnostic] = []

    # trace source sanity is domain-independent and cheap
    if scenario.domain == "switch" and scenario.trace.generator is not None:
        from repro.traces.workloads import WORKLOADS
        if scenario.trace.generator not in WORKLOADS:
            diags.append(Diagnostic(
                "SPAC100", "error",
                f"unknown trace generator {scenario.trace.generator!r} "
                f"(known: {sorted(WORKLOADS)})", "trace.generator"))

    if scenario.domain != "switch":
        # the comm fabric model has no port-addressing / FPGA-resource
        # analogue; its specs are validated structurally by Scenario itself
        return diags

    from repro.core.binding import bind

    if scenario.protocol.is_space:
        try:
            space = scenario.protocol.space()
        except ValueError as e:
            diags.append(Diagnostic("SPAC100", "error", str(e), "protocol"))
            return diags
        diags.extend(_check_space_genes(scenario, space))
        if scenario.search is not None or scenario.co_design:
            diags.extend(_check_inert_arch_dims(scenario))
        diags.extend(_check_space_fraction(scenario, space))
        if scenario.topology is not None:
            diags.extend(_check_topology(scenario, None, space))
        # price the SLA/budget bounds at the widest layout (bindable iff any
        # is) but serialize the *narrowest* feasible header on the wire
        try:
            bound = bind(space.decode(space.max_widths()),
                         scenario.semantic_binding(),
                         flit_bits=scenario.flit_bits)
        except ValueError as e:
            diags.append(Diagnostic(
                "SPAC100", "error",
                f"widest layout of the protocol space does not bind: {e}",
                "protocol"))
            return diags
        min_header_bits = sum(min(f.bits) for f in space.fields)
        min_header_bytes = max(1, -(-min_header_bits // 8))
    else:
        try:
            protocol = scenario.protocol.build()
            bound = bind(protocol, scenario.semantic_binding(),
                         flit_bits=scenario.flit_bits)
        except ValueError as e:
            diags.append(Diagnostic("SPAC100", "error", str(e), "protocol"))
            return diags
        diags.extend(_check_addressing_point(scenario, bound))
        if scenario.topology is not None:
            diags.extend(_check_topology(scenario, bound, None))
        min_header_bytes = bound.protocol.header_bytes

    diags.extend(_check_sla(scenario, bound, min_header_bytes))
    diags.extend(_check_budget(scenario, bound))
    return diags
