"""Structured diagnostics shared by ``spac check`` and ``spaclint``.

One record type serves both front-ends: spec-level findings (``SPAC1xx``,
``repro.analysis.check``) and source-level lint findings (``SPAC2xx``,
``repro.analysis.lint``) render through the same text/JSON formatting and
the same exit-code convention:

  * ``0`` — clean (only ``info`` diagnostics, if any)
  * ``1`` — findings (at least one ``warning`` or ``error``)
  * ``2`` — usage / input error (bad path, malformed JSON, unknown scenario)

Severities order ``error > warning > info``; ``info`` never fails a run —
it carries context like co-design space sizes and feasible fractions.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterable, List

__all__ = ["Diagnostic", "SEVERITIES", "worst_severity", "exit_code",
           "format_text", "to_json_payload",
           "EXIT_CLEAN", "EXIT_FINDINGS", "EXIT_USAGE"]

#: ranked weakest-first so ``max(..., key=SEVERITIES.index)`` picks the worst
SEVERITIES = ("info", "warning", "error")

EXIT_CLEAN = 0
EXIT_FINDINGS = 1
EXIT_USAGE = 2


@dataclasses.dataclass(frozen=True)
class Diagnostic:
    """One finding: a stable code, a severity, where, what, and how to fix.

    ``location`` is a spec path (``protocol.dst``, ``sla.p99_latency_ns``)
    for check diagnostics and ``file.py:line`` for lint diagnostics —
    always a plain string so the record serializes untouched.
    """

    code: str           # "SPAC101" / "SPAC204" / ...
    severity: str       # one of SEVERITIES
    message: str
    location: str
    hint: str = ""

    def __post_init__(self):
        if self.severity not in SEVERITIES:
            raise ValueError(f"unknown severity {self.severity!r}; "
                             f"known: {SEVERITIES}")

    def to_dict(self) -> Dict[str, Any]:
        d = {"code": self.code, "severity": self.severity,
             "message": self.message, "location": self.location}
        if self.hint:
            d["hint"] = self.hint
        return d

    def format(self) -> str:
        line = f"{self.location}: {self.severity} {self.code} {self.message}"
        if self.hint:
            line += f"\n    hint: {self.hint}"
        return line


def worst_severity(diags: Iterable[Diagnostic]) -> str:
    worst = "info"
    for d in diags:
        if SEVERITIES.index(d.severity) > SEVERITIES.index(worst):
            worst = d.severity
    return worst


def exit_code(diags: Iterable[Diagnostic]) -> int:
    """The 0/1 half of the convention (2 is raised before any Diagnostic
    exists — it means the input never became checkable)."""
    return EXIT_CLEAN if worst_severity(diags) == "info" else EXIT_FINDINGS


def format_text(diags: List[Diagnostic], *, clean_message: str = "clean") -> str:
    if not diags:
        return clean_message
    return "\n".join(d.format() for d in diags)


def to_json_payload(diags: List[Diagnostic]) -> Dict[str, Any]:
    return {"diagnostics": [d.to_dict() for d in diags],
            "worst_severity": worst_severity(diags) if diags else None,
            "exit_code": exit_code(diags)}
