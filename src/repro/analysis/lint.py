"""``spaclint``: AST rules for the repo's determinism and jit-hygiene contracts.

The repo's hardest-won guarantees — bit-identical goldens, mesh-invariant
compiles, remesh-proof resume — are enforced dynamically by tests, and the
changelog shows what slips through anyway (PR 3 shipped a shared mutable
``NetSimConfig`` default that let one caller's mutation leak into every
other).  These rules catch those bug *classes* at review time, before a
golden ever diverges.  Each rule's registry entry names the contract it
protects and the incident (or near-miss) motivating it.

Usage::

    python -m repro.analysis.lint src tests benchmarks
    spaclint --format json src
    spac lint src tests benchmarks      # same engine via the spac CLI

Suppression is per physical line, narrowest-scope first::

    t0 = time.time()   # spaclint: disable=SPAC203
    x = risky()        # spaclint: disable        (all rules; avoid)

Exit codes follow ``repro.analysis.diagnostics``: 0 clean, 1 findings,
2 usage error.  Parse failures are findings (``SPAC200``), not crashes.
"""

from __future__ import annotations

import argparse
import ast
import dataclasses
import json
import os
import re
import sys
from typing import Dict, Iterable, List, Optional, Sequence, Set

from .diagnostics import (Diagnostic, EXIT_USAGE, exit_code, format_text,
                          to_json_payload)

__all__ = ["Rule", "RULES", "lint_source", "lint_paths", "main"]


@dataclasses.dataclass(frozen=True)
class Rule:
    code: str
    summary: str
    contract: str       # the repo guarantee this rule protects
    incident: str       # the changelog incident / near-miss motivating it


RULES: Dict[str, Rule] = {r.code: r for r in (
    Rule("SPAC200", "file does not parse",
         "everything below assumes an AST",
         "n/a — reported instead of crashing the lint run"),
    Rule("SPAC201", "mutable default argument",
         "no shared state between calls: goldens are bit-identical only if "
         "f(x) is a pure function of its arguments",
         "PR 3 shipped `cfg: NetSimConfig = NetSimConfig()`; one caller's "
         "mutation leaked into every later call and skewed p99 latencies"),
    Rule("SPAC202", "global np.random.* outside a seeded Generator",
         "all randomness flows from an explicit seed: trace generators and "
         "NSGA-II take `np.random.default_rng(seed)`, never module state",
         "a single `np.random.shuffle` in a helper would decouple goldens "
         "from their recorded seeds with no test able to say why"),
    Rule("SPAC203", "wall-clock value in a report payload outside *_time_s",
         "golden comparison strips volatile keys by the `*_time_s` naming "
         "convention (PR 4); timings under any other key diff every run",
         "launch/dryrun.py recorded `lower_s`/`compile_s` — invisible to "
         "the stripper, found by this rule's first repo-wide run"),
    Rule("SPAC204", "unordered set iteration feeding an ordered sink",
         "arrays, serialized dicts and report rows must not inherit "
         "PYTHONHASHSEED-dependent iteration order",
         "benchmarks/fig7_dse_pareto.py iterated a set comprehension of "
         "depths straight into result rows — row order varied per process"),
    Rule("SPAC205", "jitted function reads a module-level mutable global",
         "jit traces close over values at trace time: later mutation is "
         "silently ignored (stale constant) or retriggers tracing",
         "the PR 6 sharded engines were rebuilt around lru-cached pure "
         "builders precisely to avoid this class"),
    Rule("SPAC206", "unscoped enable_x64 / global jax_enable_x64 flip",
         "x64 is scoped per engine call (`with enable_x64():`, PR 4); a "
         "process-wide flip changes every other engine's dtypes mid-run",
         "surrogate quantile math needs f64 while netsim runs f32 — one "
         "global `config.update` would corrupt whichever runs second"),
    Rule("SPAC207", "jax.jit constructed inside a loop",
         "engines are jitted once at module level or inside lru-cached "
         "builders; a jit in a loop body retraces every iteration",
         "the stage-2 batched engine exists to amortise one trace over "
         "thousands of candidates — a loop-local jit undoes exactly that"),
    Rule("SPAC208", "host numpy sort inside a loop body",
         "event timelines are sorted once per trace (the `sim.timeline` "
         "memo, PR 8) and batch-wide sorts run once per call; a "
         "np.sort/argsort/lexsort in a loop body re-pays O(m log m) host "
         "work per candidate or per generation in the DSE hot path",
         "batched_netsim's shared-cap audit re-sorted the admitted "
         "departure times per candidate row — hundreds of redundant sorts "
         "of the same event batch per verify call, found while building "
         "the segmented kernel path"),
)}

_SUPPRESS_RE = re.compile(
    r"#\s*spaclint:\s*disable(?:=([A-Za-z0-9,\s]+))?")

_MUTABLE_CALLS = {"list", "dict", "set", "bytearray", "deque",
                  "defaultdict", "OrderedDict", "Counter"}
_NP_ARRAY_CALLS = {"array", "asarray", "zeros", "ones", "empty", "full",
                   "arange"}
_IMMUTABLE_CTORS = {"frozenset", "tuple", "Fraction", "Decimal"}
_SAFE_NP_RANDOM = {"default_rng", "Generator", "SeedSequence", "PCG64",
                   "MT19937", "Philox", "SFC64", "BitGenerator"}
_CLOCK_SUFFIXES = ("time.time", "time.perf_counter", "time.monotonic",
                   "time.process_time", "time.time_ns",
                   "time.perf_counter_ns", "time.monotonic_ns")
_TAINT_PRESERVING = {"round", "float", "abs", "min", "max", "sum"}
_ORDERED_SINKS = {"list", "tuple", "enumerate", "np.array", "np.asarray",
                  "numpy.array", "numpy.asarray", "jnp.array", "jnp.asarray"}


def _dotted(node: ast.AST) -> Optional[str]:
    """``a.b.c`` for a Name/Attribute chain, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _is_clock_call(node: ast.AST) -> bool:
    if not isinstance(node, ast.Call):
        return False
    name = _dotted(node.func)
    if name is None:
        return False
    if name.endswith(_CLOCK_SUFFIXES) or name in {
            s.split(".", 1)[1] for s in _CLOCK_SUFFIXES}:
        return True
    parts = name.split(".")
    return parts[-1] in {"now", "utcnow"} and "datetime" in parts


def _is_set_expr(node: ast.AST) -> bool:
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    return (isinstance(node, ast.Call) and _dotted(node.func) == "set")


def _is_jit_call(node: ast.AST) -> bool:
    if not isinstance(node, ast.Call):
        return False
    name = _dotted(node.func)
    if name in {"jax.jit", "jit", "pjit", "jax.pjit"}:
        return True
    # functools.partial(jax.jit, ...) used as a decorator factory
    if name is not None and name.split(".")[-1] == "partial" and node.args:
        return _dotted(node.args[0]) in {"jax.jit", "jit"}
    return False


class _Finding:
    __slots__ = ("code", "lineno", "message", "hint")

    def __init__(self, code: str, lineno: int, message: str, hint: str = ""):
        self.code, self.lineno = code, lineno
        self.message, self.hint = message, hint


# --------------------------------------------------------------------------
# individual rule passes
# --------------------------------------------------------------------------

def _mutable_default_reason(node: ast.AST) -> Optional[str]:
    if isinstance(node, (ast.List, ast.Dict, ast.Set,
                         ast.ListComp, ast.DictComp, ast.SetComp)):
        return "a mutable literal"
    if isinstance(node, ast.Call):
        name = _dotted(node.func)
        if name is None:
            return None
        last = name.split(".")[-1]
        if last in _IMMUTABLE_CTORS:
            return None
        if last in _MUTABLE_CALLS:
            return f"a call to {last}()"
        if name.split(".")[0] in {"np", "numpy"} and last in _NP_ARRAY_CALLS:
            return f"a numpy array ({name})"
        if last[:1].isupper():
            return f"an instance of {last} (the NetSimConfig shape)"
    return None


def _check_mutable_defaults(tree: ast.AST) -> List[_Finding]:
    out = []
    for node in ast.walk(tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.Lambda)):
            continue
        fname = getattr(node, "name", "<lambda>")
        a = node.args
        pos = a.posonlyargs + a.args
        pairs = list(zip(pos[len(pos) - len(a.defaults):], a.defaults))
        pairs += [(arg, d) for arg, d in zip(a.kwonlyargs, a.kw_defaults)
                  if d is not None]
        for arg, default in pairs:
            reason = _mutable_default_reason(default)
            if reason:
                out.append(_Finding(
                    "SPAC201", default.lineno,
                    f"default of {fname}({arg.arg}=...) is {reason}, shared "
                    f"across every call",
                    hint=f"use `{arg.arg}=None` and construct the value "
                         f"inside the function"))
    return out


def _check_global_np_random(tree: ast.AST) -> List[_Finding]:
    out = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        name = _dotted(node.func)
        if name is None:
            continue
        parts = name.split(".")
        if (len(parts) >= 3 and parts[-2] == "random"
                and parts[-3] in {"np", "numpy"}
                and parts[-1] not in _SAFE_NP_RANDOM):
            out.append(_Finding(
                "SPAC202", node.lineno,
                f"{name}() draws from numpy's global RNG state",
                hint="thread a seeded np.random.default_rng(seed) Generator "
                     "through instead"))
    return out


def _check_wallclock_keys(tree: ast.AST) -> List[_Finding]:
    out = []

    def scope_body(scope) -> List[ast.stmt]:
        return scope.body

    def run_scope(body: Sequence[ast.stmt]) -> None:
        tainted: Set[str] = set()

        def is_tainted(expr: ast.AST) -> bool:
            if isinstance(expr, ast.Name):
                return expr.id in tainted
            if _is_clock_call(expr):
                return True
            if isinstance(expr, ast.Call):
                name = _dotted(expr.func) or ""
                if name.split(".")[-1] in _TAINT_PRESERVING:
                    return any(is_tainted(a) for a in expr.args)
                return False
            if isinstance(expr, ast.BinOp):
                if isinstance(expr.op, (ast.Add, ast.Sub)):
                    return is_tainted(expr.left) or is_tainted(expr.right)
                return False        # Div/Mult launder: rates, not timestamps
            if isinstance(expr, ast.UnaryOp):
                return is_tainted(expr.operand)
            if isinstance(expr, ast.IfExp):
                return is_tainted(expr.body) or is_tainted(expr.orelse)
            return False

        def check_sinks(stmt: ast.stmt) -> None:
            for node in ast.walk(stmt):
                if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                     ast.Lambda)):
                    continue
                if isinstance(node, ast.Dict):
                    for key, value in zip(node.keys, node.values):
                        if (isinstance(key, ast.Constant)
                                and isinstance(key.value, str)
                                and not key.value.endswith("_time_s")
                                and is_tainted(value)):
                            out.append(_Finding(
                                "SPAC203", value.lineno,
                                f"wall-clock value stored under report key "
                                f"{key.value!r}",
                                hint=f"rename to {key.value + '_time_s'!r} "
                                     f"(or any *_time_s) so golden "
                                     f"comparison strips it"))
            if isinstance(stmt, ast.Assign):
                for tgt in stmt.targets:
                    if (isinstance(tgt, ast.Subscript)
                            and isinstance(tgt.slice, ast.Constant)
                            and isinstance(tgt.slice.value, str)
                            and not tgt.slice.value.endswith("_time_s")
                            and is_tainted(stmt.value)):
                        out.append(_Finding(
                            "SPAC203", stmt.lineno,
                            f"wall-clock value stored under report key "
                            f"{tgt.slice.value!r}",
                            hint=f"rename to "
                                 f"{tgt.slice.value + '_time_s'!r}"))

        def update_env(stmt: ast.stmt) -> None:
            if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 \
                    and isinstance(stmt.targets[0], ast.Name):
                name = stmt.targets[0].id
                (tainted.add if is_tainted(stmt.value)
                 else tainted.discard)(name)
            elif isinstance(stmt, ast.AugAssign) \
                    and isinstance(stmt.target, ast.Name) \
                    and isinstance(stmt.op, (ast.Add, ast.Sub)) \
                    and is_tainted(stmt.value):
                tainted.add(stmt.target.id)

        def walk_stmts(stmts: Sequence[ast.stmt]) -> None:
            for stmt in stmts:
                if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    continue            # own scope, handled separately
                check_sinks(stmt)
                update_env(stmt)
                for attr in ("body", "orelse", "finalbody"):
                    walk_stmts(getattr(stmt, attr, []) or [])
                for handler in getattr(stmt, "handlers", []) or []:
                    walk_stmts(handler.body)

        walk_stmts(body)

    run_scope(scope_body(tree))
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            run_scope(node.body)
    return out


def _check_set_iteration(tree: ast.AST) -> List[_Finding]:
    out = []

    def flag(node: ast.AST, sink: str) -> None:
        out.append(_Finding(
            "SPAC204", node.lineno,
            f"unordered set iterated by {sink}: order depends on "
            f"PYTHONHASHSEED",
            hint="wrap in sorted(...) to fix the order"))

    for node in ast.walk(tree):
        if isinstance(node, ast.For) and _is_set_expr(node.iter):
            flag(node.iter, "a for loop")
        elif isinstance(node, (ast.ListComp, ast.SetComp, ast.DictComp,
                               ast.GeneratorExp)):
            for gen in node.generators:
                if _is_set_expr(gen.iter):
                    flag(gen.iter, "a comprehension")
        elif isinstance(node, ast.Call):
            name = _dotted(node.func)
            is_join = (isinstance(node.func, ast.Attribute)
                       and node.func.attr == "join")
            if (name in _ORDERED_SINKS or is_join) and node.args \
                    and _is_set_expr(node.args[0]):
                flag(node.args[0], name or "str.join")
    return out


def _check_jit_mutable_globals(tree: ast.AST) -> List[_Finding]:
    if not isinstance(tree, ast.Module):
        return []
    mutable_globals: Set[str] = set()
    for stmt in tree.body:
        if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 \
                and isinstance(stmt.targets[0], ast.Name) \
                and _mutable_default_reason(stmt.value) in (
                    "a mutable literal", "a call to list()",
                    "a call to dict()", "a call to set()"):
            mutable_globals.add(stmt.targets[0].id)
    if not mutable_globals:
        return []

    jitted: List[ast.FunctionDef] = []
    fdefs = {n.name: n for n in tree.body if isinstance(n, ast.FunctionDef)}
    for node in ast.walk(tree):
        if isinstance(node, ast.FunctionDef) \
                and any(_is_jit_call(d) or _dotted(d) in {"jax.jit", "jit"}
                        for d in node.decorator_list):
            jitted.append(node)
        elif isinstance(node, ast.Assign) and _is_jit_call(node.value):
            for arg in node.value.args:
                if isinstance(arg, ast.Name) and arg.id in fdefs:
                    jitted.append(fdefs[arg.id])

    out = []
    for fn in jitted:
        local = {a.arg for a in (fn.args.posonlyargs + fn.args.args
                                 + fn.args.kwonlyargs)}
        for node in ast.walk(fn):
            if isinstance(node, ast.Name) and isinstance(node.ctx, ast.Store):
                local.add(node.id)
        for node in ast.walk(fn):
            if isinstance(node, ast.Name) and isinstance(node.ctx, ast.Load) \
                    and node.id in mutable_globals and node.id not in local:
                out.append(_Finding(
                    "SPAC205", node.lineno,
                    f"jitted {fn.name}() reads module-level mutable "
                    f"{node.id!r}: the trace freezes its value and ignores "
                    f"later mutation",
                    hint="pass it as an argument (static_argnames for "
                         "hashables) or make the global immutable"))
    return out


def _check_x64(tree: ast.AST) -> List[_Finding]:
    with_items = set()
    for node in ast.walk(tree):
        if isinstance(node, (ast.With, ast.AsyncWith)):
            for item in node.items:
                with_items.add(id(item.context_expr))
    out = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        name = _dotted(node.func) or ""
        if name.split(".")[-1] == "enable_x64" and id(node) not in with_items:
            out.append(_Finding(
                "SPAC206", node.lineno,
                "enable_x64() called outside a with-block leaks x64 into "
                "every engine that runs afterwards",
                hint="scope it: `with enable_x64(): ...`"))
        elif name.split(".")[-1] == "update" and node.args \
                and isinstance(node.args[0], ast.Constant) \
                and node.args[0].value == "jax_enable_x64":
            out.append(_Finding(
                "SPAC206", node.lineno,
                "global jax_enable_x64 flip changes dtypes for the whole "
                "process",
                hint="use the scoped `with enable_x64():` helper from "
                     "repro.sim instead"))
    return out


def _check_jit_in_loop(tree: ast.AST) -> List[_Finding]:
    out = []

    def visit(node: ast.AST, in_loop: bool) -> None:
        if _is_jit_call(node) and in_loop \
                and not (isinstance(node, ast.Call)
                         and (_dotted(node.func) or "").split(".")[-1]
                         == "partial"):
            out.append(_Finding(
                "SPAC207", node.lineno,
                "jax.jit constructed inside a loop body retraces every "
                "iteration",
                hint="hoist the jit to module level or an lru-cached "
                     "builder keyed on the static arguments"))
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.Lambda)):
                visit(child, False)     # new scope resets loop context
            elif isinstance(child, (ast.For, ast.While)):
                for grand in ast.iter_child_nodes(child):
                    visit(grand, True)
            else:
                visit(child, in_loop)

    visit(tree, False)
    return out


_HOST_SORTS = {"sort", "argsort", "lexsort"}


def _check_sort_in_loop(tree: ast.AST) -> List[_Finding]:
    out = []

    def is_host_sort(node: ast.AST) -> bool:
        if not isinstance(node, ast.Call):
            return False
        parts = (_dotted(node.func) or "").split(".")
        return (len(parts) == 2 and parts[0] in {"np", "numpy"}
                and parts[1] in _HOST_SORTS)

    def visit(node: ast.AST, in_loop: bool) -> None:
        if in_loop and is_host_sort(node):
            name = _dotted(node.func)  # type: ignore[union-attr]
            out.append(_Finding(
                "SPAC208", node.lineno,
                f"{name}() inside a loop body re-sorts on every iteration",
                hint="hoist it: sort once per call (batch axis) or memoise "
                     "per trace via repro.sim.timeline"))
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.Lambda)):
                visit(child, False)     # new scope resets loop context
            elif isinstance(child, ast.For):
                # the iterable is evaluated once — `for k in np.argsort(x)`
                # is a single sort, not a per-iteration one
                visit(child.target, in_loop)
                visit(child.iter, in_loop)
                for stmt in child.body + child.orelse:
                    visit(stmt, True)
            elif isinstance(child, ast.While):
                for grand in ast.iter_child_nodes(child):
                    visit(grand, True)
            else:
                visit(child, in_loop)

    visit(tree, False)
    return out


_PASSES = (_check_mutable_defaults, _check_global_np_random,
           _check_wallclock_keys, _check_set_iteration,
           _check_jit_mutable_globals, _check_x64, _check_jit_in_loop,
           _check_sort_in_loop)


# --------------------------------------------------------------------------
# driver
# --------------------------------------------------------------------------

def _suppressions(source: str) -> Dict[int, Set[str]]:
    """lineno -> suppressed codes (empty set = every rule)."""
    sup: Dict[int, Set[str]] = {}
    for i, line in enumerate(source.splitlines(), start=1):
        m = _SUPPRESS_RE.search(line)
        if m:
            codes = m.group(1)
            sup[i] = ({c.strip().upper() for c in codes.split(",") if c.strip()}
                      if codes else set())
    return sup


def lint_source(source: str, filename: str = "<string>",
                select: Optional[Set[str]] = None) -> List[Diagnostic]:
    try:
        tree = ast.parse(source, filename=filename)
    except SyntaxError as e:
        return [Diagnostic("SPAC200", "error", f"does not parse: {e.msg}",
                           f"{filename}:{e.lineno or 0}")]
    sup = _suppressions(source)
    findings: List[_Finding] = []
    for check in _PASSES:
        findings.extend(check(tree))
    diags = []
    for f in sorted(findings, key=lambda f: (f.lineno, f.code)):
        if select is not None and f.code not in select:
            continue
        codes = sup.get(f.lineno)
        if codes is not None and (not codes or f.code in codes):
            continue
        diags.append(Diagnostic(f.code, "warning", f.message,
                                f"{filename}:{f.lineno}", hint=f.hint))
    return diags


def _iter_py_files(paths: Iterable[str]) -> List[str]:
    files = []
    for p in paths:
        if os.path.isfile(p):
            files.append(p)
        else:
            for root, dirs, names in os.walk(p):
                dirs[:] = sorted(d for d in dirs
                                 if not d.startswith((".", "__pycache__")))
                files.extend(os.path.join(root, n) for n in sorted(names)
                             if n.endswith(".py"))
    return files


def lint_paths(paths: Sequence[str],
               select: Optional[Set[str]] = None) -> List[Diagnostic]:
    diags = []
    for path in _iter_py_files(paths):
        with open(path, "r", encoding="utf-8") as fh:
            diags.extend(lint_source(fh.read(), filename=path, select=select))
    return diags


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="spaclint",
        description="static rules for the repo's determinism and "
                    "jit-hygiene contracts (SPAC2xx)")
    p.add_argument("paths", nargs="*",
                   help="files or directories to lint (default: .)")
    p.add_argument("--format", choices=("text", "json"), default="text")
    p.add_argument("--select", default=None, metavar="CODES",
                   help="comma-separated rule codes to run (default: all)")
    p.add_argument("--list-rules", action="store_true",
                   help="print the rule catalogue and exit")
    return p


def main(argv: Optional[Sequence[str]] = None) -> int:
    args = build_parser().parse_args(argv)
    if args.list_rules:
        for rule in RULES.values():
            print(f"{rule.code}  {rule.summary}")
            print(f"    contract: {rule.contract}")
            print(f"    incident: {rule.incident}")
        return 0
    select: Optional[Set[str]] = None
    if args.select:
        select = {c.strip().upper() for c in args.select.split(",")
                  if c.strip()}
        unknown = select - set(RULES)
        if unknown:
            print(f"spaclint: unknown rule code(s): {', '.join(sorted(unknown))}"
                  f" (known: {', '.join(RULES)})", file=sys.stderr)
            return EXIT_USAGE
    paths = list(args.paths) or ["."]
    missing = [p for p in paths if not os.path.exists(p)]
    if missing:
        print(f"spaclint: no such path: {', '.join(missing)}",
              file=sys.stderr)
        return EXIT_USAGE
    diags = lint_paths(paths, select=select)
    if args.format == "json":
        print(json.dumps(to_json_payload(diags), indent=2, sort_keys=True))
    else:
        print(format_text(diags, clean_message=(
            f"spaclint: {len(_iter_py_files(paths))} file(s) clean")))
    return exit_code(diags)


if __name__ == "__main__":
    raise SystemExit(main())
