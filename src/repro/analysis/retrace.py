"""Compile-count guard for the jitted DSE engines.

The mesh-sharding contract (PR 6, ``docs/architecture.md``) includes a cost
clause the determinism tests cannot see: the lru-cached sharded engine
builders must compile **once per (shape, mesh)** — a silently retracing
engine still produces bit-identical numbers while quietly throwing away the
batched stages' entire speedup.  This module makes that clause assertable.

The engine modules register their jitted callables at creation time
(``track``); ``compile_counts`` reads each callable's jit cache size (the
number of distinct (shape, static-args) entries traced so far), and
``retrace_guard`` turns a before/after delta into a hard assertion:

    with retrace_guard(expect=1) as g:
        run_surrogate_batched(cands, bound, trace)   # first call: one trace
    with retrace_guard(expect=0):
        run_surrogate_batched(cands, bound, trace)   # same shapes: cached

Deliberately dependency-free (no jax import): the engine modules import
``track`` at module load, and this module must never create an import cycle
back through ``repro.sim``/``repro.api``.
"""

from __future__ import annotations

import contextlib
from typing import Any, Callable, Dict, Optional

__all__ = ["track", "tracked_names", "compile_counts", "RetraceError",
           "RetraceGuard", "retrace_guard"]

#: name -> jitted callable; names are stable ("surrogate.engine") or derived
#: from the sharded builder's cache key ("netsim.sharded[cand=2,...]")
_TRACKED: Dict[str, Callable] = {}


def track(name: str, fn: Callable) -> Callable:
    """Register a jitted callable under a stable name and return it
    unchanged — the engine modules wrap their ``jax.jit(...)`` calls in this
    at creation time (module level and inside the lru-cached builders)."""
    _TRACKED[name] = fn
    return fn


def tracked_names():
    return sorted(_TRACKED)


def _jit_cache_size(fn: Any) -> int:
    """Distinct traced entries of one jitted callable (0 if unreadable)."""
    probe = getattr(fn, "_cache_size", None)
    if callable(probe):
        try:
            return int(probe())
        except Exception:       # pragma: no cover - jax-internal API drift
            return 0
    return 0


def compile_counts() -> Dict[str, int]:
    """Current per-engine trace counts, summable into a before/after delta."""
    return {name: _jit_cache_size(fn) for name, fn in _TRACKED.items()}


class RetraceError(AssertionError):
    """An engine traced more (or less) than the contract allows."""


class RetraceGuard:
    """Before/after snapshot of every tracked engine's jit cache."""

    def __init__(self):
        self._before = compile_counts()
        self._after: Optional[Dict[str, int]] = None

    def finish(self) -> None:
        self._after = compile_counts()

    def deltas(self) -> Dict[str, int]:
        """Per-engine new compiles since the guard opened (engines first
        tracked inside the guarded region count in full)."""
        after = self._after if self._after is not None else compile_counts()
        return {name: n - self._before.get(name, 0)
                for name, n in after.items()
                if n - self._before.get(name, 0) != 0}

    @property
    def new_compiles(self) -> int:
        return sum(self.deltas().values())


@contextlib.contextmanager
def retrace_guard(expect: Optional[int] = None):
    """Assert the guarded region compiled exactly ``expect`` new engine
    traces (``None`` = just observe; read ``.deltas()`` afterwards)."""
    guard = RetraceGuard()
    try:
        yield guard
    finally:
        guard.finish()
    if expect is not None and guard.new_compiles != expect:
        raise RetraceError(
            f"expected exactly {expect} new engine compile(s), got "
            f"{guard.new_compiles}: {guard.deltas() or '{}'}")
