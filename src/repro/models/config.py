"""Model configuration + sharding plan datatypes for all 10 architectures."""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp

__all__ = ["ModelConfig", "ShardingPlan", "SINGLE_POD_PLAN", "MULTI_POD_PLAN"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0              # 0 -> d_model // n_heads
    # --- MoE (the switch-fabric layer) ---
    moe_experts: int = 0
    moe_topk: int = 0
    capacity_factor: float = 1.25  # VOQ depth sizing — DSE-tunable
    router: str = "learned_topk"   # FullLookup analogue | "hash" (MultiBankHash)
    # --- SSM ---
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    # --- attention ---
    rope_theta: float = 1e6
    mrope: bool = False                      # qwen2-vl M-RoPE
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)
    sliding_window: int = 0                  # hybrid long-context attention
    attn_impl: str = "auto"                  # plain | blockwise | auto
    # --- IO frontend ---
    frontend: str = "tokens"                 # tokens | embeddings (vlm/audio stub)
    # --- numerics ---
    dtype: str = "bfloat16"
    norm_eps: float = 1e-6
    remat: str = "block"                     # none | block — activation ckpt policy
    # --- lowering/measurement knobs (dry-run cost variant) ---
    scan_layers: bool = True                 # False: unroll (exact cost_analysis)
    attn_unroll: bool = False                # fully unroll blockwise-attn scans

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def ssm_heads(self) -> int:
        return (self.d_model * self.ssm_expand) // self.ssm_headdim

    @property
    def ssm_inner(self) -> int:
        return self.d_model * self.ssm_expand

    @property
    def is_moe(self) -> bool:
        return self.moe_experts > 0

    @property
    def has_attention(self) -> bool:
        return self.family != "ssm"

    @property
    def has_ssm(self) -> bool:
        return self.family in ("ssm", "hybrid")

    @property
    def activation_dtype(self):
        return jnp.dtype(self.dtype)

    # ------------------------------------------------------- parameter counts
    def param_count(self) -> int:
        d, ff, v = self.d_model, self.d_ff, self.vocab
        per_layer = 0
        if self.has_attention:
            hq, hkv, hd = self.n_heads, self.n_kv_heads, self.hd
            per_layer += d * hq * hd + 2 * d * hkv * hd + hq * hd * d
        if self.has_ssm:
            di, n, hs = self.ssm_inner, self.ssm_state, self.ssm_heads
            # wz + wx + wb + wc + wdt + conv + norm_g + wo (+ per-head scalars)
            per_layer += d * (2 * di + 2 * n + hs) + di * (self.ssm_conv + 1) + di * d + 3 * hs
        if self.is_moe:
            per_layer += self.moe_experts * (3 * d * ff) + d * self.moe_experts
        elif ff:
            per_layer += 3 * d * ff                      # gated MLP
        per_layer += 2 * d                               # norms
        return self.n_layers * per_layer + 2 * v * d     # embed + unembed

    def active_param_count(self) -> int:
        """6·N_active·D convention for MoE roofline accounting."""
        if not self.is_moe:
            return self.param_count()
        d, ff = self.d_model, self.d_ff
        per_layer_moe_active = self.moe_topk * (3 * d * ff) + d * self.moe_experts
        per_layer_moe_total = self.moe_experts * (3 * d * ff) + d * self.moe_experts
        return self.param_count() - self.n_layers * (per_layer_moe_total - per_layer_moe_active)


@dataclasses.dataclass(frozen=True)
class ShardingPlan:
    """Named-axis parallelism plan; axis names must exist in the mesh."""

    dp_axes: Tuple[str, ...] = ("data",)      # batch-sharding axes
    tp_axis: str = "model"                    # tensor/expert parallel axis
    fsdp_axes: Tuple[str, ...] = ("data",)    # ZeRO-3 weight-sharding axes (⊆ dp)
    fsdp_weights: bool = True
    tensor_parallel: bool = True              # False: pure DP/FSDP (small dense
                                              # models over-shard at TP=16)
    sp_activations: bool = False              # sequence-parallel residual stream
    shard_kv_seq_decode: bool = True          # decode KV cache seq-sharded on tp
    embed_dmodel_sharded: bool = False        # shard embed on d (local gather)
                                              # instead of vocab (replicating
                                              # gather under GSPMD)

    @property
    def all_axes(self) -> Tuple[str, ...]:
        return tuple(self.dp_axes) + (self.tp_axis,)

    @property
    def tp(self):
        """Tensor axis for parameter specs (None disables TP sharding)."""
        return self.tp_axis if self.tensor_parallel else None


#: single pod: batch+FSDP over "data", TP over "model"
SINGLE_POD_PLAN = ShardingPlan(dp_axes=("data",), fsdp_axes=("data",))
#: multi-pod: batch over ("pod","data"), FSDP within pod ("data"), pure DP
#: across pods — the cross-pod gradient all-reduce is where the compressed
#: gradient protocol (int8 payload) applies.
MULTI_POD_PLAN = ShardingPlan(dp_axes=("pod", "data"), fsdp_axes=("data",))
