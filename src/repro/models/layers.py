"""Shared layers: norms, gated MLP, embeddings — functional, pytree params.

Every ``init_*`` returns ``(params, specs)`` where ``specs`` mirrors the
params pytree with ``jax.sharding.PartitionSpec`` leaves; the launcher builds
``NamedSharding``s from them.  Stacked-layer params carry a leading ``L`` dim
(scan-over-layers, MaxText-style) — spec leaves get ``None`` prepended by the
transformer's stacker.
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .config import ModelConfig, ShardingPlan

__all__ = ["rms_norm", "init_embedding", "init_unembed", "init_mlp", "apply_mlp",
           "init_norm", "dense_init"]


def dense_init(key, shape, fan_in: Optional[int] = None, dtype=jnp.bfloat16):
    fan_in = fan_in if fan_in is not None else shape[-2] if len(shape) > 1 else shape[0]
    std = 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def rms_norm(x: jnp.ndarray, gamma: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * gamma.astype(jnp.float32)).astype(x.dtype)


def init_norm(cfg: ModelConfig):
    return jnp.ones((cfg.d_model,), jnp.float32), P(None)


# ----------------------------------------------------------------- embeddings

def _fsdp(plan: ShardingPlan):
    if not plan.fsdp_weights:
        return None
    axes = tuple(plan.fsdp_axes)
    return axes if len(axes) > 1 else axes[0]


def init_embedding(key, cfg: ModelConfig, plan: ShardingPlan):
    p = dense_init(key, (cfg.vocab, cfg.d_model), fan_in=cfg.d_model, dtype=jnp.bfloat16)
    if plan.embed_dmodel_sharded:
        # vocab replicated, d sharded on tp: token gathers stay device-local
        return p, P(None, plan.tp)
    return p, P(plan.tp, _fsdp(plan))


def init_unembed(key, cfg: ModelConfig, plan: ShardingPlan):
    p = dense_init(key, (cfg.d_model, cfg.vocab), dtype=jnp.bfloat16)
    return p, P(_fsdp(plan), plan.tp)


# ------------------------------------------------------------------ gated MLP

def init_mlp(key, cfg: ModelConfig, plan: ShardingPlan, d_ff: Optional[int] = None):
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    params = {
        "wi": dense_init(k1, (d, ff)),
        "wg": dense_init(k2, (d, ff)),
        "wo": dense_init(k3, (ff, d), fan_in=ff),
    }
    fs = _fsdp(plan)
    specs = {
        "wi": P(fs, plan.tp),
        "wg": P(fs, plan.tp),
        "wo": P(plan.tp, fs),
    }
    return params, specs


def apply_mlp(params, x: jnp.ndarray) -> jnp.ndarray:
    h = jnp.einsum("bsd,df->bsf", x, params["wi"])
    g = jnp.einsum("bsd,df->bsf", x, params["wg"])
    h = h * jax.nn.silu(g.astype(jnp.float32)).astype(h.dtype)
    return jnp.einsum("bsf,fd->bsd", h, params["wo"])
