"""MoE layer as a SPAC switching fabric (DESIGN.md §2.2).

Token dispatch to experts *is* an input-queued crossbar, and this layer
implements it with the paper's architecture mapped 1:1:

  forward table   router: ``learned_topk`` (FullLookup: direct indexed one-hot
                  lookup) or ``hash`` (MultiBankHash: k LSH banks; bank
                  conflicts surface as capacity overflows)
  VOQ buffer      per-(shard, expert) capacity buffers [E, C, d]; C sized by
                  the capacity factor — the DSE's statistical buffer sizing
                  (queue-occupancy histogram @ drop rate ε) tunes it
  scheduler       the all-to-all schedule: "single" (one bulk exchange),
                  "chunked:K" (K pipelined exchanges that overlap expert
                  compute — the iSLIP/EDRRM analogue)
  protocol        dispatch payload dtype: bf16 or int8+scales (quant_pack),
                  cutting fabric bytes ~2×
  drops           tokens past capacity are dropped (combine contributes 0),
                  reported in aux — the packet-loss column of Table II

The layer runs inside ``jax.shard_map`` over the full mesh: tokens are
batch-sharded over dp axes, each tensor-parallel shard takes a distinct slice
of its row's tokens (flat EP over the tp axis), packs VOQ buffers, exchanges
them with ``lax.all_to_all`` over tp, runs its local experts, and returns via
the reverse exchange + all-gather.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.kernels.quant_pack import ref as qref
from .config import ModelConfig, ShardingPlan
from .layers import dense_init

__all__ = ["MoEOptions", "init_moe", "apply_moe", "moe_in_specs"]


@dataclasses.dataclass(frozen=True)
class MoEOptions:
    """The DSE-tunable fabric knobs (CommSpec fragment)."""

    capacity_factor: float = 1.25
    payload: str = "bf16"          # bf16 | int8 — dispatch wire format
    a2a_chunks: int = 1            # 1 = "single"; >1 = pipelined chunks
    router: str = "learned_topk"   # learned_topk | hash
    weights: str = "gathered"      # gathered (FSDP all-gather at the boundary)
                                   # | ff_sharded (expert TP over the fsdp axis:
                                   #   zero weight comm — decode/serve path)

    @staticmethod
    def from_config(cfg: ModelConfig) -> "MoEOptions":
        return MoEOptions(capacity_factor=cfg.capacity_factor, router=cfg.router)


def init_moe(key, cfg: ModelConfig, plan: ShardingPlan):
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.moe_experts
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    params = {
        "router": dense_init(k1, (d, e), dtype=jnp.float32),
        "hash_proj": jax.random.normal(k5, (d, 32), jnp.float32),  # LSH banks
        "w1": dense_init(k2, (e, d, ff)),
        "wg": dense_init(k3, (e, d, ff)),
        "w2": dense_init(k4, (e, ff, d), fan_in=ff),
    }
    fs = plan.fsdp_axes if plan.fsdp_weights else None
    fs = fs if fs is None or len(fs) > 1 else fs[0]
    tp = plan.tp_axis
    specs = {
        "router": P(None, None),
        "hash_proj": P(None, None),
        "w1": P(tp, fs, None),
        "wg": P(tp, fs, None),
        "w2": P(tp, None, fs),
    }
    return params, specs


def moe_in_specs(plan: ShardingPlan, weights: str = "gathered"):
    """shard_map in_specs for (x, params).

    gathered:   weights enter TP-sharded only (FSDP gather at the boundary).
    ff_sharded: weights additionally stay sharded on the expert-FFN dim over
                the first fsdp axis — no weight gather at all; the fabric
                computes partial FFNs and psums activations instead.
    """
    tp = plan.tp_axis
    if weights == "ff_sharded":
        fs = plan.fsdp_axes[0]
        return {
            "router": P(None, None),
            "hash_proj": P(None, None),
            "w1": P(tp, None, fs),
            "wg": P(tp, None, fs),
            "w2": P(tp, fs, None),
        }
    return {
        "router": P(None, None),
        "hash_proj": P(None, None),
        "w1": P(tp, None, None),
        "wg": P(tp, None, None),
        "w2": P(tp, None, None),
    }


def _route_learned(xs, router, topk):
    logits = jnp.einsum("td,de->te", xs.astype(jnp.float32), router)
    gates, experts = jax.lax.top_k(jax.nn.softmax(logits, axis=-1), topk)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    # load-balance aux loss (Switch-style): E * sum_e f_e * p_e
    e = router.shape[-1]
    probs_mean = jax.nn.softmax(logits, axis=-1).mean(0)
    counts = jnp.zeros((e,), jnp.float32).at[experts.reshape(-1)].add(1.0)
    f = counts / jnp.maximum(counts.sum(), 1.0)
    aux = e * jnp.sum(f * probs_mean)
    return experts.astype(jnp.int32), gates.astype(xs.dtype), aux


def _route_hash(xs, hash_proj, topk, e):
    """MultiBankHash routing: k independent sign-LSH banks; conflicts appear
    as load imbalance -> capacity overflow (the paper's bank-conflict cost)."""
    proj = jax.lax.stop_gradient(
        jnp.einsum("td,dh->th", xs.astype(jnp.float32), hash_proj))
    bits = (proj > 0).astype(jnp.uint32)
    # fold sign bits into k bank hashes (distinct odd multipliers per bank)
    mults = jnp.asarray([2654435761, 2246822519, 3266489917, 668265263,
                         374761393, 2869860233, 3624381081, 961748927][:topk], jnp.uint32)
    folded = jnp.sum(bits * (jnp.arange(bits.shape[-1], dtype=jnp.uint32) + 1), -1)
    experts = ((folded[:, None] + 1) * mults[None, :] >> jnp.uint32(8)) % jnp.uint32(e)
    gates = jnp.full(experts.shape, 1.0 / topk, xs.dtype)
    return experts.astype(jnp.int32), gates, jnp.zeros((), jnp.float32)


def _fabric(xs, params, cfg: ModelConfig, opts: MoEOptions, tp_axis: str,
            tp_size: int, ff_axis=None):
    """Per-device dispatch → exchange → expert FFN → return.  xs [T_m, d]."""
    t_m, d = xs.shape
    e, k = cfg.moe_experts, cfg.moe_topk
    e_loc = e // tp_size
    cap = max(int(np.ceil(t_m * k / e * opts.capacity_factor)), 1)

    if opts.router == "hash":
        experts, gates, aux = _route_hash(xs, params["hash_proj"], k, e)
    else:
        experts, gates, aux = _route_learned(xs, params["router"], k)

    # ---- VOQ pack: sort by expert, position-in-queue, drop past capacity
    e_flat = experts.reshape(-1)                                  # [T_m*k]
    g_flat = gates.reshape(-1)
    order = jnp.argsort(e_flat)                                   # stable
    es = e_flat[order]
    ts = order // k
    gs = g_flat[order]
    counts = jnp.bincount(es, length=e)                           # queue occupancy
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(es.shape[0], dtype=jnp.int32) - starts[es].astype(jnp.int32)
    keep = pos < cap
    slot = jnp.where(keep, es * cap + pos, 0)
    buf = jnp.zeros((e * cap, d), xs.dtype).at[slot].add(
        jnp.where(keep[:, None], xs[ts], 0))

    # ---- fabric exchange + expert compute, possibly in pipelined chunks
    n_chunks = max(1, min(opts.a2a_chunks, cap))
    c_sub = -(-cap // n_chunks)
    pad = n_chunks * c_sub - cap
    buf4 = buf.reshape(e, cap, d)
    if pad:
        buf4 = jnp.pad(buf4, ((0, 0), (0, pad), (0, 0)))
    buf5 = buf4.reshape(tp_size, e_loc, n_chunks, c_sub, d)

    w1, wg, w2 = params["w1"], params["wg"], params["w2"]         # local [E_loc,...]

    def expert_ffn(xin):                                          # [M, E_loc, c, d]
        h = jnp.einsum("mecd,edf->mecf", xin, w1)   # ff possibly ff/|ff_axis|
        g = jnp.einsum("mecd,edf->mecf", xin, wg)
        h = h * jax.nn.silu(g.astype(jnp.float32)).astype(h.dtype)
        y = jnp.einsum("mecf,efd->mecd", h, w2)
        if ff_axis is not None:                     # combine partial FFN sums
            y = jax.lax.psum(y, ff_axis)
        return y

    outs = []
    for ci in range(n_chunks):                                    # pipelined exchanges
        send = buf5[:, :, ci]                                     # [M, E_loc, c_sub, d]
        if opts.payload == "int8":
            q, s = qref.quantize_ref(send.reshape(-1, d))
            q = jax.lax.all_to_all(q.reshape(tp_size, e_loc, c_sub, d), tp_axis, 0, 0)
            s = jax.lax.all_to_all(
                s.reshape(tp_size, e_loc, c_sub, d // qref.GROUP), tp_axis, 0, 0)
            recv = qref.dequantize_ref(
                q.reshape(-1, d), s.reshape(-1, d // qref.GROUP), xs.dtype
            ).reshape(tp_size, e_loc, c_sub, d)
        else:
            recv = jax.lax.all_to_all(send, tp_axis, 0, 0)
        y = expert_ffn(recv)
        if opts.payload == "int8":
            q, s = qref.quantize_ref(y.reshape(-1, d))
            q = jax.lax.all_to_all(q.reshape(tp_size, e_loc, c_sub, d), tp_axis, 0, 0)
            s = jax.lax.all_to_all(
                s.reshape(tp_size, e_loc, c_sub, d // qref.GROUP), tp_axis, 0, 0)
            y = qref.dequantize_ref(
                q.reshape(-1, d), s.reshape(-1, d // qref.GROUP), xs.dtype
            ).reshape(tp_size, e_loc, c_sub, d)
        else:
            y = jax.lax.all_to_all(y, tp_axis, 0, 0)
        outs.append(y)

    y5 = jnp.stack(outs, axis=2)                                  # [M, E_loc, K, c_sub, d]
    y_flat = y5.reshape(e, n_chunks * c_sub, d)[:, :cap].reshape(e * cap, d)

    # ---- VOQ combine: weighted un-dispatch (dropped tokens contribute 0)
    vals = y_flat[slot] * (gs * keep)[:, None]
    y_tok = jnp.zeros((t_m, d), xs.dtype).at[ts].add(vals)

    drop_frac = 1.0 - keep.mean()
    occupancy = counts                                            # per-queue depth sample
    return y_tok, aux, drop_frac, occupancy


def apply_moe(
    params,
    cfg: ModelConfig,
    plan: ShardingPlan,
    mesh,
    x: jnp.ndarray,                     # [B, S, d]
    opts: Optional[MoEOptions] = None,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    opts = opts or MoEOptions.from_config(cfg)
    tp = plan.tp_axis
    dp = tuple(plan.dp_axes)
    try:  # inside a pod-manual region, drop manual axes from the specs
        am = jax.sharding.get_abstract_mesh()
        manual = {n for n, t in zip(am.axis_names, am.axis_types)
                  if t == jax.sharding.AxisType.Manual}
        dp = tuple(a for a in dp if a not in manual)
    except Exception:
        pass
    tp_size = mesh.shape[tp]
    ff_axis = plan.fsdp_axes[0] if opts.weights == "ff_sharded" else None
    ff_size = mesh.shape[ff_axis] if ff_axis else 1

    def f(xl, prm):
        b_loc, s, d = xl.shape
        flat = xl.reshape(b_loc * s, d)
        if ff_axis:
            # expert-TP fabric: gather this row-group's tokens over the ff
            # axis (cheap at decode), compute partial FFNs on every shard,
            # psum the partials, then keep our slice — zero weight movement.
            row = jax.lax.axis_index(ff_axis)
            t_row = flat.shape[0]
            flat = jax.lax.all_gather(flat, ff_axis, axis=0, tiled=True)
        m_idx = jax.lax.axis_index(tp)
        t_loc = flat.shape[0]
        t_m = -(-t_loc // tp_size)                 # ceil: decode rows < tp_size
        pad = t_m * tp_size - t_loc
        if pad:
            flat = jnp.pad(flat, ((0, pad), (0, 0)))
        xs = jax.lax.dynamic_slice_in_dim(flat, m_idx * t_m, t_m, axis=0)
        y_m, aux, drops, occ = _fabric(xs, prm, cfg, opts, tp, tp_size,
                                       ff_axis=ff_axis)
        y = jax.lax.all_gather(y_m, tp, axis=0, tiled=True)       # [T_loc(+pad), d]
        if pad:
            y = y[:t_loc]
        if ff_axis:
            y = jax.lax.dynamic_slice_in_dim(y, row * t_row, t_row, axis=0)
        aux = jax.lax.pmean(aux, tp)
        drops = jax.lax.pmean(drops, tp)
        occ = jax.lax.psum(occ, tp)
        for ax in dp:
            aux = jax.lax.pmean(aux, ax)
            drops = jax.lax.pmean(drops, ax)
            occ = jax.lax.psum(occ, ax)
        return y.reshape(b_loc, s, d), aux, drops, occ

    in_specs = (P(dp, None, None), moe_in_specs(plan, opts.weights))
    out_specs = (P(dp, None, None), P(), P(), P())
    y, aux, drops, occ = compat.shard_map(
        f, mesh, in_specs=in_specs, out_specs=out_specs, check=False,
    )(x, {k: params[k] for k in ("router", "hash_proj", "w1", "wg", "w2")})
    return y, {"aux_loss": aux, "drop_frac": drops, "expert_load": occ}
