"""Model zoo: config/plan datatypes, layers, attention, MoE fabric, SSD,
transformer composition for all 10 assigned architectures."""
from .config import (MULTI_POD_PLAN, SINGLE_POD_PLAN, ModelConfig, ShardingPlan)
from .moe import MoEOptions
from .transformer import (ModelBundle, decode_state_structs, decode_step, forward,
                          init_decode_state, init_params, loss_fn, param_specs,
                          prefill)
__all__ = ["MULTI_POD_PLAN", "ModelBundle", "ModelConfig", "MoEOptions",
           "SINGLE_POD_PLAN", "ShardingPlan", "decode_step", "forward",
           "init_decode_state", "init_params", "loss_fn", "param_specs", "prefill",
           "decode_state_structs"]
