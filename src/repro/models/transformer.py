"""Model composition: blocks, scan-over-layers, train forward, decode step.

One code path serves all 10 architectures; the block is assembled from the
config's family:

  dense / vlm / audio  : rmsnorm → GQA attn → rmsnorm → gated MLP
  moe                  : rmsnorm → GQA attn → rmsnorm → switch-fabric MoE
  ssm                  : rmsnorm → Mamba-2 SSD mix (attention-free)
  hybrid (hymba)       : rmsnorm → ½·(attn ‖ SSD) parallel heads → rmsnorm → MLP

Layers are scanned with stacked parameters (small HLO, fast 512-device
compiles) and per-block activation checkpointing (cfg.remat).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import attention as attn_mod
from . import mamba2 as ssm_mod
from . import moe as moe_mod
from .config import ModelConfig, ShardingPlan
from .layers import (apply_mlp, init_embedding, init_mlp, init_norm, init_unembed,
                     rms_norm)

__all__ = ["init_params", "param_specs", "forward", "loss_fn", "init_decode_state",
           "decode_state_structs", "prefill", "decode_step", "ModelBundle"]




def _visible_axes(axes):
    """Drop mesh axes that are Manual in the current tracing context (e.g.
    inside the pod-manual shard_map of the compressed gradient protocol)."""
    try:
        am = jax.sharding.get_abstract_mesh()
        manual = {n for n, t in zip(am.axis_names, am.axis_types)
                  if t == jax.sharding.AxisType.Manual}
    except Exception:
        manual = set()
    return tuple(a for a in axes if a not in manual)

# --------------------------------------------------------------------- init

def _init_block(key, cfg: ModelConfig, plan: ShardingPlan):
    keys = jax.random.split(key, 6)
    params: Dict[str, Any] = {}
    specs: Dict[str, Any] = {}
    params["ln1"], specs["ln1"] = init_norm(cfg)
    if cfg.has_attention:
        params["attn"], specs["attn"] = attn_mod.init_attention(keys[0], cfg, plan)
    if cfg.has_ssm:
        params["ssm"], specs["ssm"] = ssm_mod.init_mamba(keys[1], cfg, plan)
    if cfg.family == "ssm":
        return params, specs                     # mamba2: single-mix block, no MLP
    params["ln2"], specs["ln2"] = init_norm(cfg)
    if cfg.is_moe:
        params["moe"], specs["moe"] = moe_mod.init_moe(keys[2], cfg, plan)
    else:
        params["mlp"], specs["mlp"] = init_mlp(keys[3], cfg, plan)
    return params, specs


def init_params(key, cfg: ModelConfig, plan: ShardingPlan):
    """Returns (params, specs): layers stacked along a leading L dim."""
    k_embed, k_un, k_layers = jax.random.split(key, 3)
    params: Dict[str, Any] = {}
    specs: Dict[str, Any] = {}
    if cfg.frontend == "tokens":
        params["embed"], specs["embed"] = init_embedding(k_embed, cfg, plan)
    params["unembed"], specs["unembed"] = init_unembed(k_un, cfg, plan)
    params["final_norm"], specs["final_norm"] = init_norm(cfg)

    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    l0, block_specs = _init_block(layer_keys[0], cfg, plan)
    layers = [l0] + [_init_block(k, cfg, plan)[0] for k in layer_keys[1:]]
    params["layers"] = jax.tree.map(lambda *xs: jnp.stack(xs), *layers)
    specs["layers"] = jax.tree.map(
        lambda s: P(*((None,) + tuple(s))), block_specs,
        is_leaf=lambda x: isinstance(x, P))
    return params, specs


def param_specs(cfg: ModelConfig, plan: ShardingPlan):
    """Spec tree without materialising parameters (dry-run path)."""
    captured = {}

    def f(k):
        params, specs = init_params(k, cfg, plan)
        captured["specs"] = specs
        return params

    jax.eval_shape(f, jax.random.PRNGKey(0))
    return captured["specs"]


# ------------------------------------------------------------------- forward

def _block_apply(layer_params, cfg: ModelConfig, plan: ShardingPlan, mesh,
                 x, positions, moe_opts, window: int):
    h = rms_norm(x, layer_params["ln1"], cfg.norm_eps)
    aux = {}
    if cfg.family == "ssm":
        x = x + ssm_mod.apply_mamba(layer_params["ssm"], cfg, h)
        return x, aux
    if cfg.family == "hybrid":
        a = attn_mod.apply_attention(layer_params["attn"], cfg, h, positions, window=window)
        m = ssm_mod.apply_mamba(layer_params["ssm"], cfg, h)
        x = x + 0.5 * (a + m)                       # parallel heads (Hymba)
    else:
        x = x + attn_mod.apply_attention(layer_params["attn"], cfg, h, positions,
                                         window=window)
    h2 = rms_norm(x, layer_params["ln2"], cfg.norm_eps)
    if cfg.is_moe:
        y, aux = moe_mod.apply_moe(layer_params["moe"], cfg, plan, mesh, h2, moe_opts)
        x = x + y
    else:
        x = x + apply_mlp(layer_params["mlp"], h2)
    return x, aux


def forward(
    params,
    cfg: ModelConfig,
    plan: ShardingPlan,
    mesh,
    batch: Dict[str, jnp.ndarray],
    *,
    moe_opts: Optional[moe_mod.MoEOptions] = None,
    window: int = 0,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Token/embedding batch -> logits [B, S, V] (+ aux)."""
    dp = _visible_axes(plan.dp_axes)
    if cfg.frontend == "tokens":
        tok = batch["tokens"]
        x = params["embed"].astype(cfg.activation_dtype)[tok]
        b, s = tok.shape
    else:
        x = batch["embeddings"].astype(cfg.activation_dtype)
        b, s = x.shape[:2]
    x_spec = P(dp, plan.tp_axis, None) if plan.sp_activations else P(dp, None, None)
    x = jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(mesh, x_spec))
    if cfg.mrope:
        positions = batch.get("positions3")
        if positions is None:
            base = jnp.arange(s)[None].repeat(b, 0)
            positions = jnp.stack([base, base, base], axis=1)
    else:
        positions = batch.get("positions")
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    block = functools.partial(_block_apply, cfg=cfg, plan=plan, mesh=mesh,
                              positions=positions, moe_opts=moe_opts, window=window)
    sp_sharding = (jax.sharding.NamedSharding(mesh, P(dp, plan.tp_axis, None))
                   if plan.sp_activations else None)

    def scan_body(carry, layer_params):
        fn = block
        if cfg.remat == "block":
            fn = jax.checkpoint(lambda lp, xx: block(layer_params=lp, x=xx),
                                prevent_cse=False)
            y, aux = fn(layer_params, carry)
        else:
            y, aux = fn(layer_params=layer_params, x=carry)
        if sp_sharding is not None:
            # sequence parallelism: residual stream lives seq-sharded on the
            # tensor axis between blocks -> TP all-reduces lower to
            # reduce-scatter (+ gather at the next consumer)
            y = jax.lax.with_sharding_constraint(y, sp_sharding)
        return y, aux

    if cfg.scan_layers:
        x, auxs = jax.lax.scan(scan_body, x, params["layers"])
    else:  # unrolled: exact cost_analysis accounting (dry-run cost variant)
        aux_list = []
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda a: a[i], params["layers"])
            x, aux_i = scan_body(x, lp)
            aux_list.append(aux_i)
        auxs = jax.tree.map(lambda *xs: jnp.stack(xs), *aux_list) if aux_list and aux_list[0] else {}
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["unembed"].astype(x.dtype))
    aux = {k: v.mean() for k, v in auxs.items()} if auxs else {}
    return logits, aux


def loss_fn(params, cfg, plan, mesh, batch, *, moe_opts=None, window: int = 0,
            aux_weight: float = 0.01):
    logits, aux = forward(params, cfg, plan, mesh, batch,
                          moe_opts=moe_opts, window=window)
    labels = batch["labels"]
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    nll = logz - gold
    mask = (labels >= 0).astype(jnp.float32)
    loss = jnp.sum(nll * mask) / jnp.maximum(mask.sum(), 1.0)
    if aux and "aux_loss" in aux:
        loss = loss + aux_weight * aux["aux_loss"]
    metrics = {"loss": loss, "tokens": mask.sum(), **aux}
    return loss, metrics


# ------------------------------------------------------------------- prefill

def prefill(
    params,
    cfg: ModelConfig,
    plan: ShardingPlan,
    mesh,
    batch: Dict[str, jnp.ndarray],
    *,
    moe_opts: Optional[moe_mod.MoEOptions] = None,
) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    """Serving prefill: consume the prompt, emit (last-token logits, decode
    state) — the real serve-side counterpart of the decode cells."""
    dp = _visible_axes(plan.dp_axes)
    if cfg.frontend == "tokens":
        tok = batch["tokens"]
        x = params["embed"].astype(cfg.activation_dtype)[tok]
        b, s = tok.shape
    else:
        x = batch["embeddings"].astype(cfg.activation_dtype)
        b, s = x.shape[:2]
    x = jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(mesh, P(dp, None, None)))
    if cfg.mrope:
        positions = batch.get("positions3")
        if positions is None:
            base = jnp.arange(s)[None].repeat(b, 0)
            positions = jnp.stack([base, base, base], axis=1)
    else:
        positions = batch.get("positions")
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    window = cfg.sliding_window
    cache_len = min(window, s) if window else s

    def scan_body(carry, lp):
        x = carry
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        cache: Dict[str, Any] = {}
        if cfg.family == "ssm":
            y, st = ssm_mod.apply_mamba(lp["ssm"], cfg, h, return_state=True)
            return x + y, st
        if cfg.family == "hybrid":
            a, ck, cv = attn_mod.apply_attention(lp["attn"], cfg, h, positions,
                                                 window=window, return_kv=True)
            m, st = ssm_mod.apply_mamba(lp["ssm"], cfg, h, return_state=True)
            x = x + 0.5 * (a + m)
            cache = {"cache_k": ck[:, :, -cache_len:], "cache_v": cv[:, :, -cache_len:], **st}
        else:
            a, ck, cv = attn_mod.apply_attention(lp["attn"], cfg, h, positions,
                                                 return_kv=True)
            x = x + a
            cache = {"cache_k": ck[:, :, -cache_len:], "cache_v": cv[:, :, -cache_len:]}
        h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
        if cfg.is_moe:
            y, _ = moe_mod.apply_moe(lp["moe"], cfg, plan, mesh, h2, moe_opts)
            x = x + y
        else:
            x = x + apply_mlp(lp["mlp"], h2)
        return x, cache

    if cfg.scan_layers:
        x, caches = jax.lax.scan(scan_body, x, params["layers"])
    else:
        cache_list = []
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda a: a[i], params["layers"])
            x, ci = scan_body(x, lp)
            cache_list.append(ci)
        caches = jax.tree.map(lambda *xs: jnp.stack(xs), *cache_list)
    x_last = rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x_last, params["unembed"].astype(x.dtype))
    state = dict(caches)
    state["pos"] = jnp.asarray(s, jnp.int32)
    return logits[:, 0], state


# -------------------------------------------------------------------- decode

def decode_state_structs(cfg: ModelConfig, plan: ShardingPlan, batch: int, s_max: int):
    """Abstract decode-state descriptions + shardings (no allocation)."""
    dp = tuple(plan.dp_axes)
    tp = plan.tp_axis
    structs: Dict[str, Any] = {"pos": jax.ShapeDtypeStruct((), jnp.int32)}
    specs: Dict[str, Any] = {"pos": P()}
    if cfg.has_attention:
        hkv, hd = cfg.n_kv_heads, cfg.hd
        window = cfg.sliding_window or s_max
        cache_len = min(window, s_max)
        shape = (cfg.n_layers, batch, hkv, cache_len, hd)
        structs["cache_k"] = jax.ShapeDtypeStruct(shape, cfg.activation_dtype)
        structs["cache_v"] = jax.ShapeDtypeStruct(shape, cfg.activation_dtype)
        seq_ax = tp if plan.shard_kv_seq_decode else None
        head_ax = None if plan.shard_kv_seq_decode else tp
        specs["cache_k"] = specs["cache_v"] = P(None, dp, head_ax, seq_ax, None)
    if cfg.has_ssm:
        h, p, n, di = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state, cfg.ssm_inner
        structs["ssm"] = jax.ShapeDtypeStruct((cfg.n_layers, batch * h, p, n), jnp.float32)
        structs["conv"] = jax.ShapeDtypeStruct((cfg.n_layers, batch, cfg.ssm_conv - 1, di),
                                               cfg.activation_dtype)
        specs["ssm"] = P(None, dp, None, None)
        specs["conv"] = P(None, dp, None, tp)
    return structs, specs


def init_decode_state(cfg: ModelConfig, plan: ShardingPlan, batch: int, s_max: int):
    """Allocate per-layer caches/states (stacked on L) + their shardings."""
    structs, specs = decode_state_structs(cfg, plan, batch, s_max)
    state = jax.tree.map(lambda st: jnp.zeros(st.shape, st.dtype), structs)
    return state, specs


def decode_step(
    params,
    cfg: ModelConfig,
    plan: ShardingPlan,
    mesh,
    state: Dict[str, Any],
    tokens_or_embeds: jnp.ndarray,            # [B, 1] int32 or [B, 1, d]
    *,
    moe_opts: Optional[moe_mod.MoEOptions] = None,
) -> Tuple[Dict[str, Any], jnp.ndarray]:
    """One serving step: consume one token, emit next-token logits."""
    dp = tuple(plan.dp_axes)
    pos = state["pos"]
    if cfg.frontend == "tokens":
        x = params["embed"].astype(cfg.activation_dtype)[tokens_or_embeds]
    else:
        x = tokens_or_embeds.astype(cfg.activation_dtype)
    b = x.shape[0]
    if cfg.mrope:
        pq = jnp.broadcast_to(pos[None, None], (b, 1))
        positions_q = jnp.stack([pq, pq, pq], axis=1)
    else:
        positions_q = jnp.broadcast_to(pos[None, None], (b, 1))
    window = cfg.sliding_window

    def scan_body(carry, inp):
        x = carry
        lp, cache = inp
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        new_cache = {}
        if cfg.family == "ssm":
            st = {"ssm": cache["ssm"], "conv": cache["conv"]}
            st, y = ssm_mod.decode_mamba(lp["ssm"], cfg, st, h)
            x = x + y
            return x, st
        if cfg.family == "hybrid":
            a, ck, cv = attn_mod.decode_attention(
                lp["attn"], cfg, h, cache["cache_k"], cache["cache_v"],
                pos % cache["cache_k"].shape[2], positions_q, ring=True)
            st = {"ssm": cache["ssm"], "conv": cache["conv"]}
            st, m = ssm_mod.decode_mamba(lp["ssm"], cfg, st, h)
            x = x + 0.5 * (a + m)
            new_cache = {"cache_k": ck, "cache_v": cv, **st}
        else:
            a, ck, cv = attn_mod.decode_attention(
                lp["attn"], cfg, h, cache["cache_k"], cache["cache_v"],
                pos, positions_q, window=window)
            x = x + a
            new_cache = {"cache_k": ck, "cache_v": cv}
        h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
        if cfg.is_moe:
            y, _ = moe_mod.apply_moe(lp["moe"], cfg, plan, mesh, h2, moe_opts)
            x = x + y
        else:
            x = x + apply_mlp(lp["mlp"], h2)
        return x, new_cache

    cache_keys = [k for k in ("cache_k", "cache_v", "ssm", "conv") if k in state]
    caches = {k: state[k] for k in cache_keys}
    if cfg.scan_layers:
        x, new_caches = jax.lax.scan(scan_body, x, (params["layers"], caches))
    else:
        nc_list = []
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda a: a[i], params["layers"])
            ci = jax.tree.map(lambda a: a[i], caches)
            x, nc = scan_body(x, (lp, ci))
            nc_list.append(nc)
        new_caches = jax.tree.map(lambda *xs: jnp.stack(xs), *nc_list)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["unembed"].astype(x.dtype))
    new_state = dict(state)
    new_state.update(new_caches)
    new_state["pos"] = pos + 1
    return new_state, logits


@dataclasses.dataclass
class ModelBundle:
    """Convenience wrapper used by the launcher and examples."""

    cfg: ModelConfig
    plan: ShardingPlan
    mesh: Any

    def init(self, key):
        return init_params(key, self.cfg, self.plan)

    def loss(self, params, batch, **kw):
        return loss_fn(params, self.cfg, self.plan, self.mesh, batch, **kw)

    def decode(self, params, state, tok, **kw):
        return decode_step(params, self.cfg, self.plan, self.mesh, state, tok, **kw)
