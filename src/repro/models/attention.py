"""GQA attention with RoPE / M-RoPE, blockwise (flash-style) XLA path, and
seq-sharded KV-cache decode.

Implementation selection:
  * ``plain``      — full [S, T] score materialisation (small S only)
  * ``blockwise``  — lax.scan online-softmax over KV blocks; O(S·bk) live
                     memory, same FLOP shape as the Pallas kernel ⇒ the
                     dry-run roofline transfers to the TPU deployment path
  * ``auto``       — blockwise when S ≥ 8192

Decode reads a KV cache laid out [B, Hkv, S_max, hd]; at 32k–500k contexts
the cache is sequence-sharded across the tensor axis and GSPMD turns the
softmax reductions into the flash-decode combine.
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .config import ModelConfig, ShardingPlan
from .layers import dense_init

__all__ = ["init_attention", "apply_attention", "decode_attention", "rope", "mrope"]

NEG_INF = -1e30


# ------------------------------------------------------------------- RoPE

def _rope_angles(positions: jnp.ndarray, dim: int, theta: float) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """positions [...] -> cos/sin [..., dim/2]."""
    freqs = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def _apply_rot(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """x [..., d] rotated pairwise-interleaved as (x1, x2) halves."""
    d = x.shape[-1]
    x1, x2 = x[..., : d // 2], x[..., d // 2:]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


def rope(q: jnp.ndarray, k: jnp.ndarray, positions: jnp.ndarray, theta: float):
    """q/k [B, H, S, hd]; positions [B, S]."""
    cos, sin = _rope_angles(positions, q.shape[-1], theta)       # [B, S, hd/2]
    cos, sin = cos[:, None], sin[:, None]
    return _apply_rot(q, cos, sin), _apply_rot(k, cos, sin)


def mrope(q, k, positions3, theta, sections: Tuple[int, int, int]):
    """Qwen2-VL multimodal RoPE: positions3 [B, 3, S] (t, h, w) with the
    rotary dim split into per-modality sections."""
    hd = q.shape[-1]
    half = hd // 2
    cos_parts, sin_parts = [], []
    start = 0
    for comp, sec in enumerate(sections):
        pos = positions3[:, comp]                                 # [B, S]
        freqs = 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))
        ang = pos.astype(jnp.float32)[..., None] * freqs          # [B, S, half]
        cos_parts.append(jnp.cos(ang[..., start:start + sec]))
        sin_parts.append(jnp.sin(ang[..., start:start + sec]))
        start += sec
    cos = jnp.concatenate(cos_parts, -1)[:, None]                 # [B, 1, S, half]
    sin = jnp.concatenate(sin_parts, -1)[:, None]
    return _apply_rot(q, cos, sin), _apply_rot(k, cos, sin)


# ------------------------------------------------------------------- params

def init_attention(key, cfg: ModelConfig, plan: ShardingPlan):
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    k1, k2, k3, k4 = jax.random.split(key, 4)
    params = {
        "wq": dense_init(k1, (d, hq * hd)),
        "wk": dense_init(k2, (d, hkv * hd)),
        "wv": dense_init(k3, (d, hkv * hd)),
        "wo": dense_init(k4, (hq * hd, d), fan_in=hq * hd),
    }
    fs = plan.fsdp_axes if plan.fsdp_weights else None
    fs = fs if fs is None or len(fs) > 1 else fs[0]
    tp = plan.tp
    specs = {"wq": P(fs, tp), "wk": P(fs, tp), "wv": P(fs, tp), "wo": P(tp, fs)}
    return params, specs


def _project(params, x, cfg: ModelConfig):
    b, s, _ = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = jnp.einsum("bsd,dh->bsh", x, params["wq"]).reshape(b, s, hq, hd).transpose(0, 2, 1, 3)
    k = jnp.einsum("bsd,dh->bsh", x, params["wk"]).reshape(b, s, hkv, hd).transpose(0, 2, 1, 3)
    v = jnp.einsum("bsd,dh->bsh", x, params["wv"]).reshape(b, s, hkv, hd).transpose(0, 2, 1, 3)
    return q, k, v


def _group_q(q, hkv):
    """[B, Hq, S, D] -> [B, Hkv, R, S, D]: GQA without materialising expanded
    KV (the grouped-einsum formulation — 8× less KV traffic than repeat)."""
    b, hq, s, d = q.shape
    return q.reshape(b, hkv, hq // hkv, s, d)


def plain_attention(q, k, v, *, causal: bool, window: int = 0) -> jnp.ndarray:
    b, hq, sq, hd = q.shape
    hkv, tk = k.shape[1], k.shape[2]
    q5 = _group_q(q, hkv)
    s = jnp.einsum("bkrsd,bktd->bkrst", q5, k,
                   preferred_element_type=jnp.float32) / (hd ** 0.5)
    rows = jnp.arange(sq)[:, None] + (tk - sq)
    cols = jnp.arange(tk)[None, :]
    if causal:
        s = jnp.where(rows >= cols, s, NEG_INF)
    if window:
        s = jnp.where(rows - cols < window, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)   # bf16 P, f32 accum (MXU style)
    o = jnp.einsum("bkrst,bktd->bkrsd", p, v, preferred_element_type=jnp.float32)
    return o.reshape(b, hq, sq, hd).astype(q.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                              "window", "unroll"))
def blockwise_attention(q, k, v, *, causal: bool = True, block_q: int = 512,
                        block_k: int = 1024, window: int = 0,
                        unroll: bool = False) -> jnp.ndarray:
    """XLA-level flash attention: scan over q blocks (outer) and kv blocks
    (inner, online softmax).  Causal wastage is masked, not skipped — the
    §Perf log tracks the two-phase variant that skips it."""
    b, h, s, hd = q.shape
    hkv, t = k.shape[1], k.shape[2]
    rep = h // hkv
    bq, bk = min(block_q, s), min(block_k, t)
    assert s % bq == 0 and t % bk == 0
    scale = 1.0 / (hd ** 0.5)
    qb = _group_q(q, hkv).reshape(b, hkv, rep, s // bq, bq, hd)

    def q_block(carry, iq):
        qi = qb[:, :, :, iq]                            # [b, hkv, rep, bq, hd]

        def kv_block(state, ik):
            m, l, acc = state
            ks = jax.lax.dynamic_slice_in_dim(k, ik * bk, bk, axis=2)
            vs = jax.lax.dynamic_slice_in_dim(v, ik * bk, bk, axis=2)
            sc = jnp.einsum("bkrqd,bkKd->bkrqK", qi, ks,
                            preferred_element_type=jnp.float32) * scale
            rows = iq * bq + jnp.arange(bq)[:, None] + (t - s)
            cols = ik * bk + jnp.arange(bk)[None, :]
            if causal:
                sc = jnp.where(rows >= cols, sc, NEG_INF)
            if window:
                sc = jnp.where(rows - cols < window, sc, NEG_INF)
            m_new = jnp.maximum(m, sc.max(-1, keepdims=True))
            p = jnp.exp(sc - m_new)
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p.sum(-1, keepdims=True)
            acc_new = acc * alpha + jnp.einsum("bkrqK,bkKd->bkrqd",
                                               p.astype(q.dtype), vs,
                                               preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        init = (jnp.full((b, hkv, rep, bq, 1), NEG_INF, jnp.float32),
                jnp.zeros((b, hkv, rep, bq, 1), jnp.float32),
                jnp.zeros((b, hkv, rep, bq, hd), jnp.float32))
        (m, l, acc), _ = jax.lax.scan(kv_block, init, jnp.arange(t // bk),
                                      unroll=unroll)
        out = (acc / jnp.maximum(l, 1e-30)).astype(q.dtype)
        return carry, out

    _, outs = jax.lax.scan(q_block, None, jnp.arange(s // bq),
                           unroll=unroll)               # [nq, b, hkv, rep, bq, hd]
    return outs.transpose(1, 2, 3, 0, 4, 5).reshape(b, h, s, hd)


def apply_attention(
    params,
    cfg: ModelConfig,
    x: jnp.ndarray,                        # [B, S, d]
    positions,                             # [B, S] or [B, 3, S] for mrope
    *,
    impl: Optional[str] = None,
    window: int = 0,
    return_kv: bool = False,
):
    b, s, d = x.shape
    q, k, v = _project(params, x, cfg)
    if cfg.mrope:
        q, k = mrope(q, k, positions, cfg.rope_theta, cfg.mrope_sections)
    else:
        q, k = rope(q, k, positions, cfg.rope_theta)
    kv_cacheable = (k, v)                  # rotated k, raw v, Hkv heads
    impl = impl or cfg.attn_impl
    if impl == "auto":
        impl = "blockwise" if s >= 8192 else "plain"
    if impl == "blockwise":
        bq = 2048 if cfg.attn_unroll else 512   # fewer, larger blocks when unrolled
        bk = 4096 if cfg.attn_unroll else 1024
        o = blockwise_attention(q, k, v, causal=True, window=window,
                                block_q=bq, block_k=bk, unroll=cfg.attn_unroll)
    else:
        o = plain_attention(q, k, v, causal=True, window=window)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, cfg.n_heads * cfg.hd)
    out = jnp.einsum("bsh,hd->bsd", o, params["wo"])
    if return_kv:
        return out, kv_cacheable[0], kv_cacheable[1]
    return out


# ------------------------------------------------------------------- decode

def decode_attention(
    params,
    cfg: ModelConfig,
    x: jnp.ndarray,                 # [B, 1, d]
    cache_k: jnp.ndarray,           # [B, Hkv, S_max, hd]
    cache_v: jnp.ndarray,
    pos: jnp.ndarray,               # [] int32 — current position
    positions_q,                    # [B, 1] (or [B, 3, 1] mrope)
    *,
    window: int = 0,
    ring: bool = False,             # cache is a ring buffer of size window
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One-token decode with cache update.  Returns (out, cache_k, cache_v)."""
    b = x.shape[0]
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q, k_new, v_new = _project(params, x, cfg)      # [B, H, 1, hd]
    if cfg.mrope:
        q, k_new = mrope(q, k_new, positions_q, cfg.rope_theta, cfg.mrope_sections)
    else:
        q, k_new = rope(q, k_new, positions_q, cfg.rope_theta)
    cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k_new.astype(cache_k.dtype), pos, axis=2)
    cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v_new.astype(cache_v.dtype), pos, axis=2)
    s_max = cache_k.shape[2]
    q5 = _group_q(q, hkv)                                # [B, Hkv, R, 1, hd]
    sc = jnp.einsum("bkrqd,bktd->bkrqt", q5, cache_k,
                    preferred_element_type=jnp.float32) / (hd ** 0.5)
    t_idx = jnp.arange(s_max)[None, None, None, None, :]
    if ring:
        # ring buffer: every slot holds a token from the last `s_max` steps
        valid = (t_idx <= pos) | (pos >= s_max)
    else:
        valid = t_idx <= pos
        if window:
            valid = valid & (t_idx > pos - window)
    sc = jnp.where(valid, sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1).astype(cache_v.dtype)
    o = jnp.einsum("bkrqt,bktd->bkrqd", p, cache_v,
                   preferred_element_type=jnp.float32).astype(x.dtype)
    o = o.reshape(b, hq, 1, hd).transpose(0, 2, 1, 3).reshape(b, 1, hq * hd)
    return jnp.einsum("bsh,hd->bsd", o, params["wo"]), cache_k, cache_v
