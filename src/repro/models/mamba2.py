"""Mamba-2 (SSD) block — attention-free sequence mixing, TP over heads.

Projections are stored unpacked (wz/wx/wb/wc/wdt) so tensor parallelism
shards the head/inner dims cleanly; B and C are group-shared (G=1) and
replicated.  The sequence mix runs through the chunked SSD op
(`repro.kernels.ssd.ops.ssd_chunked`, same math as the Pallas kernel).
Decode keeps a [B, H, P, N] state + a depthwise-conv tail instead of a KV
cache — why SSM/hybrid archs own the 500k-context cells.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.kernels.ssd.ops import ssd_chunked, ssd_decode_step
from .config import ModelConfig, ShardingPlan
from .layers import dense_init

__all__ = ["init_mamba", "apply_mamba", "init_mamba_state", "decode_mamba"]


def _fs(plan):
    if not plan.fsdp_weights:
        return None
    a = tuple(plan.fsdp_axes)
    return a if len(a) > 1 else a[0]


def init_mamba(key, cfg: ModelConfig, plan: ShardingPlan):
    d, di, n, h = cfg.d_model, cfg.ssm_inner, cfg.ssm_state, cfg.ssm_heads
    ks = jax.random.split(key, 8)
    params = {
        "wz": dense_init(ks[0], (d, di)),
        "wx": dense_init(ks[1], (d, di)),
        "wb": dense_init(ks[2], (d, n)),
        "wc": dense_init(ks[3], (d, n)),
        "wdt": dense_init(ks[4], (d, h), dtype=jnp.float32),
        "conv_w": (jax.random.normal(ks[5], (di, cfg.ssm_conv), jnp.float32) * 0.1),
        "a_log": jnp.zeros((h,), jnp.float32),            # A = -exp(a_log) = -1
        "dskip": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm_g": jnp.ones((di,), jnp.float32),
        "wo": dense_init(ks[6], (di, d), fan_in=di),
    }
    fs, tp = _fs(plan), plan.tp
    specs = {
        "wz": P(fs, tp), "wx": P(fs, tp), "wb": P(fs, None), "wc": P(fs, None),
        "wdt": P(fs, tp), "conv_w": P(tp, None), "a_log": P(tp), "dskip": P(tp),
        "dt_bias": P(tp), "norm_g": P(tp), "wo": P(tp, fs),
    }
    return params, specs


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv over seq: x [B, S, C], w [C, K]."""
    k = w.shape[-1]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(k):                    # K=4: unrolled shifts beat conv_general here
        out = out + xp[:, i: i + x.shape[1]] * w[None, None, :, k - 1 - i][0]
    return out


def _heads(x, b, c, dt, cfg):
    bsz, s, _ = x.shape
    h, p, n = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state
    xh = x.reshape(bsz, s, h, p).transpose(0, 2, 1, 3).reshape(bsz * h, s, p)
    bh = jnp.broadcast_to(b[:, None], (bsz, h, s, n)).reshape(bsz * h, s, n)
    ch = jnp.broadcast_to(c[:, None], (bsz, h, s, n)).reshape(bsz * h, s, n)
    dth = dt.transpose(0, 2, 1).reshape(bsz * h, s)
    return xh, bh, ch, dth


def apply_mamba(params, cfg: ModelConfig, x: jnp.ndarray, *, chunk: int = 128,
                return_state: bool = False):
    bsz, s, d = x.shape
    h, p = cfg.ssm_heads, cfg.ssm_headdim
    z = jnp.einsum("bsd,di->bsi", x, params["wz"])
    xi = jnp.einsum("bsd,di->bsi", x, params["wx"])
    xi = jax.nn.silu(_causal_conv(xi, params["conv_w"]).astype(jnp.float32)).astype(x.dtype)
    b = jnp.einsum("bsd,dn->bsn", x, params["wb"])
    c = jnp.einsum("bsd,dn->bsn", x, params["wc"])
    dt = jax.nn.softplus(
        jnp.einsum("bsd,dh->bsh", x.astype(jnp.float32), params["wdt"]) + params["dt_bias"])
    xh, bh, ch, dth = _heads(xi, b, c, dt, cfg)
    a = -jnp.exp(jnp.broadcast_to(params["a_log"][None], (bsz, h)).reshape(-1))
    ch_len = min(chunk, s) if s % min(chunk, s) == 0 else s
    if return_state:
        y, final_state = ssd_chunked(xh, dth, a, bh, ch, chunk=ch_len, return_state=True)
    else:
        y = ssd_chunked(xh, dth, a, bh, ch, chunk=ch_len)              # [BH, S, P]
    y = y + xh * jnp.broadcast_to(
        params["dskip"][None, :, None, None], (bsz, h, s, p)).reshape(bsz * h, s, p)
    y = y.reshape(bsz, h, s, p).transpose(0, 2, 1, 3).reshape(bsz, s, h * p)
    # gated RMSNorm
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    yf = y.astype(jnp.float32)
    y = (yf * jax.lax.rsqrt(jnp.mean(yf * yf, -1, keepdims=True) + cfg.norm_eps)
         * params["norm_g"]).astype(x.dtype)
    out = jnp.einsum("bsi,id->bsd", y, params["wo"])
    if return_state:
        conv_tail = xi[:, -(cfg.ssm_conv - 1):] if s >= cfg.ssm_conv - 1 else jnp.pad(
            xi, ((0, 0), (cfg.ssm_conv - 1 - s, 0), (0, 0)))
        return out, {"ssm": final_state, "conv": conv_tail}
    return out


# ------------------------------------------------------------------ decode

def init_mamba_state(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> Dict[str, jnp.ndarray]:
    h, p, n, di = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state, cfg.ssm_inner
    return {
        "ssm": jnp.zeros((batch * h, p, n), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, di), dtype),
    }


def decode_mamba(params, cfg: ModelConfig, state, x: jnp.ndarray):
    """One-token step.  x [B, 1, d] -> (state, y [B, 1, d])."""
    bsz = x.shape[0]
    h, p, n = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state
    z = jnp.einsum("bsd,di->bsi", x, params["wz"])[:, 0]
    xi = jnp.einsum("bsd,di->bsi", x, params["wx"])[:, 0]            # [B, di]
    window = jnp.concatenate([state["conv"], xi[:, None]], axis=1)   # [B, K, di]
    w = params["conv_w"]                                             # [di, K]
    xc = jnp.einsum("bki,ik->bi", window, w)
    xc = jax.nn.silu(xc.astype(jnp.float32)).astype(x.dtype)
    new_conv = window[:, 1:]
    b = jnp.einsum("bsd,dn->bsn", x, params["wb"])[:, 0]
    c = jnp.einsum("bsd,dn->bsn", x, params["wc"])[:, 0]
    dt = jax.nn.softplus(
        jnp.einsum("bsd,dh->bsh", x.astype(jnp.float32), params["wdt"])[:, 0] + params["dt_bias"])
    xh = xc.reshape(bsz, h, p).reshape(bsz * h, p).astype(jnp.float32)
    bh = jnp.broadcast_to(b[:, None], (bsz, h, n)).reshape(bsz * h, n).astype(jnp.float32)
    chh = jnp.broadcast_to(c[:, None], (bsz, h, n)).reshape(bsz * h, n).astype(jnp.float32)
    dth = dt.reshape(bsz * h)
    a = -jnp.exp(jnp.broadcast_to(params["a_log"][None], (bsz, h)).reshape(-1))
    ssm, yh = ssd_decode_step(state["ssm"], xh, dth, a, bh, chh)
    yh = yh + xh * jnp.broadcast_to(params["dskip"][None], (bsz, h)).reshape(-1)[:, None]
    y = yh.reshape(bsz, h * p).astype(x.dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    yf = y.astype(jnp.float32)
    y = (yf * jax.lax.rsqrt(jnp.mean(yf * yf, -1, keepdims=True) + cfg.norm_eps)
         * params["norm_g"]).astype(x.dtype)
    out = jnp.einsum("bi,id->bd", y, params["wo"])[:, None]
    return {"ssm": ssm, "conv": new_conv}, out
