"""Cycle-level JAX model of the SPAC switch datapath (paper SS III-B).

Module map: parser.py (SS III-B.1), forward_table.py (SS III-B.2),
voq.py (SS III-B.3), scheduler.py (SS III-B.4), switch.py (composition;
egress/deparser is the departure path inside ``simulate``).
"""
from .forward_table import BROADCAST, init_table, learn, lookup
from .parser import make_field_extractor, n_header_words, pack_header_words
from .scheduler import SchedState, init_sched, schedule
from .switch import SwitchSimResult, prepare_cycle_inputs, simulate
from .voq import VOQState, init_voq, occupancy, enqueue, dequeue

__all__ = [
    "BROADCAST", "SchedState", "SwitchSimResult", "VOQState", "dequeue",
    "enqueue", "init_sched", "init_table", "init_voq", "learn", "lookup",
    "make_field_extractor", "n_header_words", "occupancy",
    "pack_header_words", "prepare_cycle_inputs", "schedule", "simulate",
]
