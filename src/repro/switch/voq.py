"""Virtual-Output-Queue buffers (§III-B.3): N×N data VOQs and Shared VOQs.

* **N×N** — fully partitioned per-(input, output) data queues; broadcast
  packets are *copied* into every queue of the source (memory duplication,
  the stated drawback), each queue bounded by ``voq_depth``.
* **Shared** — one central data buffer with pointer-based per-(i,j) queues
  and a per-packet reference count (the bitmap of pending destinations):
  broadcast stores payload once and replicates only pointers.  Total data
  capacity is ``n_ports × voq_depth`` slots (vs N²×depth for N×N), which is
  where the BRAM saving comes from; the logic overhead of pointer management
  shows up as +1 pipeline stage in ``SwitchArch.pipeline_depth``.

Queues store packet *ids*; payload width only affects the resource model and
multi-flit timing (handled by the switch's busy counters).
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax.numpy as jnp

from repro.core.archspec import SwitchArch, VOQKind
from .forward_table import BROADCAST

__all__ = ["VOQState", "init_voq", "occupancy", "enqueue", "dequeue"]


class VOQState(NamedTuple):
    queue: jnp.ndarray       # [N, N, D] int32 packet ids
    head: jnp.ndarray        # [N, N] int32
    tail: jnp.ndarray        # [N, N] int32
    data_slots: jnp.ndarray  # scalar int32: payload slots in use (shared semantics)
    rem_copies: jnp.ndarray  # [n_packets] int32 pending copies (shared refcount)
    drops: jnp.ndarray       # scalar int32 dropped copies


def init_voq(arch: SwitchArch, n_packets: int) -> VOQState:
    n, d = arch.n_ports, arch.voq_depth
    return VOQState(
        queue=jnp.full((n, n, d), -1, dtype=jnp.int32),
        head=jnp.zeros((n, n), dtype=jnp.int32),
        tail=jnp.zeros((n, n), dtype=jnp.int32),
        data_slots=jnp.zeros((), dtype=jnp.int32),
        rem_copies=jnp.zeros((max(n_packets, 1),), dtype=jnp.int32),
        drops=jnp.zeros((), dtype=jnp.int32),
    )


def shared_capacity(arch: SwitchArch) -> int:
    return arch.n_ports * arch.voq_depth


def occupancy(st: VOQState) -> jnp.ndarray:
    return st.tail - st.head


def enqueue(
    arch: SwitchArch,
    st: VOQState,
    pids: jnp.ndarray,      # [N] int32 arriving packet id per input port (-1 none)
    out_ports: jnp.ndarray, # [N] int32 destination port, BROADCAST, or -1 invalid
    valid: jnp.ndarray,     # [N] bool
) -> VOQState:
    n, d = arch.n_ports, arch.voq_depth
    ports = jnp.arange(n, dtype=jnp.int32)
    # fanout matrix: unicast one-hot, broadcast = everyone but the source
    uni = out_ports[:, None] == ports[None, :]
    bcast = (out_ports[:, None] == BROADCAST) & (ports[None, :] != ports[:, None])
    fan = (uni | bcast) & valid[:, None]                                   # [N,N]
    occ = occupancy(st)
    room = occ < d
    if arch.voq is VOQKind.SHARED:
        # shared data buffer admission: packets admitted in port order until full
        wants = fan.any(1)
        order = jnp.cumsum(wants.astype(jnp.int32))
        admit = wants & (st.data_slots + order <= shared_capacity(arch))
        fan = fan & admit[:, None]
    store = fan & room                                                     # [N,N]
    dropped = (fan & ~room).sum() + (
        (jnp.zeros((), jnp.int32))
        if arch.voq is not VOQKind.SHARED
        else ((uni | bcast) & valid[:, None]).any(1).sum() - fan.any(1).sum()
    )
    # ring-buffer write at tail
    slot = st.tail % d                                                     # [N,N]
    wmask = store[:, :, None] & (jnp.arange(d)[None, None, :] == slot[:, :, None])
    queue = jnp.where(wmask, pids[:, None, None], st.queue)
    tail = st.tail + store.astype(jnp.int32)
    # refcounts / data slot accounting
    copies = store.sum(1)                                                  # per input
    pid_safe = jnp.clip(pids, 0)
    rem = st.rem_copies.at[pid_safe].add(jnp.where(valid, copies, 0))
    if arch.voq is VOQKind.SHARED:
        data_slots = st.data_slots + store.any(1).sum()                    # one slot per packet
    else:
        data_slots = st.data_slots + store.sum()                           # one per copy
    return VOQState(queue, st.head, tail, data_slots.astype(jnp.int32), rem,
                    st.drops + dropped.astype(jnp.int32))


def dequeue(
    arch: SwitchArch,
    st: VOQState,
    match: jnp.ndarray,     # [N, N] bool accepted matching
) -> Tuple[VOQState, jnp.ndarray, jnp.ndarray]:
    """Pop matched heads. Returns (state, dep_pid[N_out], dep_in[N_out])."""
    n, d = arch.n_ports, arch.voq_depth
    slot = st.head % d
    heads = jnp.take_along_axis(st.queue, slot[:, :, None], axis=2)[:, :, 0]  # [N,N]
    head = st.head + match.astype(jnp.int32)
    pid_mat = jnp.where(match, heads, -1)
    dep_pid = pid_mat.max(0)                       # one match per column
    dep_in = jnp.where(match, jnp.arange(n, dtype=jnp.int32)[:, None], -1).max(0)
    # refcount update
    popped = jnp.where(match, heads, 0)
    dec = match.astype(jnp.int32)
    rem = st.rem_copies.at[jnp.clip(popped, 0)].add(-dec.astype(jnp.int32) * match)
    if arch.voq is VOQKind.SHARED:
        # free the data slot only when the last pending copy leaves
        freed = (match & (jnp.take(rem, jnp.clip(heads, 0)) <= 0)).sum()
    else:
        freed = match.sum()
    data_slots = st.data_slots - freed.astype(jnp.int32)
    return VOQState(st.queue, head, st.tail, data_slots, rem, st.drops), dep_pid, dep_in
