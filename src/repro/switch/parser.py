"""Protocol-aware parser (§III-B.1) — JAX functional model.

The SPAC parser is *template-driven*: protocol details are baked in at compile
time (no TCAM, no runtime config registers).  Here the ``ParserPlan`` produced
by ``Protocol.compile`` plays the role of the instantiated C++ template: every
field access lowers to hard-wired shifts/masks over 32-bit header words, with
extra pieces only for fields that straddle word boundaries.

``pack_header_words`` is the vectorised serialiser (the NetBlocks driver
role); ``make_field_extractor`` returns a jit-able extractor identical in
contract to the generated Pallas kernel in ``repro.kernels.parser``.
"""

from __future__ import annotations

from typing import Callable, Dict, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.dsl import ParserPlan, Protocol

__all__ = ["WORD_BITS", "n_header_words", "pack_header_words", "make_field_extractor"]

WORD_BITS = 32


def n_header_words(protocol: Protocol) -> int:
    return -(-protocol.header_bits // WORD_BITS)


def pack_header_words(protocol: Protocol, values: Dict[str, np.ndarray]) -> np.ndarray:
    """Vectorised bit-exact packing into uint32 words, MSB-first.

    values[name] is an int array [n]; fields absent default to Field.default.
    Returns uint32 [n, n_words].
    """
    plan = protocol.compile(WORD_BITS)
    n = len(next(iter(values.values())))
    words = np.zeros((n, n_header_words(protocol)), dtype=np.uint64)
    for f in protocol.fields:
        v = np.asarray(values.get(f.name, np.full(n, f.default)), dtype=np.uint64)
        for s in plan.slices_for(f.name):
            take = s.hi - s.lo + 1
            piece = (v >> np.uint64(s.dst_shift)) & np.uint64((1 << take) - 1)
            words[:, s.word] |= piece << np.uint64(s.lo)
    return words.astype(np.uint32)


def make_field_extractor(
    protocol: Protocol, field_names: Sequence[str]
) -> Callable[[jnp.ndarray], Tuple[jnp.ndarray, ...]]:
    """Compile-time specialised extractor: uint32 words [..., W] -> uint32 values.

    Fields wider than 32 bits are truncated to their low 32 bits (the switch
    uses addresses modulo table size, so this is lossless for lookups of
    addr_bits <= 32; documented in DESIGN.md).
    """
    plan = protocol.compile(WORD_BITS)
    baked = []
    for name in field_names:
        slices = []
        for s in plan.slices_for(name):
            take = s.hi - s.lo + 1
            if s.dst_shift >= WORD_BITS:
                continue  # piece entirely above bit 31: truncated away
            slices.append((s.word, s.lo, take, s.dst_shift))
        baked.append(tuple(slices))
    baked = tuple(baked)

    def extract(words: jnp.ndarray) -> Tuple[jnp.ndarray, ...]:
        words = words.astype(jnp.uint32)
        outs = []
        for slices in baked:
            v = jnp.zeros(words.shape[:-1], dtype=jnp.uint32)
            for word, lo, take, dst_shift in slices:
                piece = (words[..., word] >> jnp.uint32(lo)) & jnp.uint32((1 << take) - 1)
                v = v | (piece << jnp.uint32(dst_shift))
            outs.append(v)
        return tuple(outs)

    return extract
