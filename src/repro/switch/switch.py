"""The composed SPAC switch: parser ∘ kernels ∘ table ∘ VOQ ∘ scheduler ∘ deparser.

A cycle-level, fully vectorised JAX model of the generated switch.  One
``lax.scan`` step = one clock cycle of the FPGA datapath:

  1. ingress: per-port arriving flit (head flit carries the packed header);
     the compile-time-specialised parser extracts routing/src keys,
  2. custom kernels (optional, §III-B.5) may rewrite destinations/drop,
  3. the forward table learns src→port and looks up the output port
     (miss ⇒ broadcast),
  4. the VOQ buffer enqueues (drops when full),
  5. the scheduler computes an input/output matching,
  6. matched heads dequeue; multi-flit packets hold their input & output busy
     for ``size_flits`` cycles (serialisation),

This model is the repo's "real hardware": the statistical surrogate
(``repro.sim.surrogate``) is validated against it (Fig. 6), and the DSE's
stage-4 verification runs it via the network simulator.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.archspec import SwitchArch
from repro.core.binding import BoundProtocol
from . import forward_table as ft
from . import scheduler as sch
from . import voq as vq
from .parser import make_field_extractor, n_header_words, pack_header_words

__all__ = ["SwitchSimResult", "prepare_cycle_inputs", "simulate"]


@dataclasses.dataclass
class SwitchSimResult:
    latency_cycles: np.ndarray      # per delivered packet (last copy), queueing incl.
    latency_ns: np.ndarray          # + pipeline latency, at fclk
    drops: int
    offered: int
    delivered_copies: int
    throughput_gbps: float          # delivered payload+header bits / sim time
    goodput_gbps: float             # delivered payload bits / sim time
    occ_max: np.ndarray             # [N, N] per-queue max occupancy
    occ_trace: np.ndarray           # [T] per-cycle max queue occupancy
    data_slots_max: int
    n_cycles: int
    fclk_hz: float

    @property
    def drop_rate(self) -> float:
        return self.drops / max(self.offered, 1)

    def p(self, q: float) -> float:
        return float(np.percentile(self.latency_ns, q)) if self.latency_ns.size else math.inf


def prepare_cycle_inputs(
    arch: SwitchArch,
    bound: BoundProtocol,
    trace,
    fclk_hz: float,
    *,
    drain_cycles: int = 2048,
    max_cycles: Optional[int] = None,
) -> Dict[str, np.ndarray]:
    """Bin a trace into per-cycle per-port arrivals with link serialisation."""
    n = arch.n_ports
    t = np.asarray(trace.time_s, dtype=np.float64)
    src = np.asarray(trace.src, dtype=np.int64) % n
    dst = np.asarray(trace.dst, dtype=np.int64) % n
    payload = np.asarray(trace.payload_bytes, dtype=np.int64)
    order = np.argsort(t, kind="stable")
    t, src, dst, payload = t[order], src[order], dst[order], payload[order]
    npkt = t.size

    wire_bytes = payload + bound.header_bytes
    flit_bytes = arch.bus_bits // 8
    size_flits = np.maximum(1, -(-wire_bytes // flit_bytes)).astype(np.int32)

    # ingress serialisation: each port delivers one flit/cycle
    arr_cycle = np.zeros(npkt, dtype=np.int64)
    port_free = np.zeros(n, dtype=np.int64)
    rel = t - t.min()
    for k in range(npkt):
        c = int(round(rel[k] * fclk_hz))
        c = max(c, port_free[src[k]])
        arr_cycle[k] = c
        port_free[src[k]] = c + size_flits[k]

    total_cycles = int(arr_cycle.max() + size_flits.max() + drain_cycles) if npkt else drain_cycles
    if max_cycles is not None and total_cycles > max_cycles:
        total_cycles = max_cycles
    keep = arr_cycle < total_cycles
    arr_pid = np.full((total_cycles, n), -1, dtype=np.int32)
    arr_pid[arr_cycle[keep], src[keep]] = np.nonzero(keep)[0].astype(np.int32)

    # pack headers once (the host driver / NetBlocks role)
    vals = {
        bound.semantics["routing_key"]: dst.astype(np.uint64),
        bound.semantics["src_key"]: src.astype(np.uint64),
    }
    if bound.has("length"):
        f = bound.protocol.field(bound.semantics["length"])
        vals[bound.semantics["length"]] = np.minimum(payload, (1 << f.bits) - 1).astype(np.uint64)
    words = pack_header_words(bound.protocol, vals)

    return dict(
        arr_pid=arr_pid,
        header_words=words.astype(np.uint32),
        size_flits=size_flits,
        payload_bytes=payload.astype(np.int64),
        wire_bytes=wire_bytes.astype(np.int64),
        arr_cycle=arr_cycle,
        n_cycles=np.int64(total_cycles),
    )


class _Carry(NamedTuple):
    table: object
    voq: vq.VOQState
    sched: sch.SchedState
    busy_in: jnp.ndarray     # [N] cycles remaining
    busy_out: jnp.ndarray
    dep_cycle: jnp.ndarray   # [n_packets] last-copy departure cycle (-1 = not yet)
    delivered: jnp.ndarray   # scalar copies delivered
    occ_max: jnp.ndarray     # [N, N]
    data_max: jnp.ndarray    # scalar
    kstates: Tuple           # custom kernel states


def simulate(
    arch: SwitchArch,
    bound: BoundProtocol,
    trace,
    *,
    fclk_hz: float,
    max_cycles: Optional[int] = None,
) -> SwitchSimResult:
    """Run the cycle-level switch on a trace and gather per-packet stats."""
    prep = prepare_cycle_inputs(arch, bound, trace, fclk_hz, max_cycles=max_cycles)
    n = arch.n_ports
    npkt = prep["header_words"].shape[0]
    header_words = jnp.asarray(prep["header_words"])
    size_flits = jnp.asarray(prep["size_flits"])
    extractor = make_field_extractor(
        bound.protocol, [bound.semantics["routing_key"], bound.semantics["src_key"]]
    )
    kernels = list(arch.custom_kernels)

    # xs carries (cycle_index, arrivals)
    cycles = jnp.arange(prep["n_cycles"], dtype=jnp.int32)

    def cycle_step(c: _Carry, xs):
        cyc, pids = xs
        valid = pids >= 0
        pid_safe = jnp.clip(pids, 0)
        words = header_words[pid_safe]                       # [N, W]
        dst_key, src_key = extractor(words)
        in_ports = jnp.arange(n, dtype=jnp.int32)
        # learn then lookup (learning on every arrival, §III-B.2)
        table = ft.learn(arch, c.table, src_key, in_ports, valid)
        out_port = ft.lookup(arch, table, dst_key, valid)
        # custom kernel hooks
        kstates = []
        for spec, kst in zip(kernels, c.kstates):
            if spec.fn is not None:
                kst, out_port, valid = spec.fn(kst, pids, out_port, valid, cyc)
            kstates.append(kst)
        voq = vq.enqueue(arch, c.voq, pids, out_port, valid)
        occ = vq.occupancy(voq)
        match, sched = sch.schedule(arch, c.sched, occ, c.busy_in > 0, c.busy_out > 0)
        voq, dep_pid, dep_in = vq.dequeue(arch, voq, match)
        occ_after = vq.occupancy(voq)
        sched = sch.release_exhausted(sched, match, occ_after)
        # busy counters: transfer occupies ports for size_flits cycles total
        dep_valid = dep_pid >= 0
        dep_sz = size_flits[jnp.clip(dep_pid, 0)]
        busy_out = jnp.maximum(c.busy_out - 1, 0)
        busy_out = jnp.where(dep_valid, dep_sz - 1, busy_out)
        busy_in = jnp.maximum(c.busy_in - 1, 0)
        in_sz = jnp.zeros((n,), jnp.int32).at[jnp.clip(dep_in, 0)].max(
            jnp.where(dep_valid, dep_sz - 1, 0))
        busy_in = jnp.maximum(busy_in, in_sz)
        # departure bookkeeping (last flit leaves at cyc + size)
        dep_cycle = c.dep_cycle.at[jnp.clip(dep_pid, 0)].max(
            jnp.where(dep_valid, cyc + dep_sz, -1))
        delivered = c.delivered + dep_valid.sum()
        occ_max = jnp.maximum(c.occ_max, occ)
        data_max = jnp.maximum(c.data_max, voq.data_slots)
        carry = _Carry(table, voq, sched, busy_in, busy_out, dep_cycle,
                       delivered, occ_max, data_max, tuple(kstates))
        return carry, occ.max()

    init = _Carry(
        table=ft.init_table(arch),
        voq=vq.init_voq(arch, npkt),
        sched=sch.init_sched(arch),
        busy_in=jnp.zeros((n,), jnp.int32),
        busy_out=jnp.zeros((n,), jnp.int32),
        dep_cycle=jnp.full((max(npkt, 1),), -1, dtype=jnp.int32),
        delivered=jnp.zeros((), jnp.int32),
        occ_max=jnp.zeros((n, n), jnp.int32),
        data_max=jnp.zeros((), jnp.int32),
        kstates=tuple(getattr(k, "init_state", None) for k in kernels),
    )
    arr = jnp.asarray(prep["arr_pid"])
    final, occ_trace = jax.lax.scan(cycle_step, init, (cycles, arr))

    dep = np.asarray(final.dep_cycle)
    arrc = prep["arr_cycle"]
    done = dep >= 0
    lat_cycles = (dep[done] - arrc[done]).astype(np.float64)
    lat_ns = (lat_cycles + arch.pipeline_depth) / fclk_hz * 1e9
    sim_s = float(prep["n_cycles"]) / fclk_hz
    delivered_bits = float(prep["wire_bytes"][done].sum() * 8)
    goodput_bits = float(prep["payload_bytes"][done].sum() * 8)
    return SwitchSimResult(
        latency_cycles=lat_cycles,
        latency_ns=lat_ns,
        drops=int(final.voq.drops),
        offered=int(npkt),
        delivered_copies=int(final.delivered),
        throughput_gbps=delivered_bits / sim_s / 1e9,
        goodput_gbps=goodput_bits / sim_s / 1e9,
        occ_max=np.asarray(final.occ_max),
        occ_trace=np.asarray(occ_trace),
        data_slots_max=int(final.data_max),
        n_cycles=int(prep["n_cycles"]),
        fclk_hz=fclk_hz,
    )
