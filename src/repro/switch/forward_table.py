"""Forward tables (§III-B.2): FullLookup array and Multi-Bank Hash table.

Both variants keep address→port mappings, learn the source address on every
arrival, and answer multi-port lookups in parallel (the FPGA design fully
partitions the array / banks the hash table so every port hits memory in the
same cycle).  A lookup miss yields ``BROADCAST`` (-2).
"""

from __future__ import annotations

from typing import NamedTuple, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core.archspec import ForwardTableKind, SwitchArch

__all__ = ["BROADCAST", "FullLookupState", "MultiBankState", "init_table", "lookup", "learn"]

BROADCAST = -2
_EMPTY = -1


class FullLookupState(NamedTuple):
    ports: jnp.ndarray  # [2^addr_bits] int32, -1 = unknown


class MultiBankState(NamedTuple):
    keys: jnp.ndarray    # [banks, depth] uint32
    ports: jnp.ndarray   # [banks, depth] int32, -1 = empty
    mults: jnp.ndarray   # [banks] uint32 per-bank hash multipliers


TableState = Union[FullLookupState, MultiBankState]

# Knuth-style odd multipliers (distinct per bank → near-independent hashes)
_HASH_MULTS = (2654435761, 2246822519, 3266489917, 668265263, 374761393, 2869860233, 3624381081, 961748927)


def init_table(arch: SwitchArch) -> TableState:
    if arch.fwd is ForwardTableKind.FULL_LOOKUP:
        return FullLookupState(ports=jnp.full((1 << arch.addr_bits,), _EMPTY, dtype=jnp.int32))
    mults = jnp.asarray([_HASH_MULTS[b % len(_HASH_MULTS)] for b in range(arch.hash_banks)], dtype=jnp.uint32)
    return MultiBankState(
        keys=jnp.zeros((arch.hash_banks, arch.hash_depth), dtype=jnp.uint32),
        ports=jnp.full((arch.hash_banks, arch.hash_depth), _EMPTY, dtype=jnp.int32),
        mults=mults,
    )


def _bank_slots(state: MultiBankState, key: jnp.ndarray) -> jnp.ndarray:
    """Per-bank slot index for a key [..., banks] (multiplicative hashing)."""
    depth = state.keys.shape[1]
    h = key[..., None].astype(jnp.uint32) * state.mults  # [..., banks]
    return (h >> jnp.uint32(16)).astype(jnp.int32) % depth


def lookup(arch: SwitchArch, state: TableState, dst_key: jnp.ndarray, valid: jnp.ndarray) -> jnp.ndarray:
    """Parallel multi-port lookup.  dst_key [P] uint32 -> out_port [P] int32.

    Returns BROADCAST (-2) on miss, -1 for invalid lanes.
    """
    if arch.fwd is ForwardTableKind.FULL_LOOKUP:
        mask = jnp.uint32((1 << arch.addr_bits) - 1)
        port = state.ports[(dst_key & mask).astype(jnp.int32)]
    else:
        slots = _bank_slots(state, dst_key)                       # [P, B]
        b_idx = jnp.arange(state.keys.shape[0])
        keys = state.keys[b_idx[None, :], slots]                  # [P, B]
        ports = state.ports[b_idx[None, :], slots]                # [P, B]
        hit = (keys == dst_key[..., None]) & (ports != _EMPTY)
        port = jnp.where(hit.any(-1), ports[jnp.arange(dst_key.shape[0]), hit.argmax(-1)], _EMPTY)
    port = jnp.where(port == _EMPTY, BROADCAST, port)
    return jnp.where(valid, port, _EMPTY)


def learn(arch: SwitchArch, state: TableState, src_key: jnp.ndarray, in_port: jnp.ndarray, valid: jnp.ndarray) -> TableState:
    """Learn src→port on every arrival (parallel across ports).

    MultiBank insert: first bank whose slot is free or already holds the key;
    if every bank slot is occupied by a different key, evict in bank 0 — the
    conflict behaviour the DSE's II penalty models.
    """
    if arch.fwd is ForwardTableKind.FULL_LOOKUP:
        mask = jnp.uint32((1 << arch.addr_bits) - 1)
        idx = (src_key & mask).astype(jnp.int32)
        # invalid lanes scatter out of bounds and are dropped (aliasing a real
        # index would clobber concurrently-learned entries)
        idx = jnp.where(valid, idx, state.ports.shape[0])
        return FullLookupState(
            ports=state.ports.at[idx].set(in_port.astype(jnp.int32), mode="drop"))

    def insert_one(st: MultiBankState, args):
        key, port, ok = args
        slots = _bank_slots(st, key[None])[0]                    # [B]
        b_idx = jnp.arange(st.keys.shape[0])
        cur_keys = st.keys[b_idx, slots]
        cur_ports = st.ports[b_idx, slots]
        free_or_same = (cur_ports == _EMPTY) | (cur_keys == key)
        bank = jnp.where(free_or_same.any(), free_or_same.argmax(), 0)  # evict bank 0
        slot = slots[bank]
        new_keys = st.keys.at[bank, slot].set(jnp.where(ok, key, st.keys[bank, slot]))
        new_ports = st.ports.at[bank, slot].set(jnp.where(ok, port.astype(jnp.int32), st.ports[bank, slot]))
        return MultiBankState(new_keys, new_ports, st.mults), None

    state, _ = jax.lax.scan(insert_one, state, (src_key, in_port, valid))
    return state
