"""Schedulers (§III-B.4): Round-Robin, iSLIP, EDRRM — bit-matrix matching.

All three compute a one-to-one matching between input and output ports from
the VOQ occupancy matrix, as pure JAX on [N, N] boolean matrices so the whole
switch steps inside one ``lax.scan``:

* **RR** — single request/grant/accept round with rotating priorities that
  always advance (the classic desynchronisation weakness is retained on
  purpose; it is why RR under-performs on uniform traffic in Fig. 1).
* **iSLIP** — ``islip_iters`` request/grant/accept iterations; grant/accept
  pointers move only on a first-iteration accepted grant (McKeown's rule),
  which desynchronises outputs and approaches 100% uniform throughput.
* **EDRRM** — dual round-robin request/grant with *exhaustive service*: a
  matched (input, output) pair is held as long as the queue stays non-empty,
  amortising arbitration across a burst (why it wins on bursty traffic).
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.archspec import SchedulerKind, SwitchArch

__all__ = ["SchedState", "init_sched", "schedule"]


class SchedState(NamedTuple):
    grant_ptr: jnp.ndarray   # [N] output-side rotating pointers
    accept_ptr: jnp.ndarray  # [N] input-side rotating pointers (iSLIP accept / EDRRM request)
    held: jnp.ndarray        # [N] EDRRM: output currently held by each input (-1 = none)


def init_sched(arch: SwitchArch) -> SchedState:
    n = arch.n_ports
    z = jnp.zeros((n,), dtype=jnp.int32)
    return SchedState(grant_ptr=z, accept_ptr=z, held=jnp.full((n,), -1, dtype=jnp.int32))


def _rot_pick(v: jnp.ndarray, p: jnp.ndarray) -> jnp.ndarray:
    """One-hot of the first set bit of v at/after rotating pointer p."""
    n = v.shape[-1]
    idx = jnp.arange(n, dtype=jnp.int32)
    score = jnp.where(v, (idx - p) % n, n + 1)
    sel = jnp.argmin(score)
    return (idx == sel) & v.any()


_pick_rows = jax.vmap(_rot_pick, in_axes=(0, 0), out_axes=0)     # per input row
_pick_cols = jax.vmap(_rot_pick, in_axes=(1, 0), out_axes=1)     # per output col


def _rr(arch: SwitchArch, st: SchedState, req: jnp.ndarray) -> Tuple[jnp.ndarray, SchedState]:
    grants = _pick_cols(req, st.grant_ptr)            # each output grants one input
    match = _pick_rows(grants, st.accept_ptr)         # each input accepts one grant
    n = arch.n_ports
    g_in = grants.argmax(0)                           # input granted by each output
    a_out = match.argmax(1)                           # output accepted by each input
    new_g = jnp.where(grants.any(0), (g_in + 1) % n, st.grant_ptr).astype(jnp.int32)
    new_a = jnp.where(match.any(1), (a_out + 1) % n, st.accept_ptr).astype(jnp.int32)
    return match, SchedState(new_g, new_a, st.held)


def _islip(arch: SwitchArch, st: SchedState, req: jnp.ndarray) -> Tuple[jnp.ndarray, SchedState]:
    n = arch.n_ports

    def one_iter(carry, it):
        match, gptr, aptr = carry
        free = ~match.any(1)[:, None] & ~match.any(0)[None, :]
        grants = _pick_cols(req & free, gptr)
        accepts = _pick_rows(grants, aptr)
        # pointers move only on first-iteration accepted grants
        first = it == 0
        g_in = accepts.argmax(0)
        a_out = accepts.argmax(1)
        out_accepted = accepts.any(0)
        in_accepted = accepts.any(1)
        gptr = jnp.where(first & out_accepted, (g_in + 1) % n, gptr).astype(jnp.int32)
        aptr = jnp.where(first & in_accepted, (a_out + 1) % n, aptr).astype(jnp.int32)
        return (match | accepts, gptr, aptr), None

    init = (jnp.zeros((n, n), dtype=bool), st.grant_ptr, st.accept_ptr)
    (match, gptr, aptr), _ = jax.lax.scan(one_iter, init, jnp.arange(arch.islip_iters))
    return match, SchedState(gptr, aptr, st.held)


def _edrrm(arch: SwitchArch, st: SchedState, req: jnp.ndarray) -> Tuple[jnp.ndarray, SchedState]:
    n = arch.n_ports
    idx = jnp.arange(n, dtype=jnp.int32)
    # --- request phase: one request per input; held output has priority
    held_valid = (st.held >= 0) & req[idx, jnp.clip(st.held, 0)]
    fresh = _pick_rows(req, st.accept_ptr)                       # [N,N] one-hot rows
    req_out = jnp.where(held_valid, st.held, jnp.where(fresh.any(1), fresh.argmax(1), -1))
    rq = (req_out[:, None] == idx[None, :]) & (req_out >= 0)[:, None]  # [N,N]
    # --- grant phase: held input has priority at its output, else rotating pick
    held_req = rq & held_valid[:, None]                          # held continuations
    grants_held = _pick_cols(held_req, st.grant_ptr)             # at most one per output
    remaining = rq & ~grants_held.any(0)[None, :]
    grants_new = _pick_cols(remaining, st.grant_ptr)
    match = grants_held | grants_new
    # --- exhaustive-service state: hold matched pairs (release handled by caller
    # via occupancy-after; here hold optimistically, caller clears empties)
    new_held = jnp.where(match.any(1), match.argmax(1), -1).astype(jnp.int32)
    g_in = grants_new.argmax(0)
    new_g = jnp.where(grants_new.any(0), (g_in + 1) % n, st.grant_ptr).astype(jnp.int32)
    fresh_used = match.any(1) & ~held_valid
    new_a = jnp.where(fresh_used, (req_out + 1) % n, st.accept_ptr).astype(jnp.int32)
    return match, SchedState(new_g, new_a, new_held)


def schedule(
    arch: SwitchArch,
    st: SchedState,
    occupancy: jnp.ndarray,   # [N, N] int queue counts
    busy_in: jnp.ndarray,     # [N] bool — mid multi-flit transfer
    busy_out: jnp.ndarray,    # [N] bool
) -> Tuple[jnp.ndarray, SchedState]:
    req = (occupancy > 0) & ~busy_in[:, None] & ~busy_out[None, :]
    if arch.sched is SchedulerKind.RR:
        return _rr(arch, st, req)
    if arch.sched is SchedulerKind.ISLIP:
        return _islip(arch, st, req)
    return _edrrm(arch, st, req)


def release_exhausted(st: SchedState, match: jnp.ndarray, occ_after: jnp.ndarray) -> SchedState:
    """EDRRM: drop the hold when the matched queue just emptied."""
    n = st.held.shape[0]
    idx = jnp.arange(n, dtype=jnp.int32)
    out = jnp.clip(st.held, 0)
    empty = occ_after[idx, out] <= 0
    new_held = jnp.where((st.held >= 0) & empty, -1, st.held)
    return st._replace(held=new_held.astype(jnp.int32))
