"""Public SSD op + the XLA-level chunked fallback used inside jit graphs.

The Pallas kernel (`kernel.ssd_scan`) is the TPU deployment path, validated
in interpret mode.  ``ssd_chunked`` is mathematically the same chunked
algorithm expressed with `lax.scan` over chunks — used by the model code so
the multi-pod dry-run lowers on any backend with the same FLOP/byte shape.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import ssd_scan
from .ref import ssd_ref


@functools.partial(jax.jit, static_argnames=("chunk", "return_state"))
def ssd_chunked(x, dt, a, b, c, *, chunk: int = 128, return_state: bool = False):
    """Chunk-parallel SSD in pure jnp (same math as the Pallas kernel)."""
    bh, s, p = x.shape
    n = b.shape[-1]
    assert s % chunk == 0
    nc = s // chunk
    xc = x.reshape(bh, nc, chunk, p).astype(jnp.float32)
    dtc = dt.reshape(bh, nc, chunk).astype(jnp.float32)
    bc = b.reshape(bh, nc, chunk, n).astype(jnp.float32)
    cc = c.reshape(bh, nc, chunk, n).astype(jnp.float32)
    da = dtc * a[:, None, None]                       # [BH, NC, L]
    cum = jnp.cumsum(da, axis=-1)
    # intra-chunk causal term
    l_mat = jnp.exp(cum[..., :, None] - cum[..., None, :])
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    l_mat = jnp.where(mask, l_mat, 0.0)
    scores = jnp.einsum("hcin,hcjn->hcij", cc, bc) * l_mat * dtc[..., None, :]
    y_intra = jnp.einsum("hcij,hcjp->hcip", scores, xc)
    # inter-chunk recurrence over [P, N] states
    total = cum[..., -1]                              # [BH, NC]
    w = jnp.exp(total[..., None] - cum) * dtc         # [BH, NC, L]
    chunk_state = jnp.einsum("hcjp,hcjn->hcpn", xc * w[..., None], bc)

    def carry_fn(state, inp):
        tot, cst = inp
        new = state * jnp.exp(tot)[:, None, None] + cst
        return new, state                              # emit state *before* chunk

    init = jnp.zeros((bh, p, n), jnp.float32)
    final_state, states_in = jax.lax.scan(
        carry_fn, init, (jnp.moveaxis(total, 1, 0), jnp.moveaxis(chunk_state, 1, 0)))
    states_in = jnp.moveaxis(states_in, 0, 1)          # [BH, NC, P, N]
    y_inter = jnp.einsum("hcin,hcpn->hcip", cc * jnp.exp(cum)[..., None], states_in)
    y = (y_intra + y_inter).reshape(bh, s, p).astype(x.dtype)
    if return_state:
        return y, final_state
    return y


def ssd(x, dt, a, b, c, *, chunk: int = 128, use_pallas: bool = False,
        interpret: bool = True) -> jnp.ndarray:
    if use_pallas:
        return ssd_scan(x, dt, a, b, c, chunk=chunk, interpret=interpret)
    return ssd_chunked(x, dt, a, b, c, chunk=chunk)


def ssd_reference(x, dt, a, b, c) -> jnp.ndarray:
    return ssd_ref(x, dt, a, b, c)


def ssd_decode_step(state, x_t, dt_t, a, b_t, c_t):
    """Single-token recurrence for serving.  state [BH,P,N] -> (state, y [BH,P])."""
    decay = jnp.exp(dt_t * a)[:, None, None]
    state = state * decay + dt_t[:, None, None] * jnp.einsum("hp,hn->hpn", x_t, b_t)
    y = jnp.einsum("hpn,hn->hp", state, c_t)
    return state, y
