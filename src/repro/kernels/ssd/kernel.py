"""Mamba-2 SSD (state-space duality) chunked-scan Pallas kernel.

The SSD recurrence  h_t = exp(dt_t·A)·h_{t-1} + dt_t·(x_t ⊗ B_t),
y_t = h_t·C_t  is computed chunk-parallel: within a length-``L`` chunk the
output is an attention-like causal matmul (MXU-friendly), and only one
[P, N] state matrix crosses chunk boundaries — carried in VMEM scratch across
the sequential chunk grid dimension.  This is the TPU-native re-blocking of
the CUDA chunked scan: chunk length is chosen so (L×P + L×N + P×N) tiles fit
VMEM with MXU-aligned L, P, N (multiples of 128 where possible).

Shapes: x [BH, S, P] (P = head dim), dt [BH, S], a [BH] (per-head decay,
A = -exp(A_log)), B/C [BH, S, N] (state dim N).  Output y [BH, S, P].
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, o_ref, state, *, chunk):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        state[...] = jnp.zeros_like(state)

    x = x_ref[0].astype(jnp.float32)          # [L, P]
    dt = dt_ref[0].astype(jnp.float32)        # [L, lanes] (value replicated)
    dt = dt[:, :1]                            # [L, 1]
    a = a_ref[0, 0]                           # scalar
    b = b_ref[0].astype(jnp.float32)          # [L, N]
    c = c_ref[0].astype(jnp.float32)          # [L, N]

    da = dt[:, 0] * a                         # [L] (a < 0 ⇒ decays)
    cum = jnp.cumsum(da)                      # inclusive cumulative log-decay
    # ---- intra-chunk (attention-like) term: i attends to j <= i
    l_mat = jnp.exp(cum[:, None] - cum[None, :])
    rows = jax.lax.broadcasted_iota(jnp.int32, l_mat.shape, 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, l_mat.shape, 1)
    l_mat = jnp.where(rows >= cols, l_mat, 0.0)
    scores = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # [L, L]
    scores = scores * l_mat * dt[None, :, 0]
    y = jax.lax.dot_general(scores, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)       # [L, P]
    # ---- inter-chunk: contribution of the carried state
    c_scaled = c * jnp.exp(cum)[:, None]                               # [L, N]
    y = y + jax.lax.dot_general(c_scaled, state[...], (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)    # [L, P]
    # ---- state update: decay over the whole chunk + inject chunk inputs
    total = cum[-1]
    w = jnp.exp(total - cum) * dt[:, 0]                                # [L]
    state[...] = state[...] * jnp.exp(total) + jax.lax.dot_general(
        x * w[:, None], b, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                            # [P, N]
    o_ref[0] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(
    x: jnp.ndarray,    # [BH, S, P]
    dt: jnp.ndarray,   # [BH, S]
    a: jnp.ndarray,    # [BH]
    b: jnp.ndarray,    # [BH, S, N]
    c: jnp.ndarray,    # [BH, S, N]
    *,
    chunk: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    bh, s, p = x.shape
    n = b.shape[-1]
    assert s % chunk == 0, (s, chunk)
    lanes = 128
    dt_pad = jnp.broadcast_to(dt[..., None], (bh, s, lanes))
    a_pad = jnp.broadcast_to(a[:, None], (bh, lanes))
    kern = functools.partial(_ssd_kernel, chunk=chunk)
    return pl.pallas_call(
        kern,
        grid=(bh, s // chunk),
        in_specs=[
            pl.BlockSpec((1, chunk, p), lambda h, i: (h, i, 0)),
            pl.BlockSpec((1, chunk, lanes), lambda h, i: (h, i, 0)),
            pl.BlockSpec((1, lanes), lambda h, i: (h, 0)),
            pl.BlockSpec((1, chunk, n), lambda h, i: (h, i, 0)),
            pl.BlockSpec((1, chunk, n), lambda h, i: (h, i, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, p), lambda h, i: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(x, dt_pad, a_pad, b, c)
