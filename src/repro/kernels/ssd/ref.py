"""Pure-jnp oracle for SSD: the sequential state-space recurrence."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(x, dt, a, b, c) -> jnp.ndarray:
    """Sequential scan.  x [BH,S,P], dt [BH,S], a [BH], b/c [BH,S,N] -> [BH,S,P]."""
    bh, s, p = x.shape
    n = b.shape[-1]

    def per_head(xh, dth, ah, bh_, ch):
        def step(state, inp):
            xt, dtt, bt, ct = inp
            decay = jnp.exp(dtt * ah)
            state = state * decay + dtt * jnp.outer(xt, bt)     # [P, N]
            y = state @ ct                                       # [P]
            return state, y

        init = jnp.zeros((p, n), jnp.float32)
        _, ys = jax.lax.scan(step, init, (xh.astype(jnp.float32), dth.astype(jnp.float32),
                                          bh_.astype(jnp.float32), ch.astype(jnp.float32)))
        return ys

    ys = jax.vmap(per_head)(x, dt, a, b, c)
    return ys.astype(x.dtype)
