"""Admission-gated lean replay as a Pallas kernel, candidate-tiled.

Like the crossbar kernel, one replay step is tiny (two ``[B, N]`` slack
vectors), so the grid runs over *candidate blocks*: each program keeps the
port-slack state for ``block_b`` candidates resident in VMEM scratch and
walks the shared event timeline with a ``fori_loop``, processing one event
per iteration lane-parallel across the batch.  Port gather/scatter is done
by masking against a lane iota (ports are padded to the 128-lane boundary
by ``ops.lean_replay``).  B×E work therefore maps to ``B/block_b`` grid
blocks instead of B lanes inside one host scan.

Contract (per batch row): dnow [1, m] float32, src/dst [1, m] int32 shared;
svc [B, m] float32, admit [B, m] float32 (1.0 admitted / 0.0 dropped),
pipe [B, 1] float32 per candidate → dep [B, m] float32 departure *offsets*.
Implements the slack formulation (``ref.netsim_replay_slack_ref``) — the
carries never hold absolute timestamps, so float32 survives long traces —
and matches that oracle bit-for-bit in interpret mode
(``tests/test_netsim_kernels.py``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _netsim_kernel(dnow_ref, src_ref, dst_ref, svc_ref, admit_ref, pipe_ref,
                   dep_ref, in_s, out_s, *, m: int):
    in_s[...] = jnp.zeros_like(in_s)
    out_s[...] = jnp.zeros_like(out_s)
    lane = jax.lax.broadcasted_iota(jnp.int32, in_s.shape, 1)   # [B, Np]
    pipe = pipe_ref[...][:, 0]                                  # [B]

    def body(k, _):
        dtk = dnow_ref[0, k]
        i = src_ref[0, k]
        j = dst_ref[0, k]
        s = pl.load(svc_ref, (slice(None), pl.ds(k, 1)))[:, 0]      # [B]
        ad = pl.load(admit_ref, (slice(None), pl.ds(k, 1)))[:, 0] > 0.5
        ins = jnp.maximum(in_s[...] - dtk, 0.0)
        outs = jnp.maximum(out_s[...] - dtk, 0.0)
        wait = jnp.maximum(
            jnp.maximum(
                jnp.max(jnp.where(lane == i, ins, 0.0), axis=1),
                jnp.max(jnp.where(lane == j, outs, 0.0), axis=1)),
            pipe)
        dep = wait + s                                              # [B]
        upd = ad[:, None]
        in_s[...] = jnp.where((lane == i) & upd, dep[:, None], ins)
        out_s[...] = jnp.where((lane == j) & upd, dep[:, None], outs)
        pl.store(dep_ref, (slice(None), pl.ds(k, 1)), dep[:, None])
        return 0

    jax.lax.fori_loop(0, m, body, 0)


@functools.partial(jax.jit, static_argnames=("n_pad", "block_b", "interpret"))
def netsim_replay_padded(
    dnow: jnp.ndarray,   # [1, m] float32
    src: jnp.ndarray,    # [1, m] int32
    dst: jnp.ndarray,    # [1, m] int32
    svc: jnp.ndarray,    # [B, m] float32 (B a multiple of block_b)
    admit: jnp.ndarray,  # [B, m] float32 (1.0 / 0.0)
    pipe: jnp.ndarray,   # [B, 1] float32
    *,
    n_pad: int,          # ports padded to the lane boundary
    block_b: int = 8,
    interpret: bool = True,
):
    b, m = svc.shape
    assert b % block_b == 0, (b, block_b)
    kern = functools.partial(_netsim_kernel, m=m)
    return pl.pallas_call(
        kern,
        grid=(b // block_b,),
        in_specs=[
            pl.BlockSpec((1, m), lambda i: (0, 0)),
            pl.BlockSpec((1, m), lambda i: (0, 0)),
            pl.BlockSpec((1, m), lambda i: (0, 0)),
            pl.BlockSpec((block_b, m), lambda i: (i, 0)),
            pl.BlockSpec((block_b, m), lambda i: (i, 0)),
            pl.BlockSpec((block_b, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, m), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, m), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((block_b, n_pad), jnp.float32),
            pltpu.VMEM((block_b, n_pad), jnp.float32),
        ],
        interpret=interpret,
    )(dnow, src, dst, svc, admit, pipe)
