from .ops import (ChainIndex, build_chain_index, kernel_available,
                  lean_replay, netsim_fixed_point, resolve_use_kernel,
                  segmented_admission, segmented_occupancy)
from .ref import netsim_replay_abs_ref, netsim_replay_slack_ref

__all__ = [
    "ChainIndex", "build_chain_index", "kernel_available", "lean_replay",
    "netsim_fixed_point", "resolve_use_kernel", "segmented_admission",
    "segmented_occupancy", "netsim_replay_abs_ref", "netsim_replay_slack_ref",
]
