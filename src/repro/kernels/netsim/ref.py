"""Admission-gated port-contention replay, pure-jnp oracles (``lax.scan``).

The stage-4 verifier's recurrence per event k (arrival order) is

    start_k = max(now_k + pipe, in_free[src_k], out_free[dst_k])
    end_k   = start_k + svc_k
    if admit_k: in_free[src_k] = out_free[dst_k] = end_k

The classic batched engine (``sim.batched_netsim._verify_engine_impl``)
carries a ``[B, N², D]`` departure-time ring alongside the port state so it
can *decide* admissions inside the scan.  The kernels family splits that
work instead: admission flags are an **input** here (derived outside by the
segmented chain pass in ``ops.segmented_admission``), so the scan carries
only the two ``[B, N]`` port vectors — the "lean replay".  Measured on the
container CPU the ring was ~80% of the old scan's wall-clock; the lean
replay returns end times bitwise equal to it given the same flags.

Two formulations, mirroring ``kernels/xbar/ref.py``:

* ``netsim_replay_abs_ref`` carries absolute port-free times, float64 — the
  exactness oracle.  With the serial oracle's admission flags its end times
  are bit-identical to the full ring scan and to ``run_netsim``.
* ``netsim_replay_slack_ref`` carries arrival-relative *slacks* and returns
  departure offsets, so float32 keeps queueing-delay precision on long
  traces — the TPU-native form the Pallas kernel implements.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

__all__ = ["netsim_replay_abs_ref", "netsim_replay_slack_ref"]


@functools.partial(jax.jit, static_argnames=("n_ports",))
def netsim_replay_abs_ref(
    now: jnp.ndarray,    # [m] float — sorted switch-arrival times
    src: jnp.ndarray,    # [m] int32 — source port per event (shared timeline)
    dst: jnp.ndarray,    # [m] int32 — destination port per event
    svc: jnp.ndarray,    # [B, m] float — per-candidate service time per event
    pipe: jnp.ndarray,   # [B] float — per-candidate pipeline latency
    admit: jnp.ndarray,  # [B, m] bool — admission flags (gate port updates)
    *,
    n_ports: int,
) -> jnp.ndarray:        # [B, m] — absolute departure time per event
    b_n = svc.shape[0]

    def step(carry, xs):
        in_f, out_f = carry
        tk, i, j, s, ad = xs
        start = jnp.maximum(jnp.maximum(tk + pipe, in_f[:, i]), out_f[:, j])
        end = start + s
        in_f = in_f.at[:, i].set(jnp.where(ad, end, in_f[:, i]))
        out_f = out_f.at[:, j].set(jnp.where(ad, end, out_f[:, j]))
        return (in_f, out_f), end

    zeros = jnp.zeros((b_n, n_ports), svc.dtype)
    _, end = jax.lax.scan(step, (zeros, zeros), (now, src, dst, svc.T, admit.T))
    return end.T


@functools.partial(jax.jit, static_argnames=("n_ports",))
def netsim_replay_slack_ref(
    dnow: jnp.ndarray,   # [m] float — inter-arrival gaps, dnow[0] == 0
    src: jnp.ndarray,    # [m] int32
    dst: jnp.ndarray,    # [m] int32
    svc: jnp.ndarray,    # [B, m] float
    pipe: jnp.ndarray,   # [B] float
    admit: jnp.ndarray,  # [B, m] bool
    *,
    n_ports: int,
) -> jnp.ndarray:        # [B, m] — departure offsets (end_k − now_k)
    b_n = svc.shape[0]

    def step(carry, xs):
        in_s, out_s = carry
        dtk, i, j, s, ad = xs
        in_s = jnp.maximum(in_s - dtk, 0.0)
        out_s = jnp.maximum(out_s - dtk, 0.0)
        dep = jnp.maximum(jnp.maximum(in_s[:, i], out_s[:, j]), pipe) + s
        in_s = in_s.at[:, i].set(jnp.where(ad, dep, in_s[:, i]))
        out_s = out_s.at[:, j].set(jnp.where(ad, dep, out_s[:, j]))
        return (in_s, out_s), dep

    zeros = jnp.zeros((b_n, n_ports), svc.dtype)
    _, dep = jax.lax.scan(step, (zeros, zeros), (dnow, src, dst, svc.T, admit.T))
    return dep.T
