"""Dispatch + segmented-chain machinery for the netsim kernel family.

The stage-4 finite-VOQ recurrence looks inherently serial: every event's
admission depends on the departure ring of its (src, dst) VOQ, and every
departure depends on shared port state.  The kernels family splits those two
couplings and conquers each with the structure it actually has:

* **Port coupling** (departure times) keeps a scan, but a *lean* one — the
  admission-gated port replay (``ref.netsim_replay_abs_ref`` / the Pallas
  candidate-tiled form in ``kernel.py``), with no ``[B, N², D]`` ring.  The
  ring was ~80% of the old scan's measured wall-clock.
* **VOQ coupling** (admission flags) is *per-chain*: whether event k of
  chain (i, j) is dropped depends only on earlier events of the same chain.
  Inside a chain, admitted departures are FIFO (shared input and output
  port), so "the queue holds ``depth`` undeparted packets at ``now_k``" is
  exactly "the admission ``depth`` slots ago has not departed" — a
  segmented-scan question answered for **all events of all candidates at
  once** by ``segmented_admission`` (one segmented cumsum + one gather over
  the chain-sorted timeline, no replay).

The two halves meet in ``netsim_fixed_point``: speculate all-admitted, replay,
re-derive admissions, repeat.  Why the fixed point is the serial solution:
order events by arrival; event k's departure depends only on flags of events
< k, and event k's admission flag depends only on departures of its chain's
events < k.  By induction over k, any self-consistent (flags, departures)
pair equals the serial replay's — so when the loop closes, the result is
*exact*, not approximate (drop decisions bitwise, ``tests/test_netsim_kernels``).
In the common no-drop regime round 1 already closes; only rows that dropped
something iterate further, and a row that fails to close in ``max_rounds``
is reported unconverged so the caller can fall back to the serial oracle.
"""

from __future__ import annotations

import dataclasses
import functools
import os
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.retrace import track

from .kernel import netsim_replay_padded
from .ref import netsim_replay_abs_ref

__all__ = [
    "LANES", "ChainIndex", "build_chain_index", "segmented_admission",
    "segmented_occupancy", "lean_replay", "netsim_fixed_point",
    "kernel_available", "resolve_use_kernel",
]

LANES = 128          # TPU vector lane width: port axis pads to a multiple


def kernel_available() -> bool:
    """Whether ``use_kernel="auto"`` resolves to the kernel path.

    The fixed-point path needs nothing beyond the JAX runtime the repo
    already requires (the Pallas tile is optional and off by default on
    CPU), so this is an environment kill-switch, not a capability probe:
    ``SPAC_NETSIM_KERNEL=off`` forces the bit-exact oracle engines
    everywhere without touching call sites."""
    return os.environ.get("SPAC_NETSIM_KERNEL", "").lower() not in {
        "0", "off", "false", "no"}


def resolve_use_kernel(value) -> bool:
    """Normalise the ``use_kernel`` knob: True/"on", False/"off", "auto"."""
    if isinstance(value, bool):
        return value
    if value is None:
        return kernel_available()
    v = str(value).lower()
    if v in {"on", "true", "1", "yes"}:
        return True
    if v in {"off", "false", "0", "no"}:
        return False
    if v == "auto":
        return kernel_available()
    raise ValueError(f"use_kernel must be 'auto', 'on'/'off' or a bool, "
                     f"got {value!r}")


# --------------------------------------------------------------------------
# chain index: the segmented view of the shared timeline
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ChainIndex:
    """Per-(src,dst) chain structure of one time-ordered event timeline.

    ``perm`` stably sorts events by chain id (time order preserved inside a
    chain), ``inv`` undoes it, ``seg_start[p]``/``rank[p]`` give, for the
    event at *permuted* position p, its chain's first permuted position and
    its arrival rank within the chain.  Pure function of (timeline, n_ports)
    — computed once per trace by ``sim.timeline`` and reused across every
    generation, candidate and campaign scenario."""

    perm: np.ndarray        # [m] intp — stable argsort of chain ids
    inv: np.ndarray         # [m] intp — inverse permutation
    seg_start: np.ndarray   # [m] int32 — chain block start, permuted domain
    rank: np.ndarray        # [m] int32 — arrivals-before-me within my chain
    n_chains: int


def build_chain_index(qid: np.ndarray) -> ChainIndex:
    m = qid.size
    perm = np.argsort(qid, kind="stable")
    g = qid[perm]
    first = np.ones(m, bool)
    first[1:] = g[1:] != g[:-1]
    starts = np.nonzero(first)[0]
    run_ids = np.cumsum(first) - 1
    seg_start = starts[run_ids].astype(np.int32) if m else np.zeros(0, np.int32)
    rank = (np.arange(m, dtype=np.int32) - seg_start).astype(np.int32)
    inv = np.empty(m, np.intp)
    inv[perm] = np.arange(m)
    return ChainIndex(perm=perm.astype(np.intp), inv=inv, seg_start=seg_start,
                      rank=rank, n_chains=int(starts.size))


# --------------------------------------------------------------------------
# segmented admission: finite-VOQ fullness without replay
# --------------------------------------------------------------------------

def segmented_admission(end: np.ndarray, admit: np.ndarray, now: np.ndarray,
                        depth: np.ndarray, chain: ChainIndex) -> np.ndarray:
    """Derive next-round admission flags from a candidate replay.

    Given departure times ``end`` produced under speculative flags ``admit``,
    answer for every event of every candidate: *with these departures, would
    my VOQ have been full when I arrived?*  FIFO-per-chain makes that "has
    the admission ``depth`` slots before me departed by ``now``" — a
    segmented cumulative count (``na`` = admissions before me in my chain)
    plus one gather into a compacted per-chain admission array.  All numpy,
    no scan: one pass covers the whole [B, m] block.
    """
    b_n, m = end.shape
    perm, seg_start = chain.perm, chain.seg_start
    a_s = admit[:, perm]
    e_s = end[:, perm]
    n_s = now[perm]
    cum = np.cumsum(a_s, axis=1, dtype=np.int32)
    excl = cum - a_s                                    # admits before me, global
    na = excl - np.take(excl, seg_start, axis=1)        # ... within my chain
    # compact admitted departure times to their admission-rank slots; dropped
    # events park in the spare column m (never read: full needs na >= depth,
    # and that rank's slot was written by a real admission)
    slot = np.where(a_s, seg_start + na, m)
    comp = np.zeros((b_n, m + 1))
    rows = np.arange(b_n, dtype=np.intp)[:, None] * (m + 1)
    comp.ravel()[(slot + rows).ravel()] = e_s.ravel()
    r = na - depth[:, None].astype(np.int32)
    look = np.where(r >= 0, seg_start + r, m)
    oldest = np.take(comp.ravel(), (look + rows).ravel()).reshape(b_n, m)
    full = (r >= 0) & (oldest > n_s[None, :])
    return (~full)[:, chain.inv]


# --------------------------------------------------------------------------
# segmented occupancy: stage 2's per-VOQ counts without the per-row loop
# --------------------------------------------------------------------------

def segmented_occupancy(t: np.ndarray, dep: np.ndarray,
                        chain: ChainIndex) -> np.ndarray:
    """Exact per-VOQ occupancy at arrival instants, one searchsorted total.

    The serial reference (``batched_surrogate._exact_occupancy``) runs one
    ``searchsorted`` per candidate row.  Occupancy at event k is
    ``(chain arrivals ≤ k) − (chain departures ≤ now_k) − 1`` — a prefix
    count over chain segments.  Departures are FIFO inside a chain (shared
    ports), so the chain-major key ``chain·span + time`` is globally sorted
    per row; adding a per-row offset makes the whole [B, m] block one sorted
    key stream and a *single* flat ``np.searchsorted`` answers every
    (candidate, event) query at once.

    Precision: the composite key spends ~log2(B·n²) mantissa bits on the
    (row, chain) id — ≈14 bits at B=256, n=8, leaving time resolution of
    span·2⁻³⁸ ≈ femtoseconds on the registry traces, far below any service
    time.  Counts are integers and bit-identical to the serial reference on
    every registry workload (asserted in ``tests/test_netsim_kernels.py``).
    """
    b_n, m = dep.shape
    perm = chain.perm
    pos = np.arange(m, dtype=np.int64)
    span = max(float(dep.max(initial=0.0)), float(t.max(initial=0.0))) + 1.0
    # chain id per permuted slot: seg_start is constant within a chain and
    # unique across chains, so it serves as a compact chain id
    g = chain.seg_start.astype(np.float64)
    key_dep = g[None, :] * span + dep[:, perm]
    key_arr = g * span + t[perm]
    big = (float(chain.seg_start[-1]) + 2.0) * span if m else 1.0
    rows = np.arange(b_n, dtype=np.float64)[:, None] * big
    fd = (key_dep + rows).ravel()
    fa = (key_arr[None, :] + rows).ravel()
    departed = (np.searchsorted(fd, fa, side="right").reshape(b_n, m)
                - np.arange(b_n, dtype=np.int64)[:, None] * m)
    occ_s = (pos[None, :] + 1) - departed - 1
    return occ_s[:, chain.inv]


# --------------------------------------------------------------------------
# the lean replay: tracked jit, sharded builder, Pallas tile
# --------------------------------------------------------------------------

def _round1_body(now, src, dst, svc_t, pipe, depth, perm, seg_start, rank,
                 *, n_ports):
    """Fused first round: ungated replay + all-admitted fullness check.

    With all-ones flags the gated recurrence degenerates to the plain port
    replay, and the admission question needs no compaction at all — the
    ``rank − depth``-th event of my chain *is* the depth-ago admission, so
    one ``take_along_axis`` answers fullness for the whole batch.  Returns
    the replay and a per-row "round 1 is the fixed point" flag; rows where
    it is (every row, in the sized no-drop regime) are done after this one
    call."""
    b_n = svc_t.shape[1]

    def step(carry, xs):
        in_f, out_f = carry
        tk, i, j, s = xs
        start = jnp.maximum(jnp.maximum(tk + pipe, in_f[:, i]), out_f[:, j])
        end = start + s
        return (in_f.at[:, i].set(end), out_f.at[:, j].set(end)), end

    zeros = jnp.zeros((b_n, n_ports), svc_t.dtype)
    _, end_t = jax.lax.scan(step, (zeros, zeros), (now, src, dst, svc_t))
    end = end_t.T                                           # [B, m]
    e_s = jnp.take(end, perm, axis=1)
    n_s = jnp.take(now, perm)
    r = rank[None, :] - depth[:, None]                      # [B, m] int32
    look = jnp.clip(seg_start[None, :] + r, 0, max(e_s.shape[1] - 1, 0))
    oldest = jnp.take_along_axis(e_s, look, axis=1)
    full = (r >= 0) & (oldest > n_s[None, :])
    ok = ~jnp.any(full, axis=1)
    return end, ok


_round1 = track("netsim.kernel.round1",
                jax.jit(_round1_body, static_argnames=("n_ports",)))

_gated_replay = track("netsim.kernel.replay", netsim_replay_abs_ref)


@functools.lru_cache(maxsize=None)
def _sharded_round1(mesh, n_ports):
    """Round 1 under ``shard_map``: candidate axis split over every mesh
    axis, timeline and chain structure replicated.  Rowwise — no collectives
    — so each shard is bitwise the single-device call on its slice."""
    from jax.sharding import PartitionSpec as P

    from repro import compat

    names = tuple(mesh.axis_names)
    cand = P(names)
    rep = P()
    body = functools.partial(_round1_body, n_ports=n_ports)
    name = (f"netsim.kernel.round1.sharded["
            f"{'x'.join(map(str, mesh.devices.shape))} "
            f"{','.join(names)} n_ports={n_ports}]")
    return track(name, jax.jit(compat.shard_map(
        body, mesh,
        in_specs=(rep, rep, rep, P(None, names), cand, cand, rep, rep, rep),
        out_specs=(cand, cand))))


@functools.lru_cache(maxsize=None)
def _sharded_gated_replay(mesh, n_ports):
    from jax.sharding import PartitionSpec as P

    from repro import compat

    names = tuple(mesh.axis_names)
    cand = P(names)
    rep = P()

    def body(now, src, dst, svc, pipe, admit):
        return netsim_replay_abs_ref(now, src, dst, svc, pipe, admit,
                                     n_ports=n_ports)

    name = (f"netsim.kernel.replay.sharded["
            f"{'x'.join(map(str, mesh.devices.shape))} "
            f"{','.join(names)} n_ports={n_ports}]")
    return track(name, jax.jit(compat.shard_map(
        body, mesh,
        in_specs=(rep, rep, rep, cand, cand, cand),
        out_specs=cand)))


def lean_replay(now, src, dst, svc, pipe, admit, *, n_ports: int,
                use_pallas: bool = False, interpret: bool = True,
                block_b: int = 8):
    """The admission-gated lean replay, oracle or Pallas tile.

    Oracle path (default): the jitted float64 ``lax.scan``
    (``ref.netsim_replay_abs_ref``), absolute departure times, bit-exact
    against the serial model.  Pallas path: the float32 slack-formulation
    kernel with the candidate axis tiled onto the grid; returns departure
    *offsets* (``end − now``), parity at float32 tolerance.  ``interpret``
    validates the tile on CPU; ``interpret=False`` compiles it for a real
    TPU backend."""
    if not use_pallas:
        return netsim_replay_abs_ref(
            jnp.asarray(now), jnp.asarray(src, jnp.int32),
            jnp.asarray(dst, jnp.int32), jnp.asarray(svc),
            jnp.asarray(pipe), jnp.asarray(admit), n_ports=n_ports)
    now = np.asarray(now, np.float64)
    b_n, m = np.asarray(svc).shape
    n_pad = -(-n_ports // LANES) * LANES
    b_pad = -(-b_n // block_b) * block_b
    dnow = np.diff(now, prepend=0.0).astype(np.float32)[None, :]
    svc_p = np.zeros((b_pad, m), np.float32)
    svc_p[:b_n] = np.asarray(svc, np.float32)
    ad_p = np.zeros((b_pad, m), np.float32)
    ad_p[:b_n] = np.asarray(admit, np.float32)
    pipe_p = np.zeros((b_pad, 1), np.float32)
    pipe_p[:b_n, 0] = np.asarray(pipe, np.float32)
    dep = netsim_replay_padded(
        jnp.asarray(dnow), jnp.asarray(src, jnp.int32)[None, :],
        jnp.asarray(dst, jnp.int32)[None, :], jnp.asarray(svc_p),
        jnp.asarray(ad_p), jnp.asarray(pipe_p),
        n_pad=n_pad, block_b=block_b, interpret=interpret)
    return dep[:b_n]


def _pad_rows(a: np.ndarray, size: int) -> np.ndarray:
    """Pad the candidate axis to ``size`` by replicating row 0 (a no-op
    workload: rowwise engines ignore replicas, callers strip them)."""
    if a.shape[0] == size:
        return a
    reps = np.repeat(a[:1], size - a.shape[0], axis=0)
    return np.concatenate([a, reps], axis=0)


def _bucket(b_n: int, k: int) -> int:
    """Compile-friendly batch size: next power of two, then up to a multiple
    of the shard count — subset iterations reuse O(log B) compiled shapes."""
    size = 1 << max(b_n - 1, 0).bit_length()
    if k > 1:
        size = -(-size // k) * k
    return size


def netsim_fixed_point(
    now: np.ndarray,       # [m] sorted switch-arrival times
    src: np.ndarray,       # [m] int32
    dst: np.ndarray,       # [m] int32
    svc: np.ndarray,       # [B, m] float64
    pipe: np.ndarray,      # [B] float64
    depth: np.ndarray,     # [B] int — per-candidate VOQ depth (>= 1)
    *,
    n_ports: int,
    chain: ChainIndex,
    mesh_spec=None,
    max_rounds: int = 24,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, int]:
    """Speculative fixed point: (end [B,m], admit [B,m], converged [B], rounds).

    Round 1 runs the fused ungated replay + fullness check for the whole
    batch (one jitted call, mesh-sharded when ``mesh_spec`` names devices).
    Rows whose all-admitted replay is already self-consistent — every row,
    when stage-3 sizing did its job — are final.  The rest iterate
    replay ↔ ``segmented_admission`` on the row subset only (padded to a
    power-of-two bucket so compiles stay O(log B)); unconverged rows after
    ``max_rounds`` are flagged for the caller's serial fallback.  Callers
    must handle ``depth < 1`` rows themselves (serial semantics drop every
    packet; no replay needed)."""
    b_n, m = svc.shape
    if np.any(depth < 1):
        raise ValueError("netsim_fixed_point requires depth >= 1 rows")
    k = 1 if mesh_spec is None else mesh_spec.shard_axis
    depth32 = np.minimum(depth, np.int64(2**31 - 1)).astype(np.int32)

    now_j = jnp.asarray(now)
    src_j = jnp.asarray(src, jnp.int32)
    dst_j = jnp.asarray(dst, jnp.int32)
    perm_j = jnp.asarray(chain.perm, jnp.int32)
    seg_j = jnp.asarray(chain.seg_start, jnp.int32)
    rank_j = jnp.asarray(chain.rank, jnp.int32)

    if k > 1:
        from repro.launch.mesh import shard_pad
        fn = _sharded_round1(mesh_spec.build(), n_ports)
        end, ok = fn(now_j, src_j, dst_j,
                     jnp.asarray(shard_pad(svc, k).T),
                     jnp.asarray(shard_pad(pipe, k)),
                     jnp.asarray(shard_pad(depth32, k)),
                     perm_j, seg_j, rank_j)
    else:
        end, ok = _round1(now_j, src_j, dst_j, jnp.asarray(svc.T),
                          jnp.asarray(pipe), jnp.asarray(depth32),
                          perm_j, seg_j, rank_j, n_ports=n_ports)
    # np.array (not asarray): device output views are read-only and the
    # subset iteration scatters into end below
    end = np.array(end[:b_n])
    ok = np.asarray(ok)[:b_n]
    admit = np.ones((b_n, m), bool)
    converged = ok.copy()
    if bool(ok.all()):
        return end, admit, converged, 1

    rows = np.nonzero(~ok)[0]
    sub_svc, sub_pipe = svc[rows], pipe[rows]
    sub_depth = depth32[rows]
    sub_end = end[rows]
    cur = segmented_admission(sub_end, np.ones((rows.size, m), bool), now,
                              sub_depth, chain)
    rounds = 1
    conv_sub = np.zeros(rows.size, bool)
    while rounds < max_rounds:
        rounds += 1
        size = _bucket(rows.size, k)
        svc_p = _pad_rows(sub_svc, size)
        admit_p = _pad_rows(cur, size)
        pipe_p = _pad_rows(sub_pipe, size)
        if k > 1:
            fn = _sharded_gated_replay(mesh_spec.build(), n_ports)
            sub_end = np.asarray(fn(now_j, src_j, dst_j, jnp.asarray(svc_p),
                                    jnp.asarray(pipe_p),
                                    jnp.asarray(admit_p)))[:rows.size]
        else:
            sub_end = np.asarray(_gated_replay(
                now_j, src_j, dst_j, jnp.asarray(svc_p), jnp.asarray(pipe_p),
                jnp.asarray(admit_p), n_ports=n_ports))[:rows.size]
        derived = segmented_admission(sub_end, cur, now, sub_depth, chain)
        eq = (derived == cur).all(axis=1)
        conv_sub = np.asarray(eq)
        if bool(eq.all()):
            break
        cur = derived
    end[rows] = sub_end
    admit[rows] = cur
    converged[rows] = conv_sub
    return end, admit, converged, rounds
