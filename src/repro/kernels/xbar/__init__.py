from .ops import xbar_contend

__all__ = ["xbar_contend"]
