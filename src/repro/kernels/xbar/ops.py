"""Public batched crossbar-contention op.

Dispatch policy: float64 inputs take the absolute-time scan (fastest, and
bit-identical to the serial surrogate's recurrence — it returns *absolute*
departure times so ulp-exact occupancy comparisons hold downstream);
float32 inputs take the slack-form scan whose carries never hold absolute
timestamps, so precision survives long traces — that is also the form the
Pallas kernel implements for TPU deployment (validated in interpret mode on
CPU).  The f32 paths return departure *offsets* (dep - arrival)."""

from __future__ import annotations

import jax.numpy as jnp

from .kernel import xbar_contend_padded
from .ref import xbar_contend_abs_ref, xbar_contend_slack_ref

LANES = 128


def xbar_contend(t, dt, src, dst, svc, *, n_ports: int, use_pallas: bool = False,
                 block_b: int = 8, interpret: bool = True,
                 absolute: bool = None):
    """t/dt/src/dst [m] shared trace, svc [B, m] -> [B, m] departure times
    (absolute on the float64 path, arrival-relative offsets on float32).

    Pass ``absolute=True`` to *require* absolute-time semantics: if x64 is
    disabled JAX silently downcasts float64 inputs and the dtype dispatch
    would quietly hand back offsets instead — this raises there."""
    is_f64 = jnp.asarray(svc).dtype == jnp.float64
    if absolute is None:
        absolute = is_f64 and not use_pallas
    elif absolute and (use_pallas or not is_f64):
        raise ValueError(
            "absolute departure times need the float64 scan (enable jax x64 "
            f"and use_pallas=False); got dtype {jnp.asarray(svc).dtype}")
    if use_pallas:
        b, m = svc.shape
        n_pad = -(-n_ports // LANES) * LANES
        pad_b = (-b) % block_b
        svc32 = jnp.asarray(svc, jnp.float32)
        if pad_b:
            svc32 = jnp.pad(svc32, ((0, pad_b), (0, 0)))
        dep = xbar_contend_padded(
            jnp.asarray(dt, jnp.float32)[None, :],
            jnp.asarray(src, jnp.int32)[None, :],
            jnp.asarray(dst, jnp.int32)[None, :],
            svc32,
            n_pad=n_pad, block_b=block_b, interpret=interpret,
        )
        return dep[:b]
    if absolute:
        return xbar_contend_abs_ref(t, src, dst, svc, n_ports=n_ports)
    return xbar_contend_slack_ref(dt, src, dst, svc, n_ports=n_ports)
