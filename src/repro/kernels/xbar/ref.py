"""Greedy-crossbar contention scan, pure-jnp oracles (``jax.lax.scan``).

The stage-2 surrogate admits packets against input/output port availability
in global arrival order (``repro.sim.surrogate``).  This is the batched
reformulation: one shared, time-sorted trace and a batch axis of candidate
micro-architectures whose per-packet service times ``svc`` differ (bus
width, η, stalls, f_clk are all folded into ``svc`` upstream).

Two formulations, numerically equivalent, with different dtype trade-offs:

* ``xbar_contend_abs_ref`` carries *absolute* port-free times and returns
  absolute departure times — fewest ops per step and, in float64,
  bit-identical to the serial recurrence ``start = max(t_k, in_free_i,
  out_free_j); end = start + svc`` (returning ``end`` itself, not an offset,
  so downstream ulp-exact comparisons against arrival times hold).
* ``xbar_contend_slack_ref`` carries *slacks* (offsets from the current
  arrival instant) and returns departure *offsets*, so float32 keeps
  queueing-delay precision no matter how long the trace runs — the
  TPU-native form the Pallas kernel implements.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

__all__ = ["xbar_contend_abs_ref", "xbar_contend_slack_ref"]


@functools.partial(jax.jit, static_argnames=("n_ports",))
def xbar_contend_abs_ref(
    t: jnp.ndarray,     # [m] float — sorted arrival times, t[0] == 0
    src: jnp.ndarray,   # [m] int32 — source port per packet (shared trace)
    dst: jnp.ndarray,   # [m] int32 — destination port per packet
    svc: jnp.ndarray,   # [B, m] float — per-candidate service time per packet
    *,
    n_ports: int,
) -> jnp.ndarray:       # [B, m] — absolute departure time per packet
    b = svc.shape[0]
    zeros = jnp.zeros((b, n_ports), svc.dtype)

    def step(carry, x):
        in_f, out_f = carry
        tk, i, j, s = x
        start = jnp.maximum(jnp.maximum(in_f[:, i], out_f[:, j]), tk)
        end = start + s
        return (in_f.at[:, i].set(end), out_f.at[:, j].set(end)), end

    _, dep = jax.lax.scan(step, (zeros, zeros), (t, src, dst, svc.T))
    return dep.T


@functools.partial(jax.jit, static_argnames=("n_ports",))
def xbar_contend_slack_ref(
    dt: jnp.ndarray,    # [m] float — inter-arrival gaps, dt[0] == 0
    src: jnp.ndarray,   # [m] int32
    dst: jnp.ndarray,   # [m] int32
    svc: jnp.ndarray,   # [B, m] float
    *,
    n_ports: int,
) -> jnp.ndarray:       # [B, m] — departure offsets, as above
    b = svc.shape[0]
    zeros = jnp.zeros((b, n_ports), svc.dtype)

    def step(carry, x):
        in_s, out_s = carry
        dtk, i, j, s = x
        in_s = jnp.maximum(in_s - dtk, 0.0)
        out_s = jnp.maximum(out_s - dtk, 0.0)
        dep = jnp.maximum(in_s[:, i], out_s[:, j]) + s
        return (in_s.at[:, i].set(dep), out_s.at[:, j].set(dep)), dep

    _, dep = jax.lax.scan(step, (zeros, zeros), (dt, src, dst, svc.T))
    return dep.T
