"""Pure-jnp oracle: exact softmax attention in f32."""

from __future__ import annotations

import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True) -> jnp.ndarray:
    """q [BH, S, D], k/v [BH, T, D] -> [BH, S, D]."""
    d = q.shape[-1]
    s = jnp.einsum("bsd,btd->bst", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s / (d ** 0.5)
    if causal:
        sq, tk = s.shape[-2:]
        mask = jnp.tril(jnp.ones((sq, tk), bool), k=tk - sq)
        s = jnp.where(mask, s, -1e30)
    p = jnp.exp(s - s.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    return jnp.einsum("bst,btd->bsd", p, v.astype(jnp.float32)).astype(q.dtype)
