"""Blockwise (flash) attention Pallas kernel with online softmax.

TPU-native tiling: the KV sequence streams through VMEM in ``block_k`` tiles
while running max/denominator/accumulator live in VMEM scratch across the
innermost (sequential) grid dimension.  MXU-aligned blocks (multiples of 128)
keep the two matmuls on the systolic array.  Causal masking is applied
in-block; fully-masked blocks still flow through the grid (masked to -inf),
which keeps the index maps trivial — the XLA-level fallback used for the
dry-run (`repro.models.attention.blockwise_attention`) has the same FLOP
shape, so roofline numbers transfer.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
MIN_LANE = 128


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, acc, m_scr, l_scr, *, scale, causal, block_q, block_k):
    iq, ik = pl.program_id(1), pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)

    q = q_ref[0].astype(jnp.float32)                    # [bq, D]
    k = k_ref[0].astype(jnp.float32)                    # [bk, D]
    v = v_ref[0].astype(jnp.float32)                    # [bk, D]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale  # [bq, bk]
    if causal:
        rows = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        cols = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(rows >= cols, s, NEG_INF)

    m_prev = m_scr[...][:, :1]                          # [bq, 1]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)                              # [bq, bk]
    alpha = jnp.exp(m_prev - m_new)                     # [bq, 1]
    l_prev = l_scr[...][:, :1]
    l_new = l_prev * alpha + p.sum(axis=-1, keepdims=True)
    acc[...] = acc[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
    l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)

    @pl.when(ik == pl.num_programs(2) - 1)
    def _flush():
        o_ref[0] = (acc[...] / jnp.maximum(l_scr[...][:, :1], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "block_q", "block_k", "interpret"))
def flash_attention_bhsd(
    q: jnp.ndarray,   # [BH, S, D]
    k: jnp.ndarray,   # [BH, T, D]
    v: jnp.ndarray,   # [BH, T, D]
    *,
    causal: bool = True,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    bh, s, d = q.shape
    t = k.shape[1]
    assert s % block_q == 0 and t % block_k == 0, (s, t, block_q, block_k)
    scale = 1.0 / (d ** 0.5)
    kern = functools.partial(_attn_kernel, scale=scale, causal=causal,
                             block_q=block_q, block_k=block_k)
    return pl.pallas_call(
        kern,
        grid=(bh, s // block_q, t // block_k),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q, MIN_LANE), jnp.float32),
            pltpu.VMEM((block_q, MIN_LANE), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
