"""Public flash-attention op over [B, H, S, D] with GQA head expansion."""

from __future__ import annotations

import jax.numpy as jnp

from .kernel import flash_attention_bhsd
from .ref import attention_ref


def flash_attention(
    q: jnp.ndarray,   # [B, Hq, S, D]
    k: jnp.ndarray,   # [B, Hkv, T, D]
    v: jnp.ndarray,   # [B, Hkv, T, D]
    *,
    causal: bool = True,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    if hkv != hq:
        rep = hq // hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    out = flash_attention_bhsd(
        q.reshape(b * hq, s, d), k.reshape(b * hq, -1, d), v.reshape(b * hq, -1, d),
        causal=causal, block_q=min(block_q, s), block_k=min(block_k, k.shape[-2]),
        interpret=interpret,
    )
    return out.reshape(b, hq, s, d)


def attention_reference(q, k, v, *, causal: bool = True) -> jnp.ndarray:
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    if hkv != hq:
        rep = hq // hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    out = attention_ref(q.reshape(b * hq, s, d), k.reshape(b * hq, -1, d),
                        v.reshape(b * hq, -1, d), causal=causal)
    return out.reshape(b, hq, s, d)
