"""Pure-jnp oracle for the quantise/pack kernel."""

from __future__ import annotations

import jax.numpy as jnp

GROUP = 128


def quantize_ref(x: jnp.ndarray):
    r, c = x.shape
    g = x.astype(jnp.float32).reshape(r, c // GROUP, GROUP)
    absmax = jnp.max(jnp.abs(g), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax, 1e-30) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q.reshape(r, c), scale[..., 0]


def dequantize_ref(q: jnp.ndarray, s: jnp.ndarray, out_dtype=jnp.float32):
    r, c = q.shape
    g = q.astype(jnp.float32).reshape(r, c // GROUP, GROUP)
    return (g * s[..., None]).reshape(r, c).astype(out_dtype)
