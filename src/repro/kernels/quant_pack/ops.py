"""Public ops for payload compression, with a jax-native fallback.

``compress``/``decompress`` round-trip arbitrary-shaped tensors by flattening
to [R, 128k].  On CPU the Pallas kernel runs in interpret mode; inside
jit-for-dryrun graphs we use the pure-jnp reference (identical math) so the
HLO compiles on any backend — the kernel is the TPU deployment path.
"""

from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp
import numpy as np

from . import kernel, ref

GROUP = kernel.GROUP


def _to_2d(x: jnp.ndarray) -> Tuple[jnp.ndarray, tuple]:
    shape = x.shape
    flat = int(np.prod(shape))
    pad = (-flat) % GROUP
    v = jnp.pad(x.reshape(-1), (0, pad))
    return v.reshape(-1, GROUP), (shape, flat)


def compress(x: jnp.ndarray, *, use_pallas: bool = False):
    """tensor -> (q int8 [R,128], scales f32 [R,1], meta) — the wire format."""
    v, meta = _to_2d(x)
    if use_pallas:
        q, s = kernel.quantize(v)
    else:
        q, s = ref.quantize_ref(v)
    return q, s, meta


def decompress(q: jnp.ndarray, s: jnp.ndarray, meta, *, dtype=jnp.float32,
               use_pallas: bool = False) -> jnp.ndarray:
    shape, flat = meta
    x = kernel.dequantize(q, s, out_dtype=dtype) if use_pallas else ref.dequantize_ref(q, s, dtype)
    return x.reshape(-1)[:flat].reshape(shape)


def compression_ratio(x: jnp.ndarray) -> float:
    """Wire-bytes ratio vs the uncompressed dtype (the 'header compression' win)."""
    in_bytes = x.size * x.dtype.itemsize
    out_bytes = x.size * 1 + (x.size // GROUP) * 4
    return in_bytes / out_bytes
