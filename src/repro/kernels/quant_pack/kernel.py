"""Payload quantise/pack Pallas kernel — SPAC's protocol compression on TPU.

The paper shrinks protocol headers (42 B → 2 B) at compile time; the TPU
analogue compresses the *payload* of comm-layer messages (gradient buckets,
MoE dispatch tokens): bf16/f32 tensors are quantised to int8 with one f32
scale per 128-element group, cutting collective bytes ~2×(bf16) / ~3.6×(f32
with scales).  ``dequantize`` is the receive-side parser.

Tiling: rows are processed in blocks of ``block_rows``; the last dim must be
a multiple of the 128-lane group so the absmax reduction stays within a
vector register tile (MXU-free, pure VPU kernel).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

GROUP = 128  # quantisation group = one VREG lane row


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)                   # [bр, G*k]
    r, c = x.shape
    g = x.reshape(r, c // GROUP, GROUP)
    absmax = jnp.max(jnp.abs(g), axis=-1, keepdims=True) # [r, c/G, 1]
    scale = jnp.maximum(absmax, 1e-30) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    q_ref[...] = q.reshape(r, c)
    s_ref[...] = scale[..., 0]


def _dequant_kernel(q_ref, s_ref, x_ref):
    q = q_ref[...].astype(jnp.float32)
    r, c = q.shape
    g = q.reshape(r, c // GROUP, GROUP)
    x = g * s_ref[...][..., None]
    x_ref[...] = x.reshape(r, c).astype(x_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def quantize(x: jnp.ndarray, *, block_rows: int = 256, interpret: bool = True):
    """x [R, C] (C % 128 == 0) -> (q int8 [R, C], scales f32 [R, C/128])."""
    r, c = x.shape
    assert c % GROUP == 0, f"last dim {c} must be a multiple of {GROUP}"
    br = min(block_rows, r)
    assert r % br == 0, f"rows {r} not divisible by block {br}"
    q, s = pl.pallas_call(
        _quant_kernel,
        grid=(r // br,),
        in_specs=[pl.BlockSpec((br, c), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((br, c), lambda i: (i, 0)),
            pl.BlockSpec((br, c // GROUP), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r, c), jnp.int8),
            jax.ShapeDtypeStruct((r, c // GROUP), jnp.float32),
        ],
        interpret=interpret,
    )(x)
    return q, s


@functools.partial(jax.jit, static_argnames=("block_rows", "out_dtype", "interpret"))
def dequantize(q: jnp.ndarray, s: jnp.ndarray, *, block_rows: int = 256,
               out_dtype=jnp.float32, interpret: bool = True):
    r, c = q.shape
    br = min(block_rows, r)
    assert r % br == 0
    return pl.pallas_call(
        _dequant_kernel,
        grid=(r // br,),
        in_specs=[
            pl.BlockSpec((br, c), lambda i: (i, 0)),
            pl.BlockSpec((br, c // GROUP), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((br, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, c), out_dtype),
        interpret=interpret,
    )(q, s)
