"""Oracle: the repo's lax-based iSLIP (repro.switch.scheduler), vmapped."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.archspec import (ForwardTableKind, SchedulerKind, SwitchArch,
                                 VOQKind)
from repro.switch.scheduler import SchedState, schedule


def islip_ref(req: jnp.ndarray, gptr: jnp.ndarray, aptr: jnp.ndarray,
              *, iters: int = 2):
    """req [B, N, N] int -> (match [B,N,N] int32, gptr', aptr')."""
    n = req.shape[-1]
    arch = SwitchArch(n_ports=n, bus_bits=256, fwd=ForwardTableKind.FULL_LOOKUP,
                      voq=VOQKind.NXN, sched=SchedulerKind.ISLIP,
                      voq_depth=4, islip_iters=iters, addr_bits=4)

    def one(r, g, a):
        st = SchedState(grant_ptr=g.astype(jnp.int32), accept_ptr=a.astype(jnp.int32),
                        held=jnp.full((n,), -1, jnp.int32))
        busy = jnp.zeros((n,), bool)
        m, st2 = schedule(arch, st, r.astype(jnp.int32), busy, busy)
        return m.astype(jnp.int32), st2.grant_ptr, st2.accept_ptr

    return jax.vmap(one)(req, gptr, aptr)
