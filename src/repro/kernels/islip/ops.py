"""Public batched-iSLIP op: pad ports to the lane boundary, run the kernel."""

from __future__ import annotations

import jax.numpy as jnp

from .kernel import islip_schedule_padded
from .ref import islip_ref

LANES = 128


def islip_schedule(req, gptr, aptr, *, iters: int = 2, use_pallas: bool = True,
                   interpret: bool = True):
    """req [B, N, N] -> (match, gptr', aptr').  N padded to 128 internally."""
    b, n, _ = req.shape
    if not use_pallas:
        return islip_ref(req, gptr, aptr, iters=iters)
    np_ = -(-n // LANES) * LANES
    rq = jnp.zeros((b, np_, np_), jnp.int32).at[:, :n, :n].set(req.astype(jnp.int32))
    g = jnp.zeros((b, np_), jnp.int32).at[:, :n].set(gptr.astype(jnp.int32))
    a = jnp.zeros((b, np_), jnp.int32).at[:, :n].set(aptr.astype(jnp.int32))
    bb = 8
    pad_b = (-b) % bb
    if pad_b:
        rq = jnp.pad(rq, ((0, pad_b), (0, 0), (0, 0)))
        g = jnp.pad(g, ((0, pad_b), (0, 0)))
        a = jnp.pad(a, ((0, pad_b), (0, 0)))
    m, g2, a2 = islip_schedule_padded(rq, g, a, iters=iters, n_valid=n,
                                      block_b=bb, interpret=interpret)
    return m[:b, :n, :n], g2[:b, :n], a2[:b, :n]
