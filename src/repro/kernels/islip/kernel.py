"""Batched iSLIP matching as a Pallas kernel — the paper's scheduler on the VPU.

The DSE's brute-force stage and the surrogate calibration both want to
arbitrate *many* switch instances per step (one per candidate × traffic
window).  A single matching is tiny (an [N, N] bit matrix), so the kernel
batches: each grid step arbitrates ``block_b`` independent switches held in
one VMEM tile, with the request/grant/accept iterations fully unrolled
(iters is compile-time, like the paper's HLS template parameter).

Contract (per batch row): requests [N, N] int32 (0/1), grant/accept pointers
[N] int32 → match [N, N] int32 one-hot matching + updated pointers
(McKeown's rule: pointers advance only on a first-iteration accepted grant).
N is padded to the 128-lane boundary by ``ops.islip_schedule``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BIG = 1 << 20  # plain int: jnp constants would be captured consts in the kernel


def _rot_pick_rows(v, p, n_valid):
    """One-hot first set bit at/after rotating pointer, per row.

    v [B, R, C] int32 0/1; p [B, R] int32 -> one-hot [B, R, C]."""
    c = v.shape[-1]
    idx = jax.lax.broadcasted_iota(jnp.int32, v.shape, 2)
    in_range = idx < n_valid
    score = jnp.where((v > 0) & in_range, (idx - p[..., None]) % n_valid, BIG)
    best = jnp.min(score, axis=-1, keepdims=True)
    pick = (score == best) & (best < BIG)
    # break ties (can't happen for distinct mod values, but keep it safe)
    first = jnp.cumsum(pick.astype(jnp.int32), axis=-1) == 1
    return (pick & first).astype(jnp.int32)


def _islip_kernel(req_ref, gptr_ref, aptr_ref, match_ref, gout_ref, aout_ref,
                  *, iters: int, n_valid: int):
    req = req_ref[...].astype(jnp.int32)        # [B, N, N]
    gptr = gptr_ref[...].astype(jnp.int32)      # [B, N]
    aptr = aptr_ref[...].astype(jnp.int32)
    b, n, _ = req.shape
    match = jnp.zeros_like(req)
    new_g, new_a = gptr, aptr
    for it in range(iters):                     # unrolled (template parameter)
        row_busy = (match.sum(2) > 0)[:, :, None]
        col_busy = (match.sum(1) > 0)[:, None, :]
        free = req * (1 - row_busy.astype(jnp.int32)) * (1 - col_busy.astype(jnp.int32))
        # grant: each output (column) picks a requesting input
        grants_t = _rot_pick_rows(free.transpose(0, 2, 1), gptr, n_valid)
        grants = grants_t.transpose(0, 2, 1)    # [B, N_in, N_out]
        # accept: each input (row) picks among its grants
        accepts = _rot_pick_rows(grants, aptr, n_valid)
        match = match + accepts
        if it == 0:                             # McKeown's pointer rule
            out_accepted = accepts.sum(1)       # [B, N_out] 0/1
            in_accepted = accepts.sum(2)        # [B, N_in]
            g_in = jnp.argmax(accepts, axis=1).astype(jnp.int32)   # per output
            a_out = jnp.argmax(accepts, axis=2).astype(jnp.int32)  # per input
            new_g = jnp.where(out_accepted > 0, (g_in + 1) % n_valid, gptr)
            new_a = jnp.where(in_accepted > 0, (a_out + 1) % n_valid, aptr)
    match_ref[...] = match
    gout_ref[...] = new_g
    aout_ref[...] = new_a


@functools.partial(jax.jit, static_argnames=("iters", "n_valid", "block_b", "interpret"))
def islip_schedule_padded(
    req: jnp.ndarray,    # [B, Np, Np] int32 (padded to 128 lanes)
    gptr: jnp.ndarray,   # [B, Np] int32
    aptr: jnp.ndarray,   # [B, Np] int32
    *,
    iters: int = 2,
    n_valid: int = 16,
    block_b: int = 8,
    interpret: bool = True,
):
    b, np_, _ = req.shape
    assert b % block_b == 0, (b, block_b)
    kern = functools.partial(_islip_kernel, iters=iters, n_valid=n_valid)
    return pl.pallas_call(
        kern,
        grid=(b // block_b,),
        in_specs=[
            pl.BlockSpec((block_b, np_, np_), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_b, np_), lambda i: (i, 0)),
            pl.BlockSpec((block_b, np_), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_b, np_, np_), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_b, np_), lambda i: (i, 0)),
            pl.BlockSpec((block_b, np_), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, np_, np_), jnp.int32),
            jax.ShapeDtypeStruct((b, np_), jnp.int32),
            jax.ShapeDtypeStruct((b, np_), jnp.int32),
        ],
        interpret=interpret,
    )(req, gptr, aptr)
