"""Pallas TPU kernels for the framework's compute hot-spots.

  parser/          - generated line-rate header parser (paper SS III-B.1)
  quant_pack/      - payload quantise/pack (protocol compression analogue)
  flash_attention/ - blockwise attention w/ online softmax (prefill path)
  ssd/             - Mamba-2 SSD chunked scan (SSM/hybrid archs)

Each subpackage: kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd
wrapper + XLA fallback used in dry-run graphs), ref.py (pure-jnp oracle).
Validated on CPU via interpret=True; TPU is the deployment target.
"""
