"""Pure-jnp oracle for the generated parser kernel (same contract)."""

from __future__ import annotations

from typing import Sequence

import jax.numpy as jnp

from repro.core.dsl import Protocol
from .kernel import bake_slices


def parse_ref(protocol: Protocol, field_names: Sequence[str], words: jnp.ndarray) -> jnp.ndarray:
    baked = bake_slices(protocol, field_names)
    cols = []
    for pieces in baked:
        v = jnp.zeros(words.shape[:1], dtype=jnp.uint32)
        for word, lo, take, dst_shift in pieces:
            piece = (words[:, word].astype(jnp.uint32) >> jnp.uint32(lo)) & jnp.uint32((1 << take) - 1)
            v = v | (piece << jnp.uint32(dst_shift))
        cols.append(v)
    return jnp.stack(cols, axis=1)
