"""Batch header parsing op: pad, tile, run the generated kernel."""

from __future__ import annotations

from typing import Sequence

import jax.numpy as jnp
import numpy as np

from repro.core.dsl import Protocol
from . import kernel
from .ref import parse_ref

LANES = kernel.LANES


def parse_headers(
    protocol: Protocol,
    field_names: Sequence[str],
    words: jnp.ndarray,            # [B, W] uint32 packed headers
    *,
    use_pallas: bool = True,
    block_rows: int = 256,
    interpret: bool = True,
) -> jnp.ndarray:
    """Returns uint32 [B, len(field_names)] parsed field values."""
    b, w = words.shape
    if not use_pallas:
        return parse_ref(protocol, field_names, words)
    w_pad = -(-w // LANES) * LANES
    b_block = min(block_rows, b) if b else 1
    b_pad = -(-b // b_block) * b_block
    padded = jnp.zeros((b_pad, w_pad), dtype=jnp.uint32).at[:b, :w].set(words)
    parse = kernel.make_parser(protocol, field_names, block_rows=b_block, interpret=interpret)
    return parse(padded)[:b]
