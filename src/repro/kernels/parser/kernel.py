"""Generated header-parser Pallas kernel — §III-B.1 on TPU.

SPAC's parser is an HLS template instantiated with compile-time traits; here
``make_parser`` *generates* a Pallas kernel with the protocol's bit offsets
baked into the closure (the `packet.hpp` role).  Field accesses lower to
hard-wired shift/mask ops on 32-bit words; fields that straddle word
boundaries emit one extra shift-or (the "minimal state retention" analogue).
Batches of packed headers are parsed at VPU line rate: [B, W] uint32 words →
[B, F] uint32 field values.

Tiling: rows (packets) stream through in ``block_rows`` blocks; the word dim
is zero-padded to the 128-lane boundary inside ``ops.parse_headers``.
"""

from __future__ import annotations

import functools
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.dsl import Protocol

WORD_BITS = 32
LANES = 128


def bake_slices(protocol: Protocol, field_names: Sequence[str]):
    """Compile-time lowering: field -> ((word, lo, width, dst_shift), ...)."""
    plan = protocol.compile(WORD_BITS)
    baked = []
    for name in field_names:
        pieces = []
        for s in plan.slices_for(name):
            take = s.hi - s.lo + 1
            if s.dst_shift >= WORD_BITS:
                continue  # truncated to low 32 bits (lookup keys are <=32b)
            pieces.append((s.word, s.lo, take, s.dst_shift))
        baked.append(tuple(pieces))
    return tuple(baked)


def make_parser(
    protocol: Protocol,
    field_names: Sequence[str],
    *,
    block_rows: int = 256,
    interpret: bool = True,
) -> Callable[[jnp.ndarray], jnp.ndarray]:
    """Generate the specialised parser kernel for (protocol, fields)."""
    baked = bake_slices(protocol, field_names)
    n_fields = len(baked)
    f_pad = -(-n_fields // LANES) * LANES

    def _kernel(w_ref, o_ref):
        words = w_ref[...]                                 # [br, Wpad] uint32
        cols = []
        for pieces in baked:                               # unrolled at trace time
            v = jnp.zeros(words.shape[:1], dtype=jnp.uint32)
            for word, lo, take, dst_shift in pieces:
                piece = (words[:, word] >> jnp.uint32(lo)) & jnp.uint32((1 << take) - 1)
                v = v | (piece << jnp.uint32(dst_shift))
            cols.append(v)
        for _ in range(f_pad - n_fields):
            cols.append(jnp.zeros(words.shape[:1], dtype=jnp.uint32))
        o_ref[...] = jnp.stack(cols, axis=1)

    @functools.partial(jax.jit, static_argnames=())
    def parse(words_padded: jnp.ndarray) -> jnp.ndarray:
        b, w_pad = words_padded.shape
        br = min(block_rows, b)
        assert b % br == 0, f"batch {b} not divisible by block_rows {br}"
        out = pl.pallas_call(
            _kernel,
            grid=(b // br,),
            in_specs=[pl.BlockSpec((br, w_pad), lambda i: (i, 0))],
            out_specs=pl.BlockSpec((br, f_pad), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((b, f_pad), jnp.uint32),
            interpret=interpret,
        )(words_padded)
        return out[:, :n_fields]

    return parse
