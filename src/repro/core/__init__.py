"""SPAC core: protocol DSL, semantic binding, architecture space, DSE engine.

This package is the paper's primary contribution (SS III-A, SS IV-B) and is
shared by both halves of the repo: the faithful FPGA-switch reproduction
(``repro.switch`` / ``repro.sim``) and the TPU training-framework adaptation
(``repro.comm`` / ``repro.launch``).
"""

from .archspec import (
    AUTO,
    ArchRequest,
    BUS_WIDTHS,
    CustomKernelSpec,
    ForwardTableKind,
    SchedulerKind,
    SwitchArch,
    VOQKind,
    enumerate_candidates,
)
from .binding import BoundProtocol, SemanticBinding, bind
from .dse import (
    DSEProblem,
    DSEResult,
    ResourceBudget,
    SLA,
    StageLog,
    SurrogateResult,
    VerifyResult,
    depth_for_drop_rate,
    finalize_result,
    run_dse,
    stage1_static,
    stage2_screen,
    stage3_size,
    stage3_verify,
    stage4_verify,
)
from .dsl import (
    ETHERNET_HEADER_BYTES,
    Field,
    FieldSpec,
    ParserPlan,
    Protocol,
    ProtocolSpace,
    compressed_protocol,
    compressed_protocol_space,
    ethernet_ipv4_udp,
    layout_key,
)
from .features import TraceFeatures, analyze
from .pareto import hypervolume_2d, is_dominated, pareto_front
from .search import (
    DesignSpace,
    Dim,
    NSGA2Search,
    SearchDriver,
    SearchOutcome,
    SearchSpec,
    evaluate_space,
    run_search,
)

__all__ = [
    "AUTO", "ArchRequest", "BUS_WIDTHS", "BoundProtocol", "CustomKernelSpec",
    "DSEProblem", "DSEResult", "DesignSpace", "Dim", "ETHERNET_HEADER_BYTES",
    "Field", "FieldSpec", "ForwardTableKind", "NSGA2Search", "ParserPlan",
    "Protocol", "ProtocolSpace", "ResourceBudget", "SLA", "SchedulerKind",
    "SearchDriver", "SearchOutcome", "SearchSpec", "SemanticBinding",
    "StageLog", "SurrogateResult", "SwitchArch", "TraceFeatures", "VOQKind",
    "VerifyResult", "analyze", "bind", "compressed_protocol",
    "compressed_protocol_space", "depth_for_drop_rate", "enumerate_candidates",
    "ethernet_ipv4_udp", "evaluate_space", "finalize_result", "hypervolume_2d",
    "is_dominated", "layout_key", "pareto_front", "run_dse", "run_search",
    "stage1_static", "stage2_screen", "stage3_size", "stage3_verify",
    "stage4_verify",
]
