"""SPAC protocol DSL — NetBlocks-compatible bit-level packet layout.

The paper (§III-A) uses NetBlocks syntax to declare custom protocols with
bit-level serialization.  A ``Protocol`` here is the single source of truth
consumed by

  * the switch parser generator (compile-time bit offsets, straddle detection,
    ``src/repro/switch/parser.py`` and the Pallas kernel generator in
    ``src/repro/kernels/parser``),
  * the network simulator (header/payload serialization delay),
  * the DSE engine (S_min, header overhead),
  * and the TPU comm layer (message layouts for gradient buckets / MoE
    dispatch payloads reuse the same Field/Protocol machinery).

Layout rules mirror NetBlocks: fields are packed MSB-first, back to back, with
no implicit alignment.  ``Protocol.compile(flit_bits)`` reproduces the paper's
"template metaprogramming" stage: it recursively computes the exact bit offset
of every field relative to flit boundaries and detects fields that straddle
word boundaries (which need state retention in hardware).
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, Iterator, List, Mapping, Optional, Sequence, Tuple, Union

import numpy as np

__all__ = [
    "Field",
    "FieldSpec",
    "Protocol",
    "ProtocolSpace",
    "FieldSlice",
    "ParserPlan",
    "ethernet_ipv4_udp",
    "compressed_protocol",
    "compressed_protocol_space",
    "layout_key",
    "ETHERNET_HEADER_BYTES",
]

# A standard Ethernet+IPv4+UDP stack costs 42 B of header (the paper's §II-B
# "at least 42B" figure): 14 (Eth) + 20 (IPv4) + 8 (UDP).
ETHERNET_HEADER_BYTES = 42


@dataclasses.dataclass(frozen=True)
class Field:
    """One bit-field of a protocol header.

    ``bits`` is the exact width; ``semantic`` is the optional alias used by
    semantic binding (§III-A), e.g. ``routing_key`` / ``src_key`` / ``qos`` /
    ``length`` / ``seq_no`` / ``opcode``.
    """

    name: str
    bits: int
    semantic: Optional[str] = None
    default: int = 0

    def __post_init__(self):
        if self.bits <= 0 or self.bits > 64:
            raise ValueError(f"field {self.name!r}: bits must be in [1, 64], got {self.bits}")


@dataclasses.dataclass(frozen=True)
class FieldSlice:
    """Where a (piece of a) field lives relative to flit boundaries.

    ``word`` indexes the flit, ``hi``/``lo`` are bit positions inside the flit
    (MSB-first, ``hi`` inclusive, ``lo`` inclusive, hi >= lo).  A field that
    straddles a flit boundary compiles to >1 slice — the hardware then needs
    minimal state retention, exactly the paper's straddle handling.
    """

    field: str
    word: int
    hi: int
    lo: int
    dst_shift: int  # how far left (in bits) this piece sits inside the value


@dataclasses.dataclass(frozen=True)
class ParserPlan:
    """Compile-time parsing plan for a given flit width (§III-B.1)."""

    flit_bits: int
    header_bits: int
    slices: Tuple[FieldSlice, ...]
    straddling_fields: Tuple[str, ...]

    @property
    def header_flits(self) -> int:
        return -(-self.header_bits // self.flit_bits)

    def slices_for(self, name: str) -> List[FieldSlice]:
        return [s for s in self.slices if s.field == name]


class Protocol:
    """An ordered sequence of bit-fields followed by a variable payload."""

    def __init__(self, name: str, fields: Sequence[Field]):
        names = [f.name for f in fields]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate field names in protocol {name!r}")
        self.name = name
        self.fields: Tuple[Field, ...] = tuple(fields)
        self._by_name: Dict[str, Field] = {f.name: f for f in fields}
        offs: Dict[str, int] = {}
        off = 0
        for f in fields:
            offs[f.name] = off
            off += f.bits
        self._offsets = offs
        self.header_bits = off

    # ------------------------------------------------------------------ meta
    @property
    def header_bytes(self) -> int:
        return -(-self.header_bits // 8)

    def field(self, name: str) -> Field:
        return self._by_name[name]

    def offset_of(self, name: str) -> int:
        return self._offsets[name]

    def fields_by_semantic(self, semantic: str) -> List[Field]:
        return [f for f in self.fields if f.semantic == semantic]

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"Protocol({self.name!r}, header={self.header_bits}b)"

    # --------------------------------------------------------------- compile
    def compile(self, flit_bits: int) -> ParserPlan:
        """Lower the layout to flit-relative bit slices (paper §III-B.1)."""
        if flit_bits <= 0 or flit_bits % 8:
            raise ValueError("flit_bits must be a positive multiple of 8")
        slices: List[FieldSlice] = []
        straddlers: List[str] = []
        for f in self.fields:
            start = self._offsets[f.name]
            end = start + f.bits  # exclusive
            w0, w1 = start // flit_bits, (end - 1) // flit_bits
            if w0 != w1:
                straddlers.append(f.name)
            remaining = f.bits
            pos = start
            while remaining > 0:
                w = pos // flit_bits
                in_word = pos - w * flit_bits
                take = min(remaining, flit_bits - in_word)
                # MSB-first: bit 0 of the stream is the MSB of flit word 0.
                hi = flit_bits - 1 - in_word
                lo = hi - take + 1
                slices.append(
                    FieldSlice(field=f.name, word=w, hi=hi, lo=lo, dst_shift=remaining - take)
                )
                pos += take
                remaining -= take
        return ParserPlan(
            flit_bits=flit_bits,
            header_bits=self.header_bits,
            slices=tuple(slices),
            straddling_fields=tuple(straddlers),
        )

    # --------------------------------------------------------- (de)serialize
    def pack(self, values: Dict[str, int], payload: bytes = b"") -> bytes:
        """Bit-exact serializer (numpy bit ops; ``pack_reference`` is the
        per-bit oracle it is tested against)."""
        bits = np.zeros(self.header_bytes * 8, dtype=np.uint8)
        for f in self.fields:
            v = int(values.get(f.name, f.default))
            if v < 0 or v >= (1 << f.bits):
                raise ValueError(f"value {v} out of range for field {f.name} ({f.bits}b)")
            start = self._offsets[f.name]
            shifts = np.arange(f.bits - 1, -1, -1, dtype=np.uint64)
            bits[start:start + f.bits] = (
                (np.uint64(v) >> shifts) & np.uint64(1)).astype(np.uint8)
        return np.packbits(bits).tobytes() + payload

    def unpack(self, data: bytes) -> Dict[str, int]:
        """Bit-exact deserializer (numpy bit ops, inverse of :meth:`pack`)."""
        arr = np.frombuffer(data, dtype=np.uint8)
        if arr.size < self.header_bytes:
            raise ValueError(
                f"protocol {self.name!r}: header needs {self.header_bytes} "
                f"bytes, got {arr.size}")
        bits = np.unpackbits(arr[:self.header_bytes])
        out: Dict[str, int] = {}
        for f in self.fields:
            start = self._offsets[f.name]
            packed = np.packbits(bits[start:start + f.bits]).tobytes()
            # packbits zero-pads the tail byte on the right (low bits)
            out[f.name] = int.from_bytes(packed, "big") >> ((-f.bits) % 8)
        return out

    # ----------------------------------------------- per-bit reference oracle
    def pack_reference(self, values: Dict[str, int], payload: bytes = b"") -> bytes:
        """The original O(header_bits) per-bit serializer, kept as the oracle
        the vectorized :meth:`pack` is regression-tested against."""
        nbytes = self.header_bytes
        buf = np.zeros(nbytes, dtype=np.uint8)
        for f in self.fields:
            v = int(values.get(f.name, f.default))
            if v < 0 or v >= (1 << f.bits):
                raise ValueError(f"value {v} out of range for field {f.name} ({f.bits}b)")
            start = self._offsets[f.name]
            for b in range(f.bits):
                bit = (v >> (f.bits - 1 - b)) & 1
                pos = start + b
                if bit:
                    buf[pos // 8] |= 1 << (7 - pos % 8)
        return bytes(buf) + payload

    def unpack_reference(self, data: bytes) -> Dict[str, int]:
        """Per-bit deserializer oracle (see :meth:`pack_reference`)."""
        arr = np.frombuffer(data, dtype=np.uint8)
        out: Dict[str, int] = {}
        for f in self.fields:
            start = self._offsets[f.name]
            v = 0
            for b in range(f.bits):
                pos = start + b
                bit = (arr[pos // 8] >> (7 - pos % 8)) & 1
                v = (v << 1) | int(bit)
            out[f.name] = v
        return out

    def wire_bytes(self, payload_bytes: int) -> int:
        """Total on-wire size for a packet with the given payload."""
        return self.header_bytes + int(payload_bytes)


# --------------------------------------------------------------------------
# Stock protocols
# --------------------------------------------------------------------------

def ethernet_ipv4_udp() -> Protocol:
    """The general-purpose 42 B header stack the paper compresses away."""
    return Protocol(
        "ethernet_ipv4_udp",
        [
            Field("eth_dst", 48, semantic="routing_key"),
            Field("eth_src", 48, semantic="src_key"),
            Field("eth_type", 16),
            Field("ip_ver_ihl", 8),
            Field("ip_tos", 8, semantic="qos"),
            Field("ip_len", 16, semantic="length"),
            Field("ip_id", 16),
            Field("ip_flags_frag", 16),
            Field("ip_ttl", 8),
            Field("ip_proto", 8),
            Field("ip_csum", 16),
            Field("ip_src", 32),
            Field("ip_dst", 32),
            Field("udp_src", 16),
            Field("udp_dst", 16),
            Field("udp_len", 16),
            Field("udp_csum", 16),
        ],
    )


# --------------------------------------------------------------------------
# Protocol as a *space* (co-design, §III-A + Table II's adaptive headers)
# --------------------------------------------------------------------------

#: canonical per-field layout identity: (name, bits, semantic, default)
LayoutKey = Tuple[Tuple[str, int, Optional[str], int], ...]


def address_width_error(semantic: str, field_name: str, bits: int,
                        n_ports: int) -> Optional[str]:
    """One home for the address-sizing rule: a routing/src field of ``bits``
    must span ``n_ports``.  Returns the reason string, or None when wide
    enough.  Shared by ``ProtocolSpace.feasible`` (co-design stage-1 prune)
    and the scenario-build validation of fixed protocols, so the rule and
    its wording cannot drift."""
    if (1 << bits) < n_ports:
        need = max(1, (n_ports - 1).bit_length())
        return (f"{semantic} field {field_name!r} is {bits} bits "
                f"(addresses {1 << bits} ports) but n_ports={n_ports} "
                f"needs >= {need} bits")
    return None


def layout_key(protocol: Protocol) -> LayoutKey:
    """Canonical, hashable identity of a protocol *layout* (name-independent).

    Two protocols with the same field sequence — names, widths, semantics,
    defaults — get the same key regardless of the protocol's display name;
    the co-design DSE memoizes compiled ``ParserPlan``s/bindings on it."""
    return tuple((f.name, f.bits, f.semantic, f.default) for f in protocol.fields)


@dataclasses.dataclass(frozen=True)
class FieldSpec:
    """One bit-field whose width is a *choice set* rather than a point.

    ``bits`` lists the candidate widths the co-design search may pick from;
    a width of 0 drops the field entirely (optional fields).  A plain
    ``Field`` is the single-choice degenerate case (``FieldSpec.fixed``).
    """

    name: str
    bits: Tuple[int, ...]
    semantic: Optional[str] = None
    default: int = 0

    def __post_init__(self):
        choices = tuple(int(b) for b in self.bits)
        if not choices:
            raise ValueError(f"field spec {self.name!r} has no width choices")
        if len(set(choices)) != len(choices):
            raise ValueError(f"field spec {self.name!r}: duplicate width choices {choices}")
        for b in choices:
            if b < 0 or b > 64:
                raise ValueError(
                    f"field spec {self.name!r}: widths must be in [0, 64], got {b}")
        if max(choices) == 0:
            raise ValueError(
                f"field spec {self.name!r} is always dropped (all widths 0); omit it")
        object.__setattr__(self, "bits", choices)

    @staticmethod
    def fixed(field: Field) -> "FieldSpec":
        """Capture a concrete ``Field`` as a single-choice spec."""
        return FieldSpec(field.name, (field.bits,), field.semantic, field.default)

    @property
    def searchable(self) -> bool:
        return len(self.bits) > 1


class ProtocolSpace:
    """A protocol as a *search space*: per-field width choices.

    Where ``Protocol`` is one point (the classic DSL), a ``ProtocolSpace``
    spans the joint layout space the paper's co-design explores: every field
    carries a finite width choice set (0 = drop the field), ``decode`` lowers
    one width assignment to a concrete ``Protocol``, ``enumerate`` walks the
    whole space, and ``feasible`` applies the stage-1 static rules (the
    routing/src key must address every port, a variable payload needs a
    length field wide enough for the largest packet, retransmission needs a
    sequence number).  ``dims()`` is what the DSE splices into the NSGA-II
    genome next to the architecture genes.
    """

    def __init__(self, name: str, fields: Sequence[FieldSpec]):
        fields = tuple(f if isinstance(f, FieldSpec) else FieldSpec.fixed(f)
                       for f in fields)
        names = [f.name for f in fields]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate field names in protocol space {name!r}")
        if not fields:
            raise ValueError(f"protocol space {name!r} has no fields")
        self.name = name
        self.fields: Tuple[FieldSpec, ...] = tuple(fields)
        self._by_name: Dict[str, FieldSpec] = {f.name: f for f in fields}

    # ------------------------------------------------------------------ meta
    def field(self, name: str) -> FieldSpec:
        return self._by_name[name]

    def fields_by_semantic(self, semantic: str) -> List[FieldSpec]:
        return [f for f in self.fields if f.semantic == semantic]

    def dims(self) -> Tuple[Tuple[str, Tuple[int, ...]], ...]:
        """(field name, width choices) per field, in layout order — the
        protocol genes a ``DesignSpace`` splices into the search genome."""
        return tuple((f.name, f.bits) for f in self.fields)

    def size(self) -> int:
        n = 1
        for f in self.fields:
            n *= len(f.bits)
        return n

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"ProtocolSpace({self.name!r}, {self.size()} layouts)"

    # ---------------------------------------------------------------- decode
    def _widths(self, widths: Union[Mapping[str, int], Sequence[int]]) -> Dict[str, int]:
        if isinstance(widths, Mapping):
            out = {f.name: int(widths[f.name]) for f in self.fields}
        else:
            if len(widths) != len(self.fields):
                raise ValueError(
                    f"protocol space {self.name!r} has {len(self.fields)} "
                    f"fields, got {len(widths)} widths")
            out = {f.name: int(w) for f, w in zip(self.fields, widths)}
        for f in self.fields:
            if out[f.name] not in f.bits:
                raise ValueError(
                    f"field {f.name!r}: width {out[f.name]} not among the "
                    f"choices {f.bits}")
        return out

    def decode(self, widths: Union[Mapping[str, int], Sequence[int]]) -> Protocol:
        """One width assignment -> a concrete ``Protocol`` (0-width fields
        dropped).  The decoded name encodes the layout, e.g.
        ``spac_hft/dst4-src4-len12``, so reports and golden snapshots carry
        the winning layout as a plain string."""
        w = self._widths(widths)
        kept = [(f, w[f.name]) for f in self.fields if w[f.name] > 0]
        tag = "-".join(f"{f.name}{bits}" for f, bits in kept) or "empty"
        return Protocol(
            f"{self.name}/{tag}",
            [Field(f.name, bits, f.semantic, f.default) for f, bits in kept])

    def layout_key(self, widths: Union[Mapping[str, int], Sequence[int]]) -> LayoutKey:
        """Canonical hashable key of one assignment (dropped fields elided) —
        equals ``layout_key(self.decode(widths))`` without building anything."""
        w = self._widths(widths)
        return tuple((f.name, w[f.name], f.semantic, f.default)
                     for f in self.fields if w[f.name] > 0)

    def max_widths(self) -> Dict[str, int]:
        """The widest point of the space (always bindable if any point is)."""
        return {f.name: max(f.bits) for f in self.fields}

    def enumerate(self) -> Iterator[Protocol]:
        """Every concrete layout of the space, row-major in choice order."""
        for combo in itertools.product(*(f.bits for f in self.fields)):
            yield self.decode(combo)

    # ----------------------------------------------------- static feasibility
    def feasible(
        self,
        widths: Union[Mapping[str, int], Sequence[int]],
        *,
        n_ports: Optional[int] = None,
        max_payload_bytes: Optional[int] = None,
        variable_payload: bool = False,
        needs_seq: bool = False,
    ) -> Optional[str]:
        """Stage-1 static feasibility of one layout; None = feasible, else a
        human-readable reason.

        Rules (paper §III-A): the routing key must exist and, with ``src_key``,
        be wide enough to address ``n_ports``; a variable-size payload needs a
        length field that can represent ``max_payload_bytes``; retransmission
        (``needs_seq``) requires a sequence-number field.
        """
        w = self._widths(widths)
        for sem in ("routing_key", "src_key"):
            for f in self.fields_by_semantic(sem):
                bits = w[f.name]
                if bits == 0:
                    if sem == "routing_key":
                        return f"routing field {f.name!r} dropped (width 0)"
                    continue                       # src may be dropped
                if n_ports is not None:
                    err = address_width_error(sem, f.name, bits, n_ports)
                    if err is not None:
                        return err
        if not self.fields_by_semantic("routing_key"):
            return "protocol space has no routing_key field"
        len_fields = self.fields_by_semantic("length")
        len_bits = max((w[f.name] for f in len_fields), default=0)
        if variable_payload and len_bits == 0:
            return "variable-size payload needs a length field (all dropped)"
        if len_bits > 0 and max_payload_bytes is not None \
                and (1 << len_bits) - 1 < max_payload_bytes:
            return (f"length field is {len_bits} bits (max "
                    f"{(1 << len_bits) - 1}) but the trace's max payload is "
                    f"{max_payload_bytes} B")
        if needs_seq:
            seq_bits = max((w[f.name] for f in self.fields_by_semantic("seq_no")),
                           default=0)
            if seq_bits == 0:
                return "retransmission needs a seq_no field (dropped or absent)"
        return None


#: default co-design width menus (Table II territory: 4b addresses up to the
#: full 16b datacenter-scale keys; 0 drops the optional field entirely)
CODESIGN_ADDR_CHOICES: Tuple[int, ...] = (4, 8, 16)
CODESIGN_QOS_CHOICES: Tuple[int, ...] = (0, 2, 4)
CODESIGN_LENGTH_CHOICES: Tuple[int, ...] = (0, 6, 12, 16)
CODESIGN_SEQ_CHOICES: Tuple[int, ...] = (0, 8, 16)


def compressed_protocol_space(
    name: str = "spac_compressed",
    addr_bits: Union[int, Sequence[int]] = CODESIGN_ADDR_CHOICES,
    qos_bits: Union[int, Sequence[int]] = CODESIGN_QOS_CHOICES,
    length_bits: Union[int, Sequence[int]] = CODESIGN_LENGTH_CHOICES,
    seq_bits: Union[int, Sequence[int]] = CODESIGN_SEQ_CHOICES,
    extra_fields: Sequence[Field] = (),
) -> ProtocolSpace:
    """The ``compressed_protocol`` family as a space: every width parameter
    may be a scalar (pinned) or a choice sequence (searched).  ``dst`` and
    ``src`` draw from the same ``addr_bits`` menu but are independent genes
    (the paper's 2 B header uses 4b+4b; asymmetric splits are legal)."""
    def choices(v) -> Tuple[int, ...]:
        return tuple(int(x) for x in v) if isinstance(v, (tuple, list)) else (int(v),)

    addr, qos, ln, seq = (choices(v) for v in (addr_bits, qos_bits, length_bits, seq_bits))
    fields: List[FieldSpec] = [
        FieldSpec("dst", addr, "routing_key"),
        FieldSpec("src", addr, "src_key"),
    ]
    for fname, sem, cs in (("qos", "qos", qos), ("len", "length", ln),
                           ("seq", "seq_no", seq)):
        if cs != (0,):                 # pinned-to-0 == omitted (builder parity)
            fields.append(FieldSpec(fname, cs, sem))
    fields.extend(FieldSpec.fixed(f) for f in extra_fields)
    return ProtocolSpace(name, fields)


def compressed_protocol(
    name: str = "spac_compressed",
    addr_bits: int = 4,
    qos_bits: int = 2,
    length_bits: int = 6,
    seq_bits: int = 0,
    extra_fields: Sequence[Field] = (),
) -> Protocol:
    """SPAC/NetBlocks-style shrunk protocol (e.g. the 2 B underwater header).

    Default = 4b dst + 4b src + 2b qos + 6b length = 16 bits = 2 bytes,
    matching Table II's ``Avg Header = 2`` rows.
    """
    fields: List[Field] = [
        Field("dst", addr_bits, semantic="routing_key"),
        Field("src", addr_bits, semantic="src_key"),
    ]
    if qos_bits:
        fields.append(Field("qos", qos_bits, semantic="qos"))
    if length_bits:
        fields.append(Field("len", length_bits, semantic="length"))
    if seq_bits:
        fields.append(Field("seq", seq_bits, semantic="seq_no"))
    fields.extend(extra_fields)
    return Protocol(name, fields)
