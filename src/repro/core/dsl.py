"""SPAC protocol DSL — NetBlocks-compatible bit-level packet layout.

The paper (§III-A) uses NetBlocks syntax to declare custom protocols with
bit-level serialization.  A ``Protocol`` here is the single source of truth
consumed by

  * the switch parser generator (compile-time bit offsets, straddle detection,
    ``src/repro/switch/parser.py`` and the Pallas kernel generator in
    ``src/repro/kernels/parser``),
  * the network simulator (header/payload serialization delay),
  * the DSE engine (S_min, header overhead),
  * and the TPU comm layer (message layouts for gradient buckets / MoE
    dispatch payloads reuse the same Field/Protocol machinery).

Layout rules mirror NetBlocks: fields are packed MSB-first, back to back, with
no implicit alignment.  ``Protocol.compile(flit_bits)`` reproduces the paper's
"template metaprogramming" stage: it recursively computes the exact bit offset
of every field relative to flit boundaries and detects fields that straddle
word boundaries (which need state retention in hardware).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "Field",
    "Protocol",
    "FieldSlice",
    "ParserPlan",
    "ethernet_ipv4_udp",
    "compressed_protocol",
    "ETHERNET_HEADER_BYTES",
]

# A standard Ethernet+IPv4+UDP stack costs 42 B of header (the paper's §II-B
# "at least 42B" figure): 14 (Eth) + 20 (IPv4) + 8 (UDP).
ETHERNET_HEADER_BYTES = 42


@dataclasses.dataclass(frozen=True)
class Field:
    """One bit-field of a protocol header.

    ``bits`` is the exact width; ``semantic`` is the optional alias used by
    semantic binding (§III-A), e.g. ``routing_key`` / ``src_key`` / ``qos`` /
    ``length`` / ``seq_no`` / ``opcode``.
    """

    name: str
    bits: int
    semantic: Optional[str] = None
    default: int = 0

    def __post_init__(self):
        if self.bits <= 0 or self.bits > 64:
            raise ValueError(f"field {self.name!r}: bits must be in [1, 64], got {self.bits}")


@dataclasses.dataclass(frozen=True)
class FieldSlice:
    """Where a (piece of a) field lives relative to flit boundaries.

    ``word`` indexes the flit, ``hi``/``lo`` are bit positions inside the flit
    (MSB-first, ``hi`` inclusive, ``lo`` inclusive, hi >= lo).  A field that
    straddles a flit boundary compiles to >1 slice — the hardware then needs
    minimal state retention, exactly the paper's straddle handling.
    """

    field: str
    word: int
    hi: int
    lo: int
    dst_shift: int  # how far left (in bits) this piece sits inside the value


@dataclasses.dataclass(frozen=True)
class ParserPlan:
    """Compile-time parsing plan for a given flit width (§III-B.1)."""

    flit_bits: int
    header_bits: int
    slices: Tuple[FieldSlice, ...]
    straddling_fields: Tuple[str, ...]

    @property
    def header_flits(self) -> int:
        return -(-self.header_bits // self.flit_bits)

    def slices_for(self, name: str) -> List[FieldSlice]:
        return [s for s in self.slices if s.field == name]


class Protocol:
    """An ordered sequence of bit-fields followed by a variable payload."""

    def __init__(self, name: str, fields: Sequence[Field]):
        names = [f.name for f in fields]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate field names in protocol {name!r}")
        self.name = name
        self.fields: Tuple[Field, ...] = tuple(fields)
        self._by_name: Dict[str, Field] = {f.name: f for f in fields}
        offs: Dict[str, int] = {}
        off = 0
        for f in fields:
            offs[f.name] = off
            off += f.bits
        self._offsets = offs
        self.header_bits = off

    # ------------------------------------------------------------------ meta
    @property
    def header_bytes(self) -> int:
        return -(-self.header_bits // 8)

    def field(self, name: str) -> Field:
        return self._by_name[name]

    def offset_of(self, name: str) -> int:
        return self._offsets[name]

    def fields_by_semantic(self, semantic: str) -> List[Field]:
        return [f for f in self.fields if f.semantic == semantic]

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"Protocol({self.name!r}, header={self.header_bits}b)"

    # --------------------------------------------------------------- compile
    def compile(self, flit_bits: int) -> ParserPlan:
        """Lower the layout to flit-relative bit slices (paper §III-B.1)."""
        if flit_bits <= 0 or flit_bits % 8:
            raise ValueError("flit_bits must be a positive multiple of 8")
        slices: List[FieldSlice] = []
        straddlers: List[str] = []
        for f in self.fields:
            start = self._offsets[f.name]
            end = start + f.bits  # exclusive
            w0, w1 = start // flit_bits, (end - 1) // flit_bits
            if w0 != w1:
                straddlers.append(f.name)
            remaining = f.bits
            pos = start
            while remaining > 0:
                w = pos // flit_bits
                in_word = pos - w * flit_bits
                take = min(remaining, flit_bits - in_word)
                # MSB-first: bit 0 of the stream is the MSB of flit word 0.
                hi = flit_bits - 1 - in_word
                lo = hi - take + 1
                slices.append(
                    FieldSlice(field=f.name, word=w, hi=hi, lo=lo, dst_shift=remaining - take)
                )
                pos += take
                remaining -= take
        return ParserPlan(
            flit_bits=flit_bits,
            header_bits=self.header_bits,
            slices=tuple(slices),
            straddling_fields=tuple(straddlers),
        )

    # ------------------------------------------------- reference (de)serialize
    def pack(self, values: Dict[str, int], payload: bytes = b"") -> bytes:
        """Bit-exact serializer (numpy reference; the oracle for the parser)."""
        total_bits = self.header_bits
        nbytes = -(-total_bits // 8)
        buf = np.zeros(nbytes, dtype=np.uint8)
        for f in self.fields:
            v = int(values.get(f.name, f.default))
            if v < 0 or v >= (1 << f.bits):
                raise ValueError(f"value {v} out of range for field {f.name} ({f.bits}b)")
            start = self._offsets[f.name]
            for b in range(f.bits):
                bit = (v >> (f.bits - 1 - b)) & 1
                pos = start + b
                if bit:
                    buf[pos // 8] |= 1 << (7 - pos % 8)
        return bytes(buf) + payload

    def unpack(self, data: bytes) -> Dict[str, int]:
        """Bit-exact deserializer."""
        arr = np.frombuffer(data, dtype=np.uint8)
        out: Dict[str, int] = {}
        for f in self.fields:
            start = self._offsets[f.name]
            v = 0
            for b in range(f.bits):
                pos = start + b
                bit = (arr[pos // 8] >> (7 - pos % 8)) & 1
                v = (v << 1) | int(bit)
            out[f.name] = v
        return out

    def wire_bytes(self, payload_bytes: int) -> int:
        """Total on-wire size for a packet with the given payload."""
        return self.header_bytes + int(payload_bytes)


# --------------------------------------------------------------------------
# Stock protocols
# --------------------------------------------------------------------------

def ethernet_ipv4_udp() -> Protocol:
    """The general-purpose 42 B header stack the paper compresses away."""
    return Protocol(
        "ethernet_ipv4_udp",
        [
            Field("eth_dst", 48, semantic="routing_key"),
            Field("eth_src", 48, semantic="src_key"),
            Field("eth_type", 16),
            Field("ip_ver_ihl", 8),
            Field("ip_tos", 8, semantic="qos"),
            Field("ip_len", 16, semantic="length"),
            Field("ip_id", 16),
            Field("ip_flags_frag", 16),
            Field("ip_ttl", 8),
            Field("ip_proto", 8),
            Field("ip_csum", 16),
            Field("ip_src", 32),
            Field("ip_dst", 32),
            Field("udp_src", 16),
            Field("udp_dst", 16),
            Field("udp_len", 16),
            Field("udp_csum", 16),
        ],
    )


def compressed_protocol(
    name: str = "spac_compressed",
    addr_bits: int = 4,
    qos_bits: int = 2,
    length_bits: int = 6,
    seq_bits: int = 0,
    extra_fields: Sequence[Field] = (),
) -> Protocol:
    """SPAC/NetBlocks-style shrunk protocol (e.g. the 2 B underwater header).

    Default = 4b dst + 4b src + 2b qos + 6b length = 16 bits = 2 bytes,
    matching Table II's ``Avg Header = 2`` rows.
    """
    fields: List[Field] = [
        Field("dst", addr_bits, semantic="routing_key"),
        Field("src", addr_bits, semantic="src_key"),
    ]
    if qos_bits:
        fields.append(Field("qos", qos_bits, semantic="qos"))
    if length_bits:
        fields.append(Field("len", length_bits, semantic="length"))
    if seq_bits:
        fields.append(Field("seq", seq_bits, semantic="seq_no"))
    fields.extend(extra_fields)
    return Protocol(name, fields)
