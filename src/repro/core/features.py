"""Trace feature extraction (§IV-B): f = [I_burst, H_addr, S_min].

* ``I_burst`` — Index of Dispersion for Counts (IDC) over fixed windows; a
  congestion / burstiness proxy (Poisson arrivals → IDC ≈ 1, bursty ≫ 1).
* ``H_addr`` — Shannon entropy of destination addresses (bits), normalised
  variant also provided; indicates forwarding-cache effectiveness and
  incast-ness.
* ``S_min`` — minimum payload observed in the windowed trace; defines the
  worst-case arrival rate and the strict timing budget for the pipeline.

The same extractor is reused by the TPU comm layer, where "packets" are MoE
token dispatches (dst = expert id) or gradient buckets (dst = reduction peer):
expert-load dispersion ≡ IDC, routing entropy ≡ H_addr, token payload ≡ S_min.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

__all__ = ["TraceFeatures", "analyze", "idc", "address_entropy"]


@dataclasses.dataclass(frozen=True)
class TraceFeatures:
    i_burst: float          # IDC (index of dispersion for counts)
    h_addr: float           # dest-address entropy, bits
    h_addr_norm: float      # entropy / log2(n_dsts), in [0, 1]
    s_min: int              # min payload bytes
    s_mean: float           # mean payload bytes
    rate_pps: float         # mean packet rate (packets/s)
    peak_rate_pps: float    # max windowed packet rate
    incast_ratio: float     # max share of traffic landing on one destination
    n_packets: int
    duration_s: float

    def describe(self) -> str:
        return (
            f"I_burst={self.i_burst:.2f} H_addr={self.h_addr:.2f}b "
            f"(norm {self.h_addr_norm:.2f}) S_min={self.s_min}B "
            f"S_mean={self.s_mean:.1f}B rate={self.rate_pps:.3g}pps "
            f"peak={self.peak_rate_pps:.3g}pps incast={self.incast_ratio:.2f}"
        )


def idc(times_s: np.ndarray, window_s: Optional[float] = None) -> float:
    """Index of Dispersion for Counts: Var(N_w)/E(N_w) over fixed windows."""
    times_s = np.asarray(times_s, dtype=np.float64)
    if times_s.size < 2:
        return 1.0
    span = float(times_s.max() - times_s.min())
    if span <= 0:
        return 1.0
    if window_s is None:
        # aim for ~200 windows with >=1 expected packet each
        window_s = max(span / 200.0, span / max(times_s.size, 1) * 4)
    edges = np.arange(times_s.min(), times_s.max() + window_s, window_s)
    counts, _ = np.histogram(times_s, bins=edges)
    m = counts.mean()
    if m <= 0:
        return 1.0
    return float(counts.var() / m)


def address_entropy(dsts: np.ndarray, n_dsts: Optional[int] = None) -> float:
    dsts = np.asarray(dsts)
    if dsts.size == 0:
        return 0.0
    _, counts = np.unique(dsts, return_counts=True)
    p = counts / counts.sum()
    return float(-(p * np.log2(p)).sum())


def analyze(trace: "repro.traces.base.Trace", window_s: Optional[float] = None) -> TraceFeatures:  # noqa: F821
    """Characterise a trace into the Algorithm-1 feature vector f."""
    t = np.asarray(trace.time_s, dtype=np.float64)
    sizes = np.asarray(trace.payload_bytes)
    dsts = np.asarray(trace.dst)
    n = int(t.size)
    span = float(t.max() - t.min()) if n > 1 else 1e-9
    h = address_entropy(dsts)
    n_dsts = max(int(trace.n_ports), 2)
    # windowed peak rate
    if n > 1:
        w = window_s or max(span / 200.0, 1e-9)
        edges = np.arange(t.min(), t.max() + w, w)
        counts, _ = np.histogram(t, bins=edges)
        peak = float(counts.max() / w)
    else:
        peak = n / max(span, 1e-9)
    _, dst_counts = np.unique(dsts, return_counts=True)
    return TraceFeatures(
        i_burst=idc(t, window_s),
        h_addr=h,
        h_addr_norm=h / np.log2(n_dsts),
        s_min=int(sizes.min()) if n else 0,
        s_mean=float(sizes.mean()) if n else 0.0,
        rate_pps=n / max(span, 1e-9),
        peak_rate_pps=peak,
        incast_ratio=float(dst_counts.max() / max(dst_counts.sum(), 1)) if n else 0.0,
        n_packets=n,
        duration_s=span,
    )
