"""Architecture configuration space (§III-A "Architecture Configuration").

Users declare architectural *policies* — forwarding structure, VOQ
organisation, scheduler, bus width, buffer depth — either as explicit values
or as ``AUTO``, in which case the DSE engine (core/dse.py) infers the optimal
micro-architecture from trace characteristics.

The same dataclasses double as Algorithm 1's "Templates A": a concrete
``SwitchArch`` carries its initiation interval and pipeline depth, which
stage 1 uses for static timing pruning.

Custom in-network kernels (§III-B.5) are injected via ``CustomKernelSpec``,
which carries the paper's *performance interface* (latency and resource
boundaries) so the kernel participates in the DSE loop.
"""

from __future__ import annotations

import dataclasses
import enum
import itertools
from typing import Callable, List, Optional, Sequence, Tuple, Union

__all__ = [
    "AUTO",
    "ForwardTableKind",
    "VOQKind",
    "SchedulerKind",
    "CustomKernelSpec",
    "SwitchArch",
    "ArchRequest",
    "enumerate_candidates",
    "BUS_WIDTHS",
    "VOQ_DEPTHS",
]


class _Auto:
    _inst: Optional["_Auto"] = None

    def __new__(cls):
        if cls._inst is None:
            cls._inst = super().__new__(cls)
        return cls._inst

    def __repr__(self):
        return "AUTO"


AUTO = _Auto()


class ForwardTableKind(enum.Enum):
    FULL_LOOKUP = "full_lookup"      # direct-indexed, 1-cycle, O(2^addr_bits) memory
    MULTIBANK_HASH = "multibank_hash"  # banked hash table, handles long addresses


class VOQKind(enum.Enum):
    NXN = "nxn"        # fully partitioned N*N data queues (duplication on broadcast)
    SHARED = "shared"  # central buffer + pointer queues + pending bitmap


class SchedulerKind(enum.Enum):
    RR = "rr"          # rotating-priority, cheapest logic
    ISLIP = "islip"    # iterative request/grant/accept, ~100% uniform throughput
    EDRRM = "edrrm"    # 2-phase exhaustive dual round-robin, burst-friendly


#: legal bus widths (bits) — the DSE sweeps these (Table II column "Width")
BUS_WIDTHS: Tuple[int, ...] = (128, 256, 512, 1024)
#: candidate per-queue depths (packets) for stage-3 statistical sizing
VOQ_DEPTHS: Tuple[int, ...] = (16, 32, 64, 128, 256, 512, 1024, 2048)


@dataclasses.dataclass(frozen=True)
class CustomKernelSpec:
    """User compute kernel injected post-parsing (§III-B.5).

    ``ii``/``latency_cycles``/resources form the performance interface the
    user must declare so the DSE can account for the kernel; ``fn`` is the
    functional model (meta, data) -> (meta, data) used by the simulators.
    """

    name: str
    ii: int = 1
    latency_cycles: int = 4
    luts: int = 2000
    ffs: int = 2000
    brams: int = 0
    fn: Optional[Callable] = None


@dataclasses.dataclass(frozen=True)
class SwitchArch:
    """A fully concrete switch micro-architecture (one Algorithm-1 template)."""

    n_ports: int
    bus_bits: int
    fwd: ForwardTableKind
    voq: VOQKind
    sched: SchedulerKind
    voq_depth: int = 64            # packets per virtual queue (stage 3 resizes)
    hash_banks: int = 4
    hash_depth: int = 256          # entries per bank
    islip_iters: int = 2
    addr_bits: int = 8             # from the bound protocol's routing_key
    custom_kernels: Tuple[CustomKernelSpec, ...] = ()

    # ---------------------------------------------------------------- timing
    @property
    def ii(self) -> int:
        """Initiation interval (cycles/flit) of the datapath (paper §IV-A.2).

        The streaming datapath is II=1 by construction except MultiBankHash,
        whose bank-conflict resolution serialises colliding lookups — we model
        the *guaranteed* II here (worst case folded into the simulators).
        """
        return 1

    @property
    def pipeline_depth(self) -> int:
        """Deterministic pipeline latency in cycles (parser→…→deparser)."""
        parser = 3
        fwd = 1 if self.fwd is ForwardTableKind.FULL_LOOKUP else 3
        voq = 3 if self.voq is VOQKind.NXN else 4       # pointer mgmt overhead
        sched = {SchedulerKind.RR: 1, SchedulerKind.EDRRM: 2, SchedulerKind.ISLIP: 2}[self.sched]
        if self.sched is SchedulerKind.ISLIP:
            sched += self.islip_iters - 1
        deparser = 2
        kern = sum(k.latency_cycles for k in self.custom_kernels)
        return parser + fwd + voq + sched + deparser + kern

    def with_depth(self, depth: int) -> "SwitchArch":
        return dataclasses.replace(self, voq_depth=depth)

    def short(self) -> str:
        k = {"full_lookup": "Full", "multibank_hash": "MBH"}[self.fwd.value]
        v = {"nxn": "NxN", "shared": "Shared"}[self.voq.value]
        return f"{k}/{v}/{self.sched.value.upper()}@{self.bus_bits}b d{self.voq_depth}"


Policy = Union[_Auto, ForwardTableKind, VOQKind, SchedulerKind, int]


@dataclasses.dataclass(frozen=True)
class ArchRequest:
    """What the user writes in the DSL: any policy may be AUTO (§III-A)."""

    n_ports: int
    addr_bits: int
    bus_bits: Union[int, _Auto] = AUTO
    fwd: Union[ForwardTableKind, _Auto] = AUTO
    voq: Union[VOQKind, _Auto] = AUTO
    sched: Union[SchedulerKind, _Auto] = AUTO
    voq_depth: Union[int, _Auto] = AUTO
    custom_kernels: Tuple[CustomKernelSpec, ...] = ()


def _choices(value, options) -> Sequence:
    return list(options) if value is AUTO else [value]


def enumerate_candidates(req: ArchRequest) -> List[SwitchArch]:
    """Expand every AUTO policy into the concrete template set for the DSE."""
    out: List[SwitchArch] = []
    fwd_opts = _choices(req.fwd, list(ForwardTableKind))
    # FullLookup memory is 2^addr_bits * port entries: prune absurd address widths
    fwd_opts = [
        f for f in fwd_opts
        if not (f is ForwardTableKind.FULL_LOOKUP and req.addr_bits > 16)
    ] or [ForwardTableKind.MULTIBANK_HASH]
    for bus, fwd, voq, sched in itertools.product(
        _choices(req.bus_bits, BUS_WIDTHS),
        fwd_opts,
        _choices(req.voq, list(VOQKind)),
        _choices(req.sched, list(SchedulerKind)),
    ):
        depth = 64 if req.voq_depth is AUTO else req.voq_depth
        out.append(
            SwitchArch(
                n_ports=req.n_ports,
                bus_bits=bus,
                fwd=fwd,
                voq=voq,
                sched=sched,
                voq_depth=depth,
                addr_bits=req.addr_bits,
                custom_kernels=req.custom_kernels,
            )
        )
    return out
