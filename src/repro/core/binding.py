"""Semantic binding (§III-A): map protocol bit-fields to switch roles.

``routing_key`` is mandatory (the paper: "the protocol field designated for
routing must be specified, while other fields remain optional").  The binding
stage performs key-value matching over the protocol's semantic aliases and
emits a ``BoundProtocol`` — the analogue of the generated ``packet.hpp`` —
which downstream consumers (switch parser, netsim driver, Pallas kernel
generator) read instead of the raw protocol.  This is what decouples protocol
layout from switching logic: change the layout, re-bind, nothing downstream
changes.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

from .dsl import Field, ParserPlan, Protocol

__all__ = ["SemanticBinding", "BoundProtocol", "bind"]

#: semantics the switch understands.  routing_key is required.
KNOWN_SEMANTICS = ("routing_key", "src_key", "qos", "length", "seq_no", "opcode", "payload_tag")


@dataclasses.dataclass(frozen=True)
class SemanticBinding:
    """Explicit field-name overrides; fields left None are resolved by alias."""

    routing_key: Optional[str] = None
    src_key: Optional[str] = None
    qos: Optional[str] = None
    length: Optional[str] = None
    seq_no: Optional[str] = None
    opcode: Optional[str] = None
    payload_tag: Optional[str] = None


@dataclasses.dataclass(frozen=True)
class BoundProtocol:
    """A protocol + resolved semantic map + compiled parser plan.

    The single artifact handed to the switch generator (role of packet.hpp).
    """

    protocol: Protocol
    semantics: Dict[str, str]  # semantic -> field name
    plan: ParserPlan

    # convenience accessors -------------------------------------------------
    def _field(self, semantic: str) -> Field:
        try:
            return self.protocol.field(self.semantics[semantic])
        except KeyError as e:
            raise KeyError(f"protocol {self.protocol.name!r} has no bound {semantic!r}") from e

    @property
    def routing_field(self) -> Field:
        return self._field("routing_key")

    @property
    def src_field(self) -> Field:
        return self._field("src_key")

    @property
    def addr_bits(self) -> int:
        return self.routing_field.bits

    def has(self, semantic: str) -> bool:
        return semantic in self.semantics

    @property
    def header_bytes(self) -> int:
        return self.protocol.header_bytes

    def describe(self) -> str:
        lines = [f"BoundProtocol {self.protocol.name} ({self.protocol.header_bits} header bits)"]
        for sem, fname in sorted(self.semantics.items()):
            f = self.protocol.field(fname)
            lines.append(f"  {sem:12s} -> {fname} [{f.bits}b @ bit {self.protocol.offset_of(fname)}]")
        if self.plan.straddling_fields:
            lines.append(f"  straddlers @ {self.plan.flit_bits}b flits: {list(self.plan.straddling_fields)}")
        return "\n".join(lines)


def bind(
    protocol: Protocol,
    binding: Optional[SemanticBinding] = None,
    *,
    flit_bits: int = 256,
) -> BoundProtocol:
    """Resolve semantics by explicit override first, then by field alias."""
    if binding is None:
        binding = SemanticBinding()
    resolved: Dict[str, str] = {}
    for sem in KNOWN_SEMANTICS:
        override = getattr(binding, sem, None)
        if override is not None:
            if override not in {f.name for f in protocol.fields}:
                raise ValueError(f"binding {sem}={override!r}: no such field in {protocol.name!r}")
            resolved[sem] = override
            continue
        aliased = protocol.fields_by_semantic(sem)
        if len(aliased) > 1:
            raise ValueError(
                f"protocol {protocol.name!r}: multiple fields alias {sem!r}: "
                f"{[f.name for f in aliased]} — disambiguate via SemanticBinding"
            )
        if aliased:
            resolved[sem] = aliased[0].name
    if "routing_key" not in resolved:
        raise ValueError(
            f"protocol {protocol.name!r}: routing_key is mandatory (alias a field with "
            "semantic='routing_key' or pass SemanticBinding(routing_key=...))"
        )
    return BoundProtocol(protocol=protocol, semantics=resolved, plan=protocol.compile(flit_bits))
