"""Generational trace-aware DSE search: NSGA-II over the fidelity ladder.

``run_dse`` historically *enumerated* ``DSEProblem.candidates()`` — fine for
Table II's small grids, useless once the joint protocol x architecture space
explodes combinatorially.  This module adds a generational multi-objective
engine that rides the batched surrogate fan-out from PR 1-3:

  * A problem exposes a *parameterized* design space through ``space()``
    (per-dimension ranges, ``DesignSpace``) and materialises one point with
    ``decode(assignment)``.  ``SwitchDSEProblem`` and ``CommDSEProblem``
    implement both.
  * ``NSGA2Search`` is a pure ask/tell NSGA-II over integer genomes: Deb-rule
    constrained non-dominated sort + crowding distance, uniform crossover,
    reset mutation — pure NumPy on a seeded ``np.random.Generator``, no
    ambient state, so the same seed is bit-reproducible and every bit of
    engine state round-trips through ``state()``/``from_state()``.
  * ``SearchDriver`` binds an engine to a ``DSEProblem``: decodes genomes,
    applies stage-1 static-timing pruning, folds the SLA into constraint
    domination, dedupes phenotypes so the surrogate only ever sees unique
    candidates, and checkpoints generation state via
    ``repro.checkpoint.store`` (population, archive, RNG state, generation).
  * Each generation's un-evaluated population fans through **one**
    ``surrogate_batch`` call; the campaign runner drives several drivers in
    generational lockstep so scenarios sharing a (trace, bound protocol)
    share one jitted call per generation, exactly like exhaustive stage 2.
  * The final archive feeds the unchanged ``stage3_size``/``stage4_verify``
    ladder, so verification semantics are engine-independent.

Convergence: the archive hypervolume (``hypervolume_2d`` against a reference
point fixed at the first feasible generation) must improve by at least
``hv_tol`` (relative) or the plateau counter ticks; ``patience`` consecutive
plateau generations stop the search early.
"""

from __future__ import annotations

import dataclasses
import itertools
import math
import warnings
from typing import (Any, Dict, Iterator, List, Mapping, Optional, Sequence,
                    Tuple)

import numpy as np

from .dse import SLA, StageLog, SurrogateResult, check_index_aligned
from .pareto import hypervolume_2d, pareto_front

__all__ = [
    "Dim",
    "DesignSpace",
    "SearchSpec",
    "NSGA2Search",
    "SearchDriver",
    "SearchOutcome",
    "SpaceEvaluation",
    "run_search",
    "evaluate_space",
    "constrained_non_dominated_sort",
    "crowding_distance",
    "save_search_state",
    "load_search_state",
    "remesh_search_state",
]

Genome = Tuple[int, ...]

#: algorithm vocabulary shared by ``SearchSpec``, the Scenario spec and the
#: ``spac run --search`` flag
SEARCH_ALGORITHMS = ("nsga2",)


# --------------------------------------------------------------------------
# design space
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Dim:
    """One searchable dimension: a name and its (finite, ordered) choices."""

    name: str
    choices: Tuple[Any, ...]

    def __post_init__(self):
        if not self.choices:
            raise ValueError(f"dimension {self.name!r} has no choices")


@dataclasses.dataclass(frozen=True)
class DesignSpace:
    """Per-dimension ranges; a genome is one index per dimension."""

    dims: Tuple[Dim, ...]

    @property
    def n_dims(self) -> int:
        return len(self.dims)

    def size(self) -> int:
        n = 1
        for d in self.dims:
            n *= len(d.choices)
        return n

    def cardinalities(self) -> Tuple[int, ...]:
        return tuple(len(d.choices) for d in self.dims)

    def assignment(self, genome: Sequence[int]) -> Dict[str, Any]:
        return {d.name: d.choices[g] for d, g in zip(self.dims, genome)}

    def genomes(self) -> Iterator[Genome]:
        """Row-major full enumeration (the exhaustive baseline)."""
        return itertools.product(*(range(len(d.choices)) for d in self.dims))

    def random_genome(self, rng: np.random.Generator) -> Genome:
        return tuple(int(rng.integers(len(d.choices))) for d in self.dims)

    def signature(self) -> Dict[str, int]:
        """Checkpoint-compat identity: dimension names and cardinalities."""
        return {d.name: len(d.choices) for d in self.dims}


# --------------------------------------------------------------------------
# the search spec
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SearchSpec:
    """Serializable knobs of the generational engine (``Scenario.search``)."""

    algorithm: str = "nsga2"
    population: int = 48
    generations: int = 12
    seed: int = 0
    mutation_rate: float = 0.15
    crossover_rate: float = 0.9
    hv_tol: float = 1e-3          # relative hypervolume plateau threshold
    patience: int = 3             # plateau generations before early stop
    max_evaluations: Optional[int] = None   # hard cap on genomes evaluated
    checkpoint_dir: Optional[str] = None    # save state here every generation

    def __post_init__(self):
        if self.algorithm not in SEARCH_ALGORITHMS:
            raise ValueError(f"unknown search algorithm {self.algorithm!r}; "
                             f"known: {SEARCH_ALGORITHMS}")
        if self.population < 2:
            raise ValueError("population must be >= 2")
        if self.generations < 1:
            raise ValueError("generations must be >= 1")
        for name in ("mutation_rate", "crossover_rate"):
            v = getattr(self, name)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{name} must be in [0, 1], got {v}")
        if self.patience < 1:
            raise ValueError("patience must be >= 1")

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @staticmethod
    def from_dict(d: Mapping[str, Any]) -> "SearchSpec":
        return SearchSpec(**dict(d))


# --------------------------------------------------------------------------
# NSGA-II primitives (pure NumPy)
# --------------------------------------------------------------------------

def constrained_non_dominated_sort(
    objs: np.ndarray, violation: Optional[np.ndarray] = None
) -> np.ndarray:
    """Deb-rule fast non-dominated sort -> rank per row (0 = best front).

    A feasible point (``violation == 0``) dominates every infeasible one;
    two infeasible points compare on violation alone; two feasible points
    compare by Pareto dominance (all objectives minimised).
    """
    objs = np.asarray(objs, dtype=float).reshape(len(objs), -1)
    n = len(objs)
    if n == 0:
        return np.zeros(0, dtype=int)
    v = (np.zeros(n) if violation is None
         else np.asarray(violation, dtype=float))
    le = np.all(objs[:, None, :] <= objs[None, :, :], axis=2)
    lt = np.any(objs[:, None, :] < objs[None, :, :], axis=2)
    feas = v == 0.0
    dominates = (
        (feas[:, None] & ~feas[None, :])
        | (~feas[:, None] & ~feas[None, :] & (v[:, None] < v[None, :]))
        | (feas[:, None] & feas[None, :] & le & lt)
    )
    np.fill_diagonal(dominates, False)
    ranks = np.full(n, -1, dtype=int)
    remaining = np.ones(n, dtype=bool)
    counts = dominates.sum(axis=0).astype(int)
    r = 0
    while remaining.any():
        front = remaining & (counts == 0)
        if not front.any():          # unreachable with a strict partial order
            front = remaining
        ranks[front] = r
        remaining &= ~front
        counts = counts - dominates[front].sum(axis=0)
        r += 1
    return ranks


def crowding_distance(objs: np.ndarray) -> np.ndarray:
    """NSGA-II crowding distance within one front (minimisation)."""
    objs = np.asarray(objs, dtype=float).reshape(len(objs), -1)
    n, m = objs.shape
    if n <= 2:
        return np.full(n, np.inf)
    d = np.zeros(n)
    for k in range(m):
        col = np.nan_to_num(objs[:, k], posinf=1e300, neginf=-1e300)
        # one tiny [population] sort per objective, not an [m]-event sort in
        # the evaluation hot path — the blessed exception to the rule
        order = np.argsort(col, kind="stable")   # spaclint: disable=SPAC208
        d[order[0]] = d[order[-1]] = np.inf
        span = col[order[-1]] - col[order[0]]
        if span <= 0.0:
            continue
        d[order[1:-1]] += (col[order[2:]] - col[order[:-2]]) / span
    return d


# --------------------------------------------------------------------------
# the generational engine
# --------------------------------------------------------------------------

class NSGA2Search:
    """Seeded ask/tell NSGA-II over a discrete ``DesignSpace``.

    The engine never touches the problem: ``ask()`` yields the genomes of the
    current population that still lack objectives, ``tell()`` supplies
    ``(objectives, constraint-violation)`` per genome and advances one
    generation (environmental selection over parents + offspring, then
    tournament / uniform-crossover / reset-mutation breeding).  All
    randomness flows through one seeded ``np.random.Generator`` so equal
    seeds are bit-identical, and ``state()``/``from_state()`` round-trip the
    whole engine — population, archive cache, RNG state, generation index —
    for checkpoint/resume.
    """

    def __init__(self, space: DesignSpace, spec: SearchSpec):
        self.space = space
        self.spec = spec
        self.rng = np.random.default_rng(spec.seed)
        self.generation = 0
        self.done = False
        self.n_asked = 0            # genomes sent for evaluation (budget metric)
        #: genome -> ((obj1, obj2), violation); the full evaluation archive
        self.cache: Dict[Genome, Tuple[Tuple[float, float], float]] = {}
        self.parents: List[Genome] = []
        self.pending: List[Genome] = self._seed_population()
        self.ref: Optional[Tuple[float, float]] = None
        self.hv_history: List[float] = []
        self._plateau = 0

    # ----------------------------------------------------------- population
    def _seed_population(self) -> List[Genome]:
        target = min(self.spec.population, self.space.size())
        pop: List[Genome] = []
        seen: set = set()
        attempts = 0
        while len(pop) < target and attempts < 50 * self.spec.population:
            attempts += 1
            g = self.space.random_genome(self.rng)
            if g not in seen:
                seen.add(g)
                pop.append(g)
        return pop

    # ------------------------------------------------------------- ask/tell
    def ask(self) -> List[Genome]:
        """Genomes of the current population that need objectives."""
        if self.done:
            return []
        pend = [g for g in self.pending if g not in self.cache]
        if self.spec.max_evaluations is not None:
            room = max(self.spec.max_evaluations - self.n_asked, 0)
            if len(pend) > room:     # hard budget: drop the un-evaluable tail
                kept = set(pend[:room])
                self.pending = [g for g in self.pending
                                if g in self.cache or g in kept]
                pend = pend[:room]
        self.n_asked += len(pend)
        return pend

    def tell(self, results: Mapping[Genome, Tuple[Sequence[float], float]]) -> None:
        """Record objectives for the asked genomes and advance one generation."""
        if self.done:
            raise RuntimeError("search already finished")
        for g, (objs, viol) in results.items():
            self.cache[tuple(g)] = (
                (float(objs[0]), float(objs[1])), float(viol))
        missing = [g for g in self.pending if g not in self.cache]
        if missing:
            raise ValueError(
                f"tell() is missing objectives for {len(missing)} of "
                f"{len(self.pending)} pending genome(s)")
        pset = set(self.parents)
        combined = self.parents + [g for g in self.pending if g not in pset]
        self.parents = self._select(combined,
                                    min(self.spec.population, len(combined)))
        self._update_metrics()
        self.generation += 1
        if self.generation >= self.spec.generations:
            self.done = True
        if (self.spec.max_evaluations is not None
                and self.n_asked >= self.spec.max_evaluations):
            self.done = True
        if self._plateau >= self.spec.patience:
            self.done = True
        self.pending = [] if self.done else self._breed()

    # ------------------------------------------------------------ selection
    def _rank_crowd(self, pool: Sequence[Genome]):
        objs = np.asarray([self.cache[g][0] for g in pool], dtype=float)
        viol = np.asarray([self.cache[g][1] for g in pool], dtype=float)
        ranks = constrained_non_dominated_sort(objs, viol)
        crowd = np.zeros(len(pool))
        for r in sorted(set(ranks.tolist())):
            idx = np.where(ranks == r)[0]
            crowd[idx] = crowding_distance(objs[idx])
        return objs, ranks, crowd

    def _select(self, pool: List[Genome], k: int) -> List[Genome]:
        if not pool:
            return []
        objs, ranks, _ = self._rank_crowd(pool)
        chosen: List[int] = []
        for r in range(int(ranks.max()) + 1):
            idx = [i for i in range(len(pool)) if ranks[i] == r]
            if len(chosen) + len(idx) <= k:
                chosen.extend(idx)
            else:
                room = k - len(chosen)
                crowd = crowding_distance(objs[idx])
                order = sorted(range(len(idx)),
                               key=lambda t: (-crowd[t], idx[t]))
                chosen.extend(idx[t] for t in order[:room])
                break
            if len(chosen) == k:
                break
        return [pool[i] for i in chosen]

    def _tournament(self, n: int, ranks: np.ndarray, crowd: np.ndarray) -> int:
        i, j = (int(x) for x in self.rng.integers(n, size=2))
        if ranks[i] != ranks[j]:
            return i if ranks[i] < ranks[j] else j
        if crowd[i] != crowd[j]:
            return i if crowd[i] > crowd[j] else j
        return min(i, j)

    def _breed(self) -> List[Genome]:
        pool = self.parents
        if not pool:                 # nothing evaluable survived: reseed
            return self._seed_population()
        _, ranks, crowd = self._rank_crowd(pool)
        cards = self.space.cardinalities()
        out: List[Genome] = []
        seen: set = set()
        attempts = 0
        while len(out) < self.spec.population and attempts < 50 * self.spec.population:
            attempts += 1
            a = pool[self._tournament(len(pool), ranks, crowd)]
            b = pool[self._tournament(len(pool), ranks, crowd)]
            child = list(a)
            if self.rng.random() < self.spec.crossover_rate:
                mask = self.rng.random(len(cards)) < 0.5
                child = [bg if m else ag for ag, bg, m in zip(a, b, mask)]
            for d, card in enumerate(cards):
                if card > 1 and self.rng.random() < self.spec.mutation_rate:
                    shift = 1 + int(self.rng.integers(card - 1))
                    child[d] = (child[d] + shift) % card
            g = tuple(int(x) for x in child)
            if g not in seen:
                seen.add(g)
                out.append(g)
        return out

    # -------------------------------------------------------------- archive
    def archive(self) -> List[Genome]:
        """Every evaluated, fully feasible genome (insertion order)."""
        return [g for g, (_, v) in self.cache.items() if v == 0.0]

    def front(self) -> List[Tuple[Genome, Tuple[float, float]]]:
        """Non-dominated feasible archive, deterministically ordered."""
        items = [(g, self.cache[g][0]) for g in self.archive()]
        if not items:
            return []
        fr = pareto_front(items, key=lambda gv: gv[1])
        return sorted(fr, key=lambda gv: (gv[1], gv[0]))

    def hypervolume(self) -> float:
        if self.ref is None:
            return 0.0
        pts = [o for _, o in self.front()]
        return hypervolume_2d(pts, self.ref) if pts else 0.0

    def _update_metrics(self) -> None:
        if self.ref is None:
            arch = self.archive()
            if arch:
                objs = np.asarray([self.cache[g][0] for g in arch], float)
                finite = objs[np.all(np.isfinite(objs), axis=1)]
                if finite.size:
                    # fixed once, so archive hypervolume is monotone from here
                    self.ref = tuple(float(x)
                                     for x in finite.max(axis=0) * 1.1 + 1e-9)
        hv = self.hypervolume()
        # the plateau clock starts only once a feasible point fixed the
        # reference: 0 -> 0 before that is "nothing found yet", not
        # convergence, and must not stop a search still hunting feasibility
        if self.ref is not None and self.hv_history:
            prev = self.hv_history[-1]
            rel = (hv - prev) / max(abs(prev), 1e-12)
            self._plateau = self._plateau + 1 if rel < self.spec.hv_tol else 0
        self.hv_history.append(hv)

    # ----------------------------------------------------- state round-trip
    _STATE_KEYS = ("parents", "pending", "cache_genomes", "cache_objs",
                   "cache_violation")

    def state(self) -> Tuple[Dict[str, np.ndarray], Dict[str, Any]]:
        """(array tree, JSON-able extra) capturing every bit of the engine."""
        nd = self.space.n_dims

        def as_arr(gs: Sequence[Genome]) -> np.ndarray:
            return np.asarray([list(g) for g in gs],
                              dtype=np.int64).reshape(len(gs), nd)

        cg = list(self.cache)
        tree = {
            "parents": as_arr(self.parents),
            "pending": as_arr(self.pending),
            "cache_genomes": as_arr(cg),
            "cache_objs": np.asarray([self.cache[g][0] for g in cg],
                                     dtype=np.float64).reshape(len(cg), 2),
            "cache_violation": np.asarray([self.cache[g][1] for g in cg],
                                          dtype=np.float64),
        }
        extra = {
            "generation": self.generation,
            "done": self.done,
            "n_asked": self.n_asked,
            "plateau": self._plateau,
            "ref": list(self.ref) if self.ref is not None else None,
            "hv_history": [float(h) for h in self.hv_history],
            "rng_state": self.rng.bit_generator.state,
            "spec": self.spec.to_dict(),
            "space": self.space.signature(),
        }
        return tree, extra

    @classmethod
    def from_state(cls, space: DesignSpace, spec: SearchSpec,
                   tree: Mapping[str, np.ndarray],
                   extra: Mapping[str, Any]) -> "NSGA2Search":
        if dict(extra["spec"]) != spec.to_dict():
            raise ValueError(
                "checkpointed SearchSpec differs from the requested one: "
                f"{extra['spec']} vs {spec.to_dict()}")
        if dict(extra["space"]) != space.signature():
            raise ValueError(
                "checkpointed design space differs from the problem's: "
                f"{extra['space']} vs {space.signature()}")
        eng = cls.__new__(cls)
        eng.space, eng.spec = space, spec
        eng.rng = np.random.default_rng()
        eng.rng.bit_generator.state = extra["rng_state"]
        eng.generation = int(extra["generation"])
        eng.done = bool(extra["done"])
        eng.n_asked = int(extra["n_asked"])
        eng._plateau = int(extra["plateau"])
        eng.ref = (tuple(float(x) for x in extra["ref"])
                   if extra["ref"] is not None else None)
        eng.hv_history = [float(h) for h in extra["hv_history"]]

        def as_gs(a) -> List[Genome]:
            return [tuple(int(x) for x in row)
                    for row in np.asarray(a).reshape(-1, space.n_dims)]

        eng.parents = as_gs(tree["parents"])
        eng.pending = as_gs(tree["pending"])
        cg = as_gs(tree["cache_genomes"])
        objs = np.asarray(tree["cache_objs"], float).reshape(len(cg), 2)
        viol = np.asarray(tree["cache_violation"], float).reshape(len(cg))
        eng.cache = {g: ((float(o[0]), float(o[1])), float(v))
                     for g, o, v in zip(cg, objs, viol)}
        return eng


# --------------------------------------------------------------------------
# checkpoint plumbing (repro.checkpoint.store)
# --------------------------------------------------------------------------

def save_search_state(ckpt_dir: str, engine: NSGA2Search, mesh=None) -> str:
    """Persist one generation of search state (``step_<generation>``).

    ``mesh`` (an optional ``launch.mesh.MeshSpec``) is stamped into the
    manifest purely as provenance: the state arrays are host-resident and
    mesh-agnostic, so a checkpoint written on N devices restores on M —
    restore never reads the stamp (see :func:`remesh_search_state`)."""
    from repro.checkpoint import store      # lazy: store imports jax
    tree, extra = engine.state()
    if mesh is not None:
        extra = dict(extra, mesh=mesh.to_dict())
    return store.save(ckpt_dir, engine.generation, tree, extra=extra)


def load_search_state(ckpt_dir: str, space: DesignSpace,
                      spec: SearchSpec) -> Optional[NSGA2Search]:
    """Latest checkpointed engine under ``ckpt_dir``, or None if empty.

    Deliberately ignores any ``mesh`` stamp in the manifest: search state is
    mesh-shape-independent, so resuming on a different device count is the
    normal path, not an error."""
    from repro.checkpoint import store
    step = store.latest_step(ckpt_dir)
    if step is None:
        return None
    template = {k: np.zeros((0,), np.int64) for k in NSGA2Search._STATE_KEYS}
    tree, manifest = store.restore(ckpt_dir, step, template=template)
    return NSGA2Search.from_state(space, spec, tree, manifest["extra"])


def remesh_search_state(tree: Mapping[str, np.ndarray],
                        extra: Mapping[str, Any], mesh=None):
    """The ``runtime.elastic.remesh`` analogue for search state.

    Search state lives on the host (pure NumPy) and contains nothing shaped
    by the mesh — population, eval cache, RNG stream and hv history are all
    device-count-independent — so remeshing is the identity on the arrays
    and only restamps the provenance ``mesh`` entry.  ``remesh(state, N→M→N)
    == state`` by construction; ``tests/test_mesh_properties.py`` holds the
    codebase to it."""
    from repro.launch.mesh import MeshSpec
    mesh = MeshSpec.coerce(mesh)
    extra = {k: v for k, v in extra.items() if k != "mesh"}
    if mesh is not None:
        extra["mesh"] = mesh.to_dict()
    return dict(tree), extra


# --------------------------------------------------------------------------
# the problem-facing driver
# --------------------------------------------------------------------------

class SearchDriver:
    """Binds an ``NSGA2Search`` to a ``DSEProblem`` at candidate level.

    ``ask_candidates()`` decodes the engine's pending genomes, applies the
    stage-1 static-timing prune (infeasible genomes never reach the
    surrogate) and dedupes phenotypes (distinct genomes with inert genes can
    decode to one micro-architecture); ``tell_candidates()`` maps the batched
    surrogate results back to genomes, folds the SLA into a constraint-
    violation scalar and advances the engine one generation, checkpointing
    if a directory is configured.  The campaign runner drives several
    instances in generational lockstep so every scenario's population rides
    one batched call per generation.
    """

    def __init__(self, problem, spec: SearchSpec, sla: SLA, *,
                 delta: float = 0.2, checkpoint_dir: Optional[str] = None,
                 resume: bool = False):
        space = problem.space()
        if space is None:
            raise ValueError(
                f"{type(problem).__name__} does not define a design space; "
                "implement space()/decode() to use a generational search")
        self.problem = problem
        self.spec = spec
        self.sla = sla
        self.delta = delta
        self.space = space
        self.checkpoint_dir = (checkpoint_dir if checkpoint_dir is not None
                               else spec.checkpoint_dir)
        self.resumed = False
        engine = None
        if resume:
            if not self.checkpoint_dir:
                raise ValueError("resume=True needs a checkpoint directory "
                                 "(argument or SearchSpec.checkpoint_dir)")
            engine = load_search_state(self.checkpoint_dir, space, spec)
            self.resumed = engine is not None
            if engine is None:
                # a mistyped/relocated directory must not silently restart a
                # long campaign from generation 0
                warnings.warn(
                    f"resume=True but no search checkpoint under "
                    f"{self.checkpoint_dir!r}; starting a fresh search",
                    RuntimeWarning, stacklevel=3)
        self.engine = engine if engine is not None else NSGA2Search(space, spec)
        self._decoded: Dict[Genome, Any] = {}
        self._static_ok: Dict[Genome, bool] = {}
        self._sr: Dict[Any, SurrogateResult] = {}     # phenotype-level cache
        self.surrogate_rows = 0                       # actual surrogate cost
        self._pending_genomes: List[Genome] = []
        self._pending_cands: List[Any] = []

    @property
    def done(self) -> bool:
        return self.engine.done

    # -------------------------------------------------------------- decode
    def _decode(self, g: Genome):
        c = self._decoded.get(g)
        if c is None:
            c = self.problem.decode(self.space.assignment(g))
            self._decoded[g] = c
            t_proc, t_arrival = self.problem.static_timing(c)
            self._static_ok[g] = t_proc <= (1.0 + self.delta) * t_arrival
        return c

    def _violation(self, sr: SurrogateResult) -> float:
        v = 0.0
        p99 = sr.p(99)
        if math.isfinite(self.sla.p99_latency_ns) and p99 > self.sla.p99_latency_ns:
            v += p99 / self.sla.p99_latency_ns - 1.0
        if sr.throughput_gbps < self.sla.min_throughput_gbps:
            v += ((self.sla.min_throughput_gbps - sr.throughput_gbps)
                  / max(self.sla.min_throughput_gbps, 1e-12))
        return v

    # ------------------------------------------------------------ ask/tell
    def ask_candidates(self) -> List[Any]:
        """Unique, static-feasible, un-cached candidates of this generation."""
        genomes = self.engine.ask()
        self._pending_genomes = genomes
        cands: List[Any] = []
        seen: set = set()
        for g in genomes:
            c = self._decode(g)
            if not self._static_ok[g]:
                continue                       # told as infeasible, no eval
            if c in self._sr or c in seen:
                continue                       # phenotype cache hit
            seen.add(c)
            cands.append(c)
        self._pending_cands = cands
        return list(cands)

    def tell_candidates(self, results: Sequence[SurrogateResult]) -> None:
        """Map batched surrogate results back to genomes; advance one gen."""
        check_index_aligned(self.problem, results, self._pending_cands,
                            "surrogate_batch")
        for c, sr in zip(self._pending_cands, results):
            self._sr[c] = sr
        self.surrogate_rows += len(self._pending_cands)
        tell: Dict[Genome, Tuple[Tuple[float, float], float]] = {}
        for g in self._pending_genomes:
            c = self._decoded[g]
            if not self._static_ok[g]:
                tell[g] = ((math.inf, math.inf), math.inf)
                continue
            sr = self._sr[c]
            objs = self.problem.surrogate_objectives(c, sr)
            tell[g] = ((float(objs[0]), float(objs[1])), self._violation(sr))
        self._pending_genomes, self._pending_cands = [], []
        self.engine.tell(tell)
        if self.checkpoint_dir:
            save_search_state(self.checkpoint_dir, self.engine,
                              mesh=getattr(self.problem, "mesh_spec", None))

    # ------------------------------------------------------------ finalize
    def finalize(self) -> "SearchOutcome":
        """Archive -> deduped ``(candidate, SurrogateResult)`` list + log.

        Resumed runs re-evaluate archive members whose surrogate results are
        not in this process's phenotype cache — one batched call, and the
        deterministic engines reproduce the original numbers exactly.
        """
        eng = self.engine
        arch = sorted(eng.archive(), key=lambda g: (eng.cache[g][0], g))
        cands: List[Any] = []
        seen: set = set()
        for g in arch:
            c = self._decode(g)
            if c in seen:
                continue
            seen.add(c)
            cands.append(c)
        missing = [c for c in cands if c not in self._sr]
        if missing:
            srs = self.problem.surrogate_batch(missing)
            check_index_aligned(self.problem, srs, missing, "surrogate_batch")
            for c, sr in zip(missing, srs):
                self._sr[c] = sr
            self.surrogate_rows += len(missing)
        valid = [(c, self._sr[c]) for c in cands]
        hv = eng.hv_history[-1] if eng.hv_history else 0.0
        notes = [
            f"algorithm={self.spec.algorithm}",
            f"space={self.space.size()}",
            f"generations={eng.generation}",
            f"evaluations={eng.n_asked}",
            f"surrogate_rows={self.surrogate_rows}",
            # 12 significant digits: the golden harness parses this back and
            # compares at rtol 1e-6, so print well below that quantum
            f"hypervolume={hv:.12g}",
            f"resumed={self.resumed}",
        ]
        log = StageLog(f"search-{self.spec.algorithm}", self.space.size(),
                       len(valid), notes)
        return SearchOutcome(
            valid=valid, log=log, generations=eng.generation,
            evaluations=eng.n_asked, surrogate_rows=self.surrogate_rows,
            hypervolume=float(hv),
            hv_history=[float(h) for h in eng.hv_history],
            resumed=self.resumed)


@dataclasses.dataclass
class SearchOutcome:
    """What a finished (or interrupted) search hands to stages 3-4."""

    valid: List[Tuple[Any, SurrogateResult]]
    log: StageLog
    generations: int
    evaluations: int              # genomes the engine sent for evaluation
    surrogate_rows: int           # unique candidates the surrogate priced
    hypervolume: float
    hv_history: List[float]
    resumed: bool = False


def run_search(problem, spec: SearchSpec, sla: SLA, *, delta: float = 0.2,
               checkpoint_dir: Optional[str] = None, resume: bool = False,
               max_generations_this_run: Optional[int] = None) -> SearchOutcome:
    """Drive one problem's search to convergence (or an interruption point).

    ``max_generations_this_run`` bounds how many generations *this call*
    advances — with a checkpoint directory configured, that simulates an
    interrupted long campaign: the state is on disk, and a later call with
    ``resume=True`` continues bit-identically where this one stopped.
    """
    driver = SearchDriver(problem, spec, sla, delta=delta,
                          checkpoint_dir=checkpoint_dir, resume=resume)
    start_gen = driver.engine.generation
    while not driver.done:
        if (max_generations_this_run is not None
                and driver.engine.generation - start_gen >= max_generations_this_run):
            break
        cands = driver.ask_candidates()
        srs = problem.surrogate_batch(cands)
        driver.tell_candidates(srs)
    return driver.finalize()


# --------------------------------------------------------------------------
# exhaustive baseline over the same space
# --------------------------------------------------------------------------

@dataclasses.dataclass
class SpaceEvaluation:
    """Every point of ``problem.space()`` through the batched surrogate."""

    valid: List[Tuple[Any, SurrogateResult]]   # SLA-feasible, phenotype-deduped
    objectives: np.ndarray                     # [len(valid), 2]
    n_genomes: int
    n_static_ok: int
    surrogate_rows: int

    def front(self) -> List[Tuple[Any, SurrogateResult]]:
        idx = list(range(len(self.valid)))
        keep = pareto_front(idx, key=lambda i: tuple(self.objectives[i]))
        return [self.valid[i] for i in keep]

    def front_objectives(self) -> np.ndarray:
        idx = list(range(len(self.valid)))
        keep = pareto_front(idx, key=lambda i: tuple(self.objectives[i]))
        return self.objectives[keep].reshape(len(keep), 2)


def evaluate_space(problem, sla: SLA, *, delta: float = 0.2) -> SpaceEvaluation:
    """Exhaustive reference: decode every genome, static-prune, dedupe
    phenotypes and fan the remainder through one ``surrogate_batch`` call.
    This is the ground-truth front NSGA-II quality is measured against."""
    space = problem.space()
    if space is None:
        raise ValueError(
            f"{type(problem).__name__} does not define a design space; "
            "implement space()/decode() to use a generational search")
    uniq: List[Any] = []
    seen: set = set()
    n_genomes = 0
    n_static_ok = 0
    for g in space.genomes():
        n_genomes += 1
        c = problem.decode(space.assignment(g))
        t_proc, t_arrival = problem.static_timing(c)
        if t_proc > (1.0 + delta) * t_arrival:
            continue
        n_static_ok += 1
        if c in seen:
            continue
        seen.add(c)
        uniq.append(c)
    srs = problem.surrogate_batch(uniq)
    check_index_aligned(problem, srs, uniq, "surrogate_batch")
    valid: List[Tuple[Any, SurrogateResult]] = []
    objs: List[Tuple[float, float]] = []
    for c, sr in zip(uniq, srs):
        if (sr.p(99) <= sla.p99_latency_ns
                and sr.throughput_gbps >= sla.min_throughput_gbps):
            valid.append((c, sr))
            o = problem.surrogate_objectives(c, sr)
            objs.append((float(o[0]), float(o[1])))
    return SpaceEvaluation(
        valid=valid,
        objectives=np.asarray(objs, dtype=float).reshape(len(valid), 2),
        n_genomes=n_genomes,
        n_static_ok=n_static_ok,
        surrogate_rows=len(uniq))
