"""Pareto-front utilities for the DSE engine (Fig. 7 reproduction)."""

from __future__ import annotations

from typing import Callable, List, Sequence, Tuple, TypeVar

import numpy as np

T = TypeVar("T")

__all__ = ["pareto_front", "is_dominated", "hypervolume_2d"]


def is_dominated(p: Sequence[float], q: Sequence[float]) -> bool:
    """True if q dominates p (q <= p everywhere, < somewhere). Minimisation."""
    p, q = np.asarray(p, float), np.asarray(q, float)
    return bool(np.all(q <= p) and np.any(q < p))


def pareto_front(items: Sequence[T], key: Callable[[T], Sequence[float]]) -> List[T]:
    """Return the non-dominated subset (all objectives minimised)."""
    pts = np.asarray([key(it) for it in items], dtype=float)
    n = len(items)
    keep = np.ones(n, dtype=bool)
    for i in range(n):
        if not keep[i]:
            continue
        for j in range(n):
            if i == j or not keep[j]:
                continue
            if is_dominated(pts[i], pts[j]):
                keep[i] = False
                break
    return [it for it, k in zip(items, keep) if k]


def hypervolume_2d(points: Sequence[Sequence[float]], ref: Sequence[float]) -> float:
    """2-D hypervolume (minimisation) w.r.t. reference point — DSE quality metric."""
    pts = sorted((float(a), float(b)) for a, b in points)
    rx, ry = float(ref[0]), float(ref[1])
    hv, prev_y = 0.0, ry
    for x, y in pts:
        if x >= rx or y >= prev_y:
            continue
        hv += (rx - x) * (prev_y - y)
        prev_y = y
    return hv
