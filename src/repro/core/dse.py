"""Progressive Constraint Satisfaction DSE — Algorithm 1 of the paper.

The engine is deliberately generic: a ``DSEProblem`` supplies templates,
timing, surrogate evaluation, buffer sizing and verification, and the engine
runs the paper's four stages:

  Stage 1  Static pruning        T_proc > (1+δ)·T_arrival ⇒ drop
  Stage 2  Coarse profiling      surrogate w/ infinite buffers; prune on p99 SLA
  Stage 3  Statistical sizing    d_opt from queue-occupancy histogram @ ε,
                                 aligned to physical memory; prune on resources
  Stage 4  Verification          full simulation of the sized survivors, fanned
                                 through one ``verify_batch`` call

Two concrete problems implement this interface:
  * ``repro.sim.switch_problem.SwitchDSEProblem``  — the paper's FPGA switch
  * ``repro.comm.dse_comm.CommDSEProblem``         — the TPU comm/dispatch layer
"""

from __future__ import annotations

import dataclasses
import math
from typing import TYPE_CHECKING, Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .pareto import pareto_front

if TYPE_CHECKING:                      # import cycle: search imports this module
    from .search import DesignSpace, SearchSpec

__all__ = [
    "SLA",
    "ResourceBudget",
    "VERIFY_ENGINES",
    "USE_KERNEL_MODES",
    "SurrogateResult",
    "VerifyResult",
    "DSEProblem",
    "DSEResult",
    "StageLog",
    "run_dse",
    "stage1_static",
    "stage2_screen",
    "stage3_size",
    "stage3_verify",
    "stage4_verify",
    "finalize_result",
    "check_index_aligned",
    "depth_for_drop_rate",
    "IncrementalDSE",
]


#: stage-4 policy vocabulary — the single source of truth shared by the
#: switch problem, the Scenario `Fidelity` spec and the CLI flag:
#: "netsim"  — every sized survivor through the batched finite-buffer sim,
#: "cycle"   — every survivor through the cycle-accurate datapath (slow),
#: "auto"    — netsim for the front, cycle-sim for the champion only
#: (via the `escalate` hook).
VERIFY_ENGINES = ("netsim", "cycle", "auto")

#: `use_kernel` knob vocabulary — shared by the switch problem, the Scenario
#: `Fidelity` spec and the CLI `--use-kernel` flag:
#: "auto" — the segmented netsim kernels when available (bit-exact oracle
#:          fallback otherwise; `SPAC_NETSIM_KERNEL=off` disables globally),
#: "on"   — force the kernel engines,
#: "off"  — force today's oracle scans (byte-identical legacy path).
USE_KERNEL_MODES = ("auto", "on", "off")


@dataclasses.dataclass(frozen=True)
class SLA:
    p99_latency_ns: float = math.inf
    drop_rate: float = 1e-3          # ε: target tail drop rate for sizing
    min_throughput_gbps: float = 0.0


@dataclasses.dataclass(frozen=True)
class ResourceBudget:
    """Generic resource vector; keys are resource names (LUT/BRAM/… or bytes)."""

    limits: Dict[str, float]

    def admits(self, usage: Dict[str, float]) -> bool:
        return all(usage.get(k, 0.0) <= v for k, v in self.limits.items())


@dataclasses.dataclass
class SurrogateResult:
    """Stage-2 output: infinite-buffer queue occupancy histogram + latencies."""

    q_occupancy: np.ndarray       # samples (or histogram support) of max queue depth
    latency_ns: np.ndarray        # per-packet latency samples
    throughput_gbps: float = 0.0
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def p(self, q: float) -> float:
        return float(np.percentile(self.latency_ns, q)) if self.latency_ns.size else math.inf


@dataclasses.dataclass
class VerifyResult:
    p99_latency_ns: float
    mean_latency_ns: float
    drop_rate: float
    throughput_gbps: float
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def meets(self, sla: SLA) -> bool:
        return (
            self.p99_latency_ns <= sla.p99_latency_ns
            and self.drop_rate <= sla.drop_rate * 1.5  # discretised sizing slack
            and self.throughput_gbps >= sla.min_throughput_gbps
        )


class DSEProblem:
    """Interface Algorithm 1 runs against (override all methods)."""

    #: optional ``repro.launch.mesh.MeshSpec`` — problems whose batched
    #: stages can shard the candidate axis read it; results must be
    #: bit-identical to the serial default (None)
    mesh_spec = None

    def candidates(self) -> List[Any]:
        raise NotImplementedError

    def static_timing(self, cand) -> Tuple[float, float]:
        """Return (T_proc, T_arrival) in seconds for stage-1 pruning."""
        raise NotImplementedError

    def surrogate(self, cand) -> SurrogateResult:
        """Infinite-buffer statistical simulation (stage 2)."""
        raise NotImplementedError

    def surrogate_batch(self, cands: Sequence[Any]) -> List[SurrogateResult]:
        """Stage-2 fan-out hook: evaluate a whole candidate batch at once.

        Results must be index-aligned with ``cands``.  The default is the
        serial fallback (one ``surrogate`` call per candidate); problems with
        a vectorised surrogate override this — e.g. the switch problem fans
        candidates out through the batched JAX engine
        (``repro.sim.batched_surrogate``) so thousands of templates cost one
        jitted scan instead of thousands of Python loops.  Stage 3 consumes
        the returned occupancy samples unchanged."""
        return [self.surrogate(c) for c in cands]

    def size_buffers(self, cand, q_occupancy: np.ndarray, eps: float):
        """Map occupancy histogram to a sized candidate (stage 3)."""
        raise NotImplementedError

    def resources(self, cand) -> Dict[str, float]:
        raise NotImplementedError

    def verify(self, cand) -> VerifyResult:
        """High-fidelity simulation of the sized candidate (stage 4)."""
        raise NotImplementedError

    def verify_batch(self, cands: Sequence[Any]) -> List[VerifyResult]:
        """Stage-4 fan-out hook: verify a whole sized-candidate batch at once.

        The mirror of ``surrogate_batch`` one rung up the fidelity ladder:
        ``stage3_verify`` sizes *all* explored candidates first, then fans the
        sized survivors through one call here.  Results must be index-aligned
        with ``cands``.  The default is the serial fallback (one ``verify``
        call per candidate); problems with a batched verifier override it —
        the switch problem runs the finite-buffer event simulator as one
        jitted scan with sized VOQ depths as a batch axis
        (``repro.sim.batched_netsim``), the comm problem vectorises its
        analytic fabric metrics."""
        return [self.verify(c) for c in cands]

    def space(self) -> Optional["DesignSpace"]:
        """Parameterized design space for the generational search engine.

        Where ``candidates()`` returns a *pre-built list* of templates, this
        returns per-dimension ranges (``repro.core.search.DesignSpace``) that
        NSGA-II samples — so the joint space can be combinatorially larger
        than anything worth enumerating.  Problems that support search
        override this together with ``decode``; the default (None) keeps the
        problem exhaustive-only."""
        return None

    def decode(self, assignment: Dict[str, Any]) -> Any:
        """Materialise one ``space()`` point (a name->choice dict) into a
        candidate — the inverse of a genome, used by the search engine."""
        raise NotImplementedError

    def surrogate_objectives(self, cand, sr: SurrogateResult) -> Tuple[float, float]:
        """Stage-2-fidelity (latency, primary-resource) pair for the search
        engine (minimise both).  Mirrors ``objectives`` one rung down the
        ladder: the generational engine ranks whole populations on surrogate
        results long before anything is sized or verified."""
        res = self.resources(cand)
        primary = res.get("bram", res.get("bytes_per_device", sum(res.values())))
        return (sr.p(99), float(primary))

    def escalate(self, cand, v: VerifyResult) -> Optional[VerifyResult]:
        """Optional champion escalation to a higher fidelity rung.

        Called once per DSE with the winning (candidate, verify) pair; a
        problem may re-verify the champion on a more faithful engine (e.g.
        the cycle-accurate datapath under ``verify_engine="auto"``) and
        return that result — it is attached as ``meta["escalated"]`` on the
        champion's verify, never replacing the ranking metrics (so the
        Pareto front is engine-independent).  Default: no escalation."""
        return None

    def objectives(self, cand, verify: VerifyResult) -> Tuple[float, float]:
        """(latency, primary-resource) pair for ranking/Pareto (minimise both)."""
        res = self.resources(cand)
        primary = res.get("bram", res.get("bytes_per_device", sum(res.values())))
        return (verify.p99_latency_ns, float(primary))

    def diversity_key(self, cand):
        """Architecture-family key: stage 3 verifies the best candidate of
        every family in addition to the global top-K, so a surrogate ranking
        bias cannot starve a whole scheduler/buffer family of verification."""
        return None


@dataclasses.dataclass
class StageLog:
    stage: str
    considered: int
    survived: int
    notes: List[str] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class DSEResult:
    best: Optional[Any]
    best_verify: Optional[VerifyResult]
    pareto: List[Tuple[Any, VerifyResult]]
    evaluated: List[Tuple[Any, VerifyResult, Dict[str, float], bool]]
    logs: List[StageLog]

    def summary(self) -> str:
        lines = [f"DSE: {len(self.evaluated)} verified, {len(self.pareto)} on Pareto front"]
        for lg in self.logs:
            lines.append(f"  [{lg.stage}] {lg.considered} -> {lg.survived}")
        if self.best is not None and self.best_verify is not None:
            lines.append(
                f"  best: {getattr(self.best, 'short', lambda: repr(self.best))()} "
                f"p99={self.best_verify.p99_latency_ns:.1f}ns "
                f"drop={self.best_verify.drop_rate:.2e} "
                f"thru={self.best_verify.throughput_gbps:.1f}Gbps"
            )
        return "\n".join(lines)


def check_index_aligned(problem: DSEProblem, results: Sequence[Any],
                        cands: Sequence[Any], hook: str) -> None:
    """One home for the batch-hook alignment error, shared by the staged
    engine and the search driver so the message can never drift."""
    if len(results) != len(cands):
        raise ValueError(
            f"{type(problem).__name__}.{hook} returned {len(results)} "
            f"results for a {len(cands)}-candidate batch (result shape "
            f"[{len(results)}] vs candidate shape [{len(cands)}]); results "
            "must be index-aligned")


def depth_for_drop_rate(q_occupancy: np.ndarray, eps: float) -> int:
    """Smallest depth d with P(occupancy > d) <= ε (stage 3 core)."""
    q = np.asarray(q_occupancy, dtype=np.float64)
    if q.size == 0:
        return 1
    d = float(np.quantile(q, 1.0 - eps, method="higher"))
    return max(1, int(math.ceil(d)))


def stage1_static(problem: DSEProblem, *, delta: float = 0.2) -> Tuple[List[Any], StageLog]:
    """Stage 1: static timing pruning over the enumerated templates."""
    cands = list(problem.candidates())
    active = []
    for a in cands:
        t_proc, t_arrival = problem.static_timing(a)
        if t_proc <= (1.0 + delta) * t_arrival:
            active.append(a)
    return active, StageLog("stage1-static", len(cands), len(active))


def stage2_screen(
    problem: DSEProblem,
    active: Sequence[Any],
    sla: SLA,
    *,
    surrogates: Optional[Sequence[SurrogateResult]] = None,
) -> Tuple[List[Tuple[Any, SurrogateResult]], StageLog]:
    """Stage 2: coarse-grained profiling + SLA screening.

    ``surrogates`` lets a caller inject precomputed stage-2 results (index-
    aligned with ``active``) — the campaign runner uses this to fan *several*
    scenarios' candidates through one batched-engine call and hand each
    scenario its slice back.  When absent, the problem's ``surrogate_batch``
    hook runs (vectorised where the problem provides it, serial otherwise).
    """
    active = list(active)
    srs = list(surrogates) if surrogates is not None else problem.surrogate_batch(active)
    check_index_aligned(problem, srs, active, "surrogate_batch")
    valid: List[Tuple[Any, SurrogateResult]] = []
    for a, sr in zip(active, srs):
        if sr.p(99) <= sla.p99_latency_ns and sr.throughput_gbps >= sla.min_throughput_gbps:
            valid.append((a, sr))
    return valid, StageLog("stage2-surrogate", len(active), len(valid))


def stage3_size(
    problem: DSEProblem,
    valid: Sequence[Tuple[Any, SurrogateResult]],
    sla: SLA,
    budget: ResourceBudget,
    *,
    top_k: int = 8,
) -> Tuple[List[Tuple[Any, Dict[str, float]]], int]:
    """Stage 3 alone: exploration, statistical sizing, resource pruning.

    TopKLatency: explore the K best candidates by surrogate p99, plus the
    best of each architecture family (diversity-preserving).  Every explored
    candidate is sized from its occupancy histogram and priced; survivors of
    the budget come back as index-stable ``(sized, resources)`` pairs so the
    whole batch can fan through one ``verify_batch`` call.
    """
    valid = sorted(valid, key=lambda av: av[1].p(99))
    explored = list(valid[: top_k if top_k > 0 else len(valid)])
    seen_keys = {id(a) for a, _ in explored}
    families = {}
    for a, sr in valid:
        k = problem.diversity_key(a)
        if k is not None and k not in families:
            families[k] = (a, sr)
    for a, sr in families.values():
        if id(a) not in seen_keys:
            explored.append((a, sr))
    sized: List[Tuple[Any, Dict[str, float]]] = []
    for a, sr in explored:
        s = problem.size_buffers(a, sr.q_occupancy, sla.drop_rate)
        if s is None:
            continue
        res = problem.resources(s)
        if not budget.admits(res):
            continue
        sized.append((s, res))
    return sized, len(explored)


def stage4_verify(
    problem: DSEProblem,
    sized: Sequence[Tuple[Any, Dict[str, float]]],
    sla: SLA,
    *,
    verifies: Optional[Sequence[VerifyResult]] = None,
) -> Tuple[List[Tuple[Any, VerifyResult, Dict[str, float], bool]],
           Optional[Any], Optional[VerifyResult]]:
    """Stage 4: fan the sized survivors through one ``verify_batch`` call.

    ``verifies`` lets a caller inject precomputed verification results
    (index-aligned with ``sized``) — the campaign runner uses this to batch
    stage 4 across scenarios sharing a (trace, bound protocol, engine)
    exactly as it already batches stage 2.  The champion is escalated via
    ``problem.escalate`` (a no-op by default)."""
    cands = [a for a, _ in sized]
    vs = list(verifies) if verifies is not None else problem.verify_batch(cands)
    check_index_aligned(problem, vs, cands, "verify_batch")
    evaluated: List[Tuple[Any, VerifyResult, Dict[str, float], bool]] = []
    best: Optional[Any] = None
    best_v: Optional[VerifyResult] = None
    for (a, res), v in zip(sized, vs):
        feasible = v.meets(sla)
        evaluated.append((a, v, res, feasible))
        if feasible:
            if best_v is None or problem.objectives(a, v) < problem.objectives(best, best_v):
                best, best_v = a, v
    if best is not None:
        esc = problem.escalate(best, best_v)
        if esc is not None:
            best_v.meta["escalated"] = esc
    return evaluated, best, best_v


def stage3_verify(
    problem: DSEProblem,
    valid: Sequence[Tuple[Any, SurrogateResult]],
    sla: SLA,
    budget: ResourceBudget,
    *,
    top_k: int = 8,
    verifies: Optional[Sequence[VerifyResult]] = None,
) -> Tuple[List[Tuple[Any, VerifyResult, Dict[str, float], bool]],
           Optional[Any], Optional[VerifyResult], StageLog]:
    """Stages 3+4 composed: size all explored candidates, then verify the
    sized survivors in one batch (see ``stage3_size`` / ``stage4_verify``)."""
    sized, n_explored = stage3_size(problem, valid, sla, budget, top_k=top_k)
    evaluated, best, best_v = stage4_verify(problem, sized, sla,
                                            verifies=verifies)
    return evaluated, best, best_v, StageLog("stage3-sizing+verify",
                                             n_explored, len(sized))


def finalize_result(
    problem: DSEProblem,
    evaluated: List[Tuple[Any, VerifyResult, Dict[str, float], bool]],
    best: Optional[Any],
    best_v: Optional[VerifyResult],
    logs: List[StageLog],
) -> DSEResult:
    """Rank the verified candidates into a Pareto front and assemble the result."""
    feas = [(a, v) for a, v, _, ok in evaluated if ok] or [(a, v) for a, v, _, _ in evaluated]
    front = pareto_front(feas, key=lambda av: problem.objectives(av[0], av[1])) if feas else []
    return DSEResult(best=best, best_verify=best_v, pareto=front, evaluated=evaluated, logs=logs)


def run_dse(
    problem: DSEProblem,
    sla: SLA,
    budget: ResourceBudget,
    *,
    delta: float = 0.2,
    top_k: int = 8,
    verbose: bool = False,
    search: Optional["SearchSpec"] = None,
    checkpoint_dir: Optional[str] = None,
    resume: bool = False,
    mesh=None,
) -> DSEResult:
    """Algorithm 1: Progressive Constraint Satisfaction.

    Composed from the staged functions above so callers that need to
    interleave stages across problems (``repro.api.run_campaign`` batches
    stage 2 across scenarios) reuse the exact same semantics.

    ``search`` replaces the exhaustive stage-1/2 enumeration with the
    generational NSGA-II engine over ``problem.space()`` (seeded, resumable
    — see ``repro.core.search``): the engine's final archive plays the role
    of the screened ``valid`` set, and stages 3-4 run unchanged, so
    verification semantics are identical either way.  ``checkpoint_dir`` /
    ``resume`` control search-state persistence (``checkpoint_dir`` defaults
    to ``search.checkpoint_dir``).

    ``mesh`` is an optional ``repro.launch.mesh.MeshSpec`` (or device count)
    set on ``problem.mesh_spec``: batched stages shard their candidate axis
    across the device mesh, bit-identical to the serial default.  The mesh
    never enters search state — checkpoints written on N devices resume on M.
    """
    if mesh is not None:
        from repro.launch.mesh import MeshSpec
        problem.mesh_spec = MeshSpec.coerce(mesh)
    if search is not None:
        from .search import run_search
        outcome = run_search(problem, search, sla, delta=delta,
                             checkpoint_dir=checkpoint_dir, resume=resume)
        valid, logs = outcome.valid, [outcome.log]
        if verbose:
            print(outcome.log)
    else:
        active, log1 = stage1_static(problem, delta=delta)
        if verbose:
            print(log1)
        valid, log2 = stage2_screen(problem, active, sla)
        if verbose:
            print(log2)
        logs = [log1, log2]
    evaluated, best, best_v, log3 = stage3_verify(problem, valid, sla, budget, top_k=top_k)
    if verbose:
        print(log3)
    return finalize_result(problem, evaluated, best, best_v, logs + [log3])


class IncrementalDSE:
    """Algorithm 1 as a per-request state machine for an external batcher.

    The staged functions above run one request's whole batch per call; the
    serving engine (``repro.api.service``) instead multiplexes many
    concurrent requests through *shared* fixed-width jitted calls, so it
    needs each request's stage work exposed as "which candidates do you need
    evaluated next, at which fidelity?".  An ``IncrementalDSE`` holds one
    request's Algorithm-1 state:

    * stage 1 runs at construction (host-side, cheap);
    * ``kind``/``pending`` expose the current evaluation queue —
      ``"surrogate"`` rows until stage 2 drains, then ``"verify"`` rows;
    * the owner evaluates any *prefix* of ``pending`` (batched together with
      other requests' rows) and hands the index-aligned results to
      ``feed()``; when a queue drains the machine advances — screen → size →
      verify → ``result``.

    Feeding results chunk-at-a-time is exact because both batch hooks are
    row-independent — the same invariant the campaign runner's
    cross-scenario batching already relies on, so a served request's
    ``DSEResult`` is identical to ``run_dse`` on the same problem.

    With a ``SearchSpec``, stage 2 becomes the generational ask/tell loop
    (``repro.core.search.SearchDriver``): ``pending`` is the current
    generation's population, and a fully-fed generation advances the engine
    exactly as the campaign's lockstep driver does.
    """

    def __init__(self, problem: DSEProblem, sla: SLA, budget: ResourceBudget,
                 *, delta: float = 0.2, top_k: int = 8,
                 search: Optional["SearchSpec"] = None,
                 checkpoint_dir: Optional[str] = None, resume: bool = False):
        self.problem = problem
        self.sla = sla
        self.budget = budget
        self.top_k = top_k
        self.kind = "surrogate"
        self.stage2_candidates = 0      # rows this request fanned out
        self.stage4_candidates = 0      # sized rows verified
        self._logs: List[StageLog] = []
        self._pending: List[Any] = []
        self._fed: List[Any] = []
        self._sized: List[Tuple[Any, Dict[str, float]]] = []
        self._n_explored = 0
        self._result: Optional[DSEResult] = None
        self._driver = None
        if search is not None:
            from .search import SearchDriver
            self._driver = SearchDriver(problem, search, sla, delta=delta,
                                        checkpoint_dir=checkpoint_dir,
                                        resume=resume)
            self._ask()
        else:
            self._active, log1 = stage1_static(problem, delta=delta)
            self._logs.append(log1)
            self._pending = list(self._active)
            self.stage2_candidates = len(self._active)
            if not self._pending:
                self._finish_stage2()

    # -------------------------------------------------------------- queries
    @property
    def done(self) -> bool:
        return self._result is not None

    @property
    def result(self) -> DSEResult:
        if self._result is None:
            raise ValueError("IncrementalDSE still has pending work")
        return self._result

    @property
    def pending(self) -> List[Any]:
        """Candidates awaiting evaluation at the current ``kind`` fidelity.
        The owner may evaluate any prefix and ``feed()`` it back."""
        return list(self._pending)

    # ------------------------------------------------------------- advance
    def _ask(self) -> None:
        """Generational mode: advance to the next non-empty population (an
        ask can come back empty when every genome was pruned or answered
        from the phenotype cache — tell the engine and move on)."""
        while not self._driver.done:
            cands = self._driver.ask_candidates()
            if cands:
                self._pending = list(cands)
                self._fed = []
                return
            self._driver.tell_candidates([])
        self._finish_stage2()

    def feed(self, results: Sequence[Any]) -> None:
        """Hand back index-aligned results for the first ``len(results)``
        entries of ``pending``; drained queues advance the stage machine."""
        if self.done:
            raise ValueError("IncrementalDSE is already finished")
        results = list(results)
        if len(results) > len(self._pending):
            raise ValueError(
                f"fed {len(results)} results for {len(self._pending)} "
                "pending candidates; feed at most the pending prefix")
        self._fed.extend(results)
        del self._pending[:len(results)]
        if self._pending:
            return
        if self.kind == "surrogate":
            if self._driver is not None:
                self._driver.tell_candidates(self._fed)
                self._ask()
            else:
                self._finish_stage2()
        else:
            self._finish()

    def _finish_stage2(self) -> None:
        if self._driver is not None:
            outcome = self._driver.finalize()
            valid, log2 = outcome.valid, outcome.log
            # finalize()'s archive re-surrogation (resume path) counts as
            # stage-2 fan-out, matching run_scenario's accounting
            self.stage2_candidates = outcome.surrogate_rows
        else:
            valid, log2 = stage2_screen(self.problem, self._active, self.sla,
                                        surrogates=self._fed)
        self._logs.append(log2)
        self._sized, self._n_explored = stage3_size(
            self.problem, valid, self.sla, self.budget, top_k=self.top_k)
        self.kind = "verify"
        self._pending = [a for a, _ in self._sized]
        self._fed = []
        self.stage4_candidates = len(self._sized)
        if not self._pending:
            self._finish()

    def _finish(self) -> None:
        evaluated, best, best_v = stage4_verify(self.problem, self._sized,
                                                self.sla, verifies=self._fed)
        log3 = StageLog("stage3-sizing+verify", self._n_explored,
                        len(self._sized))
        self._result = finalize_result(self.problem, evaluated, best, best_v,
                                       self._logs + [log3])
        self.kind = "done"
