"""Serializable fabric topologies — the network the switches compose into.

A :class:`Topology` arranges identical-per-tier switches (leaf/spine, k-ary
fat-tree, ring) around ``n_hosts`` end hosts.  Every switch in a tier is one
(arch, protocol) design point — the fabric DSE searches per-*tier* genes, not
per-node, which keeps the genome tractable (ROADMAP item 4).  The topology's
only runtime job is :meth:`Topology.route`: a deterministic hop list
``(tier, node, in_port, out_port)`` per (src, dst) pair, with equal-cost
multipath resolved by an explicit integer flow hash (no ``hash()``, no RNG —
routes are part of the golden-report contract).

Port numbering inside a node is local (``0..degree-1``); the multi-hop
evaluator flattens a whole tier into one super-switch by
``flat = node * degree + local`` (see ``repro.fabric.evaluate``), so every
local port id must stay below the tier's degree.

:class:`TopologySpec` is the JSON-round-trippable half (``Scenario.topology``
carries one); ``spec.build()`` returns the live object.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Dict, Iterable, List, Mapping, NamedTuple, Tuple

__all__ = ["Hop", "Tier", "Topology", "TopologySpec", "TOPOLOGY_KINDS",
           "build_topology", "flow_hash"]


class Hop(NamedTuple):
    """One switch traversal: which tier/node the packet enters, on which
    local ingress port, and which local egress port it leaves by."""

    tier: int
    node: int
    in_port: int
    out_port: int


class Tier(NamedTuple):
    """One homogeneous switch tier: ``n_nodes`` switches of ``degree`` ports
    each, all sharing a single (arch, protocol) design point."""

    name: str
    n_nodes: int
    degree: int


def flow_hash(src: int, dst: int) -> int:
    """Deterministic 32-bit flow mix for ECMP path selection.

    Knuth/Murmur-style odd-constant mixing over the (src, dst) pair —
    explicitly *not* Python's ``hash()``, which is salted per process and
    would make routes (and therefore goldens) irreproducible."""
    h = (src * 0x9E3779B1) & 0xFFFFFFFF
    h ^= (dst * 0x85EBCA6B + 0xC2B2AE35) & 0xFFFFFFFF
    h ^= h >> 16
    return (h * 0x27D4EB2F) & 0xFFFFFFFF


class Topology:
    """Base class: a tiered switch fabric around ``n_hosts`` end hosts.

    Subclasses fill ``tiers`` and implement :meth:`route`; everything else
    (node/link enumeration, validation, key) is shared."""

    kind: str = ""

    def __init__(self, params: Mapping[str, int]):
        self.params: Dict[str, int] = {k: int(v) for k, v in sorted(params.items())}
        self.n_hosts: int = 0
        self.tiers: Tuple[Tier, ...] = ()

    # ------------------------------------------------------------ structure
    @property
    def n_tiers(self) -> int:
        return len(self.tiers)

    @property
    def max_hops(self) -> int:
        raise NotImplementedError

    def nodes(self) -> List[Tuple[int, int]]:
        """Every switch as ``(tier, node)`` in deterministic order."""
        return [(t, v) for t, tier in enumerate(self.tiers)
                for v in range(tier.n_nodes)]

    def links(self) -> List[Tuple[Tuple, Tuple]]:
        """Every physical link as an ordered endpoint pair.

        Host attachments are ``(("host", h), (tier, node, port))``; switch-to-
        switch links are ``((t1, n1, p1), (t2, n2, p2))`` with the lower tier
        first.  Deterministic order — tests diff this structurally."""
        raise NotImplementedError

    def route(self, src: int, dst: int) -> Tuple[Hop, ...]:
        """The hop list a (src, dst) packet traverses.  Deterministic: equal-
        cost choices resolve by :func:`flow_hash`."""
        raise NotImplementedError

    # ----------------------------------------------------------- invariants
    def _check_host(self, h: int, role: str) -> None:
        if not 0 <= h < self.n_hosts:
            raise ValueError(f"{role} host {h} out of range for "
                             f"{self.kind} with {self.n_hosts} hosts")

    def validate_route(self, hops: Iterable[Hop]) -> None:
        """Structural sanity used by tests: every hop's ports fit its tier."""
        for hop in hops:
            tier = self.tiers[hop.tier]
            if not (0 <= hop.node < tier.n_nodes
                    and 0 <= hop.in_port < tier.degree
                    and 0 <= hop.out_port < tier.degree):
                raise ValueError(f"hop {hop} violates tier {tier}")

    def key(self) -> str:
        """Canonical content key (content-addressed serve caching)."""
        return json.dumps({"kind": self.kind, "params": self.params},
                          sort_keys=True)

    def __repr__(self) -> str:
        args = ", ".join(f"{k}={v}" for k, v in self.params.items())
        return f"{type(self).__name__}({args})"


class FatTree(Topology):
    """2-tier folded Clos from a k-ary fat-tree: ``k`` edge switches of ``k``
    ports (``k/2`` down to hosts, ``k/2`` up), ``k/2`` core switches of ``k``
    ports (one per edge switch, so both tiers share degree ``k``).
    ``k²/2`` hosts; inter-edge traffic ECMPs over the ``k/2`` cores."""

    kind = "fattree"

    def __init__(self, k: int = 4):
        if k < 2 or k % 2:
            raise ValueError(f"fattree needs an even k >= 2, got {k}")
        super().__init__({"k": k})
        self.k = k
        self.half = k // 2
        self.n_hosts = k * self.half
        self.tiers = (Tier("edge", k, k), Tier("core", self.half, k))

    @property
    def max_hops(self) -> int:
        return 3                     # intra-edge is 1 hop, via a core is 3

    def links(self):
        out = []
        for h in range(self.n_hosts):
            out.append((("host", h), (0, h // self.half, h % self.half)))
        for e in range(self.k):
            for c in range(self.half):
                out.append(((0, e, self.half + c), (1, c, e)))
        return out

    def route(self, src: int, dst: int) -> Tuple[Hop, ...]:
        self._check_host(src, "src")
        self._check_host(dst, "dst")
        e_s, p_s = divmod(src, self.half)
        e_d, p_d = divmod(dst, self.half)
        if e_s == e_d:
            return (Hop(0, e_s, p_s, p_d),)
        c = flow_hash(src, dst) % self.half
        return (Hop(0, e_s, p_s, self.half + c),
                Hop(1, c, e_s, e_d),
                Hop(0, e_d, self.half + c, p_d))


class LeafSpine(Topology):
    """Classic leaf/spine: ``leaves`` leaf switches with ``hosts_per_leaf``
    host ports + one uplink per spine (degree ``hosts_per_leaf + spines``);
    ``spines`` spine switches with one port per leaf (degree ``leaves``).
    Inter-leaf traffic ECMPs over the spines."""

    kind = "leafspine"

    def __init__(self, leaves: int = 2, spines: int = 2,
                 hosts_per_leaf: int = 4):
        if leaves < 1 or spines < 1 or hosts_per_leaf < 1:
            raise ValueError("leafspine needs leaves/spines/hosts_per_leaf >= 1")
        super().__init__({"leaves": leaves, "spines": spines,
                          "hosts_per_leaf": hosts_per_leaf})
        self.leaves, self.spines = leaves, spines
        self.hpl = hosts_per_leaf
        self.n_hosts = leaves * hosts_per_leaf
        self.tiers = (Tier("leaf", leaves, hosts_per_leaf + spines),
                      Tier("spine", spines, leaves))

    @property
    def max_hops(self) -> int:
        return 3 if self.leaves > 1 else 1

    def links(self):
        out = []
        for h in range(self.n_hosts):
            out.append((("host", h), (0, h // self.hpl, h % self.hpl)))
        for l in range(self.leaves):
            for s in range(self.spines):
                out.append(((0, l, self.hpl + s), (1, s, l)))
        return out

    def route(self, src: int, dst: int) -> Tuple[Hop, ...]:
        self._check_host(src, "src")
        self._check_host(dst, "dst")
        l_s, p_s = divmod(src, self.hpl)
        l_d, p_d = divmod(dst, self.hpl)
        if l_s == l_d:
            return (Hop(0, l_s, p_s, p_d),)
        s = flow_hash(src, dst) % self.spines
        return (Hop(0, l_s, p_s, self.hpl + s),
                Hop(1, s, l_s, l_d),
                Hop(0, l_d, self.hpl + s, p_d))


class Ring(Topology):
    """``n_nodes`` switches in a bidirectional ring, ``hosts_per_node`` hosts
    each.  Port layout per node: ``0..hosts_per_node-1`` hosts, then the
    counter-clockwise ring port and the clockwise ring port.  Shortest
    direction wins; exact ties resolve by flow hash."""

    kind = "ring"

    def __init__(self, n_nodes: int = 4, hosts_per_node: int = 2):
        if n_nodes < 1 or hosts_per_node < 1:
            raise ValueError("ring needs n_nodes/hosts_per_node >= 1")
        super().__init__({"n_nodes": n_nodes, "hosts_per_node": hosts_per_node})
        self.n_nodes, self.hpn = n_nodes, hosts_per_node
        self.n_hosts = n_nodes * hosts_per_node
        degree = hosts_per_node + (2 if n_nodes > 1 else 0)
        self.tiers = (Tier("ring", n_nodes, degree),)

    @property
    def max_hops(self) -> int:
        return 1 + self.n_nodes // 2

    @property
    def _ccw(self) -> int:           # local port towards node v-1
        return self.hpn

    @property
    def _cw(self) -> int:            # local port towards node v+1
        return self.hpn + 1

    def links(self):
        out = []
        for h in range(self.n_hosts):
            out.append((("host", h), (0, h // self.hpn, h % self.hpn)))
        if self.n_nodes > 1:
            for v in range(self.n_nodes):
                w = (v + 1) % self.n_nodes
                out.append(((0, v, self._cw), (0, w, self._ccw)))
        return out

    def route(self, src: int, dst: int) -> Tuple[Hop, ...]:
        self._check_host(src, "src")
        self._check_host(dst, "dst")
        a, p_s = divmod(src, self.hpn)
        b, p_d = divmod(dst, self.hpn)
        if a == b:
            return (Hop(0, a, p_s, p_d),)
        n = self.n_nodes
        d_cw = (b - a) % n
        d_ccw = (a - b) % n
        if d_cw == d_ccw:
            clockwise = bool(flow_hash(src, dst) & 1)
        else:
            clockwise = d_cw < d_ccw
        step = 1 if clockwise else -1
        out_ring = self._cw if clockwise else self._ccw
        in_ring = self._ccw if clockwise else self._cw
        hops = [Hop(0, a, p_s, out_ring)]
        v = (a + step) % n
        while v != b:
            hops.append(Hop(0, v, in_ring, out_ring))
            v = (v + step) % n
        hops.append(Hop(0, b, in_ring, p_d))
        return tuple(hops)


TOPOLOGY_KINDS: Dict[str, type] = {
    FatTree.kind: FatTree,
    LeafSpine.kind: LeafSpine,
    Ring.kind: Ring,
}


def build_topology(kind: str, **params: int) -> Topology:
    cls = TOPOLOGY_KINDS.get(kind)
    if cls is None:
        raise ValueError(f"unknown topology kind {kind!r}; "
                         f"known: {', '.join(sorted(TOPOLOGY_KINDS))}")
    return cls(**params)


@dataclasses.dataclass(frozen=True)
class TopologySpec:
    """The serializable half of a topology (``Scenario.topology``).

    ``params`` is a sorted tuple of ``(name, value)`` pairs so the spec is
    hashable and its JSON form canonical."""

    kind: str
    params: Tuple[Tuple[str, int], ...] = ()

    def __post_init__(self):
        if self.kind not in TOPOLOGY_KINDS:
            raise ValueError(f"unknown topology kind {self.kind!r}; "
                             f"known: {', '.join(sorted(TOPOLOGY_KINDS))}")
        norm = tuple(sorted((str(k), int(v)) for k, v in self.params))
        object.__setattr__(self, "params", norm)
        self.build()                 # fail on bad params at spec time

    @classmethod
    def make(cls, kind: str, **params: int) -> "TopologySpec":
        return cls(kind=kind, params=tuple(params.items()))

    def build(self) -> Topology:
        return build_topology(self.kind, **dict(self.params))

    def to_dict(self) -> Dict:
        return {"kind": self.kind, "params": dict(self.params)}

    @staticmethod
    def from_dict(d: Mapping) -> "TopologySpec":
        return TopologySpec(kind=d["kind"],
                            params=tuple(dict(d.get("params", {})).items()))

    def key(self) -> str:
        return json.dumps(self.to_dict(), sort_keys=True)
