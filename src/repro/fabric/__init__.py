"""Multi-hop fabric layer: topologies of co-designed switches.

``topology`` — serializable leaf/spine, fat-tree and ring topologies with
deterministic flow-hash ECMP routing; ``evaluate`` — the hop-by-hop
composition of the batched stage-2/stage-4 engines; ``problem`` — the joint
per-tier ``FabricDSEProblem`` the search engine optimises end-to-end.
"""

from .evaluate import (FabricRoutes, evaluate_fabric_batched, fabric_routes,
                       flatten_tier_arch, surrogate_fabric_batched)
from .problem import FabricCandidate, FabricDSEProblem, TIER_DIM_PREFIX
from .topology import (TOPOLOGY_KINDS, FatTree, Hop, LeafSpine, Ring, Tier,
                       Topology, TopologySpec, build_topology, flow_hash)

__all__ = [
    "FabricCandidate",
    "FabricDSEProblem",
    "FabricRoutes",
    "FatTree",
    "Hop",
    "LeafSpine",
    "Ring",
    "TIER_DIM_PREFIX",
    "TOPOLOGY_KINDS",
    "Tier",
    "Topology",
    "TopologySpec",
    "build_topology",
    "evaluate_fabric_batched",
    "fabric_routes",
    "flatten_tier_arch",
    "flow_hash",
    "surrogate_fabric_batched",
]
