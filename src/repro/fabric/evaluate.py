"""Multi-hop fabric evaluation: thread one trace through a network of
switches using the existing batched stage-2/stage-4 engines.

Two ideas make this exact *and* keep the jit shape story unchanged:

**Super-switch flattening.**  All switches in a tier share one design, and
every piece of engine state — ``in_free``/``out_free`` port availability,
the per-(src, dst) VOQ ring, the host-NIC serialisation — is per-port or
per-port-pair.  So K p-port switches of one tier are simulated as ONE
flattened switch with ``K*p`` ports (``flat = node * degree + local``): one
batched engine call per tier, bit-exactly the K independent switches.  The
single coupling exception is ``VOQKind.SHARED``, whose global ``N*depth``
cap would pool buffers *across* nodes — fabric evaluation rejects it.

**Hop composition via the latency identity.**  Every verify engine reports
``latency[k] = (end + prop_delay - t0[k]) * 1e9`` (now exposed whole-trace in
``meta["latency_full_ns"]``, NaN = dropped), so the arrival time at the next
hop's ingress is exactly ``t0[k] + latency[k]*1e-9 = end + prop_delay``.
Each hop's departures become the next hop's arrivals; each hop re-applies the
ingress serialisation (``switch_arrival_times``) — the documented
store-and-forward contract, identical across the serial, batched and kernel
engines, so composition is engine- and mesh-invariant.

**Drops mask downstream, without changing shapes.**  A packet dropped at hop
s must not appear at hop s+1 — but removing its row would change the event
count and retrigger jit tracing per candidate.  Instead the dead row keeps
flowing with a *sentinel* arrival time strictly after every live packet in
its sub-trace (max live time + 1 s).  Sentinels sort last, so the engines'
forward-in-time state (host serialisation, port availability, VOQ ring)
processes every live packet before any sentinel: live results are provably
unperturbed, and the evaluator simply ignores sentinel rows via its own
``alive`` mask.  Candidates whose upstream histories agree bit-for-bit (hop
0: all of them) share one batched call; later hops group by arrival-array
content, so the fan-out stays batched wherever dynamics coincide.
"""

from __future__ import annotations

import math
from dataclasses import replace
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.archspec import SwitchArch, VOQKind
from repro.core.dse import SurrogateResult, VerifyResult
from repro.sim.backannotate import annotate
from repro.sim.batched_netsim import run_netsim_batched
from repro.sim.batched_surrogate import run_surrogate_batched
from repro.sim.netsim import NetSimConfig
from repro.sim.timeline import trace_key
from repro.traces import Trace

from .topology import Topology

__all__ = ["FabricRoutes", "evaluate_fabric_batched",
           "surrogate_fabric_batched", "fabric_routes", "flatten_tier_arch"]

#: route-table memo: (trace content, topology) -> FabricRoutes.  Bounded FIFO
#: like ``sim.timeline`` — hop-composition re-enters per generation.
_ROUTE_MEMO: Dict[Tuple[str, str], "FabricRoutes"] = {}
_MAX_ROUTE_MEMO = 16


class FabricRoutes:
    """Precomputed hop tables for one (topology, trace) pair.

    ``n_hops[p]`` is packet p's route length; for stage ``s < n_hops[p]``,
    ``tier_of[s, p]`` / ``flat_in[s, p]`` / ``flat_out[s, p]`` give the tier
    index and the *flattened* super-switch ingress/egress port ids
    (``node * degree + local``).  Stages a packet has already exited carry
    ``-1``."""

    def __init__(self, topo: Topology, src: np.ndarray, dst: np.ndarray):
        m = src.size
        h_max = max(topo.max_hops, 1)
        self.n_hops = np.zeros(m, np.int64)
        self.tier_of = np.full((h_max, m), -1, np.int64)
        self.flat_in = np.full((h_max, m), -1, np.int64)
        self.flat_out = np.full((h_max, m), -1, np.int64)
        degrees = [tier.degree for tier in topo.tiers]
        for p in range(m):
            hops = topo.route(int(src[p]), int(dst[p]))
            self.n_hops[p] = len(hops)
            for s, hop in enumerate(hops):
                d = degrees[hop.tier]
                self.tier_of[s, p] = hop.tier
                self.flat_in[s, p] = hop.node * d + hop.in_port
                self.flat_out[s, p] = hop.node * d + hop.out_port
        self.max_hops = int(self.n_hops.max()) if m else 0


def fabric_routes(topo: Topology, trace) -> FabricRoutes:
    key = (trace_key(trace), topo.key())
    hit = _ROUTE_MEMO.get(key)
    if hit is None:
        hit = FabricRoutes(topo, np.asarray(trace.src), np.asarray(trace.dst))
        _ROUTE_MEMO[key] = hit
        while len(_ROUTE_MEMO) > _MAX_ROUTE_MEMO:
            _ROUTE_MEMO.pop(next(iter(_ROUTE_MEMO)))
    return hit


def flatten_tier_arch(arch: SwitchArch, tier_nodes: int) -> SwitchArch:
    """The tier's per-node template widened to the super-switch port count.
    Everything else — bus width, VOQ depth, scheduler — stays per-node, and
    the per-node ``hw`` is passed to the engines explicitly, so flattening
    changes indexing only, never timing."""
    if arch.voq is VOQKind.SHARED:
        raise ValueError(
            "fabric tiers cannot use VOQKind.SHARED: the shared buffer cap "
            "would pool across nodes once the tier is flattened into a "
            "super-switch (per-node NXN VOQs flatten exactly)")
    return replace(arch, n_ports=arch.n_ports * tier_nodes)


def _check_tiers(topo: Topology, tiers: Sequence[SwitchArch]) -> None:
    if len(tiers) != topo.n_tiers:
        raise ValueError(f"{len(tiers)} tier designs for {topo.n_tiers}-tier "
                         f"{topo.kind}")
    for t, (arch, tier) in enumerate(zip(tiers, topo.tiers)):
        if arch.n_ports != tier.degree:
            raise ValueError(
                f"tier {t} ({tier.name}) arch has n_ports={arch.n_ports} but "
                f"the topology degree is {tier.degree}")


def _sorted_subtrace(name: str, times: np.ndarray, src: np.ndarray,
                     dst: np.ndarray, payload: np.ndarray, n_ports: int,
                     link_gbps: float) -> Tuple[Trace, np.ndarray]:
    """Pre-sorted Trace + the permutation mapping storage order back to the
    caller's packet order.  Sorting here (stable, once per unique arrival
    content) makes ``Trace.__post_init__``'s own stable sort the identity, so
    ``meta["latency_full_ns"]`` row i is the caller's packet ``perm[i]``."""
    perm = np.argsort(times, kind="stable")
    sub = Trace(name=name, time_s=times[perm], src=src[perm].astype(np.int32),
                dst=dst[perm].astype(np.int32),
                payload_bytes=payload[perm], n_ports=n_ports,
                link_gbps=link_gbps)
    return sub, perm


def _tier_hw(cands_archs, cands_bounds, back_annotation: bool,
             i_burst: float):
    """Per-candidate per-tier HardwareParams from the *per-node* arch — the
    flattened super-switch must run with per-node timing."""
    source = "cycle_sim" if back_annotation else "model"
    return [tuple(annotate(a, b, source=source, i_burst=i_burst)
                  for a, b in zip(archs, bounds))
            for archs, bounds in zip(cands_archs, cands_bounds)]


def _masked_times(arr_row: np.ndarray, alive_row: np.ndarray) -> np.ndarray:
    """Arrival times with dead packets pushed to a sentinel strictly after
    every live packet (max live time + 1 s), keeping the event count fixed."""
    times = arr_row.copy()
    if not alive_row.all():
        live = times[alive_row]
        base = float(live.max()) if live.size else 0.0
        times[~alive_row] = base + 1.0
    return times


def evaluate_fabric_batched(
    topo: Topology,
    cands_archs: Sequence[Sequence[SwitchArch]],
    cands_bounds: Sequence[Sequence],
    trace,
    *,
    hw: Optional[Sequence[Sequence]] = None,
    cfg: Optional[NetSimConfig] = None,
    back_annotation: bool = True,
    i_burst: float = 1.0,
    mesh=None,
    use_kernel=False,
) -> List[VerifyResult]:
    """Stage-4 verify for a batch of fabric candidates.

    ``cands_archs[b][t]`` / ``cands_bounds[b][t]`` are candidate b's per-node
    design for tier t (``n_ports`` == the tier's degree).  Results are
    index-aligned, row-independent (grouping never changes a candidate's
    numbers — each batched engine call is per-candidate exact), and
    mesh/engine-invariant, so serve-path chunking and the device mesh compose
    unchanged."""
    if cfg is None:
        cfg = NetSimConfig()
    cands_archs = [tuple(a) for a in cands_archs]
    cands_bounds = [tuple(b) for b in cands_bounds]
    n_cands = len(cands_archs)
    if n_cands == 0:
        return []
    for archs in cands_archs:
        _check_tiers(topo, archs)
    if hw is None:
        hw = _tier_hw(cands_archs, cands_bounds, back_annotation, i_burst)
    hw = [tuple(h) for h in hw]

    t0 = np.asarray(trace.time_s, np.float64)
    payload = np.asarray(trace.payload_bytes, np.int64)
    m = t0.size
    routes = fabric_routes(topo, trace)
    flat_archs = [tuple(flatten_tier_arch(a, tier.n_nodes)
                        for a, tier in zip(archs, topo.tiers))
                  for archs in cands_archs]

    arr = np.tile(t0, (n_cands, 1))            # current arrival per candidate
    # end-to-end latency accumulates as the ns-sum of per-hop latencies
    # (telescoping: each hop measures end+prop minus its own ingress time),
    # so a 1-hop fabric is bit-identical to the direct single-switch call
    e2e = np.zeros((n_cands, m))
    alive = np.ones((n_cands, m), bool)
    tier_drops = np.zeros((n_cands, topo.n_tiers), np.int64)

    for s in range(routes.max_hops):
        for t in range(topo.n_tiers):
            sel = np.nonzero(routes.tier_of[s] == t)[0]
            if sel.size == 0:
                continue
            tier = topo.tiers[t]
            n_flat = tier.n_nodes * tier.degree
            fin = routes.flat_in[s, sel]
            fout = routes.flat_out[s, sel]
            pay = payload[sel]
            # group candidates whose upstream histories agree bit-for-bit —
            # hop 0 is always one group, later hops share calls whenever
            # dynamics coincided upstream
            groups: Dict[bytes, List[int]] = {}
            times_rows = []
            for b in range(n_cands):
                row = _masked_times(arr[b, sel], alive[b, sel])
                times_rows.append(row)
                groups.setdefault(row.tobytes(), []).append(b)
            for members in groups.values():
                sub, perm = _sorted_subtrace(
                    f"{trace.name}@h{s}t{t}", times_rows[members[0]],
                    fin, fout, pay, n_flat, trace.link_gbps)
                res = run_netsim_batched(
                    [flat_archs[b][t] for b in members],
                    [cands_bounds[b][t] for b in members],
                    sub, hw=[hw[b][t] for b in members], cfg=cfg,
                    back_annotation=back_annotation, i_burst=i_burst,
                    mesh=mesh, use_kernel=use_kernel)
                for b, v in zip(members, res):
                    lat_pkt = np.empty(sel.size, np.float64)
                    lat_pkt[perm] = v.meta["latency_full_ns"]
                    was = alive[b, sel]
                    ok = was & ~np.isnan(lat_pkt)
                    arr[b, sel] = np.where(ok, arr[b, sel] + lat_pkt * 1e-9,
                                           arr[b, sel])
                    e2e[b, sel] = np.where(ok, e2e[b, sel] + lat_pkt,
                                           e2e[b, sel])
                    tier_drops[b, t] += int((was & ~ok).sum())
                    alive[b, sel] = ok

    # per-packet wire bytes at the final hop (what lands on the host's link)
    last = np.maximum(routes.n_hops - 1, 0)
    last_tier = routes.tier_of[last, np.arange(m)] if m else np.zeros(0, np.int64)
    t0_min = float(t0.min()) if m else 0.0

    out: List[VerifyResult] = []
    for b in range(n_cands):
        ok = alive[b]
        lat = e2e[b, ok]
        headers = np.array([cands_bounds[b][t].header_bytes
                            for t in range(topo.n_tiers)], np.int64)
        delivered_bits = float(((payload[ok] + headers[last_tier[ok]]) * 8).sum())
        t_end = float((arr[b, ok] - cfg.prop_delay_s).max()) if ok.any() else 0.0
        duration = max(t_end - t0_min, 1e-12)
        hops_ok = routes.n_hops[ok]
        out.append(VerifyResult(
            p99_latency_ns=float(np.percentile(lat, 99)) if lat.size else math.inf,
            mean_latency_ns=float(lat.mean()) if lat.size else math.inf,
            drop_rate=int((~ok).sum()) / max(m, 1),
            throughput_gbps=delivered_bits / duration / 1e9,
            meta={
                "latency_ns": lat,
                "latency_full_ns": np.where(ok, e2e[b], np.nan),
                "delivered": int(ok.sum()), "offered": int(m),
                "hw": hw[b], "engine": "fabric_netsim",
                "fabric": {
                    "p50_latency_ns": float(np.percentile(lat, 50)) if lat.size else math.inf,
                    "max_hops": int(routes.max_hops),
                    "mean_hops": float(hops_ok.mean()) if hops_ok.size else 0.0,
                    "per_tier_drops": [int(x) for x in tier_drops[b]],
                },
            }))
    return out


def surrogate_fabric_batched(
    topo: Topology,
    cands_archs: Sequence[Sequence[SwitchArch]],
    cands_bounds: Sequence[Sequence],
    trace,
    *,
    back_annotation: bool = False,
    i_burst: float = 1.0,
    mesh=None,
    use_kernel=False,
) -> List[SurrogateResult]:
    """Stage-2 screen for fabric candidates: one batched surrogate call per
    tier over that tier's *merged traversal trace* (every hop through the
    tier, at the packets' original injection times — candidate-independent,
    so the whole batch shares each tier's timeline and occupancy shapes).

    Per candidate the tier results combine into one fabric-level
    ``SurrogateResult``: end-to-end latency is the per-packet *sum* of its
    hop latencies (a screening proxy; stage 4 is authoritative), occupancy is
    a ``[n_tiers, max_len]`` NaN-padded stack — row t sizes tier t's buffers
    in ``FabricDSEProblem.size_buffers`` — and throughput is the bottleneck
    tier's."""
    cands_archs = [tuple(a) for a in cands_archs]
    cands_bounds = [tuple(b) for b in cands_bounds]
    n_cands = len(cands_archs)
    if n_cands == 0:
        return []
    for archs in cands_archs:
        _check_tiers(topo, archs)
    hw = _tier_hw(cands_archs, cands_bounds, back_annotation, i_burst)

    t0 = np.asarray(trace.time_s, np.float64)
    payload = np.asarray(trace.payload_bytes, np.int64)
    m = t0.size
    routes = fabric_routes(topo, trace)

    e2e = np.zeros((n_cands, m))
    tier_occ: List[Optional[np.ndarray]] = [None] * topo.n_tiers  # [B, m_t]
    tier_tput = np.full((n_cands, topo.n_tiers), np.inf)
    for t in range(topo.n_tiers):
        stage_idx, pkt_idx = np.nonzero(routes.tier_of == t)
        if pkt_idx.size == 0:
            continue
        tier = topo.tiers[t]
        n_flat = tier.n_nodes * tier.degree
        sub, perm = _sorted_subtrace(
            f"{trace.name}@tier{t}", t0[pkt_idx],
            routes.flat_in[stage_idx, pkt_idx],
            routes.flat_out[stage_idx, pkt_idx],
            payload[pkt_idx], n_flat, trace.link_gbps)
        res = run_surrogate_batched(
            [flatten_tier_arch(cands_archs[b][t], tier.n_nodes)
             for b in range(n_cands)],
            [cands_bounds[b][t] for b in range(n_cands)],
            sub, hw=[hw[b][t] for b in range(n_cands)],
            back_annotation=back_annotation, i_burst=i_burst,
            mesh=mesh, use_kernel=use_kernel).results()
        occ = np.empty((n_cands, pkt_idx.size))
        for b, sr in enumerate(res):
            lat_trav = np.empty(pkt_idx.size, np.float64)
            lat_trav[perm] = np.asarray(sr.latency_ns, np.float64)
            np.add.at(e2e[b], pkt_idx, lat_trav)
            q = np.empty(pkt_idx.size, np.float64)
            q[perm] = np.asarray(sr.q_occupancy, np.float64)
            occ[b] = q
            tier_tput[b, t] = sr.throughput_gbps
        tier_occ[t] = occ

    max_len = max((o.shape[1] for o in tier_occ if o is not None), default=1)
    out: List[SurrogateResult] = []
    for b in range(n_cands):
        stack = np.full((topo.n_tiers, max_len), np.nan)
        for t, o in enumerate(tier_occ):
            if o is not None:
                stack[t, :o.shape[1]] = o[b]
        tput = tier_tput[b][np.isfinite(tier_tput[b])]
        out.append(SurrogateResult(
            q_occupancy=stack,
            latency_ns=e2e[b],
            throughput_gbps=float(tput.min()) if tput.size else 0.0,
            meta={"engine": "fabric_surrogate", "batched": True,
                  "n_tiers": topo.n_tiers, "max_hops": int(routes.max_hops)}))
    return out
