"""Joint per-tier fabric DSE: one genome, one design per topology tier.

``FabricDSEProblem`` lifts the single-switch Progressive-Constraint-
Satisfaction problem to a network: each tier of the topology is its own
(arch, protocol) design point, evaluated end-to-end by the multi-hop
composition in ``fabric.evaluate``.  The genome is the per-tier splice —
tier t's architecture genes (and, under co-design, its ``proto:*`` layout
genes) ride the same NSGA-II genome under the ``t{t}:`` prefix, the exact
analogue of how ``PROTO_DIM_PREFIX`` splices protocol genes next to
architecture genes for one switch.

Each tier is internally a ``SwitchDSEProblem`` over a request with
``n_ports = tier.degree`` — re-using its decode memoisation, static timing,
sizing and pricing verbatim — with one fabric twist: the routing field must
address the *fabric host count*, not the tier's local port count
(``addressing_ports`` override), because a packet's destination id names a
host anywhere in the network.
"""

from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.archspec import ArchRequest, SwitchArch, VOQKind
from repro.core.dse import (DSEProblem, SurrogateResult, VerifyResult,
                            depth_for_drop_rate)
from repro.core.search import DesignSpace, Dim
from repro.sim.netsim import NetSimConfig
from repro.sim.resources import synthesize
from repro.sim.switch_problem import CoDesignCandidate, SwitchDSEProblem

from .evaluate import evaluate_fabric_batched, surrogate_fabric_batched
from .topology import Topology

__all__ = ["FabricCandidate", "FabricDSEProblem", "TIER_DIM_PREFIX"]


def TIER_DIM_PREFIX(tier: int) -> str:
    """Genome dimension-name prefix for tier ``tier``'s genes (checkpoint
    signatures include it, so per-tier resume round-trips by name)."""
    return f"t{tier}:"


@dataclasses.dataclass(frozen=True, eq=False)
class FabricCandidate:
    """One joint fabric phenotype: a per-tier tuple of single-switch
    candidates (``SwitchArch`` templates in classic mode,
    ``CoDesignCandidate`` phenotypes under co-design).  Identity is the
    tier tuple — both element kinds hash on their own phenotype identity,
    so search dedupe and checkpoint equivalence compose tier-wise."""

    tiers: Tuple[Any, ...]

    def __hash__(self):
        return hash(self.tiers)

    def __eq__(self, other):
        return isinstance(other, FabricCandidate) and self.tiers == other.tiers

    @property
    def infeasible(self) -> Optional[str]:
        for t, c in enumerate(self.tiers):
            if isinstance(c, CoDesignCandidate) and c.infeasible is not None:
                return f"tier {t}: {c.infeasible}"
        return None

    def short(self) -> str:
        return " || ".join(
            c.short() if hasattr(c, "short") else str(c) for c in self.tiers)


class _TierProblem(SwitchDSEProblem):
    """One tier's slice of the fabric problem: a stock switch problem over
    ``n_ports = degree``, minus ``VOQKind.SHARED`` (super-switch flattening
    would pool the shared cap across the tier's nodes — see
    ``fabric.evaluate``), plus fabric-wide routing addressability."""

    def __init__(self, *args, fabric_hosts: int, **kwargs):
        self._fabric_hosts = fabric_hosts
        super().__init__(*args, **kwargs)

    @property
    def addressing_ports(self) -> int:
        return self._fabric_hosts

    def candidates(self) -> List[SwitchArch]:
        return [a for a in super().candidates()
                if a.voq is not VOQKind.SHARED]

    def space(self, **kwargs) -> DesignSpace:
        base = super().space(**kwargs)
        dims = tuple(
            d if d.name != "voq" else Dim(
                "voq",
                tuple(v for v in d.choices if v is not VOQKind.SHARED)
                or (VOQKind.NXN,))
            for d in base.dims)
        return DesignSpace(dims)


class FabricDSEProblem(DSEProblem):
    """End-to-end multi-hop DSE over a topology of co-designed tiers.

    Same constructor vocabulary as ``SwitchDSEProblem`` plus the topology;
    ``request`` is the per-tier policy template — each tier's request is the
    template with ``n_ports`` set to that tier's degree, so one scenario
    parameterises every tier (homogeneous-tier: one design *per tier*, all
    nodes of a tier identical — the searchable fabric remains tractable
    while tiers still specialise independently).

    Objectives are the acceptance pair (end-to-end p99 latency, total fabric
    LUTs) with drop rate and the remaining tier resources carried through
    the SLA/budget constraints — sizing, screening and verification all run
    through the fabric-level evaluators."""

    def __init__(
        self,
        topology: Topology,
        request: ArchRequest,
        bound,
        trace,
        *,
        back_annotation: bool = True,
        headroom: float = 1.25,
        features=None,
        verify_engine: str = "netsim",
        protocol_space=None,
        binding=None,
        flit_bits: Optional[int] = None,
        require_seq: bool = False,
        mesh=None,
        use_kernel: str = "auto",
    ):
        if verify_engine not in ("netsim", "auto"):
            raise ValueError(
                f"fabric evaluation verifies with the batched netsim engine; "
                f"verify_engine={verify_engine!r} is not supported (the "
                f"cycle-accurate rung models one datapath, not a network)")
        self.topology = topology
        self.request = request
        self.trace = trace
        self.verify_engine = verify_engine
        self.tier_problems: List[_TierProblem] = []
        for tier in topology.tiers:
            tp = _TierProblem(
                dataclasses.replace(request, n_ports=tier.degree),
                bound, trace,
                fabric_hosts=topology.n_hosts,
                back_annotation=back_annotation, headroom=headroom,
                features=features, verify_engine="netsim",
                protocol_space=protocol_space, binding=binding,
                flit_bits=flit_bits, require_seq=require_seq,
                mesh=mesh, use_kernel=use_kernel)
            # campaigns analyze the trace once; later tiers share tier 0's
            features = tp.features
            self.tier_problems.append(tp)
        t0 = self.tier_problems[0]
        self.features = t0.features
        self.mesh_spec = t0.mesh_spec
        self.back_annotation = back_annotation
        self.headroom = headroom
        self.use_kernel = use_kernel
        self.bound = t0.bound
        self.cfg = NetSimConfig()

    @property
    def co_design(self) -> bool:
        return self.tier_problems[0].co_design

    @property
    def n_tiers(self) -> int:
        return self.topology.n_tiers

    # ------------------------------------------------------------ plumbing
    def _tier_archs(self, cand: FabricCandidate) -> Tuple[SwitchArch, ...]:
        return tuple(SwitchDSEProblem._arch(c) for c in cand.tiers)

    def _tier_bounds(self, cand: FabricCandidate):
        return tuple(p._bound_for(c)
                     for p, c in zip(self.tier_problems, cand.tiers))

    # ------------------------------------------------------------- stage 1
    def candidates(self) -> List[FabricCandidate]:
        """Exhaustive baseline: the cross product of the per-tier template
        enumerations (small explicit requests only — AUTO-heavy requests are
        search territory, exactly as for one switch)."""
        per_tier = [p.candidates() for p in self.tier_problems]
        return [FabricCandidate(tiers=combo)
                for combo in itertools.product(*per_tier)]

    def static_timing(self, cand: FabricCandidate) -> Tuple[float, float]:
        """Ratio encoding of the per-tier conjunction: every tier must clear
        its own line-rate bound, so stage 1's single ``t_proc <= (1+δ)·t_arr``
        comparison receives ``(max_t t_proc/t_arr, 1.0)`` — the fabric passes
        iff its slowest tier passes."""
        worst = 0.0
        for p, c in zip(self.tier_problems, cand.tiers):
            t_proc, t_arr = p.static_timing(c)
            if not math.isfinite(t_proc):
                return math.inf, 1.0
            worst = max(worst, t_proc / t_arr)
        return worst, 1.0

    # ------------------------------------------------------ search support
    def space(self) -> DesignSpace:
        """The per-tier splice: tier t's dims (architecture + ``proto:*``
        layout genes under co-design) join the genome under the ``t{t}:``
        prefix."""
        dims: List[Dim] = []
        for t, p in enumerate(self.tier_problems):
            prefix = TIER_DIM_PREFIX(t)
            dims.extend(Dim(prefix + d.name, d.choices)
                        for d in p.space().dims)
        return DesignSpace(tuple(dims))

    def decode(self, assignment: Dict[str, Any]) -> FabricCandidate:
        tiers = []
        for t, p in enumerate(self.tier_problems):
            prefix = TIER_DIM_PREFIX(t)
            sub = {k[len(prefix):]: v for k, v in assignment.items()
                   if k.startswith(prefix)}
            tiers.append(p.decode(sub))
        return FabricCandidate(tiers=tuple(tiers))

    # ------------------------------------------------------------- stage 2
    def surrogate(self, cand: FabricCandidate) -> SurrogateResult:
        return self.surrogate_batch([cand])[0]

    def surrogate_batch(self, cands) -> List[SurrogateResult]:
        cands = list(cands)
        if not cands:
            return []
        return surrogate_fabric_batched(
            self.topology,
            [self._tier_archs(c) for c in cands],
            [self._tier_bounds(c) for c in cands],
            self.trace,
            back_annotation=self.back_annotation,
            i_burst=self.features.i_burst,
            mesh=self.mesh_spec, use_kernel=self.use_kernel)

    # ------------------------------------------------------------- stage 3
    def size_buffers(self, cand: FabricCandidate, q_occupancy: np.ndarray,
                     eps: float) -> FabricCandidate:
        """Row t of the ``[n_tiers, max_len]`` NaN-padded occupancy stack
        sizes tier t's VOQ depth through the stock per-tier sizing rule; a
        tier no route traverses (all-NaN row) keeps its template depth."""
        stack = np.atleast_2d(np.asarray(q_occupancy, np.float64))
        sized = []
        for t, (p, c) in enumerate(zip(self.tier_problems, cand.tiers)):
            row = stack[t] if t < stack.shape[0] else np.array([])
            row = row[np.isfinite(row)]
            if row.size == 0:
                sized.append(c)
                continue
            sized.append(p.size_buffers(c, row, eps))
        return FabricCandidate(tiers=tuple(sized))

    def resources(self, cand: FabricCandidate) -> Dict[str, float]:
        """Summed tier resources: per-node synthesis × node count, totalled
        over tiers — the whole fabric's silicon."""
        tot = {"luts": 0.0, "ffs": 0.0, "brams": 0.0}
        for tier, p, c in zip(self.topology.tiers, self.tier_problems,
                              cand.tiers):
            rep = synthesize(SwitchDSEProblem._arch(c), p._bound_for(c))
            tot["luts"] += rep.luts * tier.n_nodes
            tot["ffs"] += rep.ffs * tier.n_nodes
            tot["brams"] += rep.brams * tier.n_nodes
        tot["bram"] = tot["brams"]
        return tot

    # ------------------------------------------------------------- stage 4
    def verify(self, cand: FabricCandidate) -> VerifyResult:
        return self.verify_batch([cand])[0]

    def verify_batch(self, cands) -> List[VerifyResult]:
        cands = list(cands)
        if not cands:
            return []
        return evaluate_fabric_batched(
            self.topology,
            [self._tier_archs(c) for c in cands],
            [self._tier_bounds(c) for c in cands],
            self.trace,
            cfg=self.cfg,
            back_annotation=self.back_annotation,
            i_burst=self.features.i_burst,
            mesh=self.mesh_spec, use_kernel=self.use_kernel)

    # ------------------------------------------------------------- ranking
    def surrogate_objectives(self, cand, sr: SurrogateResult):
        return (sr.p(99), self.resources(cand)["luts"])

    def objectives(self, cand, v: VerifyResult) -> Tuple[float, float]:
        # the acceptance pair: end-to-end tail latency vs total fabric LUTs
        return (v.p99_latency_ns, self.resources(cand)["luts"])

    def diversity_key(self, cand: FabricCandidate):
        return tuple((SwitchDSEProblem._arch(c).sched,
                      SwitchDSEProblem._arch(c).voq) for c in cand.tiers)
