"""Fault tolerance: supervisor (restart), straggler watch, elastic re-mesh."""
from .elastic import remesh, scaled_microbatches, shardings_for
from .supervisor import (FaultInjector, NodeFailure, RunResult, StragglerWatch,
                         Supervisor)
__all__ = ["FaultInjector", "NodeFailure", "RunResult", "StragglerWatch",
           "Supervisor", "remesh", "scaled_microbatches", "shardings_for"]
