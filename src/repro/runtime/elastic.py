"""Elastic re-meshing: resume a run on a different device count.

Checkpoints are mesh-agnostic (host-gathered arrays, named-axis specs), so
shrinking 512→256 chips (pod loss) or growing back is: rebuild mesh →
rebuild NamedShardings from the same PartitionSpec tree → device_put.
Global batch is preserved by rescaling microbatches (same math, new layout).
"""

from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
from jax.sharding import NamedSharding

__all__ = ["remesh", "shardings_for"]


def shardings_for(mesh, specs):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))


def remesh(state: Any, specs: Any, new_mesh) -> Any:
    """Move a (host or device) state pytree onto a new mesh."""
    shardings = shardings_for(new_mesh, specs)
    return jax.tree.map(
        lambda leaf, sh: jax.device_put(jax.device_get(leaf), sh), state, shardings)


def scaled_microbatches(old_microbatches: int, old_dp: int, new_dp: int) -> int:
    """Keep the global batch fixed when the data-parallel extent changes."""
    scaled = old_microbatches * old_dp
    assert scaled % new_dp == 0, (old_microbatches, old_dp, new_dp)
    return max(1, scaled // new_dp)
