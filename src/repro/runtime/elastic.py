"""Elastic re-meshing: resume a run on a different device count.

Checkpoints are mesh-agnostic (host-gathered arrays, named-axis specs), so
shrinking 512→256 chips (pod loss) or growing back is: rebuild mesh →
rebuild NamedShardings from the same PartitionSpec tree → device_put.
Global batch is preserved by rescaling microbatches (same math, new layout).
"""

from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
from jax.sharding import NamedSharding

__all__ = ["remesh", "shardings_for"]


def shardings_for(mesh, specs):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))


def _validate_remesh_target(new_mesh) -> None:
    """Refuse meshes that would produce silently-wrong shardings.

    Two historical failure modes: a target mesh claiming more devices than
    the runtime actually has (device_put then scatters onto a stale device
    list) and a zero-extent axis (every sharding along it is degenerate).
    Both now raise with the numbers named instead."""
    for name, extent in zip(new_mesh.axis_names, new_mesh.devices.shape):
        if extent < 1:
            raise ValueError(
                f"remesh target axis {name!r} has extent {extent}; every "
                f"mesh axis needs extent >= 1 "
                f"(shape={tuple(new_mesh.devices.shape)})")
    needed = int(new_mesh.devices.size)
    available = jax.device_count()
    if needed > available:
        raise ValueError(
            f"remesh target mesh needs {needed} devices but only "
            f"{available} are available")


def remesh(state: Any, specs: Any, new_mesh) -> Any:
    """Move a (host or device) state pytree onto a new mesh."""
    _validate_remesh_target(new_mesh)
    shardings = shardings_for(new_mesh, specs)
    return jax.tree.map(
        lambda leaf, sh: jax.device_put(jax.device_get(leaf), sh), state, shardings)


def scaled_microbatches(old_microbatches: int, old_dp: int, new_dp: int) -> int:
    """Keep the global batch fixed when the data-parallel extent changes."""
    scaled = old_microbatches * old_dp
    assert scaled % new_dp == 0, (old_microbatches, old_dp, new_dp)
    return max(1, scaled // new_dp)
