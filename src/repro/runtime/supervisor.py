"""Fault-tolerant run supervisor: checkpoint/restart, straggler watch.

At 1000+ nodes the dominant failure mode is a lost worker; the contract here
is the standard one: training state is *only* (params, opt_state, data_step),
every piece of it restores from the last atomic checkpoint, and the outer
loop survives any number of step-level failures up to ``max_restarts``.

``FaultInjector`` provides deterministic failure/straggler injection so the
restart and mitigation paths are *tested*, not just written (see
tests/test_runtime.py).  The straggler policy is EWMA step-time tracking with
a deadline multiple: on breach the supervisor records the event and invokes
the mitigation hook (on real fleets: re-dispatch the slice / swap in a hot
spare; on CPU: the hook is observed by tests).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

from repro.checkpoint.store import AsyncCheckpointer, latest_step, restore

__all__ = ["NodeFailure", "FaultInjector", "StragglerWatch", "Supervisor", "RunResult"]


class NodeFailure(RuntimeError):
    """Raised by the fault injector (stands in for a lost TPU slice)."""


@dataclasses.dataclass
class FaultInjector:
    """Deterministic failure schedule: {step: kind} with kind in
    {"crash", "straggle:<seconds>"}."""

    schedule: Dict[int, str] = dataclasses.field(default_factory=dict)
    fired: List[int] = dataclasses.field(default_factory=list)

    def maybe_fire(self, step: int):
        kind = self.schedule.get(step)
        if kind is None or step in self.fired:
            return
        self.fired.append(step)
        if kind == "crash":
            raise NodeFailure(f"injected node failure at step {step}")
        if kind.startswith("straggle:"):
            time.sleep(float(kind.split(":")[1]))


@dataclasses.dataclass
class StragglerWatch:
    deadline_multiple: float = 3.0
    ewma_alpha: float = 0.2
    _ewma: Optional[float] = None
    events: List[Dict] = dataclasses.field(default_factory=list)

    def observe(self, step: int, dt: float, on_straggler: Optional[Callable] = None):
        if self._ewma is None:
            self._ewma = dt
            return False
        breach = dt > self.deadline_multiple * self._ewma
        if breach:
            self.events.append({"step": step, "dt": dt, "ewma": self._ewma})
            if on_straggler:
                on_straggler(step, dt, self._ewma)
        # slow samples leak into the EWMA slowly; healthy ones dominate
        self._ewma = (1 - self.ewma_alpha) * self._ewma + self.ewma_alpha * min(
            dt, 2 * self._ewma)
        return breach


@dataclasses.dataclass
class RunResult:
    final_step: int
    restarts: int
    straggler_events: List[Dict]
    metrics_history: List[Dict]


class Supervisor:
    def __init__(
        self,
        ckpt_dir: str,
        *,
        ckpt_every: int = 50,
        max_restarts: int = 5,
        injector: Optional[FaultInjector] = None,
        straggler: Optional[StragglerWatch] = None,
    ):
        self.ckpt = AsyncCheckpointer(ckpt_dir)
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.max_restarts = max_restarts
        self.injector = injector
        self.straggler = straggler or StragglerWatch()

    def run(
        self,
        state: Any,                       # pytree: (params, opt_state, ...)
        step_fn: Callable[[Any, int], Any],   # (state, step) -> (state, metrics)
        *,
        start_step: int = 0,
        total_steps: int = 100,
        on_straggler: Optional[Callable] = None,
    ) -> RunResult:
        restarts = 0
        history: List[Dict] = []
        step = start_step
        # resume if a checkpoint exists
        last = latest_step(self.ckpt_dir)
        if last is not None and last > step:
            state, _ = restore(self.ckpt_dir, last, template=state)
            step = last
        while step < total_steps:
            try:
                t0 = time.time()
                if self.injector:
                    self.injector.maybe_fire(step)
                state, metrics = step_fn(state, step)
                dt = time.time() - t0
                self.straggler.observe(step, dt, on_straggler)
                history.append({"step": step, **{k: float(v) for k, v in metrics.items()}})
                step += 1
                if step % self.ckpt_every == 0 or step == total_steps:
                    self.ckpt.save_async(step, state, extra={"step": step})
            except NodeFailure:
                restarts += 1
                if restarts > self.max_restarts:
                    raise
                self.ckpt.wait()
                last = latest_step(self.ckpt_dir)
                if last is not None:
                    state, _ = restore(self.ckpt_dir, last, template=state)
                    step = last
                # else: restart from the initial state at start_step
        self.ckpt.wait()
        return RunResult(final_step=step, restarts=restarts,
                         straggler_events=self.straggler.events,
                         metrics_history=history)
