"""Deterministic synthetic data pipeline with exact-resume semantics."""
from .pipeline import DataConfig, SyntheticLM, make_batch_for_shape
__all__ = ["DataConfig", "SyntheticLM", "make_batch_for_shape"]
