"""Deterministic synthetic data pipeline with exact-resume semantics.

Production posture: the loader is a pure function of (seed, step, shard), so
a restarted job resumes mid-epoch with zero duplication/loss — checkpointing
stores only the step counter.  Token streams are generated from a seeded
Zipf-ish unigram mixture with Markov bigram structure so losses actually
*decrease* during the example runs (pure-uniform tokens would pin loss at
ln V and hide training bugs).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np

__all__ = ["DataConfig", "SyntheticLM", "make_batch_for_shape"]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    frontend: str = "tokens"      # tokens | embeddings
    d_model: int = 0              # for embedding frontends
    mrope: bool = False


class SyntheticLM:
    """Stateless-per-step loader: batch(step) is pure, resume = set step."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        root = np.random.default_rng(cfg.seed)
        v = cfg.vocab
        # fixed Markov structure: each token prefers a small successor set
        self._succ = root.integers(0, v, size=(v, 4))
        self._unigram = root.zipf(1.3, size=v * 4) % v

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        b, s, v = cfg.global_batch, cfg.seq_len, cfg.vocab
        toks = np.empty((b, s + 1), np.int32)
        toks[:, 0] = rng.integers(0, v, size=b)
        follow = rng.random((b, s)) < 0.7
        nxt_choice = rng.integers(0, 4, size=(b, s))
        rand_tok = self._unigram[rng.integers(0, self._unigram.size, size=(b, s))]
        for t in range(s):
            nxt = self._succ[toks[:, t], nxt_choice[:, t]]
            toks[:, t + 1] = np.where(follow[:, t], nxt, rand_tok[:, t])
        out: Dict[str, np.ndarray] = {"labels": toks[:, 1:].astype(np.int32)}
        if cfg.frontend == "tokens":
            out["tokens"] = toks[:, :-1].astype(np.int32)
        else:
            emb_rng = np.random.default_rng((cfg.seed, step, 7))
            out["embeddings"] = emb_rng.normal(
                0, 1, size=(b, s, cfg.d_model)).astype(np.float32)
        if cfg.mrope:
            base = np.broadcast_to(np.arange(s, dtype=np.int32)[None], (b, s))
            out["positions3"] = np.stack([base, base, base], axis=1)
        return out

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


def make_batch_for_shape(cfg_model, shape, seed: int = 0) -> Dict[str, np.ndarray]:
    """One concrete batch matching a dry-run ShapeSpec (for smoke runs)."""
    dc = DataConfig(vocab=cfg_model.vocab, seq_len=shape.seq_len,
                    global_batch=shape.global_batch, seed=seed,
                    frontend=cfg_model.frontend, d_model=cfg_model.d_model,
                    mrope=cfg_model.mrope)
    return SyntheticLM(dc).batch(0)
