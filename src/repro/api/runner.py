"""Scenario execution: one spec in, a structured ``ScenarioReport`` out —
and campaign-level fan-out that batches stage 2 across scenarios.

``run_scenario`` is semantically identical to the legacy hand-wired path
(``optimize_switch`` / ``autotune_moe``): it builds the protocol, binds it,
materialises the trace, instantiates the domain's ``DSEProblem`` and runs
Algorithm 1 with the scenario's SLA/budget/fidelity.  The legacy wrappers
remain as thin compatibility shims over the same machinery.

``run_campaign`` exploits the staged DSE (``repro.core.dse``): it prunes
every scenario (stage 1), fans *all* scenarios' surviving candidates through
the batched surrogate engine (stage 2), sizes each scenario's survivors
(stage 3), then fans *all* scenarios' sized candidates through the batched
stage-4 verifier — at both batched stages, scenarios that share a trace and
a bound protocol share one jitted call, and every scenario reuses a cached
trace + feature analysis.  The campaign report carries aggregate stage-2
*and* stage-4 throughput (candidates/sec across the whole campaign).
"""

from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

from repro.core.binding import BoundProtocol, bind
from repro.core.dse import (DSEProblem, DSEResult, ResourceBudget, SLA,
                            StageLog, SurrogateResult, VerifyResult,
                            finalize_result, stage1_static, stage2_screen,
                            stage3_size, stage4_verify)
from repro.core.search import SearchDriver, run_search

from .registry import registry
from .scenario import MeshSpec, Scenario

__all__ = ["ScenarioReport", "CampaignReport", "build_bound", "build_problem",
           "run_scenario", "run_campaign"]


# --------------------------------------------------------------------------
# problem construction
# --------------------------------------------------------------------------

def build_bound(scenario: Scenario) -> BoundProtocol:
    """Protocol spec → built ``Protocol`` → semantic binding (§III-A)."""
    return bind(scenario.protocol.build(), scenario.semantic_binding(),
                flit_bits=scenario.flit_bits)


def _validate_addressing(scenario: Scenario, bound: BoundProtocol) -> None:
    """Fail at scenario-build time if an address field cannot address every
    port — ``compressed_protocol(addr_bits=2)`` on an 8-port scenario used to
    run (and silently alias destinations via ``dst % n_ports``).  Same rule
    the co-design stage-1 prune applies (``address_width_error``).  On a
    fabric scenario the routing field must address every *host in the
    topology*, not one switch's ports (``spac check`` SPAC106)."""
    from repro.core.dsl import address_width_error
    n = (scenario.topology.build().n_hosts if scenario.topology is not None
         else scenario.arch.n_ports)
    for sem in ("routing_key", "src_key"):
        if not bound.has(sem):
            continue
        f = bound.protocol.field(bound.semantics[sem])
        err = address_width_error(sem, f.name, f.bits, n)
        if err is not None:
            raise ValueError(
                f"scenario {scenario.name!r}: protocol "
                f"{bound.protocol.name!r} {err}; widen the field")


def _default_budget(scenario: Scenario) -> ResourceBudget:
    if scenario.domain == "comm":
        return ResourceBudget({"bytes_per_device": 4e9})
    from repro.sim.resources import ALVEO_U45N
    if scenario.topology is not None:
        # fabric resources are summed over every switch; the default budget
        # is one FPGA card per node
        n_nodes = sum(t.n_nodes for t in scenario.topology.build().tiers)
        return ResourceBudget({k: v * n_nodes for k, v in ALVEO_U45N.items()})
    return ResourceBudget(dict(ALVEO_U45N))


def _build_comm_problem(scenario: Scenario) -> DSEProblem:
    import jax
    import jax.numpy as jnp

    from repro.comm.dse_comm import CommDSEProblem
    from repro.launch.mesh import compat_make_mesh
    from repro.models import SINGLE_POD_PLAN, ModelConfig
    from repro.models.moe import init_moe

    c = scenario.comm
    mesh = compat_make_mesh((1, 1), ("data", "model"))
    cfg = ModelConfig(name=scenario.name, family="moe", n_layers=1,
                      d_model=c.d_model, n_heads=c.n_heads,
                      n_kv_heads=c.n_kv_heads, d_ff=c.d_ff, vocab=c.vocab,
                      moe_experts=c.moe_experts, moe_topk=c.moe_topk,
                      router=c.router)
    plan = SINGLE_POD_PLAN
    params, _ = init_moe(jax.random.PRNGKey(c.seed), cfg, plan)
    x = jax.random.normal(jax.random.PRNGKey(c.seed + 1),
                          (c.batch, c.seq, c.d_model), jnp.bfloat16)
    return CommDSEProblem(params, cfg, plan, mesh, x, model_tp=c.model_tp)


def build_problem(
    scenario: Scenario,
    *,
    trace=None,
    features=None,
    mesh=None,
) -> Tuple[DSEProblem, SLA, ResourceBudget]:
    """Materialise the scenario into a ready-to-run ``DSEProblem``.

    ``trace``/``features`` let a campaign hand scenarios that share a
    ``TraceSpec`` one built trace and one feature analysis.  ``mesh``
    (a ``MeshSpec`` or device count) overrides ``scenario.mesh``; either
    shards the batched stages across the device mesh, with results
    bit-identical to the serial default.
    """
    mesh = MeshSpec.coerce(mesh) if mesh is not None else scenario.mesh
    budget = scenario.budget or _default_budget(scenario)
    if scenario.domain == "comm":
        return _build_comm_problem(scenario), scenario.sla, budget
    from repro.sim.switch_problem import SwitchDSEProblem
    tr = trace if trace is not None else scenario.trace.build()
    if scenario.topology is not None:
        from repro.fabric import FabricDSEProblem
        topo = scenario.topology.build()
        if scenario.co_design:
            if scenario.search is None:
                raise ValueError(
                    f"scenario {scenario.name!r}: co_design joint spaces are "
                    "generational-search territory — set a SearchSpec "
                    "(spac run --co-design --search nsga2)")
            problem = FabricDSEProblem(
                topo, scenario.arch, None, tr,
                back_annotation=scenario.fidelity.back_annotation,
                features=features,
                verify_engine=scenario.fidelity.verify_engine,
                use_kernel=scenario.fidelity.use_kernel,
                protocol_space=scenario.protocol.space(),
                binding=scenario.semantic_binding(),
                flit_bits=scenario.flit_bits,
                mesh=mesh)
            return problem, scenario.sla, budget
        bound = build_bound(scenario)
        _validate_addressing(scenario, bound)
        problem = FabricDSEProblem(
            topo, scenario.arch, bound, tr,
            back_annotation=scenario.fidelity.back_annotation,
            features=features,
            verify_engine=scenario.fidelity.verify_engine,
            use_kernel=scenario.fidelity.use_kernel,
            mesh=mesh)
        return problem, scenario.sla, budget
    if scenario.co_design:
        if scenario.search is None:
            raise ValueError(
                f"scenario {scenario.name!r}: co_design joint spaces are "
                "generational-search territory — set a SearchSpec "
                "(spac run --co-design --search nsga2)")
        problem = SwitchDSEProblem(
            scenario.arch, None, tr,
            back_annotation=scenario.fidelity.back_annotation,
            features=features,
            verify_engine=scenario.fidelity.verify_engine,
            use_kernel=scenario.fidelity.use_kernel,
            protocol_space=scenario.protocol.space(),
            binding=scenario.semantic_binding(),
            flit_bits=scenario.flit_bits,
            mesh=mesh)
        return problem, scenario.sla, budget
    bound = build_bound(scenario)
    _validate_addressing(scenario, bound)
    problem = SwitchDSEProblem(
        scenario.arch, bound, tr,
        back_annotation=scenario.fidelity.back_annotation,
        features=features,
        verify_engine=scenario.fidelity.verify_engine,
        use_kernel=scenario.fidelity.use_kernel,
        mesh=mesh)
    return problem, scenario.sla, budget


# --------------------------------------------------------------------------
# reports
# --------------------------------------------------------------------------

def _short(cand: Any) -> str:
    fn = getattr(cand, "short", None)
    return fn() if callable(fn) else repr(cand)


def _verify_dict(v: VerifyResult) -> Dict[str, Any]:
    d: Dict[str, Any] = {
        "p99_latency_ns": float(v.p99_latency_ns),
        "mean_latency_ns": float(v.mean_latency_ns),
        "drop_rate": float(v.drop_rate),
        "throughput_gbps": float(v.throughput_gbps),
    }
    fab = v.meta.get("fabric") if isinstance(v.meta, dict) else None
    if fab is not None:
        # end-to-end multi-hop metrics the single-switch path cannot express
        d["fabric"] = {
            "p50_latency_ns": float(fab["p50_latency_ns"]),
            "max_hops": int(fab["max_hops"]),
            "mean_hops": float(fab["mean_hops"]),
            "per_tier_drops": [int(x) for x in fab["per_tier_drops"]],
        }
    return d


def _protocol_dict(bound: Optional[BoundProtocol]) -> Optional[Dict[str, Any]]:
    """The winning wire layout, serialized field-by-field (report/golden)."""
    if bound is None:
        return None
    p = bound.protocol
    return {
        "name": p.name,
        "header_bits": int(p.header_bits),
        "header_bytes": int(p.header_bytes),
        "fields": [{"name": f.name, "bits": f.bits, "semantic": f.semantic}
                   for f in p.fields],
    }


@dataclasses.dataclass
class ScenarioReport:
    """Structured outcome of one scenario: Pareto front, best arch, verify
    metrics, resource report, stage logs.  ``problem``/``result`` are the
    live objects for further poking; ``to_dict()`` is the serializable view."""

    scenario: Scenario
    result: DSEResult
    problem: DSEProblem
    wall_time_s: float
    stage2_candidates: int = 0
    stage2_time_s: float = 0.0
    stage4_candidates: int = 0
    stage4_time_s: float = 0.0

    @property
    def best(self) -> Optional[Any]:
        return self.result.best

    @property
    def best_verify(self) -> Optional[VerifyResult]:
        return self.result.best_verify

    @property
    def pareto(self) -> List[Tuple[Any, VerifyResult]]:
        return self.result.pareto

    @property
    def resources(self) -> Dict[str, float]:
        if self.result.best is None:
            return {}
        return {k: float(v)
                for k, v in self.problem.resources(self.result.best).items()}

    @property
    def stage2_cands_per_sec(self) -> float:
        return self.stage2_candidates / max(self.stage2_time_s, 1e-12)

    @property
    def best_bound(self) -> Optional[BoundProtocol]:
        """The winning design's bound protocol: the co-design candidate's own
        decoded layout, or the scenario's fixed protocol (switch domain)."""
        if self.result.best is None:
            return None
        own = getattr(self.result.best, "bound", None)
        return own if own is not None else getattr(self.problem, "bound", None)

    def summary(self) -> str:
        head = (f"scenario {self.scenario.name!r} [{self.scenario.domain}] "
                f"({self.wall_time_s:.2f}s)")
        lines = [head, self.result.summary()]
        bound = self.best_bound
        if bound is not None and self.scenario.co_design:
            p = bound.protocol
            lines.append(
                f"  protocol: {p.name} — {p.header_bits} header bits "
                f"({p.header_bytes} B on the wire)")
        res = self.resources
        if res:
            lines.append("  resources: " + " ".join(
                f"{k}={v:,.0f}" for k, v in sorted(res.items()) if k != "bram"))
        return "\n".join(lines)

    def to_dict(self) -> Dict[str, Any]:
        return {
            "scenario": self.scenario.to_dict(),
            "best": _short(self.result.best) if self.result.best is not None else None,
            "best_verify": (_verify_dict(self.result.best_verify)
                            if self.result.best_verify is not None else None),
            "best_protocol": _protocol_dict(self.best_bound),
            "resources": self.resources,
            "pareto": [
                {"candidate": _short(a), **_verify_dict(v)}
                for a, v in self.result.pareto
            ],
            "stages": [
                {"stage": lg.stage, "considered": lg.considered,
                 "survived": lg.survived, "notes": list(lg.notes)}
                for lg in self.result.logs
            ],
            "n_verified": len(self.result.evaluated),
            "wall_time_s": self.wall_time_s,
            "stage2_candidates": self.stage2_candidates,
            "stage2_time_s": self.stage2_time_s,
            "stage4_candidates": self.stage4_candidates,
            "stage4_time_s": self.stage4_time_s,
        }


@dataclasses.dataclass
class CampaignReport:
    """Per-scenario reports + aggregate batched stage-2/4 throughput."""

    name: str
    reports: List[ScenarioReport]
    stage2_candidates: int
    stage2_time_s: float
    stage2_batches: int
    shared_trace_scenarios: int      # scenarios that reused a cached trace
    wall_time_s: float
    stage4_candidates: int = 0
    stage4_time_s: float = 0.0
    stage4_batches: int = 0

    @property
    def stage2_cands_per_sec(self) -> float:
        return self.stage2_candidates / max(self.stage2_time_s, 1e-12)

    @property
    def stage4_cands_per_sec(self) -> float:
        return self.stage4_candidates / max(self.stage4_time_s, 1e-12)

    def __getitem__(self, name: str) -> ScenarioReport:
        for r in self.reports:
            if r.scenario.name == name:
                return r
        raise KeyError(name)

    def summary(self) -> str:
        lines = [f"campaign {self.name!r}: {len(self.reports)} scenarios "
                 f"in {self.wall_time_s:.2f}s"]
        for r in self.reports:
            best = _short(r.best) if r.best is not None else "infeasible"
            v = r.best_verify
            tail = (f" p99={v.p99_latency_ns:.0f}ns drop={v.drop_rate:.1e}"
                    if v is not None else "")
            lines.append(f"  {r.scenario.name:16s} -> {best}{tail}")
        lines.append(
            f"  stage-2 fan-out: {self.stage2_candidates} candidates in "
            f"{self.stage2_batches} batched calls, {self.stage2_time_s*1e3:.1f}ms "
            f"({self.stage2_cands_per_sec:.0f} cand/s aggregate; "
            f"{self.shared_trace_scenarios} scenario(s) shared a trace)")
        lines.append(
            f"  stage-4 fan-out: {self.stage4_candidates} sized candidates in "
            f"{self.stage4_batches} batched calls, {self.stage4_time_s*1e3:.1f}ms "
            f"({self.stage4_cands_per_sec:.0f} cand/s verify aggregate)")
        return "\n".join(lines)

    def to_dict(self) -> Dict[str, Any]:
        return {
            "name": self.name,
            "scenarios": [r.to_dict() for r in self.reports],
            "stage2_candidates": self.stage2_candidates,
            "stage2_time_s": self.stage2_time_s,
            "stage2_cands_per_sec": self.stage2_cands_per_sec,
            "stage2_batches": self.stage2_batches,
            "stage4_candidates": self.stage4_candidates,
            "stage4_time_s": self.stage4_time_s,
            "stage4_cands_per_sec": self.stage4_cands_per_sec,
            "stage4_batches": self.stage4_batches,
            "shared_trace_scenarios": self.shared_trace_scenarios,
            "wall_time_s": self.wall_time_s,
        }


# --------------------------------------------------------------------------
# execution
# --------------------------------------------------------------------------

def _search_checkpoint_dir(scenario: Scenario, *, campaign: bool = False) -> Optional[str]:
    """Campaigns nest each scenario's search state under its own name so one
    ``checkpoint_dir`` serves the whole sweep."""
    spec = scenario.search
    if spec is None or not spec.checkpoint_dir:
        return None
    return (os.path.join(spec.checkpoint_dir, scenario.name)
            if campaign else spec.checkpoint_dir)


def run_scenario(scenario: Union[Scenario, str], *, verbose: bool = False,
                 resume: bool = False, mesh=None) -> ScenarioReport:
    """One spec in, verified Pareto front out (the quickstart in one call).

    Runs the same staged composition as ``run_dse`` (inlined only to time
    the batched surrogate call); ``tests/test_api.py`` asserts the stage
    logs and Pareto front stay identical to the legacy ``optimize_switch``
    → ``run_dse`` path, so the two cannot silently diverge.

    With ``scenario.search`` set, stages 1-2 are replaced by the seeded
    generational NSGA-II engine (``repro.core.search``); the final archive
    feeds the identical stage-3/4 ladder.  ``resume`` continues a
    checkpointed search from ``search.checkpoint_dir``.

    ``mesh`` (a ``MeshSpec`` / device count, winning over ``scenario.mesh``)
    shards the batched stages across the device mesh without entering the
    report's scenario dict — reports, including the golden snapshots, are
    mesh-invariant.
    """
    if isinstance(scenario, str):
        scenario = registry[scenario]
    t0 = time.perf_counter()
    problem, sla, budget = build_problem(scenario, mesh=mesh)
    fid = scenario.fidelity
    if scenario.search is not None:
        t2 = time.perf_counter()
        outcome = run_search(problem, scenario.search, sla, delta=fid.delta,
                             checkpoint_dir=_search_checkpoint_dir(scenario),
                             resume=resume)
        stage2_time = time.perf_counter() - t2
        valid, pre_logs = outcome.valid, [outcome.log]
        stage2_cands = outcome.surrogate_rows
        if verbose:
            print(outcome.log)
    else:
        active, log1 = stage1_static(problem, delta=fid.delta)
        if verbose:
            print(log1)
        t2 = time.perf_counter()
        srs = problem.surrogate_batch(active)
        stage2_time = time.perf_counter() - t2
        valid, log2 = stage2_screen(problem, active, sla, surrogates=srs)
        if verbose:
            print(log2)
        pre_logs = [log1, log2]
        stage2_cands = len(active)
    sized, n_explored = stage3_size(problem, valid, sla, budget, top_k=fid.top_k)
    t4 = time.perf_counter()
    verifies = problem.verify_batch([a for a, _ in sized])
    stage4_time = time.perf_counter() - t4
    evaluated, best, best_v = stage4_verify(problem, sized, sla,
                                            verifies=verifies)
    log3 = StageLog("stage3-sizing+verify", n_explored, len(sized))
    if verbose:
        print(log3)
    result = finalize_result(problem, evaluated, best, best_v, pre_logs + [log3])
    return ScenarioReport(scenario=scenario, result=result, problem=problem,
                          wall_time_s=time.perf_counter() - t0,
                          stage2_candidates=stage2_cands,
                          stage2_time_s=stage2_time,
                          stage4_candidates=len(sized),
                          stage4_time_s=stage4_time)


@dataclasses.dataclass
class _Ctx:
    scenario: Scenario
    problem: DSEProblem
    budget: ResourceBudget
    shared_trace: bool
    group_key: Optional[str]                 # None -> own surrogate_batch call
    driver: Optional[SearchDriver] = None    # set iff scenario.search
    active: List[Any] = dataclasses.field(default_factory=list)
    log1: Optional[StageLog] = None
    surrogates: List[SurrogateResult] = dataclasses.field(default_factory=list)
    stage1_time_s: float = 0.0
    stage2_time_s: float = 0.0               # this scenario's share of its batch
    stage2_candidates: int = 0               # rows this scenario fanned out
    # --- stages 2-screen + 3 (sizing), filled before the stage-4 fan-out
    log2: Optional[StageLog] = None
    sized: List[Any] = dataclasses.field(default_factory=list)
    n_explored: int = 0
    stage3_time_s: float = 0.0
    verifies: List[VerifyResult] = dataclasses.field(default_factory=list)
    stage4_time_s: float = 0.0               # this scenario's share of its batch


def _switch_group_key(s: Scenario) -> str:
    """Scenarios share one batched stage-2 call iff this key matches: the
    batched engine takes one (trace, bound protocol, back-annotation) tuple."""
    return json.dumps({
        "trace": s.trace.to_dict(),
        "protocol": s.protocol.to_dict(),
        "flit_bits": s.flit_bits,
        "binding": s.binding,
        "back_annotation": s.fidelity.back_annotation,
        "use_kernel": s.fidelity.use_kernel,
        "co_design": s.co_design,
        # a fabric problem's batched calls evaluate per-tier designs over a
        # topology-specific hop decomposition — only identical topologies
        # (incl. the single-switch None) may share one call
        "topology": (s.topology.to_dict() if s.topology is not None else None),
    }, sort_keys=True)


def _verify_group_key(ctx: _Ctx) -> str:
    """Scenarios share one batched stage-4 call iff this key matches: the
    stage-2 key plus the verify engine (sized candidates from two scenarios
    may ride one jitted netsim scan only if the same rung verifies both)."""
    if ctx.group_key is None:
        return None
    return (ctx.group_key + "|" + ctx.scenario.fidelity.verify_engine
            + "|" + ctx.scenario.fidelity.use_kernel)


def run_campaign(
    scenarios: Sequence[Union[Scenario, str]],
    *,
    name: str = "campaign",
    verbose: bool = False,
    resume: bool = False,
    mesh=None,
) -> CampaignReport:
    """Run many scenarios with shared trace analysis and batched stage 2.

    Per-scenario results are identical to ``run_scenario`` (candidates of the
    batched engine are row-independent), so a campaign is never a fidelity
    trade-off — only a throughput one.

    Scenarios carrying a ``search`` spec run their generational engines in
    *lockstep*: each round, every active engine's pending population joins
    its group's single batched surrogate call (groups share a trace + bound
    protocol exactly as in exhaustive stage 2), so N searching scenarios
    still cost one jitted call per group per generation.  ``resume``
    continues each scenario's checkpointed search from
    ``search.checkpoint_dir/<scenario name>``.

    ``mesh`` (a ``MeshSpec`` / device count, winning over each scenario's
    own ``mesh``) shards every group's batched stage-2/stage-4 call over the
    device mesh.  A ``scenario_axis > 1`` spreads the candidate axis over a
    second, data-parallel mesh dimension as well, so a group's concatenated
    per-scenario candidate blocks land on different device groups — results
    stay bit-identical to the serial path either way.
    """
    scns = [registry[s] if isinstance(s, str) else s for s in scenarios]
    if not scns:
        raise ValueError("run_campaign needs at least one scenario")
    t_start = time.perf_counter()

    # ---- build: share built traces + feature analysis across scenarios
    from repro.core.features import analyze
    trace_cache: Dict[str, Tuple[Any, Any]] = {}
    ctxs: List[_Ctx] = []
    for s in scns:
        if s.domain == "switch":
            tkey = s.trace.key()
            shared = tkey in trace_cache
            if not shared:
                tr = s.trace.build()
                trace_cache[tkey] = (tr, analyze(tr))
            tr, feats = trace_cache[tkey]
            problem, _, budget = build_problem(s, trace=tr, features=feats,
                                               mesh=mesh)
            ctxs.append(_Ctx(s, problem, budget, shared, _switch_group_key(s)))
        else:
            problem, _, budget = build_problem(s, mesh=mesh)
            ctxs.append(_Ctx(s, problem, budget, False, None))

    # ---- search engines: one driver per searching scenario
    for ctx in ctxs:
        s = ctx.scenario
        if s.search is not None:
            ctx.driver = SearchDriver(
                ctx.problem, s.search, s.sla, delta=s.fidelity.delta,
                checkpoint_dir=_search_checkpoint_dir(s, campaign=True),
                resume=resume)

    # ---- stage 1 per scenario (search drivers do their own static pruning)
    for ctx in ctxs:
        if ctx.driver is not None:
            continue
        t0 = time.perf_counter()
        ctx.active, ctx.log1 = stage1_static(ctx.problem,
                                             delta=ctx.scenario.fidelity.delta)
        ctx.stage1_time_s = time.perf_counter() - t0
        if verbose:
            print(f"[{ctx.scenario.name}] {ctx.log1}")

    # ---- stage 2: fan every scenario's survivors through the batched engine;
    # scenarios sharing (trace, bound, fidelity) share one call
    groups: Dict[str, List[_Ctx]] = {}
    order: List[str] = []
    for i, ctx in enumerate(ctxs):
        if ctx.driver is not None:
            continue
        key = ctx.group_key if ctx.group_key is not None else f"solo-{i}"
        if key not in groups:
            groups[key] = []
            order.append(key)
        groups[key].append(ctx)

    total_cands = 0
    stage2_time = 0.0
    n_batches = 0
    for key in order:
        members = groups[key]
        archs = [a for ctx in members for a in ctx.active]
        srs: List[SurrogateResult] = []
        elapsed = 0.0
        if archs:
            t0 = time.perf_counter()
            srs = members[0].problem.surrogate_batch(archs)
            elapsed = time.perf_counter() - t0
            stage2_time += elapsed
            n_batches += 1
            total_cands += len(archs)
        off = 0
        for ctx in members:
            ctx.surrogates = srs[off:off + len(ctx.active)]
            # apportion the batched call's cost by candidate share
            ctx.stage2_time_s = elapsed * len(ctx.active) / max(len(archs), 1)
            ctx.stage2_candidates = len(ctx.active)
            off += len(ctx.active)

    # ---- generational lockstep for searching scenarios: each round, every
    # active engine's pending population rides its group's one batched call
    sgroups: Dict[str, List[_Ctx]] = {}
    sorder: List[str] = []
    for i, ctx in enumerate(ctxs):
        if ctx.driver is None:
            continue
        key = (ctx.group_key if ctx.group_key is not None
               else f"solo-{i}") + "|search"
        if key not in sgroups:
            sgroups[key] = []
            sorder.append(key)
        sgroups[key].append(ctx)
    while any(not ctx.driver.done for key in sorder for ctx in sgroups[key]):
        for key in sorder:
            members = [ctx for ctx in sgroups[key] if not ctx.driver.done]
            if not members:
                continue
            asks = [ctx.driver.ask_candidates() for ctx in members]
            cands = [c for a in asks for c in a]
            elapsed = 0.0
            srs = []
            if cands:
                t0 = time.perf_counter()
                srs = members[0].problem.surrogate_batch(cands)
                elapsed = time.perf_counter() - t0
                stage2_time += elapsed
                n_batches += 1
                total_cands += len(cands)
            off = 0
            for ctx, a in zip(members, asks):
                ctx.driver.tell_candidates(srs[off:off + len(a)])
                ctx.stage2_time_s += elapsed * len(a) / max(len(cands), 1)
                ctx.stage2_candidates += len(a)
                off += len(a)

    # ---- stage-2 screening (or search finalize) + stage-3 sizing
    for ctx in ctxs:
        s = ctx.scenario
        t0 = time.perf_counter()
        if ctx.driver is not None:
            outcome = ctx.driver.finalize()
            valid, ctx.log2 = outcome.valid, outcome.log
            # match solo run_scenario accounting: finalize()'s archive
            # re-surrogation (resume path) counts as stage-2 fan-out
            ctx.stage2_candidates = outcome.surrogate_rows
        else:
            valid, ctx.log2 = stage2_screen(ctx.problem, ctx.active, s.sla,
                                            surrogates=ctx.surrogates)
        ctx.sized, ctx.n_explored = stage3_size(
            ctx.problem, valid, s.sla, ctx.budget, top_k=s.fidelity.top_k)
        ctx.stage3_time_s = time.perf_counter() - t0
        if verbose:
            print(f"[{s.name}] {ctx.log2}")

    # ---- stage 4: fan every scenario's sized survivors through the batched
    # verifier; scenarios sharing (trace, bound, fidelity, engine) share one
    # jitted call, exactly as stage 2 shares the surrogate scan
    vgroups: Dict[str, List[_Ctx]] = {}
    vorder: List[str] = []
    for i, ctx in enumerate(ctxs):
        key = _verify_group_key(ctx) or f"solo-{i}"
        if key not in vgroups:
            vgroups[key] = []
            vorder.append(key)
        vgroups[key].append(ctx)

    total_verifies = 0
    stage4_time = 0.0
    n_vbatches = 0
    for key in vorder:
        members = vgroups[key]
        cands = [a for ctx in members for a, _ in ctx.sized]
        vs: List[VerifyResult] = []
        elapsed = 0.0
        if cands:
            t0 = time.perf_counter()
            vs = members[0].problem.verify_batch(cands)
            elapsed = time.perf_counter() - t0
            stage4_time += elapsed
            n_vbatches += 1
            total_verifies += len(cands)
        off = 0
        for ctx in members:
            ctx.verifies = vs[off:off + len(ctx.sized)]
            # apportion the batched call's cost by candidate share
            ctx.stage4_time_s = elapsed * len(ctx.sized) / max(len(cands), 1)
            off += len(ctx.sized)

    # ---- assemble per-scenario results
    reports: List[ScenarioReport] = []
    for ctx in ctxs:
        s = ctx.scenario
        t0 = time.perf_counter()
        evaluated, best, best_v = stage4_verify(ctx.problem, ctx.sized, s.sla,
                                                verifies=ctx.verifies)
        log3 = StageLog("stage3-sizing+verify", ctx.n_explored, len(ctx.sized))
        result = finalize_result(
            ctx.problem, evaluated, best, best_v,
            [lg for lg in (ctx.log1, ctx.log2, log3) if lg is not None])
        if verbose:
            print(f"[{s.name}] {log3}")
        reports.append(ScenarioReport(
            scenario=s, result=result, problem=ctx.problem,
            wall_time_s=(ctx.stage1_time_s + ctx.stage2_time_s
                         + ctx.stage3_time_s + ctx.stage4_time_s
                         + time.perf_counter() - t0),
            stage2_candidates=ctx.stage2_candidates,
            stage2_time_s=ctx.stage2_time_s,
            stage4_candidates=len(ctx.sized),
            stage4_time_s=ctx.stage4_time_s))

    return CampaignReport(
        name=name,
        reports=reports,
        stage2_candidates=total_cands,
        stage2_time_s=stage2_time,
        stage2_batches=n_batches,
        stage4_candidates=total_verifies,
        stage4_time_s=stage4_time,
        stage4_batches=n_vbatches,
        shared_trace_scenarios=sum(c.shared_trace for c in ctxs),
        wall_time_s=time.perf_counter() - t_start,
    )
