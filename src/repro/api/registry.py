"""The scenario registry: the paper's five workload families plus the
TPU comm-layer scenarios, ready for ``spac run <name>``.

Each switch entry reproduces the Table II recipe: a compressed per-workload
protocol (``addr_bits`` sized to the port count, 12-bit length), every
architecture policy on AUTO, and the workload's published SLA.  The comm
entries retarget the same Algorithm 1 at the MoE dispatch fabric and the
gradient-bucket exchange (``CommDSEProblem``).

``registry`` is the module-level pre-populated instance; user code can
``registry.register(...)`` its own scenarios (examples do).
"""

from __future__ import annotations

import math
from typing import Dict, Iterator, List, Optional

from repro.core.archspec import ArchRequest, ForwardTableKind, VOQKind
from repro.core.dse import ResourceBudget, SLA

from .scenario import (CommModelSpec, Fidelity, ProtocolSpec, Scenario,
                       TopologySpec, TraceSpec)

__all__ = ["ScenarioRegistry", "registry"]


class ScenarioRegistry:
    """Name → ``Scenario`` mapping with dict-like access."""

    def __init__(self):
        self._scenarios: Dict[str, Scenario] = {}

    def register(self, scenario: Scenario, *, replace: bool = False) -> Scenario:
        if scenario.name in self._scenarios and not replace:
            raise ValueError(f"scenario {scenario.name!r} already registered "
                             "(pass replace=True to overwrite)")
        self._scenarios[scenario.name] = scenario
        return scenario

    def get(self, name: str) -> Optional[Scenario]:
        return self._scenarios.get(name)

    def __getitem__(self, name: str) -> Scenario:
        try:
            return self._scenarios[name]
        except KeyError:
            raise KeyError(f"unknown scenario {name!r}; known: {self.names()}") from None

    def __contains__(self, name: str) -> bool:
        return name in self._scenarios

    def __iter__(self) -> Iterator[Scenario]:
        return iter(self._scenarios.values())

    def __len__(self) -> int:
        return len(self._scenarios)

    def names(self) -> List[str]:
        return sorted(self._scenarios)

    def items(self):
        return self._scenarios.items()


registry = ScenarioRegistry()


def _switch_scenario(name: str, *, n_ports: int, sla: SLA,
                     length_bits: int = 12, seed: int = 0,
                     notes: str = "") -> Scenario:
    addr_bits = max(4, (n_ports - 1).bit_length())
    return Scenario(
        name=name,
        domain="switch",
        protocol=ProtocolSpec(
            builder="compressed_protocol",
            params={"addr_bits": addr_bits, "length_bits": length_bits,
                    "name": f"spac_{name}"}),
        flit_bits=256,
        trace=TraceSpec(generator=name, params={"seed": seed}),
        arch=ArchRequest(n_ports=n_ports, addr_bits=addr_bits),
        sla=sla,
        notes=notes,
    )


# ----------------------------------------------------------- paper workloads
registry.register(_switch_scenario(
    "hft", n_ports=8, sla=SLA(p99_latency_ns=5e3, drop_rate=1e-3),
    notes="market-data bursts, 24 B payloads (Table II HFT row)"))
registry.register(_switch_scenario(
    "rl_allreduce", n_ports=8, sla=SLA(p99_latency_ns=1e6, drop_rate=1e-2),
    notes="iSwitch-style synchronous gradient rounds: incast then broadcast"))
registry.register(_switch_scenario(
    "datacenter", n_ports=32, sla=SLA(p99_latency_ns=1e6, drop_rate=1e-2),
    notes="Alibaba-trace-style microservice RPC, Zipf hotspots over 32 nodes"))
registry.register(_switch_scenario(
    "industry", n_ports=10, sla=SLA(p99_latency_ns=1e5, drop_rate=1e-3),
    notes="SCADA master/outstation polling, ~58.7 B responses"))
registry.register(_switch_scenario(
    "underwater", n_ports=8, sla=SLA(p99_latency_ns=1e5, drop_rate=1e-3),
    notes="8 DESERT robots, periodic 2 B beacons"))
registry.register(_switch_scenario(
    "uniform", n_ports=8, sla=SLA(p99_latency_ns=1e6, drop_rate=1e-2),
    notes="uniform Bernoulli baseline (Fig. 1 / Fig. 8 sensitivity)"))

# ------------------------------------------------------------ fabric (multi-hop)
registry.register(Scenario(
    name="fattree_dc",
    domain="switch",
    protocol=ProtocolSpec(
        builder="compressed_protocol",
        # the routing field must address all 8 fabric *hosts* (SPAC106),
        # not one 4-port switch
        params={"addr_bits": 4, "length_bits": 12, "name": "spac_fattree_dc"}),
    flit_bits=256,
    trace=TraceSpec(generator="datacenter", params={"seed": 0, "n_ports": 8}),
    # per-tier policy template: n_ports == the fat-tree degree (k=4); fwd/voq
    # pinned so the exhaustive per-tier cross product stays smoke-sized
    # (12 x 12 = 144 fabric candidates); VOQKind.SHARED is fabric-infeasible
    arch=ArchRequest(n_ports=4, addr_bits=4, fwd=ForwardTableKind.MULTIBANK_HASH,
                     voq=VOQKind.NXN),
    topology=TopologySpec.make("fattree", k=4),
    sla=SLA(p99_latency_ns=1e5, drop_rate=1e-2),
    # the datacenter trace's minimum packet (a handful of bytes at 25 Gbps)
    # makes the strict line-rate prune reject every 4-port design; at the
    # trace's 0.2 load that bound is far too pessimistic — relax stage-1
    # slack and let the surrogate + netsim stages judge for real
    fidelity=Fidelity(delta=2.5),
    notes="2-tier k=4 fat-tree fabric: 8 hosts through 4 edge + 2 core "
          "switches, per-tier designs evaluated end-to-end hop-by-hop"))

# --------------------------------------------------------- comm-layer (TPU)
registry.register(Scenario(
    name="moe_dispatch",
    domain="comm",
    comm=CommModelSpec(d_model=512, d_ff=1024, moe_experts=32, moe_topk=4,
                       batch=8, seq=256, model_tp=16),
    sla=SLA(p99_latency_ns=math.inf, drop_rate=2e-2),
    budget=ResourceBudget({"bytes_per_device": 4e9}),
    fidelity=Fidelity(back_annotation=False),
    notes="MoE token dispatch as a SPAC switch: capacity factor = VOQ depth, "
          "payload protocol bf16/int8, a2a schedule (CommDSEProblem)"))
registry.register(Scenario(
    name="grad_bucket",
    domain="comm",
    comm=CommModelSpec(d_model=1024, d_ff=2048, moe_experts=16, moe_topk=1,
                       batch=4, seq=256, model_tp=8),
    sla=SLA(p99_latency_ns=math.inf, drop_rate=1e-2),
    budget=ResourceBudget({"bytes_per_device": 4e9}),
    fidelity=Fidelity(back_annotation=False),
    notes="gradient-bucket exchange: each bucket routes to one reduction peer "
          "(top-1), sizing the per-peer staging buffers"))
