"""Declarative, serializable experiment specs: one ``Scenario`` from protocol
to Pareto front.

The paper's pitch (§III) is a *unified workflow*: one spec drives the parser
generator, the simulator stack, and the trace-aware DSE.  A ``Scenario``
bundles everything one experiment needs —

  * the protocol (a stock constructor by name + params, or an inline
    field-by-field layout), the flit width and semantic-binding overrides,
  * the traffic trace (a ``repro.traces`` generator by name + params, or a
    ``Trace.save``d ``.npz`` by path),
  * the architecture request (``ArchRequest`` with ``AUTO`` policies) for the
    switch domain, or a ``CommModelSpec`` for the TPU comm domain,
  * the ``SLA``, the ``ResourceBudget``, and the fidelity knobs,

— and round-trips through ``to_dict()/from_dict()`` + JSON, so every
experiment is a reproducible config file (``spac run``/``spac sweep`` consume
exactly these).  All spec classes are frozen dataclasses; equality is
structural and survives the JSON round-trip bit-for-bit.
"""

from __future__ import annotations

import dataclasses
import inspect
import json
import math
from typing import Any, Dict, Mapping, Optional, Tuple, Union

from repro.core.archspec import (AUTO, ArchRequest, CustomKernelSpec,
                                 ForwardTableKind, SchedulerKind, VOQKind)
from repro.core.binding import KNOWN_SEMANTICS, SemanticBinding
from repro.core.dse import ResourceBudget, SLA, USE_KERNEL_MODES, VERIFY_ENGINES
from repro.core.dsl import (CODESIGN_ADDR_CHOICES, CODESIGN_LENGTH_CHOICES,
                            CODESIGN_QOS_CHOICES, CODESIGN_SEQ_CHOICES, Field,
                            FieldSpec, Protocol, ProtocolSpace,
                            compressed_protocol, compressed_protocol_space,
                            ethernet_ipv4_udp)
from repro.core.search import SearchSpec
from repro.fabric.topology import TopologySpec
from repro.launch.mesh import MeshSpec

__all__ = [
    "ProtocolSpec",
    "TraceSpec",
    "CommModelSpec",
    "Fidelity",
    "FieldSpec",
    "MeshSpec",
    "Scenario",
    "SearchSpec",
    "TopologySpec",
    "PROTOCOL_BUILDERS",
]

#: stock protocol constructors a ``ProtocolSpec`` may reference by name
PROTOCOL_BUILDERS = {
    "compressed_protocol": compressed_protocol,
    "ethernet_ipv4_udp": ethernet_ipv4_udp,
}


# --------------------------------------------------------------------------
# serialization helpers
# --------------------------------------------------------------------------

def _num_to_json(x: float):
    """Floats must survive json.dumps with standard-compliant output."""
    if isinstance(x, float) and math.isinf(x):
        return "inf" if x > 0 else "-inf"
    return x


def _num_from_json(x):
    if x == "inf":
        return math.inf
    if x == "-inf":
        return -math.inf
    return x


_ENUMS = {"fwd": ForwardTableKind, "voq": VOQKind, "sched": SchedulerKind}


def _policy_to_json(v):
    if v is AUTO:
        return "auto"
    if isinstance(v, (ForwardTableKind, VOQKind, SchedulerKind)):
        return v.value
    return v


def _policy_from_json(key: str, v):
    if v == "auto":
        return AUTO
    if key in _ENUMS and isinstance(v, str):
        return _ENUMS[key](v)
    return v


def arch_to_dict(req: ArchRequest) -> Dict[str, Any]:
    d = {
        "n_ports": req.n_ports,
        "addr_bits": req.addr_bits,
        "bus_bits": _policy_to_json(req.bus_bits),
        "fwd": _policy_to_json(req.fwd),
        "voq": _policy_to_json(req.voq),
        "sched": _policy_to_json(req.sched),
        "voq_depth": _policy_to_json(req.voq_depth),
    }
    if req.custom_kernels:
        # the *performance interface* is declarative and serializes; the
        # functional model (fn) is code and cannot — reattach it in code
        d["custom_kernels"] = [
            {"name": k.name, "ii": k.ii, "latency_cycles": k.latency_cycles,
             "luts": k.luts, "ffs": k.ffs, "brams": k.brams}
            for k in req.custom_kernels
        ]
    return d


def arch_from_dict(d: Mapping[str, Any]) -> ArchRequest:
    kernels = tuple(CustomKernelSpec(**k) for k in d.get("custom_kernels", ()))
    return ArchRequest(
        n_ports=int(d["n_ports"]),
        addr_bits=int(d["addr_bits"]),
        bus_bits=_policy_from_json("bus_bits", d.get("bus_bits", "auto")),
        fwd=_policy_from_json("fwd", d.get("fwd", "auto")),
        voq=_policy_from_json("voq", d.get("voq", "auto")),
        sched=_policy_from_json("sched", d.get("sched", "auto")),
        voq_depth=_policy_from_json("voq_depth", d.get("voq_depth", "auto")),
        custom_kernels=kernels,
    )


def sla_to_dict(sla: SLA) -> Dict[str, Any]:
    return {
        "p99_latency_ns": _num_to_json(sla.p99_latency_ns),
        "drop_rate": sla.drop_rate,
        "min_throughput_gbps": sla.min_throughput_gbps,
    }


def sla_from_dict(d: Mapping[str, Any]) -> SLA:
    return SLA(
        p99_latency_ns=float(_num_from_json(d.get("p99_latency_ns", "inf"))),
        drop_rate=float(d.get("drop_rate", 1e-3)),
        min_throughput_gbps=float(d.get("min_throughput_gbps", 0.0)),
    )


# --------------------------------------------------------------------------
# component specs
# --------------------------------------------------------------------------

#: default co-design width menus per ``compressed_protocol`` parameter —
#: what ``ProtocolSpec.widen()`` (and ``spac run --co-design``) opens up
_WIDEN_CHOICES = {
    "addr_bits": CODESIGN_ADDR_CHOICES,
    "qos_bits": CODESIGN_QOS_CHOICES,
    "length_bits": CODESIGN_LENGTH_CHOICES,
    "seq_bits": CODESIGN_SEQ_CHOICES,
}
#: the builder's own defaults, read off its signature so they cannot drift
_COMPRESSED_DEFAULTS = {
    k: p.default
    for k, p in inspect.signature(compressed_protocol).parameters.items()
    if k in ("addr_bits", "qos_bits", "length_bits", "seq_bits")
}


def _as_choices(v):
    """Width choice lists -> canonical int tuples; everything else verbatim."""
    if isinstance(v, (list, tuple)) and v \
            and all(isinstance(x, (int, float)) and not isinstance(x, bool) for x in v):
        return tuple(int(x) for x in v)
    return v


def _is_choices(v) -> bool:
    return isinstance(v, tuple) and bool(v) and all(isinstance(x, int) for x in v)


@dataclasses.dataclass(frozen=True)
class ProtocolSpec:
    """Protocol by stock constructor name + params, or inline field layout.

    Any width parameter (and any inline field's ``bits``) may be a *list* of
    choices instead of a point — the spec then describes a ``ProtocolSpace``
    (``space()``) the co-design DSE searches jointly with the architecture.
    Ranged specs serialize exactly like point specs (choices are JSON
    arrays) and round-trip bit-for-bit."""

    builder: str = "compressed_protocol"    # a PROTOCOL_BUILDERS key | "inline"
    params: Dict[str, Any] = dataclasses.field(default_factory=dict)
    name: Optional[str] = None              # protocol name for inline layouts
    fields: Optional[Tuple[Union[Field, FieldSpec], ...]] = None

    def __post_init__(self):
        if self.builder == "inline":
            if not self.fields:
                raise ValueError("inline ProtocolSpec needs a non-empty fields tuple")
        elif self.builder not in PROTOCOL_BUILDERS:
            raise ValueError(
                f"unknown protocol builder {self.builder!r}; "
                f"known: {sorted(PROTOCOL_BUILDERS)} or 'inline'")
        # canonical form: ranged width params are int tuples (lists arrive
        # from JSON); non-numeric sequences (extra_fields) pass through
        params = {k: _as_choices(v) for k, v in self.params.items()}
        object.__setattr__(self, "params", params)

    @staticmethod
    def inline(protocol: Protocol) -> "ProtocolSpec":
        """Capture an existing ``Protocol`` field-by-field."""
        return ProtocolSpec(builder="inline", name=protocol.name,
                            fields=tuple(protocol.fields))

    # ----------------------------------------------------------- point vs space
    @property
    def is_space(self) -> bool:
        """True iff any parameter/field carries more than a point value."""
        if any(_is_choices(v) for v in self.params.values()):
            return True
        return any(isinstance(f, FieldSpec) for f in (self.fields or ()))

    def build(self) -> Protocol:
        if self.is_space:
            raise ValueError(
                "ranged ProtocolSpec describes a protocol *space*, not one "
                "protocol; run the scenario with co_design=True (spac run "
                "--co-design) or pin every width to a single value")
        if self.builder == "inline":
            return Protocol(self.name or "inline", self.fields)
        return PROTOCOL_BUILDERS[self.builder](**dict(self.params))

    def space(self) -> ProtocolSpace:
        """The spec as a ``ProtocolSpace`` (point params become single-choice
        dimensions)."""
        if self.builder == "inline":
            specs = tuple(f if isinstance(f, FieldSpec) else FieldSpec.fixed(f)
                          for f in self.fields)
            return ProtocolSpace(self.name or "inline", specs)
        if self.builder == "compressed_protocol":
            p = dict(self.params)
            name = p.pop("name", "spac_compressed")
            extra = tuple(p.pop("extra_fields", ()))
            kw = {k: p.pop(k, _COMPRESSED_DEFAULTS[k])
                  for k in _COMPRESSED_DEFAULTS}
            if p:
                raise ValueError(f"unknown compressed_protocol params {sorted(p)}")
            return compressed_protocol_space(name=name, extra_fields=extra, **kw)
        raise ValueError(
            f"protocol builder {self.builder!r} has a fixed layout and no "
            "searchable space; use the compressed_protocol builder or inline "
            "FieldSpec fields for co-design")

    def widen(self) -> "ProtocolSpec":
        """Open the default co-design width menus around a point spec (the
        ``--co-design`` CLI toggle): each ``compressed_protocol`` width
        parameter becomes its default choice set, always including the
        pinned value so the original layout stays reachable."""
        if self.is_space:
            return self
        if self.builder != "compressed_protocol":
            raise ValueError(
                f"cannot widen builder {self.builder!r}; co-design default "
                "ranges exist for compressed_protocol only — give explicit "
                "ranged params or inline FieldSpec fields")
        params = dict(self.params)
        for k, menu in _WIDEN_CHOICES.items():
            pinned = int(params.get(k, _COMPRESSED_DEFAULTS[k]))
            params[k] = tuple(sorted(set(menu) | {pinned}))
        return dataclasses.replace(self, params=params)

    # -------------------------------------------------------- serialization
    def to_dict(self) -> Dict[str, Any]:
        d: Dict[str, Any] = {"builder": self.builder}
        if self.params:
            d["params"] = {k: list(v) if isinstance(v, tuple) else v
                           for k, v in self.params.items()}
        if self.name is not None:
            d["name"] = self.name
        if self.fields is not None:
            d["fields"] = [
                {"name": f.name,
                 "bits": list(f.bits) if isinstance(f, FieldSpec) else f.bits,
                 "semantic": f.semantic, "default": f.default}
                for f in self.fields
            ]
        return d

    @staticmethod
    def from_dict(d: Mapping[str, Any]) -> "ProtocolSpec":
        fields = d.get("fields")
        if fields is not None:
            fields = tuple(
                FieldSpec(f["name"], tuple(f["bits"]), f.get("semantic"),
                          f.get("default", 0))
                if isinstance(f["bits"], (list, tuple)) else Field(**f)
                for f in fields)
        return ProtocolSpec(
            builder=d.get("builder", "compressed_protocol"),
            params=dict(d.get("params", {})),
            name=d.get("name"),
            fields=fields,
        )


@dataclasses.dataclass(frozen=True)
class TraceSpec:
    """Traffic by generator name (``repro.traces.WORKLOADS``) or saved file."""

    generator: Optional[str] = None         # a WORKLOADS key
    params: Dict[str, Any] = dataclasses.field(default_factory=dict)
    path: Optional[str] = None              # Trace.save()d .npz

    def __post_init__(self):
        if (self.generator is None) == (self.path is None):
            raise ValueError("TraceSpec needs exactly one of generator / path")

    def build(self):
        from repro.traces import Trace
        from repro.traces.workloads import WORKLOADS
        if self.path is not None:
            return Trace.load(self.path)
        if self.generator not in WORKLOADS:
            raise ValueError(f"unknown trace generator {self.generator!r}; "
                             f"known: {sorted(WORKLOADS)}")
        return WORKLOADS[self.generator](**dict(self.params))

    def key(self) -> str:
        """Canonical identity — campaigns share one built trace per key."""
        return json.dumps(self.to_dict(), sort_keys=True)

    def to_dict(self) -> Dict[str, Any]:
        d: Dict[str, Any] = {}
        if self.generator is not None:
            d["generator"] = self.generator
        if self.params:
            d["params"] = dict(self.params)
        if self.path is not None:
            d["path"] = self.path
        return d

    @staticmethod
    def from_dict(d: Mapping[str, Any]) -> "TraceSpec":
        return TraceSpec(generator=d.get("generator"),
                         params=dict(d.get("params", {})),
                         path=d.get("path"))


@dataclasses.dataclass(frozen=True)
class CommModelSpec:
    """The comm-domain analogue of ``ArchRequest``: the MoE/gradient-bucket
    model whose routing trace drives ``CommDSEProblem``."""

    d_model: int = 512
    d_ff: int = 1024
    n_heads: int = 8
    n_kv_heads: int = 4
    vocab: int = 1000
    moe_experts: int = 32
    moe_topk: int = 4
    batch: int = 8
    seq: int = 256
    seed: int = 0
    model_tp: int = 16          # tensor extent for the analytic fabric model
    router: str = "learned_topk"

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @staticmethod
    def from_dict(d: Mapping[str, Any]) -> "CommModelSpec":
        return CommModelSpec(**dict(d))


@dataclasses.dataclass(frozen=True)
class Fidelity:
    """Knobs trading DSE speed for accuracy (stage granularity unchanged)."""

    back_annotation: bool = True   # η from the cycle sim vs the analytic fits
    delta: float = 0.2             # stage-1 timing slack
    top_k: int = 8                 # stage-3 exploration width
    #: stage-4 rung on the fidelity ladder: "netsim" verifies every sized
    #: survivor with the batched finite-buffer event sim; "cycle" runs the
    #: cycle-accurate datapath for every survivor (slow); "auto" verifies the
    #: front with batched netsim and escalates only the champion to cycle-sim
    verify_engine: str = "netsim"
    #: segmented netsim-kernel knob for the batched stage-2/4 engines:
    #: "auto" (kernel when available, oracle fallback), "on", "off".
    #: Bools normalise to "on"/"off" so JSON round-trips stay canonical.
    use_kernel: str = "auto"

    def __post_init__(self):
        if self.verify_engine not in VERIFY_ENGINES:
            raise ValueError(f"unknown verify_engine {self.verify_engine!r}; "
                             f"known: {VERIFY_ENGINES}")
        if isinstance(self.use_kernel, bool):
            object.__setattr__(self, "use_kernel",
                               "on" if self.use_kernel else "off")
        if self.use_kernel not in USE_KERNEL_MODES:
            raise ValueError(f"unknown use_kernel {self.use_kernel!r}; "
                             f"known: {USE_KERNEL_MODES} or a bool")

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        # "auto" is the default and resolves per-environment; omitting it
        # keeps serialised scenarios (and their goldens) stable across
        # versions that predate the knob
        if d["use_kernel"] == "auto":
            del d["use_kernel"]
        return d

    @staticmethod
    def from_dict(d: Mapping[str, Any]) -> "Fidelity":
        return Fidelity(**dict(d))


# --------------------------------------------------------------------------
# the Scenario
# --------------------------------------------------------------------------

#: override() sentinel — None is a meaningful value for ``search``
_KEEP = object()


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One experiment, declaratively: protocol → binding → trace → DSE → SLA.

    ``domain`` selects the problem family: ``"switch"`` (the paper's FPGA
    switch, needs ``arch``) or ``"comm"`` (the TPU dispatch fabric, needs
    ``comm``).  ``budget=None`` means the domain default (Alveo U45N for
    switch, 4 GB dispatch-buffer HBM for comm).
    """

    name: str
    domain: str = "switch"
    protocol: ProtocolSpec = ProtocolSpec()
    flit_bits: int = 256
    binding: Dict[str, str] = dataclasses.field(default_factory=dict)
    trace: TraceSpec = TraceSpec(generator="uniform")
    arch: Optional[ArchRequest] = None
    comm: Optional[CommModelSpec] = None
    sla: SLA = SLA()
    budget: Optional[ResourceBudget] = None
    fidelity: Fidelity = Fidelity()
    #: None -> exhaustive enumeration (stages 1-2); a SearchSpec -> the
    #: seeded generational NSGA-II engine over the problem's space()
    search: Optional[SearchSpec] = None
    #: protocol/architecture co-design: the protocol spec's width ranges
    #: become genes next to the architecture genes (switch domain + search
    #: only; ``override(co_design=True)`` widens a point spec automatically)
    co_design: bool = False
    #: optional MeshSpec sharding the batched DSE stages across devices;
    #: None (the default, and what every golden snapshot records) is the
    #: serial path — results are mesh-invariant either way
    mesh: Optional[MeshSpec] = None
    #: optional multi-hop fabric: the scenario evaluates a *network* of
    #: switches (``repro.fabric``) — each topology tier is its own design
    #: point, the trace routes hop-by-hop, and objectives are end-to-end
    #: (switch domain only; None keeps the single-switch path)
    topology: Optional[TopologySpec] = None
    notes: str = ""

    def __post_init__(self):
        if self.mesh is not None and not isinstance(self.mesh, MeshSpec):
            object.__setattr__(self, "mesh", MeshSpec.coerce(self.mesh))
        if self.domain not in ("switch", "comm"):
            raise ValueError(f"unknown domain {self.domain!r}")
        if self.topology is not None and self.domain != "switch":
            raise ValueError(f"scenario {self.name!r}: topology applies to "
                             "the switch domain only")
        if self.domain == "switch" and self.arch is None:
            raise ValueError(f"scenario {self.name!r}: switch domain needs arch")
        if self.domain == "comm" and self.comm is None:
            raise ValueError(f"scenario {self.name!r}: comm domain needs comm")
        unknown = set(self.binding) - set(KNOWN_SEMANTICS)
        if unknown:
            raise ValueError(f"scenario {self.name!r}: unknown binding "
                             f"semantics {sorted(unknown)}")
        if self.co_design:
            if self.domain != "switch":
                raise ValueError(f"scenario {self.name!r}: co_design applies "
                                 "to the switch domain only")
            if not self.protocol.is_space:
                raise ValueError(
                    f"scenario {self.name!r}: co_design=True needs ranged "
                    "protocol params (list-valued widths) — "
                    "override(co_design=True) widens the defaults")

    # ------------------------------------------------------------- building
    def semantic_binding(self) -> SemanticBinding:
        return SemanticBinding(**self.binding)

    # -------------------------------------------------------- serialization
    def to_dict(self) -> Dict[str, Any]:
        d: Dict[str, Any] = {
            "name": self.name,
            "domain": self.domain,
            "protocol": self.protocol.to_dict(),
            "flit_bits": self.flit_bits,
            "trace": self.trace.to_dict(),
            "sla": sla_to_dict(self.sla),
            "fidelity": self.fidelity.to_dict(),
        }
        if self.binding:
            d["binding"] = dict(self.binding)
        if self.arch is not None:
            d["arch"] = arch_to_dict(self.arch)
        if self.comm is not None:
            d["comm"] = self.comm.to_dict()
        if self.budget is not None:
            d["budget"] = {"limits": {k: _num_to_json(v)
                                      for k, v in self.budget.limits.items()}}
        if self.search is not None:
            d["search"] = self.search.to_dict()
        if self.co_design:
            d["co_design"] = True
        if self.mesh is not None:
            d["mesh"] = self.mesh.to_dict()
        if self.topology is not None:
            d["topology"] = self.topology.to_dict()
        if self.notes:
            d["notes"] = self.notes
        return d

    @staticmethod
    def from_dict(d: Mapping[str, Any]) -> "Scenario":
        arch = d.get("arch")
        comm = d.get("comm")
        budget = d.get("budget")
        search = d.get("search")
        return Scenario(
            name=d["name"],
            domain=d.get("domain", "switch"),
            protocol=ProtocolSpec.from_dict(d.get("protocol", {})),
            flit_bits=int(d.get("flit_bits", 256)),
            binding=dict(d.get("binding", {})),
            trace=TraceSpec.from_dict(d.get("trace", {"generator": "uniform"})),
            arch=arch_from_dict(arch) if arch is not None else None,
            comm=CommModelSpec.from_dict(comm) if comm is not None else None,
            sla=sla_from_dict(d.get("sla", {})),
            budget=(ResourceBudget({k: float(_num_from_json(v))
                                    for k, v in budget["limits"].items()})
                    if budget is not None else None),
            fidelity=Fidelity.from_dict(d.get("fidelity", {})),
            search=SearchSpec.from_dict(search) if search is not None else None,
            co_design=bool(d.get("co_design", False)),
            mesh=(MeshSpec.from_dict(d["mesh"])
                  if d.get("mesh") is not None else None),
            topology=(TopologySpec.from_dict(d["topology"])
                      if d.get("topology") is not None else None),
            notes=d.get("notes", ""),
        )

    def to_json(self, indent: int = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=True)

    @staticmethod
    def from_json(text: str) -> "Scenario":
        return Scenario.from_dict(json.loads(text))

    def save(self, path) -> None:
        with open(path, "w") as f:
            f.write(self.to_json() + "\n")

    @staticmethod
    def load(path) -> "Scenario":
        with open(path) as f:
            return Scenario.from_json(f.read())

    # ------------------------------------------------------------ overrides
    def override(
        self,
        *,
        search: Any = _KEEP,
        sla_p99_latency_ns: Optional[float] = None,
        sla_drop_rate: Optional[float] = None,
        sla_min_throughput_gbps: Optional[float] = None,
        trace_params: Optional[Mapping[str, Any]] = None,
        budget_limits: Optional[Mapping[str, float]] = None,
        back_annotation: Optional[bool] = None,
        delta: Optional[float] = None,
        top_k: Optional[int] = None,
        verify_engine: Optional[str] = None,
        use_kernel: Optional[str] = None,
        flit_bits: Optional[int] = None,
        co_design: Optional[bool] = None,
        devices: Optional[int] = None,
        scenario_devices: Optional[int] = None,
        name: Optional[str] = None,
    ) -> "Scenario":
        """Return a copy with the given knobs replaced (CLI flag surface).

        ``co_design=True`` on a point protocol spec widens it with the
        default co-design width menus (``ProtocolSpec.widen``), so
        ``registry["hft"].override(co_design=True, search=...)`` is the whole
        Table II header-adaptation experiment."""
        sla = SLA(
            p99_latency_ns=(self.sla.p99_latency_ns
                            if sla_p99_latency_ns is None else sla_p99_latency_ns),
            drop_rate=(self.sla.drop_rate
                       if sla_drop_rate is None else sla_drop_rate),
            min_throughput_gbps=(self.sla.min_throughput_gbps
                                 if sla_min_throughput_gbps is None
                                 else sla_min_throughput_gbps),
        )
        trace = self.trace
        if trace_params:
            if trace.generator is None:
                raise ValueError("trace params override needs a generator-"
                                 "sourced trace, not a file")
            trace = dataclasses.replace(
                trace, params={**trace.params, **dict(trace_params)})
        budget = self.budget
        if budget_limits:
            base = dict(budget.limits) if budget is not None else {}
            base.update(budget_limits)
            budget = ResourceBudget(base)
        fid = Fidelity(
            back_annotation=(self.fidelity.back_annotation
                             if back_annotation is None else back_annotation),
            delta=self.fidelity.delta if delta is None else delta,
            top_k=self.fidelity.top_k if top_k is None else top_k,
            verify_engine=(self.fidelity.verify_engine
                           if verify_engine is None else verify_engine),
            use_kernel=(self.fidelity.use_kernel
                        if use_kernel is None else use_kernel),
        )
        cd = self.co_design if co_design is None else co_design
        protocol = self.protocol
        if cd and not protocol.is_space:
            protocol = protocol.widen()
        elif co_design is False and protocol.is_space:
            # widening is lossy (the pinned point joins a menu), so there is
            # no way back — fail here with guidance instead of later with a
            # "turn co-design on" message that contradicts the user's ask
            raise ValueError(
                f"scenario {self.name!r}: cannot disable co-design on a "
                "ranged protocol spec (the original point widths are not "
                "recorded); pin each width to a single value or rebuild "
                "the scenario from the registry")
        mesh = self.mesh
        if devices is not None or scenario_devices is not None:
            base = mesh if mesh is not None else MeshSpec()
            mesh = MeshSpec(
                devices=base.devices if devices is None else devices,
                scenario_axis=(base.scenario_axis if scenario_devices is None
                               else scenario_devices))
            if mesh.is_single():
                mesh = None     # serial default serializes as no mesh at all
        return dataclasses.replace(
            self, sla=sla, trace=trace, budget=budget, fidelity=fid,
            search=self.search if search is _KEEP else search,
            flit_bits=self.flit_bits if flit_bits is None else flit_bits,
            co_design=cd, protocol=protocol, mesh=mesh,
            name=self.name if name is None else name,
        )
