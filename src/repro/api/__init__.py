"""Declarative Scenario/Campaign API — the repo's front door.

One serializable ``Scenario`` spec carries an experiment from protocol
definition to verified Pareto front; ``registry`` holds the paper's workload
scenarios; ``run_scenario``/``run_campaign`` execute one or many (campaigns
share trace analysis and batch stage 2 across scenarios); ``DSEServeEngine``
/``Client`` serve a stream of scenario requests through shared fixed-width
jitted calls with content-addressed caching; ``repro.api.cli`` is the
``spac`` console entry point.
"""

from .registry import ScenarioRegistry, registry
from .runner import (CampaignReport, ScenarioReport, build_bound,
                     build_problem, run_campaign, run_scenario)
from .scenario import (CommModelSpec, Fidelity, FieldSpec, PROTOCOL_BUILDERS,
                       ProtocolSpec, Scenario, SearchSpec, TopologySpec,
                       TraceSpec)
from .service import Client, DSEServeEngine, ServeRequest, strip_times

__all__ = [
    "CampaignReport", "Client", "CommModelSpec", "DSEServeEngine",
    "Fidelity", "FieldSpec", "PROTOCOL_BUILDERS", "ProtocolSpec", "Scenario",
    "ScenarioRegistry", "ScenarioReport", "ServeRequest", "SearchSpec",
    "TopologySpec", "TraceSpec", "build_bound", "build_problem", "registry",
    "run_campaign", "run_scenario", "strip_times",
]
