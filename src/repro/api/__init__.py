"""Declarative Scenario/Campaign API — the repo's front door.

One serializable ``Scenario`` spec carries an experiment from protocol
definition to verified Pareto front; ``registry`` holds the paper's workload
scenarios; ``run_scenario``/``run_campaign`` execute one or many (campaigns
share trace analysis and batch stage 2 across scenarios); ``repro.api.cli``
is the ``spac`` console entry point.
"""

from .registry import ScenarioRegistry, registry
from .runner import (CampaignReport, ScenarioReport, build_bound,
                     build_problem, run_campaign, run_scenario)
from .scenario import (CommModelSpec, Fidelity, FieldSpec, PROTOCOL_BUILDERS,
                       ProtocolSpec, Scenario, SearchSpec, TraceSpec)

__all__ = [
    "CampaignReport", "CommModelSpec", "Fidelity", "FieldSpec",
    "PROTOCOL_BUILDERS", "ProtocolSpec", "Scenario", "ScenarioRegistry",
    "ScenarioReport", "SearchSpec", "TraceSpec", "build_bound",
    "build_problem", "registry", "run_campaign", "run_scenario",
]
