"""The ``spac`` command-line front door (also ``python -m repro``).

    spac list                                  # registry scenarios
    spac show hft                              # dump a scenario as JSON
    spac check hft                             # static diagnostics (SPAC1xx)
    spac check my_scenario.json --format json
    spac lint src tests benchmarks             # determinism lint (SPAC2xx)
    spac ingest capture.csv -o capture.npz     # pcap/CSV capture -> Trace
    spac ingest lan.pcap --stage "filter:min_payload=64" \
        --stage "incast:dst=0,n_senders=6,n_packets=128" --seed 7
    spac run hft --sla-p99-ns 5000             # one scenario, with overrides
    spac run my_scenario.json --out report.json
    spac run hft --search nsga2 --generations 10 --search-seed 0
    spac run hft --search nsga2 --co-design      # protocol layout in the genome
    spac run hft --search nsga2 --checkpoint-dir ckpt && \
        spac run hft --search nsga2 --checkpoint-dir ckpt --resume
    spac sweep hft underwater industry         # campaign over registry names
    spac sweep --config campaign.json          # campaign from a config file
    spac serve hft datacenter --repeat 4       # continuous-batched DSE service
    spac serve --requests reqs.json --out served.json --stats

Serve request schema (JSON): a list of entries; each entry is a registry
name, a scenario dict, or ``{"base"|"scenario": ..., "seed": ..., "repeat":
N, ...overrides}`` — the scenario part resolves exactly like a campaign
entry, ``seed`` overrides the trace generator seed, ``repeat`` enqueues the
request N times (cache-hit fodder).

Campaign config schema (JSON): either a plain list of entries or
``{"name": ..., "scenarios": [...]}``; each entry is a registry name, a full
scenario dict (``Scenario.to_dict()`` shape), or ``{"base": "<registry
name>", ...overrides}`` where the overrides deep-merge into the base spec.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Any, Dict, Mapping, Optional, Sequence

__all__ = ["main", "build_parser", "resolve_entry", "load_campaign_config"]


# --------------------------------------------------------------------------
# config resolution
# --------------------------------------------------------------------------

def _deep_merge(base: Mapping[str, Any], over: Mapping[str, Any]) -> Dict[str, Any]:
    out = dict(base)
    for k, v in over.items():
        if k in out and isinstance(out[k], Mapping) and isinstance(v, Mapping):
            out[k] = _deep_merge(out[k], v)
        else:
            out[k] = v
    return out


def resolve_entry(entry):
    """Campaign entry → ``Scenario`` (name | full dict | base + overrides)."""
    from .registry import registry
    from .scenario import Scenario
    if isinstance(entry, str):
        return registry[entry]
    if not isinstance(entry, Mapping):
        raise ValueError(f"bad campaign entry {entry!r}")
    if "base" in entry:
        base = registry[entry["base"]].to_dict()
        over = {k: v for k, v in entry.items() if k != "base"}
        # an override that names a trace *source* (generator or saved file)
        # replaces the base trace wholesale — merging would leave the base's
        # generator next to an override path and fail exactly-one validation
        t = over.get("trace")
        if isinstance(t, Mapping) and ("path" in t or "generator" in t):
            base.pop("trace", None)
        return Scenario.from_dict(_deep_merge(base, over))
    return Scenario.from_dict(entry)


def load_campaign_config(cfg) -> Dict[str, Any]:
    """Normalise a campaign config to {"name", "scenarios": [Scenario, ...]}."""
    if isinstance(cfg, list):
        cfg = {"scenarios": cfg}
    entries = cfg.get("scenarios", [])
    if not entries:
        raise ValueError("campaign config has no scenarios")
    return {"name": cfg.get("name", "campaign"),
            "scenarios": [resolve_entry(e) for e in entries]}


def _parse_kv(pairs: Optional[Sequence[str]]) -> Dict[str, Any]:
    """``key=value`` CLI pairs; values parse as JSON literals, else strings."""
    out: Dict[str, Any] = {}
    for p in pairs or ():
        if "=" not in p:
            raise SystemExit(f"expected key=value, got {p!r}")
        k, v = p.split("=", 1)
        try:
            out[k] = json.loads(v)
        except json.JSONDecodeError:
            out[k] = v
    return out


def _split_stage_params(s: str):
    """Split ``key=val,key=val`` on top-level commas only, so JSON list/dict
    values (``ports=[0,1]``, ``mapping={"3": 0}``) pass through intact."""
    out, cur, depth = [], [], 0
    for ch in s:
        if ch in "[{(":
            depth += 1
        elif ch in "]})":
            depth -= 1
        if ch == "," and depth == 0:
            out.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if cur:
        out.append("".join(cur))
    return out


def _parse_stage(spec: str):
    """CLI ``--stage kind:key=val,...`` -> (kind, params).  Values parse as
    JSON literals, else strings; syntax errors raise ``ValueError`` so
    ``spac ingest`` can exit with the usage code (2)."""
    kind, _, rest = spec.partition(":")
    if not kind:
        raise ValueError(f"--stage {spec!r}: empty stage kind")
    params: Dict[str, Any] = {}
    for item in _split_stage_params(rest):
        if not item.strip():
            continue
        if "=" not in item:
            raise ValueError(
                f"--stage {spec!r}: expected key=value, got {item!r}")
        k, v = item.split("=", 1)
        try:
            params[k.strip()] = json.loads(v)
        except json.JSONDecodeError:
            params[k.strip()] = v
    return kind, params


def _load_scenario(target: str):
    """Registry name, or path to a Scenario JSON file."""
    from .registry import registry
    from .scenario import Scenario
    if target in registry:
        return registry[target]
    if target.endswith(".json"):
        try:
            return Scenario.load(target)
        except (OSError, json.JSONDecodeError) as e:
            raise SystemExit(
                f"cannot load scenario file {target!r}: {e}") from e
        except (KeyError, TypeError, ValueError) as e:
            raise SystemExit(f"bad scenario spec in {target!r}: {e}") from e
    raise SystemExit(
        f"unknown scenario {target!r} (not in registry, not a .json path); "
        f"known: {', '.join(registry.names())}")


def _search_override(scenario, args):
    """CLI search flags -> SearchSpec (or the ``override`` keep-sentinel)."""
    import dataclasses
    from .scenario import _KEEP
    from repro.core.search import SearchSpec
    updates = {k: v for k, v in {
        "population": getattr(args, "population", None),
        "generations": getattr(args, "generations", None),
        "seed": getattr(args, "search_seed", None),
        "mutation_rate": getattr(args, "mutation_rate", None),
        "crossover_rate": getattr(args, "crossover_rate", None),
        "max_evaluations": getattr(args, "max_evals", None),
        "checkpoint_dir": getattr(args, "checkpoint_dir", None),
    }.items() if v is not None}
    algo = getattr(args, "search", None)
    if algo is None and not updates:
        return _KEEP
    if algo is None and scenario.search is None:
        raise SystemExit(
            "--generations/--population/... need --search ALGO (or a "
            "scenario that already carries a search spec)")
    base = scenario.search or SearchSpec()
    if algo is not None:
        updates["algorithm"] = algo
    return dataclasses.replace(base, **updates)


def _apply_overrides(scenario, args):
    trace_params = _parse_kv(getattr(args, "trace", None))
    if getattr(args, "seed", None) is not None:
        trace_params.setdefault("seed", args.seed)
    if getattr(args, "duration_s", None) is not None:
        trace_params.setdefault("duration_s", args.duration_s)
    if trace_params and scenario.domain != "switch":
        raise SystemExit("trace overrides only apply to switch-domain scenarios")
    budget_limits = _parse_kv(getattr(args, "budget", None))
    search = _search_override(scenario, args)
    co_design = getattr(args, "co_design", None)
    try:
        out = scenario.override(
            search=search,
            sla_p99_latency_ns=args.sla_p99_ns,
            sla_drop_rate=args.sla_drop_rate,
            sla_min_throughput_gbps=args.sla_min_gbps,
            trace_params=trace_params or None,
            budget_limits={k: float(v) for k, v in budget_limits.items()} or None,
            back_annotation=args.back_annotation,
            delta=args.delta,
            top_k=args.top_k,
            verify_engine=args.verify_engine,
            use_kernel=getattr(args, "use_kernel", None),
            flit_bits=args.flit_bits,
            co_design=co_design,
        )
    except ValueError as e:
        # user-input problems (unwidenable builder, un-narrowable ranged
        # spec, bad trace override) exit cleanly like every other CLI error
        raise SystemExit(str(e)) from e
    if out.co_design:
        # whether co-design came from --co-design or from the scenario file,
        # the joint space needs a search engine and a searchable protocol —
        # fail here, cleanly, not as a build_problem traceback mid-run
        if out.search is None:
            raise SystemExit(
                f"scenario {out.name!r} has co-design on but no search "
                "spec: add --search nsga2 (the joint protocol x "
                "architecture space is not enumerable)")
        try:
            out.protocol.space()
        except ValueError as e:
            raise SystemExit(str(e)) from e
    return out


def _add_override_flags(p: argparse.ArgumentParser) -> None:
    from repro.core.dse import USE_KERNEL_MODES, VERIFY_ENGINES
    g = p.add_argument_group("scenario overrides")
    g.add_argument("--sla-p99-ns", type=float, default=None,
                   help="p99 latency SLA in ns")
    g.add_argument("--sla-drop-rate", type=float, default=None,
                   help="target tail drop rate epsilon")
    g.add_argument("--sla-min-gbps", type=float, default=None,
                   help="minimum sustained throughput (Gbps)")
    g.add_argument("--seed", type=int, default=None, help="trace generator seed")
    g.add_argument("--duration-s", type=float, default=None,
                   help="trace duration in seconds (generators that take it)")
    g.add_argument("--trace", action="append", metavar="KEY=VAL",
                   help="extra trace generator param (repeatable)")
    g.add_argument("--budget", action="append", metavar="KEY=VAL",
                   help="resource budget limit override (repeatable)")
    g.add_argument("--flit-bits", type=int, default=None)
    g.add_argument("--top-k", type=int, default=None,
                   help="stage-3 exploration width")
    g.add_argument("--delta", type=float, default=None,
                   help="stage-1 timing slack")
    g.add_argument("--back-annotation", action=argparse.BooleanOptionalAction,
                   default=None, help="eta from cycle sim (slow) vs analytic")
    g.add_argument("--verify-engine", choices=VERIFY_ENGINES,
                   default=None,
                   help="stage-4 fidelity rung: batched netsim (default), "
                        "cycle-accurate datapath for every survivor, or "
                        "auto (netsim front + cycle-sim champion)")
    g.add_argument("--use-kernel", choices=USE_KERNEL_MODES, default=None,
                   help="segmented netsim kernels for the batched stage-2/4 "
                        "engines: auto (kernel when available, bit-exact "
                        "oracle fallback), on, or off (legacy scans)")
    from repro.core.search import SEARCH_ALGORITHMS
    gs = p.add_argument_group(
        "search engine (generational NSGA-II instead of exhaustive "
        "stage-1/2 enumeration)")
    gs.add_argument("--search", choices=SEARCH_ALGORITHMS, default=None,
                    help="enable the generational engine over the "
                         "problem's parameterized design space")
    gs.add_argument("--generations", type=int, default=None,
                    help="generation budget")
    gs.add_argument("--population", type=int, default=None,
                    help="population size per generation")
    gs.add_argument("--search-seed", type=int, default=None,
                    help="engine RNG seed (bit-reproducible)")
    gs.add_argument("--mutation-rate", type=float, default=None)
    gs.add_argument("--crossover-rate", type=float, default=None)
    gs.add_argument("--max-evals", type=int, default=None,
                    help="hard cap on evaluated genomes (an upper bound on "
                         "surrogate rows: pruned/duplicate genomes are "
                         "answered from cache)")
    gs.add_argument("--checkpoint-dir", default=None,
                    help="save search state here every generation "
                         "(campaigns nest per-scenario subdirectories)")
    gs.add_argument("--resume", action="store_true",
                    help="resume a checkpointed search from its "
                         "checkpoint directory")
    gs.add_argument("--co-design", action=argparse.BooleanOptionalAction,
                    default=None, dest="co_design",
                    help="search the protocol layout jointly with the "
                         "architecture: per-field width genes join the "
                         "genome (point protocol specs widen to the default "
                         "co-design menus; needs --search)")
    gm = p.add_argument_group(
        "device mesh (results are bit-identical at any device count)")
    gm.add_argument("--devices", type=int, default=None, metavar="N",
                    help="shard the batched stage-2/stage-4 scans over N "
                         "devices (default 1 = the serial path; an execution "
                         "knob, never part of the scenario/report, so "
                         "checkpoints resume across device counts)")
    gm.add_argument("--scenario-devices", type=int, default=None, metavar="M",
                    help="second, data-parallel mesh axis campaigns use to "
                         "spread scenario groups (total devices = N*M)")


def _mesh_from_args(args):
    """--devices/--scenario-devices -> Optional[MeshSpec] (None = serial)."""
    devices = getattr(args, "devices", None)
    scenario_axis = getattr(args, "scenario_devices", None)
    if devices is None and scenario_axis is None:
        return None
    from .scenario import MeshSpec
    try:
        spec = MeshSpec(devices=devices or 1, scenario_axis=scenario_axis or 1)
    except ValueError as e:
        raise SystemExit(str(e)) from e
    return None if spec.is_single() else spec


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="spac",
        description="SPAC: protocol-adaptive switch customization — "
                    "declarative scenarios from protocol to Pareto front.")
    sub = p.add_subparsers(dest="cmd", required=True)

    lp = sub.add_parser("list", help="list registry scenarios")
    lp.add_argument("--json", action="store_true", help="emit JSON")

    sp = sub.add_parser("show", help="dump a scenario spec as JSON")
    sp.add_argument("scenario", help="registry name or .json path")

    cp = sub.add_parser(
        "check",
        help="static spec diagnostics (SPAC1xx): addressability, SLA "
             "satisfiability, budget vs the minimal plan, dead co-design "
             "genes — no trace, no jit; exits 0 clean / 1 findings / 2 usage")
    cp.add_argument("scenarios", nargs="+",
                    help="registry names or .json paths")
    cp.add_argument("--format", choices=("text", "json"), default="text")

    tp = sub.add_parser(
        "lint",
        help="determinism/jit-hygiene lint (SPAC2xx) — same engine as the "
             "spaclint entry point")
    tp.add_argument("paths", nargs="*",
                    help="files or directories to lint (default: .)")
    tp.add_argument("--format", choices=("text", "json"), default="text")
    tp.add_argument("--select", default=None, metavar="CODES",
                    help="comma-separated rule codes to run")
    tp.add_argument("--list-rules", action="store_true",
                    help="print the rule catalogue and exit")

    ip = sub.add_parser(
        "ingest",
        help="pcap/CSV capture -> Trace .npz, through a declarative stage "
             "pipeline (filter/remap_ports/rescale_time/clip) plus "
             "generative stressors (incast/zipf_drift/diurnal); exits 0 "
             "ok / 2 malformed input")
    ip.add_argument("capture", help="input .pcap/.cap or .csv path")
    ip.add_argument("-o", "--out", default=None, metavar="FILE",
                    help="output .npz path (default: capture stem + .npz)")
    ip.add_argument("--name", default=None,
                    help="trace name recorded in the .npz (default: stem)")
    ip.add_argument("--n-ports", type=int, default=None,
                    help="declared endpoint count (default: inferred from "
                         "the max src/dst id seen)")
    ip.add_argument("--link-gbps", type=float, default=100.0,
                    help="link rate the trace models (default 100)")
    ip.add_argument("--stage", action="append", metavar="KIND[:K=V,...]",
                    help="pipeline stage, repeatable and order-preserving; "
                         "values are JSON literals, e.g. "
                         "--stage 'clip:max_packets=1000' "
                         "--stage 'incast:dst=0,n_senders=4,n_packets=64'")
    ip.add_argument("--seed", type=int, default=0,
                    help="pipeline seed; stage i draws an independent "
                         "stream from (seed, i), so results are "
                         "bit-reproducible")

    rp = sub.add_parser("run", help="run one scenario")
    rp.add_argument("scenario", help="registry name or .json path")
    _add_override_flags(rp)
    rp.add_argument("--out", default=None, metavar="FILE",
                    help="write the structured report as JSON")
    rp.add_argument("--save-config", default=None, metavar="FILE",
                    help="write the (post-override) scenario spec as JSON")
    rp.add_argument("-v", "--verbose", action="store_true")

    wp = sub.add_parser("sweep", help="run a multi-scenario campaign")
    wp.add_argument("scenarios", nargs="*",
                    help="registry names (overrides below apply to each)")
    wp.add_argument("--config", default=None, metavar="FILE",
                    help="campaign JSON (see module docstring for the schema)")
    _add_override_flags(wp)
    wp.add_argument("--out", default=None, metavar="FILE",
                    help="write the campaign report as JSON")
    wp.add_argument("-v", "--verbose", action="store_true")

    vp = sub.add_parser(
        "serve",
        help="continuously-batched DSE service: scenario requests share "
             "fixed-width jitted stage-2/stage-4 calls and content-addressed "
             "trace/problem/report caches — repeat traffic is answered "
             "without touching a simulator")
    vp.add_argument("scenarios", nargs="*",
                    help="registry names or .json paths to enqueue")
    vp.add_argument("--requests", default=None, metavar="FILE",
                    help="request list JSON (see module docstring)")
    vp.add_argument("--repeat", type=int, default=1, metavar="N",
                    help="enqueue each positional scenario N times")
    vp.add_argument("--seed", type=int, action="append", default=None,
                    metavar="S", help="trace seed(s); repeatable — each "
                    "positional scenario is enqueued once per seed")
    vp.add_argument("--slots", type=int, default=4,
                    help="concurrent requests multiplexed per tick")
    vp.add_argument("--batch-width", type=int, default=64,
                    help="fixed stage-2 surrogate chunk width (rows)")
    vp.add_argument("--verify-width", type=int, default=16,
                    help="fixed stage-4 netsim chunk width (rows)")
    vp.add_argument("--devices", type=int, default=None, metavar="N",
                    help="shard every chunk over N devices (bit-identical "
                         "results at any device count)")
    vp.add_argument("--out", default=None, metavar="FILE",
                    help="write per-request reports + engine stats as JSON")
    vp.add_argument("--stats", action="store_true",
                    help="print cache/chunk counters after draining")
    return p


# --------------------------------------------------------------------------
# subcommands
# --------------------------------------------------------------------------

def _cmd_list(args) -> int:
    from .registry import registry
    if args.json:
        print(json.dumps([s.to_dict() for s in registry], indent=2))
        return 0
    print(f"{'name':16s} {'domain':7s} {'trace':14s} {'sla p99':>10s} "
          f"{'drop':>8s}  notes")
    for name in registry.names():
        s = registry[name]
        trace = ("routing" if s.domain == "comm"
                 else s.trace.generator or "file")
        p99 = ("-" if s.sla.p99_latency_ns == float("inf")
               else f"{s.sla.p99_latency_ns:.0f}ns")
        print(f"{name:16s} {s.domain:7s} {trace:14s} {p99:>10s} "
              f"{s.sla.drop_rate:>8.0e}  {s.notes[:60]}")
    return 0


def _cmd_show(args) -> int:
    print(_load_scenario(args.scenario).to_json())
    return 0


def _cmd_run(args) -> int:
    from .runner import run_scenario
    scenario = _apply_overrides(_load_scenario(args.scenario), args)
    if args.save_config:
        scenario.save(args.save_config)
        print(f"wrote scenario spec to {args.save_config}")
    report = run_scenario(scenario, verbose=args.verbose, resume=args.resume,
                          mesh=_mesh_from_args(args))
    print(report.summary())
    if args.out:
        with open(args.out, "w") as f:
            json.dump(report.to_dict(), f, indent=2)
        print(f"wrote report to {args.out}")
    return 0 if report.best is not None else 1


def _cmd_ingest(args) -> int:
    from repro.analysis.diagnostics import EXIT_USAGE
    from repro.traces.ingest import Pipeline, ingest
    try:
        pipe = Pipeline(seed=args.seed)
        for spec in args.stage or ():
            kind, params = _parse_stage(spec)
            pipe = pipe.then(kind, **params)
        tr = ingest(args.capture,
                    pipeline=pipe if pipe.stages else None,
                    name=args.name, n_ports=args.n_ports,
                    link_gbps=args.link_gbps)
    except (ValueError, OSError) as e:
        # malformed capture/stage input is a usage error (2), matching
        # ``spac check``'s convention for input that never became runnable
        print(f"spac ingest: {e}", file=sys.stderr)
        return EXIT_USAGE
    out = args.out or (os.path.splitext(args.capture)[0] + ".npz")
    tr.save(out)
    dur_us = ((tr.time_s[-1] - tr.time_s[0]) * 1e6) if len(tr.time_s) else 0.0
    print(f"wrote {out}: {len(tr.time_s)} packets, {tr.n_ports} ports, "
          f"{dur_us:.1f} us span, {tr.link_gbps:g} Gbps")
    return 0


def _cmd_check(args) -> int:
    import dataclasses
    from repro.analysis.check import check_scenario
    from repro.analysis.diagnostics import (EXIT_USAGE, exit_code,
                                            format_text, to_json_payload)
    diags = []
    for target in args.scenarios:
        try:
            scenario = _load_scenario(target)
        except SystemExit as e:
            # input that never became checkable is a usage error (2), kept
            # distinct from findings (1) so CI can tell the two apart
            print(f"spac check: {e}", file=sys.stderr)
            return EXIT_USAGE
        diags.extend(dataclasses.replace(d, location=f"{target}:{d.location}")
                     for d in check_scenario(scenario))
    if args.format == "json":
        print(json.dumps(to_json_payload(diags), indent=2, sort_keys=True))
    else:
        print(format_text(diags, clean_message=(
            f"spac check: {len(args.scenarios)} scenario(s) clean")))
    return exit_code(diags)


def _cmd_lint(args) -> int:
    from repro.analysis.lint import main as lint_main
    argv = []
    if args.list_rules:
        argv.append("--list-rules")
    if args.select:
        argv += ["--select", args.select]
    argv += ["--format", args.format]
    return lint_main(argv + list(args.paths))


def _cmd_sweep(args) -> int:
    from .runner import run_campaign
    if args.config:
        with open(args.config) as f:
            cfg = load_campaign_config(json.load(f))
        name, scenarios = cfg["name"], cfg["scenarios"]
    elif args.scenarios:
        name = "campaign"
        scenarios = [_load_scenario(t) for t in args.scenarios]
    else:
        raise SystemExit("sweep needs scenario names or --config FILE")
    scenarios = [_apply_overrides(s, args) for s in scenarios]
    report = run_campaign(scenarios, name=name, verbose=args.verbose,
                          resume=args.resume, mesh=_mesh_from_args(args))
    print(report.summary())
    if args.out:
        with open(args.out, "w") as f:
            json.dump(report.to_dict(), f, indent=2)
        print(f"wrote campaign report to {args.out}")
    return 0 if all(r.best is not None for r in report.reports) else 1


def _serve_requests(args):
    """CLI inputs → [(Scenario, seed|None)] in submission order."""
    pairs = []
    seeds = args.seed if args.seed else [None]
    for target in args.scenarios:
        for s in seeds:
            pairs.extend([(_load_scenario(target), s)] * max(args.repeat, 1))
    if args.requests:
        with open(args.requests) as f:
            entries = json.load(f)
        if not isinstance(entries, list):
            raise SystemExit(f"{args.requests}: expected a JSON list")
        for entry in entries:
            seed, repeat = None, 1
            if isinstance(entry, Mapping):
                entry = dict(entry)
                seed = entry.pop("seed", None)
                repeat = int(entry.pop("repeat", 1))
                inner = entry.pop("scenario", None)
                if inner is not None:
                    # {"scenario": name-or-dict, ...overrides}: the inner
                    # spec is the base the remaining keys merge into
                    if isinstance(inner, str):
                        entry["base"] = inner
                    else:
                        entry = _deep_merge(inner, entry)
            spec = resolve_entry(entry)
            pairs.extend([(spec, seed)] * max(repeat, 1))
    if not pairs:
        raise SystemExit("serve needs scenario names or --requests FILE")
    return pairs


def _cmd_serve(args) -> int:
    from .service import DSEServeEngine
    pairs = _serve_requests(args)
    eng = DSEServeEngine(slots=args.slots, batch_width=args.batch_width,
                         verify_width=args.verify_width,
                         mesh=_mesh_from_args(args))
    for scenario, seed in pairs:
        eng.submit(scenario, seed=seed)
    finished = eng.run_until_drained()
    stats = eng.stats()
    for req in finished:
        mark = "cached" if req.cached else f"{req.wall_time_s:6.2f}s"
        tail = (f"error: {req.error}" if req.error
                else f"best={req.report.get('best')}")
        print(f"  {req.rid:>6s} {req.scenario.name:16s} [{mark}] {tail}")
    n_err = sum(1 for r in finished if r.error)
    print(f"served {len(finished)} request(s), {n_err} error(s); "
          f"report cache {stats['report_hits']} hit / "
          f"{stats['report_misses']} miss, "
          f"stage2 {stats['stage2_rows']} rows / "
          f"{stats['stage2_chunks']} chunks "
          f"({stats['stage2_cands_per_sec']:.0f} cand/s)")
    if args.stats:
        print(json.dumps({k: v for k, v in stats.items()},
                         indent=2, sort_keys=True))
    if args.out:
        payload = {"requests": [dict(r.summary_dict(), report=r.report)
                                for r in finished],
                   "stats": stats}
        with open(args.out, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote serve report to {args.out}")
    return 0 if n_err == 0 else 1


def main(argv: Optional[Sequence[str]] = None) -> int:
    args = build_parser().parse_args(argv)
    return {"list": _cmd_list, "show": _cmd_show, "check": _cmd_check,
            "lint": _cmd_lint, "ingest": _cmd_ingest, "run": _cmd_run,
            "sweep": _cmd_sweep, "serve": _cmd_serve}[args.cmd](args)


if __name__ == "__main__":
    sys.exit(main())
